"""BENCH-file section merging (benchmarks/bench_queries.py).

Regression coverage for the shared-file clobbering bugs: ``run_batch``
used to merge-preserve only the ``"sharded"`` key of BENCH_queries.json
(anything else — including the cache section — was silently dropped) and
``run_mixed`` overwrote BENCH_updates.json wholesale.  Every writer now
routes through ``_write_bench_section``: one mode owns one top-level
section and every foreign key survives a re-run of any sibling mode.
"""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks.bench_queries import (_read_bench_json, _write_bench_section,
                                      run_batch, run_cache, run_mixed,
                                      run_sharded)

ALL_SECTIONS = ("batch", "sharded", "cache", "mixed", "recover", "failover")


def test_write_bench_section_round_trip_all_modes(tmp_path):
    """Writing every mode's section in sequence — twice, in two orders —
    loses nothing: the pre-seeded foreign key and every section survive."""
    out = tmp_path / "BENCH.json"
    out.write_text(json.dumps({"foreign_tool_key": {"keep": "me"}}))
    for i, section in enumerate(ALL_SECTIONS):
        _write_bench_section(out, "unused-default.json", section, {"run": i})
    for i, section in enumerate(reversed(ALL_SECTIONS)):  # re-run, reordered
        _write_bench_section(out, "unused-default.json", section,
                             {"run": 100 + i})
    doc = json.loads(out.read_text())
    assert doc["foreign_tool_key"] == {"keep": "me"}
    for i, section in enumerate(reversed(ALL_SECTIONS)):
        assert doc[section] == {"run": 100 + i}       # last write wins...
    assert set(doc) == {"foreign_tool_key", *ALL_SECTIONS}  # ...nothing lost


def test_write_bench_section_tolerates_corrupt_file(tmp_path):
    out = tmp_path / "BENCH.json"
    out.write_text("{not json")
    _write_bench_section(out, "unused-default.json", "batch", {"ok": 1})
    assert json.loads(out.read_text()) == {"batch": {"ok": 1}}
    assert _read_bench_json(tmp_path / "missing.json") == {}


def test_queries_bench_writers_preserve_foreign_sections(tmp_path):
    """REAL runs of every BENCH_queries.json writer against one file: each
    mode lands in its own section and no run disturbs the others."""
    out = tmp_path / "BENCH_queries.json"
    out.write_text(json.dumps({"sentinel": 42}))
    run_batch(rows=2_000, n_queries=16, batch_sizes=(1, 8),
              out_path=str(out), backend="numpy")
    run_sharded(rows=2_000, n_queries=16, shard_counts=(1, 2),
                out_path=str(out))
    run_cache(rows=2_000, n_queries=32, n_hot=4, out_path=str(out),
              smoke=True)
    doc = json.loads(out.read_text())
    assert doc["sentinel"] == 42
    assert set(doc) == {"sentinel", "batch", "sharded", "cache"}
    assert doc["batch"]["single_qps"] > 0
    assert doc["sharded"]["shards"]["2"]["qps"] > 0
    assert doc["cache"]["warm_hit_rate"] > 0
    assert doc["cache"]["mvcc"]["pinned_agreement"] is True
    # a re-run of one mode leaves the other two sections byte-identical
    before = {k: doc[k] for k in ("sharded", "cache")}
    run_batch(rows=2_000, n_queries=16, batch_sizes=(1, 8),
              out_path=str(out), backend="numpy")
    doc2 = json.loads(out.read_text())
    assert doc2["sentinel"] == 42
    assert {k: doc2[k] for k in ("sharded", "cache")} == before


def test_mixed_bench_writer_preserves_foreign_sections(tmp_path):
    """Regression: run_mixed used to clobber BENCH_updates.json wholesale."""
    out = tmp_path / "BENCH_updates.json"
    out.write_text(json.dumps({"other_bench": {"qps": 1.0}, "sentinel": 7}))
    run_mixed(rows=1_500, n_queries=64, insert_ratios=(0.25,), batch=32,
              out_path=str(out))
    doc = json.loads(out.read_text())
    assert doc["sentinel"] == 7
    assert doc["other_bench"] == {"qps": 1.0}
    assert doc["mixed"]["ratios"]["0.25"]["qps"] > 0
