"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-fallback
from numpy.testing import assert_allclose

from repro.kernels import bucket_histogram, range_scan_query, split_by_margin
from repro.kernels import ref
from repro.kernels.grid_histogram import grid_histogram
from repro.kernels.margin_split import margin_split
from repro.kernels.range_scan import range_scan


@pytest.mark.parametrize("n", [512, 1024, 4096])
@pytest.mark.parametrize("d", [2, 5, 8])
@pytest.mark.parametrize("tile", [256, 512])
def test_range_scan_shapes(n, d, tile):
    rng = np.random.default_rng(n + d)
    rows = rng.normal(0, 5, (d, n)).astype(np.float32)
    lo = np.full(d, -3, np.float32)
    hi = np.full(d, 3, np.float32)
    win = np.array([n // 8, n - n // 8], np.int32)
    mask_k, counts_k = range_scan(jnp.asarray(rows), jnp.asarray(lo),
                                  jnp.asarray(hi), jnp.asarray(win),
                                  tile=tile, interpret=True)
    mask_r, counts_r = ref.range_scan_ref(jnp.asarray(rows), jnp.asarray(lo),
                                          jnp.asarray(hi), jnp.asarray(win),
                                          tile=tile)
    assert np.array_equal(np.asarray(mask_k), np.asarray(mask_r))
    assert np.array_equal(np.asarray(counts_k), np.asarray(counts_r))


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(10, 2_000),
    d=st.integers(1, 6),
    seed=st.integers(0, 1_000),
)
def test_range_scan_query_property(n, d, seed):
    """Padded wrapper equals a brute-force numpy evaluation for ragged N."""
    rng = np.random.default_rng(seed)
    rows = rng.normal(0, 2, (d, n)).astype(np.float32)
    lo = rng.normal(-2, 1, d).astype(np.float32)
    hi = lo + rng.uniform(0.5, 4, d).astype(np.float32)
    count, mask = range_scan_query(rows, lo, hi, use_pallas=True)
    want = ((rows >= lo[:, None]) & (rows < hi[:, None])).all(axis=0)
    assert int(count) == int(want.sum())
    assert np.array_equal(np.asarray(mask, bool), want)


@pytest.mark.parametrize("buckets", [16, 64, 128])
@pytest.mark.parametrize("n", [999, 4096])
def test_grid_histogram_matches_ref(buckets, n):
    rng = np.random.default_rng(buckets + n)
    x = rng.normal(0, 3, n).astype(np.float32)
    d = rng.gamma(2.0, 2.0, n).astype(np.float32)
    h_k = bucket_histogram(x, d, buckets=buckets, use_pallas=True)
    h_r = bucket_histogram(x, d, buckets=buckets, use_pallas=False)
    assert_allclose(np.asarray(h_k), np.asarray(h_r), rtol=0, atol=0)
    assert float(h_k.sum()) == n  # every record lands in exactly one cell


def test_grid_histogram_agrees_with_numpy_bincount():
    rng = np.random.default_rng(7)
    n, b = 2_048, 32
    x = rng.uniform(0, 1, n).astype(np.float32)
    d = rng.uniform(0, 1, n).astype(np.float32)
    h = np.asarray(bucket_histogram(x, d, buckets=b, use_pallas=True))
    wx = (x.max() - x.min()) / b
    wd = (d.max() - d.min()) / b
    ix = np.clip(((x - x.min()) / wx).astype(int), 0, b - 1)
    jd = np.clip(((d - d.min()) / wd).astype(int), 0, b - 1)
    want = np.bincount(ix * b + jd, minlength=b * b).reshape(b, b)
    assert_allclose(h, want)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(8, 5_000),
    m=st.floats(-4, 4),
    b=st.floats(-50, 50),
    eps=st.floats(0.01, 10),
    seed=st.integers(0, 100),
)
def test_margin_split_property(n, m, b, eps, seed):
    rng = np.random.default_rng(seed)
    x = rng.uniform(-100, 100, n).astype(np.float32)
    d = (m * x + b + rng.normal(0, eps, n)).astype(np.float32)
    disp_k, in_k = split_by_margin(x, d, m, b, eps, eps, use_pallas=True)
    disp_r, in_r = split_by_margin(x, d, m, b, eps, eps, use_pallas=False)
    assert_allclose(np.asarray(disp_k), np.asarray(disp_r), rtol=1e-6, atol=1e-4)
    assert np.array_equal(np.asarray(in_k), np.asarray(in_r))
    # oracle vs float64 numpy: agree away from the margin boundary (float32
    # rounding can flip rows whose displacement sits within the f32 ulp band)
    dispf = d.astype(np.float64) - (m * x.astype(np.float64) + b)
    want = np.abs(dispf) <= eps
    band = 1e-4 * (np.abs(m * x.astype(np.float64)) + abs(b) + eps + 1.0)
    near_edge = np.abs(np.abs(dispf) - eps) <= band
    got = np.asarray(in_k)
    assert ((got == want) | near_edge).all()


def test_margin_split_matches_alg1_split():
    """Kernel path reproduces the COAX build split exactly."""
    from repro.core import LinearModel
    rng = np.random.default_rng(11)
    x = rng.uniform(0, 1_000, 8_192).astype(np.float32)
    d = (2.0 * x + 5 + rng.normal(0, 3, 8_192)).astype(np.float32)
    model = LinearModel(m=2.0, b=5.0, eps_lb=6.0, eps_ub=6.0)
    want = model.inlier_mask(x.astype(np.float64), d.astype(np.float64))
    _, got = split_by_margin(x, d, 2.0, 5.0, 6.0, 6.0, use_pallas=True)
    assert (np.asarray(got) == want).mean() > 0.999
