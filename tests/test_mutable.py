"""Mutable index lifecycle (DESIGN.md §5): delta plane, tombstones,
incremental FD maintenance, compaction.

The contract under test: after ANY interleaving of inserts/deletes (with
and without a compaction), ``query``, ``query_batch`` (numpy) and
``query_batch`` (device) return bit-identical hit sets equal to a
scratch-built ``COAXIndex`` over the final row set — and to the
``FullScan`` ground truth — across workloads that include FD-violating
inserts.  Plus the lifecycle plumbing: compaction triggers (size + §7.2
drift), epoch versioning through the engine, server write admission with
per-wave snapshot semantics, ``cancel``, and footprint accounting.
"""
import copy

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import COAXIndex, CoaxConfig, DeltaPlane, FullScan, full_rect
from repro.data import make_airline, make_generic_fd
from repro.engine import BatchQueryExecutor, QueryServer
from workloads import (NOAUTO, assert_equiv, fullscan_expected,
                       mutable_workloads, rects_for, violate_fd)


def _rects(data, n=10, seed=0):
    """Mutation-schedule rect mix (no far-out-of-range extreme rect)."""
    return rects_for(data, n=n, seed=seed, extremes=False, sample_cap=8_000)


@pytest.mark.parametrize("name,ds,more", mutable_workloads(),
                         ids=lambda w: w if isinstance(w, str) else "")
def test_interleaved_ops_equal_scratch_rebuild(name, ds, more):
    """Deterministic interleaving: base deletes, in-pattern inserts,
    FD-VIOLATING inserts, delta-log deletes, a compaction, then more writes —
    equivalence checked before AND after the compaction, numpy and device."""
    rng = np.random.default_rng(1)
    idx = COAXIndex(ds.data, NOAUTO)
    rects = _rects(ds.data, n=8, seed=0)

    idx.delete(rng.choice(ds.data.shape[0], 400, replace=False))
    fresh = more(101, 600)
    ids_a = idx.insert(fresh[:300])                      # in-pattern
    ids_b = idx.insert(violate_fd(ds, fresh[300:]))        # FD-violating
    assert idx.delta_outlier.n_live > 0, "violators must hit the outlier delta"
    idx.delete(ids_a[:50])                               # delta-log tombstones
    idx.delete(ids_b[:50])
    assert idx.delete(ids_a[:50]) == 0                   # double delete: no-op
    assert_equiv(idx, rects, device=(name == "airline"), tag=f"{name}-pre")

    info = idx.compact()
    assert info["epoch"] == idx.epoch == 1
    assert idx.delta_rows == 0 and idx.tombstone_count == 0
    assert idx.primary.epoch == idx.outlier.epoch == 1

    idx.delete(np.concatenate([ids_a[50:80], ids_b[50:80]]))
    idx.insert(violate_fd(ds, more(103, 120)))
    assert_equiv(idx, rects, device=True, tag=f"{name}-post")


def test_row_count_and_id_bookkeeping():
    ds = make_generic_fd(4_000, 4, ((0, 1),), seed=2)
    idx = COAXIndex(ds.data, NOAUTO)
    assert idx.n_rows == 4_000
    ids = idx.insert(ds.data[:70])
    assert ids.tolist() == list(range(4_000, 4_070))
    assert idx.n_rows == 4_070
    assert idx.delete(ids[:20]) == 20
    assert idx.delete([4_000_000]) == 0                  # unknown id ignored
    assert idx.n_rows == 4_050
    idx.compact()
    assert idx.n_rows == 4_050 == idx.data.shape[0]
    # ids survive compaction; the next insert continues the id sequence
    new = idx.insert(ds.data[:1])
    assert int(new[0]) == 4_070


def test_empty_index_after_deleting_everything():
    ds = make_generic_fd(2_000, 4, ((0, 1),), seed=4)
    idx = COAXIndex(ds.data, NOAUTO)
    assert idx.delete(np.arange(2_000)) == 2_000
    rects = _rects(ds.data, n=4, seed=1)
    for r in rects:
        assert idx.query(r).size == 0
    qids, rids = idx.query_batch(rects)
    assert qids.size == 0 and rids.size == 0
    idx.compact()
    assert idx.n_rows == 0 and idx.query(full_rect(4)).size == 0
    ids = idx.insert(ds.data[:10])                       # rebuild from empty
    assert np.array_equal(np.sort(idx.query(full_rect(4))), ids)


# --------------------------------------------------------------------- #
# Property test: arbitrary interleavings == scratch rebuild (satellite)
# --------------------------------------------------------------------- #
_PROP_DS = make_generic_fd(1_500, 4, ((0, 1),), seed=5)
_PROP_BASE = COAXIndex(_PROP_DS.data, NOAUTO)
_PROP_POOL = make_generic_fd(2_048, 4, ((0, 1),), seed=6).data
_PROP_RECTS = _rects(_PROP_DS.data, n=6, seed=9)

_op = st.one_of(
    st.tuples(st.just("insert"), st.integers(0, 1_900), st.integers(1, 64),
              st.booleans()),
    st.tuples(st.just("delete"), st.integers(0, 10_000), st.integers(1, 64)),
    st.tuples(st.just("compact")),
)


@settings(max_examples=12, deadline=None)
@given(st.lists(_op, min_size=1, max_size=8))
def test_property_any_interleaving_equals_scratch(ops):
    idx = copy.deepcopy(_PROP_BASE)
    for op in ops:
        if op[0] == "insert":
            _, start, m, violate = op
            rows = _PROP_POOL[start:start + m]
            if violate:
                rows = violate_fd(_PROP_DS, rows)
            idx.insert(rows)
        elif op[0] == "delete":
            _, seed, m = op
            rng = np.random.default_rng(seed)
            live = idx.live_rows()[1]
            if live.size:
                idx.delete(rng.choice(live, min(m, live.size), replace=False))
        else:
            idx.compact()
    assert_equiv(idx, _PROP_RECTS, scratch=True, tag=str(ops)[:80])


# --------------------------------------------------------------------- #
# Compaction triggers
# --------------------------------------------------------------------- #
def test_size_trigger_auto_compacts():
    ds = make_generic_fd(4_000, 4, ((0, 1),), seed=2)
    cfg = CoaxConfig(auto_compact=True, compact_min_delta=128,
                     compact_delta_frac=0.01, drift_min_delta=10**9)
    idx = COAXIndex(ds.data, cfg)
    idx.insert(ds.data[:100])                 # below both thresholds
    assert idx.compactions == 0 and idx.delta_rows == 100
    idx.insert(ds.data[:100])                 # load 200 >= max(128, 40)
    assert idx.compactions == 1 and idx.epoch == 1
    assert idx.delta_rows == 0 and idx.n_rows == 4_200


def test_drift_trigger_relearns_on_fd_break():
    """A burst of inserts following a DIFFERENT linear trend drags the live
    posterior slope away from the frozen model; the §7.2 predictability
    ratio falls below the threshold and compaction fires with a relearn."""
    ds = make_generic_fd(4_000, 4, ((0, 1),), seed=3)
    cfg = CoaxConfig(auto_compact=True, compact_min_delta=10**9,
                     compact_delta_frac=10.0, drift_min_delta=64,
                     drift_threshold=0.5)
    idx = COAXIndex(ds.data, cfg)
    assert idx.drift_predictability() > 0.9   # seeded at the frozen trend
    drifted = violate_fd(ds, make_generic_fd(3_000, 4, ((0, 1),), seed=8).data)
    idx.insert(drifted)
    assert idx.compactions == 1 and idx.epoch == 1, \
        "drift trigger should have compacted"
    # after relearn the trackers are reseeded from the merged snapshot
    assert idx.drift_predictability() > idx.config.drift_threshold


def test_delta_plane_unit():
    dp = DeltaPlane(2)
    dp.insert(np.array([[0.0, 0.0], [5.0, 5.0]], np.float32), np.array([10, 11]))
    assert len(dp) == 2 and dp.n_tombstones == 0
    absorbed = dp.tombstone_log(np.array([11, 99]))
    assert absorbed.tolist() == [True, False] and dp.n_live == 1
    assert dp.tombstone_base(np.array([3, 3, 4])) == 2    # dupes collapse
    assert dp.is_dead(np.array([3, 4, 10, 11])).tolist() == [True, True, False, True]
    rect = np.array([[-1.0, 1.0], [-1.0, 1.0]])
    assert dp.scan(rect).tolist() == [10]
    qids, rids = dp.scan_batch(np.stack([rect, full_rect(2)]))
    assert qids.tolist() == [0, 1] and rids.tolist() == [10, 10]
    assert dp.nbytes() == 2 * 2 * 4 + 2 * 8 + 3 * 8
    # compaction feed excludes tombstoned log rows
    rows, ids = dp.live_log()
    assert ids.tolist() == [10] and rows.shape == (1, 2)


# --------------------------------------------------------------------- #
# Satellites: footprint accounting, executor revalidation, server writes
# --------------------------------------------------------------------- #
def test_memory_footprint_includes_bbox_and_delta():
    ds = make_generic_fd(6_000, 5, ((0, 1), (2, 3)), seed=7)
    idx = COAXIndex(ds.data, NOAUTO)
    assert idx._outlier_lo is not None
    base = idx.memory_footprint()
    grids = idx.primary.memory_footprint() + idx.outlier.memory_footprint()
    bbox = idx._outlier_lo.nbytes + idx._outlier_hi.nbytes
    assert base >= grids + bbox               # bbox arrays are accounted
    ids = idx.insert(ds.data[:200])
    idx.delete(ids[:40])
    idx.delete(np.arange(40))
    grown = idx.memory_footprint()
    delta_bytes = idx.delta_primary.nbytes() + idx.delta_outlier.nbytes()
    assert delta_bytes > 0 and grown == base + delta_bytes
    d = idx.describe()
    assert d["outlier_bbox_bytes"] == bbox
    assert d["delta_primary"]["bytes"] + d["delta_outlier"]["bytes"] == delta_bytes
    assert d["tombstones"] == 80 and d["n_rows"] == 6_000 + 200 - 80


def test_executor_revalidates_backend_and_tracks_epochs():
    jax = pytest.importorskip("jax")
    ds = make_airline(6_000, seed=2)
    idx = COAXIndex(ds.data, NOAUTO)
    rects = _rects(ds.data, n=6, seed=3)
    ex = BatchQueryExecutor(idx, max_batch=4, backend="device")
    got = ex.execute(rects)
    idx.backend = "numpy"                     # external flip mid-stream...
    idx.insert(make_airline(64, seed=9).data)
    idx.compact()                             # ...and a compaction (epoch 1)
    got2 = ex.execute(rects)
    assert idx.backend == "device", "executor must re-assert its backend"
    s = ex.stats()
    assert s["backend"] == "device"
    assert s["epochs"] == [0, 1]              # waves stamped with their epoch
    want = fullscan_expected(*idx.live_rows(), rects)
    for i in range(rects.shape[0]):
        assert np.array_equal(got2[i], want[i]), i
    assert ex.wave_stats[-1].epoch == 1


def test_server_write_admission_and_cancel():
    ds = make_generic_fd(5_000, 4, ((0, 1),), seed=1)
    idx = COAXIndex(ds.data, NOAUTO)
    srv = QueryServer(idx, max_batch=4)
    rects = _rects(ds.data, n=5, seed=2)
    qids = srv.submit_many(rects)
    assert srv.cancel(qids[0]) and not srv.cancel(qids[0])
    assert not srv.cancel(10**6)
    w1 = srv.insert(ds.data[:80])
    w2 = srv.delete(np.arange(30))
    assert srv.stats()["writes_pending"] == 2
    res = srv.drain()
    assert qids[0] not in res and len(res) == len(rects) - 1
    assert srv.write_results[w1].size == 80 and srv.write_results[w2] == 30
    # per-wave snapshot: writes were applied before wave 1, so every answer
    # reflects them
    want = fullscan_expected(*idx.live_rows(), rects)
    for qid, w in zip(qids[1:], want[1:]):
        assert np.array_equal(res[qid], w)
    s = srv.stats()
    assert s["writes_applied"] == 2 and s["writes_pending"] == 0
    assert s["rows_inserted"] == 80 and s["rows_deleted"] == 30
    assert s["delta_rows"] == 80 and s["tombstones"] == 30
    # a drain with only writes queued still applies them
    srv.insert(ds.data[:5])
    srv.drain()
    assert srv.stats()["writes_applied"] == 3


def test_server_rejects_writes_on_immutable_engine():
    ds = make_generic_fd(1_000, 4, ((0, 1),), seed=1)
    srv = QueryServer(FullScan(ds.data))
    with pytest.raises(TypeError):
        srv.insert(ds.data[:2])
    with pytest.raises(TypeError):
        srv.delete([0])
