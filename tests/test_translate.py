"""Query translation (Eq. 2) unit + property tests."""
import numpy as np
from _hypothesis_compat import given, settings, st  # hypothesis or skip-fallback

from repro.core import LinearModel, translate_dependent_interval, translate_rect
from repro.core.types import FDGroup, full_rect


def _model(m, b, lb, ub):
    return LinearModel(m=m, b=b, eps_lb=lb, eps_ub=ub)


def test_positive_slope_interval():
    m = _model(2.0, 10.0, 1.0, 1.0)
    lo, hi = translate_dependent_interval(m, 20.0, 30.0)
    # d >= 20 requires 2x + 10 + 1 >= 20 -> x >= 4.5
    # d <= 30 requires 2x + 10 - 1 <= 30 -> x <= 10.5
    assert abs(lo - 4.5) < 1e-12 and abs(hi - 10.5) < 1e-12


def test_negative_slope_interval_flips():
    m = _model(-2.0, 10.0, 1.0, 1.0)
    lo, hi = translate_dependent_interval(m, -30.0, -20.0)
    assert lo < hi


@settings(max_examples=200, deadline=None)
@given(
    slope=st.floats(-5, 5).filter(lambda v: abs(v) > 0.05),
    intercept=st.floats(-100, 100),
    eps_lb=st.floats(0.01, 10),
    eps_ub=st.floats(0.01, 10),
    x=st.floats(-1000, 1000),
    dlo=st.floats(-500, 500),
    width=st.floats(0.1, 200),
    disp_frac=st.floats(0, 1),
)
def test_property_inlier_matching_dep_constraint_is_in_window(
        slope, intercept, eps_lb, eps_ub, x, dlo, width, disp_frac):
    """Any inlier point whose dependent value satisfies [dlo, dhi) MUST fall
    inside the translated x-window (no false negatives — paper §4)."""
    model = _model(slope, intercept, eps_lb, eps_ub)
    dhi = dlo + width
    # construct an inlier at displacement in [-eps_lb, eps_ub]
    disp = -eps_lb + disp_frac * (eps_lb + eps_ub)
    d = slope * x + intercept + disp
    if not (dlo <= d < dhi):
        return  # point doesn't match the constraint; nothing to assert
    t_lo, t_hi = translate_dependent_interval(model, dlo, dhi)
    assert t_lo - 1e-6 <= x <= t_hi + 1e-6


def test_translate_rect_intersects_direct_and_derived():
    g = FDGroup(predictor=0, dependents=(1,), models={1: _model(1.0, 0.0, 1.0, 1.0)})
    rect = full_rect(3)
    rect[0] = [2.0, 50.0]     # direct constraint on predictor
    rect[1] = [10.0, 20.0]    # dependent constraint -> x in [9, 21]
    out = translate_rect(rect, [g], keep_dims=[0, 2])
    assert out.shape == (2, 2)
    assert abs(out[0, 0] - 9.0) < 1e-9   # max(2, 9)
    assert abs(out[0, 1] - 21.0) < 1e-9  # min(50, 21)
    assert np.isinf(out[1]).all()


def test_translate_rect_empty_intersection_clamps():
    g = FDGroup(predictor=0, dependents=(1,), models={1: _model(1.0, 0.0, 0.5, 0.5)})
    rect = full_rect(2)
    rect[0] = [100.0, 200.0]
    rect[1] = [0.0, 1.0]      # translated window [-0.5, 1.5] — disjoint
    out = translate_rect(rect, [g], keep_dims=[0])
    assert out[0, 0] >= out[0, 1] - 1e-9 or out[0, 1] <= 100.0  # empty window


def test_unconstrained_dependent_is_noop():
    g = FDGroup(predictor=0, dependents=(1,), models={1: _model(2.0, 0.0, 1.0, 1.0)})
    rect = full_rect(2)
    rect[0] = [5.0, 6.0]
    out = translate_rect(rect, [g], keep_dims=[0])
    assert out[0].tolist() == [5.0, 6.0]
