"""COAX core invariants: the index returns EXACTLY the full-scan result set
on every engine, margins/grouping reproduce Table 1, translation (Eq. 2)
over-approximates but never loses results."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-fallback

from repro.core import (
    COAXIndex,
    CoaxConfig,
    ColumnFiles,
    FullScan,
    GridFile,
    STRTree,
    SoftFDConfig,
    UniformGrid,
    full_rect,
    point_rect,
    translate_rect,
)
from repro.core.softfd import bayes_linear_regress, BayesianLinearModel
from repro.data import knn_rect_queries, make_airline, make_generic_fd, make_osm


@pytest.fixture(scope="module")
def airline():
    return make_airline(60_000, seed=3)


@pytest.fixture(scope="module")
def osm():
    return make_osm(60_000, seed=3)


def _engines(data):
    return [
        COAXIndex(data),
        UniformGrid(data),
        ColumnFiles(data),
        STRTree(data),
    ]


@pytest.mark.parametrize("ds_name", ["airline", "osm"])
def test_all_engines_match_full_scan(ds_name, airline, osm):
    ds = {"airline": airline, "osm": osm}[ds_name]
    fs = FullScan(ds.data)
    engines = _engines(ds.data)
    rects = knn_rect_queries(ds.data, 15, 150, seed=1, sample_cap=10_000)
    for r in rects:
        truth = fs.query(r)
        for eng in engines:
            got = eng.query(r)
            assert np.array_equal(got, truth), (
                f"{eng.name} mismatch on {ds_name}: {len(got)} vs {len(truth)}")


def test_point_queries_match(airline):
    fs = FullScan(airline.data)
    cx = COAXIndex(airline.data)
    rng = np.random.default_rng(0)
    for i in rng.choice(airline.data.shape[0], 25, replace=False):
        r = point_rect(airline.data[i])
        truth = fs.query(r)
        assert i in truth
        assert np.array_equal(cx.query(r), truth)


def test_airline_grouping_matches_table1(airline):
    cx = COAXIndex(airline.data)
    # Table 1: two groups of three correlated dims; 2-4 indexed dims; ~92%.
    group_members = [set([g.predictor, *g.dependents]) for g in cx.groups]
    assert set(map(frozenset, group_members)) == {
        frozenset({0, 1, 2}), frozenset({3, 4, 5})}
    assert 2 <= len(cx.keep_dims) <= 4 + 2  # +2 uncorrelated cols always kept
    assert 0.85 <= cx.primary_ratio <= 0.97


def test_osm_grouping_matches_table1(osm):
    cx = COAXIndex(osm.data)
    assert [set([g.predictor, *g.dependents]) for g in cx.groups] == [{0, 1}]
    assert 0.65 <= cx.primary_ratio <= 0.85
    assert len(cx.keep_dims) == 3


def test_memory_footprint_reduction(airline):
    """Paper headline (§8.2.4): at EQUAL per-dim resolution, dropping the
    dependent dims (8 -> n-m-1 grid dims) shrinks the directory by orders of
    magnitude — cells go from c^8 to c^(n-m-1)."""
    c = 8
    cx = COAXIndex(airline.data, CoaxConfig(primary_cells_per_dim=c,
                                            outlier_cells_per_dim=2))
    ug = UniformGrid(airline.data, cells_per_dim=c)
    assert len(cx.primary.grid_dims) <= 4
    ratio = ug.memory_footprint() / cx.memory_footprint()
    assert ratio > 1e3, ratio  # 8 dims -> 3 grid dims at c=8: >= 3 orders


def test_unconstrained_query_returns_everything(airline):
    cx = COAXIndex(airline.data)
    out = cx.query(full_rect(airline.data.shape[1]))
    assert out.size == airline.data.shape[0]


def test_translation_never_loses_primary_rows(airline):
    """S-box contains R-box (paper §7.1): every primary row matching the
    original rect must fall inside the translated nav rect."""
    cx = COAXIndex(airline.data)
    rects = knn_rect_queries(airline.data, 10, 400, seed=5, sample_cap=10_000)
    prim_rows = cx.primary.rows
    for r in rects:
        nav = cx.translate(r)
        from repro.core import rect_contains
        full_hit = rect_contains(r, prim_rows)
        nav_full = np.stack([nav[:, 0], nav[:, 1]], axis=1)
        sub = prim_rows[:, cx.keep_dims]
        nav_hit = np.all((sub >= nav[:, 0]) & (sub <= nav[:, 1]), axis=1)
        # anything matching the full predicate must be inside the nav window
        assert not np.any(full_hit & ~nav_hit)


@settings(max_examples=25, deadline=None)
@given(
    n_dims=st.integers(3, 6),
    noise=st.floats(0.005, 0.05),
    outlier=st.floats(0.0, 0.25),
    seed=st.integers(0, 10_000),
)
def test_property_coax_equals_fullscan(n_dims, noise, outlier, seed):
    """Property: for arbitrary FD structure/noise/outlier mass, COAX returns
    the exact full-scan result set."""
    ds = make_generic_fd(4_000, n_dims, ((0, 1),), noise=noise,
                         outlier_frac=outlier, seed=seed)
    cfg = CoaxConfig(softfd=SoftFDConfig(sample_count=4_000, seed=seed))
    cx = COAXIndex(ds.data, cfg)
    fs = FullScan(ds.data)
    rects = knn_rect_queries(ds.data, 4, 60, seed=seed + 1, sample_cap=4_000)
    for r in rects:
        assert np.array_equal(cx.query(r), fs.query(r))


def test_bayesian_incremental_update_matches_batch():
    rng = np.random.default_rng(0)
    x = rng.normal(0, 5, 2_000)
    y = 3.0 * x + 2.0 + rng.normal(0, 0.5, 2_000)
    m1, b1 = bayes_linear_regress(x, y)
    blm = BayesianLinearModel.empty()
    for lo in range(0, 2_000, 100):  # stream in chunks (paper §5 updates)
        blm.update(x[lo:lo + 100], y[lo:lo + 100])
    m2, b2 = blm.posterior_mean()
    assert abs(m1 - m2) < 1e-9 and abs(b1 - b2) < 1e-9
    assert abs(m1 - 3.0) < 0.05 and abs(b1 - 2.0) < 0.1


def test_supplied_groups_skip_detection(airline):
    cx1 = COAXIndex(airline.data)
    cx2 = COAXIndex(airline.data, groups=cx1.groups)
    fs = FullScan(airline.data)
    r = knn_rect_queries(airline.data, 3, 100, seed=9, sample_cap=5_000)[0]
    assert np.array_equal(cx2.query(r), fs.query(r))
