"""§7 theory: closed forms vs Monte-Carlo simulation (Thm 7.1-7.4, Eq. 5)."""
import numpy as np
import pytest

from repro.core import theory


def test_effectiveness_eq5():
    assert theory.effectiveness(10.0, 0.0) == 1.0
    assert abs(theory.effectiveness(10.0, 1.0) - 10.0 / 12.0) < 1e-12
    # monotone: tighter margin -> higher effectiveness
    effs = [theory.effectiveness(5.0, e) for e in (0.1, 0.5, 1.0, 5.0)]
    assert all(a > b for a, b in zip(effs, effs[1:]))


def test_areas_eq3_eq4_consistent():
    q_y, eps, a = 7.0, 1.5, 2.0
    sr = theory.result_area(q_y, eps, a)
    ss = theory.scanned_area(q_y, eps, a)
    assert abs(sr / ss - theory.effectiveness(q_y, eps)) < 1e-12
    assert ss >= sr


@pytest.mark.parametrize("eps,sigma", [(20.0, 1.0), (8.0, 0.5)])
def test_met_theorem_7_1(eps, sigma):
    """Thm 7.1 holds in the sigma << eps Brownian limit; a discrete walk
    exits with overshoot ~0.58*sigma (ladder height), biasing the simulated
    MET to ~(eps + 0.58*sigma)^2 — so test at large eps/sigma with a band
    wide enough for that bias."""
    mean, var = theory.simulate_met(eps, sigma, trials=800, seed=2)
    expect = theory.met_expectation(eps, sigma)
    assert abs(mean - expect) / expect < 0.12


def test_met_variance_theorem_7_3():
    eps, sigma = 20.0, 1.0
    _, var = theory.simulate_met(eps, sigma, trials=3_000, seed=3)
    expect = theory.met_variance(eps, sigma)
    assert abs(var - expect) / expect < 0.3  # MC + overshoot bias band


def test_optimal_slope_theorem_7_2():
    """MET is maximised at slope == mean gap (zero drift)."""
    eps, sigma, mu = 8.0, 1.0, 2.0
    at_mu = theory.met_drifted_expectation(eps, sigma, 0.0)
    off = [theory.met_drifted_expectation(eps, sigma, d) for d in (-0.5, -0.1, 0.1, 0.5)]
    assert all(at_mu >= o for o in off)
    # simulated drifted walk is also worse
    m_drift, _ = theory.simulate_met(eps, sigma, mu=mu, slope=mu + 0.2,
                                     trials=500, seed=4)
    m_opt, _ = theory.simulate_met(eps, sigma, mu=mu, slope=mu, trials=500, seed=4)
    assert m_opt > m_drift


def test_segment_count_theorem_7_4():
    rng = np.random.default_rng(5)
    n, sigma, eps = 150_000, 1.0, 10.0
    gaps = rng.normal(4.0, sigma, n)
    segs = theory.greedy_segment_count(gaps, eps)
    expect = theory.expected_segments(n, eps, sigma)
    assert abs(segs - expect) / expect < 0.35  # renewal asymptotics, loose band
