"""Shared differential-test harness: ONE workload registry + ONE oracle.

Every equivalence test in this repo (batch vs scalar, device vs numpy,
mutated vs scratch rebuild, sharded vs single index) used to hand-roll its
own synthetic workloads and oracle assertions; they all live here now so a
new backend or plane (DESIGN.md §6's ``ShardedCOAX`` being the first) gets
the full (workload × rect-shape × mutation-schedule) matrix by importing
three helpers instead of copying them.

Registry
--------
``engine_workloads()``   — the 4 read-path workloads (airline, osm,
    generic_fd, and a no-outlier variant that exercises the empty outlier
    grid + disabled bbox skip).
``mutable_workloads()``  — 3 workloads paired with a ``more(seed, m)``
    generator producing fresh in-pattern rows for insert schedules.
``rects_for(data)``      — the standard rect mix: knn rects + full-range +
    far-out-of-range + point (empty-result) + half-open (±inf bounds).
``zipf_rects(data)``     — Zipfian hot-rect mix (repeats + nested subsets)
    for the §9 semantic-cache gate (DESIGN.md §9.2).
``violate_fd(ds, rows)`` — break the workload's first FD group on a copy
    (drives outlier-delta and drift paths).

Oracles
-------
``fullscan_expected(rows, ids, rects)`` — ground-truth sorted hit ids per
    rect from a brute-force scan of an explicit row set.
``assert_equiv(idx, rects, ...)`` — THE differential assertion: the index's
    scalar and batched answers must equal the FullScan ground truth over its
    own ``live_rows()``; optionally also a rebuild-from-scratch ``COAXIndex``
    over that row set, and the device backend's batched answers.
"""
import numpy as np
import pytest

from repro.core import COAXIndex, CoaxConfig, FullScan, full_rect, point_rect
from repro.data import knn_rect_queries, make_airline, make_generic_fd, make_osm
from repro.engine import split_hits

NOAUTO = CoaxConfig(auto_compact=False)


_ENGINE_WORKLOADS = {
    "airline": lambda: make_airline(20_000, seed=3),
    "osm": lambda: make_osm(20_000, seed=3),
    "generic_fd": lambda: make_generic_fd(15_000, 5, ((0, 1), (2, 3)), seed=7),
    "generic_no_outliers":
        lambda: make_generic_fd(15_000, 4, ((0, 1),), outlier_frac=0.0, seed=11),
}


def engine_workloads():
    """(name, Dataset) pairs for read-path equivalence matrices."""
    return [(name, build()) for name, build in _ENGINE_WORKLOADS.items()]


def engine_workload(name):
    """Build ONE registry workload by name (skips the other datasets)."""
    return _ENGINE_WORKLOADS[name]()


def mutable_workloads(n_rows: int = 12_000):
    """(name, Dataset, more) triples; ``more(seed, m)`` yields m fresh rows
    following the same generative pattern, for insert schedules."""
    return [
        ("airline", make_airline(n_rows, seed=3),
         lambda s, m: make_airline(m, seed=s).data),
        ("osm", make_osm(n_rows, seed=3),
         lambda s, m: make_osm(m, seed=s).data),
        ("generic_fd",
         make_generic_fd(max(n_rows - 2_000, 1_000), 5, ((0, 1), (2, 3)), seed=7),
         lambda s, m: make_generic_fd(m, 5, ((0, 1), (2, 3)), seed=s).data),
    ]


def rects_for(data, n=24, seed=0, extremes=True, sample_cap=10_000):
    """The standard rect mix every equivalence matrix runs.

    knn rects around sampled rows, a full-range rect, a far-out-of-range
    rect (``extremes``; exercises f32 overflow rounding), a point rect on
    row 0 (usually empty under half-open semantics), and a half-open rect
    with ±inf bounds.
    """
    d = data.shape[1]
    rects = list(knn_rect_queries(data, n, 64, seed=seed, sample_cap=sample_cap))
    rects.append(full_rect(d))                            # full-range rect
    if extremes:
        rects.append(np.stack([np.full(d, 1e12), np.full(d, 1e12 + 1)], axis=-1))
    rects.append(point_rect(data[0]))                     # empty-result rect
    lop = np.full(d, -np.inf)
    lop[0] = float(np.median(data[:, 0]))
    rects.append(np.stack([lop, np.full(d, np.inf)], axis=-1))  # half-open
    return np.stack(rects)


def zipf_rects(data, n=256, n_hot=16, alpha=1.1, nest_frac=0.25, seed=0,
               sample_cap=10_000):
    """Zipfian hot-rect query mix — the §9 semantic-cache gate workload
    (DESIGN.md §9.2; the ROADMAP cache item's Zipfian sweep).

    Draws ``n`` rects from a pool of ``n_hot`` "hot" knn rects under a
    Zipf(``alpha``) popularity law, so a small set of rects dominates the
    stream the way skewed real query logs do (the Tsunami motivation).
    Repeated draws are BIT-IDENTICAL to their pool rect — exact cache hits
    — and a ``nest_frac`` fraction are re-drawn shrunk strictly inside
    their hot rect (per-side shrink ≤ 30% of the width), exercising the
    containment/partial-hit path.  Deterministic per ``seed``.
    """
    if n_hot < 1:
        raise ValueError("n_hot must be >= 1")
    rng = np.random.default_rng(seed)
    pool = np.asarray(knn_rect_queries(data, n_hot, 64, seed=seed,
                                       sample_cap=sample_cap), np.float64)
    ranks = np.arange(1, n_hot + 1, dtype=np.float64)
    w = ranks ** -float(alpha)
    w /= w.sum()
    picks = rng.choice(n_hot, size=n, p=w)
    rects = pool[picks].copy()
    nest = rng.random(n) < nest_frac
    if nest.any():
        sub = rects[nest]
        width = sub[:, :, 1] - sub[:, :, 0]
        lo_shrink = rng.uniform(0.0, 0.3, size=width.shape) * width
        hi_shrink = rng.uniform(0.0, 0.3, size=width.shape) * width
        sub[:, :, 0] = sub[:, :, 0] + lo_shrink
        sub[:, :, 1] = np.maximum(sub[:, :, 1] - hi_shrink, sub[:, :, 0])
        rects[nest] = sub
    return rects


def violate_fd(ds, rows):
    """Break the workload's first FD group on a copy of ``rows`` (inserts
    built from this land in the outlier delta and drag the drift tracker)."""
    rows = rows.copy()
    dep = ds.correlated_groups[0][1]
    rows[:, dep] = rows[:, dep] * 3.0 + 1000.0
    return rows


# --------------------------------------------------------------------- #
# Oracles
# --------------------------------------------------------------------- #
def fullscan_expected(rows, ids, rects):
    """Ground truth: sorted original-id hits per rect, by brute-force scan
    of the explicit (rows, ids) set."""
    ids = np.asarray(ids, dtype=np.int64)
    fs = FullScan(rows)
    return [np.sort(ids[fs.query(r)]) for r in rects]


def assert_equiv(idx, rects, device=False, scratch=True, tag=""):
    """idx's scalar + batched answers == FullScan ground truth over its own
    live rows; optionally == a scratch-rebuilt ``COAXIndex`` (original ids
    preserved) and == the device backend's batched answers.

    Works for any engine with the ``COAXIndex`` serving surface (``query``,
    ``query_batch_split``, ``live_rows``), including ``ShardedCOAX``.
    """
    rows, ids = idx.live_rows()
    want = fullscan_expected(rows, ids, rects)
    batch = idx.query_batch_split(rects)
    for i, r in enumerate(rects):
        assert np.array_equal(idx.query(r), want[i]), (tag, "scalar", i)
        assert np.array_equal(batch[i], want[i]), (tag, "batch", i)
    if scratch:
        fresh = COAXIndex(rows, NOAUTO, row_ids=ids)
        for i, r in enumerate(rects):
            assert np.array_equal(fresh.query(r), want[i]), (tag, "scratch", i)
    if device:
        pytest.importorskip("jax")
        bk = idx.backend
        idx.backend = "device"
        qd, rd = idx.query_batch(rects)
        idx.backend = bk
        dev = split_hits(qd, rd, rects.shape[0])
        for i in range(rects.shape[0]):
            assert np.array_equal(dev[i], want[i]), (tag, "device", i)
    return want
