"""Extended coverage: split local/global cache, dry-run report/probe
machinery, MoE capacity semantics, loader epoch rollover, launcher helpers."""
import dataclasses
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_config
from repro.configs import SHAPES, get_config
from repro.models import build_model
from repro.models.moe import moe_apply, moe_init

DRYRUN = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


# ---------------- split local/global KV cache (hillclimb cell B) ----------- #

def test_split_local_cache_equivalence():
    base = tiny_config(get_config("gemma2-27b"))
    rng = np.random.default_rng(0)
    S = 12
    toks = jnp.asarray(rng.integers(0, 200, (2, S + 1)), jnp.int32)
    outs = {}
    for split in (False, True):
        cfg = dataclasses.replace(base, split_local_cache=split)
        model = build_model(cfg)
        params, _ = model.init(jax.random.key(1))
        _, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(
            params, {"tokens": toks[:, :S]})
        if split:
            assert set(cache) == {"k_loc", "v_loc", "k_glob", "v_glob"}
            assert cache["k_loc"].shape[2] == cfg.window  # ring slots only
            assert cache["k_glob"].shape[2] == 32
        dl, _ = jax.jit(model.decode_step)(params, cache, toks[:, S:S + 1],
                                           jnp.int32(S))
        outs[split] = np.asarray(dl.astype(jnp.float32))
    # same math, modulo bf16 summation-order noise from ring slot rotation
    assert np.abs(outs[False] - outs[True]).max() < 0.02


def test_split_cache_memory_is_smaller():
    cfg = dataclasses.replace(get_config("gemma2-27b"), split_local_cache=True)
    model = build_model(cfg)
    flat = build_model(get_config("gemma2-27b")).init_cache(2, 32768, abstract=True)
    split = model.init_cache(2, 32768, abstract=True)
    size = lambda c: sum(np.prod(v.shape) * v.dtype.itemsize for v in c.values())
    assert size(split) < 0.6 * size(flat)  # local layers: 4096/32768 slots


# ---------------- MoE capacity semantics ----------------------------------- #

def test_moe_capacity_drops_monotone():
    """Lower capacity factor -> more dropped pairs -> larger output deficit."""
    rng = jax.random.key(0)
    params, _ = moe_init(rng, 32, 64, 4)
    x = jax.random.normal(jax.random.key(1), (2, 16, 32), jnp.float32)

    def out_norm(cf):
        out, _ = moe_apply(params, x, n_experts=4, top_k=2, capacity_factor=cf)
        return float(jnp.linalg.norm(out.astype(jnp.float32)))

    full = out_norm(8.0)     # ample capacity: nothing dropped
    tight = out_norm(0.25)   # heavy drops
    assert tight < full


def test_moe_ample_capacity_routes_every_token():
    params, _ = moe_init(jax.random.key(0), 16, 32, 4)
    x = jax.random.normal(jax.random.key(1), (2, 8, 16), jnp.float32)
    out, aux = moe_apply(params, x, n_experts=4, top_k=2, capacity_factor=8.0)
    assert out.shape == x.shape
    assert float(aux) > 0.9  # Switch aux ~ 1 when balanced


# ---------------- dry-run artifacts (skip when absent) --------------------- #

@pytest.mark.skipif(not DRYRUN.exists() or not list(DRYRUN.glob("*.json")),
                    reason="dry-run results not generated")
def test_dryrun_cells_complete_and_fit():
    cells = {}
    for f in DRYRUN.glob("*__baseline.json"):
        d = json.loads(f.read_text())
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    assert len(cells) == 80, len(cells)
    bad = [k for k, d in cells.items() if d["status"] == "error"]
    assert not bad, bad
    skips = [k for k, d in cells.items() if d["status"] == "skipped"]
    assert len(skips) == 12  # long_500k x full-attention archs x 2 meshes
    assert all(k[1] == "long_500k" for k in skips)
    ok = [d for d in cells.values() if d["status"] == "ok"]
    # probe-corrected costs present with positive flops
    assert all(d["cost"]["flops_per_device"] > 0 for d in ok)
    # trip-count correction matters: probe >> scan-body for deep models
    g = cells[("gemma2-27b", "train_4k", "single")]
    assert g["cost"]["flops_per_device"] > 5 * g["cost_scanbody"]["flops"]


@pytest.mark.skipif(not DRYRUN.exists() or not list(DRYRUN.glob("*.json")),
                    reason="dry-run results not generated")
def test_multi_pod_cells_shard_the_pod_axis():
    """Multi-pod memory per device must not exceed single-pod (batch folds
    over pod x data)."""
    for arch in ("gemma2-27b", "mixtral-8x7b", "mamba2-130m"):
        s = json.loads((DRYRUN / f"{arch}__train_4k__single__baseline.json").read_text())
        m = json.loads((DRYRUN / f"{arch}__train_4k__multi__baseline.json").read_text())
        if s["status"] == m["status"] == "ok":
            assert (m["memory"]["peak_bytes_per_device"]
                    <= s["memory"]["peak_bytes_per_device"] * 1.1), arch


# ---------------- probe depth selection ------------------------------------ #

def test_probe_depths_respect_period():
    from repro.launch.dryrun import _probe_depths
    c1, c2, l1, l2 = _probe_depths(get_config("gemma2-27b"))
    assert (l1, l2) == (2, 4)  # local/global period
    assert c1.layer_pattern == ("local", "global")
    c1, c2, l1, l2 = _probe_depths(get_config("zamba2-2.7b"))
    assert l1 == 12 and l2 == 24  # attn_every * n_shared segments
    c1, c2, l1, l2 = _probe_depths(get_config("seamless-m4t-large-v2"))
    assert c1.enc_layers == 1 and c2.enc_layers == 2


# ---------------- loader epoch rollover ------------------------------------ #

def test_loader_epoch_rollover():
    from repro.data.pipeline import ShardedLoader, make_corpus
    corpus = make_corpus(40, vocab_size=128, seed=0)
    l = ShardedLoader(corpus, batch_size=16, seq_len=8, seed=2)
    it = iter(l)
    for _ in range(5):  # 40/16 = 2 batches/epoch -> crosses epochs
        next(it)
    l.close()
    assert l.epoch >= 2


# ---------------- launcher helper ------------------------------------------ #

def test_launch_reduced_configs_instantiate():
    from repro.launch.train import reduced
    for arch in ("gemma2-27b", "mixtral-8x7b", "zamba2-2.7b", "seamless-m4t-large-v2"):
        cfg = reduced(get_config(arch), 2, 64)
        model = build_model(cfg)
        assert model.param_count() < 20e6
