"""Device-resident serving plane (DESIGN.md §4): device/numpy equivalence.

The contract under test: ``backend="device"`` returns EXACTLY the numpy
path's ``(query_ids, row_ids)`` on every workload — including waves that
overflow the candidate-cell cap and fall back to numpy — and steady-state
serving compiles at most once per ``(bucket_B, padded_N, D)`` shape.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (COAXIndex, GridFile, full_rect, point_rect)
from repro.data import make_airline, make_osm
from repro.engine import BatchQueryExecutor, QueryServer, split_hits
from workloads import engine_workload, engine_workloads, rects_for


@pytest.mark.parametrize("name,ds", engine_workloads(),
                         ids=lambda w: w if isinstance(w, str) else "")
def test_device_equals_numpy_and_scalar(name, ds):
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    q_n, r_n = idx.query_batch(rects)
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects)
    assert np.array_equal(q_d, q_n), name
    assert np.array_equal(r_d, r_n), name
    assert np.all(np.diff(q_d) >= 0)
    per_query = split_hits(q_d, r_d, rects.shape[0])
    idx.backend = "numpy"
    for i, r in enumerate(rects):
        assert np.array_equal(per_query[i], idx.query(r)), (name, i)


@pytest.mark.parametrize("sort_dim", [None, 0, 2])
def test_gridfile_device_equals_numpy(sort_dim):
    rng = np.random.default_rng(4)
    data = rng.normal(0, 10, (6_000, 3)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=5,
                  sort_dim=sort_dim, backend="device")
    rects = np.sort(rng.uniform(-20, 20, (40, 3, 2)), axis=-1)
    rects[0] = full_rect(3)
    q_d, r_d = gf.query_batch(rects, rects)
    gf.backend = "numpy"
    q_n, r_n = gf.query_batch(rects, rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n), sort_dim


def test_device_pallas_kernel_path():
    """The same pipeline with the Pallas kernel (interpret mode) slotted in
    for step 5 instead of the jnp oracle — identical results."""
    rng = np.random.default_rng(7)
    data = rng.normal(0, 10, (1_500, 3)).astype(np.float32)
    rects = np.sort(rng.uniform(-20, 20, (8, 3, 2)), axis=-1)
    rects[0] = full_rect(3)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=4, sort_dim=1,
                  backend="device",
                  device_opts={"use_pallas": True, "interpret": True, "tile": 256})
    q_d, r_d = gf.query_batch(rects, rects)
    gf.backend = "numpy"
    q_n, r_n = gf.query_batch(rects, rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_device_empty_batch_and_empty_index():
    ds = make_airline(5_000, seed=1)
    idx = COAXIndex(ds.data, backend="device")
    q, r = idx.query_batch(np.zeros((0, ds.data.shape[1], 2)))
    assert q.size == 0 and r.size == 0
    gf = GridFile(np.empty((0, 2), np.float32), index_dims=[0, 1],
                  cells_per_dim=3, backend="device")
    q, r = gf.query_batch(full_rect(2)[None], full_rect(2)[None])
    assert q.size == 0 and r.size == 0


def test_device_all_outlier_queries():
    """Point queries aimed only at outlier rows: the primary probe returns
    nothing, every hit flows through the outlier grid's device plan."""
    ds = engine_workload("generic_fd")
    idx = COAXIndex(ds.data)
    assert idx.outlier.n_rows > 0
    o_rows = ds.data[idx.outlier.row_ids[:12]]
    rects = np.stack([point_rect(p) for p in o_rows])
    q_n, r_n = idx.query_batch(rects)
    assert r_n.size >= rects.shape[0]          # every target row is a hit
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_device_f32_range_bounds():
    """Rect bounds beyond float32 range exercise the f32_ceil/f32_floor
    +-inf padding interplay: +-1e39 must behave like +-inf, and bounds just
    inside f32 range must not round across any record value."""
    ds = make_airline(8_000, seed=2)
    d = ds.data.shape[1]
    idx = COAXIndex(ds.data)
    rects = np.stack([
        np.stack([np.full(d, -1e39), np.full(d, 1e39)], axis=-1),   # ~full
        np.stack([np.full(d, 1e38), np.full(d, 1e39)], axis=-1),    # empty
        np.stack([np.full(d, -1e39), ds.data[0].astype(np.float64)], axis=-1),
        point_rect(ds.data[3]),
    ])
    q_n, r_n = idx.query_batch(rects)
    assert split_hits(q_n, r_n, 4)[0].size == ds.data.shape[0]      # full hit
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_overflow_fallback_matches_numpy():
    """cell_cap=1 forces every multi-cell wave back to the numpy path; the
    contract (identical hits) must hold across the fallback seam."""
    rng = np.random.default_rng(9)
    data = rng.normal(0, 10, (4_000, 3)).astype(np.float32)
    rects = np.sort(rng.uniform(-20, 20, (16, 3, 2)), axis=-1)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=5, sort_dim=1,
                  backend="device", device_opts={"cell_cap": 1})
    q_d, r_d = gf.query_batch(rects, rects)
    assert gf.last_batch_stats.fallbacks == 1
    assert gf.last_batch_stats.backend == "numpy"
    gf.backend = "numpy"
    q_n, r_n = gf.query_batch(rects, rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_compile_cache_and_bucketed_shapes():
    """Steady-state serving compiles at most once per (bucket_B, N, D):
    repeated same-width waves reuse one executable; a single execute() call
    spanning two wave widths (8 + 4) compiles exactly two shapes."""
    rng = np.random.default_rng(11)
    data = rng.normal(0, 10, (6_000, 3)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=4, sort_dim=2,
                  backend="device")
    rects = np.sort(rng.uniform(-20, 20, (12, 3, 2)), axis=-1)

    ex = BatchQueryExecutor(gf_wrap(gf), max_batch=8, backend="device")
    plan = gf.device_plan
    assert plan is not None
    for _ in range(3):                       # repeated same-shape waves
        ex.execute(rects[:8])
    assert plan.compile_count == 1, "steady-state wave recompiled"

    got = ex.execute(rects)                  # one call, waves of 8 and 4
    assert plan.compile_count == 2, "second bucket shape should compile once"
    for _ in range(2):
        ex.execute(rects)
    assert plan.compile_count == 2, "repeat waves must hit the jit cache"

    gf.backend = "numpy"
    for i, r in enumerate(rects):
        assert np.array_equal(got[i], gf.query(r, r)), i


def gf_wrap(gf):
    """Adapter giving a raw GridFile the (rects,)-shaped query_batch the
    executor drives (nav == filter), plus backend passthrough."""
    class _W:
        backend = property(lambda s: gf.backend,
                           lambda s, v: setattr(gf, "backend", v))

        def query_batch(self, rects):
            return gf.query_batch(rects, rects)

        @property
        def last_batch_stats(self):
            return gf.last_batch_stats
    return _W()


def test_executor_and_server_device_plumbing():
    ds = make_osm(8_000, seed=5)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=10, seed=3)[:10]
    ex = BatchQueryExecutor(idx, max_batch=4, backend="device")
    assert idx.backend == "device" and ex.backend == "device"
    got = ex.execute(rects)
    s = ex.stats()
    assert s["backend"] == "device"
    assert s["rows_scanned"] > 0 and s["cells_probed"] > 0
    assert any(w.backend == "device" for w in ex.wave_stats)

    srv = QueryServer(COAXIndex(ds.data), max_batch=4, backend="device")
    qids = srv.submit_many(rects)
    results = srv.drain()
    idx.backend = "numpy"
    for qid, r, g in zip(qids, rects, got):
        assert np.array_equal(results[qid], g)
        assert np.array_equal(g, idx.query(r))


def test_executor_backend_validation():
    from repro.core import FullScan
    ds = make_airline(2_000, seed=0)
    with pytest.raises(ValueError):
        BatchQueryExecutor(FullScan(ds.data), backend="device")
    ex = BatchQueryExecutor(FullScan(ds.data), backend="numpy")
    assert ex.backend == "numpy"


# --------------------------------------------------------------------- #
# Fused megakernel (DESIGN.md §4): interpret-mode parity vs the oracles
# --------------------------------------------------------------------- #
def test_fused_kernel_interpret_parity():
    """The Pallas megakernel in interpret mode vs the jnp oracle vs the
    shipped batch-scan oracle, across every stage combination — counts,
    compacted hit prefixes and rows-scanned must agree exactly."""
    from repro.kernels import fused_range_scan
    from repro.kernels import ref as kref
    import jax.numpy as jnp

    rng = np.random.default_rng(21)
    n, d, b, tile, cap = 700, 3, 5, 256, 64
    rows_t = rng.normal(0, 10, (d, n)).astype(np.float32)
    lo = rng.uniform(-15, 0, (b, d)).astype(np.float32)
    hi = lo + rng.uniform(0, 20, (b, d)).astype(np.float32)
    alive = (rng.random(n) > 0.1).astype(np.int32)
    coords = rng.integers(0, 4, (2, n)).astype(np.int32)
    first = rng.integers(0, 2, (b, 2)).astype(np.int32)
    last = first + rng.integers(0, 3, (b, 2)).astype(np.int32)
    sv = rows_t[1]
    tband = np.stack([lo[:, 1], hi[:, 1]], axis=1)

    stage_sets = [{}, {"coords": coords, "first": first, "last": last},
                  {"sv": sv, "tband": tband},
                  {"coords": coords, "first": first, "last": last,
                   "sv": sv, "tband": tband}]
    for stages in stage_sets:
        outs = [fused_range_scan(rows_t, lo, hi, alive, **stages,
                                 tile=tile, hit_cap=cap, use_pallas=up)
                for up in (True, False)]
        for (c_a, h_a, s_a), (c_b, h_b, s_b) in zip(outs, outs[1:]):
            assert np.array_equal(c_a, c_b), stages.keys()
            assert np.array_equal(s_a, s_b), stages.keys()
            # hit buffers agree on the defined prefix (rest unspecified)
            take = np.minimum(np.asarray(c_a), cap)
            for q in range(b):
                assert np.array_equal(np.asarray(h_a)[q, :take[q]],
                                      np.asarray(h_b)[q, :take[q]])

        # brute-force ground truth for the full predicate + stages
        inside = np.all((rows_t[None] >= lo[:, :, None])
                        & (rows_t[None] < hi[:, :, None]), axis=1)
        cand = np.broadcast_to(alive > 0, (b, n)).copy()
        if "coords" in stages:
            cand &= np.all((coords[None] >= first[:, :, None])
                           & (coords[None] <= last[:, :, None]), axis=1)
        if "sv" in stages:
            cand &= (sv[None] >= tband[:, :1]) & (sv[None] < tband[:, 1:])
        hit = cand & inside
        counts, hits, scanned = outs[0]
        assert np.array_equal(np.asarray(counts), hit.sum(axis=1))
        assert np.array_equal(np.asarray(scanned), cand.sum(axis=1))
        for q in range(b):
            want = np.nonzero(hit[q])[0][:min(int(counts[q]), cap)]
            assert np.array_equal(np.asarray(hits)[q, :want.size], want)

    # cross-check counts against the shipped batch-scan oracle (no stages)
    win = jnp.broadcast_to(jnp.array([0, n], jnp.int32), (b, 2))
    pad = 256 - (n % 256)
    padded = jnp.pad(jnp.asarray(rows_t), ((0, 0), (0, pad)),
                     constant_values=jnp.inf)
    _, ref_counts = kref.range_scan_batch_ref(
        padded, jnp.asarray(lo).T, jnp.asarray(hi).T, win, tile=256)
    c0, _, _ = fused_range_scan(rows_t, lo, hi, tile=tile, hit_cap=cap,
                                use_pallas=True)
    assert np.array_equal(np.asarray(c0), np.asarray(ref_counts.sum(axis=1)))


def test_gather_oracle_matches_full_scan():
    """The CPU oracle's candidate-gather scan (per-query ``gidx`` row
    lists) is bit-identical to the full-array scan whenever the lists
    cover each query's candidate coord box — the probe-derived contract
    the device plans rely on (cell-major rows, one contiguous block per
    box cell, pad slots pointing at a dead pad row)."""
    from repro.engine.device import _multi_arange
    from repro.kernels import ref as kref

    rng = np.random.default_rng(33)
    n, d, b, k, c = 2_040, 3, 7, 2, 4
    tile, cap = 256, 64
    # cell-major layout: rows sorted by linear cell id, like a GridFile,
    # then one dead +inf pad row for the gather lists to point at
    cell = np.sort(rng.integers(0, c ** k, n))
    coords = np.stack([(cell // c ** (k - 1 - j)) % c for j in range(k)])
    coords = np.pad(coords, ((0, 0), (0, 1)),
                    constant_values=-1).astype(np.int32)
    offsets = np.searchsorted(cell, np.arange(c ** k + 1))
    rows_t = rng.normal(0, 10, (d, n)).astype(np.float32)
    rows_t = np.pad(rows_t, ((0, 0), (0, 1)), constant_values=np.inf)
    alive = np.append((rng.random(n) > 0.1), 0).astype(np.int32)
    lo = rng.uniform(-15, 0, (b, d)).astype(np.float32)
    hi = lo + rng.uniform(0, 25, (b, d)).astype(np.float32)
    first = rng.integers(0, c - 1, (b, k)).astype(np.int32)
    last = first + rng.integers(0, 2, (b, k)).astype(np.int32)
    radix = c ** (k - 1 - np.arange(k))
    lists = []
    for q in range(b):
        cells = (first[q][None, :] +
                 np.stack(np.meshgrid(*[np.arange(last[q, j] - first[q, j] + 1)
                                        for j in range(k)], indexing="ij"),
                          axis=-1).reshape(-1, k)) @ radix
        cells.sort()
        lists.append(_multi_arange(offsets[cells],
                                   offsets[cells + 1] - offsets[cells]))
    gw = 1 << int(max(max(l.size for l in lists), 1) - 1).bit_length()
    assert 0 < gw < n
    gidx = np.full((b, gw), n, np.int32)           # pad -> the dead pad row
    for q, lst in enumerate(lists):
        gidx[q, :lst.size] = lst

    full = kref.fused_scan_ref(rows_t, lo.T, hi.T, alive[None], coords,
                               first, last, tile=tile, hit_cap=cap)
    gath = kref.fused_scan_ref(rows_t, lo.T, hi.T, alive[None], coords,
                               first, last, gidx=np.asarray(gidx),
                               tile=tile, hit_cap=cap)
    c_f, h_f, s_f = (np.asarray(x) for x in full)
    c_g, h_g, s_g = (np.asarray(x) for x in gath)
    assert np.array_equal(c_f, c_g)
    assert np.array_equal(s_f, s_g)
    for q in range(b):
        take = min(int(c_f[q, 0]), cap)
        assert np.array_equal(h_f[q, :take], h_g[q, :take])
        assert (h_g[q, take:] == -1).all()


def test_hit_cap_overflow_reanswer_matches_numpy():
    """A tiny hit buffer forces per-query host re-answers at drain time;
    results stay bit-identical and the overflow count is surfaced."""
    ds = make_airline(6_000, seed=4)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=10, seed=5)     # includes a full-range rect
    q_n, r_n = idx.query_batch(rects)
    idx_d = COAXIndex(ds.data, backend="device",
                      device_opts={"hit_cap": 16})
    q_d, r_d = idx_d.query_batch(rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)
    assert idx_d.last_batch_stats.backend == "device"   # not a wave fallback
    assert idx_d.last_batch_stats.hit_overflows > 0


def test_one_dispatch_per_wave_and_device_stats():
    """The §4 gate on CPU: every non-fallback wave is exactly ONE kernel
    dispatch (primary + outlier + delta fused), counted on the plan."""
    ds = make_osm(6_000, seed=8)
    idx = COAXIndex(ds.data, backend="device")
    rects = rects_for(ds.data, n=12, seed=9)
    ex = BatchQueryExecutor(idx, max_batch=4, backend="device")
    n_waves = -(-rects.shape[0] // 4)
    ex.execute(rects)
    s = ex.stats()
    assert s["device_fallbacks"] == 0 and s["fallback_waves"] == 0
    ds_stats = idx.device_stats()
    assert ds_stats is not None
    assert ds_stats["dispatches"] == s["waves"] == n_waves
    assert ds_stats["bytes_h2d"] > 0 and ds_stats["bytes_d2h"] > 0
    assert s["wave_p50_ms"] > 0 and s["wave_p99_ms"] >= s["wave_p50_ms"]
    # writes dirty the delta segment; still one dispatch per wave
    idx.insert(ds.data[:40] + 0.25)
    ex.execute(rects[:4])
    assert idx.device_stats()["dispatches"] == n_waves + 1


def test_resident_drain_across_waves_with_interleaved_writes():
    """≥3 in-flight waves with inserts/deletes/compaction landing between
    submit and drain: every wave must answer from the snapshot+delta state
    it was SUBMITTED from (per-wave snapshot semantics), even across an
    epoch bump that swaps the grids out from under the in-flight tickets."""
    rng = np.random.default_rng(31)
    ds = make_airline(6_000, seed=6)
    idx = COAXIndex(ds.data, backend="device",
                    device_opts={"hit_cap": 64})  # small cap: overflow path
    rects = rects_for(ds.data, n=12, seed=11)     # under writes, too
    waves = [rects[0:4], rects[4:8], rects[8:12]]
    handles, expected = [], []
    e0 = idx.epoch
    for i, w in enumerate(waves):
        idx.backend = "numpy"
        expected.append(idx.query_batch(w))       # truth for CURRENT state
        idx.backend = "device"
        handles.append(idx.query_batch_submit(w))
        # writes land AFTER the submit, BEFORE any drain
        idx.insert(rng.normal(0, 5, (30, ds.data.shape[1])).astype(np.float32))
        idx.delete(np.arange(i * 7, i * 7 + 5))
        if i == 1:
            idx.compact()                         # epoch bump mid-stream
    assert idx.epoch > e0
    for (q_e, r_e), h in zip(expected, handles):
        q_d, r_d = idx.query_batch_collect(h)
        assert np.array_equal(q_d, q_e) and np.array_equal(r_d, r_e)
    # post-compaction wave: delta emptied then refilled; fresh plan epoch
    idx.backend = "numpy"
    q_e, r_e = idx.query_batch(rects[:6])
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects[:6])
    assert np.array_equal(q_d, q_e) and np.array_equal(r_d, r_e)


def test_server_pipelined_drain_device_equals_numpy():
    """QueryServer drain on the device backend (double-buffered submit one
    wave ahead of drain) with writes interleaving wave boundaries — same
    answers as a numpy server fed the identical admission sequence."""
    ds = make_airline(5_000, seed=12)
    rng = np.random.default_rng(41)
    rects = rects_for(ds.data, n=12, seed=13)
    extra = rng.normal(0, 5, (20, ds.data.shape[1])).astype(np.float32)

    def run(backend):
        srv = QueryServer(COAXIndex(ds.data), max_batch=4, backend=backend)
        qids = srv.submit_many(rects[:8])
        srv.insert(extra)
        qids += srv.submit_many(rects[8:])
        srv.delete(np.arange(10))
        res = srv.drain()
        return [res[q] for q in qids], srv

    got_d, srv_d = run("device")
    got_n, _ = run("numpy")
    for a, b in zip(got_d, got_n):
        assert np.array_equal(a, b)
    s = srv_d.stats()
    assert s["backend"] == "device" and s["waves_drained"] >= 3
    assert s["device_fallbacks"] == 0
    assert srv_d.executor.index.device_stats()["dispatches"] == s["waves"]
