"""Device-resident serving plane (DESIGN.md §4): device/numpy equivalence.

The contract under test: ``backend="device"`` returns EXACTLY the numpy
path's ``(query_ids, row_ids)`` on every workload — including waves that
overflow the candidate-cell cap and fall back to numpy — and steady-state
serving compiles at most once per ``(bucket_B, padded_N, D)`` shape.
"""
import numpy as np
import pytest

jax = pytest.importorskip("jax")

from repro.core import (COAXIndex, GridFile, full_rect, point_rect)
from repro.data import make_airline, make_osm
from repro.engine import BatchQueryExecutor, QueryServer, split_hits
from workloads import engine_workload, engine_workloads, rects_for


@pytest.mark.parametrize("name,ds", engine_workloads(),
                         ids=lambda w: w if isinstance(w, str) else "")
def test_device_equals_numpy_and_scalar(name, ds):
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    q_n, r_n = idx.query_batch(rects)
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects)
    assert np.array_equal(q_d, q_n), name
    assert np.array_equal(r_d, r_n), name
    assert np.all(np.diff(q_d) >= 0)
    per_query = split_hits(q_d, r_d, rects.shape[0])
    idx.backend = "numpy"
    for i, r in enumerate(rects):
        assert np.array_equal(per_query[i], idx.query(r)), (name, i)


@pytest.mark.parametrize("sort_dim", [None, 0, 2])
def test_gridfile_device_equals_numpy(sort_dim):
    rng = np.random.default_rng(4)
    data = rng.normal(0, 10, (6_000, 3)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=5,
                  sort_dim=sort_dim, backend="device")
    rects = np.sort(rng.uniform(-20, 20, (40, 3, 2)), axis=-1)
    rects[0] = full_rect(3)
    q_d, r_d = gf.query_batch(rects, rects)
    gf.backend = "numpy"
    q_n, r_n = gf.query_batch(rects, rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n), sort_dim


def test_device_pallas_kernel_path():
    """The same pipeline with the Pallas kernel (interpret mode) slotted in
    for step 5 instead of the jnp oracle — identical results."""
    rng = np.random.default_rng(7)
    data = rng.normal(0, 10, (1_500, 3)).astype(np.float32)
    rects = np.sort(rng.uniform(-20, 20, (8, 3, 2)), axis=-1)
    rects[0] = full_rect(3)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=4, sort_dim=1,
                  backend="device",
                  device_opts={"use_pallas": True, "interpret": True, "tile": 256})
    q_d, r_d = gf.query_batch(rects, rects)
    gf.backend = "numpy"
    q_n, r_n = gf.query_batch(rects, rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_device_empty_batch_and_empty_index():
    ds = make_airline(5_000, seed=1)
    idx = COAXIndex(ds.data, backend="device")
    q, r = idx.query_batch(np.zeros((0, ds.data.shape[1], 2)))
    assert q.size == 0 and r.size == 0
    gf = GridFile(np.empty((0, 2), np.float32), index_dims=[0, 1],
                  cells_per_dim=3, backend="device")
    q, r = gf.query_batch(full_rect(2)[None], full_rect(2)[None])
    assert q.size == 0 and r.size == 0


def test_device_all_outlier_queries():
    """Point queries aimed only at outlier rows: the primary probe returns
    nothing, every hit flows through the outlier grid's device plan."""
    ds = engine_workload("generic_fd")
    idx = COAXIndex(ds.data)
    assert idx.outlier.n_rows > 0
    o_rows = ds.data[idx.outlier.row_ids[:12]]
    rects = np.stack([point_rect(p) for p in o_rows])
    q_n, r_n = idx.query_batch(rects)
    assert r_n.size >= rects.shape[0]          # every target row is a hit
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_device_f32_range_bounds():
    """Rect bounds beyond float32 range exercise the f32_ceil/f32_floor
    +-inf padding interplay: +-1e39 must behave like +-inf, and bounds just
    inside f32 range must not round across any record value."""
    ds = make_airline(8_000, seed=2)
    d = ds.data.shape[1]
    idx = COAXIndex(ds.data)
    rects = np.stack([
        np.stack([np.full(d, -1e39), np.full(d, 1e39)], axis=-1),   # ~full
        np.stack([np.full(d, 1e38), np.full(d, 1e39)], axis=-1),    # empty
        np.stack([np.full(d, -1e39), ds.data[0].astype(np.float64)], axis=-1),
        point_rect(ds.data[3]),
    ])
    q_n, r_n = idx.query_batch(rects)
    assert split_hits(q_n, r_n, 4)[0].size == ds.data.shape[0]      # full hit
    idx.backend = "device"
    q_d, r_d = idx.query_batch(rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_overflow_fallback_matches_numpy():
    """cell_cap=1 forces every multi-cell wave back to the numpy path; the
    contract (identical hits) must hold across the fallback seam."""
    rng = np.random.default_rng(9)
    data = rng.normal(0, 10, (4_000, 3)).astype(np.float32)
    rects = np.sort(rng.uniform(-20, 20, (16, 3, 2)), axis=-1)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=5, sort_dim=1,
                  backend="device", device_opts={"cell_cap": 1})
    q_d, r_d = gf.query_batch(rects, rects)
    assert gf.last_batch_stats.fallbacks == 1
    assert gf.last_batch_stats.backend == "numpy"
    gf.backend = "numpy"
    q_n, r_n = gf.query_batch(rects, rects)
    assert np.array_equal(q_d, q_n) and np.array_equal(r_d, r_n)


def test_compile_cache_and_bucketed_shapes():
    """Steady-state serving compiles at most once per (bucket_B, N, D):
    repeated same-width waves reuse one executable; a single execute() call
    spanning two wave widths (8 + 4) compiles exactly two shapes."""
    rng = np.random.default_rng(11)
    data = rng.normal(0, 10, (6_000, 3)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=4, sort_dim=2,
                  backend="device")
    rects = np.sort(rng.uniform(-20, 20, (12, 3, 2)), axis=-1)

    ex = BatchQueryExecutor(gf_wrap(gf), max_batch=8, backend="device")
    plan = gf.device_plan
    assert plan is not None
    for _ in range(3):                       # repeated same-shape waves
        ex.execute(rects[:8])
    assert plan.compile_count == 1, "steady-state wave recompiled"

    got = ex.execute(rects)                  # one call, waves of 8 and 4
    assert plan.compile_count == 2, "second bucket shape should compile once"
    for _ in range(2):
        ex.execute(rects)
    assert plan.compile_count == 2, "repeat waves must hit the jit cache"

    gf.backend = "numpy"
    for i, r in enumerate(rects):
        assert np.array_equal(got[i], gf.query(r, r)), i


def gf_wrap(gf):
    """Adapter giving a raw GridFile the (rects,)-shaped query_batch the
    executor drives (nav == filter), plus backend passthrough."""
    class _W:
        backend = property(lambda s: gf.backend,
                           lambda s, v: setattr(gf, "backend", v))

        def query_batch(self, rects):
            return gf.query_batch(rects, rects)

        @property
        def last_batch_stats(self):
            return gf.last_batch_stats
    return _W()


def test_executor_and_server_device_plumbing():
    ds = make_osm(8_000, seed=5)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=10, seed=3)[:10]
    ex = BatchQueryExecutor(idx, max_batch=4, backend="device")
    assert idx.backend == "device" and ex.backend == "device"
    got = ex.execute(rects)
    s = ex.stats()
    assert s["backend"] == "device"
    assert s["rows_scanned"] > 0 and s["cells_probed"] > 0
    assert any(w.backend == "device" for w in ex.wave_stats)

    srv = QueryServer(COAXIndex(ds.data), max_batch=4, backend="device")
    qids = srv.submit_many(rects)
    results = srv.drain()
    idx.backend = "numpy"
    for qid, r, g in zip(qids, rects, got):
        assert np.array_equal(results[qid], g)
        assert np.array_equal(g, idx.query(r))


def test_executor_backend_validation():
    from repro.core import FullScan
    ds = make_airline(2_000, seed=0)
    with pytest.raises(ValueError):
        BatchQueryExecutor(FullScan(ds.data), backend="device")
    ex = BatchQueryExecutor(FullScan(ds.data), backend="numpy")
    assert ex.backend == "numpy"
