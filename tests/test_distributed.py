"""Distribution-layer tests: sharding rules, sharded-vs-single-device step
equivalence, pipeline parallelism, gradient compression, HLO parsing.

Multi-device cases run in subprocesses with fake XLA devices so the main
test process keeps exactly one device (per the brief).  Mesh construction
goes through ``make_mesh_compat`` so the cases run on the pinned jax 0.4.x
(no ``axis_types`` kwarg, no ``jax.set_mesh``) as well as newer versions;
the only version gate left is ``jax.make_mesh`` itself (added in 0.4.35),
expressed as a skip — never an ``xfail(strict=False)``, whose silent
pass/fail flapping can hide regressions."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import run_in_subprocess
from repro.configs import SHAPES, get_config
from repro.distributed.compression import (Int8Compressor, TopKCompressor,
                                           make_compressed_train_step)
from repro.distributed.partitioning import logical_to_spec, use_rules
from repro.launch.hloparse import collective_bytes

requires_make_mesh = pytest.mark.skipif(
    not hasattr(jax, "make_mesh"),
    reason=f"jax.make_mesh absent in jax {jax.__version__} (needs >= 0.4.35)")


def test_main_process_single_device():
    assert len(jax.devices()) == 1


# --------------------------- sharding rules ------------------------------ #

@requires_make_mesh
def test_rules_divisibility_adaptation():
    code = """
import jax
from jax.sharding import PartitionSpec as P
from repro.launch.mesh import make_local_mesh
from repro.distributed.sharding import rules_for_arch
from repro.configs import get_config, SHAPES

mesh = make_local_mesh(2, 4)  # tp=4
r = rules_for_arch(get_config("gemma2-27b"), mesh, SHAPES["train_4k"])
assert r["heads"] == ("model",), r
assert r["attn_seq"] is None

r = rules_for_arch(get_config("qwen2-vl-2b"), mesh, SHAPES["train_4k"])
assert r["heads"] == ("model",)  # 12 % 4 == 0 on tp=4

mesh8 = make_local_mesh(1, 8)
r = rules_for_arch(get_config("qwen2-vl-2b"), mesh8, SHAPES["train_4k"])
assert r["heads"] is None and r["attn_seq"] == ("model",)  # 12 % 8 != 0

r = rules_for_arch(get_config("phi3.5-moe-42b-a6.6b"), mesh8, SHAPES["train_4k"])
assert r["experts"] == ("model",)  # 16 % 8 == 0 -> EP
r = rules_for_arch(get_config("mixtral-8x7b"), mesh8, SHAPES["train_4k"])
assert r["experts"] is None and r["expert_ff"] == ("model",)  # 8 % 8... wait
print("RULES_OK")
"""
    # mixtral E=8 divides tp=8 — adjust expectation inside subprocess
    code = code.replace(
        'assert r["experts"] is None and r["expert_ff"] == ("model",)  # 8 % 8... wait',
        'assert r["experts"] == ("model",)  # 8 % 8 == 0 -> EP on tp=8')
    out = run_in_subprocess(code, devices=8)
    assert "RULES_OK" in out


@requires_make_mesh
def test_tiny_batch_falls_back_to_context_parallel_decode():
    code = """
from repro.launch.mesh import make_local_mesh
from repro.distributed.sharding import rules_for_arch
from repro.configs import get_config, SHAPES
mesh = make_local_mesh(4, 2)
r = rules_for_arch(get_config("h2o-danube-3-4b"), mesh, SHAPES["long_500k"])
assert r["batch"] is None          # batch=1 cannot shard over data=4
assert r["kv_len"] == ("data",)    # cache length shards instead
print("CP_OK")
"""
    assert "CP_OK" in run_in_subprocess(code, devices=8)


@requires_make_mesh
def test_sharded_step_matches_single_device():
    """The same train step on a 2x2 mesh must produce the same loss as on a
    single device — GSPMD must not change the math.  ``in_shardings`` take
    explicit ``NamedSharding``s under a ``with mesh:`` scope, which both
    jax 0.4.x (no ``jax.set_mesh``) and current jax accept."""
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
import jax.tree_util as jtu
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.models.common import axes_to_pspecs
from repro.distributed.partitioning import use_rules
from repro.distributed.sharding import rules_for_arch, input_pspecs
from repro.launch.mesh import make_local_mesh
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step

cfg = get_config("h2o-danube-3-4b")
cfg = dataclasses.replace(cfg, n_layers=2, d_model=32, d_ff=64, vocab_size=256,
                          n_heads=4, n_kv_heads=2, head_dim=8, window=8)
model = build_model(cfg)
params, axes = model.init(jax.random.key(0))
opt = adamw_init(params)
rng = np.random.default_rng(0)
batch = {"tokens": jnp.asarray(rng.integers(0, 200, (4, 16)), jnp.int32),
         "labels": jnp.asarray(rng.integers(0, 200, (4, 16)), jnp.int32)}
step = make_train_step(model, AdamWConfig(lr=1e-3))

# single device reference
_, _, m_ref = jax.jit(step)(params, opt, batch)

mesh = make_local_mesh(2, 2)
rules = rules_for_arch(cfg, mesh)
with mesh, use_rules(rules):
    pspecs = jtu.tree_map(lambda s: NamedSharding(mesh, s),
                          axes_to_pspecs(axes, rules))
    bspecs = {"tokens": NamedSharding(mesh, P("data")),
              "labels": NamedSharding(mesh, P("data"))}
    f = jax.jit(step, in_shardings=(pspecs, None, bspecs))
    _, _, m_sh = f(params, opt, batch)
d = abs(float(m_ref["loss"]) - float(m_sh["loss"]))
assert d < 5e-3, (float(m_ref["loss"]), float(m_sh["loss"]))
print("SHARDED_OK", d)
"""
    assert "SHARDED_OK" in run_in_subprocess(code, devices=4)


@requires_make_mesh
def test_pipeline_parallel_forward_matches_sequential():
    code = """
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_forward, bubble_fraction
from repro.launch.mesh import make_mesh_compat

assert abs(bubble_fraction(4, 12) - 3/15) < 1e-12

def stage_fn(w, x):
    return jnp.tanh(x @ w)

n_stages, d = 4, 16
rng = np.random.default_rng(0)
ws = jnp.asarray(rng.normal(0, 0.5, (n_stages, d, d)), jnp.float32)
x = jnp.asarray(rng.normal(0, 1, (8, d)), jnp.float32)

ref = x
for s in range(n_stages):
    ref = stage_fn(ws[s], ref)

mesh = make_mesh_compat((n_stages,), ("stage",))
out = pipeline_forward(stage_fn, ws, x, mesh, n_microbatches=4)
err = float(jnp.abs(out - ref).max())
assert err < 1e-5, err
print("PIPELINE_OK", err)
"""
    assert "PIPELINE_OK" in run_in_subprocess(code, devices=4)


@requires_make_mesh
def test_compressed_psum_close_to_exact():
    code = """
import jax, jax.numpy as jnp, numpy as np
from functools import partial
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P
from repro.distributed.compression import compressed_psum
from repro.launch.mesh import make_mesh_compat

mesh = make_mesh_compat((4,), ("data",))
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(0, 1, (4, 256)), jnp.float32)

@partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
def exact(x):
    return jax.lax.psum(x, "data")

@partial(shard_map, mesh=mesh, in_specs=P("data"), out_specs=P("data"))
def compressed(x):
    return compressed_psum(x, "data")

a, b = exact(x), compressed(x)
rel = float(jnp.abs(a - b).max() / jnp.abs(a).max())
assert rel < 0.05, rel
print("PSUM_OK", rel)
"""
    assert "PSUM_OK" in run_in_subprocess(code, devices=4)


# --------------------------- compression --------------------------------- #

def test_int8_error_feedback_reduces_bias():
    comp = Int8Compressor()
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(0, 1, 512), jnp.float32)}
    err = comp.init(g)
    acc_true = jnp.zeros(512)
    acc_comp = jnp.zeros(512)
    for _ in range(50):
        deq, err = comp.compress(g, err)
        acc_true += g["w"]
        acc_comp += deq["w"]
    # error feedback keeps the long-run sums together
    rel = float(jnp.abs(acc_true - acc_comp).max() / jnp.abs(acc_true).max())
    assert rel < 0.01
    assert comp.wire_bytes_ratio() == 0.25


def test_topk_compressor_sparsity():
    comp = TopKCompressor(frac=0.1)
    g = {"w": jnp.asarray(np.random.default_rng(1).normal(0, 1, 1000), jnp.float32)}
    err = comp.init(g)
    kept, err = comp.compress(g, err)
    nz = float((kept["w"] != 0).mean())
    assert 0.05 <= nz <= 0.15


def test_compressed_train_step_trains():
    import dataclasses
    from conftest import make_batch, tiny_config
    from repro.models import build_model
    from repro.optim import AdamWConfig, adamw_init
    cfg = tiny_config(get_config("mamba2-130m"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    comp = Int8Compressor()
    step = jax.jit(make_compressed_train_step(model, AdamWConfig(lr=3e-3), comp))
    opt = adamw_init(params)
    ef = comp.init(params)
    losses = []
    for i in range(12):
        batch = make_batch(cfg, batch=2, seq=16, seed=i % 3)
        params, opt, ef, m = step(params, opt, ef, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-3:]) < np.mean(losses[:3])


# --------------------------- HLO parsing --------------------------------- #

def test_collective_bytes_parser():
    hlo = """
  %x = bf16[16,4096]{1,0} all-reduce(bf16[16,4096]{1,0} %a), replica_groups={}
  %y = f32[8,128]{1,0} all-gather(f32[8,32]{1,0} %b), dimensions={1}
  %z = (f32[4,4]{1,0}, f32[4,4]{1,0}) reduce-scatter(f32[16,4]{1,0} %c, f32[16,4]{1,0} %d)
  %w = f32[64]{0} all-reduce-start(f32[64]{0} %e)
  %w2 = f32[64]{0} all-reduce-done(f32[64]{0} %w)
  %n = f32[2,2]{1,0} add(f32[2,2]{1,0} %p, f32[2,2]{1,0} %q)
"""
    total, per = collective_bytes(hlo)
    assert per["all-reduce"] == 16 * 4096 * 2 + 64 * 4
    assert per["all-gather"] == 8 * 128 * 4
    assert per["reduce-scatter"] == 2 * 16 * 4
    assert total == sum(per.values())


def test_logical_to_spec_rules():
    from jax.sharding import PartitionSpec as P
    rules = {"batch": ("pod", "data"), "heads": ("model",), "seq": None}
    with use_rules(rules):
        assert logical_to_spec(("batch", "seq", "heads")) == P(("pod", "data"), None, "model")
    assert logical_to_spec(("batch",), None) == P()  # no rules -> replicated
