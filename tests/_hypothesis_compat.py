"""Import ``given``/``settings``/``st`` from here instead of ``hypothesis``.

When hypothesis is installed this re-exports the real thing.  When it is
absent (minimal CI images), property tests decorated with ``@given`` are
collected but SKIPPED — the rest of the module still runs, instead of the
whole file erroring at import time.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategy:
        """Inert stand-in: supports the chaining used at decoration time."""

        def filter(self, *a, **k):
            return self

        def map(self, *a, **k):
            return self

        def flatmap(self, *a, **k):
            return self

    class _Strategies:
        def __getattr__(self, name):
            return lambda *a, **k: _Strategy()

    st = _Strategies()

    def given(*_a, **_k):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*_a, **_k):
        def deco(fn):
            return fn

        return deco

strategies = st
