"""Semantic result cache + pinned-epoch MVCC reads (DESIGN.md §9).

The contracts under test:

- §9.1 exactness: with a cache attached, every answer — exact hit,
  containment partial, or miss — is BIT-IDENTICAL to the cache-disabled
  path, across (workload × backend × shard-count).
- §9.2 invalidation: any write (insert, delete, background-compaction
  handoff) moves the version key, so no stale entry can ever answer; on a
  sharded plane each shard keys on its OWN version, never the ambiguous
  aggregate epoch sum.
- §9.3 MVCC: a pinned reader answers bit-identically to pin time across
  concurrent writes and handoff installs, and the old epoch's objects are
  freed only after the last pin releases.
"""
import gc
import weakref

import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.core import COAXIndex, CoaxConfig
from repro.data import make_airline, make_generic_fd, make_osm
from repro.engine import QueryServer, SemanticCache, ShardedCOAX
from workloads import NOAUTO, rects_for, zipf_rects

BG = CoaxConfig(background_compact=True, compact_min_delta=256,
                compact_delta_frac=0.01, compact_check_rows=32)

_DS = {
    "airline": lambda: make_airline(6_000, seed=3),
    "osm": lambda: make_osm(6_000, seed=3),
    "generic_fd": lambda: make_generic_fd(5_000, 5, ((0, 1), (2, 3)), seed=7),
}


def _mix(data, seed=0):
    """Zipfian hot-rect stream (repeats + nested subsets) plus the standard
    mix (full-range, ±inf, empty) — hits, partials and misses in one wave."""
    return np.concatenate([zipf_rects(data, n=48, n_hot=8, seed=seed),
                           rects_for(data, n=8, seed=seed)])


def _split_equal(got, want, tag=""):
    assert len(got) == len(want), tag
    for i, (a, b) in enumerate(zip(got, want)):
        assert np.array_equal(a, b), (tag, i)


# --------------------------------------------------------------------- #
# §9.1 bit-identity matrix: (workload × backend × shards)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("wl", sorted(_DS))
@pytest.mark.parametrize("backend", ["numpy", "device"])
@pytest.mark.parametrize("shards", [None, 4])
def test_cached_answers_bit_identical(wl, backend, shards):
    if backend == "device":
        pytest.importorskip("jax")
    ds = _DS[wl]()
    rects = _mix(ds.data)
    if shards is None:
        idx = COAXIndex(ds.data, NOAUTO, backend=backend)
    else:
        idx = ShardedCOAX(ds.data, NOAUTO, n_shards=shards, backend=backend)
    want = idx.query_batch_split(rects)         # cache-disabled oracle
    idx.attach_cache(byte_budget=8 << 20)
    _split_equal(idx.query_batch_split(rects), want, (wl, backend, "cold"))
    _split_equal(idx.query_batch_split(rects), want, (wl, backend, "warm"))
    cs = idx.last_cache_stats
    assert cs is not None and cs.hits + cs.partial > 0, (wl, backend, shards)


def test_cache_partial_hits_filter_supersets():
    """Nested rects must answer from containing entries (the §9.1 filter),
    not just byte-identical repeats."""
    ds = _DS["airline"]()
    idx = COAXIndex(ds.data, NOAUTO).attach_cache()
    rects = np.asarray(zipf_rects(ds.data, n=16, n_hot=16, nest_frac=0.0,
                                  seed=5), np.float64)
    idx.query_batch(rects)                      # populate with the supersets
    inner = rects.copy()
    width = inner[:, :, 1] - inner[:, :, 0]
    inner[:, :, 0] += 0.25 * width
    inner[:, :, 1] = np.maximum(inner[:, :, 1] - 0.25 * width, inner[:, :, 0])
    want = COAXIndex(ds.data, NOAUTO).query_batch_split(inner)
    _split_equal(idx.query_batch_split(inner), want, "nested")
    assert idx.last_cache_stats.partial == inner.shape[0]


# --------------------------------------------------------------------- #
# §9.2 invalidation: every write moves the version key
# --------------------------------------------------------------------- #
def test_write_invalidates_cache_entries():
    ds = _DS["airline"]()
    idx = COAXIndex(ds.data, NOAUTO).attach_cache()
    row = ds.data[42]
    rect = np.stack([row.astype(np.float64) - 1e-3,
                     row.astype(np.float64) + 1e-3], axis=-1)[None]

    def q():
        return idx.query_batch_split(rect)[0]

    before = q()
    assert np.array_equal(q(), before)                   # cached repeat
    assert idx.cache.hits == 1
    new_id = idx.insert(row[None])[0]
    after = q()                                          # must see the insert
    assert new_id in after and np.array_equal(
        np.sort(np.append(before, new_id)), after)
    assert idx.cache.invalidations > 0                   # old entry purged
    idx.delete([new_id])
    assert np.array_equal(q(), before)                   # and the delete


def test_handoff_install_invalidates_cache():
    """A background-compaction epoch install is a version bump like any
    other write: post-handoff answers come from the new epoch, never a
    pre-handoff cache entry."""
    ds = _DS["airline"]()
    idx = COAXIndex(ds.data, BG).attach_cache()
    rects = _mix(ds.data)
    idx.query_batch(rects)                               # populate
    rng = np.random.default_rng(9)
    while idx.background_compactions < 1:
        idx.insert(ds.data[rng.integers(0, ds.data.shape[0], 64)])
        idx.poll_handoff(wait=True)
    idx.finish_handoff()
    rows, ids = idx.live_rows()
    want = COAXIndex(rows, NOAUTO, row_ids=ids).query_batch_split(rects)
    _split_equal(idx.query_batch_split(rects), want, "post-handoff")


def test_sharded_cache_keys_on_own_shard_version():
    """Compacting shard 0 must strand ONLY shard 0's entries: shard 1's
    keep hitting (its version never moved), and no key ever contains the
    plane's aggregate epoch sum."""
    ds = _DS["airline"]()
    pl = ShardedCOAX(ds.data, NOAUTO, n_shards=2, partition="range")
    pl.attach_cache()
    rects = np.asarray(zipf_rects(ds.data, n=32, n_hot=8, nest_frac=0.0,
                                  seed=2), np.float64)
    pl.query_batch(rects)
    hits0 = [pl.shards[k].cache.hits for k in range(2)]
    pl.shards[0].compact()                      # moves shard 0's version only
    pl.query_batch(rects)                       # re-keys shard 0, hits shard 1
    assert pl.shards[1].cache.hits > hits0[1]   # shard 1 entries survived
    assert pl.shards[0].cache.invalidations > 0  # shard 0's were purged
    assert pl.epoch == 1                        # aggregate moved ...
    for k in (0, 1):
        assert len(pl.shards[k].cache) > 0
        for vkey, _rect_bytes in pl.shards[k].cache._entries:
            assert vkey[0] == k                           # (shard_id, ...)
            assert vkey[1] == pl.shards[k].epoch          # shard's OWN epoch
    # ... but shard 1's entries still key on ITS epoch 0, not the sum:
    assert all(vkey[1] == 0 for vkey, _ in pl.shards[1].cache._entries)
    rows, ids = pl.live_rows()
    want = COAXIndex(rows, NOAUTO, row_ids=ids).query_batch_split(rects)
    _split_equal(pl.query_batch_split(rects), want, "sharded-post-compact")


# --------------------------------------------------------------------- #
# §9.3 MVCC pins
# --------------------------------------------------------------------- #
def test_pin_epoch_exact_across_background_handoff():
    ds = _DS["airline"]()
    idx = COAXIndex(ds.data, BG)
    rects = _mix(ds.data)
    pin = idx.pin_epoch()
    assert idx.pinned_epochs == [pin.epoch]
    want = pin.query_batch_split(rects)
    _split_equal(idx.query_batch_split(rects), want, "pin == live at pin time")
    old_primary = weakref.ref(idx.primary)
    rng = np.random.default_rng(11)
    while idx.background_compactions < 1:
        idx.insert(ds.data[rng.integers(0, ds.data.shape[0], 64)])
        idx.poll_handoff(wait=True)
    idx.finish_handoff()
    assert idx.epoch > pin.epoch
    live = idx.query_batch_split(rects)
    assert any(not np.array_equal(a, b) for a, b in zip(live, want))
    _split_equal(pin.query_batch_split(rects), want, "pin across handoff")
    assert old_primary() is not None            # pin keeps the old epoch alive
    pin.release()
    gc.collect()
    assert old_primary() is None                # ... and releasing frees it
    assert idx.pinned_epochs == []
    with pytest.raises(RuntimeError):
        pin.query(rects[0])
    pin.release()                               # idempotent


def test_pin_epoch_refcount_and_context_manager():
    ds = _DS["generic_fd"]()
    idx = COAXIndex(ds.data, NOAUTO)
    rects = rects_for(ds.data, n=6)
    p1 = idx.pin_epoch()
    with idx.pin_epoch() as p2:
        assert idx._pins[idx.epoch] == 2
        want = p1.query_batch_split(rects)
        _split_equal(p2.query_batch_split(rects), want, "two pins agree")
    assert idx._pins[idx.epoch] == 1            # p2 released at exit
    p1.release()
    assert idx.pinned_epochs == []


def test_sharded_pin_exact_across_writes():
    ds = _DS["osm"]()
    pl = ShardedCOAX(ds.data, NOAUTO, n_shards=4)
    rects = _mix(ds.data)
    pin = pl.pin_epoch()
    assert len(pin.shard_epochs) == 4
    want = pin.query_batch_split(rects)
    _split_equal(pl.query_batch_split(rects), want, "sharded pin at pin time")
    pl.insert(ds.data[:128])
    pl.compact()
    _split_equal(pin.query_batch_split(rects), want, "sharded pin after writes")
    live = pl.query_batch_split(rects)
    assert any(not np.array_equal(a, b) for a, b in zip(live, want))
    pin.release()
    with pytest.raises(RuntimeError):
        pin.query(rects[0])


def test_server_pin_flushes_queued_writes_first():
    ds = _DS["airline"]()
    srv = QueryServer(COAXIndex(ds.data, NOAUTO), max_batch=16)
    rect = np.stack([ds.data[7].astype(np.float64) - 1e-3,
                     ds.data[7].astype(np.float64) + 1e-3], axis=-1)
    srv.insert(ds.data[7][None])                # queued, not yet applied
    pin = srv.pin_epoch()                       # must flush, then freeze
    assert srv.executor.index.delta_rows > 0
    want = pin.query(rect)
    assert want.size == srv.executor.index.query(rect).size
    srv.insert(ds.data[7][None])
    srv.drain()                                 # applies the second insert
    assert np.array_equal(pin.query(rect), want)
    assert srv.executor.index.query(rect).size == want.size + 1
    pin.release()


# --------------------------------------------------------------------- #
# Eviction under a tiny byte budget
# --------------------------------------------------------------------- #
def test_eviction_respects_byte_budget():
    ds = _DS["airline"]()
    idx = COAXIndex(ds.data, NOAUTO)
    twin = COAXIndex(ds.data, NOAUTO)
    idx.attach_cache(byte_budget=16 << 10)      # ~a handful of entries
    rects = rects_for(ds.data, n=40, seed=1, extremes=False)
    for wave in (rects[:20], rects[20:], rects[:20]):
        got = idx.query_batch_split(wave)
        _split_equal(got, twin.query_batch_split(wave), "evicting")
        assert idx.cache.nbytes <= idx.cache.byte_budget
    assert idx.cache.evictions > 0
    # entries too large for the whole budget are refused, not thrashed
    assert idx.cache.rejections >= 0
    assert len(idx.cache) <= idx.cache.max_entries


def test_cache_rejects_entry_larger_than_budget():
    cache = SemanticCache(byte_budget=256, max_entries=8)
    rect = np.array([[0.0, 1.0], [0.0, 1.0]])
    ids = np.arange(1000, dtype=np.int64)
    rows = np.zeros((1000, 2), np.float32)
    assert not cache.admit((0, 0, 0, 0, 0), rect, ids, rows)
    assert cache.rejections == 1 and len(cache) == 0


# --------------------------------------------------------------------- #
# Executor/server stats plumbing
# --------------------------------------------------------------------- #
def test_server_reports_cache_stats():
    ds = _DS["airline"]()
    srv = QueryServer(COAXIndex(ds.data, NOAUTO), max_batch=16,
                      cache_bytes=8 << 20)
    rects = zipf_rects(ds.data, n=48, n_hot=6, seed=4)
    srv.submit_many(rects)
    srv.drain()
    srv.submit_many(rects)
    srv.drain()
    s = srv.stats()
    assert s["cache_hits"] + s["cache_partial"] > 0
    assert 0.0 < s["cache_hit_rate"] <= 1.0
    assert s["cache_bytes"] > 0
    assert any(w.cache_hits + w.cache_partial > 0
               for w in srv.executor.wave_stats)


# --------------------------------------------------------------------- #
# Zipfian generator properties (tests/workloads.py)
# --------------------------------------------------------------------- #
def test_zipf_rects_deterministic_and_nested():
    ds = _DS["osm"]()
    a = zipf_rects(ds.data, n=64, n_hot=8, seed=3)
    b = zipf_rects(ds.data, n=64, n_hot=8, seed=3)
    assert np.array_equal(a, b)                 # deterministic per seed
    pool = zipf_rects(ds.data, n=256, n_hot=8, nest_frac=0.0, seed=3)
    uniq = {r.tobytes() for r in pool}
    assert len(uniq) <= 8                       # draws come from the hot pool
    # every rect (nested or not) is contained in some hot-pool rect
    hot = np.unique(pool.reshape(pool.shape[0], -1), axis=0).reshape(-1, *a.shape[1:])
    for r in a:
        assert any(np.all(h[:, 0] <= r[:, 0]) and np.all(r[:, 1] <= h[:, 1])
                   for h in hot)
    assert np.all(a[:, :, 0] <= a[:, :, 1])     # well-formed half-open rects


# --------------------------------------------------------------------- #
# Hypothesis: arbitrary query/write interleavings, cached == plain
# --------------------------------------------------------------------- #
_H_DS = make_airline(2_000, seed=13)
_H_RECTS = np.concatenate([
    zipf_rects(_H_DS.data, n=12, n_hot=4, seed=21),
    rects_for(_H_DS.data, n=4, seed=21, extremes=False)])


@settings(max_examples=10, deadline=None)
@given(st.lists(st.tuples(st.sampled_from("qidc"),
                          st.integers(min_value=0, max_value=15)),
                min_size=1, max_size=12))
def test_cached_equals_plain_under_interleavings(ops):
    """Property: under ANY interleaving of queries, inserts, deletes and
    cache-clears, a cached index answers bit-identically to an uncached
    twin driven through the same schedule (ids align by construction)."""
    cached = COAXIndex(_H_DS.data, NOAUTO).attach_cache(byte_budget=1 << 20)
    plain = COAXIndex(_H_DS.data, NOAUTO)
    inserted = []
    for op, k in ops:
        if op == "q":
            rects = _H_RECTS[k % _H_RECTS.shape[0]:][:4]
            _split_equal(cached.query_batch_split(rects),
                         plain.query_batch_split(rects), ("q", k))
        elif op == "i":
            rows = _H_DS.data[k * 7 % _H_DS.data.shape[0]][None]
            inserted.append((cached.insert(rows)[0], plain.insert(rows)[0]))
            assert inserted[-1][0] == inserted[-1][1]
        elif op == "d" and inserted:
            ca, pa = inserted.pop(k % len(inserted))
            assert cached.delete([ca]) == plain.delete([pa]) == 1
        elif op == "c":
            cached.cache.clear()
    rects = _H_RECTS
    _split_equal(cached.query_batch_split(rects),
                 plain.query_batch_split(rects), "final")
