"""Property tests for the exactness primitives (DESIGN.md §4, §6).

Every backend's bit-identity argument bottoms out in two facts:

* paired f32 rounding is conservative — ``f32_ceil(c)`` is the smallest
  float32 >= the float64 bound ``c`` (and ``f32_floor`` its mirror), so a
  float32 record can be compared against ``c`` entirely in float32 without
  ever flipping a membership decision;
* the batched Eq. 2 translation is BIT-identical to the scalar reference,
  so the numpy, device and sharded planes all navigate from the same
  nav-rects.

Hypothesis (via ``_hypothesis_compat``: skipped, not errored, when absent)
drives both over adversarial floats — ±inf, subnormals, f32-overflowing
magnitudes — alongside deterministic spot checks of the same corners that
run even without hypothesis.
"""
import numpy as np
import pytest

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from repro.core import COAXIndex, translate_rect, translate_rects
from repro.core.gridfile import f32_ceil
from repro.data import make_generic_fd
from repro.engine.device import f32_floor

# NaN-free float64s, infinities and subnormals included
_f64 = st.floats(allow_nan=False, allow_infinity=True, width=64)
# float32 record values (what the stored rows can actually hold)
_f32 = st.floats(allow_nan=False, allow_infinity=True, width=32)

_ADVERSARIAL = [
    0.0, -0.0, np.inf, -np.inf, 1e39, -1e39,           # beyond f32 range
    float(np.finfo(np.float32).max), float(np.finfo(np.float32).tiny),
    5e-324, -5e-324,                                    # f64 subnormals
    float(np.float64(np.float32(1.1)) + 1e-12),         # straddles an f32
    1.0 + 2**-40, -1.0 - 2**-40,
]


def _probe_values(c):
    """float32 values worth testing against a float64 bound ``c``: the
    rounded bound itself and its f32 neighbours."""
    with np.errstate(over="ignore"):
        y = np.float64(np.clip(c, -3.4e38, 3.4e38)).astype(np.float32)
    return [np.float32(y),
            np.nextafter(y, np.float32(-np.inf)),
            np.nextafter(y, np.float32(np.inf))]


def _check_ceil(c, vs):
    cu = f32_ceil(np.asarray([c]))[0]
    assert cu.dtype == np.float32
    cu64 = float(cu)
    assert cu64 >= c                                     # conservative
    if np.isfinite(cu64) and cu64 > float(np.finfo(np.float32).min):
        # minimal: the next f32 down is strictly below c
        assert float(np.nextafter(np.float32(cu), np.float32(-np.inf))) < c
    for v in vs:                                         # membership-preserving
        v64 = float(np.float32(v))
        assert (v64 >= c) == (np.float32(v) >= cu), (c, v)
        assert (v64 < c) == (np.float32(v) < cu), (c, v)


def _check_floor(c, vs):
    fl = f32_floor(np.asarray([c]))[0]
    assert fl.dtype == np.float32
    fl64 = float(fl)
    assert fl64 <= c                                     # conservative
    if np.isfinite(fl64) and fl64 < float(np.finfo(np.float32).max):
        assert float(np.nextafter(np.float32(fl), np.float32(np.inf))) > c
    for v in vs:
        v64 = float(np.float32(v))
        assert (v64 <= c) == (np.float32(v) <= fl), (c, v)
        assert (v64 > c) == (np.float32(v) > fl), (c, v)


def test_f32_rounding_spot_checks():
    """The adversarial corner list runs even without hypothesis."""
    for c in _ADVERSARIAL:
        _check_ceil(c, _probe_values(c))
        _check_floor(c, _probe_values(c))


@settings(max_examples=300, deadline=None)
@given(c=_f64, v=_f32)
def test_f32_ceil_paired_rounding_conservative(c, v):
    _check_ceil(c, [np.float32(v)] + _probe_values(c))


@settings(max_examples=300, deadline=None)
@given(c=_f64, v=_f32)
def test_f32_floor_paired_rounding_conservative(c, v):
    _check_floor(c, [np.float32(v)] + _probe_values(c))


@settings(max_examples=200, deadline=None)
@given(c=_f64)
def test_f32_floor_ceil_bracket(c):
    """floor(c) <= c <= ceil(c), and they coincide exactly when c is
    representable in float32."""
    fl = float(f32_floor(np.asarray([c]))[0])
    cu = float(f32_ceil(np.asarray([c]))[0])
    assert fl <= c <= cu
    representable = float(np.float32(c)) == c or not np.isfinite(c)
    assert (fl == cu) == representable


# --------------------------------------------------------------------- #
# Batched vs scalar Eq. 2 translation
# --------------------------------------------------------------------- #
_TR_DS = make_generic_fd(6_000, 5, ((0, 1), (2, 3)), seed=7)
_TR_IDX = COAXIndex(_TR_DS.data)

_bound = st.one_of(_f64, st.sampled_from(_ADVERSARIAL))


def _check_translate_agreement(rects):
    batch = translate_rects(rects, _TR_IDX.groups, _TR_IDX.keep_dims)
    for i, r in enumerate(rects):
        single = translate_rect(r, _TR_IDX.groups, _TR_IDX.keep_dims)
        assert np.array_equal(batch[i], single), (i, r.tolist())


def test_translate_degenerate_inf_constraints():
    """Deterministic corners: fully unconstrained, half-open, and the
    degenerate all-infinite dependent constraints the scalar path skips."""
    d = _TR_DS.data.shape[1]
    dep = _TR_IDX.groups[0].dependents[0]
    base = np.stack([np.full(d, -np.inf), np.full(d, np.inf)], axis=-1)
    rects = []
    for lo, hi in [(-np.inf, np.inf), (np.inf, np.inf), (-np.inf, -np.inf),
                   (1e39, 1e39), (-np.inf, 0.0), (0.0, np.inf)]:
        r = base.copy()
        r[dep] = [lo, hi]
        rects.append(r)
    _check_translate_agreement(np.stack(rects))


@settings(max_examples=150, deadline=None)
@given(st.lists(st.tuples(_bound, _bound), min_size=5, max_size=5))
def test_translate_rects_matches_scalar_on_adversarial_floats(bounds):
    rect = np.array([[min(a, b), max(a, b)] for a, b in bounds])
    _check_translate_agreement(rect[None])
    # and inside a batch whose other rows are ordinary
    other = np.stack([np.zeros(5), np.ones(5)], axis=-1)
    _check_translate_agreement(np.stack([other, rect, other]))


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_hypothesis_is_driving():
    """Guard: when hypothesis IS available the @given tests above must be
    real property tests, not silently inert decorators."""
    from hypothesis import given as real_given
    assert given is real_given
