"""LSM write path: tiered delta runs + background compaction with handoff.

Three layers of coverage for DESIGN.md §5.3–§5.4:

* ``DeltaPlane`` tiered runs — spill/merge policy invariants, the
  binary-searched ``scan_batch`` against a dense oracle (tombstones
  included), sub-linear probe accounting, and the ``organized``-boundary
  state round-trip (L0 fill level is part of §7.3 determinism);
* the epoch-handoff window — queries, inserts and deletes interleaved
  while a background build is deterministically HELD OPEN (the poll is
  stubbed to a no-op, so the old epoch must keep serving exactly), on
  numpy and device backends, single and sharded, plus a hypothesis
  variant over drawn op sequences;
* crash injection mid-handoff — the primary dies inside
  ``Durability.handoff_rotate`` after the tail re-journal but before the
  new snapshot publishes; recovery must replay the old pair, re-fire the
  compaction synchronously and land bit-identical to a never-crashed
  synchronous twin (§7.5).

Satellites asserted here: amortized ``trigger_checks``, the
``describe()`` background/run surfacing, and the device plane's
``compile_count`` staying flat across compaction epochs (pow2-bucketed
images + ``_PlanBase.adopt``).
"""
import numpy as np
import pytest

from repro.core import COAXIndex, CoaxConfig
from repro.core.delta import DeltaPlane
from repro.core.types import rect_contains
from repro.data import make_generic_fd
from repro.engine import ShardedCOAX
from repro.storage import Durability, restore

from _hypothesis_compat import given, settings, st
from workloads import assert_equiv, rects_for, violate_fd

_DS = make_generic_fd(9_000, 5, ((0, 1), (2, 3)), seed=7)


def _more(seed, m):
    return make_generic_fd(m, 5, ((0, 1), (2, 3)), seed=seed).data


# triggers low enough that short schedules cross them; checks amortized
BG = CoaxConfig(compact_min_delta=300, compact_delta_frac=0.01,
                drift_min_delta=200, compact_check_rows=64,
                delta_l0_spill=64, background_compact=True)
SYNC = CoaxConfig(compact_min_delta=300, compact_delta_frac=0.01,
                  drift_min_delta=200, compact_check_rows=64,
                  delta_l0_spill=64, background_compact=False)


def _device_ok():
    try:
        from repro.engine import device_available
        return device_available()
    except ImportError:
        return False


needs_device = pytest.mark.skipif(not _device_ok(), reason="jax unavailable")


def _hold_window_open(idx):
    """Freeze the handoff window: shadow ``poll_handoff`` with a no-op so
    the finished build cannot install and every query/write must be served
    by the old epoch ∪ its delta — the §5.4 during-build contract, made
    deterministic."""
    idx.poll_handoff = lambda wait=False: False


def _release_window(idx):
    del idx.poll_handoff               # uncover the real method


# --------------------------------------------------------------------- #
# DeltaPlane: tiered runs
# --------------------------------------------------------------------- #
def test_tiered_spill_and_merge_policy():
    dp = DeltaPlane(3, key_dim=1, l0_spill=8)
    rng = np.random.default_rng(0)
    next_id = 0
    for i in range(40):
        rows = rng.random((8, 3)).astype(np.float32)
        spilled = dp.insert(rows, np.arange(next_id, next_id + 8))
        next_id += 8
        assert spilled == 1                      # exactly at the fill level
        assert dp.l0_rows == 0
        sizes = [p.size for p, _ in dp._runs]
        assert sum(sizes) == dp._organized == dp.n_log
        # tier invariant: after merging, every older run is > 2x its newer
        # neighbour, so the run count stays logarithmic
        for a, b in zip(sizes, sizes[1:]):
            assert a > 2 * b, sizes
        for pos, keys in dp._runs:               # runs are sorted views
            assert np.all(np.diff(keys) >= 0)
            assert np.array_equal(
                np.sort(keys),
                np.sort(dp._log_rows()[pos, 1].astype(np.float64)))
    assert dp.spills == 40
    assert dp.merges > 0
    assert dp.n_runs <= int(np.log2(dp.n_log)) + 1
    # sub-spill appends stay in L0
    dp.insert(rng.random((3, 3)).astype(np.float32), np.arange(10**6, 10**6 + 3))
    assert dp.l0_rows == 3 and dp.spills == 40


def _dense_oracle(dp, rects):
    rows, ids = dp.live_log()
    q_parts, r_parts = [], []
    for qi, rect in enumerate(rects):
        hit = ids[rect_contains(np.asarray(rect, np.float64), rows)]
        q_parts.append(np.full(hit.size, qi, np.int64))
        r_parts.append(hit)
    q = np.concatenate(q_parts) if q_parts else np.empty(0, np.int64)
    r = np.concatenate(r_parts) if r_parts else np.empty(0, np.int64)
    order = np.lexsort((r, q))
    return q[order], r[order]


def test_scan_batch_equals_dense_oracle_with_tombstones():
    rng = np.random.default_rng(1)
    dp = DeltaPlane(4, key_dim=2, l0_spill=32)
    for i in range(30):
        m = int(rng.integers(5, 60))
        rows = rng.random((m, 4)).astype(np.float32)
        dp.insert(rows, np.arange(dp.n_log, dp.n_log + m))
        if i % 4 == 3:
            dp.tombstone_log(rng.integers(0, dp.n_log, 15).astype(np.int64))
    # rect mix: narrow key-dim windows, an empty window, full range, ±inf
    rects = []
    for _ in range(12):
        lo = rng.random(4) * 0.9
        hi = lo + rng.random(4) * 0.15
        rects.append(np.stack([lo, hi], axis=-1))
    rects.append(np.stack([np.full(4, 2.0), np.full(4, 3.0)], axis=-1))
    rects.append(np.stack([np.full(4, -np.inf), np.full(4, np.inf)], axis=-1))
    rects = np.stack(rects)
    q, r = dp.scan_batch(rects)
    order = np.lexsort((r, q))
    oq, orr = _dense_oracle(dp, rects)
    assert np.array_equal(q[order], oq) and np.array_equal(r[order], orr)
    # per-run binary search means narrow windows probe far fewer candidate
    # rows than a dense scan of the whole log would
    assert dp.last_scan_probed < rects.shape[0] * dp.n_live / 2
    # scalar scan agrees per rect
    for qi, rect in enumerate(rects):
        assert np.array_equal(np.sort(dp.scan(rect)), orr[oq == qi])


def test_state_roundtrip_preserves_l0_boundary():
    rng = np.random.default_rng(2)
    dp = DeltaPlane(3, key_dim=1, l0_spill=16)
    dp.insert(rng.random((32, 3)).astype(np.float32), np.arange(32))
    dp.insert(rng.random((8, 3)).astype(np.float32), np.arange(32, 40))
    dp.tombstone_log(np.array([3, 17, 35]))
    dp.tombstone_base(np.array([10**7]))
    assert dp.l0_rows == 8
    rt = DeltaPlane.from_state(3, dp.state_dict(), key_dim=1, l0_spill=16)
    assert rt._organized == dp._organized == 32
    assert rt.l0_rows == dp.l0_rows and rt.n_runs == 1
    assert rt.n_log_dead == dp.n_log_dead
    assert rt.n_base_dead == dp.n_base_dead
    rects = np.stack([np.stack([np.full(3, 0.2), np.full(3, 0.8)], axis=-1)])
    for plane in (dp, rt):
        q, r = plane.scan_batch(rects)
        o = np.lexsort((r, q))
        plane.hits = (q[o], r[o])
    assert np.array_equal(dp.hits[0], rt.hits[0])
    assert np.array_equal(dp.hits[1], rt.hits[1])
    # the restored L0 fill level spills at the SAME append as the original
    more = rng.random((8, 3)).astype(np.float32)
    assert dp.insert(more, np.arange(100, 108)) == \
        rt.insert(more, np.arange(100, 108)) == 1


# --------------------------------------------------------------------- #
# Amortized trigger checks
# --------------------------------------------------------------------- #
def test_trigger_checks_amortized_by_rows():
    cfg = CoaxConfig(compact_check_rows=64, compact_min_delta=10**9,
                     drift_min_delta=10**9)
    idx = COAXIndex(_DS.data[:2_000], cfg)
    for i in range(200):                       # one-row writes, 200 of them
        idx.insert(_DS.data[i % 2_000][None])
    assert idx.trigger_checks == 200 // 64     # not 200
    assert idx.describe()["trigger_checks"] == idx.trigger_checks


def test_trigger_check_fires_on_l0_spill():
    cfg = CoaxConfig(compact_check_rows=10**6, compact_min_delta=10**9,
                     drift_min_delta=10**9, delta_l0_spill=32)
    idx = COAXIndex(_DS.data[:2_000], cfg)
    for i in range(40):
        idx.insert(_DS.data[i][None])
    # the spill at row 32 forced a check even though the row budget never
    # filled; rows 33..40 bank toward the next one
    assert idx.trigger_checks == 1
    assert idx.delta_primary.spills + idx.delta_outlier.spills == 1


# --------------------------------------------------------------------- #
# The handoff window: old epoch ∪ fresh delta serves during the build
# --------------------------------------------------------------------- #
def _write_until_build_starts(idx, seed0=500, batch=120):
    i = 0
    while idx._handoff_thread is None:
        rows = _more(seed0 + i, batch)
        if i % 3 == 2:
            rows = violate_fd(_DS, rows)
        idx.insert(rows)
        i += 1
        assert i < 60, "background build never triggered"


@pytest.mark.parametrize("backend", [
    "numpy", pytest.param("device", marks=needs_device)])
def test_queries_exact_during_background_build(backend):
    dev = backend == "device"
    idx = COAXIndex(_DS.data, BG)
    if dev:
        idx.backend = "device"
    rects = rects_for(_DS.data, n=8)
    _write_until_build_starts(idx)
    _hold_window_open(idx)
    assert idx.epoch == 0 and idx.describe()["background"]["in_flight"]
    for j in range(4):                 # writes + queries inside the window
        idx.insert(_more(900 + j, 50))
        idx.delete(np.arange(j * 11, j * 11 + 7))
        assert_equiv(idx, rects, device=dev, tag=("window", j))
    assert idx.epoch == 0, "held-open window must keep serving the old epoch"
    _release_window(idx)
    assert idx.finish_handoff()
    # the tail replay ticks live counters; a big-enough banked tail may
    # legitimately re-fire a nested SYNC compaction (epoch 2)
    assert idx.epoch >= 1 and idx.background_compactions == 1
    assert idx.compactions == idx.epoch
    d = idx.describe()
    assert d["background"]["completed"] == 1 and not d["background"]["in_flight"]
    assert idx.last_handoff_s > 0
    assert_equiv(idx, rects, device=dev, tag="after-handoff")


def test_sharded_background_compaction_exact():
    sh = ShardedCOAX(_DS.data, BG, n_shards=3, partition="range",
                     partition_dim=0)
    rects = rects_for(_DS.data, n=8)
    for j in range(12):
        rows = _more(700 + j, 150)
        if j % 4 == 3:
            rows = violate_fd(_DS, rows)
        sh.insert(rows)
        sh.delete(np.arange(j * 29, j * 29 + 11))
        if j % 3 == 2:                 # polls happen at query entry
            assert_equiv(sh, rects, scratch=False, tag=("mid", j))
    sh.finish_handoff()
    assert sh.background_compactions >= 1
    d = sh.describe()
    assert d["background"]["completed"] == sh.background_compactions
    assert d["background"]["in_flight"] == 0
    assert d["trigger_checks"] > 0 and len(d["delta_runs"]) == 3
    assert_equiv(sh, rects, tag="sharded-final")


@settings(max_examples=10, deadline=None)
@given(data=st.data())
def test_prop_interleaved_ops_during_handoff(data):
    """Hypothesis: ANY short interleaving of inserts/deletes applied inside
    a held-open handoff window answers bit-identically to a scratch rebuild,
    and still does after the handoff installs."""
    idx = COAXIndex(_DS.data[:4_000], BG)
    rects = rects_for(_DS.data[:4_000], n=5, extremes=False)
    _write_until_build_starts(idx, seed0=data.draw(
        st.integers(min_value=0, max_value=10**4), label="seed0"))
    _hold_window_open(idx)
    for j in range(data.draw(st.integers(min_value=1, max_value=4),
                             label="n_ops")):
        kind = data.draw(st.sampled_from(["ins", "ins_viol", "del"]),
                         label=f"op{j}")
        if kind == "del":
            lo = data.draw(st.integers(min_value=0, max_value=3_000),
                           label=f"del_lo{j}")
            idx.delete(np.arange(lo, lo + 40))
        else:
            rows = _more(data.draw(st.integers(min_value=0, max_value=10**4),
                                   label=f"seed{j}"),
                         data.draw(st.integers(min_value=1, max_value=80),
                                   label=f"m{j}"))
            idx.insert(violate_fd(_DS, rows) if kind == "ins_viol" else rows)
        assert_equiv(idx, rects, tag=("prop-window", j))
    _release_window(idx)
    idx.finish_handoff()
    assert idx.epoch >= 1
    assert_equiv(idx, rects, tag="prop-after")


# --------------------------------------------------------------------- #
# Crash injection: die inside handoff_rotate, recover via §7
# --------------------------------------------------------------------- #
class _Boom(RuntimeError):
    pass


def test_crash_mid_handoff_recovers_bit_identical(tmp_path):
    import repro.storage.durability as dmod

    idx = COAXIndex(_DS.data, BG)
    Durability.attach(idx, tmp_path)
    oracle = COAXIndex(_DS.data.copy(), SYNC)   # never-crashed sync twin

    def both(op, *args):
        getattr(idx, op)(*args)
        getattr(oracle, op)(*args)

    i = 0
    while idx._handoff_thread is None:          # identical journaled history
        rows = _more(500 + i, 120)
        if i % 3 == 2:
            rows = violate_fd(_DS, rows)
        both("insert", rows)
        i += 1
        assert i < 60
    _hold_window_open(idx)
    for j in range(3):                          # the tail the handoff owes
        both("insert", _more(900 + j, 50))
        both("delete", np.arange(j * 11, j * 11 + 7))
    _release_window(idx)

    # kill the primary INSIDE the rotation: tail re-journaled + fsynced
    # into the new WAL, new snapshot never published (§7.5 crash window)
    orig = dmod.write_snapshot
    dmod.write_snapshot = lambda *a, **k: (_ for _ in ()).throw(_Boom())
    try:
        with pytest.raises(_Boom):
            idx.finish_handoff()
    finally:
        dmod.write_snapshot = orig
    del idx                                     # the crash: memory is gone

    rec = restore(tmp_path, durable=True)
    rects = rects_for(_DS.data, n=8)
    lq, lr = oracle.query_batch(rects)
    q, r = rec.query_batch(rects)
    assert np.array_equal(q, lq) and np.array_equal(r, lr)
    assert rec.epoch == oracle.epoch >= 1       # replay re-fired the build
    assert rec.compactions == oracle.compactions
    assert rec.n_rows == oracle.n_rows
    assert rec._next_id == oracle._next_id
    # amortized-trigger phase converged too (§5.4 counter contract)
    assert rec._write_units == oracle._write_units
    assert rec.trigger_checks == oracle.trigger_checks
    # resume writing on the recovered plane: same trigger timing onwards
    for j in range(4):
        rows = _more(2_000 + j, 120)
        rec.insert(rows)
        oracle.insert(rows)
    assert rec.epoch == oracle.epoch
    assert rec.trigger_checks == oracle.trigger_checks
    q, r = rec.query_batch(rects)
    lq, lr = oracle.query_batch(rects)
    assert np.array_equal(q, lq) and np.array_equal(r, lr)


def test_background_world_converges_with_sync_world():
    """Same op stream, background vs synchronous compaction: query results,
    epochs and trigger phase all converge once the handoff lands."""
    bg = COAXIndex(_DS.data, BG)
    sy = COAXIndex(_DS.data.copy(), SYNC)
    for i in range(14):
        rows = _more(500 + i, 120)
        if i % 3 == 2:
            rows = violate_fd(_DS, rows)
        bg.insert(rows)
        sy.insert(rows)
        if i % 2 == 1:
            dead = np.arange(i * 13, i * 13 + 9)
            bg.delete(dead)
            sy.delete(dead)
    bg.finish_handoff()
    assert sy.compactions >= 1
    assert bg.epoch == sy.epoch
    assert bg.compactions == sy.compactions
    assert bg._write_units == sy._write_units
    assert bg.trigger_checks == sy.trigger_checks
    rects = rects_for(_DS.data, n=8)
    bq, br = bg.query_batch(rects)
    q, r = sy.query_batch(rects)
    assert np.array_equal(bq, q) and np.array_equal(br, r)


# --------------------------------------------------------------------- #
# Device plane: pow2 image bucketing keeps the jit cache flat
# --------------------------------------------------------------------- #
@needs_device
def test_grid_image_pow2_padding():
    from repro.engine.device import _GridImage, _next_pow2

    idx = COAXIndex(_DS.data[:3_000], CoaxConfig(auto_compact=False))
    for tile in (256, 512):
        img = _GridImage(idx.primary, tile)
        n = idx.primary.n_rows
        assert img.n_pad >= n + 1                # the dead +inf pad row
        assert img.n_pad % tile == 0
        assert img.n_pad == max(tile, _next_pow2(n + 1))


@needs_device
def test_compile_count_flat_across_compaction_epochs():
    cfg = CoaxConfig(compact_min_delta=300, compact_delta_frac=0.01,
                     drift_min_delta=10**9, compact_check_rows=64,
                     delta_l0_spill=64)
    idx = COAXIndex(_DS.data[:6_000], cfg)
    idx.backend = "device"
    rects = rects_for(_DS.data[:6_000], n=8, extremes=False)
    counts = []
    for cycle in range(5):                   # identical op shape per cycle
        epoch_before = idx.epoch
        # rows from the SAME dataset follow the learned FD, so primary and
        # outlier stay inside their pow2 image buckets across epochs
        idx.insert(_DS.data[6_000:6_160])
        idx.query_batch(rects)
        idx.insert(_DS.data[6_160:6_320])    # 320 >= trigger: compacts here
        assert idx.epoch == epoch_before + 1
        idx.query_batch(rects)
        counts.append(idx._coax_plan.compile_count)
    assert counts[-1] == counts[-2] == counts[-3], counts
    # the jit cache and launch counters survived every epoch swap (adopt)
    assert idx._coax_plan.dispatch_count >= 2 * len(counts)
