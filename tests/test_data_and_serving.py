"""Data pipeline (sharded/resumable/prefetch), COAX curation, request router
and the serving loop."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import tiny_config
from repro.configs import get_config
from repro.core import FullScan
from repro.data.curation import CuratedSelector, MetaQuery
from repro.data.pipeline import ShardedLoader, make_corpus
from repro.models import build_model
from repro.runtime.router import CoaxRouter
from repro.runtime.serve_loop import ServeConfig, Server


@pytest.fixture(scope="module")
def corpus():
    return make_corpus(8_000, vocab_size=512, seed=1)


# ----------------------------- pipeline ---------------------------------- #

def test_loader_determinism_and_resume(corpus):
    l1 = ShardedLoader(corpus, batch_size=4, seq_len=32, seed=3)
    it1 = iter(l1)
    batches = [next(it1) for _ in range(5)]
    l1.close()

    # replay from a state snapshot
    l2 = ShardedLoader(corpus, batch_size=4, seq_len=32, seed=3)
    it2 = iter(l2)
    for _ in range(3):
        next(it2)
    state = l2.state_dict()
    l2.close()

    l3 = ShardedLoader(corpus, batch_size=4, seq_len=32, seed=3)
    l3.load_state(state)
    it3 = iter(l3)
    nxt = next(it3)
    l3.close()
    assert np.array_equal(nxt["tokens"], batches[3]["tokens"])
    assert np.array_equal(nxt["labels"], batches[3]["labels"])


def test_loader_host_shards_disjoint(corpus):
    a = ShardedLoader(corpus, batch_size=2, seq_len=8, process_index=0,
                      process_count=2, seed=5)
    b = ShardedLoader(corpus, batch_size=2, seq_len=8, process_index=1,
                      process_count=2, seed=5)
    da = a._epoch_order(0)
    db = b._epoch_order(0)
    assert len(np.intersect1d(da, db)) == 0
    assert len(da) + len(db) == corpus.meta.shape[0]


def test_labels_are_shifted_tokens(corpus):
    l = ShardedLoader(corpus, batch_size=2, seq_len=16, seed=7)
    it = iter(l)
    b = next(it)
    l.close()
    assert np.array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_corpus_has_soft_fds(corpus):
    """The metadata generator must contain the FDs curation relies on."""
    meta = corpus.meta.astype(np.float64)
    cc = np.corrcoef(meta[:, 2], meta[:, 4])[0, 1]   # token_len ~ compute_cost
    assert cc > 0.99
    cc2 = np.corrcoef(meta[:, 2], meta[:, 3])[0, 1]  # token_len ~ byte_len
    assert cc2 > 0.95


# ----------------------------- curation ---------------------------------- #

def test_curation_matches_reference(corpus):
    sel = CuratedSelector(corpus)
    queries = [
        MetaQuery(token_len=(256, 2048)),
        MetaQuery(token_len=(512, 4096), quality=(0.8, 1.1)),
        MetaQuery(compute_cost=(1000, 5000), domain_id=(0, 8)),
        MetaQuery(timestamp=(1.6e9, 1.6e9 + 1e6)),
    ]
    for q in queries:
        got = sel.select(q)
        want = sel.select_reference(q)
        assert np.array_equal(got, want)
    d = sel.describe()
    assert d["n_rows"] == corpus.meta.shape[0]
    assert len(d["groups"]) >= 1  # at least one soft FD exploited


def test_curriculum_stages(corpus):
    sel = CuratedSelector(corpus)
    stages = [MetaQuery(token_len=(0, 512)), MetaQuery(token_len=(512, 4096))]
    cur = sel.curriculum(stages)
    assert set(cur) == {0, 1}
    assert len(np.intersect1d(cur[0], cur[1])) == 0


# ----------------------------- router ------------------------------------ #

def test_router_admission_matches_naive_filter():
    rng = np.random.default_rng(0)
    router = CoaxRouter(rebuild_threshold=64)
    lens = []
    for i in range(400):
        n = int(rng.integers(8, 512))
        router.submit(np.ones(n, np.int32), max_new_tokens=64,
                      priority=float(rng.random()), arrival=float(i))
        lens.append(n)
    batch = router.admit(16, prompt_len_range=(64, 256))
    assert 0 < len(batch) <= 16
    for r in batch:
        assert 64 <= r.prompt_len < 256
    # admitted requests leave the pool
    assert len(router) == 400 - len(batch)
    # priority-then-FIFO ordering
    ps = [r.priority for r in batch]
    assert ps == sorted(ps, reverse=True)


def test_router_stats_expose_index():
    router = CoaxRouter(rebuild_threshold=64)
    rng = np.random.default_rng(1)
    for i in range(128):
        router.submit(np.ones(int(rng.integers(8, 400)), np.int32), 32,
                      arrival=float(i))
    s = router.stats()
    assert s["indexed"] > 0
    assert s["pending"] == 128


# ----------------------------- serving ----------------------------------- #

def test_server_end_to_end():
    cfg = tiny_config(get_config("h2o-danube-3-4b"))
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(0))
    srv = Server(model, params, ServeConfig(batch_size=4, max_new_tokens=8,
                                            cache_len=64, eos_token=0))
    rng = np.random.default_rng(2)
    rids = [srv.submit(rng.integers(1, 200, rng.integers(4, 24)).astype(np.int32))
            for _ in range(10)]
    results = srv.run_until_drained()
    assert len(results) == 10
    assert {r.rid for r in results} == set(rids)
    for r in results:
        assert r.tokens.shape[0] <= 8
    assert srv.waves >= 2  # 10 requests, batch 4 -> at least 3 waves
