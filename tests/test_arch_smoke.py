"""Per-architecture smoke tests (deliverable f): every assigned arch at a
reduced config runs one forward + one train step on CPU with finite loss,
correct shapes, and decode-path consistency with the full forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_config
from repro.configs import get_config, list_configs
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init
from repro.runtime.steps import make_train_step

ARCHS = list_configs()


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = tiny_config(get_config(arch))
    model = build_model(cfg)
    params, axes = model.init(jax.random.key(0))
    is_ax = lambda x: isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)
    ax_leaves = jax.tree.leaves(axes, is_leaf=is_ax)
    p_leaves = jax.tree.leaves(params)
    assert len(ax_leaves) == len(p_leaves)
    for a, pl in zip(ax_leaves, p_leaves):
        assert len(a) == pl.ndim, (a, pl.shape)  # axes annotate every dim
    batch = make_batch(cfg, batch=2, seq=16)

    logits, aux = jax.jit(model.forward)(params, batch)
    if cfg.family == "vlm":
        assert logits.shape[1] == 16  # patches + text
    else:
        assert logits.shape[:2] == (2, 16)
    assert logits.shape[-1] == cfg.padded_vocab
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())

    step = jax.jit(make_train_step(model, AdamWConfig(lr=1e-3)))
    opt = adamw_init(params)
    p2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"])) and float(metrics["grad_norm"]) > 0
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum()) for a, b in
                zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """Prefill+decode logits must match the full forward at the same
    positions — validates KV caches, ring buffers, MLA absorption and the
    SSD/recurrence duality."""
    cfg = tiny_config(get_config(arch))
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(1))
    rng = np.random.default_rng(0)
    S = 12
    toks = jnp.asarray(rng.integers(0, 200, (2, S + 1)), jnp.int32)

    batch = make_batch(cfg, batch=2, seq=S, with_labels=False)
    batch_next = dict(batch)
    batch["tokens"] = toks[:, :S]
    batch_next["tokens"] = toks[:, :S + 1]
    logits_full, _ = jax.jit(model.forward)(params, batch)

    last, cache = jax.jit(lambda p, b: model.prefill(p, b, 32))(params, batch)
    a = np.asarray(last[:, -1].astype(jnp.float32))
    b = np.asarray(logits_full[:, -1].astype(jnp.float32))
    np.testing.assert_allclose(a, b, atol=0.08, rtol=0.05)

    step = S if cfg.family != "vlm" else S + cfg.n_patches
    dl, _ = jax.jit(model.decode_step)(params, cache, toks[:, S:S + 1],
                                       jnp.int32(step))
    logits_full2, _ = jax.jit(model.forward)(params, batch_next)
    np.testing.assert_allclose(
        np.asarray(dl[:, -1].astype(jnp.float32)),
        np.asarray(logits_full2[:, -1].astype(jnp.float32)),
        atol=0.08, rtol=0.05)


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b", "mamba2-130m"])
def test_long_decode_bounded_cache(arch):
    """SWA ring / SSM state: cache size must NOT grow with requested length."""
    cfg = tiny_config(get_config(arch))
    model = build_model(cfg)
    small = model.init_cache(1, 64, abstract=True)
    huge = model.init_cache(1, 1 << 19, abstract=True)
    for k in small:
        if k in ("k", "v"):
            assert huge[k].shape[2] == min(cfg.window, 1 << 19)
        if k.startswith("conv") or k == "ssm":
            assert huge[k].shape == small[k].shape


def test_full_configs_match_assignment():
    """The FULL configs carry the exact assigned hyperparameters."""
    spec = {
        "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
        "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
        "gemma2-27b": (46, 4608, 32, 16, 36864, 256000),
        "minitron-4b": (32, 3072, 24, 8, 9216, 256000),
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "mamba2-130m": (24, 768, 0, 0, 0, 50280),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        cfg = get_config(name)
        assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                cfg.d_ff, cfg.vocab_size) == (L, d, h, kv, ff, v), name
    # families / features
    assert get_config("mixtral-8x7b").n_experts == 8
    assert get_config("phi3.5-moe-42b-a6.6b").n_experts == 16
    assert get_config("zamba2-2.7b").ssm_state == 64
    assert get_config("mamba2-130m").ssm_state == 128
    assert get_config("minicpm3-4b").mla
    assert get_config("gemma2-27b").layer_pattern == ("local", "global")
    assert get_config("qwen2-vl-2b").mrope_sections == (16, 24, 24)
    assert get_config("seamless-m4t-large-v2").enc_layers == 24


def test_param_counts_match_published_scale():
    """Full configs should land near their nameplate parameter counts."""
    import math
    expect = {
        "gemma2-27b": (26e9, 29e9),
        "mixtral-8x7b": (45e9, 48e9),
        "phi3.5-moe-42b-a6.6b": (40e9, 44e9),
        "mamba2-130m": (0.10e9, 0.18e9),
    }
    for name, (lo, hi) in expect.items():
        model = build_model(get_config(name))
        n = model.param_count()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B not in [{lo/1e9}, {hi/1e9}]"
    # mixtral active ~12.9B
    act = build_model(get_config("mixtral-8x7b")).active_param_count()
    assert 12e9 <= act <= 14e9
