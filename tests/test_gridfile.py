"""Grid-file and helper invariants (paper §6)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st  # hypothesis or skip-fallback

from repro.core import FullScan, GridFile, fit_cells_per_dim, gather_ranges
from repro.core.types import full_rect, rect_contains


def test_gather_ranges_basic():
    out = gather_ranges(np.array([0, 5, 9]), np.array([2, 5, 12]))
    assert out.tolist() == [0, 1, 9, 10, 11]
    assert gather_ranges(np.array([3]), np.array([3])).size == 0


@given(st.lists(st.tuples(st.integers(0, 50), st.integers(0, 50)), max_size=8))
@settings(max_examples=50, deadline=None)
def test_gather_ranges_property(pairs):
    los = np.array([min(a, b) for a, b in pairs], np.int64)
    his = np.array([max(a, b) for a, b in pairs], np.int64)
    got = gather_ranges(los, his)
    want = np.concatenate([np.arange(l, h) for l, h in zip(los, his)]) if pairs else np.empty(0)
    assert np.array_equal(got, want.astype(np.int64))


def test_fit_cells_per_dim():
    assert fit_cells_per_dim(2, 100) == 10
    assert fit_cells_per_dim(3, 27) == 3
    assert fit_cells_per_dim(0, 5) == 1
    assert fit_cells_per_dim(4, 1) == 1


@pytest.mark.parametrize("sort_dim", [None, 0, 2])
@pytest.mark.parametrize("quantile", [True, False])
def test_gridfile_equals_fullscan(sort_dim, quantile):
    rng = np.random.default_rng(1)
    data = rng.normal(0, 10, size=(5_000, 3)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=6,
                  sort_dim=sort_dim, quantile=quantile)
    fs = FullScan(data)
    for seed in range(8):
        r = np.sort(rng.normal(0, 10, size=(3, 2)), axis=1)
        assert np.array_equal(gf.query(r, r), fs.query(r))


def test_gridfile_empty_data():
    data = np.zeros((0, 3), np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=4, sort_dim=0)
    r = full_rect(3)
    assert gf.query(r, r).size == 0


def test_gridfile_stats_and_memory():
    rng = np.random.default_rng(2)
    data = rng.uniform(0, 1, size=(2_000, 2)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1], cells_per_dim=8, sort_dim=1)
    r = np.array([[0.2, 0.4], [0.1, 0.9]])
    out = gf.query(r, r)
    st_ = gf.last_query_stats
    assert st_.rows_matched == out.size
    assert st_.rows_scanned >= st_.rows_matched
    assert gf.memory_footprint() > 0
    # sorted dim removes one grid dimension
    assert len(gf.grid_dims) == 1


def test_gridfile_duplicate_values_ok():
    """Quantile edges collapse on heavily-duplicated columns; queries must
    still be exact."""
    rng = np.random.default_rng(3)
    data = np.stack([
        rng.integers(0, 3, 3_000).astype(np.float32),
        rng.normal(0, 1, 3_000).astype(np.float32),
    ], axis=1)
    gf = GridFile(data, index_dims=[0, 1], cells_per_dim=8, sort_dim=1)
    fs = FullScan(data)
    r = np.array([[1.0, 2.0 + 1e-6], [-0.5, 0.5]])
    assert np.array_equal(gf.query(r, r), fs.query(r))
