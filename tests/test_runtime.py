"""Runtime substrate: checkpointing (atomic/async/elastic), fault tolerance
(retry-from-checkpoint, SIGTERM, straggler detection), train loop, optimizer."""
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import make_batch, tiny_config
from repro.configs import get_config
from repro.models import build_model
from repro.optim import AdamWConfig, adamw_init, adamw_update, global_norm
from repro.runtime.checkpoint import Checkpointer, latest_step
from repro.runtime.failure import (FailureInjector, GracefulShutdown,
                                   StragglerDetector, retry)
from repro.runtime.train_loop import TrainLoopConfig, train


def _tiny_model():
    cfg = tiny_config(get_config("h2o-danube-3-4b"))
    return cfg, build_model(cfg)


def _data_iter(cfg, seed=0):
    i = 0
    while True:
        yield make_batch(cfg, batch=2, seq=16, seed=seed + i)
        i += 1


# --------------------------- optimizer ---------------------------------- #

def test_adamw_minimises_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.05
    assert int(state["step"]) == 200


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(3)}
    state = adamw_init(params)
    cfg = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    _, _, metrics = adamw_update(params, {"w": jnp.full(3, 1e6)}, state, cfg)
    assert float(metrics["grad_norm"]) > 1e5  # reported pre-clip


# --------------------------- checkpointing ------------------------------- #

def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones(4, jnp.bfloat16)}}
    ck.save(7, tree)
    out = ck.restore(tree)
    assert np.allclose(np.asarray(out["a"], np.float32), np.arange(6).reshape(2, 3))
    assert out["b"]["c"].dtype == np.asarray(jax.device_get(tree["b"]["c"])).dtype
    assert latest_step(tmp_path) == 7
    assert ck.manifest()["step"] == 7


def test_checkpoint_gc_and_latest(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    tree = {"x": jnp.zeros(2)}
    for s in (1, 2, 3, 4):
        ck.save(s, tree)
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]


def test_checkpoint_async(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save_async(5, {"x": jnp.arange(3)})
    ck.wait()
    assert latest_step(tmp_path) == 5


def test_checkpoint_atomicity(tmp_path):
    """A leftover temp dir must never be picked up as a checkpoint."""
    ck = Checkpointer(tmp_path)
    (tmp_path / ".tmp.step_00000009").mkdir()
    ck.save(3, {"x": jnp.zeros(1)})
    assert latest_step(tmp_path) == 3


def test_checkpoint_elastic_restore_new_sharding(tmp_path):
    """Restore with explicit shardings (elastic path: new mesh/device set)."""
    ck = Checkpointer(tmp_path)
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}
    ck.save(1, tree)
    sharding = jax.sharding.SingleDeviceSharding(jax.devices()[0])
    out = ck.restore(tree, shardings={"w": sharding})
    assert out["w"].sharding == sharding
    assert np.allclose(np.asarray(out["w"]), np.arange(8))


# --------------------------- failure handling ---------------------------- #

def test_retry_recovers():
    calls = {"n": 0}

    def flaky():
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("boom")
        return "ok"

    assert retry(flaky, retries=5, backoff=0.01) == "ok"
    assert calls["n"] == 3


def test_retry_exhausts():
    with pytest.raises(RuntimeError):
        retry(lambda: (_ for _ in ()).throw(RuntimeError("x")).__next__(),
              retries=1, backoff=0.01)


def test_straggler_detector_flags_slow_step():
    det = StragglerDetector(warmup=5, z_thresh=3.0, trip_count=2)
    for s in range(20):
        det.record(s, 0.1 + 0.001 * (s % 3))
    rep = det.record(20, 5.0)
    assert rep is not None and rep.z > 3
    det.record(21, 5.0)
    assert det.hot


def test_graceful_shutdown_flag():
    with GracefulShutdown() as g:
        assert not g.requested
        g.request()
        assert g.requested


# --------------------------- train loop ---------------------------------- #

def test_train_loop_loss_decreases(tmp_path):
    cfg, model = _tiny_model()
    out = train(model, _data_iter(cfg),
                AdamWConfig(lr=3e-3),
                TrainLoopConfig(steps=30, ckpt_dir=str(tmp_path), ckpt_every=10,
                                log_every=1000, warmup=2),
                log_fn=lambda s: None)
    losses = [h["loss"] for h in out["history"]]
    assert np.mean(losses[-5:]) < np.mean(losses[:5])
    assert latest_step(tmp_path) is not None


def test_train_loop_resume_continues(tmp_path):
    cfg, model = _tiny_model()
    kw = dict(opt_cfg=AdamWConfig(lr=1e-3))
    train(model, _data_iter(cfg), kw["opt_cfg"],
          TrainLoopConfig(steps=10, ckpt_dir=str(tmp_path), ckpt_every=5,
                          log_every=1000), log_fn=lambda s: None)
    out = train(model, _data_iter(cfg), kw["opt_cfg"],
                TrainLoopConfig(steps=15, ckpt_dir=str(tmp_path), ckpt_every=5,
                                log_every=1000), log_fn=lambda s: None)
    # resumed from 10, ran to 15
    assert out["history"][0]["step"] >= 10
    assert out["final_step"] == 15


def test_train_loop_failure_injection_recovers(tmp_path):
    cfg, model = _tiny_model()
    inj = FailureInjector(fail_at_steps=(7,))
    out = train(model, _data_iter(cfg), AdamWConfig(lr=1e-3),
                TrainLoopConfig(steps=12, ckpt_dir=str(tmp_path), ckpt_every=5,
                                log_every=1000),
                failure_injector=inj, log_fn=lambda s: None)
    assert out["restarts"] == 1
    assert out["final_step"] == 12
