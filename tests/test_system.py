"""End-to-end system test: data -> COAX curation -> sharded loader ->
training loop -> checkpoint -> serving with COAX-routed admission.
The full paper pipeline plus the framework substrate in one pass."""
import numpy as np

import jax

from conftest import tiny_config
from repro.configs import get_config
from repro.data.curation import CuratedSelector, MetaQuery
from repro.data.pipeline import ShardedLoader, make_corpus
from repro.models import build_model
from repro.optim import AdamWConfig
from repro.runtime.serve_loop import ServeConfig, Server
from repro.runtime.train_loop import TrainLoopConfig, train


def test_end_to_end_curate_train_serve(tmp_path):
    # 1. corpus with correlated metadata; COAX selects mid-length docs
    corpus = make_corpus(4_000, vocab_size=256, seed=0)
    sel = CuratedSelector(corpus)
    docs = sel.select(MetaQuery(token_len=(128, 2048)))
    assert docs.size > 100
    assert np.array_equal(docs, sel.select_reference(MetaQuery(token_len=(128, 2048))))

    # 2. sharded loader over the curated subset feeds the training loop;
    # a handful of docs so the model can memorise (random-token corpora have
    # no cross-batch signal beyond unigram frequency)
    cfg = tiny_config(get_config("h2o-danube-3-4b"))
    model = build_model(cfg)
    loader = ShardedLoader(corpus, batch_size=2, seq_len=16, doc_ids=docs[:6],
                           seed=1)
    out = train(model, iter(loader), AdamWConfig(lr=3e-3),
                TrainLoopConfig(steps=30, ckpt_dir=str(tmp_path), ckpt_every=10,
                                log_every=1000, warmup=2),
                log_fn=lambda s: None)
    loader.close()
    losses = [h["loss"] for h in out["history"]]
    assert np.isfinite(losses).all()
    assert np.mean(losses[-5:]) < np.mean(losses[:5])

    # 3. serve the trained params with COAX-routed admission
    srv = Server(model, out["params"],
                 ServeConfig(batch_size=4, max_new_tokens=4, cache_len=64,
                             eos_token=0))
    rng = np.random.default_rng(3)
    for _ in range(6):
        srv.submit(rng.integers(1, 200, int(rng.integers(4, 16))).astype(np.int32))
    results = srv.run_until_drained()
    assert len(results) == 6
