"""Sharded scatter-gather serving plane (DESIGN.md §6): differential tests.

The contract under test: ``ShardedCOAX`` over K shards returns results
bit-identical to a single ``COAXIndex`` over the union of rows — same flat
``(query_id, row_id)`` arrays, same per-query hit sets — for every
(workload × backend{numpy,device} × K ∈ {1,2,4} × deterministic
insert/delete schedule) cell, including post-compaction epochs; every cell
is also checked against the shared FullScan / rebuild-from-scratch oracles
in ``tests/workloads.py``.  Plus the sharding-specific plumbing: hash and
range routing, bbox pruning (a rect that misses every shard launches
nowhere), empty/single-row/all-outlier shard edges, K > n_rows, per-shard
epoch independence, and the executor/server ``shards=K`` mode with
per-shard wave rollups.
"""
import numpy as np
import pytest

from repro.core import COAXIndex, full_rect, point_rect
from repro.engine import (BatchQueryExecutor, QueryServer, ShardedCOAX,
                          partition_rows, split_hits)
from workloads import (NOAUTO, assert_equiv, fullscan_expected,
                       mutable_workloads, rects_for, violate_fd)

K_VALUES = (1, 2, 4)


def _rects(data, n=6, seed=0):
    return rects_for(data, n=n, seed=seed, extremes=False, sample_cap=6_000)


def _assert_flat_equal(sharded, single, rects, tag=""):
    """THE merge contract: identical flat (query_ids, row_ids) arrays."""
    q_s, r_s = single.query_batch(rects)
    q_k, r_k = sharded.query_batch(rects)
    assert np.array_equal(q_k, q_s), (tag, "query_ids")
    assert np.array_equal(r_k, r_s), (tag, "row_ids")


def _apply_schedule(idx, ds, more):
    """The deterministic insert/delete schedule every matrix cell runs:
    base deletes, in-pattern inserts, FD-violating inserts, delta-log
    deletes.  Ids come out identical for any index that assigns them in
    global arrival order (COAXIndex and ShardedCOAX both do)."""
    rng = np.random.default_rng(2)
    idx.delete(rng.choice(ds.data.shape[0], 300, replace=False))
    fresh = more(201, 400)
    ids_a = idx.insert(fresh[:200])                  # in-pattern
    ids_b = idx.insert(violate_fd(ds, fresh[200:]))  # FD-violating
    idx.delete(ids_a[:40])
    idx.delete(ids_b[:40])


@pytest.mark.parametrize("k", K_VALUES)
@pytest.mark.parametrize("name,ds,more", mutable_workloads(6_000),
                         ids=lambda w: w if isinstance(w, str) else "")
def test_sharded_matrix_equals_single_and_oracles(name, ds, more, k):
    """One full matrix cell: build → mutate → compact, checking sharded ==
    single index == scratch rebuild == FullScan on numpy AND device at
    every stage."""
    rects = _rects(ds.data)
    single = COAXIndex(ds.data, NOAUTO)
    sh = ShardedCOAX(ds.data, NOAUTO, n_shards=k, partition="range")
    assert sh.n_rows == single.n_rows == ds.data.shape[0]
    _assert_flat_equal(sh, single, rects, tag=f"{name}-K{k}-build")

    _apply_schedule(single, ds, more)
    _apply_schedule(sh, ds, more)
    assert sh.n_rows == single.n_rows
    assert_equiv(sh, rects, device=True, scratch=True, tag=f"{name}-K{k}-mut")
    _assert_flat_equal(sh, single, rects, tag=f"{name}-K{k}-mut")

    sh.compact()
    single.compact()
    assert all(s.epoch >= 1 for s in sh.shards)
    assert sh.delta_rows == 0 and sh.tombstone_count == 0
    assert_equiv(sh, rects, device=True, scratch=False, tag=f"{name}-K{k}-post")
    _assert_flat_equal(sh, single, rects, tag=f"{name}-K{k}-post")


def test_hash_partition_equals_range_and_single():
    """Both partitioning strategies answer identically (routing only moves
    rows between shards; results are routing-invariant)."""
    name, ds, more = mutable_workloads(6_000)[0]
    rects = _rects(ds.data)
    single = COAXIndex(ds.data, NOAUTO)
    for part in ("hash", "range"):
        sh = ShardedCOAX(ds.data, NOAUTO, n_shards=3, partition=part,
                         partition_dim=2)
        _assert_flat_equal(sh, single, rects, tag=part)
        for r in rects[:3]:
            assert np.array_equal(sh.query(r), single.query(r)), part


def test_partition_rows_routing_is_stable():
    """Insert routing must agree with build routing: same value -> same
    shard (hash), and range boundaries frozen at build route identically
    when passed back in."""
    rng = np.random.default_rng(3)
    data = rng.normal(0, 100, (4_000, 3)).astype(np.float32)
    for part in ("hash", "range"):
        shard_of, bounds = partition_rows(data, 4, part, 1)
        again, _ = partition_rows(data, 4, part, 1, boundaries=bounds)
        assert np.array_equal(shard_of, again), part
        assert shard_of.min() >= 0 and shard_of.max() < 4
    with pytest.raises(ValueError):
        partition_rows(data, 4, "round_robin", 0)


# --------------------------------------------------------------------- #
# Empty-shard and single-row-shard edges
# --------------------------------------------------------------------- #
def test_rect_pruning_to_zero_shards(rng):
    """A rect beyond every shard's bbox launches on no shard and returns
    empty — identical to the single index's answer."""
    data = rng.uniform(0, 100, (3_000, 3)).astype(np.float32)
    sh = ShardedCOAX(data, NOAUTO, n_shards=4, partition="range")
    single = COAXIndex(data, NOAUTO)
    far = np.stack([np.full(3, 1e6), np.full(3, 1e6 + 1)], axis=-1)
    rects = np.stack([far, full_rect(3)])
    assert not sh._touch_mask(far[None]).any()     # pruned everywhere
    assert sh.query(far).size == 0
    _assert_flat_equal(sh, single, rects, tag="prune")
    q, r = sh.query_batch(far[None])
    assert q.size == 0 and r.size == 0
    assert all(s.queries == 0 for s in sh.last_shard_stats)


def test_all_outlier_shard():
    """Force the global FD groups onto every shard and aim one range shard
    at rows that all violate them: that shard's primary grid is empty and
    every one of its hits flows through its outlier sub-index."""
    name, ds, _ = mutable_workloads(6_000)[2]      # generic_fd, FDs on (0,1)
    groups = COAXIndex(ds.data, NOAUTO).groups     # learned from CLEAN data
    assert len(groups) > 0
    data = ds.data.copy()
    # rows in the partition attribute's top quartile all break the FD:
    # the dependent pinned far outside any clean-data margin
    col = data[:, 0]
    cut = np.quantile(col.astype(np.float64), 0.75)
    hi_mask = col >= cut
    data[hi_mask, ds.correlated_groups[0][1]] = 1e7
    single = COAXIndex(data, NOAUTO, groups=groups)
    sh = ShardedCOAX(data, NOAUTO, n_shards=4, partition="range",
                     groups=groups)
    top = sh.shards[-1]
    assert top.n_rows > 0 and top.primary.n_rows == 0, \
        "top range shard should hold only FD outliers"
    rects = _rects(data)
    _assert_flat_equal(sh, single, rects, tag="all-outlier-shard")
    want = fullscan_expected(data, np.arange(data.shape[0]), rects)
    got = sh.query_batch_split(rects)
    for i in range(rects.shape[0]):
        assert np.array_equal(got[i], want[i]), i


def test_more_shards_than_rows(rng):
    """K > n_rows: most shards are empty (bbox None -> always pruned),
    some hold a single row; results still match the single index, and
    writes into empty shards set their bbox."""
    data = rng.uniform(0, 10, (5, 4)).astype(np.float32)
    sh = ShardedCOAX(data, NOAUTO, n_shards=8, partition="hash")
    single = COAXIndex(data, NOAUTO)
    assert sum(n == 0 for n in sh.shard_sizes()) >= 3
    rects = np.stack([full_rect(4), point_rect(data[0]),
                      np.stack([data[1], np.nextafter(data[1], np.inf)], axis=-1)])
    _assert_flat_equal(sh, single, rects, tag="K>n")
    want = fullscan_expected(data, np.arange(5), rects)
    got = sh.query_batch_split(rects)
    for i in range(rects.shape[0]):
        assert np.array_equal(got[i], want[i]), i

    # delete everything, then insert through the empty plane
    assert sh.delete(np.arange(5)) == 5
    assert sh.n_rows == 0
    q, r = sh.query_batch(rects)
    assert q.size == 0 and r.size == 0
    new_rows = rng.uniform(0, 10, (16, 4)).astype(np.float32)
    ids = sh.insert(new_rows)
    assert ids.tolist() == list(range(5, 21))
    want = fullscan_expected(new_rows, ids, rects)
    got = sh.query_batch_split(rects)
    for i in range(rects.shape[0]):
        assert np.array_equal(got[i], want[i]), i
    assert_equiv(sh, rects, scratch=True, tag="K>n-after-writes")


def test_shard_local_compaction_independence():
    """Writes aimed at ONE range shard compact only that shard: other
    shards' epochs (and frozen plans) stay untouched, results stay exact."""
    name, ds, more = mutable_workloads(6_000)[0]
    from repro.core import CoaxConfig
    cfg = CoaxConfig(auto_compact=True, compact_min_delta=64,
                     compact_delta_frac=0.01, drift_min_delta=10**9)
    sh = ShardedCOAX(ds.data, cfg, n_shards=4, partition="range")
    # rows drawn from the lowest partition-attribute quartile -> shard 0
    col = ds.data[:, 0]
    low_rows = ds.data[col < np.quantile(col.astype(np.float64), 0.1)][:600]
    sh.insert(low_rows)
    assert sh.shards[0].compactions >= 1, "target shard should have compacted"
    assert all(s.compactions == 0 for s in sh.shards[1:]), \
        "write-free shards must not compact"
    rects = _rects(ds.data)
    assert_equiv(sh, rects, scratch=True, tag="shard-local-compact")


# --------------------------------------------------------------------- #
# Engine plumbing: executor/server shards=K mode
# --------------------------------------------------------------------- #
def test_executor_shards_mode_and_rollups():
    name, ds, more = mutable_workloads(6_000)[0]
    rects = _rects(ds.data)
    single = COAXIndex(ds.data, NOAUTO)
    _apply_schedule(single, ds, more)
    want = fullscan_expected(*single.live_rows(), rects)

    # shards=K re-partitions a mutated single index over its live rows
    ex = BatchQueryExecutor(single, max_batch=4, shards=4)
    assert isinstance(ex.index, ShardedCOAX) and ex.index.n_shards == 4
    got = ex.execute(rects)
    for i in range(rects.shape[0]):
        assert np.array_equal(got[i], want[i]), i
    s = ex.stats()
    assert s["shards"] == 4 and len(s["per_shard"]) == 4
    # range pruning: some (query, shard) pairs were skipped
    scattered = sum(p["queries"] for p in s["per_shard"])
    assert 0 < scattered < s["queries"] * 4
    assert sum(p["rows_scanned"] for p in s["per_shard"]) == s["rows_scanned"]
    assert all(0 < w.shards_hit <= 4 for w in ex.wave_stats)

    # an index that is already sharded passes through; mismatched K raises
    ex2 = BatchQueryExecutor(ex.index, shards=4)
    assert ex2.index is ex.index
    with pytest.raises(ValueError):
        BatchQueryExecutor(ex.index, shards=2)
    from repro.core import FullScan
    with pytest.raises(ValueError):
        BatchQueryExecutor(FullScan(ds.data), shards=2)


def test_from_index_preserves_id_high_water_mark():
    """Re-sharding after the highest-id rows were deleted must NOT reuse
    their ids: a reused id would alias a client's handle to a dead row,
    and the 'ids == single-index ids for the same insert stream' contract
    would break."""
    name, ds, more = mutable_workloads(6_000)[2]
    idx = COAXIndex(ds.data, NOAUTO)
    new_ids = idx.insert(more(31, 10))
    idx.delete(new_ids)                            # high-water ids all dead
    sh = ShardedCOAX.from_index(idx, 2)
    got = sh.insert(more(32, 3))
    assert got.tolist() == idx.insert(more(32, 3)).tolist(), \
        "sharded ids must continue the donor's sequence"
    assert int(got.min()) > int(new_ids.max())


def test_server_sharded_writes_and_stats():
    """The server's write admission + per-wave snapshot semantics hold
    unchanged over the sharded plane (writes route per shard at wave
    boundaries)."""
    name, ds, more = mutable_workloads(6_000)[0]
    rects = _rects(ds.data, n=5)
    srv = QueryServer(ShardedCOAX(ds.data, NOAUTO, n_shards=2), max_batch=4)
    qids = srv.submit_many(rects)
    w1 = srv.insert(more(11, 60))
    w2 = srv.delete(np.arange(30))
    res = srv.drain()
    assert srv.write_results[w1].size == 60 and srv.write_results[w2] == 30
    idx = srv.executor.index
    want = fullscan_expected(*idx.live_rows(), rects)
    for qid, w in zip(qids, want):
        assert np.array_equal(res[qid], w)
    s = srv.stats()
    assert s["shards"] == 2 and s["rows_inserted"] == 60
    assert s["delta_rows"] == idx.delta_rows


def test_sharded_describe_and_footprint():
    name, ds, _ = mutable_workloads(6_000)[0]
    sh = ShardedCOAX(ds.data, NOAUTO, n_shards=3, partition="range")
    d = sh.describe()
    assert d["n_shards"] == 3 and sum(d["shard_sizes"]) == ds.data.shape[0]
    assert len(d["shard_groups"]) == 3
    assert d["memory_footprint_bytes"] >= sum(
        s.memory_footprint() for s in sh.shards)
    assert sh.memory_footprint() > 0
    with pytest.raises(ValueError):
        ShardedCOAX(ds.data, n_shards=0)
