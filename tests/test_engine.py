"""Batched execution engine: batch/single equivalence across layers.

The contract under test (DESIGN.md §2): for every workload, every rect, the
batched path returns EXACTLY the per-rect result — ``translate_rects`` row i
== ``translate_rect(rects[i])``, ``GridFile.query_batch`` per query ==
``GridFile.query``, ``COAXIndex.query_batch`` per query == ``COAXIndex.query``
(including the §8.2.3 per-query outlier skip), and the batched Pallas kernel
== the single-query kernel == the jnp oracle.
"""
import numpy as np
import pytest

from repro.core import (COAXIndex, FullScan, GridFile, full_rect, point_rect,
                        translate_rect, translate_rects)
from repro.data import make_airline, make_osm
from repro.engine import BatchQueryExecutor, QueryServer, split_hits
from workloads import (engine_workload, engine_workloads,
                       fullscan_expected, rects_for)


@pytest.mark.parametrize("name,ds", engine_workloads(),
                         ids=lambda w: w if isinstance(w, str) else "")
def test_coax_query_batch_equals_per_rect_query(name, ds):
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    qids, rids = idx.query_batch(rects)
    # flat hit list is (query, row) sorted
    assert np.all(np.diff(qids) >= 0)
    per_query = split_hits(qids, rids, rects.shape[0])
    want = fullscan_expected(ds.data, np.arange(ds.data.shape[0]), rects)
    saw_empty = saw_full = False
    for i, r in enumerate(rects):
        assert np.array_equal(idx.query(r), want[i]), (name, i)  # ground truth
        assert np.array_equal(per_query[i], want[i]), (name, i)
        saw_empty |= want[i].size == 0
        saw_full |= want[i].size == ds.data.shape[0]
    assert saw_empty and saw_full


def test_outlier_bbox_boundary_query_not_skipped():
    """A rect whose lower bound equals the outlier bbox max must still probe
    the outlier index (half-open [lo, hi) vs closed bbox: lo <= bhi)."""
    ds = engine_workload("generic_fd")
    idx = COAXIndex(ds.data)
    assert idx._outlier_lo is not None
    d = int(np.argmax(idx._outlier_hi - idx._outlier_lo))
    # a row attaining the outlier bbox max on dim d
    cand = np.where(ds.data[:, d].astype(np.float64) == float(idx._outlier_hi[d]))[0]
    assert cand.size
    rect = point_rect(ds.data[cand[0]])
    want = fullscan_expected(ds.data, np.arange(ds.data.shape[0]), rect[None])[0]
    assert np.array_equal(idx.query(rect), want)
    assert np.array_equal(idx.query_batch_split(rect[None])[0], want)


def test_translate_rects_matches_scalar():
    ds = make_airline(10_000, seed=5)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=16, seed=2)
    batch = translate_rects(rects, idx.groups, idx.keep_dims)
    for i, r in enumerate(rects):
        single = translate_rect(r, idx.groups, idx.keep_dims)
        assert np.array_equal(batch[i], single), i


@pytest.mark.parametrize("sort_dim", [None, 0, 2])
def test_gridfile_query_batch_equals_query(sort_dim):
    rng = np.random.default_rng(4)
    data = rng.normal(0, 10, (6_000, 3)).astype(np.float32)
    gf = GridFile(data, index_dims=[0, 1, 2], cells_per_dim=5, sort_dim=sort_dim)
    rects = np.sort(rng.uniform(-20, 20, (40, 3, 2)), axis=-1)
    rects[0] = full_rect(3)
    qids, rids = gf.query_batch(rects, rects)
    for i, r in enumerate(rects):
        assert np.array_equal(rids[qids == i], gf.query(r, r)), (sort_dim, i)


def test_gridfile_empty_batch_and_empty_grid():
    data = np.empty((0, 2), np.float32)
    gf = GridFile(data, index_dims=[0, 1], cells_per_dim=3)
    qids, rids = gf.query_batch(np.zeros((0, 2, 2)), np.zeros((0, 2, 2)))
    assert qids.size == 0 and rids.size == 0
    qids, rids = gf.query_batch(full_rect(2)[None], full_rect(2)[None])
    assert qids.size == 0 and rids.size == 0


def test_batch_kernel_matches_single_and_oracle():
    jax = pytest.importorskip("jax")
    import jax.numpy as jnp
    from repro.kernels import range_scan_batch_query, range_scan_query, ref
    from repro.kernels.range_scan_batch import range_scan_batch

    rng = np.random.default_rng(0)
    d, n, b = 4, 700, 5
    rows = rng.normal(0, 5, (d, n)).astype(np.float32)
    lo = rng.uniform(-6, 0, (b, d)).astype(np.float32)
    hi = lo + rng.uniform(0, 8, (b, d)).astype(np.float32)
    wins = np.stack([rng.integers(0, n // 2, b),
                     rng.integers(n // 2, n, b)], 1).astype(np.int32)

    counts_b, mask_b = range_scan_batch_query(rows, lo, hi, wins, interpret=True)
    counts_r, mask_r = range_scan_batch_query(rows, lo, hi, wins, use_pallas=False)
    assert np.array_equal(np.asarray(mask_b), np.asarray(mask_r))
    assert np.array_equal(np.asarray(counts_b), np.asarray(counts_r))
    for i in range(b):
        c1, m1 = range_scan_query(rows, lo[i], hi[i], wins[i])
        assert int(c1) == int(counts_b[i])
        assert np.array_equal(np.asarray(m1), np.asarray(mask_b[i])), i


def test_executor_waves_and_fallback():
    ds = make_osm(8_000, seed=1)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=10, seed=3)
    ex = BatchQueryExecutor(idx, max_batch=4)
    got = ex.execute(rects)
    # baseline engine without query_batch goes through the per-rect loop
    ex_fb = BatchQueryExecutor(FullScan(ds.data), max_batch=4)
    want = ex_fb.execute(rects)
    assert len(got) == len(want) == rects.shape[0]
    for g, w in zip(got, want):
        assert np.array_equal(g, w)
    s = ex.stats()
    assert s["batched"] and not ex_fb.stats()["batched"]
    assert s["waves"] == -(-rects.shape[0] // 4) and s["queries"] == rects.shape[0]


def test_wavestats_report_planning_work():
    """Per-wave rows_scanned/cells_probed surface the index's planning-stage
    work so backend comparisons report work done, not just QPS."""
    ds = make_airline(8_000, seed=2)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=10, seed=3)
    ex = BatchQueryExecutor(idx, max_batch=4)
    ex.execute(rects)
    s = ex.stats()
    assert s["rows_scanned"] > 0 and s["cells_probed"] > 0
    assert s["backend"] == "numpy" and s["device_fallbacks"] == 0
    assert sum(w.rows_scanned for w in ex.wave_stats) == s["rows_scanned"]
    assert sum(w.cells_probed for w in ex.wave_stats) == s["cells_probed"]
    assert all(w.rows_scanned >= w.n_hits for w in ex.wave_stats)
    # the full-range rect's wave must have scanned at least every row once
    assert s["rows_scanned"] >= ds.data.shape[0]


def test_batched_searchsorted_inf_early_exits():
    from repro.core import batched_searchsorted
    rng = np.random.default_rng(0)
    vals = np.sort(rng.normal(0, 5, 64)).astype(np.float32)
    blk_lo = np.array([0, 10, 30, 50, 60])
    blk_hi = np.array([10, 30, 50, 60, 64])

    def brute(target, side="left"):
        t = np.broadcast_to(np.asarray(target, np.float64), blk_lo.shape)
        return np.array([l + np.searchsorted(vals[l:h], tv, side=side)
                         for l, h, tv in zip(blk_lo, blk_hi, t)])

    for t in (-np.inf, np.inf, 0.0,
              np.full(5, -np.inf), np.full(5, np.inf),
              np.array([-np.inf, 0.5, np.inf, -1.0, np.inf])):
        got = batched_searchsorted(vals, blk_lo, blk_hi, t, "left",
                                   vals_finite=True)
        assert np.array_equal(got, brute(t)), t
    # +inf target over vals that CONTAIN +inf: the early exit must be
    # declined (vals_finite=False) and the loop answer stays exact.
    vals_inf = vals.copy(); vals_inf[40:] = np.inf
    vals_inf = np.concatenate([np.sort(vals_inf[:30]), np.sort(vals_inf[30:])])
    got = batched_searchsorted(vals_inf, blk_lo, blk_hi, np.inf, "left")
    want = np.array([l + np.searchsorted(vals_inf[l:h], np.inf, side="left")
                     for l, h in zip(blk_lo, blk_hi)])
    assert np.array_equal(got, want)


def test_gather_ranges_accepts_precomputed_lens():
    from repro.core import gather_ranges
    los = np.array([0, 5, 9, 7])
    his = np.array([2, 5, 12, 3])            # one inverted pair -> len 0
    lens = np.maximum(his - los, 0)
    assert np.array_equal(gather_ranges(los, his, lens),
                          gather_ranges(los, his))


def test_query_server_drains_priority_waves():
    ds = make_airline(8_000, seed=2)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=9, seed=4)
    srv = QueryServer(idx, max_batch=5)
    qids = [srv.submit(r, priority=float(i % 3), arrival=float(i))
            for i, r in enumerate(rects)]
    assert len(srv) == rects.shape[0]
    first = srv.drain(max_waves=1)
    assert len(first) == 5                     # one wave, highest priority first
    assert all(qids[i] in first for i in (2, 5, 8))  # priority-2 submissions
    rest = srv.drain()
    assert len(srv) == 0
    results = {**first, **rest}
    for qid, r in zip(qids, rects):
        assert np.array_equal(results[qid], idx.query(r)), qid
    assert srv.stats()["queries"] == rects.shape[0]


def test_query_server_mixed_clock_submit_ordering():
    """Regression: ``submit`` used to default ``arrival`` to ``time.time()``
    (epoch seconds, ~1.7e9) while explicit callers pass ``perf_counter``
    stamps — the drain sort then compared the two clocks, so ANY explicit
    arrival out-sorted every default one regardless of true order.  Both
    must come from ``perf_counter`` now: FIFO order is submit order."""
    import time

    ds = make_airline(4_000, seed=2)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data, n=3, seed=4)[:3]
    srv = QueryServer(idx, max_batch=1)
    qa = srv.submit(rects[0])                               # default stamp
    qb = srv.submit(rects[1])                               # default stamp
    qc = srv.submit(rects[2], arrival=time.perf_counter())  # explicit stamp
    first = srv.drain(max_waves=1)
    assert set(first) == {qa}, (
        "explicit perf_counter arrival out-sorted earlier default submits")
    second = srv.drain(max_waves=1)
    assert set(second) == {qb}
    assert set(srv.drain()) == {qc}
