"""Replication plane: WAL shipping, fault injection, failover (DESIGN.md §8).

The load-bearing suite is the failover differential matrix: for every
(workload × replica backend{numpy,device} × fault schedule) cell, a
2-replica ``ReplicatedServer`` runs a deterministic insert/delete/compact
schedule while a ``FaultPlan`` damages the wire (drops, torn frames,
duplicates, reordering, delays, transport errors), crashes a replica
mid-apply, or kills the primary — mid-stream and mid-compaction-rotation.
The §8.7 invariant gates every cell: each replica, once caught up to
frontier F, answers bit-identically to a never-crashed oracle index
replayed to F; promotions must land at a frontier ≥ the last
client-acknowledged write (no data loss).

Satellite coverage: WAL frame-cursor torn-tail/resume semantics, the
frame-aligned-prefix closure property (any intact WAL prefix restores to
a valid, consistent index — hypothesis-driven), idempotent
``Durability.close`` (double-close, close-after-failed-rotation),
graceful-shutdown wiring in ``QueryServer``, and the observability
surface (per-replica frontier/lag/heartbeat, fault + retry counters).
"""
import os
import signal

import numpy as np
import pytest

from repro.core import COAXIndex, CoaxConfig
from repro.engine import QueryServer
from repro.replication import (FaultyTransport, FrameError, InProcTransport,
                               Frame, ReplicatedServer, ReplicationHub,
                               Replica, TransportError, decode_frame,
                               encode_frame, frame_nbytes, seed_state,
                               write_frame)
from repro.runtime.failure import FaultPlan, GracefulShutdown, retry
from repro.storage import (WalFrameCursor, WriteAheadLog, read_wal, restore,
                           wal_path)
from repro.storage.wal import _FILE_HDR, _REC_HDR, OP_INSERT

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from workloads import fullscan_expected, mutable_workloads, rects_for, violate_fd

# compaction triggers low enough that the schedules below cross them
TRIG = CoaxConfig(compact_min_delta=300, compact_delta_frac=0.01,
                  drift_min_delta=200)
NOAUTO = CoaxConfig(auto_compact=False)

WORKLOADS = {name: (ds, more) for name, ds, more in mutable_workloads(2_500)}

# ---------------------------------------------------------------------- #
# Fault schedules (≥4, incl. torn shipped frames and primary kills)
# ---------------------------------------------------------------------- #
WIRE = {
    "ship.replica-0": {1: "drop", 3: "tear", 5: "dup", 8: "reorder",
                       11: ("tear", 7)},
    "ship.replica-1": {2: ("delay", 2), 4: ("error", 2), 7: "drop",
                       10: "dup"},
}
REPLICA_CRASH = {
    "replica-0.apply": {4: "crash"},
    "ship.replica-1": {3: "tear", 6: "drop"},
}
KILL_ROTATE = {"primary.rotate": {0: "crash"},
               "ship.replica-0": {2: "tear"}}

SCHEDULES = {"clean": {}, "wire": WIRE, "replica_crash": REPLICA_CRASH}


def _ops(name, n=12, batch=90):
    """Deterministic op stream for a workload: insert bursts (every 4th
    FD-violating) interleaved with deletes of known original ids."""
    ds, more = WORKLOADS[name]
    ops = []
    for i in range(n):
        rows = more(50 + i, batch)
        if i % 4 == 3:
            rows = violate_fd(ds, rows)
        ops.append(("insert", rows))
        if i % 3 == 2:
            ops.append(("delete", np.arange(i * 41, i * 41 + 30)))
    return ops


def _apply(target, op):
    (target.insert if op[0] == "insert" else target.delete)(op[1])


def _assert_identical(a, b, rects, tag):
    ra, ia = a.live_rows()
    rb, ib = b.live_rows()
    assert np.array_equal(ra, rb) and np.array_equal(ia, ib), tag
    ha = a.query_batch_split(rects)
    hb = b.query_batch_split(rects)
    for i in range(len(rects)):
        assert np.array_equal(ha[i], hb[i]), (tag, i)


def _settle(srv, limit=8):
    for _ in range(limit):
        srv.tick()
        if all(not r.alive or r.frontier == srv.hub.frontier
               for r in srv.replicas):
            return
    raise AssertionError("replicas failed to converge: "
                         + str([r.describe() for r in srv.replicas]))


@pytest.fixture(params=["numpy", "device"])
def replica_backend(request):
    if request.param == "device":
        pytest.importorskip("jax")
    return request.param


# ---------------------------------------------------------------------- #
# Convergence matrix: wire damage + replica crashes, no promotion
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("workload", list(WORKLOADS))
@pytest.mark.parametrize("schedule", list(SCHEDULES))
def test_replicas_bit_identical_under_faults(tmp_path, workload, schedule,
                                             replica_backend):
    if replica_backend == "device" and schedule == "clean":
        pytest.skip("device cells run under the fault schedules")
    ds, _ = WORKLOADS[workload]
    plan = FaultPlan({k: dict(v) for k, v in SCHEDULES[schedule].items()})
    idx = COAXIndex(ds.data, TRIG)
    oracle = COAXIndex(ds.data.copy(), TRIG)
    srv = ReplicatedServer(idx, tmp_path, n_replicas=2, plan=plan,
                           replica_backend=replica_backend)
    for i, op in enumerate(_ops(workload)):
        _apply(srv, op)
        _apply(oracle, op)
        if i % 2 == 1:
            srv.tick()
    srv.compact()                        # manual rotation ships F_ROTATE
    oracle.compact()
    dead = [r for r in srv.replicas if not r.alive]
    for r in dead:
        r.revive()                       # crashed replicas resume + catch up
    _settle(srv)

    rects = rects_for(ds.data, n=10, seed=2)
    assert srv.primary.epoch == oracle.epoch >= 1
    for rep in srv.replicas:
        assert rep.frontier == srv.hub.frontier
        assert rep.lag_frames() == 0 and rep.lag_bytes() == 0
        _assert_identical(rep.index, oracle, rects,
                          (workload, schedule, rep.name))
    if schedule == "wire":
        t = srv.transport
        assert t.tears >= 2 and t.drops >= 2 and t.dups >= 2
        assert sum(r.frames_corrupt for r in srv.replicas) >= 2
        assert srv.hub.send_retries >= 1
    if schedule == "replica_crash":
        assert sum(r.crashes for r in dead) == 1


# ---------------------------------------------------------------------- #
# Failover matrix: primary kills, incl. mid-compaction-rotation
# ---------------------------------------------------------------------- #
@pytest.mark.parametrize("workload", list(WORKLOADS))
@pytest.mark.parametrize("kill", ["midstream", "mid_rotation_auto",
                                  "mid_rotation_manual"])
def test_failover_no_data_loss(tmp_path, workload, kill, replica_backend):
    if replica_backend == "device" and kill == "mid_rotation_manual":
        pytest.skip("device cells run the midstream and auto-rotation kills")
    ds, _ = WORKLOADS[workload]
    auto = kill == "mid_rotation_auto"
    cfg = TRIG if auto else NOAUTO
    plan = FaultPlan(dict(KILL_ROTATE) if kill != "midstream"
                     else {"ship.replica-0": {2: "tear"}})
    idx = COAXIndex(ds.data, cfg)
    oracle = COAXIndex(ds.data.copy(), cfg)
    srv = ReplicatedServer(idx, tmp_path, n_replicas=2, plan=plan,
                           replica_backend=replica_backend)
    ops = _ops(workload)
    survived = []
    died = False
    for i, op in enumerate(ops):
        try:
            _apply(srv, op)
        except RuntimeError:
            died = True                   # auto-compaction hit the injected
            break                         # rotation crash; op never acked
        survived.append(op)
        if i % 3 == 1:
            srv.tick()                    # replicas lag behind the tail
    if kill == "mid_rotation_manual":
        with pytest.raises(RuntimeError):
            srv.compact()                 # dies inside the §7.5 window
        died = True
    if auto:
        assert died, "schedule never crossed the compaction trigger"
    acked = srv.acked

    srv.kill_primary()
    promoted = srv.promote()
    assert promoted.frontier >= acked     # the no-data-loss gate held
    assert srv.promotions == 1 and srv.primary is promoted.index

    # never-crashed oracle replayed to the promoted frontier: every acked
    # op, plus — for rotation kills — the journaled trigger/compaction
    # (journaled before the crash, hence legitimately recovered)
    for op in survived:
        _apply(oracle, op)
    if died:
        if auto:
            # the fatal op WAS journaled before the primary died; the
            # promoted replica recovered it (frontier > acked is allowed)
            _apply(oracle, ops[len(survived)])
        else:
            oracle.compact()              # rotation completed on disk
    rects = rects_for(ds.data, n=10, seed=2)
    assert promoted.index.epoch == oracle.epoch
    _assert_identical(promoted.index, oracle, rects, (workload, kill))

    # the promoted primary serves writes; survivors re-seed and track it
    _, more = WORKLOADS[workload]
    srv.insert(more(99, 80))
    _apply(oracle, ("insert", more(99, 80)))
    _settle(srv)
    for rep in srv.replicas:
        assert rep.lag_frames() == 0
        _assert_identical(rep.index, oracle, rects,
                          (workload, kill, rep.name))


# ---------------------------------------------------------------------- #
# Shipped-frame codec + transport faults
# ---------------------------------------------------------------------- #
def test_frame_codec_rejects_damage():
    frame = write_frame(3, 7, OP_INSERT, b"\x01\x02\x03\x04")
    data = encode_frame(frame)
    back = decode_frame(data)
    assert back == frame and back.key == (3, 7)
    assert frame_nbytes(frame) == len(data)
    for cut in (1, len(data) // 2, len(data) - 1):
        with pytest.raises(FrameError):
            decode_frame(data[:cut])              # torn in transit
    with pytest.raises(FrameError):
        decode_frame(b"XXXX" + data[4:])          # bad magic
    corrupt = bytearray(data)
    corrupt[-1] ^= 0xFF
    with pytest.raises(FrameError):
        decode_frame(bytes(corrupt))              # payload CRC
    with pytest.raises(FrameError):
        decode_frame(data + b"junk")              # trailing garbage


def test_faulty_transport_actions():
    # m7's successful third attempt consumes event 8, so the tear sits at
    # event 9 and lands on m8
    plan = FaultPlan({"ship.r": {0: "drop", 1: "dup", 2: "reorder",
                                 4: ("delay", 2), 7: ("error", 2),
                                 9: "tear"}})
    t = FaultyTransport(InProcTransport(), plan)
    sent = [f"m{i}".encode() for i in range(10)]
    got = []

    def send(i):
        retry(lambda: t.send("r", sent[i]), retries=3, backoff=0.0,
              retryable=(TransportError,))

    for i in range(10):
        send(i)
        got.extend(t.recv("r"))
    # m0 dropped; m1 twice; m2 held past m3; m4 held 2 sends; m7 delivered
    # after 2 injected errors (retry path); m8 truncated
    assert sent[0] not in got
    assert got.count(sent[1]) == 2
    assert got.index(sent[3]) < got.index(sent[2])
    assert got.index(sent[5]) < got.index(sent[4])
    assert sent[7] in got
    assert any(m == sent[8][:len(m)] and len(m) < len(sent[8]) for m in got)
    assert t.counts() == {"drops": 1, "dups": 1, "tears": 1, "reorders": 1,
                          "delays": 1, "errors": 2}
    assert plan.counts() == {"drop": 1, "dup": 1, "reorder": 1, "delay": 1,
                             "error": 1, "tear": 1}


def test_seed_state_does_not_alias_the_primary(tmp_path):
    ds, more = WORKLOADS["generic_fd"]
    idx = COAXIndex(ds.data, NOAUTO)
    idx.insert(more(1, 60))
    rep = COAXIndex._restore_state(seed_state(idx))
    before = rep.live_rows()
    idx.insert(more(2, 60))               # must not leak into the copy
    idx.delete(np.arange(40))
    after = rep.live_rows()
    assert np.array_equal(before[0], after[0])
    assert np.array_equal(before[1], after[1])
    assert rep.n_rows != idx.n_rows


# ---------------------------------------------------------------------- #
# WalFrameCursor: torn tails, resumability (satellite 3)
# ---------------------------------------------------------------------- #
def _journal(tmp_path, n_ops=6):
    """A real journal + the (kind, payload) records it shipped."""
    ds, more = WORKLOADS["generic_fd"]
    idx = COAXIndex(ds.data, NOAUTO)
    idx.attach_durability(tmp_path / "j")
    shipped = []
    idx.durable.frame_observer = \
        lambda e, s, k, p: shipped.append((s, k, p))
    for i in range(n_ops):
        if i % 3 == 2:
            idx.delete(np.arange(i * 20, i * 20 + 10))
        else:
            idx.insert(more(10 + i, 40))
    idx.durable.sync()
    return idx, wal_path(tmp_path / "j", idx.epoch), shipped


def test_frame_cursor_reads_and_resumes(tmp_path):
    idx, path, shipped = _journal(tmp_path)
    cur = WalFrameCursor(path, expect_epoch=0)
    out = cur.read()
    assert [(s, k, p) for s, k, p in out] == shipped
    assert cur.read() == []               # fully drained
    n0 = len(shipped)                     # the observer keeps appending
    idx.insert(np.zeros((3, idx.n_dims), np.float32))   # live appender
    more_frames = cur.read()
    assert len(more_frames) == 1 and more_frames[0][0] == n0
    assert cur.next_seq == n0 + 1
    # start_seq skips the already-applied prefix
    late = WalFrameCursor(path, expect_epoch=0, start_seq=4)
    assert [s for s, _, _ in late.read()] == list(range(4, n0 + 1))


def test_frame_cursor_pauses_on_torn_tail(tmp_path):
    _, path, shipped = _journal(tmp_path)
    blob = path.read_bytes()
    torn = tmp_path / "torn.log"
    torn.write_bytes(blob[:-11])          # last record torn mid-payload
    cur = WalFrameCursor(torn, expect_epoch=0)
    out = cur.read()
    assert [s for s, _, _ in out] == list(range(len(shipped) - 1))
    assert cur.read() == []               # parked at the torn record
    # ... and RESUMES if the bytes were merely in flight
    torn.write_bytes(blob)
    resumed = cur.read()
    assert [s for s, _, _ in resumed] == [len(shipped) - 1]
    # genuinely corrupt bytes pause it forever
    bad = bytearray(blob)
    bad[-3] ^= 0xFF
    forever = tmp_path / "bad.log"
    forever.write_bytes(bytes(bad))
    cur2 = WalFrameCursor(forever, expect_epoch=0)
    assert [s for s, _, _ in cur2.read()] == list(range(len(shipped) - 1))
    assert cur2.read() == []


def test_frame_cursor_header_cases(tmp_path):
    missing = WalFrameCursor(tmp_path / "nope.log")
    assert missing.read() == []           # missing file reads empty
    stub = tmp_path / "stub.log"
    stub.write_bytes(b"CW")               # header still in flight
    cur = WalFrameCursor(stub)
    assert cur.read() == []
    wal = WriteAheadLog(tmp_path / "w.log", epoch=5)
    wal.close()
    with pytest.raises(ValueError):
        WalFrameCursor(tmp_path / "w.log", expect_epoch=3).read()


# ---------------------------------------------------------------------- #
# Prefix closure: ANY frame-aligned WAL prefix is a valid state (sat. 3)
# ---------------------------------------------------------------------- #
_PREFIX_CACHE = {}


def _prefix_fixture(tmp_path_factory=None):
    if "j" not in _PREFIX_CACHE:
        import tempfile
        from pathlib import Path
        root = Path(tempfile.mkdtemp(prefix="coax_prefix_"))
        ds, more = WORKLOADS["airline"]
        idx = COAXIndex(ds.data, NOAUTO)
        idx.attach_durability(root / "j")
        ops = _ops("airline", n=8, batch=60)
        for op in ops:
            _apply(idx, op)
        idx.durable.sync()
        path = wal_path(root / "j", 0)
        blob = path.read_bytes()
        records, n, intact = read_wal(path, expect_epoch=0)
        assert intact == len(blob) and n == len(ops)
        bounds = [_FILE_HDR.size]
        off = _FILE_HDR.size
        for rec in records:
            _, _, _, plen, _ = _REC_HDR.unpack_from(blob, off)
            off += _REC_HDR.size + plen
            bounds.append(off)
        rects = rects_for(ds.data, n=8, seed=4)
        _PREFIX_CACHE["j"] = (root, ds, ops, blob, bounds, rects)
    return _PREFIX_CACHE["j"]


def _check_prefix(k):
    root, ds, ops, blob, bounds, rects = _prefix_fixture()
    prefix_dir = root / f"prefix_{k}"
    if not (prefix_dir / "wal_00000000.log").exists():
        import shutil
        shutil.copytree(root / "j", prefix_dir)
        os.truncate(prefix_dir / "wal_00000000.log", bounds[k])
    rec = restore(prefix_dir)
    oracle = COAXIndex(ds.data, NOAUTO)
    for op in ops[:k]:
        _apply(oracle, op)
    rows, ids = rec.live_rows()
    orows, oids = oracle.live_rows()
    assert np.array_equal(rows, orows) and np.array_equal(ids, oids)
    want = fullscan_expected(rows, ids, rects)
    got = rec.query_batch_split(rects)
    for i in range(len(rects)):
        assert np.array_equal(got[i], want[i])
    assert rec._next_id == oracle._next_id


@settings(max_examples=12, deadline=None)
@given(st.data())
def test_wal_prefix_closure(data):
    """Replaying any frame-aligned prefix of the journal yields exactly the
    oracle state after that many ops — the §8 shipping protocol's licence
    to resume a replica from an arbitrary applied frontier."""
    root, ds, ops, blob, bounds, rects = _prefix_fixture()
    _check_prefix(data.draw(st.integers(min_value=0, max_value=len(ops)),
                            label="k"))


def test_wal_prefix_closure_exhaustive():
    """Every frame boundary, deterministically — keeps the closure property
    covered on images without hypothesis."""
    _, _, ops, _, bounds, _ = _prefix_fixture()
    assert len(bounds) == len(ops) + 1
    for k in range(len(ops) + 1):
        _check_prefix(k)


# ---------------------------------------------------------------------- #
# Idempotent close + fsync-on-close (satellite 2)
# ---------------------------------------------------------------------- #
def test_durability_close_is_idempotent(tmp_path):
    ds, more = WORKLOADS["generic_fd"]
    idx = COAXIndex(ds.data, NOAUTO)
    idx.attach_durability(tmp_path / "d")
    idx.insert(more(3, 50))
    dur = idx.durable
    assert dur.wal.pending_bytes > 0 and not dur.closed
    dur.close()                           # fsyncs the tail
    assert dur.closed and dur.wal.pending_bytes == 0
    dur.close()                           # double close: no-op, no raise
    dur.sync()                            # sync after close: no-op
    assert dur.wal.nbytes() == (tmp_path / "d" /
                                dur.wal.path.name).stat().st_size
    # the closed journal is complete and recoverable
    rec = restore(tmp_path / "d")
    assert rec.n_rows == idx.n_rows


def test_close_after_failed_rotation(tmp_path, monkeypatch):
    ds, more = WORKLOADS["generic_fd"]
    idx = COAXIndex(ds.data, NOAUTO)
    idx.attach_durability(tmp_path / "d")
    idx.insert(more(3, 50))

    import repro.storage.durability as dmod
    def boom(*a, **k):
        raise OSError("disk full mid-rotation")
    monkeypatch.setattr(dmod, "write_snapshot", boom)
    with pytest.raises(OSError):
        idx.compact()                     # dies before the new pair exists
    monkeypatch.undo()
    dur = idx.durable
    dur.close()                           # old handle still closes cleanly
    dur.close()
    assert dur.closed
    # the §7.5 contract held: the OLD (snapshot, WAL) pair still recovers,
    # and replays the compaction the crash interrupted
    rec = restore(tmp_path / "d", durable=True)
    assert rec.n_rows == idx.n_rows


def test_sharded_close_idempotent(tmp_path):
    from repro.engine import ShardedCOAX
    ds, more = WORKLOADS["generic_fd"]
    sh = ShardedCOAX(ds.data, n_shards=2, config=NOAUTO)
    sh.attach_durability(tmp_path / "s")
    sh.insert(more(5, 40))
    sh.durable.close()
    assert sh.durable.closed
    sh.durable.close()                    # fan-out stays idempotent
    sh.durable.sync()


def test_wal_close_guards(tmp_path):
    wal = WriteAheadLog(tmp_path / "w.log", epoch=0)
    wal.append_delete(np.arange(4))
    wal.close()
    assert wal.closed
    wal.close()                           # idempotent
    wal.pending_bytes = 99                # the failed-rotation zombie state
    wal.sync()                            # must not raise on a dead handle
    assert wal.nbytes() == (tmp_path / "w.log").stat().st_size


# ---------------------------------------------------------------------- #
# Graceful shutdown wiring (satellite 1)
# ---------------------------------------------------------------------- #
def test_query_server_graceful_shutdown(tmp_path):
    ds, more = WORKLOADS["airline"]
    idx = COAXIndex(ds.data, NOAUTO)
    idx.attach_durability(tmp_path / "d")
    rects = rects_for(ds.data, n=12, seed=1)
    with GracefulShutdown(signals=(signal.SIGTERM,)) as stop:
        srv = QueryServer(idx, max_batch=4, shutdown=stop)
        for r in rects:
            srv.submit(r)
        srv.insert(more(7, 50))
        first = srv.drain(max_waves=1)
        assert len(first) == 4 and not srv.shutdown_requested
        os.kill(os.getpid(), signal.SIGTERM)    # the real preemption signal
        assert srv.shutdown_requested
        srv.insert(more(8, 30))
        rest = srv.drain()                # forms no new waves
        assert rest == {}
        assert len(srv) == len(rects) - 4  # queries kept for the successor
        srv.close()                       # flush writes + fsync + release
    assert srv.closed and len(srv._write_queue) == 0
    assert idx.durable.closed and idx.durable.wal_pending_bytes == 0
    srv.close()                           # close is idempotent too
    rec = restore(tmp_path / "d")         # every flushed write survived
    assert rec.n_rows == idx.n_rows
    st_ = srv.stats()
    assert st_["shutdown_requested"] and st_["closed"]


# ---------------------------------------------------------------------- #
# Observability (satellite 6)
# ---------------------------------------------------------------------- #
def test_replication_stats_surface(tmp_path):
    ds, more = WORKLOADS["generic_fd"]
    plan = FaultPlan({"ship.replica-0": {1: "drop", 3: "dup", 5: "tear"},
                      "ship.replica-1": {2: ("error", 1)}})
    idx = COAXIndex(ds.data, NOAUTO)
    srv = ReplicatedServer(idx, tmp_path, n_replicas=2, plan=plan)
    for i in range(6):
        srv.insert(more(20 + i, 40))
        for rep in srv.replicas:          # pump without heartbeats so the
            rep.pump()                    # plan's event indices stay on the
                                          # write frames alone
    rects = rects_for(ds.data, n=4, seed=0)
    for _ in range(3):
        srv.query_batch_split(rects)
    s = srv.stats()
    assert s["frontier"] == {"epoch": 0, "seq": 6}
    assert s["acked"] == {"epoch": 0, "seq": 6}
    assert s["ship"]["shipped_frames"] == 6
    assert s["ship"]["shipped_bytes"] > 0
    assert s["ship"]["send_retries"] >= 1          # the injected error path
    assert s["transport_faults"]["drops"] == 1
    assert s["transport_faults"]["dups"] == 1
    assert s["transport_faults"]["tears"] == 1
    assert s["fault_plan"] == {"drop": 1, "dup": 1, "tear": 1, "error": 1}
    assert s["reads"]["replica"] == 3 and s["reads"]["degraded"] == 0
    for r in s["replicas"]:
        assert r["alive"] and r["lag_frames"] == 0 and r["lag_bytes"] == 0
        assert (r["epoch"], r["next_seq"]) == (0, 6)
        assert r["heartbeat_age"] < 5.0
        assert r["frames_applied"] >= 6
    r0 = next(r for r in s["replicas"] if r["name"] == "replica-0")
    assert r0["frames_corrupt"] == 1               # the torn frame
    assert r0["frames_duplicate"] >= 1             # the duplicated frame
    assert r0["catchup_fetches"] >= 1              # repaired the drop/tear
    # degradation: every replica unhealthy -> primary serves (counted)
    for rep in srv.replicas:
        rep.alive = False
    srv.query_batch_split(rects)
    s2 = srv.stats()
    assert s2["reads"]["degraded"] == 1 and s2["reads"]["primary"] == 1


def test_promotion_requires_live_replica(tmp_path):
    from repro.replication import ReplicationError
    ds, _ = WORKLOADS["generic_fd"]
    idx = COAXIndex(ds.data, NOAUTO)
    srv = ReplicatedServer(idx, tmp_path, n_replicas=1)
    srv.replicas[0].alive = False
    srv.kill_primary()
    with pytest.raises(ReplicationError):
        srv.promote()
    with pytest.raises(ReplicationError):
        srv.insert(np.zeros((1, ds.data.shape[1]), np.float32))
    with pytest.raises(ReplicationError):
        srv.query_batch_split(rects_for(ds.data, n=2, seed=0))
