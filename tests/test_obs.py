"""Telemetry plane (DESIGN.md §10): registry semantics, tracing structure,
watchdog attribution, and — the gate everything else hangs off — telemetry
on/off bit-identity of query answers.

Quantile checks compare the log-bucketed histogram against a numpy oracle:
the bucket geometry (×2 growth) bounds any reported quantile inside one
bucket of the true order statistic, so the assertions use that factor-of-2
envelope rather than exact equality.
"""
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.core import COAXIndex
from repro.data import make_airline
from repro.engine import BatchQueryExecutor, QueryServer
from repro.obs import (MetricsRegistry, PauseWatchdog, Tracer,
                       parse_text_exposition)
from workloads import rects_for


@pytest.fixture(autouse=True)
def _no_global_tracer():
    """Tests toggle the process-global tracer; always restore 'off'."""
    yield
    obs.disable_tracing()


# ===================================================================== #
# MetricsRegistry
# ===================================================================== #
def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "requests", ("plane", "shard"))
    c.inc(plane="read", shard="0")
    c.inc(3, plane="read", shard="1")
    c.inc(plane="write", shard="0")
    assert c.value(plane="read", shard="0") == 1
    assert c.value(plane="read", shard="1") == 3
    assert c.value(plane="write", shard="1") == 0   # never touched
    assert c.total() == 5
    # get-or-create returns the SAME family; a conflicting re-declaration
    # is a programming error, not a silent second family
    assert reg.counter("requests_total", "requests",
                       ("plane", "shard")) is c
    with pytest.raises(ValueError):
        reg.counter("requests_total", "requests", ("other",))
    with pytest.raises(ValueError):
        reg.gauge("requests_total", "now a gauge?")


def test_gauge_set_add():
    reg = MetricsRegistry()
    g = reg.gauge("resident_bytes", "bytes", ("plane",))
    g.set(100, plane="cache")
    g.add(-25, plane="cache")
    assert g.value(plane="cache") == 75


def test_histogram_quantiles_against_numpy_oracle():
    rng = np.random.default_rng(7)
    samples = rng.lognormal(mean=-7.0, sigma=2.0, size=4000)
    reg = MetricsRegistry()
    h = reg.histogram("lat_seconds", "latency")
    for s in samples:
        h.observe(float(s))
    for q in (0.5, 0.9, 0.99):
        want = float(np.quantile(samples, q))
        got = h.quantile(q)
        assert want / 2 <= got <= want * 2, (q, want, got)
    summ = h.summary()
    assert summ["count"] == len(samples)
    assert summ["sum"] == pytest.approx(samples.sum(), rel=1e-9)
    assert summ["max"] == pytest.approx(samples.max())


def test_histogram_labeled_rollup():
    reg = MetricsRegistry()
    h = reg.histogram("stage_seconds", "stages", ("stage",))
    h.observe(1.0, stage="probe")
    h.observe(2.0, stage="filter")
    assert h.summary(stage="probe")["count"] == 1
    assert h.summary()["count"] == 2          # no labels = all-series rollup
    assert h.summary()["sum"] == pytest.approx(3.0)


def test_render_text_round_trips_and_is_stable():
    reg = MetricsRegistry()
    reg.counter("a_total", "as", ("k",)).inc(2, k="x")
    reg.gauge("b_bytes", "bs").set(7)
    reg.histogram("c_seconds", "cs").observe(0.25)
    text = reg.render_text()
    assert text == reg.render_text()          # deterministic rendering
    parsed = parse_text_exposition(text)
    assert parsed["a_total"]["type"] == "counter"
    assert parsed["a_total"]["samples"] == [("a_total", {"k": "x"}, 2.0)]
    assert parsed["b_bytes"]["samples"] == [("b_bytes", {}, 7.0)]
    # histogram renders as a summary family: quantiles + _sum/_count/_max
    c_samples = {s[0]: s[2] for s in parsed["c_seconds"]["samples"]}
    assert c_samples["c_seconds_count"] == 1.0
    assert c_samples["c_seconds_sum"] == pytest.approx(0.25)
    with pytest.raises(ValueError):
        parse_text_exposition("not { an exposition")


def test_registry_reset_and_snapshot():
    reg = MetricsRegistry()
    reg.counter("n_total", "n").inc(5)
    snap = reg.snapshot()
    assert snap["n_total"]["series"][0]["value"] == 5
    reg.reset()
    assert reg.counter("n_total", "n").value() == 0


# ===================================================================== #
# Tracer
# ===================================================================== #
def test_span_nesting_implicit_parent():
    tr = Tracer()
    with tr.span("outer"):
        with tr.span("inner"):
            pass
    outer, inner = {e["name"]: e for e in tr.events()}.values()
    evs = {e["name"]: e for e in tr.events()}
    assert evs["inner"]["parent"] == evs["outer"]["id"]
    assert evs["outer"]["parent"] is None
    ok, problems = tr.validate()
    assert ok, problems


def test_pipelined_collect_does_not_adopt_next_wave():
    """The §10.2 seam: wave k's collect-side child must parent to wave k,
    not to wave k+1 whose submit is already on the stack."""
    tr = Tracer()
    w1 = tr.start("wave", k=1)
    # wave 2's submit begins while wave 1 is still in flight
    w2 = tr.start("wave", k=2)
    with tr.attach(w2):
        # ... submit-side work of wave 2 would nest here ...
        pass
    # collect side of wave 1 re-attaches wave 1 explicitly
    with tr.attach(w1):
        with tr.span("device.transfer"):
            pass
    tr.finish(w1)
    with tr.attach(w2):
        with tr.span("device.transfer"):
            pass
    tr.finish(w2)
    evs = tr.events()
    transfers = [e for e in evs if e["name"] == "device.transfer"]
    waves = {e["args"]["k"]: e["id"] for e in evs if e["name"] == "wave"}
    assert transfers[0]["parent"] == waves[1]
    assert transfers[1]["parent"] == waves[2]
    ok, problems = tr.validate()
    assert ok, problems


def test_ring_eviction_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(10):
        with tr.span(f"s{i}"):
            pass
    assert len(tr.events()) == 4
    assert tr.dropped == 6
    assert [e["name"] for e in tr.events()] == ["s6", "s7", "s8", "s9"]


def test_validate_flags_unclosed_and_uncovered():
    tr = Tracer()
    tr.start("dangling")
    ok, problems = tr.validate()
    assert not ok and any("never finished" in p for p in problems)

    tr2 = Tracer()
    with tr2.span("not_a_wave"):
        with tr2.span("device.dispatch"):
            pass
    ok2, problems2 = tr2.validate()
    assert not ok2 and any("not covered" in p for p in problems2)

    tr3 = Tracer()
    with tr3.span("wave", k=0):
        with tr3.span("device.dispatch"):
            pass
    ok3, problems3 = tr3.validate()
    assert ok3, problems3


def test_cross_thread_finish_and_chrome_export(tmp_path):
    tr = Tracer()
    with tr.span("wave") as w:
        bsp = tr.start("compact.build", parent=w)

        def _worker():
            tr.finish(bsp)

        t = threading.Thread(target=_worker)
        t.start()
        t.join()
    evs = {e["name"]: e for e in tr.events()}
    assert evs["compact.build"]["parent"] == evs["wave"]["id"]
    chrome = tr.to_chrome()
    assert all(ev["ph"] == "X" for ev in chrome["traceEvents"])
    path = tmp_path / "trace.jsonl"
    assert tr.dump_jsonl(str(path)) == 2
    lines = [json.loads(l) for l in path.read_text().splitlines()]
    assert {l["name"] for l in lines} == {"wave", "compact.build"}


# ===================================================================== #
# PauseWatchdog
# ===================================================================== #
def test_watchdog_detects_pause_and_attributes_culprit():
    tr = Tracer()
    reg = MetricsRegistry()
    seen = []
    wd = PauseWatchdog(factor=5.0, window=32, min_samples=4,
                       min_gap_s=1e-4, tracer=tr, registry=reg,
                       callback=lambda g, m, c: seen.append((g, c)))
    t = 0.0
    for _ in range(8):                       # steady 10ms cadence
        wd.wave_done(now=t)
        t += 0.01
    # a background install span sits exactly inside the big gap
    sp = tr.start("compact.install")
    sp.t0 = t + 0.05
    tr.finish(sp)
    sp.t1 = t + 0.45
    rec = wd.wave_done(now=t + 0.5)          # 0.5s gap vs 10ms median
    assert rec is not None
    assert rec["culprit"]["name"] == "compact.install"
    assert reg.counter("serving_pause_total", "", ("culprit",)) \
              .value(culprit="compact.install") == 1
    assert seen and seen[0][1]["name"] == "compact.install"
    assert wd.describe()["last_culprit"] == "compact.install"


def test_watchdog_steady_cadence_never_fires():
    wd = PauseWatchdog(factor=5.0, min_samples=4, registry=MetricsRegistry())
    t = 0.0
    for _ in range(64):
        assert wd.wave_done(now=t) is None
        t += 0.01
    assert wd.pause_count == 0


# ===================================================================== #
# Executor ring + stats delegation (satellite a)
# ===================================================================== #
def test_wave_stats_ring_bounded_but_totals_exact():
    ds = make_airline(4000)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    ex = BatchQueryExecutor(idx, max_batch=4, wave_history=3)
    want = [idx.query(r) for r in rects]
    got = ex.execute(rects)
    assert all(np.array_equal(g, w) for g, w in zip(got, want))
    n_waves = -(-len(rects) // 4)
    s = ex.stats()
    assert s["waves"] == n_waves
    assert s["queries"] == len(rects)        # totals survive ring eviction
    assert len(ex.wave_stats) == min(3, n_waves)
    # the ring keeps the TRAILING waves, with their original indices
    assert [w.wave for w in ex.wave_stats] == \
        list(range(n_waves - min(3, n_waves), n_waves))


def test_executor_stats_from_private_registry():
    ds = make_airline(3000)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    ex = BatchQueryExecutor(idx, max_batch=8)
    ex.execute(rects)
    s = ex.stats()
    assert s["queries"] == len(rects)
    assert ex.metrics.counter("queries").value() == len(rects)
    assert ex.metrics.get("wave_seconds").summary()["count"] == s["waves"]
    # two executors never share counters
    ex2 = BatchQueryExecutor(idx, max_batch=8)
    assert ex2.stats()["queries"] == 0


# ===================================================================== #
# Bit-identity: telemetry on == telemetry off
# ===================================================================== #
def _flat(executor, rects):
    return executor.execute(rects)


def test_tracing_on_off_bit_identity_numpy():
    ds = make_airline(5000)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    ex = BatchQueryExecutor(idx, max_batch=8, backend="numpy")
    obs.disable_tracing()
    off = _flat(ex, rects)
    tr = obs.enable_tracing()
    on = _flat(ex, rects)
    ok, problems = tr.validate()
    assert ok, problems
    assert all(np.array_equal(a, b) for a, b in zip(on, off))
    assert any(e["name"] == "wave" for e in tr.events())


def test_tracing_on_off_bit_identity_device():
    pytest.importorskip("jax")
    ds = make_airline(5000)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    ex = BatchQueryExecutor(idx, max_batch=8, backend="device")
    obs.disable_tracing()
    off = _flat(ex, rects)
    tr = obs.enable_tracing()
    on = _flat(ex, rects)
    ok, problems = tr.validate()
    assert ok, problems
    assert all(np.array_equal(a, b) for a, b in zip(on, off))
    # device waves must show their dispatch/transfer split under the wave
    names = {e["name"] for e in tr.events()}
    assert "device.dispatch" in names and "device.transfer" in names


def test_server_drain_span_and_watchdog_wiring():
    ds = make_airline(3000)
    idx = COAXIndex(ds.data)
    rects = rects_for(ds.data)
    srv = QueryServer(idx, max_batch=8)
    tr = obs.enable_tracing()
    for r in rects:
        srv.submit(r)
    srv.drain()
    names = [e["name"] for e in tr.events()]
    assert "server.drain" in names
    s = srv.stats()
    assert "pauses" in s and "pause_median_gap_s" in s
