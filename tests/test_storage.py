"""Durability plane: snapshots, WAL, crash injection, recovery ≡ replay.

The load-bearing suite is the kill-and-recover matrix: for every
(workload × backend{numpy,device} × {single, K=4 sharded}) cell, an index
crashed at an arbitrary point of a deterministic insert/delete schedule and
recovered via snapshot + WAL replay must — after resuming the remaining
ops — return flat (query, row) hits bit-identical to the uninterrupted
index, pre- and post-compaction, with bit-equal Bayesian tracker
statistics (so drift-gated compaction fires at the same op).  Crash
injection covers every window of DESIGN.md §7: torn WAL tails, staged
snapshots that never renamed, stale shard snapshots, rotation interrupted
between snapshot and truncation.
"""
import os
from pathlib import Path

import numpy as np
import pytest

from repro.core import COAXIndex, CoaxConfig
from repro.data import make_airline
from repro.engine import QueryServer, ShardedCOAX
from repro.storage import (WriteAheadLog, atomic, latest_snapshot,
                           read_manifest, read_wal, restore, wal_path,
                           write_snapshot)

from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
from workloads import (NOAUTO, fullscan_expected, mutable_workloads,
                       rects_for, violate_fd)

# compaction triggers low enough that deterministic schedules cross them
TRIG = CoaxConfig(compact_min_delta=400, compact_delta_frac=0.01,
                  drift_min_delta=200)


def _schedule(ds, more, n_ops=16, violate_every=4, delete_every=3):
    """Deterministic op list: insert bursts (every ``violate_every``-th
    FD-violating) interleaved with deletes of known original ids."""
    ops = []
    for i in range(n_ops):
        rows = more(100 + i, 120)
        if i % violate_every == violate_every - 1:
            rows = violate_fd(ds, rows)
        ops.append(("insert", rows))
        if i % delete_every == delete_every - 1:
            ops.append(("delete", np.arange(i * 37, i * 37 + 25)))
    return ops


def _apply(idx, op):
    (idx.insert if op[0] == "insert" else idx.delete)(op[1])


def _flat_hits(idx, rects, backend=None):
    if backend is not None:
        bk = idx.backend
        idx.backend = backend
        out = idx.query_batch(rects)
        idx.backend = bk
        return out
    return idx.query_batch(rects)


def _assert_state_equal(live, rec, rects, tag=""):
    """Every behavioral dimension of bit-identity (DESIGN.md §7.4)."""
    lq, lr = _flat_hits(live, rects)
    q, r = _flat_hits(rec, rects)
    assert np.array_equal(q, lq) and np.array_equal(r, lr), (tag, "hits")
    assert rec.epoch == live.epoch, (tag, "epoch")
    assert rec.compactions == live.compactions, (tag, "compactions")
    assert rec._next_id == live._next_id, (tag, "next_id")
    assert rec.n_rows == live.n_rows, (tag, "n_rows")


def _assert_trackers_equal(live, rec, tag=""):
    """Satellite: recovered Bayesian sufficient statistics must be BIT
    equal to the live tracker's, and the drift score must match exactly."""
    if hasattr(live, "shards"):
        for k, (ls, rs) in enumerate(zip(live.shards, rec.shards)):
            _assert_trackers_equal(ls, rs, (tag, k))
        return
    keys = live._tracker_keys()
    assert rec._tracker_keys() == keys, (tag, "tracker keys")
    for k in keys:
        assert np.array_equal(live._fd_trackers[k].xtx,
                              rec._fd_trackers[k].xtx), (tag, k, "xtx")
        assert np.array_equal(live._fd_trackers[k].xty,
                              rec._fd_trackers[k].xty), (tag, k, "xty")
    assert live._x_scale == rec._x_scale, (tag, "x_scale")
    assert live.drift_predictability() == rec.drift_predictability(), tag


def _device_ok():
    try:
        from repro.engine import device_available
        return device_available()
    except ImportError:
        return False


# --------------------------------------------------------------------- #
# atomic.py: the shared staged-write idiom
# --------------------------------------------------------------------- #
def test_atomic_stage_rename_and_completeness(tmp_path):
    def good(tmp):
        (tmp / "payload.bin").write_bytes(b"x" * 64)
        (tmp / "MANIFEST.json").write_text("{}")

    atomic.stage_and_rename(tmp_path / "epoch_00000001_000000000000", good)
    # a crash mid-stage = a .tmp dir that never renamed + a manifest-less dir
    (tmp_path / ".tmp.deadbeef.epoch_00000002_000000000000").mkdir()
    torn = tmp_path / "epoch_00000003_000000000000"
    torn.mkdir()
    (torn / "payload.bin").write_bytes(b"partial")
    latest = atomic.latest_complete(tmp_path, "epoch_")
    assert latest is not None and latest.name == "epoch_00000001_000000000000"
    assert atomic.parse_key(latest.name, "epoch_") == (1, 0)
    assert atomic.sweep_stale_tmp(tmp_path) == 1


def test_atomic_retention_keeps_newest(tmp_path):
    def writer(tmp):
        (tmp / "MANIFEST.json").write_text("{}")

    for step in range(5):
        atomic.stage_and_rename(tmp_path / f"step_{step:08d}", writer)
    assert atomic.retain(tmp_path, "step_", keep=2) == 3
    keys = [k for k, _ in atomic.complete_entries(tmp_path, "step_")]
    assert keys == [(3,), (4,)]


def test_atomic_failed_stage_leaves_previous(tmp_path):
    def writer(tmp):
        (tmp / "MANIFEST.json").write_text('{"v": 1}')

    atomic.stage_and_rename(tmp_path / "step_00000001", writer)

    def boom(tmp):
        (tmp / "junk").write_bytes(b"j")
        raise RuntimeError("disk full")

    with pytest.raises(RuntimeError):
        atomic.stage_and_rename(tmp_path / "step_00000001", boom)
    assert (tmp_path / "step_00000001" / "MANIFEST.json").read_text() == '{"v": 1}'
    assert not list(tmp_path.glob(".tmp.*"))


# --------------------------------------------------------------------- #
# wal.py: framing, torn tails
# --------------------------------------------------------------------- #
def test_wal_roundtrip(tmp_path):
    p = wal_path(tmp_path, 3)
    wal = WriteAheadLog(p, epoch=3)
    rows = np.arange(12, dtype=np.float32).reshape(3, 4)
    wal.append_insert(rows, np.array([7, 8, 9], np.int64))
    wal.append_delete(np.array([1, 2], np.int64))
    assert wal.pending_records == 2 and wal.pending_bytes > 0
    wal.sync()
    assert wal.pending_bytes == 0
    wal.close()
    records, next_seq, intact = read_wal(p, expect_epoch=3)
    assert next_seq == 2 and intact == p.stat().st_size
    assert np.array_equal(records[0].rows, rows)
    assert np.array_equal(records[0].ids, [7, 8, 9])
    assert records[1].rows is None
    assert np.array_equal(records[1].ids, [1, 2])
    with pytest.raises(ValueError):
        read_wal(p, expect_epoch=4)


@pytest.mark.parametrize("cut", [1, 10, 21, 30])
def test_wal_torn_tail_recovers_prefix(tmp_path, cut):
    """Truncating the WAL mid-record (any byte of the last frame) must
    yield exactly the complete-prefix records."""
    p = wal_path(tmp_path, 0)
    wal = WriteAheadLog(p, epoch=0)
    for i in range(3):
        wal.append_insert(np.full((2, 2), i, np.float32),
                          np.array([2 * i, 2 * i + 1], np.int64))
    wal.close()
    full = p.stat().st_size
    records, _, _ = read_wal(p)
    assert len(records) == 3
    os.truncate(p, full - cut)              # torn write: lose tail bytes
    records, next_seq, intact = read_wal(p)
    assert len(records) == 2 and next_seq == 2
    assert intact <= full - cut
    # garbage tail (not just truncation) must also stop at the prefix
    # (0xff can never complete the torn record: its true bytes differ)
    with open(p, "ab") as f:
        f.write(b"\xff" * 40)
    records, next_seq, _ = read_wal(p)
    assert len(records) == 2 and next_seq == 2


# --------------------------------------------------------------------- #
# snapshot round trip
# --------------------------------------------------------------------- #
def test_snapshot_roundtrip_midepoch(tmp_path):
    """A full-state save with live deltas, tombstones and dragged trackers
    restores bit-identically — no WAL involved."""
    name, ds, more = mutable_workloads(4000)[0]
    idx = COAXIndex(ds.data, NOAUTO)
    idx.insert(more(100, 300))
    idx.insert(violate_fd(ds, more(101, 80)))
    idx.delete(np.arange(50, 120))
    path = idx.save(tmp_path)
    man = read_manifest(path)
    assert man["kind"] == "coax" and man["wal_seq"] == 0
    rects = rects_for(ds.data, n=8)
    rec = COAXIndex.restore(tmp_path)
    _assert_state_equal(idx, rec, rects, "roundtrip")
    _assert_trackers_equal(idx, rec, "roundtrip")
    # the restored index keeps mutating correctly: scratch-oracle agreement
    rec.insert(more(102, 60))
    idx.insert(more(102, 60))
    rows, ids = idx.live_rows()
    want = fullscan_expected(rows, ids, rects)
    got = rec.query_batch_split(rects)
    assert all(np.array_equal(g, w) for g, w in zip(got, want))


def test_snapshot_newest_complete_wins(tmp_path):
    ds = make_airline(3000, seed=3)
    idx = COAXIndex(ds.data, NOAUTO)
    write_snapshot(idx, tmp_path, wal_seq=0)
    idx.insert(make_airline(100, seed=9).data)
    newer = write_snapshot(idx, tmp_path, wal_seq=5)
    assert latest_snapshot(tmp_path) == newer
    # a staged-but-never-renamed snapshot must not shadow it
    (tmp_path / ".tmp.cafef00d.epoch_00000009_000000000000").mkdir()
    bogus = tmp_path / "epoch_00000009_000000000000"
    bogus.mkdir()
    (bogus / "arrays.npz").write_bytes(b"not an npz")
    assert latest_snapshot(tmp_path) == newer
    rec = restore(tmp_path)
    assert rec.n_rows == idx.n_rows


# --------------------------------------------------------------------- #
# kill-and-recover differential matrix (the acceptance test)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("wname", ["airline", "osm", "generic_fd"])
@pytest.mark.parametrize("shards", [None, 4])
def test_kill_and_recover_matrix(tmp_path, wname, shards):
    """Crash at arbitrary points of a deterministic schedule; recover;
    resume the remaining ops; flat hits must be bit-identical to the
    uninterrupted index on numpy AND device, pre- and post-compaction."""
    name, ds, more = next(w for w in mutable_workloads(5000) if w[0] == wname)
    rects = rects_for(ds.data, n=8)
    ops = _schedule(ds, more)

    def build():
        if shards:
            return ShardedCOAX(ds.data, TRIG, n_shards=shards)
        return COAXIndex(ds.data, TRIG)

    live = build()
    compact_ops = []                      # ops after which a compaction fired
    for i, op in enumerate(ops):
        before = live.compactions
        _apply(live, op)
        if live.compactions != before:
            compact_ops.append(i)
    assert compact_ops, "schedule must cross the compaction trigger"
    check_device = _device_ok()

    # crash points: start, pre-first-compaction, right at it, and the end
    points = sorted({0, max(compact_ops[0] - 1, 0), compact_ops[0] + 1,
                     len(ops)})
    for crash_at in points:
        d = tmp_path / f"crash_{crash_at}"
        vic = build()
        vic.attach_durability(d)
        for op in ops[:crash_at]:
            _apply(vic, op)
        vic.durable.sync()
        del vic                            # the crash: memory is gone
        rec = restore(d, durable=True)
        assert type(rec) is type(live)
        for op in ops[crash_at:]:
            _apply(rec, op)
        _assert_state_equal(live, rec, rects, (wname, shards, crash_at))
        _assert_trackers_equal(live, rec, (wname, shards, crash_at))
        if check_device:
            lq, lr = _flat_hits(live, rects, backend="device")
            q, r = _flat_hits(rec, rects, backend="device")
            assert np.array_equal(q, lq) and np.array_equal(r, lr), \
                (wname, shards, crash_at, "device")


def test_recover_preserves_compaction_schedule(tmp_path):
    """After recovery the drift/size triggers fire at the SAME op as the
    never-crashed index — the tracker-seeding satellite's observable."""
    name, ds, more = mutable_workloads(5000)[0]
    ops = _schedule(ds, more, n_ops=20)
    live = COAXIndex(ds.data, TRIG)
    d = Path(tmp_path) / "dur"
    vic = COAXIndex(ds.data, TRIG).attach_durability(d)
    crash_at = 6
    live_epochs, rec_epochs = [], []
    for op in ops[:crash_at]:
        _apply(live, op)
        _apply(vic, op)
    vic.durable.sync()
    del vic
    rec = restore(d, durable=True)
    for op in ops[crash_at:]:
        _apply(live, op)
        live_epochs.append(live.epoch)
        _apply(rec, op)
        rec_epochs.append(rec.epoch)
    assert live_epochs == rec_epochs      # compactions at identical ops
    assert live.compactions == rec.compactions > 0


# --------------------------------------------------------------------- #
# crash injection
# --------------------------------------------------------------------- #
def test_truncated_wal_recovers_to_durable_prefix(tmp_path):
    """Kill mid-append: the torn record is dropped, recovery lands exactly
    on the scratch-rebuild oracle of the ops that survived, and the
    re-attached journal keeps working from there."""
    name, ds, more = mutable_workloads(4000)[0]
    rects = rects_for(ds.data, n=6)
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    idx.insert(more(100, 200))
    idx.delete(np.arange(40))
    idx.durable.sync()
    oracle_rows, oracle_ids = idx.live_rows()
    idx.insert(more(101, 150))            # will be torn mid-record
    idx.durable.close()
    p = wal_path(tmp_path, 0)
    os.truncate(p, p.stat().st_size - 17)

    rec = restore(tmp_path, durable=True)
    want = fullscan_expected(oracle_rows, oracle_ids, rects)
    got = rec.query_batch_split(rects)
    assert all(np.array_equal(g, w) for g, w in zip(got, want))
    # the truncated tail was cut, so appending resumes at the right seq
    rec.insert(more(102, 50))
    rec.durable.sync()
    records, next_seq, intact = read_wal(p, expect_epoch=0)
    assert next_seq == 3 and intact == p.stat().st_size
    rec2 = restore(tmp_path)
    assert rec2.n_rows == rec.n_rows


def test_crash_between_stage_and_rename(tmp_path):
    """Kill after staging a checkpoint but before the rename: the .tmp
    litter is invisible, recovery uses the previous snapshot + full WAL."""
    name, ds, more = mutable_workloads(4000)[0]
    rects = rects_for(ds.data, n=6)
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    idx.insert(more(100, 300))
    idx.delete(np.arange(60))
    idx.durable.sync()
    lq, lr = idx.query_batch(rects)
    # simulate the checkpoint dying mid-stage: payload written, no rename
    litter = tmp_path / ".tmp.00c0ffee.epoch_00000000_000000000002"
    litter.mkdir()
    (litter / "arrays.npz").write_bytes(b"half-written")
    (litter / "manifest.json").write_text("{}")
    del idx
    rec = restore(tmp_path, durable=True)
    q, r = rec.query_batch(rects)
    assert np.array_equal(q, lq) and np.array_equal(r, lr)
    assert not list(tmp_path.glob(".tmp.*"))   # recovery swept the litter
    man = read_manifest(latest_snapshot(tmp_path))
    assert man["wal_seq"] == 0                 # the staged one never won


def test_rotation_crash_window_snapshot_published_wal_not_cut(tmp_path):
    """Kill between the rotation's snapshot rename and the old-WAL delete:
    the newest snapshot wins and the stale WAL is ignored AND cleaned."""
    name, ds, more = mutable_workloads(4000)[0]
    rects = rects_for(ds.data, n=6)
    idx = COAXIndex(ds.data, TRIG).attach_durability(tmp_path)
    while idx.compactions == 0:                # drive across the trigger
        idx.insert(more(103, 120))
    idx.durable.sync()
    lq, lr = idx.query_batch(rects)
    assert idx.epoch >= 1
    # resurrect a stale pre-rotation WAL as the crash would leave it
    stale = wal_path(tmp_path, idx.epoch - 1)
    WriteAheadLog(stale, idx.epoch - 1).close()
    del idx
    rec = restore(tmp_path, durable=True)
    q, r = rec.query_batch(rects)
    assert np.array_equal(q, lq) and np.array_equal(r, lr)
    assert not stale.exists()                  # recovery cleaned it


def test_midreplay_compaction_defers_rotation(tmp_path):
    """Crash BETWEEN the WAL append of a trigger-tripping op and the
    rotation's disk work: the WAL still holds the op, replay re-fires the
    compaction, and the deferred rotation leaves a crash-safe pair — a
    second recovery lands on the identical state."""
    name, ds, more = mutable_workloads(4000)[0]
    rects = rects_for(ds.data, n=6)
    live = COAXIndex(ds.data, TRIG)
    d = tmp_path / "dur"
    vic = COAXIndex(ds.data, TRIG).attach_durability(d)
    burst = 0
    while True:                            # stop just before the trigger
        rows = more(200 + burst, 120)
        load = vic.delta_rows + vic.tombstone_count + rows.shape[0]
        if load >= max(TRIG.compact_min_delta,
                       int(TRIG.compact_delta_frac * vic.data.shape[0])):
            break
        live.insert(rows)
        vic.insert(rows)
        burst += 1
    assert vic.compactions == 0
    # the fatal op: journaled, applied, compacts in memory — but the
    # process dies before on_compact's disk work runs
    vic.durable.on_compact = lambda index: None
    live.insert(rows)
    vic.insert(rows)
    assert vic.compactions == 1
    vic.durable.sync()
    del vic
    # on disk: epoch-0 snapshot + a WAL whose last record trips the trigger
    assert wal_path(d, 0).exists() and not wal_path(d, 1).exists()
    rec = restore(d, durable=True)
    _assert_state_equal(live, rec, rects, "midreplay")
    _assert_trackers_equal(live, rec, "midreplay")
    # deferred rotation converged disk: new-epoch pair, old WAL gone
    assert not wal_path(d, 0).exists() and wal_path(d, rec.epoch).exists()
    del rec
    rec2 = restore(d, durable=True)        # crash right after recovery
    _assert_state_equal(live, rec2, rects, "midreplay-again")
    _assert_trackers_equal(live, rec2, "midreplay-again")


def test_attach_truncates_recordless_torn_tail(tmp_path):
    """A first append that died mid-record leaves a recordless torn WAL;
    re-attaching must cut it so later appends stay readable."""
    ds = make_airline(2000, seed=3)
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    idx.insert(make_airline(40, seed=9).data)
    idx.durable.close()
    p = wal_path(tmp_path, 0)
    os.truncate(p, p.stat().st_size - 11)  # tear the ONLY record
    assert read_wal(p)[1] == 0
    idx2 = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    idx2.insert(make_airline(30, seed=10).data)
    idx2.durable.sync()
    records, next_seq, intact = read_wal(p, expect_epoch=0)
    assert next_seq == 1 and intact == p.stat().st_size
    assert records[0].rows.shape[0] == 30


def test_from_index_refuses_journaled_donor(tmp_path):
    from repro.engine import BatchQueryExecutor

    ds = make_airline(2000, seed=3)
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    with pytest.raises(ValueError, match="journaled"):
        ShardedCOAX.from_index(idx, 2)
    with pytest.raises(ValueError, match="journaled"):
        BatchQueryExecutor(idx, shards=2)   # the server/executor route


def test_republish_crash_window_repairable(tmp_path):
    """A same-key republish that dies between its two renames leaves the
    old artifact under .old.<nonce>.<name>; the durable-recovery sweep
    renames it back instead of losing the only snapshot."""
    ds = make_airline(2000, seed=3)
    idx = COAXIndex(ds.data, NOAUTO)
    snap = write_snapshot(idx, tmp_path, wal_seq=0)
    # simulate the window: old renamed aside, new never landed
    backup = tmp_path / f".old.deadbeef.{snap.name}"
    os.rename(snap, backup)
    assert latest_snapshot(tmp_path) is None
    assert atomic.sweep_stale_tmp(tmp_path) == 1
    assert latest_snapshot(tmp_path) is not None
    rec = restore(tmp_path)
    assert rec.n_rows == idx.n_rows


def test_stale_shard_snapshot_recovers_from_wal(tmp_path):
    """One shard's snapshot is old (its later checkpoints deleted) while
    its WAL holds the whole epoch tail — per-shard recovery replays it and
    the plane still matches the uninterrupted index exactly."""
    name, ds, more = mutable_workloads(4000)[0]
    rects = rects_for(ds.data, n=6)
    live = ShardedCOAX(ds.data, NOAUTO, n_shards=3)
    vic = ShardedCOAX(ds.data, NOAUTO, n_shards=3).attach_durability(tmp_path)
    ops = _schedule(ds, more, n_ops=6)
    for op in ops[:3]:
        _apply(live, op)
        _apply(vic, op)
    vic.durable.checkpoint()                   # every shard snapshots @ mid
    for op in ops[3:]:
        _apply(live, op)
        _apply(vic, op)
    vic.durable.checkpoint()
    vic.durable.sync()
    del vic
    # stale-snapshot injection: shard 1 loses every snapshot newer than
    # its epoch-0 build snapshot, keeping only the WAL
    sdir = tmp_path / "shard_01"
    entries = atomic.complete_entries(sdir, "epoch_", "manifest.json")
    assert len(entries) >= 2
    import shutil
    for _, p in entries[1:]:
        shutil.rmtree(p)
    # crash litter inside a SHARD directory must be swept on recovery too
    (sdir / ".tmp.0badc0de.epoch_00000000_000000000009").mkdir()
    rec = restore(tmp_path, durable=True)
    _assert_state_equal(live, rec, rects, "stale-shard")
    _assert_trackers_equal(live, rec, "stale-shard")
    assert not list(sdir.glob(".tmp.*"))


def test_attach_refuses_live_history(tmp_path):
    ds = make_airline(2000, seed=3)
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    idx.insert(make_airline(50, seed=9).data)
    idx.durable.sync()
    fresh = COAXIndex(ds.data, NOAUTO)
    with pytest.raises(ValueError, match="journal records"):
        fresh.attach_durability(tmp_path)
    # a newer-keyed snapshot alone (no WAL records) must also refuse: it
    # would shadow the fresh index's history at restore time
    idx.durable.checkpoint()
    os.unlink(wal_path(tmp_path, 0))
    with pytest.raises(ValueError, match="newer"):
        fresh.attach_durability(tmp_path)


# --------------------------------------------------------------------- #
# server + stats surfacing
# --------------------------------------------------------------------- #
def test_server_wave_sync_checkpoint_and_recover(tmp_path):
    name, ds, more = mutable_workloads(4000)[0]
    rects = rects_for(ds.data, n=10)
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    srv = QueryServer(idx, max_batch=4, checkpoint_every=2)
    srv.insert(more(100, 80))
    srv.delete(np.arange(30))
    for r in rects:
        srv.submit(r)
    res = srv.drain()
    s = srv.stats()
    assert s["wal_records"] == 2
    assert s["wal_pending_bytes"] == 0          # synced at wave boundaries
    assert s["checkpoints_written"] >= 1
    assert s["last_snapshot_bytes"] > 0
    man = read_manifest(latest_snapshot(tmp_path))
    assert man["wal_seq"] == 2                  # checkpoint absorbed the ops
    del srv, idx
    srv2 = QueryServer.recover(tmp_path, max_batch=4)
    for r in rects:
        srv2.submit(r)
    res2 = srv2.drain()
    assert all(np.array_equal(a, b)
               for a, b in zip(res.values(), res2.values()))


def test_describe_and_footprint_surface_durability(tmp_path):
    name, ds, more = mutable_workloads(3000)[0]
    idx = COAXIndex(ds.data, NOAUTO)
    base_fp = idx.memory_footprint()
    assert idx.describe()["durability"] is None
    idx.attach_durability(tmp_path)
    idx.insert(more(100, 64))
    d = idx.describe()["durability"]
    assert d["wal_records"] == 1 and d["wal_pending_bytes"] > 0
    assert d["last_snapshot_bytes"] > 0 and d["snapshots"] == 1
    assert idx.memory_footprint() >= base_fp + d["wal_pending_bytes"]
    idx.durable.sync()
    assert idx.describe()["durability"]["wal_pending_bytes"] == 0
    # sharded rollup
    sh = ShardedCOAX(ds.data, NOAUTO, n_shards=2)
    sh.attach_durability(tmp_path / "sharded")
    sh.insert(more(101, 32))
    sd = sh.describe()["durability"]
    assert len(sd["per_shard"]) == 2 and sd["wal_records"] >= 1


def test_restore_readonly_leaves_directory_untouched(tmp_path):
    """durable=False is the cold-start-replica path: byte-for-byte no
    directory mutation, and the loaded index does not journal."""
    name, ds, more = mutable_workloads(3000)[0]
    idx = COAXIndex(ds.data, NOAUTO).attach_durability(tmp_path)
    idx.insert(more(100, 100))
    idx.durable.sync()
    before = sorted((str(p.relative_to(tmp_path)), p.stat().st_size)
                    for p in tmp_path.rglob("*") if p.is_file())
    rec = restore(tmp_path)
    assert rec.durable is None
    rec.insert(more(101, 10))              # mutates memory only
    after = sorted((str(p.relative_to(tmp_path)), p.stat().st_size)
                   for p in tmp_path.rglob("*") if p.is_file())
    assert before == after


# --------------------------------------------------------------------- #
# property test: arbitrary op sequences, crash point mid-sequence
# --------------------------------------------------------------------- #
_DS = None


def _dataset():
    global _DS
    if _DS is None:
        _DS = mutable_workloads(2500)[0]
    return _DS


@given(st.data())
@settings(max_examples=10, deadline=None)
def test_recovery_equals_uninterrupted_property(tmp_path_factory, data):
    """Hypothesis: for ANY short op sequence and ANY crash point drawn
    mid-sequence, snapshot+WAL recovery followed by the remaining ops is
    bit-identical to the uninterrupted run."""
    name, ds, more = _dataset()
    n_ops = data.draw(st.integers(min_value=1, max_value=8), label="n_ops")
    ops = []
    for i in range(n_ops):
        kind = data.draw(st.sampled_from(["ins", "ins_bad", "del"]),
                         label=f"op{i}")
        if kind == "del":
            lo = data.draw(st.integers(min_value=0, max_value=2400),
                           label=f"lo{i}")
            ops.append(("delete", np.arange(lo, lo + 40)))
        else:
            seed = data.draw(st.integers(min_value=50, max_value=80),
                             label=f"seed{i}")
            rows = more(seed, 60)
            if kind == "ins_bad":
                rows = violate_fd(ds, rows)
            ops.append(("insert", rows))
    crash_at = data.draw(st.integers(min_value=0, max_value=n_ops),
                         label="crash_at")
    cfg = CoaxConfig(compact_min_delta=150, compact_delta_frac=0.01,
                     drift_min_delta=100)
    rects = rects_for(ds.data, n=4, seed=1)

    live = COAXIndex(ds.data, cfg)
    for op in ops:
        _apply(live, op)
    d = tmp_path_factory.mktemp("wal_prop")
    vic = COAXIndex(ds.data, cfg).attach_durability(d)
    for op in ops[:crash_at]:
        _apply(vic, op)
    vic.durable.sync()
    del vic
    rec = restore(d, durable=True)
    for op in ops[crash_at:]:
        _apply(rec, op)
    _assert_state_equal(live, rec, rects, ("prop", crash_at))
    _assert_trackers_equal(live, rec, ("prop", crash_at))


if not HAVE_HYPOTHESIS:
    # emulated draws: the property still runs on minimal CI images
    def test_recovery_property_emulated(tmp_path):
        name, ds, more = _dataset()
        rng = np.random.default_rng(0)
        cfg = CoaxConfig(compact_min_delta=150, compact_delta_frac=0.01,
                         drift_min_delta=100)
        rects = rects_for(ds.data, n=4, seed=1)
        for trial in range(5):
            n_ops = int(rng.integers(1, 9))
            ops = []
            for i in range(n_ops):
                kind = rng.choice(["ins", "ins_bad", "del"])
                if kind == "del":
                    lo = int(rng.integers(0, 2400))
                    ops.append(("delete", np.arange(lo, lo + 40)))
                else:
                    rows = more(int(rng.integers(50, 80)), 60)
                    if kind == "ins_bad":
                        rows = violate_fd(ds, rows)
                    ops.append(("insert", rows))
            crash_at = int(rng.integers(0, n_ops + 1))
            live = COAXIndex(ds.data, cfg)
            for op in ops:
                _apply(live, op)
            d = tmp_path / f"trial{trial}"
            vic = COAXIndex(ds.data, cfg).attach_durability(d)
            for op in ops[:crash_at]:
                _apply(vic, op)
            vic.durable.sync()
            del vic
            rec = restore(d, durable=True)
            for op in ops[crash_at:]:
                _apply(rec, op)
            _assert_state_equal(live, rec, rects, ("emul", trial))
            _assert_trackers_equal(live, rec, ("emul", trial))
