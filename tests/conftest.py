import dataclasses
import subprocess
import sys
import textwrap
from pathlib import Path

import numpy as np
import pytest

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)


@pytest.fixture
def rng():
    """Deterministic per-test RNG.

    Tests draw randomness from this instead of seeding global numpy state,
    so results are identical whether or not a plugin (e.g. pytest-randomly)
    reseeds the globals — the suite behaves the same with and without
    ``-p no:randomly``.
    """
    return np.random.default_rng(0xC0AC5)


def tiny_config(cfg):
    """Shrink an arch config to smoke scale, preserving its family traits."""
    kw = dict(
        n_layers=2, d_model=64, d_ff=128, vocab_size=256,
        n_heads=4 if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads else 0,
        head_dim=16 if cfg.head_dim else None, window=8,
    )
    if cfg.mla:
        kw.update(q_lora=32, kv_lora=16, nope_dim=8, rope_dim=4, v_dim=8)
    if cfg.n_experts:
        kw.update(n_experts=4, top_k=2, capacity_factor=8.0)
    if cfg.ssm_state:
        kw.update(ssm_state=8, ssm_expand=2, ssm_head_p=8)
    if cfg.family == "hybrid":
        kw.update(n_layers=4, attn_every=2, n_shared_attn=2)
    if cfg.enc_layers:
        kw.update(enc_layers=2)
    if cfg.n_patches:
        kw.update(n_patches=4)
    if cfg.mrope_sections:
        kw.update(mrope_sections=(2, 3, 3))
    return dataclasses.replace(cfg, **kw)


def make_batch(cfg, batch=2, seq=16, seed=0, with_labels=True):
    import jax.numpy as jnp
    rng = np.random.default_rng(seed)
    toks = jnp.asarray(rng.integers(0, 200, (batch, seq)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 200, (batch, seq)), jnp.int32)
    if cfg.family == "encdec":
        b = {"frames": jnp.asarray(rng.normal(0, 1, (batch, seq, cfg.d_model)),
                                   jnp.bfloat16),
             "tokens": toks}
        if with_labels:
            b["labels"] = labels
        return b
    if cfg.family == "vlm":
        st = seq - cfg.n_patches
        b = {"patches": jnp.asarray(rng.normal(0, 1, (batch, cfg.n_patches, cfg.d_model)),
                                    jnp.bfloat16),
             "tokens": toks[:, :st]}
        if with_labels:
            b["labels"] = labels[:, :st]
        return b
    b = {"tokens": toks}
    if with_labels:
        b["labels"] = labels
    return b


def run_in_subprocess(code: str, devices: int = 8, timeout: int = 560) -> str:
    """Run ``code`` in a fresh python with N fake XLA devices; returns stdout."""
    prelude = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import sys
        sys.path.insert(0, {SRC!r})
    """)
    proc = subprocess.run(
        [sys.executable, "-c", prelude + textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout)
    assert proc.returncode == 0, f"subprocess failed:\n{proc.stdout}\n{proc.stderr}"
    return proc.stdout
