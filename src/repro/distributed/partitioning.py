"""Logical-axis partitioning (MaxText-style rules).

Model code annotates every parameter and key activation with LOGICAL axis
names ("batch", "heads", "ff", ...).  A rules table maps logical names to
mesh axes; ``logical_to_spec`` builds PartitionSpecs and ``shard`` applies
``with_sharding_constraint`` — or is a no-op when no rules are active, so the
same model code runs single-device smoke tests and 512-chip dry-runs.

Rules are installed via ``use_rules`` (context manager) or ``set_rules``.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

__all__ = [
    "LogicalRules",
    "DEFAULT_RULES",
    "SP_RULES",
    "rules_for_mesh",
    "set_rules",
    "get_rules",
    "use_rules",
    "logical_to_spec",
    "shard",
]

MeshAxes = Union[None, str, Tuple[str, ...]]
LogicalRules = Dict[str, MeshAxes]

# Baseline DP+TP rules for the production meshes (launch/mesh.py):
#   single-pod ("data", "model"); multi-pod adds a leading "pod" axis that the
#   mesh-aware helpers fold into the batch axes at dry-run time.
DEFAULT_RULES: LogicalRules = {
    "batch": ("data",),
    "seq": None,            # sequence replicated (no SP) by default
    "attn_seq": None,       # seq sharding INSIDE attention (context parallel)
                            # — used instead of "heads" when heads % tp != 0
    "mlp_seq": None,        # seq inside the FFN: gathered when ff is sharded
                            # (Megatron SP semantics)
    "logit_seq": None,      # seq at the unembed: gathered when vocab sharded
    "embed": None,
    "heads": ("model",),
    "kv_heads": ("model",),
    "head_dim": None,
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),  # EP when n_experts divides the model axis
    "expert_ff": None,      # MoE fallback: ff sharding inside each expert
    "moe_capacity": ("data",),  # capacity dim of (E, C, d) dispatch buffers
                            # follows the batch axes (C ~ tokens)
    "ssm_inner": ("model",),
    "ssm_state": None,
    "layers": None,         # stacked-scan leading axis is never sharded
    "kv_len": None,
    "q_lora": None,
    "kv_lora": None,
}

# Sequence-parallel variant: activations' seq axis sharded over "model" in
# the norm/residual regions (attention/FFN re-gather via their own specs).
SP_RULES: LogicalRules = dict(DEFAULT_RULES, seq=("model",))

def rules_for_mesh(mesh, *, sequence_parallel: bool = False,
                   expert_parallel: bool = True) -> LogicalRules:
    """Rules adapted to a concrete mesh.

    * multi-pod meshes fold the leading "pod" axis into the batch sharding
      (pods are outer data parallelism; gradients cross DCN once per step);
    * ``sequence_parallel`` shards the activations' seq axis over "model";
    * ``expert_parallel=False`` forces MoE to TP (ff inside each expert).
    """
    rules = dict(SP_RULES if sequence_parallel else DEFAULT_RULES)
    if "pod" in getattr(mesh, "axis_names", ()):
        rules["batch"] = ("pod", "data")
    if not expert_parallel:
        rules["experts"] = None
        rules["expert_ff"] = ("model",)
    return rules


_state = threading.local()


def set_rules(rules: Optional[LogicalRules]) -> None:
    _state.rules = rules


def get_rules() -> Optional[LogicalRules]:
    return getattr(_state, "rules", None)


@contextlib.contextmanager
def use_rules(rules: Optional[LogicalRules]):
    prev = get_rules()
    set_rules(rules)
    try:
        yield
    finally:
        set_rules(prev)


def _flatten(axes: MeshAxes):
    if axes is None:
        return None
    if isinstance(axes, str):
        return axes
    if len(axes) == 0:
        return None
    return axes if len(axes) > 1 else axes[0]


def logical_to_spec(logical_axes: Sequence[Optional[str]],
                    rules: Optional[LogicalRules] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec."""
    rules = rules if rules is not None else get_rules()
    if rules is None:
        return P()
    out = []
    for name in logical_axes:
        if name is None:
            out.append(None)
        else:
            out.append(_flatten(rules.get(name)))
    return P(*out)


def shard(x: jax.Array, *logical_axes: Optional[str]) -> jax.Array:
    """Constrain ``x`` to the sharding implied by its logical axes.

    No-op when rules are inactive (single-device tests) so model code stays
    identical across environments.
    """
    rules = get_rules()
    if rules is None:
        return x
    spec = logical_to_spec(logical_axes, rules)
    return jax.lax.with_sharding_constraint(x, spec)
