"""Gradient compression with error feedback (distributed-optimization trick).

Two compressors:

* ``Int8Compressor`` — per-leaf symmetric int8 quantisation (scale =
  max|g|/127), error feedback accumulates the quantisation residual so the
  compression bias vanishes over steps (Karimireddy et al., 2019).
* ``TopKCompressor`` — keep the top-k fraction by magnitude, error feedback
  on the rest.

``compressed_psum`` is the wire-level form: inside ``shard_map`` over the
data axis it quantises, sums the int32 payload across the axis, and
dequantises — this is what replaces the DP all-reduce on real hardware
(8x less ICI/DCN traffic for int8 against f32 master grads).  The pjit
train-loop path uses ``make_grad_transform`` (numerically identical model
of compress->allreduce->decompress with EF state threaded through).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Tuple

import jax
import jax.numpy as jnp

__all__ = ["Int8Compressor", "TopKCompressor", "compressed_psum",
           "make_compressed_train_step"]


class Int8Compressor:
    name = "int8_ef"

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def compress(self, grads, err):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            q = jnp.clip(jnp.round(g / scale), -127, 127)
            deq = q * scale
            return deq, g - deq
        out = jax.tree.map(one, grads, err)
        deq = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return deq, new_err

    def wire_bytes_ratio(self) -> float:
        return 1.0 / 4.0  # int8 vs f32


@dataclasses.dataclass
class TopKCompressor:
    frac: float = 0.05
    name = "topk_ef"

    def init(self, params):
        return jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)

    def compress(self, grads, err):
        def one(g, e):
            g = g.astype(jnp.float32) + e
            flat = g.reshape(-1)
            k = max(int(flat.size * self.frac), 1)
            thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
            kept = jnp.where(jnp.abs(g) >= thresh, g, 0.0)
            return kept, g - kept
        out = jax.tree.map(one, grads, err)
        kept = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        return kept, new_err

    def wire_bytes_ratio(self) -> float:
        return 2.0 * self.frac  # value+index per kept entry


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-quantised all-reduce for use INSIDE shard_map over the DP axis.

    Quantises with a per-tensor scale agreed via a (tiny) f32 psum of the
    max, sums int32 payloads (exact), dequantises.  Payload on the wire is
    the int8-representable sum — 4x smaller than f32."""
    m = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32), axis_name)
    scale = jnp.maximum(m, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127).astype(jnp.int32)
    total = jax.lax.psum(q, axis_name)
    return total.astype(jnp.float32) * scale


def make_compressed_train_step(model, opt_cfg, compressor,
                               lr_schedule: Callable = None):
    """Train step threading error-feedback state through the loop:
    (params, opt_state, ef_state, batch) -> (params, opt_state, ef_state,
    metrics)."""
    from ..optim import adamw_update

    def train_step(params, opt_state, ef_state, batch):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        grads, ef_state = compressor.compress(grads, ef_state)
        params, opt_state, metrics = adamw_update(
            params, grads, opt_state, opt_cfg, lr_schedule)
        metrics["loss"] = loss
        return params, opt_state, ef_state, metrics

    return train_step
