"""Per-architecture sharding policy.

``rules_for_arch`` adapts the logical-axis rules to a concrete
(architecture, mesh, workload) cell:

* TP axes engage only where tensor dims divide the model-axis size
  (heads/kv_heads/ff/vocab/experts/ssm head-dim);
* architectures whose head count does NOT divide TP (minitron 24H,
  qwen2-vl 12H, minicpm3 40H) fall back to CONTEXT-PARALLEL attention —
  the "attn_seq" logical axis shards the query sequence over the model
  axis, so attention compute still spreads across all chips without
  splitting heads (DESIGN.md §6);
* MoE: expert parallelism when E % tp == 0 (phi3.5: 16e), otherwise
  per-expert d_ff tensor parallelism (mixtral: 8e on tp=16);
* tiny-batch decode cells (long_500k, batch=1) replicate batch and shard
  the KV-cache length over the data axis instead (context-parallel decode).

``zero1_state_specs`` shards AdamW mu/nu over the data axis along the first
divisible unsharded dim (ZeRO-1).  ``fsdp_param_specs`` applies the same
transform to the parameters themselves (ZeRO-3 / FSDP via GSPMD).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig, ShapeConfig
from .partitioning import LogicalRules, rules_for_mesh

__all__ = ["rules_for_arch", "zero1_state_specs", "fsdp_param_specs",
           "batch_axis_size", "input_pspecs"]


def batch_axis_size(mesh, rules: LogicalRules) -> int:
    axes = rules.get("batch")
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def rules_for_arch(
    cfg: ModelConfig,
    mesh,
    shape: Optional[ShapeConfig] = None,
    *,
    sequence_parallel: bool = False,
    expert_parallel: bool = True,
) -> LogicalRules:
    rules = rules_for_mesh(mesh, sequence_parallel=sequence_parallel,
                           expert_parallel=expert_parallel)
    tp = mesh.shape["model"]

    heads_ok = bool(cfg.n_heads) and cfg.n_heads % tp == 0
    rules["heads"] = ("model",) if heads_ok else None
    if cfg.n_heads and not heads_ok:
        # context-parallel attention fallback; align the residual stream so
        # norms/projections don't reshard on every block boundary.
        rules["attn_seq"] = ("model",)
        rules["seq"] = ("model",)
    rules["kv_heads"] = ("model",) if (cfg.n_kv_heads and cfg.n_kv_heads % tp == 0) else None
    rules["ff"] = ("model",) if (cfg.d_ff and cfg.d_ff % tp == 0) else None
    rules["vocab"] = ("model",) if cfg.padded_vocab % tp == 0 else None
    if cfg.n_experts:
        if expert_parallel and cfg.n_experts % tp == 0:
            rules["experts"], rules["expert_ff"] = ("model",), None
        else:
            rules["experts"] = None
            rules["expert_ff"] = ("model",) if cfg.d_ff % tp == 0 else None
        rules["moe_capacity"] = rules["batch"]  # C ~ tokens: batch axes
    if cfg.ssm_state:
        rules["ssm_inner"] = ("model",) if cfg.ssm_head_p % tp == 0 else None

    if shape is not None:
        if shape.kind == "decode" and rules["kv_heads"] is None:
            # KV heads can't split over the model axis -> shard the cache
            # LENGTH there instead (partial-softmax decode); otherwise the
            # 32k cache replicates 16x (v0 dry-run: 30-135 GiB/device).
            rules["kv_len"] = ("model",)
        bsz = batch_axis_size(mesh, rules)
        if shape.global_batch % bsz != 0:
            # tiny-batch cell (long_500k): context-parallel decode — batch
            # replicated, KV length sharded over the data axis too.
            rules["batch"] = None
            if cfg.n_experts:
                rules["moe_capacity"] = None
            if shape.kind == "decode":
                kl = rules.get("kv_len")
                kl = kl if isinstance(kl, tuple) else ((kl,) if kl else ())
                rules["kv_len"] = tuple(dict.fromkeys(("data",) + kl))
    return rules


def _spec_axes(spec: P):
    out = set()
    for e in spec:
        if e is None:
            continue
        if isinstance(e, (tuple, list)):
            out.update(e)
        else:
            out.add(e)
    return out


def _add_axis(spec: P, shape: Tuple[int, ...], axis_name: str, axis_size: int) -> P:
    """Shard the first unsharded, divisible dim of ``shape`` on ``axis_name``.

    No-op if the spec already uses ``axis_name`` (e.g. FSDP ran first) or if
    no dim is divisible."""
    if axis_name in _spec_axes(spec):
        return spec
    entries = list(spec) + [None] * (len(shape) - len(spec))
    for i, (dim, cur) in enumerate(zip(shape, entries)):
        if cur is None and dim % axis_size == 0 and dim >= axis_size:
            entries[i] = axis_name
            return P(*entries)
    return spec  # nothing divisible: leave as-is


def zero1_state_specs(param_specs, param_shapes, mesh) -> Any:
    """AdamW state specs: mu/nu sharded over "data" (ZeRO-1), step replicated."""
    data = mesh.shape["data"]

    def tr(spec, sds):
        return _add_axis(spec, sds.shape, "data", data)

    mu = jax.tree.map(tr, param_specs, param_shapes,
                      is_leaf=lambda x: isinstance(x, P))
    return {"mu": mu, "nu": jax.tree.map(lambda s: s, mu,
                                         is_leaf=lambda x: isinstance(x, P)),
            "step": P()}


def fsdp_param_specs(param_specs, param_shapes, mesh) -> Any:
    """FSDP / ZeRO-3: parameters additionally sharded over "data"."""
    data = mesh.shape["data"]
    return jax.tree.map(lambda spec, sds: _add_axis(spec, sds.shape, "data", data),
                        param_specs, param_shapes,
                        is_leaf=lambda x: isinstance(x, P))


def input_pspecs(logical_axes: Dict[str, Tuple], rules: LogicalRules) -> Dict[str, P]:
    from .partitioning import logical_to_spec
    return {k: logical_to_spec(ax, rules) for k, ax in logical_axes.items()}
