from .partitioning import (
    DEFAULT_RULES,
    SP_RULES,
    LogicalRules,
    get_rules,
    logical_to_spec,
    rules_for_mesh,
    set_rules,
    shard,
    use_rules,
)

__all__ = [
    "DEFAULT_RULES", "SP_RULES", "LogicalRules", "get_rules",
    "logical_to_spec", "rules_for_mesh", "set_rules", "shard", "use_rules",
]
