"""Pipeline parallelism: GPipe-style microbatched execution via shard_map +
collective_permute over a "stage" mesh axis.

Each device (stage) holds one contiguous slice of layers.  Microbatches
stream through: at tick t, stage s computes microbatch (t - s) and passes
its activation to stage s+1 with ``ppermute``.  Total ticks =
n_microbatches + n_stages - 1; bubble fraction = (S-1)/(M+S-1).

This is an opt-in distribution mode (config ``pipeline_stages > 1``); the
production dry-run meshes use DP x TP where PP is unnecessary at 256-512
chips, but the mechanism is required for >1k-chip scale-out (DESIGN.md §6)
and is tested on a local multi-device mesh in tests/test_distributed.py.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = ["pipeline_forward", "bubble_fraction"]


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)


def pipeline_forward(
    stage_fn: Callable[[Any, jax.Array], jax.Array],
    stage_params: Any,
    x: jax.Array,
    mesh,
    *,
    n_microbatches: int,
    axis: str = "stage",
) -> jax.Array:
    """Run ``stage_fn`` over ``n_stages`` pipeline stages.

    stage_params: pytree whose leaves have leading dim n_stages (stage-major)
        — sharded so each device holds ITS stage's slice.
    x: (batch, ...) global input; batch % n_microbatches == 0.
    Returns the final-stage output with the same global shape as ``x``
    (as transformed by the stages, which must preserve shape).
    """
    n_stages = mesh.shape[axis]
    b = x.shape[0]
    assert b % n_microbatches == 0, (b, n_microbatches)
    mb = b // n_microbatches

    def per_stage(params_s, x_all):
        # params_s: this stage's params (leading stage dim of size 1)
        params_s = jax.tree.map(lambda a: a[0], params_s)
        stage_id = jax.lax.axis_index(axis)
        micro = x_all.reshape(n_microbatches, mb, *x_all.shape[1:])

        n_ticks = n_microbatches + n_stages - 1
        buf = jnp.zeros_like(micro[0])          # activation arriving this tick
        outs = jnp.zeros_like(micro)

        def tick(carry, t):
            buf, outs = carry
            # stage 0 ingests microbatch t from its local input copy
            feed = jnp.where(t < n_microbatches, t, 0)
            inject = micro[feed]
            cur_in = jnp.where(stage_id == 0, inject, buf)
            # compute only when a real microbatch occupies this stage
            live = (t - stage_id >= 0) & (t - stage_id < n_microbatches)
            y = stage_fn(params_s, cur_in)
            y = jnp.where(live, y, jnp.zeros_like(y))
            # last stage records its completed microbatch
            done_idx = jnp.clip(t - (n_stages - 1), 0, n_microbatches - 1)
            record = live & (stage_id == n_stages - 1)
            outs = jax.lax.cond(
                record,
                lambda o: o.at[done_idx].set(y),
                lambda o: o,
                outs)
            # pass activations forward one stage
            nxt = jax.lax.ppermute(
                y, axis, [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (nxt, outs), None

        (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(n_ticks))
        # every stage returns outs; only the last stage's is real — share it
        outs = jax.lax.ppermute(
            outs, axis,
            [( (n_stages - 1 + i) % n_stages, i) for i in range(n_stages)])
        # after permute, every stage holds a copy rotated from the last stage;
        # stage 0's copy is the true result (broadcast convention)
        return outs.reshape(b, *x_all.shape[1:])

    from jax.experimental.shard_map import shard_map
    fn = shard_map(
        per_stage, mesh=mesh,
        in_specs=(P(axis), P()),       # params stage-sharded, x replicated
        out_specs=P(),                 # result replicated
        check_rep=False,
    )
    return fn(stage_params, x)
