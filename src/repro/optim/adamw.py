"""Hand-rolled AdamW (+ global-norm clipping, schedules) — no optax offline.

State layout mirrors the param tree (mu/nu per leaf + scalar step), so the
ZeRO-1 sharding transform in distributed/sharding.py can map param specs to
state specs one-to-one.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "global_norm",
           "clip_by_global_norm", "cosine_schedule", "linear_warmup_cosine"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale).astype(x.dtype), tree), gn


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(lambda x: jnp.zeros_like(x, dtype=jnp.float32), p)
    return {"mu": zeros(params), "nu": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, cfg: AdamWConfig,
                 lr_schedule: Optional[Callable] = None):
    """Returns (new_params, new_state, metrics)."""
    grads, gn = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cfg.lr if lr_schedule is None else lr_schedule(step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32)
        mu_n = b1 * mu + (1 - b1) * g
        nu_n = b2 * nu + (1 - b2) * jnp.square(g)
        mu_hat = mu_n / bc1
        nu_hat = nu_n / bc2
        delta = mu_hat / (jnp.sqrt(nu_hat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu_n, nu_n

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, mu, nu) for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_mu = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_nu = jax.tree.unflatten(treedef, [o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}, {"grad_norm": gn, "lr": lr}


def cosine_schedule(base_lr: float, total_steps: int, final_frac: float = 0.1):
    def fn(step):
        t = jnp.clip(step.astype(jnp.float32) / total_steps, 0.0, 1.0)
        return base_lr * (final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return fn


def linear_warmup_cosine(base_lr: float, warmup: int, total_steps: int,
                         final_frac: float = 0.1):
    cos = cosine_schedule(base_lr, max(total_steps - warmup, 1), final_frac)
    def fn(step):
        s = step.astype(jnp.float32)
        warm = base_lr * s / max(warmup, 1)
        return jnp.where(step <= warmup, warm, cos(step - warmup))
    return fn
