"""COAX core: the paper's contribution as a composable library.

Public API
----------
COAXIndex / CoaxConfig       — the correlation-aware index (paper §3-§6)
learn_soft_fds / SoftFDConfig — soft-FD detection & model learning (§5, Alg. 1)
translate_rect               — query translation (Eq. 2)
GridFile                     — quantile grid file with sorted dim (§6)
FullScan/UniformGrid/ColumnFiles/STRTree — evaluation baselines (§8.1.3)
theory                       — §7 closed forms + simulations
"""
from .types import (
    FDGroup,
    FDPair,
    LinearModel,
    Rect,
    full_rect,
    point_rect,
    rect_contains,
    split_hits,
)
from .softfd import (
    BayesianLinearModel,
    SoftFDConfig,
    bayes_linear_regress,
    bucket_centres,
    detect_soft_fds,
    learn_soft_fds,
    merge_groups,
)
from .translate import (reduced_dims, translate_dependent_interval,
                        translate_rect, translate_rects)
from .gridfile import (BatchStats, GridFile, batched_searchsorted,
                       fit_cells_per_dim, gather_ranges)
from .delta import DeltaPlane
from .baselines import ColumnFiles, FullScan, STRTree, UniformGrid
from .coax import COAXIndex, CoaxConfig
from . import theory

__all__ = [
    "COAXIndex",
    "CoaxConfig",
    "SoftFDConfig",
    "BayesianLinearModel",
    "LinearModel",
    "FDPair",
    "FDGroup",
    "Rect",
    "full_rect",
    "point_rect",
    "rect_contains",
    "split_hits",
    "bucket_centres",
    "bayes_linear_regress",
    "detect_soft_fds",
    "merge_groups",
    "learn_soft_fds",
    "translate_rect",
    "translate_rects",
    "translate_dependent_interval",
    "reduced_dims",
    "GridFile",
    "DeltaPlane",
    "BatchStats",
    "gather_ranges",
    "batched_searchsorted",
    "fit_cells_per_dim",
    "FullScan",
    "UniformGrid",
    "ColumnFiles",
    "STRTree",
    "theory",
]
