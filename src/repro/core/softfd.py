"""Soft functional dependency detection and model learning (paper §5, Alg. 1).

Pipeline
--------
1. ``bucket_centres``     — Algorithm 1's grid bucketing: draw a sample, overlay a
   ``bucket_chunks x bucket_chunks`` grid over an attribute pair, drop sparse
   cells, and return the *weighted centres* of the dense cells.  This is the
   (small) training set for the regression.
2. ``bayes_linear_regress`` — conjugate Bayesian linear regression (ridge) on the
   weighted centres.  The paper uses pymc3; for a linear-Gaussian model the
   posterior mean is available in closed form, and the sufficient statistics
   (X'X, X'y) support the paper's incremental-update story directly.
3. ``fit_pair``           — fit one candidate pair, choose margins from residual
   quantiles, Monte-Carlo stability check (paper: "use a Monte Carlo sampler to
   check whether a linear model fits").
4. ``detect_soft_fds``    — scan all unique ordered pairs, keep predictable ones.
5. ``merge_groups``       — union-find merge of pairs sharing attributes; pick the
   predictor that best explains the rest of its group (paper §5 last step).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .types import FDGroup, FDPair, LinearModel

__all__ = [
    "SoftFDConfig",
    "bucket_centres",
    "bayes_linear_regress",
    "BayesianLinearModel",
    "fit_pair",
    "detect_soft_fds",
    "merge_groups",
    "learn_soft_fds",
]


@dataclasses.dataclass(frozen=True)
class SoftFDConfig:
    """Tuning knobs of Algorithm 1 (paper §5: 'accuracy and runtime of the
    learning step can be adjusted by tuning parameters')."""

    sample_count: int = 32_768      # rows sampled for detection
    bucket_chunks: int = 64         # grid resolution per axis
    cell_threshold: Optional[int] = None  # min hits for a 'dense' cell;
                                    # None -> 2x the uniform-average density
    margin_cover: float = 0.995     # fraction of DENSE rows the margin covers
    max_width_frac: float = 0.35    # accept FD if margin width < frac * range(dep)
    mc_rounds: int = 5              # Monte-Carlo stability fits
    mc_slope_tol: float = 0.25      # max coefficient of variation of the slope
    ridge_lambda: float = 1e-6      # prior precision of the Bayesian regression
    robust_rounds: int = 2          # MAD-trimmed refit rounds after bucket fit
    robust_k: float = 6.0           # trim radius in robust sigmas
    seed: int = 0


# ---------------------------------------------------------------------------
# Step 1: grid bucketing (Algorithm 1, first half)
# ---------------------------------------------------------------------------

def bucket_centres(
    x: np.ndarray,
    d: np.ndarray,
    bucket_chunks: int,
    cell_threshold: Optional[int] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Weighted centres of dense grid cells for the pair (x, d).

    Returns ``(cx, cd, w, dense_rows)``: cell-centre coordinates, their counts,
    and a per-row mask of rows that landed in a dense cell.  Mirrors Algorithm
    1; empty/sparse cells are dropped (paper Fig. 3), which is also what makes
    the margin estimate robust to outlier mass — margins are drawn around the
    dense band, not around stragglers.

    ``cell_threshold=None`` auto-scales to twice the uniform-average density,
    so a 27%-outlier dataset (OSM) still isolates its main trend.
    """
    x = np.asarray(x, dtype=np.float64)
    d = np.asarray(d, dtype=np.float64)
    x_lo, x_hi = float(x.min()), float(x.max())
    d_lo, d_hi = float(d.min()), float(d.max())
    wx = (x_hi - x_lo) / bucket_chunks or 1.0
    wd = (d_hi - d_lo) / bucket_chunks or 1.0

    ix = np.clip(((x - x_lo) / wx).astype(np.int64), 0, bucket_chunks - 1)
    id_ = np.clip(((d - d_lo) / wd).astype(np.int64), 0, bucket_chunks - 1)
    flat = ix * bucket_chunks + id_
    counts = np.bincount(flat, minlength=bucket_chunks * bucket_chunks)

    if cell_threshold is None:
        avg = x.size / float(bucket_chunks * bucket_chunks)
        cell_threshold = max(4, int(2.0 * avg))
    dense_cells = counts > cell_threshold
    if not dense_cells.any():  # fall back: keep every non-empty cell
        dense_cells = counts > 0
    dense = np.nonzero(dense_cells)[0]
    ci = dense // bucket_chunks
    cj = dense % bucket_chunks
    cx = x_lo + (ci + 0.5) * wx
    cd = d_lo + (cj + 0.5) * wd
    return cx, cd, counts[dense].astype(np.float64), dense_cells[flat]


# ---------------------------------------------------------------------------
# Step 2: conjugate Bayesian linear regression
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class BayesianLinearModel:
    """Conjugate Gaussian linear regression with sufficient statistics.

    Prior: weights ~ N(0, (lambda I)^-1).  Posterior mean given weighted data
    is the ridge solution; ``update`` folds in new observations without
    refitting from scratch — this is what makes the index updatable (paper §5:
    'we can use the previous gradient and intersect and continuously adjust
    our existing model').
    """

    xtx: np.ndarray  # (2, 2) accumulated design-matrix Gram
    xty: np.ndarray  # (2,)   accumulated cross moment
    lam: float = 1e-6

    @classmethod
    def empty(cls, lam: float = 1e-6) -> "BayesianLinearModel":
        return cls(np.zeros((2, 2)), np.zeros(2), lam)

    def update(self, x: np.ndarray, y: np.ndarray, w: Optional[np.ndarray] = None) -> None:
        # closed-form Gram sums: the design matrix is [x, 1], so the five
        # moments below ARE Xw.T @ X and Xw.T @ y — no (n, 2) stack/matmul
        # per call (this runs on every insert's drift-tracker update)
        x = np.asarray(x, dtype=np.float64)
        y = np.asarray(y, dtype=np.float64)
        if w is None:
            sw, sx, sxx = float(x.size), float(x.sum()), float(x @ x)
            sy, sxy = float(y.sum()), float(x @ y)
        else:
            w = np.asarray(w, dtype=np.float64)
            wx = w * x
            sw, sx, sxx = float(w.sum()), float(wx.sum()), float(wx @ x)
            sy, sxy = float(w @ y), float(wx @ y)
        self.xtx[0, 0] += sxx
        self.xtx[0, 1] += sx
        self.xtx[1, 0] += sx
        self.xtx[1, 1] += sw
        self.xty[0] += sxy
        self.xty[1] += sy

    def posterior_mean(self) -> Tuple[float, float]:
        # 2x2 ridge solve by Cramer's rule — ``np.linalg.solve`` costs ~40us
        # of LAPACK dispatch per call, and ``drift_predictability`` evaluates
        # every tracker on every amortized trigger check
        a = self.xtx[0, 0] + self.lam
        b = self.xtx[0, 1]
        c = self.xtx[1, 0]
        d = self.xtx[1, 1] + self.lam
        det = a * d - b * c
        if det == 0.0:
            A = self.xtx + self.lam * np.eye(2)
            m, b = np.linalg.solve(A, self.xty)
            return float(m), float(b)
        t0, t1 = self.xty[0], self.xty[1]
        return float((d * t0 - b * t1) / det), float((a * t1 - c * t0) / det)


def bayes_linear_regress(
    x: np.ndarray, y: np.ndarray, w: Optional[np.ndarray] = None, lam: float = 1e-6
) -> Tuple[float, float]:
    """One-shot weighted Bayesian-ridge fit; returns (slope, intercept)."""
    blm = BayesianLinearModel.empty(lam)
    blm.update(x, y, w)
    return blm.posterior_mean()


# ---------------------------------------------------------------------------
# Step 3: fit one candidate pair with margins + Monte-Carlo stability check
# ---------------------------------------------------------------------------

def _margins_from_residuals(resid: np.ndarray, cover: float) -> Tuple[float, float]:
    """Asymmetric margins (eps_lb, eps_ub) covering ``cover`` of residuals.

    The margins are the paper's two parallel separator lines (Fig. 1): the
    tightest [lo, hi] quantile band of the displacement distribution that keeps
    ``cover`` of the rows in the primary index.
    """
    alpha = 1.0 - cover
    lo = np.quantile(resid, alpha / 2.0)
    hi = np.quantile(resid, 1.0 - alpha / 2.0)
    # eps_lb is a magnitude (inlier iff resid >= -eps_lb), so negate lo.
    eps_lb = max(-float(lo), 0.0)
    eps_ub = max(float(hi), 0.0)
    # Never emit an exactly-zero band: float32 data needs breathing room.
    span = float(resid.max() - resid.min()) or 1.0
    pad = 1e-7 * span
    return eps_lb + pad, eps_ub + pad


def fit_pair(
    x: np.ndarray,
    d: np.ndarray,
    cfg: SoftFDConfig,
    rng: np.random.Generator,
) -> Optional[Tuple[LinearModel, float, float]]:
    """Fit ``d ~ m x + b`` on bucketed centres; return (model, score, inlier_frac).

    Returns None when the pair fails the Monte-Carlo stability check or the
    predictability (width) criterion — i.e., no usable soft FD.
    """
    cx, cd, w, dense_rows = bucket_centres(x, d, cfg.bucket_chunks, cfg.cell_threshold)
    if cx.size < 4:
        return None
    m, b = bayes_linear_regress(cx, cd, w, cfg.ridge_lambda)

    d_range = float(d.max() - d.min())
    x_range = float(x.max() - x.min())
    if d_range == 0.0 or x_range == 0.0:
        return None  # constant attribute: trivially dependent, nothing to index
    # A near-flat model cannot translate dependent-attribute constraints into
    # selective predictor ranges (S-box base ~ (q + 2eps)/|m| -> inf).
    if abs(m) * x_range < 1e-3 * d_range:
        return None

    # Monte-Carlo stability: refit on random half-samples of the centres and
    # require a stable slope (coefficient of variation below tolerance).
    slopes = []
    for _ in range(cfg.mc_rounds):
        take = rng.random(cx.size) < 0.5
        if take.sum() < 4:
            continue
        mi, _ = bayes_linear_regress(cx[take], cd[take], w[take], cfg.ridge_lambda)
        slopes.append(mi)
    if len(slopes) >= 2:
        s = np.asarray(slopes)
        scale = max(abs(m), 1e-12)
        if float(s.std() / scale) > cfg.mc_slope_tol:
            return None

    # Margins from the residuals of DENSE-cell rows only (Fig. 3: the margin is
    # set by 'the density of the data records around the model'); sparse-cell
    # rows are exactly the outliers the margin should NOT chase.  On top of the
    # bucket filter, a couple of MAD-trimmed refits remove dense-but-off-trend
    # bands (e.g. OSM bulk-import timestamp rows) that survive any fixed cell
    # threshold — robust regression in the paper's 'Bayesian method' spirit.
    resid = d - (m * x + b)
    sel = dense_rows
    for _ in range(cfg.robust_rounds):
        r = resid[sel]
        if r.size < 16:
            break
        med = float(np.median(r))
        mad = float(np.median(np.abs(r - med))) * 1.4826 + 1e-12
        keep = np.abs(resid - med) < cfg.robust_k * mad
        new_sel = dense_rows & keep
        if new_sel.sum() < 16:
            break
        sel = new_sel
        m, b = bayes_linear_regress(x[sel], d[sel], lam=cfg.ridge_lambda)
        resid = d - (m * x + b)
    # Margins cover every row inside the robust band (not only dense-cell
    # rows): the bucket filter is a FIT robustness device; restricting the
    # margin to dense cells would under-cover heavy-tailed-but-legitimate
    # residual mass and needlessly inflate the outlier index.
    r_sel = resid[sel]
    if r_sel.size < 4:
        return None
    med = float(np.median(r_sel))
    mad = float(np.median(np.abs(r_sel - med))) * 1.4826 + 1e-12
    in_band = np.abs(resid - med) < cfg.robust_k * mad
    resid_band = resid[in_band]
    if resid_band.size < 4:
        return None
    eps_lb, eps_ub = _margins_from_residuals(resid_band, cfg.margin_cover)
    model = LinearModel(m=m, b=b, eps_lb=eps_lb, eps_ub=eps_ub)
    width = model.width
    score = width / d_range
    if score > cfg.max_width_frac:
        return None
    inlier_frac = float(model.inlier_mask(x, d).mean())
    return model, score, inlier_frac


# ---------------------------------------------------------------------------
# Steps 4-5: detect over all pairs, merge into groups, pick predictors
# ---------------------------------------------------------------------------

def detect_soft_fds(
    data: np.ndarray,
    cfg: SoftFDConfig = SoftFDConfig(),
    candidate_dims: Optional[Sequence[int]] = None,
) -> List[FDPair]:
    """Scan unique attribute pairs of a sample for soft FDs (paper §5)."""
    rng = np.random.default_rng(cfg.seed)
    n, n_dims = data.shape
    dims = list(candidate_dims) if candidate_dims is not None else list(range(n_dims))

    take = rng.choice(n, size=min(cfg.sample_count, n), replace=False)
    sample = np.asarray(data[take], dtype=np.float64)

    pairs: List[FDPair] = []
    for i, j in itertools.combinations(dims, 2):
        # Try both directions; keep the more predictable one (smaller width).
        best: Optional[FDPair] = None
        for pred, dep in ((i, j), (j, i)):
            out = fit_pair(sample[:, pred], sample[:, dep], cfg, rng)
            if out is None:
                continue
            model, score, frac = out
            cand = FDPair(pred=pred, dep=dep, model=model, score=score, inlier_frac=frac)
            if best is None or cand.score < best.score:
                best = cand
        if best is not None:
            pairs.append(best)
    return pairs


class _UnionFind:
    def __init__(self, n: int):
        self.parent = list(range(n))

    def find(self, a: int) -> int:
        while self.parent[a] != a:
            self.parent[a] = self.parent[self.parent[a]]
            a = self.parent[a]
        return a

    def union(self, a: int, b: int) -> None:
        ra, rb = self.find(a), self.find(b)
        if ra != rb:
            self.parent[rb] = ra


def merge_groups(
    pairs: Sequence[FDPair],
    data: np.ndarray,
    cfg: SoftFDConfig = SoftFDConfig(),
) -> List[FDGroup]:
    """Union-find merge of FD pairs; one predictor per group (paper §5).

    The predictor of a group is the member attribute whose models to every
    other member have the smallest total normalised width — i.e., the best
    single explainer.  Models predictor->dependent are then (re)fit on a data
    sample for each dependent.

    Over-merge recovery: union-find is transitive, so a single weak bridge
    pair (e.g. minted by a burst of FD-violating rows inflating a column's
    range) can fuse two unrelated groups into one component that no single
    predictor explains.  When that happens we keep the best sub-star and
    requeue the unexplained members as their own component rather than
    silently dropping them — so a ~1% contamination burst costs at most the
    bridge pair, never a whole group's worth of eliminated dims
    (DESIGN.md §5.2).
    """
    if not pairs:
        return []
    n_dims = data.shape[1]
    uf = _UnionFind(n_dims)
    in_any = set()
    for p in pairs:
        uf.union(p.pred, p.dep)
        in_any.add(p.pred)
        in_any.add(p.dep)

    members: Dict[int, List[int]] = {}
    for a in sorted(in_any):
        members.setdefault(uf.find(a), []).append(a)

    rng = np.random.default_rng(cfg.seed + 1)
    n = data.shape[0]
    take = rng.choice(n, size=min(cfg.sample_count, n), replace=False)
    sample = np.asarray(data[take], dtype=np.float64)

    groups: List[FDGroup] = []
    work: List[List[int]] = [mem for mem in members.values() if len(mem) >= 2]
    while work:
        mem = work.pop(0)
        # Score each candidate predictor by the total width of its models.
        best_pred, best_cost, best_models = -1, np.inf, None
        for pred in mem:
            cost = 0.0
            models: Dict[int, LinearModel] = {}
            ok = True
            for dep in mem:
                if dep == pred:
                    continue
                out = fit_pair(sample[:, pred], sample[:, dep], cfg, rng)
                if out is None:
                    ok = False
                    break
                model, score, _ = out
                models[dep] = model
                cost += score
            if ok and cost < best_cost:
                best_pred, best_cost, best_models = pred, cost, models
        if best_models is None:
            # No single predictor covers the whole component: the union-find
            # over-merged on a weak bridge pair.  Keep the best sub-star
            # (largest; total width breaks ties) and requeue the unexplained
            # members so their own group survives the bridge.
            star: Dict[int, Dict[int, LinearModel]] = {}
            star_cost: Dict[int, float] = {}
            for pred in mem:
                models = {}
                cost = 0.0
                for dep in mem:
                    if dep == pred:
                        continue
                    out = fit_pair(sample[:, pred], sample[:, dep], cfg, rng)
                    if out is not None:
                        models[dep] = out[0]
                        cost += out[1]
                if models:
                    star[pred] = models
                    star_cost[pred] = cost
            if not star:
                continue
            best_pred = max(star, key=lambda p: (len(star[p]), -star_cost[p]))
            best_models = star[best_pred]
            leftover = [a for a in mem
                        if a != best_pred and a not in best_models]
            if len(leftover) >= 2:
                work.append(leftover)
        groups.append(
            FDGroup(
                predictor=best_pred,
                dependents=tuple(sorted(best_models)),
                models=best_models,
            )
        )
    return groups


def learn_soft_fds(
    data: np.ndarray,
    cfg: SoftFDConfig = SoftFDConfig(),
    candidate_dims: Optional[Sequence[int]] = None,
) -> List[FDGroup]:
    """End-to-end: detect pairs, merge into predictor groups.

    Degenerate inputs (fewer rows than a bucket fit can support — empty
    shards of a partitioned build, freshly emptied indexes) learn nothing:
    every dim stays indexed and the caller's primary grid holds all rows.
    """
    if data.shape[0] < 8:
        return []
    pairs = detect_soft_fds(data, cfg, candidate_dims)
    return merge_groups(pairs, data, cfg)
