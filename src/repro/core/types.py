"""Core value types shared across the COAX index stack.

Conventions
-----------
* A *dataset* is a float32 ndarray of shape (N, D): N records, D attributes.
* A *rect* (query rectangle) is a float ndarray of shape (D, 2): column 0 is the
  inclusive-exclusive lower bound, column 1 the upper bound, i.e. the query is
  ``lo <= x < hi`` per dimension... the paper uses open ranges ``lo < x < hi``;
  we standardise on half-open ``lo <= x < hi`` which composes cleanly with
  ``searchsorted`` semantics and makes point queries expressible as
  ``[v, nextafter(v)]``.  Unconstrained dimensions use ``(-inf, +inf)``.
* Batched queries are (Q, D, 2).
* Query answers are sorted int64 arrays of *original row ids* so result-set
  equality across engines is exact set equality.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "LinearModel",
    "FDPair",
    "FDGroup",
    "Rect",
    "full_rect",
    "point_rect",
    "rect_contains",
    "sorted_contains",
    "split_hits",
    "validate_rect",
]

Rect = np.ndarray  # (D, 2)


@dataclasses.dataclass(frozen=True)
class LinearModel:
    """A soft-FD model ``dep ~= m * pred + b`` with asymmetric error margins.

    Inlier condition (Eq. 1 of the paper):
        ``-eps_lb <= dep - (m * pred + b) <= eps_ub``
    """

    m: float
    b: float
    eps_lb: float
    eps_ub: float

    def predict(self, x):
        return self.m * x + self.b

    def displacement(self, x, d):
        """Residual of ``d`` against the model prediction at ``x``."""
        return d - (self.m * x + self.b)

    def inlier_mask(self, x, d):
        r = self.displacement(x, d)
        return (r >= -self.eps_lb) & (r <= self.eps_ub)

    @property
    def width(self) -> float:
        return float(self.eps_lb + self.eps_ub)


@dataclasses.dataclass(frozen=True)
class FDPair:
    """A detected soft functional dependency ``pred -> dep``."""

    pred: int
    dep: int
    model: LinearModel
    score: float          # normalised margin width; lower = more predictable
    inlier_frac: float    # fraction of the detection sample inside the margin


@dataclasses.dataclass
class FDGroup:
    """A merged group of correlated attributes with one predictor.

    ``models[d]`` maps the predictor's value to dependent attribute ``d``.
    """

    predictor: int
    dependents: Tuple[int, ...]
    models: Dict[int, LinearModel]

    def inlier_mask(self, data: np.ndarray) -> np.ndarray:
        """Rows satisfying *every* dependent's margin in this group."""
        x = data[:, self.predictor]
        mask = np.ones(data.shape[0], dtype=bool)
        for d in self.dependents:
            mask &= self.models[d].inlier_mask(x, data[:, d])
        return mask


def full_rect(n_dims: int) -> Rect:
    r = np.empty((n_dims, 2), dtype=np.float64)
    r[:, 0] = -np.inf
    r[:, 1] = np.inf
    return r


def point_rect(point: np.ndarray) -> Rect:
    """A degenerate rectangle matching exactly ``point`` (paper §8.1.2)."""
    p = np.asarray(point, dtype=np.float64)
    return np.stack([p, np.nextafter(p, np.inf)], axis=-1)


def rect_contains(rect: Rect, data: np.ndarray) -> np.ndarray:
    """Boolean mask of rows of ``data`` inside ``rect`` (half-open per dim)."""
    lo, hi = rect[:, 0], rect[:, 1]
    return np.all((data >= lo) & (data < hi), axis=-1)


def sorted_contains(haystack: np.ndarray, values: np.ndarray) -> np.ndarray:
    """Membership mask of ``values`` in a SORTED ``haystack``.

    ``np.isin`` re-sorts the larger operand on every call — O(n log n) per
    lookup against a 50k-id base array; binary search against the already-
    sorted array is O(m log n), the difference between the write path
    scaling with the base size or not (DESIGN.md §5.1).
    """
    values = np.asarray(values)
    if haystack.size == 0 or values.size == 0:
        return np.zeros(values.shape, dtype=bool)
    pos = np.searchsorted(haystack, values)
    pos[pos == haystack.size] = haystack.size - 1
    return haystack[pos] == values


def split_hits(qids: np.ndarray, row_ids: np.ndarray,
               n_queries: int) -> List[np.ndarray]:
    """Flat (query_id, row_id) hit list -> one row-id array per query.

    ``qids`` must be sorted ascending (the ``query_batch`` contract).
    """
    bounds = np.searchsorted(qids, np.arange(n_queries + 1))
    return [row_ids[bounds[i]:bounds[i + 1]] for i in range(n_queries)]


def validate_rect(rect: Rect, n_dims: int) -> Rect:
    rect = np.asarray(rect, dtype=np.float64)
    if rect.shape != (n_dims, 2):
        raise ValueError(f"rect must be ({n_dims}, 2), got {rect.shape}")
    if np.any(rect[:, 0] > rect[:, 1]):
        raise ValueError("rect has lo > hi")
    return rect
