"""The COAX index (paper §3, Fig. 1): soft-FD learning + query translation +
primary index on reduced dims + full-dimensional outlier index.

Build path (``COAXIndex.fit``):
  1. learn soft-FD groups from a sample (Alg. 1; ``softfd.learn_soft_fds``);
  2. split rows: every group's margins satisfied -> primary, else -> outlier
     (Alg. 1, second half);
  3. primary = grid file over only the INDEXED dims (non-dependents) with an
     in-cell sorted dim -> ``n - m - 1`` grid dimensions (§6);
  4. outliers = an ordinary full-dimensional multidimensional index (§3:
     'a typical multidimensional index structure') — quantile grid here.

Query path (``COAXIndex.query``):
  translate the rect onto indexed dims (Eq. 2), probe the primary with the
  translated nav-rect plus the ORIGINAL full predicate, probe the outlier
  index with the original rect, union row ids.  §8.2.3's optimisation is
  applied: each sub-index is only invoked when the query can intersect it.

Write path (DESIGN.md §5): the two grid files are *epoch-versioned frozen
snapshots*; ``insert``/``delete`` land in per-sub-index ``DeltaPlane``s
(append log + tombstones, organized into tiered sorted runs, §5.3) and
every query unions (snapshot − tombstones) ∪ delta.  Inserts are
margin-checked against the learned FD groups — in-margin rows feed the
primary delta, violators the outlier delta — and stream into per-model
``BayesianLinearModel`` trackers so FD drift is measured from live
sufficient statistics (§5: 'continuously adjust our existing model').
``compact()`` merges deltas into rebuilt snapshots and bumps the epoch; it
fires automatically on delta size, or on drift when the §7.2 predictability
ratio (``theory.met_drifted_expectation``) says the frozen slopes have
decayed.  Trigger evaluation is amortized (every ``compact_check_rows``
written rows or on an L0 spill — ``maybe_compact``), and with
``background_compact`` the rebuild itself moves off the serving thread:
``_begin_background_compact`` freezes the live row set and builds the next
epoch on a daemon thread while the old epoch keeps serving; ``poll_handoff``
installs the finished build at the next write/query/wave boundary and
replays the writes admitted during the build into the new epoch (the
epoch-handoff state machine, DESIGN.md §5.4).

Durability (DESIGN.md §7): ``attach_durability`` hooks a ``storage``
durability plane onto the write path — every ``insert``/``delete`` appends
one frame to an epoch-stamped write-ahead log before mutating memory, and
``compact`` rotates the log under a fresh epoch snapshot.  ``save`` writes
a one-shot full-state snapshot (delta planes and drift trackers included);
``restore`` loads the newest complete snapshot and replays the WAL tail
through these same write paths, yielding an index bit-identical to the
never-crashed one on every backend.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from . import theory
from .delta import DeltaPlane
from .gridfile import BatchStats, GridFile, fit_cells_per_dim
from .softfd import BayesianLinearModel, SoftFDConfig, learn_soft_fds
from .translate import reduced_dims, translate_rect, translate_rects
from .types import (FDGroup, Rect, full_rect, rect_contains, sorted_contains,
                    split_hits)

__all__ = ["CoaxConfig", "COAXIndex"]


@dataclasses.dataclass(frozen=True)
class CoaxConfig:
    softfd: SoftFDConfig = SoftFDConfig()
    primary_cells_per_dim: Optional[int] = None   # None -> auto from rows_per_cell
    outlier_cells_per_dim: Optional[int] = None
    sort_dim: Optional[int] = None                # None -> auto (widest kept dim)
    rows_per_cell: int = 256                      # target cell occupancy (sweet
                                                  # spot lever, paper Fig. 8)
    directory_budget_frac: float = 1.0            # directory <= frac * data bytes

    # --- mutable lifecycle (DESIGN.md §5) ------------------------------- #
    auto_compact: bool = True        # insert/delete check triggers themselves
    compact_delta_frac: float = 0.25  # size trigger: delta load > frac * base
    compact_min_delta: int = 1024     # ... and at least this many delta entries
    drift_threshold: float = 0.5      # compact+relearn when the §7.2
                                      # predictability ratio drops below this
    drift_min_delta: int = 256        # drift trigger needs this much fresh data
    drift_seed_rows: int = 4096       # rows seeding the live FD trackers
    drift_track_k: float = 6.0        # slope trackers only ingest rows within
                                      # the margin band expanded by k*width —
                                      # gross violators feed the violation-MASS
                                      # statistic instead (mirrors robust_k)

    # --- LSM write path (DESIGN.md §5.3–§5.4) --------------------------- #
    background_compact: bool = False  # build the next epoch on a daemon
                                      # thread, swap at an atomic handoff
    compact_check_rows: int = 64      # amortize trigger checks: evaluate
                                      # once per this-many written rows (or
                                      # on an L0 spill), not every write
    delta_l0_spill: int = 256         # delta L0 rows that spill into a
                                      # sorted run (§5.3)


class COAXIndex:
    name = "coax"

    def __init__(self, data: np.ndarray, config: CoaxConfig = CoaxConfig(),
                 groups: Optional[Sequence[FDGroup]] = None,
                 backend: str = "numpy",
                 device_opts: Optional[dict] = None,
                 row_ids: Optional[np.ndarray] = None):
        """Build the index.  ``groups`` may be supplied to skip detection
        (e.g. when the DBA already knows the FDs, or from a previous fit).

        ``backend="device"`` routes ``query_batch`` through the frozen
        device plans of both sub-grids (DESIGN.md §4); numpy stays the
        default and the correctness oracle.

        ``row_ids`` assigns the original identities of ``data`` rows
        (defaults to ``arange(N)``); a scratch rebuild of a mutated index
        passes the surviving ids here so result sets stay comparable.
        """
        self.config = config
        self.data = np.ascontiguousarray(data, dtype=np.float32)
        self.n_dims = self.data.shape[1]
        self.row_ids = (np.arange(self.data.shape[0], dtype=np.int64)
                        if row_ids is None
                        else np.asarray(row_ids, dtype=np.int64).copy())
        if self.row_ids.shape[0] != self.data.shape[0]:
            raise ValueError("row_ids length must match data rows")
        self._next_id = int(self.row_ids.max()) + 1 if self.row_ids.size else 0
        self.epoch = 0
        self.compactions = 0
        self.groups: List[FDGroup] = (
            list(groups) if groups is not None else learn_soft_fds(self.data, config.softfd)
        )
        self.keep_dims = reduced_dims(self.n_dims, self.groups)
        self._device_opts = device_opts
        self._coax_plan = None          # engine.device.CoaxDevicePlan (lazy)
        self._device_plan_failed = False
        self.last_batch_stats = BatchStats()
        self.durable = None             # storage.Durability, via attach_durability
        self._init_write_state()
        self._fit()
        self.backend = backend

    def _init_write_state(self) -> None:
        """Amortized-trigger counters + background-handoff machinery
        (DESIGN.md §5.3–§5.4), fresh — shared by build and restore."""
        self._write_units = 0           # rows written since the last check
        self._spill_pending = False     # an L0 spill since the last check
        self.trigger_checks = 0         # full trigger evaluations ever run
        self.background_compactions = 0  # handoffs installed
        self.last_handoff_s = 0.0       # build-start → install latency
        self._handoff_t0 = 0.0
        self._handoff_thread = None     # the in-flight compactor thread
        self._handoff_result = None     # [None] | [("ok", fitted, relearned)]
        self._handoff_ops = None        # writes admitted during the build
        self._in_handoff_replay = False
        self._last_compact_relearned = False
        self._viol_total = {}           # per-group arriving-row counts and
        self._viol_bad = {}             # margin violations since tracker reseed
        self.cache = None               # engine.cache.SemanticCache (§9.2)
        self.last_cache_stats = None    # CacheLookup of the latest wave
        self._pins = {}                 # epoch -> live EpochPin count (§9.3)
        self._id_order_cache = None     # (argsort, sorted ids) of row_ids

    # ------------------------------------------------------------------ #
    @property
    def backend(self) -> str:
        return self.primary.backend

    @backend.setter
    def backend(self, value: str) -> None:
        self.primary.backend = value
        self.outlier.backend = value

    @property
    def n_rows(self) -> int:
        """LIVE row count: snapshot rows − tombstones + live delta rows."""
        return (self.data.shape[0]
                - self.delta_primary.n_base_dead - self.delta_outlier.n_base_dead
                + self.delta_primary.n_live + self.delta_outlier.n_live)

    @property
    def delta_rows(self) -> int:
        """Live (not yet compacted) inserted rows across both delta planes."""
        return self.delta_primary.n_live + self.delta_outlier.n_live

    @property
    def tombstone_count(self) -> int:
        return self.delta_primary.n_tombstones + self.delta_outlier.n_tombstones

    # ------------------------------------------------------------------ #
    def _fit(self) -> None:
        self._install_fit(self._fit_state(self.data, self.row_ids,
                                          self.groups, self.epoch))

    def _fit_state(self, data: np.ndarray, row_ids: np.ndarray,
                   groups: Sequence[FDGroup], epoch: int) -> dict:
        """Pure fit: build both epoch grids, the base id partitions, the
        §8.2.3 bbox and the tracker seeds for ``data`` under ``groups``,
        stamped ``epoch`` — NO self mutation.  Reads only immutable config,
        so the §5.4 background compactor thread can run it against a frozen
        row set while the serving thread keeps answering from the old epoch
        (``_install_fit`` is the serving-thread half of the handoff)."""
        cfg = self.config
        n = data.shape[0]
        n_dims = data.shape[1]
        keep_dims = reduced_dims(n_dims, groups)
        # Split into primary (all groups' margins hold) and outliers.
        inlier = np.ones(n, dtype=bool)
        for g in groups:
            inlier &= g.inlier_mask(data)
        primary_ratio = float(inlier.mean()) if n else 0.0

        p_rows, p_ids = data[inlier], row_ids[inlier]
        o_rows, o_ids = data[~inlier], row_ids[~inlier]

        # Sorted dim: the kept dim with the widest normalised spread by
        # default — maximises the benefit of in-cell binary search.
        if cfg.sort_dim is not None:
            sort_dim = cfg.sort_dim
        elif n:
            spread = [
                float(np.std(data[:, d])) / (float(np.ptp(data[:, d])) or 1.0)
                for d in keep_dims
            ]
            sort_dim = keep_dims[int(np.argmax(spread))] if keep_dims else 0
        else:
            sort_dim = keep_dims[0] if keep_dims else 0

        budget_cells = max(int(data.nbytes * cfg.directory_budget_frac) // 8, 1)
        n_grid = max(len(keep_dims) - 1, 0)
        target = max(int(p_rows.shape[0] / cfg.rows_per_cell), 1)
        auto = max(int(round(target ** (1.0 / max(n_grid, 1)))), 2)
        p_cells = cfg.primary_cells_per_dim or min(
            auto, fit_cells_per_dim(max(n_grid, 1), budget_cells))
        primary = GridFile(
            p_rows, index_dims=keep_dims, cells_per_dim=p_cells,
            sort_dim=sort_dim if keep_dims else None, quantile=True, row_ids=p_ids,
            device_opts=self._device_opts, epoch=epoch,
        )

        # Outlier index: full-dimensional quantile grid with its own (much
        # smaller) budget — outliers are typically a few % of rows.
        o_budget = max(int(o_rows.nbytes * cfg.directory_budget_frac) // 8, 1)
        o_target = max(int(o_rows.shape[0] / cfg.rows_per_cell), 1)
        o_auto = max(int(round(o_target ** (1.0 / max(n_dims - 1, 1)))), 2)
        o_cells = cfg.outlier_cells_per_dim or min(
            o_auto, fit_cells_per_dim(max(n_dims - 1, 1), o_budget))
        outlier = GridFile(
            o_rows, index_dims=list(range(n_dims)), cells_per_dim=o_cells,
            sort_dim=sort_dim, quantile=True, row_ids=o_ids,
            device_opts=self._device_opts, epoch=epoch,
        )

        trackers, x_scale = self._seed_tracker_state(groups, p_rows)
        return {
            "data": data, "row_ids": row_ids, "epoch": epoch,
            "groups": list(groups), "keep_dims": keep_dims,
            "primary_ratio": primary_ratio,
            "primary": primary, "outlier": outlier,
            # §8.2.3: outlier bbox lets queries skip the outlier probe
            "outlier_lo": o_rows.min(axis=0) if o_rows.shape[0] else None,
            "outlier_hi": o_rows.max(axis=0) if o_rows.shape[0] else None,
            # sorted base id partitions (delete classification)
            "base_primary_ids": np.sort(p_ids),
            "base_outlier_ids": np.sort(o_ids),
            "trackers": trackers, "x_scale": x_scale,
        }

    def _install_fit(self, fitted: dict) -> None:
        """Adopt a ``_fit_state`` result as the CURRENT epoch — the atomic
        serving-thread half of the §5.4 handoff.  Swapping ``primary`` /
        ``outlier`` is what invalidates any frozen device plan (identity
        check in ``_device_plan_obj``); fresh delta planes are keyed on the
        new groups' first dependent (``_delta_key_dim``).  The stale device
        plan is deliberately KEPT on ``_coax_plan``: the identity check in
        ``_device_plan_obj`` rebuilds against the new grids on the next
        wave, and the rebuild ``adopt()``s the stale plan's jit cache so a
        compaction costs zero recompiles (pow2-bucketed image shapes)."""
        self.data = fitted["data"]
        self.row_ids = fitted["row_ids"]
        self.epoch = int(fitted["epoch"])
        self.groups = fitted["groups"]
        self.keep_dims = fitted["keep_dims"]
        self.primary_ratio = fitted["primary_ratio"]
        self.primary = fitted["primary"]
        self.outlier = fitted["outlier"]
        self._outlier_lo = fitted["outlier_lo"]
        self._outlier_hi = fitted["outlier_hi"]
        self._base_primary_ids = fitted["base_primary_ids"]
        self._base_outlier_ids = fitted["base_outlier_ids"]
        self._fd_trackers = fitted["trackers"]
        self._x_scale = fitted["x_scale"]
        # violation-mass counters restart with the reseeded trackers: the
        # new margins absorbed (or re-rejected) the old epoch's violators
        self._viol_total = {gi: 0 for gi in range(len(self.groups))}
        self._viol_bad = {gi: 0 for gi in range(len(self.groups))}
        kd, spill = self._delta_key_dim(), self.config.delta_l0_spill
        self.delta_primary = DeltaPlane(self.n_dims, key_dim=kd, l0_spill=spill)
        self.delta_outlier = DeltaPlane(self.n_dims, key_dim=kd, l0_spill=spill)
        # the id->row gather index follows the snapshot arrays (§9.2); any
        # attached SemanticCache survives the swap untouched — its entries
        # are keyed on the pre-swap version and simply never match again,
        # and live EpochPins (§9.3) hold their own refs to the old epoch
        self._id_order_cache = None

    def _delta_key_dim(self) -> int:
        """Run key for the delta planes (DESIGN.md §5.3): the first FD
        dependent (Eq. 2 maps query ranges onto dependents, so key windows
        stay selective), else the primary's sort dim.  Derived from the
        current groups — never serialized — so live, restored and replica
        planes agree by construction."""
        for g in self.groups:
            for dep in g.dependents:
                return int(dep)
        sd = getattr(self.primary, "sort_dim", None) if hasattr(self, "primary") else None
        return int(sd) if sd is not None else 0

    def _seed_tracker_state(self, groups: Sequence[FDGroup],
                            inlier_rows: np.ndarray):
        """Per-(group, dependent) live Bayesian models, seeded from a sample
        of the snapshot's IN-MARGIN rows so the posterior slope starts at the
        frozen trend (outlier mass would bias the seed away from the robust
        fit and fake drift at epoch start).  Pure: returns (trackers,
        x_scale) without touching self."""
        cfg = self.config
        n = inlier_rows.shape[0]
        rng = np.random.default_rng(cfg.softfd.seed + 2)
        take = (rng.choice(n, size=min(cfg.drift_seed_rows, n), replace=False)
                if n else np.empty(0, np.int64))
        sample = inlier_rows[take].astype(np.float64)
        trackers: Dict[Tuple[int, int], BayesianLinearModel] = {}
        x_scale: Dict[int, float] = {}
        for gi, g in enumerate(groups):
            x = sample[:, g.predictor] if sample.size else np.empty(0)
            x_scale[gi] = float(np.std(x)) if x.size else 1.0
            for dep in g.dependents:
                blm = BayesianLinearModel.empty(cfg.softfd.ridge_lambda)
                if x.size:
                    blm.update(x, sample[:, dep])
                trackers[(gi, dep)] = blm
        return trackers, x_scale

    # ------------------------------------------------------------------ #
    # Write path (DESIGN.md §5)
    # ------------------------------------------------------------------ #
    def insert(self, rows: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        """Insert rows; returns their assigned original row ids.

        Each row is margin-checked against every learned FD group: rows
        satisfying all margins land in the primary delta, violators in the
        outlier delta (the write-time mirror of the build-time split).  All
        inserts stream into the live ``BayesianLinearModel`` trackers so
        ``drift_predictability`` reflects the data actually arriving.

        ``ids`` lets an owning plane (``engine.sharded.ShardedCOAX``) assign
        ids from a GLOBAL sequence so they stay unique across shards; the
        caller is responsible for never reusing an id.  Default: the index's
        own ``arange`` sequence.
        """
        self._poll_entry()
        rows = np.ascontiguousarray(np.atleast_2d(np.asarray(rows, dtype=np.float32)))
        if rows.ndim != 2 or rows.shape[1] != self.n_dims:
            raise ValueError(f"rows must be (m, {self.n_dims}), got {rows.shape}")
        m = rows.shape[0]
        if ids is None:
            ids = np.arange(self._next_id, self._next_id + m, dtype=np.int64)
            self._next_id += m
        else:
            ids = np.asarray(ids, dtype=np.int64).copy()
            if ids.shape[0] != m:
                raise ValueError("ids length must match rows")
            if m:
                self._next_id = max(self._next_id, int(ids.max()) + 1)
        if m == 0:
            return ids
        if self.durable is not None:    # WAL before memory (DESIGN.md §7.2)
            self.durable.log_insert(rows, ids)
        if self._handoff_ops is not None and not self._in_handoff_replay:
            # a background build is in flight: remember the op so the new
            # epoch can replay it after the handoff (DESIGN.md §5.4)
            self._handoff_ops.append(("i", rows, ids.copy()))
        inlier = np.ones(m, dtype=bool)
        for gi, g in enumerate(self.groups):
            gm = g.inlier_mask(rows)
            # violation MASS per group: the contamination-vs-drift statistic
            # (``drift_predictability``) — a minority of gross violators is
            # outlier-plane work, a majority is a regime change
            self._viol_total[gi] += m
            self._viol_bad[gi] += int(m - gm.sum())
            inlier &= gm
        spilled = self.delta_primary.insert(rows[inlier], ids[inlier])
        spilled += self.delta_outlier.insert(rows[~inlier], ids[~inlier])
        x64 = rows.astype(np.float64)
        k = self.config.drift_track_k
        for (gi, dep), blm in self._fd_trackers.items():
            g = self.groups[gi]
            model = g.models[dep]
            x, d = x64[:, g.predictor], x64[:, dep]
            # robust slope tracking: only rows within the margin band
            # expanded by k*width update the posterior — gross violators
            # would drag the slope and fake drift (they are contamination,
            # measured by the mass counters above, not slope movement)
            slack = k * max(model.width, 1e-12)
            r = d - (model.m * x + model.b)
            band = (r >= -model.eps_lb - slack) & (r <= model.eps_ub + slack)
            if band.any():
                blm.update(x[band], d[band])
        self._write_units += m
        if spilled:
            self._spill_pending = True
        if self.config.auto_compact:
            self.maybe_compact()
        return ids

    def delete(self, row_ids) -> int:
        """Delete rows by original id; returns how many live rows died.

        Ids living in a delta log are tombstoned there; ids frozen into the
        snapshot are classified primary/outlier and tombstoned in the
        matching plane (so each sub-index's hits are masked by exactly its
        own plane).  Unknown or already-dead ids are ignored.
        """
        self._poll_entry()
        ids = np.unique(np.asarray(row_ids, dtype=np.int64).reshape(-1))
        if ids.size == 0:
            return 0
        if self.durable is not None:    # WAL before memory (DESIGN.md §7.2)
            self.durable.log_delete(ids)
        if self._handoff_ops is not None and not self._in_handoff_replay:
            self._handoff_ops.append(("d", ids.copy()))
        self._write_units += int(ids.size)
        removed = 0
        absorbed = self.delta_primary.tombstone_log(ids)
        removed += int(absorbed.sum())
        ids = ids[~absorbed]
        absorbed = self.delta_outlier.tombstone_log(ids)
        removed += int(absorbed.sum())
        ids = ids[~absorbed]
        # base id arrays are sorted (``_fit_state``): binary-search
        # membership instead of ``isin`` re-sorting 50k ids per delete
        in_p = sorted_contains(self._base_primary_ids, ids)
        removed += self.delta_primary.tombstone_base(ids[in_p])
        rest = ids[~in_p]
        in_o = sorted_contains(self._base_outlier_ids, rest)
        removed += self.delta_outlier.tombstone_base(rest[in_o])
        if self.config.auto_compact:
            self.maybe_compact()
        return removed

    # ------------------------------------------------------------------ #
    def drift_predictability(self) -> float:
        """§7.2 predictability of the frozen models against live statistics
        (the drift-vs-contamination statistic, DESIGN.md §5.2).

        For each (group, dependent) model, the live posterior slope's
        mismatch ``d = |m_live − m_frozen| · std(x)`` is scored with the
        drifted mean-exit-time ratio
        ``met_drifted_expectation(ε, σ, d) / met_expectation(ε, σ)``
        (= tanh(u)/u, u = εd/σ²) with ε = half the margin width and the
        σ = ε/2 convention; 1.0 = no drift, →0 as the frozen slope decays.

        The slope trackers are ROBUST (``drift_track_k``): gross margin
        violators never enter the posterior, so a contamination burst — a
        minority of rows following a different trend, which the write path
        already routes to the outlier delta — cannot fake slope drift and
        trigger a relearn that would return the very same models.  What
        gross violators feed instead is the per-group violation-MASS
        fraction; its complement ``1 − bad/total`` joins the min, so a
        MAJORITY of arriving rows breaking a margin (a genuine regime
        change, where relearning finds different models) still degrades
        predictability below any sane threshold.

        Returns the minimum over all models and mass fractions (the
        weakest link triggers the relearn), or 1.0 when no FDs are
        tracked.
        """
        worst = 1.0
        for (gi, dep), blm in self._fd_trackers.items():
            model = self.groups[gi].models[dep]
            eps = model.width / 2.0
            if eps <= 0.0:
                continue
            m_live, _ = blm.posterior_mean()
            d = abs(m_live - model.m) * self._x_scale[gi]
            sigma = eps / 2.0
            ratio = (theory.met_drifted_expectation(eps, sigma, d)
                     / theory.met_expectation(eps, sigma))
            worst = min(worst, float(ratio))
        for gi, total in self._viol_total.items():
            if total:
                worst = min(worst, 1.0 - self._viol_bad[gi] / total)
        return worst

    def maybe_compact(self) -> bool:
        """Evaluate the compaction triggers (DESIGN.md §5) — AMORTIZED: the
        size+drift evaluation only runs once per ``compact_check_rows``
        written rows, or when a delta L0 spill signalled that the write
        plane grew a run (§5.3); evaluations are counted in
        ``trigger_checks``.  The counters are serialized with the index, so
        check timing — and therefore every auto-compaction decision — is
        bit-reproducible across snapshot/restore and WAL replay (§7.3).

        * size — delta load (live inserts + tombstones) exceeds both
          ``compact_min_delta`` and ``compact_delta_frac`` of the snapshot;
        * drift — predictability fell below ``drift_threshold`` with at
          least ``drift_min_delta`` of fresh delta evidence (the relearn
          path: compaction re-runs ``learn_soft_fds``).

        With ``background_compact`` a fired trigger starts a §5.4
        background build instead of compacting synchronously — except
        during WAL replay and during the handoff tail replay, both of
        which compact SYNCHRONOUSLY: replay must land on the same state a
        single-threaded run of the same ops would (§7.3), so a trigger
        firing mid-replay fires exactly where the sync world fires it.
        """
        if self._handoff_thread is not None:
            # one build at a time: fold it in if done, else keep serving
            return self.poll_handoff()
        cfg = self.config
        if self._write_units < cfg.compact_check_rows and not self._spill_pending:
            return False
        self._write_units = 0
        self._spill_pending = False
        self.trigger_checks += 1
        load = self.delta_rows + self.tombstone_count
        size_trigger = load >= max(cfg.compact_min_delta,
                                   int(cfg.compact_delta_frac * max(self.data.shape[0], 1)))
        drift_trigger = (load >= cfg.drift_min_delta
                         and self.drift_predictability() < cfg.drift_threshold)
        if not (size_trigger or drift_trigger):
            return False
        if (cfg.background_compact and not self._in_handoff_replay
                and not (self.durable is not None and self.durable._replaying)):
            self._begin_background_compact(relearn=drift_trigger or None)
            return True
        self.compact(relearn=drift_trigger or None)
        return True

    # ------------------------------------------------------------------ #
    # Background compaction + epoch handoff (DESIGN.md §5.4)
    # ------------------------------------------------------------------ #
    def _poll_entry(self) -> None:
        """Cheap per-call handoff check at write/query entry points."""
        if self._handoff_thread is not None:
            self.poll_handoff()

    def _begin_background_compact(self, relearn: Optional[bool]) -> None:
        """Kick off the §5.4 background build: freeze the live row set,
        decide the relearn flag NOW (from the serving thread's trackers —
        the decision is part of the rotation contract a replica replays,
        §8.2), and hand the pure ``_fit_state`` to a daemon thread.  The
        old epoch keeps serving; writes admitted during the build land in
        its delta planes AND are recorded for the post-handoff tail replay.
        """
        with obs.span("compact.freeze", epoch=self.epoch):
            rows, ids = self.live_rows()       # the frozen build input
            data = np.ascontiguousarray(rows, dtype=np.float32)
            row_ids = np.asarray(ids, dtype=np.int64).copy()
        if relearn is None:
            relearn = self.drift_predictability() < self.config.drift_threshold
        relearned = bool(relearn) and data.shape[0] >= 64
        epoch = self.epoch + 1
        groups_in = list(self.groups)
        cfg = self.config
        result = [None]
        # the build span is opened HERE (serving thread, implicit parent)
        # and finished by the builder thread — the §10.2 cross-thread case
        tr = obs.tracer()
        bsp = tr.start("compact.build", rows=int(data.shape[0]),
                       epoch=epoch, relearn=relearned) if tr else None

        def _build():
            try:
                groups = (learn_soft_fds(data, cfg.softfd)
                          if relearned else groups_in)
                result[0] = ("ok",
                             self._fit_state(data, row_ids, groups, epoch),
                             relearned)
            except BaseException as e:         # surfaced at the next poll
                result[0] = ("err", e)
            finally:
                if bsp is not None:
                    tr.finish(bsp)

        self._handoff_ops = []
        self._handoff_result = result
        self._handoff_t0 = time.perf_counter()
        t = threading.Thread(target=_build, name="coax-compactor", daemon=True)
        self._handoff_thread = t
        t.start()

    def poll_handoff(self, wait: bool = False) -> bool:
        """Fold a finished background build into the serving state — the
        atomic epoch handoff (DESIGN.md §5.4).  Called at every write/query
        entry and at wave boundaries; ``wait=True`` blocks for an in-flight
        build (``finish_handoff`` — the graceful-shutdown join).  Returns
        True iff a handoff was installed.  SERVING THREAD ONLY: installation
        swaps the plan the next wave is answered from.

        Install order (crash-safe, §7.5): adopt the built epoch → open the
        new WAL → replay the recorded tail through the ordinary write paths
        (journaled into the new WAL, frame shipping suppressed — replicas
        pull the re-journaled tail via catch-up, §8.4) → fsync → publish
        the new-epoch snapshot → delete old WALs.  A crash before the
        snapshot publish recovers from the old pair, whose WAL still holds
        the trigger record and the full tail.
        """
        t = self._handoff_thread
        if t is None:
            return False
        if not wait and t.is_alive():
            return False
        t.join()
        self._handoff_thread = None
        status = self._handoff_result[0] if self._handoff_result else None
        self._handoff_result = None
        ops, self._handoff_ops = (self._handoff_ops or []), None
        if status is None or status[0] == "err":
            err = status[1] if status else None
            raise RuntimeError("background compaction failed") from err
        _, fitted, relearned = status
        bk = self.backend
        with obs.span("compact.install", epoch=self.epoch + 1):
            self._install_fit(fitted)  # atomic swap: new epoch serves next
        self.compactions += 1
        self.backend = bk
        self._last_compact_relearned = relearned
        # Counter convergence with the synchronous world: a sync compaction
        # at the trigger record leaves ``write_units`` at 0 and the tail
        # ops then tick the ordinary check schedule.  Resetting here and
        # replaying the tail WITH live counters lands the amortized-trigger
        # phase exactly where a sync replica (§8.2 implicit rotation) or a
        # crash replay (§7.3) lands it, so future trigger timing agrees.
        self._write_units = 0
        self._spill_pending = False

        def _replay_tail():
            self._in_handoff_replay = True
            try:
                with obs.span("compact.tail_replay", ops=len(ops)):
                    for op in ops:
                        if op[0] == "i":
                            self.insert(op[1], ids=op[2])
                        else:
                            self.delete(op[1])
            finally:
                self._in_handoff_replay = False

        if self.durable is not None:
            self.durable.handoff_rotate(self, _replay_tail, relearned)
        else:
            _replay_tail()
        self.background_compactions += 1
        self.last_handoff_s = time.perf_counter() - self._handoff_t0
        g = obs.get_registry()
        g.counter("coax_compactions_total", "epoch rebuilds installed",
                  ("mode",)).inc(mode="background")
        g.histogram("coax_handoff_seconds",
                    "background build start -> tail replayed").observe(
                        self.last_handoff_s)
        return True

    def finish_handoff(self) -> bool:
        """Block until any in-flight background build is installed —
        called before checkpoints, seeds, synchronous ``compact()`` and at
        ``QueryServer.close`` (the §8.1 graceful-shutdown join)."""
        return self.poll_handoff(wait=True)

    def live_rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, ids) of every live row: snapshot survivors + delta logs —
        the compaction feed, and the scratch-rebuild oracle's input."""
        dead = self._dead_ids()
        if dead.size:
            keep = ~sorted_contains(dead, self.row_ids)
            rows, ids = self.data[keep], self.row_ids[keep]
        else:
            rows, ids = self.data, self.row_ids
        dp_rows, dp_ids = self.delta_primary.live_log()
        do_rows, do_ids = self.delta_outlier.live_log()
        if dp_ids.size or do_ids.size:
            rows = np.concatenate([rows, dp_rows, do_rows])
            ids = np.concatenate([ids, dp_ids, do_ids])
        return rows, ids

    def compact(self, relearn: Optional[bool] = None) -> dict:
        """Merge the delta planes into rebuilt snapshot grids.

        Materialises the live row set, optionally re-runs ``learn_soft_fds``
        (``relearn=None`` relearns iff the drift gate says the frozen models
        decayed), refits both grid files, resets the delta planes, and bumps
        the epoch — which is what invalidates any frozen ``DevicePlan``:
        the rebuilt ``GridFile``s carry the new epoch and lazily build fresh
        plans on first device use (DESIGN.md §5 invalidation contract).
        Any in-flight background build is folded in first, so explicit
        compaction composes with the §5.4 handoff machinery.
        """
        self.poll_handoff(wait=True)   # fold an in-flight handoff first
        if relearn is None:
            relearn = self.drift_predictability() < self.config.drift_threshold
        t0 = time.perf_counter()
        with obs.span("compact.sync", epoch=self.epoch + 1):
            rows, ids = self.live_rows()
            bk = self.backend
            self.data = np.ascontiguousarray(rows, dtype=np.float32)
            self.row_ids = np.asarray(ids, dtype=np.int64)
            relearned = bool(relearn) and self.data.shape[0] >= 64
            if relearned:
                self.groups = learn_soft_fds(self.data, self.config.softfd)
                self.keep_dims = reduced_dims(self.n_dims, self.groups)
            self.epoch += 1
            self.compactions += 1
            self._fit()
            self.backend = bk
        g = obs.get_registry()
        g.counter("coax_compactions_total", "epoch rebuilds installed",
                  ("mode",)).inc(mode="sync")
        g.histogram("coax_compact_sync_seconds",
                    "stop-the-world rebuild time").observe(
                        time.perf_counter() - t0)
        # what THIS compaction decided, for the rotation control frame a
        # replication hub ships (DESIGN.md §8.2) — a replica whose own
        # trigger did not fire replays the same decision verbatim
        self._last_compact_relearned = relearned
        if self.durable is not None:
            # new epoch snapshot + WAL rotation — the §7.5 truncation point
            self.durable.on_compact(self)
        return {"epoch": self.epoch, "rows": int(self.data.shape[0]),
                "relearned": relearned}

    def _dead_ids(self) -> np.ndarray:
        """Tombstoned ids across both planes, SORTED (the hit-masking
        paths binary-search this instead of ``isin``-sorting per wave)."""
        dead = np.concatenate([self.delta_primary.dead_ids(),
                               self.delta_outlier.dead_ids()])
        dead.sort()
        return dead

    # ------------------------------------------------------------------ #
    # Durability (DESIGN.md §7): full-state capture, save/restore
    # ------------------------------------------------------------------ #
    def _tracker_keys(self) -> List[Tuple[int, int]]:
        """(group index, dependent) pairs in the canonical (frozen) order —
        the serialisation order of tracker sufficient statistics."""
        return [(gi, dep) for gi, g in enumerate(self.groups)
                for dep in g.dependents]

    def _snapshot_state(self) -> dict:
        """Everything ``_restore_state`` needs to resurrect this exact
        index: epoch arrays in their exact order (the order feeds the next
        compaction's sampling rng, so it is part of bit-identity), both
        grid states, FD groups/margins, outlier bbox, live delta planes and
        the Bayesian drift trackers' sufficient statistics."""
        keys = self._tracker_keys()
        return {
            "data": self.data,
            "row_ids": self.row_ids,
            "next_id": self._next_id,
            "epoch": self.epoch,
            "compactions": self.compactions,
            "primary_ratio": self.primary_ratio,
            "config": self.config,
            "groups": self.groups,
            "primary": self.primary.state_dict(),
            "outlier": self.outlier.state_dict(),
            "outlier_lo": self._outlier_lo,
            "outlier_hi": self._outlier_hi,
            "delta_primary": self.delta_primary.state_dict(),
            "delta_outlier": self.delta_outlier.state_dict(),
            "write_units": self._write_units,
            "spill_pending": self._spill_pending,
            "trigger_checks": self.trigger_checks,
            "tracker_xtx": (np.stack([self._fd_trackers[k].xtx for k in keys])
                            if keys else np.empty((0, 2, 2))),
            "tracker_xty": (np.stack([self._fd_trackers[k].xty for k in keys])
                            if keys else np.empty((0, 2))),
            "tracker_lam": np.asarray(
                [self._fd_trackers[k].lam for k in keys], np.float64),
            "x_scale": np.asarray(
                [self._x_scale[gi] for gi in range(len(self.groups))], np.float64),
            "viol_total": np.asarray(
                [self._viol_total[gi] for gi in range(len(self.groups))], np.int64),
            "viol_bad": np.asarray(
                [self._viol_bad[gi] for gi in range(len(self.groups))], np.int64),
        }

    @classmethod
    def _restore_state(cls, state: dict, backend: str = "numpy",
                       device_opts: Optional[dict] = None) -> "COAXIndex":
        """Rebuild an index from ``_snapshot_state`` output WITHOUT
        refitting: grids, trackers and delta planes come back verbatim, so
        a warm restart costs deserialisation, not a relearn (DESIGN.md §7.3).
        Bit-identity contract: every query on any backend, and every future
        write/compaction decision, behaves exactly as the saved index
        would have."""
        idx = cls.__new__(cls)
        idx.config = state["config"]
        idx.data = np.ascontiguousarray(state["data"], dtype=np.float32)
        idx.n_dims = idx.data.shape[1]
        idx.row_ids = np.asarray(state["row_ids"], dtype=np.int64)
        idx._next_id = int(state["next_id"])
        idx.epoch = int(state["epoch"])
        idx.compactions = int(state["compactions"])
        idx.primary_ratio = float(state["primary_ratio"])
        idx.groups = list(state["groups"])
        idx.keep_dims = reduced_dims(idx.n_dims, idx.groups)
        idx._device_opts = device_opts
        idx._coax_plan = None
        idx._device_plan_failed = False
        idx.last_batch_stats = BatchStats()
        idx.durable = None
        idx.primary = GridFile.from_state(state["primary"],
                                          device_opts=device_opts)
        idx.outlier = GridFile.from_state(state["outlier"],
                                          device_opts=device_opts)
        idx._outlier_lo = state["outlier_lo"]
        idx._outlier_hi = state["outlier_hi"]
        idx._base_primary_ids = np.sort(idx.primary.row_ids)
        idx._base_outlier_ids = np.sort(idx.outlier.row_ids)
        kd = idx._delta_key_dim()
        spill = idx.config.delta_l0_spill
        idx.delta_primary = DeltaPlane.from_state(
            idx.n_dims, state["delta_primary"], key_dim=kd, l0_spill=spill)
        idx.delta_outlier = DeltaPlane.from_state(
            idx.n_dims, state["delta_outlier"], key_dim=kd, l0_spill=spill)
        idx._init_write_state()
        idx._write_units = int(state.get("write_units", 0))
        idx._spill_pending = bool(state.get("spill_pending", False))
        idx.trigger_checks = int(state.get("trigger_checks", 0))
        keys = idx._tracker_keys()
        xtx, xty = state["tracker_xtx"], state["tracker_xty"]
        lam = state["tracker_lam"]
        idx._fd_trackers = {
            k: BayesianLinearModel(np.array(xtx[i], np.float64),
                                   np.array(xty[i], np.float64),
                                   float(lam[i]))
            for i, k in enumerate(keys)
        }
        idx._x_scale = {gi: float(s) for gi, s in enumerate(state["x_scale"])}
        n_groups = len(idx.groups)
        vt = np.asarray(state.get("viol_total", ()), np.int64)
        vb = np.asarray(state.get("viol_bad", ()), np.int64)
        if vt.shape[0] != n_groups or vb.shape[0] != n_groups:
            vt = np.zeros(n_groups, np.int64)   # pre-counter snapshot
            vb = np.zeros(n_groups, np.int64)
        idx._viol_total = {gi: int(vt[gi]) for gi in range(n_groups)}
        idx._viol_bad = {gi: int(vb[gi]) for gi in range(n_groups)}
        idx.backend = backend
        return idx

    def save(self, directory, keep: Optional[int] = None):
        """One-shot full-state snapshot into ``directory`` (atomic staged
        rename; newest-complete wins at restore).  Returns the snapshot
        path.  Saving into the attached durability directory routes through
        ``Durability.checkpoint`` so the snapshot's ``wal_seq`` stays
        consistent with the journal; any other target gets a self-contained
        snapshot (the cold-start-replica / shard-migration artifact)."""
        from pathlib import Path
        from ..storage import write_snapshot
        if (self.durable is not None
                and Path(directory).resolve() == self.durable.directory.resolve()):
            return self.durable.checkpoint(keep=keep)
        return write_snapshot(self, directory, keep=keep)

    @classmethod
    def restore(cls, directory, backend: str = "numpy",
                device_opts: Optional[dict] = None,
                durable: bool = False) -> "COAXIndex":
        """Load the newest complete snapshot under ``directory`` and replay
        the matching WAL tail; ``durable=True`` re-attaches the durability
        plane so the recovered index keeps journaling where the crashed one
        stopped.  See ``repro.storage.restore``."""
        from ..storage import restore as _restore
        idx = _restore(directory, backend=backend, device_opts=device_opts,
                       durable=durable)
        if not isinstance(idx, cls):
            raise TypeError(f"{directory} holds a {type(idx).__name__} "
                            f"snapshot, not {cls.__name__}")
        return idx

    def attach_durability(self, directory, keep: int = 3,
                          sync_every_op: bool = False) -> "COAXIndex":
        """Start journaling this index's writes under ``directory``: writes
        the current epoch snapshot if missing and opens the epoch's WAL.
        Returns self."""
        from ..storage import Durability
        Durability.attach(self, directory, keep=keep,
                          sync_every_op=sync_every_op)
        return self

    # ------------------------------------------------------------------ #
    def translate(self, rect: Rect) -> np.ndarray:
        """Eq. 2 translation of a full rect onto the indexed dims."""
        return translate_rect(rect, self.groups, self.keep_dims)

    def query(self, rect: Rect) -> np.ndarray:
        self._poll_entry()
        rect = np.asarray(rect, dtype=np.float64)
        nav = self.translate(rect)
        hits = [self.primary.query(nav, rect)]
        # half-open rects: [lo, hi) intersects [blo, bhi] iff lo <= bhi, hi > blo
        if self._outlier_lo is not None and bool(
            np.all((rect[:, 0] <= self._outlier_hi) & (rect[:, 1] > self._outlier_lo))
        ):
            o_nav = rect.copy()
            hits.append(self.outlier.query(o_nav, rect))
        out = np.concatenate(hits) if len(hits) > 1 else hits[0]
        dead = self._dead_ids()
        if dead.size and out.size:
            out = out[~sorted_contains(dead, out)]
        d1 = self.delta_primary.scan(rect)
        d2 = self.delta_outlier.scan(rect)
        if d1.size or d2.size:
            out = np.concatenate([out, d1, d2])
        return np.sort(out)

    # ------------------------------------------------------------------ #
    def translate_batch(self, rects: np.ndarray) -> np.ndarray:
        """Batched Eq. 2: (B, D, 2) full rects -> (B, K, 2) nav-rects."""
        return translate_rects(rects, self.groups, self.keep_dims)

    def query_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Answer B range queries in one vectorised pass.

        rects : (B, D, 2).  Returns ``(query_ids, row_ids)`` sorted by
        (query_id, row_id); per query the row-id set is exactly what
        ``query`` returns.  One translation pass, one primary directory
        probe and one outlier probe are shared by the whole batch; the
        §8.2.3 outlier skip is a vectorised bbox test.

        ``backend="device"`` serves the wave from the §4 COAX device plan —
        primary + outlier + delta/tombstone scan fused into ONE kernel
        launch (``query_batch_submit`` + ``query_batch_collect``, which
        pipelined callers may drive directly to overlap waves); waves whose
        candidate cells overflow ``cell_cap`` fall back to the host path.
        Either way the answer is bit-identical to the numpy backend.

        With an attached ``SemanticCache`` (``attach_cache``) the wave is
        consulted first (DESIGN.md §9.2): exact/contained rects answer from
        the cache, only the misses run the pipeline (and are admitted
        back), and the merged answer is bit-identical to the uncached path.
        """
        self._poll_entry()
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        if b == 0:
            self.last_batch_stats = BatchStats(backend=self.backend)
            return np.empty(0, np.int64), np.empty(0, np.int64)
        if self.backend == "device":
            return self.query_batch_collect(self.query_batch_submit(rects))
        route = self._cache_route(rects)
        if route is None:
            q_p, r_p, stats = self._query_batch_host(rects,
                                                     self.translate_batch(rects))
            self.last_batch_stats = stats
            return q_p, r_p
        answers, miss, version = route
        if miss.size:
            sub = np.ascontiguousarray(rects[miss])
            q_m, r_m, stats = self._query_batch_host(sub,
                                                     self.translate_batch(sub))
            self._cache_admit(version, sub, q_m, r_m)
        else:
            q_m = r_m = np.empty(0, np.int64)
            stats = BatchStats(backend=self.backend)
        self.last_batch_stats = dataclasses.replace(stats, queries=b)
        return self._merge_cached(answers, miss, q_m, r_m)

    def _query_batch_host(self, rects: np.ndarray, nav: np.ndarray,
                          fallbacks: int = 0):
        """The exact host composition (DESIGN.md §5): snapshot grids via the
        numpy path, tombstone mask, exact delta scans — the numpy backend's
        ``query_batch`` body and the device plan's ``cell_cap``-fallback
        path.  Returns ``(query_ids, row_ids, BatchStats)``."""
        b = rects.shape[0]
        q_p, r_p = self.primary._query_batch_numpy(nav, rects)
        stats = dataclasses.replace(self.primary.last_batch_stats,
                                    queries=b, backend=self.backend,
                                    fallbacks=fallbacks)

        if self._outlier_lo is not None:
            # same half-open/closed-bbox intersection test as ``query``
            touch = np.all(
                (rects[:, :, 0] <= self._outlier_hi) & (rects[:, :, 1] > self._outlier_lo),
                axis=1,
            )
            if touch.any():
                sub = rects[touch]
                q_o, r_o = self.outlier._query_batch_numpy(sub, sub)
                stats = stats.merge(self.outlier.last_batch_stats)
                if r_o.size:
                    q_o = np.nonzero(touch)[0][q_o]    # sub-batch ids -> batch ids
                    q_p = np.concatenate([q_p, q_o])
                    r_p = np.concatenate([r_p, r_o])
                    order = np.lexsort((r_p, q_p))     # merge the two hit lists
                    q_p, r_p = q_p[order], r_p[order]

        q_d1, r_d1 = self.delta_primary.scan_batch(rects)
        q_d2, r_d2 = self.delta_outlier.scan_batch(rects)
        with obs.stage_timer("merge", self.backend):
            dead = self._dead_ids()
            if dead.size and r_p.size:
                keep = ~sorted_contains(dead, r_p)
                q_p, r_p = q_p[keep], r_p[keep]
            if r_d1.size or r_d2.size:
                q_p = np.concatenate([q_p, q_d1, q_d2])
                r_p = np.concatenate([r_p, r_d1, r_d2])
                order = np.lexsort((r_p, q_p))
                q_p, r_p = q_p[order], r_p[order]
        # delta work actually done: run-window candidates + dense L0 rows
        # (was b * delta_rows before the §5.3 tiered runs)
        stats.rows_scanned += (self.delta_primary.last_scan_probed
                               + self.delta_outlier.last_scan_probed)
        return q_p, r_p, stats

    # ------------------------------------------------------------------ #
    # Semantic result cache (DESIGN.md §9.1–§9.2) + pinned-epoch MVCC
    # reads (§9.3).  The cache consults BEFORE the pipeline and admits
    # after it; pins capture the current epoch's objects for readers that
    # must stay on it across background-compaction handoffs.
    # ------------------------------------------------------------------ #
    def attach_cache(self, byte_budget: int = 64 << 20,
                     max_entries: int = 512,
                     shard_id: Optional[int] = None) -> "COAXIndex":
        """Attach a rect-containment ``SemanticCache`` (DESIGN.md §9.2) to
        every batched read path (numpy and device).  ``shard_id`` is set by
        ``ShardedCOAX.attach_cache`` so entries key on (shard, the shard's
        OWN version), never an aggregate epoch.  Returns self."""
        from ..engine.cache import SemanticCache
        self.cache = SemanticCache(byte_budget=byte_budget,
                                   max_entries=max_entries,
                                   shard_id=shard_id)
        self.last_cache_stats = None
        return self

    def detach_cache(self) -> None:
        self.cache = None
        self.last_cache_stats = None

    def _cache_version(self) -> tuple:
        """The write-state version cache entries are keyed on (§9.2):
        epoch plus both planes' log/tombstone counters.  Every component
        is monotone within an epoch and the epoch is monotone across
        compactions, so ANY write — insert, delete, or an installed
        handoff — moves the key and strands stale entries."""
        dp, do = self.delta_primary, self.delta_outlier
        return (self.epoch, dp.n_log, dp.n_tombstones,
                do.n_log, do.n_tombstones)

    def _cache_route(self, rects: np.ndarray):
        """Consult the cache for a wave: ``None`` when no cache is
        attached, else ``(answers, miss_indices, version)`` with per-wave
        stats latched on ``last_cache_stats`` (read by the executor at
        submit time, §9.2)."""
        if self.cache is None:
            return None
        with obs.span("cache.route", queries=int(rects.shape[0])) as sp:
            with obs.stage_timer("cache_route", self.backend):
                version = self._cache_version()
                answers, stats = self.cache.lookup_wave(version, rects)
            if sp is not None:
                sp.args.update(hits=stats.hits, partial=stats.partial)
        self.last_cache_stats = stats
        miss = np.array([i for i, a in enumerate(answers) if a is None],
                        dtype=np.int64)
        return answers, miss, version

    def _cache_admit(self, version: tuple, rects: np.ndarray,
                     qids: np.ndarray, rids: np.ndarray) -> None:
        """Admit freshly answered rects.  Skipped wholesale when the live
        version moved since the wave was routed (the §9.2 stale-admission
        gate: a pipelined device wave may drain after writes — or a
        handoff — landed; its answer is correct for the OLD version but
        must not be stored under the new key)."""
        if self.cache is None or version != self._cache_version():
            return
        with obs.span("cache.admit", queries=int(rects.shape[0])):
            with obs.stage_timer("cache_admit", self.backend):
                for rect, ids in zip(rects,
                                     split_hits(qids, rids, rects.shape[0])):
                    self.cache.admit(version, rect, ids,
                                     self.rows_for_ids(ids))

    @staticmethod
    def _merge_cached(answers, miss, q_m, r_m):
        """Merge cached per-query answers with the miss sub-batch's flat
        hits back into the ``query_batch`` contract (lexsorted by
        (query, row); cached id arrays are already sorted)."""
        qs, rs = [], []
        for i, a in enumerate(answers):
            if a is not None and a.size:
                qs.append(np.full(a.size, i, dtype=np.int64))
                rs.append(a)
        if q_m.size:
            qs.append(miss[q_m])
            rs.append(r_m)
        if not qs:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        q = np.concatenate(qs)
        r = np.concatenate(rs)
        order = np.lexsort((r, q))
        return q[order], r[order]

    def rows_for_ids(self, ids: np.ndarray) -> np.ndarray:
        """(m, D) f32 row values for LIVE original ids — the §9.2 cache-
        admission gather.  Snapshot ids resolve through a cached argsort of
        ``row_ids`` (reset at every epoch install), the rest through the
        delta planes' own gathers.  Raises ``KeyError`` for ids in neither
        (a query's hit ids are always resolvable at its own version)."""
        ids = np.asarray(ids, dtype=np.int64)
        out = np.empty((ids.shape[0], self.n_dims), dtype=np.float32)
        if ids.size == 0:
            return out
        if self._id_order_cache is None:
            order = np.argsort(self.row_ids, kind="stable")
            self._id_order_cache = (order, self.row_ids[order])
        order, sids = self._id_order_cache
        if sids.size:
            pos = np.searchsorted(sids, ids)
            pos[pos == sids.size] = sids.size - 1
            found = sids[pos] == ids
            if found.any():
                out[found] = self.data[order[pos[found]]]
        else:
            found = np.zeros(ids.shape, dtype=bool)
        rest = np.nonzero(~found)[0]
        if rest.size:
            f1, rows1 = self.delta_primary.rows_for_ids(ids[rest])
            out[rest[f1]] = rows1
            rem = rest[~f1]
            if rem.size:
                f2, rows2 = self.delta_outlier.rows_for_ids(ids[rem])
                out[rem[f2]] = rows2
                if not f2.all():
                    raise KeyError(
                        f"{int((~f2).sum())} ids not in snapshot or delta logs")
        return out

    def pin_epoch(self):
        """Open an MVCC read handle on the CURRENT epoch (DESIGN.md §9.3):
        the returned ``EpochPin`` keeps this epoch's grids, device plan and
        a frozen delta image alive — refcounted in ``_pins`` — so its
        answers stay bit-identical to this instant while writes and
        background-compaction handoffs (§5.4) move the serving index to
        newer epochs.  Release (or ``with``-exit) the pin to free the old
        epoch once the serving index has moved on."""
        self._poll_entry()
        from ..engine.cache import EpochPin
        pin = EpochPin(self)
        self._pins[pin.epoch] = self._pins.get(pin.epoch, 0) + 1
        return pin

    def _release_pin(self, epoch: int) -> None:
        n = self._pins.get(epoch, 0)
        if n <= 1:
            self._pins.pop(epoch, None)
        else:
            self._pins[epoch] = n - 1

    @property
    def pinned_epochs(self) -> List[int]:
        """Epochs with at least one live ``EpochPin`` (§9.3)."""
        return sorted(self._pins)

    # ------------------------------------------------------------------ #
    # Device wave pipelining (DESIGN.md §4): submit launches the fused
    # kernel without transferring results; collect is the drain point.
    # ------------------------------------------------------------------ #
    def _device_plan_obj(self):
        """Lazily (re)build the §4 COAX device plan for the CURRENT epoch
        grids; compaction swaps the grids, which invalidates by identity.
        Warns once and degrades to the host path when jax is unavailable."""
        if self._device_plan_failed:
            return None
        plan = self._coax_plan
        if (plan is not None and plan.primary is self.primary
                and plan.outlier is self.outlier):
            return plan
        try:
            from ..engine.device import CoaxDevicePlan
            fresh = CoaxDevicePlan(self, **(self._device_opts or {}))
        except Exception as e:  # pragma: no cover - jax-less installs
            import warnings
            warnings.warn(f"device backend unavailable ({e}); using numpy path")
            self._device_plan_failed = True
            self._coax_plan = None
            return None
        if plan is not None:       # carry counters AND the jit cache across
            fresh.adopt(plan)      # epoch swaps (no recompile per epoch)
        self._coax_plan = fresh
        return fresh

    def query_batch_submit(self, rects: np.ndarray,
                           nav: Optional[np.ndarray] = None):
        """Launch one device wave (ONE kernel dispatch) and return a handle
        for ``query_batch_collect`` — results stay device-resident until
        then.  Waves the plan cannot serve (``cell_cap`` overflow, device
        unavailable) are answered synchronously here by the host path, so
        the handle ALWAYS reflects this submit's snapshot+delta state even
        if writes land before collection (per-wave snapshot semantics).
        A finished background build is folded in HERE, before the wave's
        snapshot is captured — wave-boundary handoff visibility (§5.4).
        With a cache attached the wave is consulted against it first and
        only the misses are submitted; the handle carries the cached
        answers so ``query_batch_collect`` can merge them back (§9.2)."""
        self._poll_entry()
        rects = np.asarray(rects, dtype=np.float64)
        route = self._cache_route(rects) if rects.shape[0] else None
        if route is None:
            return self._submit_uncached(rects, nav)
        answers, miss, version = route
        if miss.size == rects.shape[0]:          # all missed: plain wave
            sub = rects
            inner = self._submit_uncached(rects, nav)
        elif miss.size:                          # partial: submit subset
            sub = np.ascontiguousarray(rects[miss])
            inner = self._submit_uncached(sub, None)
        else:                                    # fully answered from cache
            sub = rects[:0]
            inner = ("host", np.empty(0, np.int64), np.empty(0, np.int64),
                     BatchStats(backend=self.backend))
        return ("cache", answers, miss, version, sub, inner)

    def _submit_uncached(self, rects: np.ndarray,
                         nav: Optional[np.ndarray] = None):
        if nav is None:
            nav = self.translate_batch(rects) if rects.shape[0] else None
        fallbacks = 0
        if rects.shape[0]:
            plan = self._device_plan_obj()
            if plan is not None:
                ticket = plan.submit_wave(nav, rects)
                if ticket is not None:
                    return ("dev", plan, ticket)
                fallbacks = 1                  # cell_cap overflow -> host
            q, r, stats = self._query_batch_host(rects, nav, fallbacks)
        else:
            q = r = np.empty(0, np.int64)
            stats = BatchStats(backend=self.backend)
        return ("host", q, r, stats)

    def query_batch_collect(self, handle) -> Tuple[np.ndarray, np.ndarray]:
        """Drain one submitted wave (``jax.block_until_ready`` + transfer of
        the compacted hit buffers) and return its ``query_batch`` answer.
        Cache-wrapped handles drain the miss sub-wave, admit its answers
        (gated on the version still matching, §9.2), and merge with the
        handle's cached answers."""
        if handle[0] != "cache":
            return self._collect_uncached(handle)
        _, answers, miss, version, sub, inner = handle
        q_m, r_m = self._collect_uncached(inner)
        if miss.size:
            self._cache_admit(version, sub, q_m, r_m)
        self.last_batch_stats = dataclasses.replace(
            self.last_batch_stats, queries=len(answers))
        return self._merge_cached(answers, miss, q_m, r_m)

    def _collect_uncached(self, handle) -> Tuple[np.ndarray, np.ndarray]:
        if handle[0] == "host":
            _, q, r, stats = handle
            self.last_batch_stats = stats
            return q, r
        _, plan, ticket = handle
        q, r, stats = plan.collect(ticket)
        self.last_batch_stats = dataclasses.replace(stats,
                                                    backend=self.backend)
        return q, r

    def device_stats(self) -> Optional[dict]:
        """Device-plane rollups (compile cache size, kernel dispatches,
        transfer bytes both ways), or None before any device wave."""
        plan = self._coax_plan
        if plan is None:
            return None
        return {"compile_count": plan.compile_count,
                "dispatches": plan.dispatch_count,
                "bytes_h2d": plan.bytes_h2d,
                "bytes_d2h": plan.bytes_d2h}

    def query_batch_split(self, rects: np.ndarray) -> List[np.ndarray]:
        """``query_batch`` reshaped to one sorted row-id array per rect."""
        rects = np.asarray(rects, dtype=np.float64)
        qids, rids = self.query_batch(rects)
        return split_hits(qids, rids, rects.shape[0])

    # ------------------------------------------------------------------ #
    def memory_footprint(self) -> int:
        """Bytes actually held beyond the snapshot payload: both grid
        directories, the soft-FD model parameters, the live drift trackers,
        the §8.2.3 outlier bbox arrays, the delta structures, and — when a
        durability plane is attached — the WAL tail appended but not yet
        fsynced (page-cache resident until the wave-boundary sync, §7.2)."""
        model_bytes = sum(len(g.dependents) * 4 * 8 + 8 for g in self.groups)
        tracker_bytes = len(self._fd_trackers) * 7 * 8     # xtx(4)+xty(2)+lam
        bbox_bytes = (self._outlier_lo.nbytes + self._outlier_hi.nbytes
                      if self._outlier_lo is not None else 0)
        delta_bytes = self.delta_primary.nbytes() + self.delta_outlier.nbytes()
        wal_pending = (self.durable.wal_pending_bytes
                       if self.durable is not None else 0)
        cache_bytes = self.cache.nbytes if self.cache is not None else 0
        return (self.primary.memory_footprint() + self.outlier.memory_footprint()
                + model_bytes + tracker_bytes + bbox_bytes + delta_bytes
                + wal_pending + cache_bytes)

    def describe(self) -> dict:
        return {
            "n_rows": self.n_rows,
            "base_rows": int(self.data.shape[0]),
            "n_dims": self.n_dims,
            "groups": [
                {
                    "predictor": g.predictor,
                    "dependents": list(g.dependents),
                    "models": {
                        int(d): dataclasses.asdict(m) for d, m in g.models.items()
                    },
                }
                for g in self.groups
            ],
            "indexed_dims": self.keep_dims,
            "grid_dims": self.primary.grid_dims,
            "sort_dim": self.primary.sort_dim,
            "primary_ratio": self.primary_ratio,
            "primary_cells": self.primary.n_cells,
            "outlier_cells": self.outlier.n_cells,
            "epoch": self.epoch,
            "compactions": self.compactions,
            "trigger_checks": self.trigger_checks,
            "write_units": self._write_units,
            "background": {
                "enabled": self.config.background_compact,
                "in_flight": self._handoff_thread is not None,
                "completed": self.background_compactions,
                "last_handoff_s": self.last_handoff_s,
            },
            "delta_primary": self.delta_primary.describe(),
            "delta_outlier": self.delta_outlier.describe(),
            "tombstones": self.tombstone_count,
            "drift_predictability": self.drift_predictability(),
            "outlier_bbox_bytes": (self._outlier_lo.nbytes + self._outlier_hi.nbytes
                                   if self._outlier_lo is not None else 0),
            "memory_footprint_bytes": self.memory_footprint(),
            "pinned_epochs": self.pinned_epochs,
            "cache": (self.cache.describe() if self.cache is not None else None),
            "durability": (self.durable.describe()
                           if self.durable is not None else None),
        }
