"""The COAX index (paper §3, Fig. 1): soft-FD learning + query translation +
primary index on reduced dims + full-dimensional outlier index.

Build path (``COAXIndex.fit``):
  1. learn soft-FD groups from a sample (Alg. 1; ``softfd.learn_soft_fds``);
  2. split rows: every group's margins satisfied -> primary, else -> outlier
     (Alg. 1, second half);
  3. primary = grid file over only the INDEXED dims (non-dependents) with an
     in-cell sorted dim -> ``n - m - 1`` grid dimensions (§6);
  4. outliers = an ordinary full-dimensional multidimensional index (§3:
     'a typical multidimensional index structure') — quantile grid here.

Query path (``COAXIndex.query``):
  translate the rect onto indexed dims (Eq. 2), probe the primary with the
  translated nav-rect plus the ORIGINAL full predicate, probe the outlier
  index with the original rect, union row ids.  §8.2.3's optimisation is
  applied: each sub-index is only invoked when the query can intersect it.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .gridfile import BatchStats, GridFile, fit_cells_per_dim
from .softfd import SoftFDConfig, learn_soft_fds
from .translate import reduced_dims, translate_rect, translate_rects
from .types import FDGroup, Rect, full_rect, rect_contains, split_hits

__all__ = ["CoaxConfig", "COAXIndex"]


@dataclasses.dataclass(frozen=True)
class CoaxConfig:
    softfd: SoftFDConfig = SoftFDConfig()
    primary_cells_per_dim: Optional[int] = None   # None -> auto from rows_per_cell
    outlier_cells_per_dim: Optional[int] = None
    sort_dim: Optional[int] = None                # None -> auto (widest kept dim)
    rows_per_cell: int = 256                      # target cell occupancy (sweet
                                                  # spot lever, paper Fig. 8)
    directory_budget_frac: float = 1.0            # directory <= frac * data bytes


class COAXIndex:
    name = "coax"

    def __init__(self, data: np.ndarray, config: CoaxConfig = CoaxConfig(),
                 groups: Optional[Sequence[FDGroup]] = None,
                 backend: str = "numpy",
                 device_opts: Optional[dict] = None):
        """Build the index.  ``groups`` may be supplied to skip detection
        (e.g. when the DBA already knows the FDs, or from a previous fit).

        ``backend="device"`` routes ``query_batch`` through the frozen
        device plans of both sub-grids (DESIGN.md §4); numpy stays the
        default and the correctness oracle.
        """
        self.config = config
        self.data = np.ascontiguousarray(data, dtype=np.float32)
        self.n_rows, self.n_dims = self.data.shape
        self.groups: List[FDGroup] = (
            list(groups) if groups is not None else learn_soft_fds(self.data, config.softfd)
        )
        self.keep_dims = reduced_dims(self.n_dims, self.groups)
        self._device_opts = device_opts
        self.last_batch_stats = BatchStats()
        self._fit()
        self.backend = backend

    # ------------------------------------------------------------------ #
    @property
    def backend(self) -> str:
        return self.primary.backend

    @backend.setter
    def backend(self, value: str) -> None:
        self.primary.backend = value
        self.outlier.backend = value

    # ------------------------------------------------------------------ #
    def _fit(self) -> None:
        cfg = self.config
        # Split into primary (all groups' margins hold) and outliers.
        inlier = np.ones(self.n_rows, dtype=bool)
        for g in self.groups:
            inlier &= g.inlier_mask(self.data)
        self.primary_ratio = float(inlier.mean()) if self.n_rows else 0.0

        ids = np.arange(self.n_rows, dtype=np.int64)
        p_rows, p_ids = self.data[inlier], ids[inlier]
        o_rows, o_ids = self.data[~inlier], ids[~inlier]

        # Sorted dim: the kept dim with the widest normalised spread by
        # default — maximises the benefit of in-cell binary search.
        if cfg.sort_dim is not None:
            sort_dim = cfg.sort_dim
        else:
            spread = [
                float(np.std(self.data[:, d])) / (float(np.ptp(self.data[:, d])) or 1.0)
                for d in self.keep_dims
            ]
            sort_dim = self.keep_dims[int(np.argmax(spread))] if self.keep_dims else 0

        budget_cells = max(int(self.data.nbytes * cfg.directory_budget_frac) // 8, 1)
        n_grid = max(len(self.keep_dims) - 1, 0)
        target = max(int(p_rows.shape[0] / cfg.rows_per_cell), 1)
        auto = max(int(round(target ** (1.0 / max(n_grid, 1)))), 2)
        p_cells = cfg.primary_cells_per_dim or min(
            auto, fit_cells_per_dim(max(n_grid, 1), budget_cells))
        self.primary = GridFile(
            p_rows, index_dims=self.keep_dims, cells_per_dim=p_cells,
            sort_dim=sort_dim if self.keep_dims else None, quantile=True, row_ids=p_ids,
            device_opts=self._device_opts,
        )

        # Outlier index: full-dimensional quantile grid with its own (much
        # smaller) budget — outliers are typically a few % of rows.
        o_budget = max(int(o_rows.nbytes * cfg.directory_budget_frac) // 8, 1)
        o_target = max(int(o_rows.shape[0] / cfg.rows_per_cell), 1)
        o_auto = max(int(round(o_target ** (1.0 / max(self.n_dims - 1, 1)))), 2)
        o_cells = cfg.outlier_cells_per_dim or min(
            o_auto, fit_cells_per_dim(max(self.n_dims - 1, 1), o_budget))
        self.outlier = GridFile(
            o_rows, index_dims=list(range(self.n_dims)), cells_per_dim=o_cells,
            sort_dim=sort_dim, quantile=True, row_ids=o_ids,
            device_opts=self._device_opts,
        )

        # Bounding box of outliers lets us skip the outlier probe entirely
        # for queries that cannot touch it (§8.2.3).
        if o_rows.shape[0]:
            self._outlier_lo = o_rows.min(axis=0)
            self._outlier_hi = o_rows.max(axis=0)
        else:
            self._outlier_lo = None

    # ------------------------------------------------------------------ #
    def translate(self, rect: Rect) -> np.ndarray:
        """Eq. 2 translation of a full rect onto the indexed dims."""
        return translate_rect(rect, self.groups, self.keep_dims)

    def query(self, rect: Rect) -> np.ndarray:
        rect = np.asarray(rect, dtype=np.float64)
        nav = self.translate(rect)
        hits = [self.primary.query(nav, rect)]
        # half-open rects: [lo, hi) intersects [blo, bhi] iff lo <= bhi, hi > blo
        if self._outlier_lo is not None and bool(
            np.all((rect[:, 0] <= self._outlier_hi) & (rect[:, 1] > self._outlier_lo))
        ):
            o_nav = rect.copy()
            hits.append(self.outlier.query(o_nav, rect))
        out = np.concatenate(hits) if len(hits) > 1 else hits[0]
        return np.sort(out)

    # ------------------------------------------------------------------ #
    def translate_batch(self, rects: np.ndarray) -> np.ndarray:
        """Batched Eq. 2: (B, D, 2) full rects -> (B, K, 2) nav-rects."""
        return translate_rects(rects, self.groups, self.keep_dims)

    def query_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Answer B range queries in one vectorised pass.

        rects : (B, D, 2).  Returns ``(query_ids, row_ids)`` sorted by
        (query_id, row_id); per query the row-id set is exactly what
        ``query`` returns.  One translation pass, one primary directory
        probe and one outlier probe are shared by the whole batch; the
        §8.2.3 outlier skip is a vectorised bbox test that sub-batches the
        outlier probe to only the queries that can touch it.
        """
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        if b == 0:
            self.last_batch_stats = BatchStats(backend=self.backend)
            return np.empty(0, np.int64), np.empty(0, np.int64)
        nav = self.translate_batch(rects)
        q_p, r_p = self.primary.query_batch(nav, rects)
        stats = dataclasses.replace(self.primary.last_batch_stats,
                                    queries=b, backend=self.backend)

        if self._outlier_lo is not None:
            # same half-open/closed-bbox intersection test as ``query``
            touch = np.all(
                (rects[:, :, 0] <= self._outlier_hi) & (rects[:, :, 1] > self._outlier_lo),
                axis=1,
            )
            if touch.any():
                sub = rects[touch]
                q_o, r_o = self.outlier.query_batch(sub, sub)
                stats = stats.merge(self.outlier.last_batch_stats)
                if r_o.size:
                    q_o = np.nonzero(touch)[0][q_o]    # sub-batch ids -> batch ids
                    q_p = np.concatenate([q_p, q_o])
                    r_p = np.concatenate([r_p, r_o])
                    order = np.lexsort((r_p, q_p))     # merge the two hit lists
                    q_p, r_p = q_p[order], r_p[order]
        self.last_batch_stats = stats
        return q_p, r_p

    def query_batch_split(self, rects: np.ndarray) -> List[np.ndarray]:
        """``query_batch`` reshaped to one sorted row-id array per rect."""
        rects = np.asarray(rects, dtype=np.float64)
        qids, rids = self.query_batch(rects)
        return split_hits(qids, rids, rects.shape[0])

    # ------------------------------------------------------------------ #
    def memory_footprint(self) -> int:
        """Directory bytes: both grids + the soft-FD model parameters."""
        model_bytes = sum(len(g.dependents) * 4 * 8 + 8 for g in self.groups)
        return self.primary.memory_footprint() + self.outlier.memory_footprint() + model_bytes

    def describe(self) -> dict:
        return {
            "n_rows": self.n_rows,
            "n_dims": self.n_dims,
            "groups": [
                {
                    "predictor": g.predictor,
                    "dependents": list(g.dependents),
                    "models": {
                        int(d): dataclasses.asdict(m) for d, m in g.models.items()
                    },
                }
                for g in self.groups
            ],
            "indexed_dims": self.keep_dims,
            "grid_dims": self.primary.grid_dims,
            "sort_dim": self.primary.sort_dim,
            "primary_ratio": self.primary_ratio,
            "primary_cells": self.primary.n_cells,
            "outlier_cells": self.outlier.n_cells,
            "memory_footprint_bytes": self.memory_footprint(),
        }
