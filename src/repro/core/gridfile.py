"""Grid-file index with quantile-aligned boundaries and an in-cell sorted
dimension (paper §6, building on Nievergelt et al.'s Grid File [29]).

Modifications from the classic grid file, per the paper:
  * grid lines are chosen from per-dimension QUANTILES (CDF-aligned), the same
    number of lines per attribute;
  * cell addresses are flattened in the original attribute order;
  * each cell's records live in one contiguous block (row-store);
  * rows inside a cell are SORTED on one attribute, so that attribute needs no
    grid lines (binary search instead) — the index loses one grid dimension.

A grid over ``g`` of the indexed dims with one sorted dim indexes
``len(index_dims) - 1`` dimensions, which is how COAX reaches ``n - m - 1``
grid dimensions overall (§6).
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from .types import Rect, rect_contains

__all__ = ["GridFile", "BatchStats", "gather_ranges", "fit_cells_per_dim",
           "batched_searchsorted"]


def batched_searchsorted(vals: np.ndarray, blk_lo: np.ndarray,
                         blk_hi: np.ndarray, target,
                         side: str = "left",
                         vals_finite: bool = False) -> np.ndarray:
    """Vectorised per-segment ``searchsorted``.

    For each segment ``[blk_lo[i], blk_hi[i])`` of the globally cell-sorted
    ``vals``, find the insertion point of ``target`` — one binary search run
    simultaneously across every candidate cell (log2(max block) vectorised
    iterations instead of a Python loop per cell; the C implementation's
    per-cell bisect equivalent, DESIGN.md §3).

    ``target`` may be a scalar (one query) or an array aligned with
    ``blk_lo`` (per-segment targets — the batched engine searches every
    (query, cell) pair in one pass).  ``-inf``/``+inf`` targets degenerate
    to ``blk_lo``/``blk_hi`` respectively, i.e. "no constraint" — when the
    whole target is ±inf the loop is skipped outright (the +inf exit needs
    ``vals_finite=True``, a fact callers can certify once at build time,
    because a stored +inf would be a valid insertion point before the end).
    Converged lanes mask their gather index to 0 instead of re-reading
    ``vals`` every iteration — the gather is this loop's hot instruction.
    """
    lo = blk_lo.astype(np.int64).copy()
    hi = blk_hi.astype(np.int64).copy()
    t = np.asarray(target)
    if side == "left" and t.size:
        if np.all(np.isneginf(t)):
            return lo                               # insert at segment start
        if vals_finite and np.all(np.isposinf(t)):
            return np.where(lo < hi, hi, lo)        # insert at segment end
    while True:
        active = lo < hi
        if not active.any():
            return lo
        mid = (lo + hi) // 2
        mv = vals[np.where(active, mid, 0)]         # gather live lanes only
        if side == "left":
            go_right = active & (mv < target)
        else:
            go_right = active & (mv <= target)
        lo = np.where(go_right, mid + 1, lo)
        hi = np.where(active & ~go_right, mid, hi)


def f32_ceil(x: np.ndarray) -> np.ndarray:
    """Smallest float32 >= x, elementwise (float64 in, float32 out).

    Lets the batched row filter compare float32 records against float64 rect
    bounds entirely in float32: for any float32 ``v`` and float64 bound ``c``,
    ``v >= c  <=>  v >= f32_ceil(c)`` and ``v < c  <=>  v < f32_ceil(c)``,
    because no float32 lies strictly between a float64 and its float32
    round-up.  Infinities pass through.
    """
    x = np.asarray(x, dtype=np.float64)
    with np.errstate(over="ignore"):
        y = x.astype(np.float32)
        rounded_down = y.astype(np.float64) < x
        # nextafter past f32 max overflows to +inf — the correct ceil there
        return np.where(rounded_down, np.nextafter(y, np.float32(np.inf)), y)


def gather_ranges(los: np.ndarray, his: np.ndarray,
                  lens: Optional[np.ndarray] = None) -> np.ndarray:
    """Concatenate ``arange(lo, hi)`` for many (lo, hi) pairs, vectorised.

    ``lens`` may be supplied when the caller has already computed the
    clamped lengths ``maximum(his - los, 0)`` (the batched query path needs
    them anyway for its query-id expansion) so the (query, cell) expansion
    does a single pass over the pairs.
    """
    los = np.asarray(los, dtype=np.int64)
    his = np.asarray(his, dtype=np.int64)
    if lens is None:
        lens = np.maximum(his - los, 0)
    total = int(lens.sum())
    if total == 0:
        return np.empty(0, dtype=np.int64)
    cum = np.concatenate([[0], np.cumsum(lens)[:-1]])
    return np.repeat(los - cum, lens) + np.arange(total, dtype=np.int64)


def fit_cells_per_dim(n_grid_dims: int, budget_cells: int) -> int:
    """Largest per-dim cell count whose directory stays within budget.

    Implements the paper's §8.2.1 rule: 'we limit any index that would require
    more memory overhead for its index directory than memory occupied by the
    underlying data itself'.
    """
    if n_grid_dims == 0:
        return 1
    c = max(int(budget_cells ** (1.0 / n_grid_dims)), 1)
    while (c + 1) ** n_grid_dims <= budget_cells:
        c += 1
    return c


@dataclasses.dataclass
class _QueryStats:
    cells_probed: int = 0
    rows_scanned: int = 0
    rows_matched: int = 0


@dataclasses.dataclass
class BatchStats:
    """Planning-stage work counters for one ``query_batch`` call.

    ``cells_probed``/``rows_scanned`` come from the index's planning stage
    (candidate (query, cell) pairs and scan-window rows respectively), so
    backend comparisons can report work done, not just wall-clock QPS.
    ``fallbacks`` counts device waves that overflowed ``cell_cap`` and were
    re-answered by the numpy path (DESIGN.md §4 submit-time overflow
    contract); ``hit_overflows`` counts individual queries whose exact
    device hit count exceeded ``hit_cap`` and were re-answered on the host
    at drain time (§4 drain-time overflow contract).
    """
    queries: int = 0
    cells_probed: int = 0
    rows_scanned: int = 0
    backend: str = "numpy"
    fallbacks: int = 0
    hit_overflows: int = 0

    def merge(self, other: "BatchStats") -> "BatchStats":
        return BatchStats(
            queries=max(self.queries, other.queries),
            cells_probed=self.cells_probed + other.cells_probed,
            rows_scanned=self.rows_scanned + other.rows_scanned,
            backend=self.backend,
            fallbacks=self.fallbacks + other.fallbacks,
            hit_overflows=self.hit_overflows + other.hit_overflows,
        )


class GridFile:
    """Multidimensional grid index over a chosen subset of attributes.

    Parameters
    ----------
    data : (N, D) float array — FULL rows (all attributes) are stored so the
        final predicate can always be evaluated, even on non-indexed dims.
    index_dims : which attributes get index structure (grid lines or sort).
    cells_per_dim : grid lines per gridded attribute.
    sort_dim : attribute (member of index_dims) kept OUT of the grid and
        sorted inside each cell; None disables the optimisation (pure grid).
    quantile : CDF-aligned boundaries when True (paper/Column-Files style),
        uniform min..max boundaries when False (Uniform-Grid baseline).
    row_ids : original identities of ``data`` rows (defaults to arange(N)).
    backend : ``"numpy"`` (default, the exact host path and correctness
        oracle) or ``"device"`` — route ``query_batch`` through the frozen
        jitted device plan (DESIGN.md §4), falling back to numpy when a
        wave's candidate cells overflow the plan's cap.
    device_opts : kwargs for ``engine.device.DevicePlan`` (cell_cap,
        hit_cap, tile, min_bucket, use_pallas, interpret).
    epoch : snapshot version label (DESIGN.md §5).  A grid file is an
        immutable snapshot of one epoch; the mutable lifecycle
        (``COAXIndex.compact``) replaces it with a new-epoch instance, which
        is what invalidates any frozen ``DevicePlan`` built from it.
    """

    def __init__(
        self,
        data: np.ndarray,
        index_dims: Sequence[int],
        cells_per_dim: int,
        sort_dim: Optional[int] = None,
        quantile: bool = True,
        row_ids: Optional[np.ndarray] = None,
        backend: str = "numpy",
        device_opts: Optional[dict] = None,
        epoch: int = 0,
    ):
        data = np.ascontiguousarray(data, dtype=np.float32)
        self.epoch = int(epoch)
        n, d_full = data.shape
        self.n_rows = n
        self.d_full = d_full
        self.index_dims = list(index_dims)
        self.sort_dim = sort_dim
        if sort_dim is not None and sort_dim not in self.index_dims:
            raise ValueError("sort_dim must be one of index_dims")
        self.grid_dims = [d for d in self.index_dims if d != sort_dim]
        self.cells_per_dim = int(cells_per_dim)
        self.quantile = quantile

        # --- grid-line boundaries (inner edges only: cells+1 edges total, we
        # store the cells-1 inner ones; outermost cells are open-ended) ------
        self.inner_edges: List[np.ndarray] = []
        for d in self.grid_dims:
            col = data[:, d] if n else np.zeros(1, np.float32)
            if quantile:
                qs = np.linspace(0.0, 1.0, self.cells_per_dim + 1)[1:-1]
                edges = np.quantile(col, qs) if n else np.zeros(0)
            else:
                lo, hi = (float(col.min()), float(col.max())) if n else (0.0, 1.0)
                edges = np.linspace(lo, hi, self.cells_per_dim + 1)[1:-1]
            self.inner_edges.append(np.asarray(edges, dtype=np.float64))

        # --- assign rows to cells, order rows by (cell, sort value) --------
        c = self.cells_per_dim
        n_cells = c ** len(self.grid_dims)
        if n:
            flat = np.zeros(n, dtype=np.int64)
            for edges, d in zip(self.inner_edges, self.grid_dims):
                flat = flat * c + np.searchsorted(edges, data[:, d], side="right")
            if sort_dim is not None:
                order = np.lexsort((data[:, sort_dim], flat))
            else:
                order = np.argsort(flat, kind="stable")
            self.rows = np.ascontiguousarray(data[order])
            self.row_ids = (
                np.arange(n, dtype=np.int64)[order]
                if row_ids is None
                else np.asarray(row_ids, dtype=np.int64)[order]
            )
            counts = np.bincount(flat, minlength=n_cells)
        else:
            self.rows = data
            self.row_ids = np.empty(0, dtype=np.int64)
            counts = np.zeros(n_cells, dtype=np.int64)
        self.offsets = np.concatenate([[0], np.cumsum(counts)]).astype(np.int64)
        self.sort_vals = (
            np.ascontiguousarray(self.rows[:, sort_dim]) if sort_dim is not None else None
        )
        # certified once so batched_searchsorted can take its all-+inf exit
        self._sort_finite = bool(
            np.isfinite(self.sort_vals).all()) if self.sort_vals is not None else True
        # certified once so the batch filter may skip unconstrained dims: a
        # +inf/NaN record value fails `v < +inf` / any compare in the exact
        # scalar and device paths, so the skip is only sound on finite data
        self._rows_finite = bool(np.isfinite(self.rows).all()) if n else True
        self.last_query_stats = _QueryStats()
        self.last_batch_stats = BatchStats()
        self.device_opts = device_opts
        self._device_plan = None
        self._device_plan_failed = False
        self.backend = backend

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Snapshot state (DESIGN.md §7.3): the cell-ordered row block, ids,
        directory and build parameters — everything ``from_state`` needs to
        resurrect this exact epoch without re-sorting or re-quantiling.
        Arrays are the live ones (callers serialise; ``np.savez`` copies)."""
        return {
            "rows": self.rows,
            "row_ids": self.row_ids,
            "offsets": self.offsets,
            "inner_edges": (np.stack(self.inner_edges) if self.inner_edges
                            else np.empty((0, max(self.cells_per_dim - 1, 0)),
                                          np.float64)),
            "meta": {
                "d_full": self.d_full,
                "index_dims": self.index_dims,
                "cells_per_dim": self.cells_per_dim,
                "sort_dim": self.sort_dim,
                "quantile": self.quantile,
                "epoch": self.epoch,
            },
        }

    @classmethod
    def from_state(cls, state: dict, backend: str = "numpy",
                   device_opts: Optional[dict] = None) -> "GridFile":
        """Rebuild a frozen grid file from ``state_dict`` output, bypassing
        the sort/quantile build — the warm-restart path (DESIGN.md §7.3).
        The restored instance is bit-identical to the saved one in every
        query-visible respect; its device plan is rebuilt lazily on first
        device wave, exactly like a post-compaction epoch."""
        meta = state["meta"]
        gf = cls.__new__(cls)
        gf.epoch = int(meta["epoch"])
        gf.rows = np.ascontiguousarray(state["rows"], dtype=np.float32)
        gf.n_rows = gf.rows.shape[0]
        gf.d_full = int(meta["d_full"])
        gf.index_dims = [int(d) for d in meta["index_dims"]]
        gf.sort_dim = None if meta["sort_dim"] is None else int(meta["sort_dim"])
        gf.grid_dims = [d for d in gf.index_dims if d != gf.sort_dim]
        gf.cells_per_dim = int(meta["cells_per_dim"])
        gf.quantile = bool(meta["quantile"])
        edges = np.asarray(state["inner_edges"], dtype=np.float64)
        gf.inner_edges = [np.ascontiguousarray(edges[i])
                          for i in range(len(gf.grid_dims))]
        gf.row_ids = np.asarray(state["row_ids"], dtype=np.int64)
        gf.offsets = np.asarray(state["offsets"], dtype=np.int64)
        gf.sort_vals = (np.ascontiguousarray(gf.rows[:, gf.sort_dim])
                        if gf.sort_dim is not None else None)
        gf._sort_finite = bool(
            np.isfinite(gf.sort_vals).all()) if gf.sort_vals is not None else True
        gf._rows_finite = bool(np.isfinite(gf.rows).all()) if gf.n_rows else True
        gf.last_query_stats = _QueryStats()
        gf.last_batch_stats = BatchStats()
        gf.device_opts = device_opts
        gf._device_plan = None
        gf._device_plan_failed = False
        gf.backend = backend
        return gf

    # ------------------------------------------------------------------ #
    @property
    def backend(self) -> str:
        return self._backend

    @backend.setter
    def backend(self, value: str) -> None:
        if value not in ("numpy", "device"):
            raise ValueError(f"backend must be 'numpy' or 'device', got {value!r}")
        self._backend = value

    @property
    def device_plan(self):
        """Lazily-built frozen device plan (engine.device.DevicePlan).

        Built (and uploaded) once on first use; ``None`` when jax is
        unavailable, in which case the device backend silently degrades to
        the numpy path.
        """
        if self._device_plan is None and not self._device_plan_failed:
            try:
                from ..engine.device import DevicePlan
                self._device_plan = DevicePlan(self, **(self.device_opts or {}))
            except ImportError as e:
                import warnings
                warnings.warn(
                    f"device backend unavailable ({e}); using numpy path")
                self._device_plan_failed = True
        return self._device_plan

    # ------------------------------------------------------------------ #
    @property
    def n_cells(self) -> int:
        return self.cells_per_dim ** len(self.grid_dims)

    def memory_footprint(self) -> int:
        """Index-directory bytes: grid lines + cell offsets + sort marker.

        Row payloads are the data itself, not index overhead (paper §8.2.4
        compares *index* memory).  ``row_ids`` is likewise payload identity.
        """
        edges = sum(e.nbytes for e in self.inner_edges)
        return edges + self.offsets.nbytes

    # ------------------------------------------------------------------ #
    def _cell_ranges(self, nav_rect: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Per-grid-dim [first, last] cell coordinates overlapping nav_rect."""
        k = len(self.grid_dims)
        first = np.zeros(k, dtype=np.int64)
        last = np.full(k, self.cells_per_dim - 1, dtype=np.int64)
        for i, (edges, d) in enumerate(zip(self.inner_edges, self.grid_dims)):
            pos = self.index_dims.index(d)
            lo, hi = nav_rect[pos, 0], nav_rect[pos, 1]
            if np.isfinite(lo):
                first[i] = np.searchsorted(edges, lo, side="right")
            if np.isfinite(hi):
                last[i] = np.searchsorted(edges, hi, side="left")
        return first, last

    def _candidate_cells(self, nav_rect: np.ndarray) -> np.ndarray:
        first, last = self._cell_ranges(nav_rect)
        if np.any(last < first):
            return np.empty(0, dtype=np.int64)
        axes = [np.arange(f, l + 1, dtype=np.int64) for f, l in zip(first, last)]
        flat = np.zeros(1, dtype=np.int64)
        for ax in axes:
            flat = (flat[:, None] * self.cells_per_dim + ax[None, :]).reshape(-1)
        return flat

    def query(self, nav_rect: np.ndarray, filter_rect: Rect) -> np.ndarray:
        """Answer a range query.

        nav_rect : (len(index_dims), 2) constraints on the INDEXED dims, in
            index_dims order — for COAX this is the translated rect (Eq. 2).
        filter_rect : (D, 2) the ORIGINAL full-dimensional predicate; applied
            to every scanned row (translation over-approximates, §7.1).

        Returns sorted original row ids.
        """
        stats = _QueryStats()
        cells = self._candidate_cells(nav_rect)
        stats.cells_probed = int(cells.size)
        if cells.size == 0:
            self.last_query_stats = stats
            return np.empty(0, dtype=np.int64)

        blk_lo = self.offsets[cells]
        blk_hi = self.offsets[cells + 1]
        if self.sort_dim is not None:
            pos = self.index_dims.index(self.sort_dim)
            q_lo, q_hi = nav_rect[pos, 0], nav_rect[pos, 1]
            sv = self.sort_vals
            # binary search inside every candidate cell block at once (§6)
            lo_idx = blk_lo
            hi_idx = blk_hi
            if np.isfinite(q_lo):
                lo_idx = batched_searchsorted(sv, blk_lo, blk_hi, q_lo, "left",
                                              vals_finite=self._sort_finite)
            if np.isfinite(q_hi):
                hi_idx = batched_searchsorted(sv, lo_idx, blk_hi, q_hi, "left",
                                              vals_finite=self._sort_finite)
            blk_lo, blk_hi = lo_idx, hi_idx

        idx = gather_ranges(blk_lo, blk_hi)
        stats.rows_scanned = int(idx.size)
        if idx.size == 0:
            self.last_query_stats = stats
            return np.empty(0, dtype=np.int64)
        hit = rect_contains(filter_rect, self.rows[idx])
        out = self.row_ids[idx[hit]]
        stats.rows_matched = int(out.size)
        self.last_query_stats = stats
        return np.sort(out)

    # ------------------------------------------------------------------ #
    # Batched execution path (DESIGN.md §2): B queries share one directory
    # probe and one fused scan instead of B python round-trips.
    # ------------------------------------------------------------------ #
    def plan_batch(self, nav_rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Vectorised directory probe for a batch of nav-rects.

        nav_rects : (B, len(index_dims), 2) translated constraints, in
            index_dims order (the batched analogue of ``query``'s nav_rect).

        Returns ``(query_ids, cells)`` — a flat list of candidate (query,
        cell) pairs covering, for every query, exactly the cells
        ``_candidate_cells`` would visit.  Cells are enumerated per query in
        the same row-major order as the scalar path.
        """
        nav_rects = np.asarray(nav_rects, dtype=np.float64)
        b = nav_rects.shape[0]
        k = len(self.grid_dims)
        c = self.cells_per_dim
        if b == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        if k == 0:
            # single cell 0 per query
            return np.arange(b, dtype=np.int64), np.zeros(b, np.int64)

        first = np.zeros((b, k), dtype=np.int64)
        last = np.full((b, k), c - 1, dtype=np.int64)
        for i, (edges, d) in enumerate(zip(self.inner_edges, self.grid_dims)):
            pos = self.index_dims.index(d)
            lo = nav_rects[:, pos, 0]
            hi = nav_rects[:, pos, 1]
            # searchsorted(±inf) lands on the open outermost cells, matching
            # the scalar path's finite-only probing.
            first[:, i] = np.searchsorted(edges, lo, side="right")
            last[:, i] = np.searchsorted(edges, hi, side="left")

        counts = last - first + 1                       # (B, k) cells per dim
        n_cells = np.where((counts > 0).all(axis=1), counts.prod(axis=1), 0)
        total = int(n_cells.sum())
        if total == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)

        qids = np.repeat(np.arange(b, dtype=np.int64), n_cells)
        starts = np.concatenate([[0], np.cumsum(n_cells)[:-1]])
        local = np.arange(total, dtype=np.int64) - np.repeat(starts, n_cells)

        # Mixed-radix decode of the per-query local cell index into per-dim
        # coordinates (last grid dim least significant, like the scalar path).
        safe = np.maximum(counts, 1)
        strides = np.ones((b, k), dtype=np.int64)
        for i in range(k - 2, -1, -1):
            strides[:, i] = strides[:, i + 1] * safe[:, i + 1]
        flat = np.zeros(total, dtype=np.int64)
        for i in range(k):
            digit = (local // strides[qids, i]) % safe[qids, i]
            flat = flat * c + (first[qids, i] + digit)
        return qids, flat

    def query_batch(
        self, nav_rects: np.ndarray, filter_rects: np.ndarray
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Answer B range queries in one vectorised pass.

        nav_rects    : (B, len(index_dims), 2) translated constraints.
        filter_rects : (B, D, 2) the ORIGINAL full predicates, applied to
            every scanned row of the owning query.

        Returns ``(query_ids, row_ids)`` — the flat hit list, sorted by
        (query_id, row_id); per query it equals ``query(nav, filter)``.

        ``backend="device"`` routes through the frozen jitted device plan
        (DESIGN.md §4) under the contract that ``nav_rects``
        over-approximates ``filter_rects`` on the indexed dims — true for
        Eq. 2 translation and for nav == filter; waves whose candidate
        cells overflow the plan's cap fall back to this numpy path.
        """
        nav_rects = np.asarray(nav_rects, dtype=np.float64)
        filter_rects = np.asarray(filter_rects, dtype=np.float64)
        b = nav_rects.shape[0]
        fallbacks = 0
        if self._backend == "device" and b:
            plan = self.device_plan
            if plan is not None:
                res = plan.run_wave(nav_rects, filter_rects)
                if res is not None:
                    out_q, out_r, s = res
                    self.last_batch_stats = BatchStats(
                        queries=b, cells_probed=s["cells_probed"],
                        rows_scanned=s["rows_scanned"], backend="device",
                        hit_overflows=s.get("hit_overflows", 0))
                    return out_q, out_r
                fallbacks = 1                   # cell_cap overflow -> numpy
        return self._query_batch_numpy(nav_rects, filter_rects, fallbacks)

    def _query_batch_numpy(
        self, nav_rects: np.ndarray, filter_rects: np.ndarray,
        fallbacks: int = 0,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """The exact host implementation of ``query_batch`` (and the device
        backend's overflow fallback / correctness oracle).

        Telemetry (DESIGN.md §10.1): each pipeline stage — directory
        *probe*, in-cell segment *search*, exact row *filter* — folds its
        wall time into ``coax_stage_seconds{stage,backend="numpy"}``, the
        per-stage breakdown ``bench_queries.py --telemetry`` reports."""
        stats = BatchStats(queries=int(nav_rects.shape[0]),
                           backend="numpy", fallbacks=fallbacks)
        self.last_batch_stats = stats
        with obs.stage_timer("probe"):
            qids, cells = self.plan_batch(nav_rects)
        stats.cells_probed = int(cells.size)
        if cells.size == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)

        with obs.stage_timer("search"):
            blk_lo = self.offsets[cells]
            blk_hi = self.offsets[cells + 1]
            if self.sort_dim is not None and self.n_rows:
                pos = self.index_dims.index(self.sort_dim)
                q_lo = nav_rects[qids, pos, 0]          # per-(query,cell) targets
                q_hi = nav_rects[qids, pos, 1]
                sv = self.sort_vals
                blk_lo = batched_searchsorted(sv, blk_lo, blk_hi, q_lo, "left",
                                              vals_finite=self._sort_finite)
                blk_hi = batched_searchsorted(sv, blk_lo, blk_hi, q_hi, "left",
                                              vals_finite=self._sort_finite)

        with obs.stage_timer("filter"):
            lens = np.maximum(blk_hi - blk_lo, 0)
            idx = gather_ranges(blk_lo, blk_hi, lens)   # one (query,cell) pass
            stats.rows_scanned = int(idx.size)
            if idx.size == 0:
                return np.empty(0, np.int64), np.empty(0, np.int64)
            row_q = np.repeat(qids, lens)               # owning query per row
            rows = self.rows[idx]                       # (T, D) one f32 gather

            # Row filter in float32 with ceil-rounded bounds (exact: see
            # ``f32_ceil``), one dim at a time so temporaries stay (T,)-sized —
            # float64 (T, D) broadcasts are the batch path's cache killer.
            lo32 = f32_ceil(filter_rects[:, :, 0])      # (B, D)
            hi32 = f32_ceil(filter_rects[:, :, 1])
            hit = np.ones(idx.size, dtype=bool)
            for j in range(self.d_full):
                if self._rows_finite and np.isneginf(lo32[:, j]).all() \
                        and np.isposinf(hi32[:, j]).all():
                    continue                            # dim unconstrained
                v = rows[:, j]
                np.logical_and(hit, v >= lo32[row_q, j], out=hit)
                np.logical_and(hit, v < hi32[row_q, j], out=hit)
            out_q = row_q[hit]
            out_r = self.row_ids[idx[hit]]
            order = np.lexsort((out_r, out_q))
            return out_q[order], out_r[order]
