"""COAX query translation (paper §4, Eq. 2).

A constraint on a *dependent* attribute ``Cd in [lo, hi)`` is mapped through the
inverse soft-FD model onto the *indexed* (predictor) attribute ``Cx``.  Because
every primary-index record satisfies

    m*x + b - eps_lb  <=  d  <=  m*x + b + eps_ub          (Eq. 1)

a record can only match ``d >= lo`` if ``m*x + b + eps_ub >= lo`` and can only
match ``d < hi`` if ``m*x + b - eps_lb < hi``.  Solving for x (slope sign aware)
gives the translated interval; the final constraint on x is the INTERSECTION of
the translated interval and any direct constraint on x (Eq. 2 / Fig. 2).

Translation over-approximates: the scanned S-box contains but may exceed the
result R-box (paper §7.1), so the engine must still apply the original full
predicate to scanned rows.  These helpers are pure and dual-backend: they work
on numpy scalars/arrays and on jnp arrays inside jit.
"""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np

from .types import FDGroup, LinearModel, Rect

__all__ = [
    "translate_dependent_interval",
    "translate_rect",
    "translate_rects",
    "reduced_dims",
]


def translate_dependent_interval(
    model: LinearModel, lo: float, hi: float
) -> Tuple[float, float]:
    """Map a dependent-attribute interval [lo, hi) to predictor space.

    Returns the (x_lo, x_hi) interval outside which NO primary-index record can
    satisfy the dependent constraint.  Handles both slope signs; a zero slope
    never reaches here (detection rejects near-flat models).
    """
    m, b = model.m, model.b
    # Record matches only if  m*x + b + eps_ub >= lo  AND  m*x + b - eps_lb <= hi.
    lo_numer = lo - b - model.eps_ub
    hi_numer = hi - b + model.eps_lb
    if m > 0:
        return lo_numer / m, hi_numer / m
    return hi_numer / m, lo_numer / m  # slope < 0 flips the interval


def translate_rect(rect: Rect, groups: Sequence[FDGroup], keep_dims: Sequence[int]) -> Rect:
    """Project a full-dimensional query rect onto the indexed dimensions.

    For every FD group, each constrained dependent contributes a translated
    interval on the group's predictor; all intervals (plus the predictor's own
    direct constraint) are intersected (Eq. 2).  Constraints on dims in
    ``keep_dims`` pass through unchanged.

    Returns a (len(keep_dims), 2) rect in the order of ``keep_dims``.
    """
    rect = np.asarray(rect, dtype=np.float64)
    n_dims = rect.shape[0]
    lo = rect[:, 0].copy()
    hi = rect[:, 1].copy()

    # Start from the direct constraints on the kept dims.
    out_lo = {d: lo[d] for d in keep_dims}
    out_hi = {d: hi[d] for d in keep_dims}

    for g in groups:
        p = g.predictor
        if p not in out_lo:  # predictor not indexed (shouldn't happen) -> skip
            continue
        for d in g.dependents:
            if not (np.isfinite(lo[d]) or np.isfinite(hi[d])):
                continue  # dependent unconstrained: nothing to translate
            t_lo, t_hi = translate_dependent_interval(g.models[d], lo[d], hi[d])
            out_lo[p] = max(out_lo[p], t_lo)
            out_hi[p] = min(out_hi[p], t_hi)

    reduced = np.empty((len(keep_dims), 2), dtype=np.float64)
    for k, d in enumerate(keep_dims):
        reduced[k, 0] = out_lo[d]
        reduced[k, 1] = max(out_hi[d], out_lo[d])  # keep lo<=hi (empty range ok)
    return reduced


def translate_rects(
    rects: np.ndarray, groups: Sequence[FDGroup], keep_dims: Sequence[int]
) -> np.ndarray:
    """Batched Eq. 2: project B full rects onto the indexed dims at once.

    ``rects`` is (B, D, 2); returns (B, len(keep_dims), 2) nav-rects in
    ``keep_dims`` order — BIT-identical to ``translate_rect`` applied per
    row (the property test in ``tests/test_exactness_props.py`` holds the
    two to that), but one vectorised pass over the batch (the batched
    engine's translation stage).

    A dependent with no finite bound is skipped per query, mirroring the
    scalar path: a fully unconstrained dependent ``(-inf, +inf)`` would
    translate to a no-op interval anyway, while a degenerate all-infinite
    constraint like ``[+inf, +inf)`` must not clamp the nav-rect the
    scalar path leaves open.
    """
    rects = np.asarray(rects, dtype=np.float64)
    if rects.ndim != 3 or rects.shape[-1] != 2:
        raise ValueError(f"rects must be (B, D, 2), got {rects.shape}")
    lo = rects[:, :, 0]                               # (B, D)
    hi = rects[:, :, 1]

    keep = list(keep_dims)
    pos = {d: k for k, d in enumerate(keep)}
    out_lo = lo[:, keep].copy()                       # (B, K) direct constraints
    out_hi = hi[:, keep].copy()

    for g in groups:
        if g.predictor not in pos:                    # predictor not indexed
            continue
        k = pos[g.predictor]
        for d in g.dependents:
            mdl = g.models[d]
            lo_numer = lo[:, d] - mdl.b - mdl.eps_ub  # (B,)
            hi_numer = hi[:, d] - mdl.b + mdl.eps_lb
            if mdl.m > 0:
                t_lo, t_hi = lo_numer / mdl.m, hi_numer / mdl.m
            else:
                t_lo, t_hi = hi_numer / mdl.m, lo_numer / mdl.m
            # same per-query skip as the scalar path: only a dependent with
            # a finite bound constrains the predictor
            con = np.isfinite(lo[:, d]) | np.isfinite(hi[:, d])
            out_lo[:, k] = np.where(con, np.maximum(out_lo[:, k], t_lo),
                                    out_lo[:, k])
            out_hi[:, k] = np.where(con, np.minimum(out_hi[:, k], t_hi),
                                    out_hi[:, k])

    out_hi = np.maximum(out_hi, out_lo)               # keep lo<=hi (empty ok)
    return np.stack([out_lo, out_hi], axis=-1)


def reduced_dims(n_dims: int, groups: Sequence[FDGroup]) -> List[int]:
    """Indexed (kept) dimensions: everything that is not a dependent."""
    dropped = {d for g in groups for d in g.dependents}
    return [d for d in range(n_dims) if d not in dropped]
