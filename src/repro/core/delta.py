"""Delta plane: the write path of the mutable index lifecycle (DESIGN.md §5).

A ``DeltaPlane`` is the mutable companion of one frozen sub-index snapshot
(a ``GridFile`` epoch): an append-only log of inserted rows plus a tombstone
set for deletes.  Every query's full predicate is evaluated *exactly*
against every live log row — correctness never depends on any learned
structure — but the plane no longer scans the whole log linearly per query.

Tiered sorted runs (DESIGN.md §5.3): the canonical append-order log is
untouched (it is the WAL-replay image and the compaction feed), but the
plane maintains *derived* sorted views over prefixes of it:

* **L0** — the unorganized tail of the log, at most ``l0_spill`` rows,
  scanned densely per query (it is tiny by construction);
* **L1+ runs** — when L0 reaches ``l0_spill`` rows it *spills*: a stable
  argsort of the tail's ``key_dim`` values becomes a new sorted run
  (a permutation of log positions + their sorted keys).  Adjacent runs
  tier-merge while the older neighbour is ≤ 2x the newer one, so run
  count stays O(log n) and merge work is amortized O(log n) per row.

A query then probes each run with two ``searchsorted`` calls on the key
dimension — ``[searchsorted(keys, lo), searchsorted(keys, hi))`` is exactly
the half-open membership ``lo <= key < hi`` after the f32→f64 upcast — and
evaluates the remaining dimensions only on rows inside the window.  Run
structure is a cache detail for *results* (any partition of the log yields
the same hit set), but the ``organized`` boundary is serialized so the L0
fill level — and therefore spill-triggered compaction-check timing — is
bit-reproducible across snapshot/restore (DESIGN.md §7.3).

Tombstones cover two id populations with one mechanism:

* *base* ids — rows frozen into the snapshot this plane shadows; the
  snapshot keeps returning them, so query paths mask them out with
  ``is_dead``;
* *log* ids — rows inserted after the snapshot; ``scan``/``scan_batch``
  exclude them at the source.  The log itself is never rewritten (append
  only); space is reclaimed at compaction, when live log rows merge into
  the next snapshot epoch and the plane resets empty.

Exactness argument (delta ∪ snapshot; DESIGN.md §5): scans compare the
float32 log rows against the float64 rect with numpy's usual upcast —
mathematically ``lo <= v < hi`` on the exact f32 value, the same membership
test the frozen numpy/device paths implement (``f32_ceil`` rounding is
provably equivalent, see ``gridfile.f32_ceil``).  The key-dim window probe
is the same predicate evaluated by binary search on the sorted (upcast)
keys, so a row hits in a run iff it would hit in the dense scan.  The union
(snapshot hits − tombstones) ∪ (live log hits) equals a scratch rebuild
from the final row set, bit for bit, on every backend.

Durability (DESIGN.md §7): the plane is exactly the state the write-ahead
log reconstructs — ``storage.wal`` records one frame per ``COAXIndex``
insert/delete call, and replaying them through the ordinary write paths
refills these logs and tombstone sets bit for bit.  ``state_dict`` /
``from_state`` additionally let a mid-epoch snapshot (``COAXIndex.save``)
carry the plane directly, so restore cost is bounded by the WAL tail, not
the epoch's whole write history.
"""
from __future__ import annotations

import time
from typing import List, Optional, Tuple

import numpy as np

from .. import obs
from .types import Rect, rect_contains, sorted_contains

__all__ = ["DeltaPlane", "FrozenDelta"]

L0_SPILL_DEFAULT = 256


def _multi_arange(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenated ``arange(starts[i], starts[i]+lens[i])`` without a
    Python loop (the cumsum trick, same as ``engine.device._multi_arange``).
    All ``lens`` must be > 0."""
    total = int(lens.sum())
    step = np.ones(total, dtype=np.int64)
    step[0] = starts[0]
    ends = starts + lens
    offs = np.cumsum(lens[:-1])
    step[offs] = starts[1:] - ends[:-1] + 1
    return np.cumsum(step)


class DeltaPlane:
    """Append log of inserted rows + tombstone set for one sub-index,
    organized into tiered sorted runs for sub-linear range probes.

    Parameters
    ----------
    n_dims : attribute count of the table (log rows are (M, n_dims) f32).
    key_dim : the dimension runs are sorted on — the owning index passes
        its first FD-dependent attribute (the dimension range queries are
        translated onto, so windows are selective) or its sort dim.
    l0_spill : L0 rows that trigger a spill into a sorted run.
    """

    def __init__(self, n_dims: int, key_dim: int = 0,
                 l0_spill: int = L0_SPILL_DEFAULT):
        self.n_dims = int(n_dims)
        key_dim = int(key_dim)
        self.key_dim = key_dim if 0 <= key_dim < self.n_dims else 0
        self.l0_spill = max(int(l0_spill), 1)
        self._chunks: List[np.ndarray] = []      # appended (m, D) f32 blocks
        self._id_chunks: List[np.ndarray] = []   # appended (m,) i64 blocks
        self._dead: set = set()                  # tombstoned ids (log or base)
        self.n_log = 0                           # rows ever appended
        self.n_log_dead = 0                      # log rows later tombstoned
        self.n_base_dead = 0                     # snapshot rows tombstoned
        # tiered runs: (abs log positions, sorted f64 keys) per run, oldest
        # first; positions [_organized, n_log) are the L0 tail
        self._runs: List[Tuple[np.ndarray, np.ndarray]] = []
        self._organized = 0
        self.spills = 0                          # L0 → run spills performed
        self.merges = 0                          # tier merges performed
        self.rows_probed = 0                     # candidate rows ever touched
        self.last_scan_probed = 0                # ... by the latest scan_batch
        self._rows_cache: Optional[np.ndarray] = None
        self._rows64_cache: Optional[np.ndarray] = None
        self._ids_cache: Optional[np.ndarray] = None
        self._log_id_set: set = set()            # O(1) tombstone membership
        self._live_cache: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._alive_cache: Optional[np.ndarray] = None
        self._dead_cache: Optional[np.ndarray] = None
        self._order_cache: Optional[np.ndarray] = None   # argsort of log_ids

    # ------------------------------------------------------------------ #
    @property
    def n_live(self) -> int:
        """Live (non-tombstoned) log rows."""
        return self.n_log - self.n_log_dead

    @property
    def n_tombstones(self) -> int:
        """All tombstones this plane holds (log + base)."""
        return self.n_log_dead + self.n_base_dead

    @property
    def n_runs(self) -> int:
        return len(self._runs)

    @property
    def l0_rows(self) -> int:
        """Rows in the unorganized L0 tail."""
        return self.n_log - self._organized

    def __len__(self) -> int:
        return self.n_live

    # ------------------------------------------------------------------ #
    def insert(self, rows: np.ndarray, ids: np.ndarray) -> int:
        """Append rows with their (new, never-seen) original ids.

        Returns the number of L0 spills this append caused (0 or 1) — the
        owning index uses a spill as an amortized compaction-check signal.
        """
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        ids = np.asarray(ids, dtype=np.int64)
        if rows.ndim != 2 or rows.shape[1] != self.n_dims:
            raise ValueError(f"rows must be (m, {self.n_dims}), got {rows.shape}")
        if rows.shape[0] != ids.shape[0]:
            raise ValueError("rows/ids length mismatch")
        m = rows.shape[0]
        if m == 0:
            return 0
        self._chunks.append(rows)
        self._id_chunks.append(ids)
        self._log_id_set.update(ids.tolist())
        self.n_log += m
        self._rows_cache = self._ids_cache = None
        self._rows64_cache = None
        self._live_cache = None
        self._order_cache = None
        if self._alive_cache is not None:   # fresh ids are never dead
            self._alive_cache = np.concatenate(
                [self._alive_cache, np.ones(m, dtype=bool)])
        if self.n_log - self._organized >= self.l0_spill:
            self._spill()
            return 1
        return 0

    def _spill(self) -> None:
        """Organize the whole L0 tail into one sorted run, then tier-merge."""
        lo, hi = self._organized, self.n_log
        keys = self._log_rows()[lo:hi, self.key_dim].astype(np.float64)
        order = np.argsort(keys, kind="stable")
        self._runs.append((np.arange(lo, hi, dtype=np.int64)[order],
                           keys[order]))
        self._organized = hi
        self.spills += 1
        # tier policy: merge while the older neighbour is not much bigger,
        # so run sizes stay geometric and run count O(log n)
        while (len(self._runs) >= 2
               and self._runs[-2][0].size <= 2 * self._runs[-1][0].size):
            p_new, k_new = self._runs.pop()
            p_old, k_old = self._runs.pop()
            keys = np.concatenate([k_old, k_new])
            order = np.argsort(keys, kind="stable")
            self._runs.append((np.concatenate([p_old, p_new])[order],
                               keys[order]))
            self.merges += 1

    def log_ids(self) -> np.ndarray:
        """All ids ever appended (dead included), in append order."""
        if self._ids_cache is None:
            self._ids_cache = (np.concatenate(self._id_chunks)
                               if self._id_chunks else np.empty(0, np.int64))
        return self._ids_cache

    def _log_rows(self) -> np.ndarray:
        if self._rows_cache is None:
            self._rows_cache = (np.concatenate(self._chunks)
                                if self._chunks else
                                np.empty((0, self.n_dims), np.float32))
        return self._rows_cache

    def _log_rows64(self) -> np.ndarray:
        if self._rows64_cache is None:
            self._rows64_cache = self._log_rows().astype(np.float64)
        return self._rows64_cache

    def _alive_mask(self) -> np.ndarray:
        """Per-log-position liveness (False where the id was tombstoned)."""
        if self._alive_cache is None:
            if self._dead:
                self._alive_cache = ~sorted_contains(self.dead_ids(),
                                                     self.log_ids())
            else:
                self._alive_cache = np.ones(self.n_log, dtype=bool)
        return self._alive_cache

    # ------------------------------------------------------------------ #
    def tombstone_log(self, ids: np.ndarray) -> np.ndarray:
        """Tombstone the subset of ``ids`` (UNIQUE ids — the
        ``COAXIndex.delete`` contract) that are live rows of THIS log.

        Returns the boolean mask of ids absorbed (callers route the rest to
        base classification or to another plane).
        """
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0 or self.n_log == 0:
            return np.zeros(ids.shape, dtype=bool)
        # set membership: each delete touches a handful of ids against a
        # log that every insert grows — hashing beats re-sorting per call
        lset, dead = self._log_id_set, self._dead
        absorbed = np.fromiter(
            ((i in lset and i not in dead) for i in ids.tolist()),
            dtype=bool, count=ids.size)
        n_fresh = int(absorbed.sum())
        if n_fresh:
            self._dead.update(ids[absorbed].tolist())
            self.n_log_dead += n_fresh
            self._live_cache = self._dead_cache = self._alive_cache = None
        return absorbed

    def tombstone_base(self, ids: np.ndarray) -> int:
        """Tombstone snapshot ids (caller has verified they belong to this
        plane's base partition).  Returns the count newly dead."""
        ids = np.asarray(ids, dtype=np.int64)
        fresh = set(ids.tolist()) - self._dead
        self._dead |= fresh
        self.n_base_dead += len(fresh)
        if fresh:
            self._dead_cache = self._alive_cache = None
        return len(fresh)

    def dead_ids(self) -> np.ndarray:
        """Sorted array of every tombstoned id (log + base)."""
        if self._dead_cache is None:
            self._dead_cache = np.fromiter(
                self._dead, dtype=np.int64, count=len(self._dead))
            self._dead_cache.sort()
        return self._dead_cache

    def is_dead(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        if not self._dead:
            return np.zeros(ids.shape, dtype=bool)
        return sorted_contains(self.dead_ids(), ids)

    # ------------------------------------------------------------------ #
    def live_log(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, ids) of live log entries in APPEND order — the compaction
        feed (append order seeds the next epoch's sampling rng, so it is
        part of bit-identity; run order never leaks here)."""
        if self._live_cache is None:
            rows, ids = self._log_rows(), self.log_ids()
            if self.n_log_dead:
                keep = self._alive_mask()
                rows, ids = rows[keep], ids[keep]
            self._live_cache = (rows, ids)
        return self._live_cache

    def scan(self, rect: Rect) -> np.ndarray:
        """Exact scan: ids of live log rows inside ``rect`` (unsorted)."""
        rows, ids = self.live_log()
        if ids.size == 0:
            return np.empty(0, np.int64)
        return ids[rect_contains(np.asarray(rect, np.float64), rows)]

    def scan_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Exact batched scan: flat (query_ids, row_ids) over live log rows.

        Each sorted run is probed with two ``searchsorted`` calls per query
        on the key dim — ``[ss(keys, lo, 'left'), ss(keys, hi, 'left'))`` is
        exactly ``lo <= key < hi`` on the upcast f32 keys — and only rows
        inside the window are checked on the remaining dims (f64 compares
        against the f32 log rows are exact after upcast).  The L0 tail
        (< ``l0_spill`` rows) is scanned densely.  Pair order is arbitrary;
        callers lexsort the merged hit list.

        Telemetry (DESIGN.md §10): wall time folds into
        ``coax_stage_seconds{stage="delta_scan"}``; with tracing enabled
        each call is one ``delta.scan`` span under its wave.
        """
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        self.last_scan_probed = 0
        if b == 0 or self.n_live == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        t_start = time.perf_counter()
        try:
            with obs.span("delta.scan", queries=b, live=self.n_live):
                return self._scan_batch_inner(rects, b)
        finally:
            obs.stage_hist().observe(time.perf_counter() - t_start,
                                     stage="delta_scan", backend="numpy")

    def _scan_batch_inner(self, rects: np.ndarray, b: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
        rows64 = self._log_rows64()
        alive = self._alive_mask()
        k = self.key_dim
        lo_all = np.ascontiguousarray(rects[:, :, 0])   # (b, D) per-query
        hi_all = np.ascontiguousarray(rects[:, :, 1])   # bounds, gather-ready
        lo, hi = lo_all[:, k], hi_all[:, k]
        probed = 0
        qid_parts: List[np.ndarray] = []
        pos_parts: List[np.ndarray] = []
        for run_pos, keys in self._runs:
            s = np.searchsorted(keys, lo, side="left")
            e = np.searchsorted(keys, hi, side="left")
            lens = e - s
            nz = np.nonzero(lens > 0)[0]
            if nz.size == 0:
                continue
            flat = _multi_arange(s[nz], lens[nz])
            qf = np.repeat(nz, lens[nz])
            pf = run_pos[flat]
            probed += pf.size
            keep = alive[pf]
            qf, pf = qf[keep], pf[keep]
            if pf.size:
                # one bounds gather + two (m, D) compares instead of a
                # python loop of per-dim gathers; the key-dim column is
                # re-checked but the window already made it True
                sub = rows64[pf]
                ok = np.all((sub >= lo_all[qf]) & (sub < hi_all[qf]), axis=1)
                qf, pf = qf[ok], pf[ok]
            if pf.size:
                qid_parts.append(qf)
                pos_parts.append(pf)
        t0 = self._organized
        if t0 < self.n_log:                       # dense L0 tail scan
            tail = rows64[t0:]
            m = tail.shape[0]
            hit = np.ones((b, m), dtype=bool)
            for j in range(self.n_dims):
                v = tail[:, j]
                np.logical_and(hit, v[None, :] >= rects[:, j, 0][:, None], out=hit)
                np.logical_and(hit, v[None, :] < rects[:, j, 1][:, None], out=hit)
            hit &= alive[t0:][None, :]
            probed += b * m
            qf, pf = np.nonzero(hit)
            if pf.size:
                qid_parts.append(qf.astype(np.int64))
                pos_parts.append(pf.astype(np.int64) + t0)
        self.last_scan_probed = probed
        self.rows_probed += probed
        if not qid_parts:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        q = np.concatenate(qid_parts).astype(np.int64, copy=False)
        p = np.concatenate(pos_parts)
        return q, self.log_ids()[p]

    def rows_for_ids(self, ids: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """(found_mask, rows) for ``ids`` among this plane's log entries —
        the cache-admission gather (DESIGN.md §9.2): a query's hit ids must
        be re-joined to their row values so a contained sub-query can later
        be filtered from the cached superset without re-probing.  The
        argsort of the append-order ids is cached (reset on insert), so a
        gather is two ``searchsorted`` passes, not a re-sort per wave."""
        ids = np.asarray(ids, dtype=np.int64)
        lids = self.log_ids()
        if ids.size == 0 or lids.size == 0:
            return (np.zeros(ids.shape, dtype=bool),
                    np.empty((0, self.n_dims), np.float32))
        if self._order_cache is None:
            self._order_cache = np.argsort(lids, kind="stable")
        order = self._order_cache
        sids = lids[order]
        pos = np.searchsorted(sids, ids)
        pos[pos == sids.size] = sids.size - 1
        found = sids[pos] == ids
        return found, self._log_rows()[order[pos[found]]]

    def freeze(self) -> "FrozenDelta":
        """Immutable point-in-time image of the LIVE log — the delta half
        of a pinned-epoch MVCC read (DESIGN.md §9.3).  Rows are copied and
        upcast once, so later appends/tombstones on this plane can never
        leak into a pinned reader's answers."""
        rows, ids = self.live_log()
        return FrozenDelta(rows, ids)

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Serializable state: the append log (dead rows included, order
        preserved), the tombstone set, the split counters, and the
        ``organized`` run boundary (the L0 fill level must survive restore
        so spill-triggered check timing stays deterministic, §7.3).  Run
        *partitioning* is NOT serialized — any partition yields the same
        hit set, so restore rebuilds one run over the organized prefix."""
        return {
            "rows": self._log_rows(),
            "ids": self.log_ids(),
            "dead": self.dead_ids(),
            "n_log_dead": self.n_log_dead,
            "n_base_dead": self.n_base_dead,
            "organized": self._organized,
        }

    @classmethod
    def from_state(cls, n_dims: int, state: dict, key_dim: int = 0,
                   l0_spill: int = L0_SPILL_DEFAULT) -> "DeltaPlane":
        """Rebuild a plane from ``state_dict`` output.  The log lands as a
        single chunk — chunk granularity is a cache detail, every query and
        compaction path sees the concatenated log either way — and the
        organized prefix comes back as ONE sorted run."""
        dp = cls(n_dims, key_dim=key_dim, l0_spill=l0_spill)
        rows = np.ascontiguousarray(state["rows"], dtype=np.float32)
        ids = np.asarray(state["ids"], dtype=np.int64)
        if rows.shape[0]:
            dp._chunks.append(rows.reshape(-1, n_dims))
            dp._id_chunks.append(ids)
            dp._log_id_set = set(ids.tolist())
        dp.n_log = int(ids.shape[0])
        dp._dead = set(np.asarray(state["dead"], dtype=np.int64).tolist())
        dp.n_log_dead = int(state["n_log_dead"])
        dp.n_base_dead = int(state["n_base_dead"])
        organized = int(state.get("organized", 0))
        organized = min(max(organized, 0), dp.n_log)
        if organized:
            keys = rows[:organized, dp.key_dim].astype(np.float64)
            order = np.argsort(keys, kind="stable")
            dp._runs.append((order.astype(np.int64), keys[order]))
        dp._organized = organized
        return dp

    # ------------------------------------------------------------------ #
    def nbytes(self) -> int:
        """Bytes actually held: log rows + log ids + tombstone ids + the
        sorted-run views (one i64 position + one f64 key per organized row)."""
        return (self.n_log * self.n_dims * 4      # f32 rows
                + self.n_log * 8                  # i64 ids
                + len(self._dead) * 8             # i64 tombstones
                + self._organized * 16)           # run views (pos + key)

    def describe(self) -> dict:
        return {
            "log_rows": self.n_log,
            "live_rows": self.n_live,
            "tombstones": self.n_tombstones,
            "bytes": self.nbytes(),
            "key_dim": self.key_dim,
            "runs": len(self._runs),
            "run_sizes": [int(p.size) for p, _ in self._runs],
            "l0_rows": self.l0_rows,
            "spills": self.spills,
            "merges": self.merges,
            "rows_probed": self.rows_probed,
        }


class FrozenDelta:
    """Immutable snapshot of a ``DeltaPlane``'s live log at freeze time —
    the write-plane half of a pinned-epoch MVCC read (DESIGN.md §9.3).

    A pinned reader composes exactly what the live host path composes —
    (snapshot hits − frozen tombstones) ∪ frozen-delta hits — but against
    state that can never move: rows are a private f64 copy (the same upcast
    the live ``scan_batch`` compares under, so membership is bit-identical),
    and tombstones were already folded into the pin's frozen dead-id array.
    Run structure is deliberately NOT carried over: a pin is a bounded
    analytical read, the frozen log is bounded by the compaction trigger,
    and the dense scan is the simplest thing that is provably the same
    predicate."""

    def __init__(self, rows: np.ndarray, ids: np.ndarray):
        self._rows64 = np.array(rows, dtype=np.float64)   # private copy
        self._ids = np.array(ids, dtype=np.int64)

    @property
    def n_live(self) -> int:
        return int(self._ids.shape[0])

    def scan(self, rect: Rect) -> np.ndarray:
        """Ids of frozen live rows inside ``rect`` (unsorted)."""
        if self._ids.size == 0:
            return np.empty(0, np.int64)
        return self._ids[rect_contains(np.asarray(rect, np.float64),
                                       self._rows64)]

    def scan_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Exact batched scan over the frozen rows: flat (query_ids,
        row_ids), same half-open f64 predicate as ``DeltaPlane.scan_batch``
        — a pinned answer is bit-identical to what the live path returned
        at pin time."""
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        m = self._ids.shape[0]
        if b == 0 or m == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        hit = np.ones((b, m), dtype=bool)
        for j in range(self._rows64.shape[1]):
            v = self._rows64[:, j]
            np.logical_and(hit, v[None, :] >= rects[:, j, 0][:, None], out=hit)
            np.logical_and(hit, v[None, :] < rects[:, j, 1][:, None], out=hit)
        qf, pf = np.nonzero(hit)
        return qf.astype(np.int64), self._ids[pf]

    def nbytes(self) -> int:
        return int(self._rows64.nbytes + self._ids.nbytes)
