"""Delta plane: the write path of the mutable index lifecycle (DESIGN.md §5).

A ``DeltaPlane`` is the mutable companion of one frozen sub-index snapshot
(a ``GridFile`` epoch): an append-only log of inserted rows plus a tombstone
set for deletes.  The log is scanned *exactly* per query — every query's
full predicate is evaluated against every live log row — so correctness
never depends on any learned structure; the plane only has to stay small,
which is the compaction trigger's job (``COAXIndex.compact``).

Tombstones cover two id populations with one mechanism:

* *base* ids — rows frozen into the snapshot this plane shadows; the
  snapshot keeps returning them, so query paths mask them out with
  ``is_dead``;
* *log* ids — rows inserted after the snapshot; ``scan``/``scan_batch``
  exclude them at the source.  The log itself is never rewritten (append
  only); space is reclaimed at compaction, when live log rows merge into
  the next snapshot epoch and the plane resets empty.

Exactness argument (delta ∪ snapshot; DESIGN.md §5): scans compare the
float32 log rows against the float64 rect with numpy's usual upcast —
mathematically ``lo <= v < hi`` on the exact f32 value, the same membership
test the frozen numpy/device paths implement (``f32_ceil`` rounding is
provably equivalent, see ``gridfile.f32_ceil``).  A row therefore hits in
the delta iff it would hit after being compacted into a snapshot, and the
union  (snapshot hits − tombstones) ∪ (live log hits)  equals a scratch
rebuild from the final row set, bit for bit, on every backend.

Durability (DESIGN.md §7): the plane is exactly the state the write-ahead
log reconstructs — ``storage.wal`` records one frame per ``COAXIndex``
insert/delete call, and replaying them through the ordinary write paths
refills these logs and tombstone sets bit for bit.  ``state_dict`` /
``from_state`` additionally let a mid-epoch snapshot (``COAXIndex.save``)
carry the plane directly, so restore cost is bounded by the WAL tail, not
the epoch's whole write history.
"""
from __future__ import annotations

from typing import List, Optional, Tuple

import numpy as np

from .types import Rect, rect_contains

__all__ = ["DeltaPlane"]


class DeltaPlane:
    """Append log of inserted rows + tombstone set for one sub-index.

    Parameters
    ----------
    n_dims : attribute count of the table (log rows are (M, n_dims) f32).
    """

    def __init__(self, n_dims: int):
        self.n_dims = int(n_dims)
        self._chunks: List[np.ndarray] = []      # appended (m, D) f32 blocks
        self._id_chunks: List[np.ndarray] = []   # appended (m,) i64 blocks
        self._dead: set = set()                  # tombstoned ids (log or base)
        self.n_log = 0                           # rows ever appended
        self.n_log_dead = 0                      # log rows later tombstoned
        self.n_base_dead = 0                     # snapshot rows tombstoned
        self._rows_cache: Optional[np.ndarray] = None
        self._ids_cache: Optional[np.ndarray] = None
        self._live_cache: Optional[Tuple[np.ndarray, np.ndarray]] = None
        self._live64_cache: Optional[np.ndarray] = None
        self._dead_cache: Optional[np.ndarray] = None

    # ------------------------------------------------------------------ #
    @property
    def n_live(self) -> int:
        """Live (non-tombstoned) log rows."""
        return self.n_log - self.n_log_dead

    @property
    def n_tombstones(self) -> int:
        """All tombstones this plane holds (log + base)."""
        return self.n_log_dead + self.n_base_dead

    def __len__(self) -> int:
        return self.n_live

    # ------------------------------------------------------------------ #
    def insert(self, rows: np.ndarray, ids: np.ndarray) -> None:
        """Append rows with their (new, never-seen) original ids."""
        rows = np.ascontiguousarray(rows, dtype=np.float32)
        ids = np.asarray(ids, dtype=np.int64)
        if rows.ndim != 2 or rows.shape[1] != self.n_dims:
            raise ValueError(f"rows must be (m, {self.n_dims}), got {rows.shape}")
        if rows.shape[0] != ids.shape[0]:
            raise ValueError("rows/ids length mismatch")
        if rows.shape[0] == 0:
            return
        self._chunks.append(rows)
        self._id_chunks.append(ids)
        self.n_log += rows.shape[0]
        self._rows_cache = self._ids_cache = None
        self._live_cache = self._live64_cache = None

    def log_ids(self) -> np.ndarray:
        """All ids ever appended (dead included), in append order."""
        if self._ids_cache is None:
            self._ids_cache = (np.concatenate(self._id_chunks)
                               if self._id_chunks else np.empty(0, np.int64))
        return self._ids_cache

    def _log_rows(self) -> np.ndarray:
        if self._rows_cache is None:
            self._rows_cache = (np.concatenate(self._chunks)
                                if self._chunks else
                                np.empty((0, self.n_dims), np.float32))
        return self._rows_cache

    # ------------------------------------------------------------------ #
    def tombstone_log(self, ids: np.ndarray) -> np.ndarray:
        """Tombstone the subset of ``ids`` (UNIQUE ids — the
        ``COAXIndex.delete`` contract) that are live rows of THIS log.

        Returns the boolean mask of ids absorbed (callers route the rest to
        base classification or to another plane).
        """
        ids = np.asarray(ids, dtype=np.int64)
        if ids.size == 0 or self.n_log == 0:
            return np.zeros(ids.shape, dtype=bool)
        absorbed = np.isin(ids, self.log_ids())
        if self._dead:
            absorbed &= ~np.isin(ids, self.dead_ids())
        n_fresh = int(absorbed.sum())
        if n_fresh:
            self._dead.update(ids[absorbed].tolist())
            self.n_log_dead += n_fresh
            self._live_cache = self._live64_cache = self._dead_cache = None
        return absorbed

    def tombstone_base(self, ids: np.ndarray) -> int:
        """Tombstone snapshot ids (caller has verified they belong to this
        plane's base partition).  Returns the count newly dead."""
        ids = np.asarray(ids, dtype=np.int64)
        fresh = set(ids.tolist()) - self._dead
        self._dead |= fresh
        self.n_base_dead += len(fresh)
        if fresh:
            self._dead_cache = None
        return len(fresh)

    def dead_ids(self) -> np.ndarray:
        """Sorted array of every tombstoned id (log + base)."""
        if self._dead_cache is None:
            self._dead_cache = np.fromiter(
                self._dead, dtype=np.int64, count=len(self._dead))
            self._dead_cache.sort()
        return self._dead_cache

    def is_dead(self, ids: np.ndarray) -> np.ndarray:
        ids = np.asarray(ids, dtype=np.int64)
        if not self._dead:
            return np.zeros(ids.shape, dtype=bool)
        return np.isin(ids, self.dead_ids())

    # ------------------------------------------------------------------ #
    def live_log(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, ids) of live log entries — the compaction feed."""
        if self._live_cache is None:
            rows, ids = self._log_rows(), self.log_ids()
            if self.n_log_dead:
                keep = ~self.is_dead(ids)
                rows, ids = rows[keep], ids[keep]
            self._live_cache = (rows, ids)
        return self._live_cache

    def scan(self, rect: Rect) -> np.ndarray:
        """Exact scan: ids of live log rows inside ``rect`` (unsorted)."""
        rows, ids = self.live_log()
        if ids.size == 0:
            return np.empty(0, np.int64)
        return ids[rect_contains(np.asarray(rect, np.float64), rows)]

    def scan_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Exact batched scan: flat (query_ids, row_ids) over live log rows.

        One (B, M) boolean accumulator built one dimension at a time (the
        same temporaries discipline as ``GridFile._query_batch_numpy``);
        float64 compares against the f32 log rows are exact after upcast.
        """
        rects = np.asarray(rects, dtype=np.float64)
        rows, ids = self.live_log()
        b, m = rects.shape[0], ids.size
        if b == 0 or m == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        hit = np.ones((b, m), dtype=bool)
        if self._live64_cache is None:      # invalidated with _live_cache
            self._live64_cache = rows.astype(np.float64)
        rows64 = self._live64_cache
        for j in range(self.n_dims):
            v = rows64[:, j]
            np.logical_and(hit, v[None, :] >= rects[:, j, 0][:, None], out=hit)
            np.logical_and(hit, v[None, :] < rects[:, j, 1][:, None], out=hit)
        qids, pos = np.nonzero(hit)
        return qids.astype(np.int64), ids[pos]

    # ------------------------------------------------------------------ #
    def state_dict(self) -> dict:
        """Serializable state: the append log (dead rows included, order
        preserved), the tombstone set and the split counters."""
        return {
            "rows": self._log_rows(),
            "ids": self.log_ids(),
            "dead": self.dead_ids(),
            "n_log_dead": self.n_log_dead,
            "n_base_dead": self.n_base_dead,
        }

    @classmethod
    def from_state(cls, n_dims: int, state: dict) -> "DeltaPlane":
        """Rebuild a plane from ``state_dict`` output.  The log lands as a
        single chunk — chunk granularity is a cache detail, every query and
        compaction path sees the concatenated log either way."""
        dp = cls(n_dims)
        rows = np.ascontiguousarray(state["rows"], dtype=np.float32)
        ids = np.asarray(state["ids"], dtype=np.int64)
        if rows.shape[0]:
            dp._chunks.append(rows.reshape(-1, n_dims))
            dp._id_chunks.append(ids)
        dp.n_log = int(ids.shape[0])
        dp._dead = set(np.asarray(state["dead"], dtype=np.int64).tolist())
        dp.n_log_dead = int(state["n_log_dead"])
        dp.n_base_dead = int(state["n_base_dead"])
        return dp

    # ------------------------------------------------------------------ #
    def nbytes(self) -> int:
        """Bytes actually held: log rows + log ids + tombstone ids."""
        return (self.n_log * self.n_dims * 4      # f32 rows
                + self.n_log * 8                  # i64 ids
                + len(self._dead) * 8)            # i64 tombstones

    def describe(self) -> dict:
        return {
            "log_rows": self.n_log,
            "live_rows": self.n_live,
            "tombstones": self.n_tombstones,
            "bytes": self.nbytes(),
        }
