"""Baseline multidimensional indexes the paper compares against (§8.1.3):

* ``FullScan``    — every record checked against the predicate.
* ``UniformGrid`` — full-dimensional grid, uniform min..max cell boundaries,
  no sorted dimension, directory = flat cell offsets.
* ``ColumnFiles`` — CDF(quantile)-aligned grid with one in-cell sorted
  dimension (dimensionality reduced by one); 'similar to Flood [28] but does
  not assume the query workload is known'.
* ``STRTree``     — an R-Tree bulk-loaded with Sort-Tile-Recursive packing,
  stored in flat per-level arrays (MBRs + child ranges) so queries are
  vectorisable.  This is the array-native adaptation of the pointer R-tree
  (DESIGN.md §3) — same asymptotics, hardware-honest layout.

All engines share the contract: ``query(rect) -> sorted original row ids`` and
``memory_footprint() -> directory bytes``, so result sets are set-comparable
with COAX and with each other.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .gridfile import GridFile, fit_cells_per_dim, gather_ranges
from .types import Rect, full_rect, rect_contains

__all__ = ["FullScan", "UniformGrid", "ColumnFiles", "STRTree"]


class FullScan:
    """Ground-truth engine: linear scan with the full predicate."""

    name = "full_scan"

    def __init__(self, data: np.ndarray):
        self.data = np.ascontiguousarray(data, dtype=np.float32)

    def query(self, rect: Rect) -> np.ndarray:
        return np.nonzero(rect_contains(rect, self.data))[0].astype(np.int64)

    def memory_footprint(self) -> int:
        return 0


class UniformGrid:
    """Full grid with uniformly sized cells (paper: 'the full grid')."""

    name = "uniform_grid"

    def __init__(self, data: np.ndarray, cells_per_dim: Optional[int] = None,
                 rows_per_cell: int = 256):
        n, d = data.shape
        if cells_per_dim is None:
            # target occupancy, capped by the paper's §8.2.1 directory budget
            budget_cells = max(int(data.nbytes // 8), 1)
            auto = max(int(round((n / rows_per_cell) ** (1.0 / d))), 2)
            cells_per_dim = min(auto, fit_cells_per_dim(d, budget_cells))
        self.grid = GridFile(
            data, index_dims=list(range(d)), cells_per_dim=cells_per_dim,
            sort_dim=None, quantile=False,
        )

    def query(self, rect: Rect) -> np.ndarray:
        return self.grid.query(np.asarray(rect, dtype=np.float64), rect)

    def memory_footprint(self) -> int:
        return self.grid.memory_footprint()

    @property
    def last_query_stats(self):
        return self.grid.last_query_stats


class ColumnFiles:
    """Non-uniform (CDF-aligned) grid + one sorted dim (paper §8.1.3)."""

    name = "column_files"

    def __init__(
        self,
        data: np.ndarray,
        cells_per_dim: Optional[int] = None,
        sort_dim: int = 0,
        rows_per_cell: int = 256,
    ):
        n, d = data.shape
        if cells_per_dim is None:
            budget_cells = max(int(data.nbytes // 8), 1)
            auto = max(int(round((n / rows_per_cell) ** (1.0 / max(d - 1, 1)))), 2)
            cells_per_dim = min(auto, fit_cells_per_dim(max(d - 1, 1), budget_cells))
        self.grid = GridFile(
            data, index_dims=list(range(d)), cells_per_dim=cells_per_dim,
            sort_dim=sort_dim, quantile=True,
        )

    def query(self, rect: Rect) -> np.ndarray:
        return self.grid.query(np.asarray(rect, dtype=np.float64), rect)

    def memory_footprint(self) -> int:
        return self.grid.memory_footprint()

    @property
    def last_query_stats(self):
        return self.grid.last_query_stats


# --------------------------------------------------------------------------- #
# STR-packed R-Tree in flat arrays
# --------------------------------------------------------------------------- #

@dataclasses.dataclass
class _Level:
    mbr_lo: np.ndarray    # (M, D)
    mbr_hi: np.ndarray    # (M, D)  (inclusive of contained points)
    child_lo: np.ndarray  # (M,) start index into the level below (or rows)
    child_hi: np.ndarray  # (M,) end index


def _str_order(points: np.ndarray, leaf_cap: int) -> np.ndarray:
    """Sort-Tile-Recursive ordering of row indices for leaf packing."""
    n, d = points.shape
    idx = np.arange(n, dtype=np.int64)

    def recurse(ids: np.ndarray, dim: int) -> np.ndarray:
        if ids.size <= leaf_cap or dim == d - 1:
            return ids[np.argsort(points[ids, dim], kind="stable")]
        ids = ids[np.argsort(points[ids, dim], kind="stable")]
        n_leaves = int(np.ceil(ids.size / leaf_cap))
        rem = d - dim
        n_slabs = max(int(np.ceil(n_leaves ** (1.0 / rem))), 1)
        slab = int(np.ceil(ids.size / n_slabs))
        parts = [recurse(ids[i : i + slab], dim + 1) for i in range(0, ids.size, slab)]
        return np.concatenate(parts)

    return recurse(idx, 0)


class STRTree:
    """Bulk-loaded R-Tree (STR packing), breadth-first array storage.

    node_cap mirrors the paper's tuning range ('best node size for R-Tree is
    between 8 and 12', §8.2.1); default 10.
    """

    name = "r_tree"

    def __init__(self, data: np.ndarray, leaf_cap: int = 10, node_cap: int = 10):
        data = np.ascontiguousarray(data, dtype=np.float32)
        n, d = data.shape
        self.leaf_cap = leaf_cap
        self.node_cap = node_cap
        order = _str_order(data, leaf_cap) if n else np.empty(0, np.int64)
        self.rows = data[order] if n else data
        self.row_ids = order
        self.levels: List[_Level] = []
        if n == 0:
            return

        # Leaf level over packed row ranges.
        starts = np.arange(0, n, leaf_cap, dtype=np.int64)
        ends = np.minimum(starts + leaf_cap, n)
        lo = np.minimum.reduceat(self.rows, starts, axis=0)
        hi = np.maximum.reduceat(self.rows, starts, axis=0)
        self.levels.append(_Level(lo, hi, starts, ends))

        # Internal levels until a single root.
        while self.levels[-1].mbr_lo.shape[0] > 1:
            below = self.levels[-1]
            m = below.mbr_lo.shape[0]
            starts = np.arange(0, m, node_cap, dtype=np.int64)
            ends = np.minimum(starts + node_cap, m)
            lo = np.minimum.reduceat(below.mbr_lo, starts, axis=0)
            hi = np.maximum.reduceat(below.mbr_hi, starts, axis=0)
            self.levels.append(_Level(lo, hi, starts, ends))
        self.levels.reverse()  # root first

    def memory_footprint(self) -> int:
        return sum(
            lv.mbr_lo.nbytes + lv.mbr_hi.nbytes + lv.child_lo.nbytes + lv.child_hi.nbytes
            for lv in self.levels
        )

    def query(self, rect: Rect) -> np.ndarray:
        if not self.levels:
            return np.empty(0, dtype=np.int64)
        rect = np.asarray(rect, dtype=np.float64)
        q_lo, q_hi = rect[:, 0], rect[:, 1]
        cand = np.zeros(1, dtype=np.int64)  # root
        for lv in self.levels:
            lo = lv.mbr_lo[cand]
            hi = lv.mbr_hi[cand]
            # half-open query vs closed MBR: overlap iff mbr_lo < q_hi & mbr_hi >= q_lo
            ok = np.all((lo < q_hi) & (hi >= q_lo), axis=1)
            cand = cand[ok]
            if cand.size == 0:
                return np.empty(0, dtype=np.int64)
            if lv is self.levels[-1]:
                idx = gather_ranges(lv.child_lo[cand], lv.child_hi[cand])
            else:
                cand = gather_ranges(lv.child_lo[cand], lv.child_hi[cand])
        hit = rect_contains(rect, self.rows[idx])
        return np.sort(self.row_ids[idx[hit]])
