"""Theoretical results of the paper (§7 + Appendix) and their empirical
counterparts, used by ``benchmarks/bench_theory.py`` and the property tests.

Closed forms
------------
* Eq. 5   effectiveness               = q_y / (2 eps + q_y)
* Thm 7.1 E[keys per linear segment]  = eps^2 / sigma^2        (MET)
* Thm 7.2 optimal slope               = mu (mean gap); drifted MET closed form
* Thm 7.3 Var[keys per segment]       = 2 eps^4 / (3 sigma^4)
* Thm 7.4 segments for n keys         -> n sigma^2 / eps^2

Empirical counterparts simulate the random walk of Appendix C (gaps G_i i.i.d.,
transformed walk Z_i = sum(G_j - a)) and run the greedy segment-splitting
process of Appendix F so the theory can be validated against measurement.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

__all__ = [
    "effectiveness",
    "scanned_area",
    "result_area",
    "met_expectation",
    "met_drifted_expectation",
    "met_variance",
    "expected_segments",
    "simulate_met",
    "greedy_segment_count",
]


# ----------------------------- §7.1 ---------------------------------------- #

def result_area(q_y: float, eps: float, slope: float) -> float:
    """S_r (Eq. 3): area of the result R-box for a Y-only range query."""
    return q_y * 2.0 * eps / slope


def scanned_area(q_y: float, eps: float, slope: float) -> float:
    """S_s (Eq. 4): area of the scanned S-box."""
    return 2.0 * eps * (2.0 * eps + q_y) / slope


def effectiveness(q_y: float, eps: float) -> float:
    """Eq. 5: S_r / S_s = q_y / (2 eps + q_y); ->1 as eps->0."""
    return q_y / (2.0 * eps + q_y)


# ----------------------------- §7.2 ---------------------------------------- #

def met_expectation(eps: float, sigma: float) -> float:
    """Thm 7.1: expected keys covered by a segment with slope mu."""
    return (eps / sigma) ** 2


def met_drifted_expectation(eps: float, sigma: float, drift: float) -> float:
    """Proof of Thm 7.2 (Eq. 14): MET with slope mismatch d = mu - a.

    T(0) = (eps/d) * tanh(eps*d/sigma^2); reduces to eps^2/sigma^2 as d->0.
    """
    if abs(drift) < 1e-12:
        return met_expectation(eps, sigma)
    return (eps / drift) * np.tanh(eps * drift / sigma**2)


def met_variance(eps: float, sigma: float) -> float:
    """Thm 7.3: variance of keys covered by a segment."""
    return 2.0 * eps**4 / (3.0 * sigma**4)


def expected_segments(n: int, eps: float, sigma: float) -> float:
    """Thm 7.4: segments needed to cover a stream of n keys."""
    return n * (sigma / eps) ** 2


# --------------------------- simulations ----------------------------------- #

def simulate_met(
    eps: float,
    sigma: float,
    mu: float = 1.0,
    slope: float = None,
    trials: int = 512,
    max_steps: int = 1_000_000,
    seed: int = 0,
) -> Tuple[float, float]:
    """Monte-Carlo mean/variance of the exit time of the transformed walk
    Z_i = sum_j (G_j - a) from the strip [-eps, +eps] (Appendix C).

    Gaps are N(mu, sigma) truncated positive; slope ``a`` defaults to mu
    (Thm 7.2's optimum).
    """
    a = mu if slope is None else slope
    rng = np.random.default_rng(seed)
    exits = np.zeros(trials, dtype=np.int64)
    # vectorised batched walk: step all trials until everyone exits
    z = np.zeros(trials)
    alive = np.ones(trials, dtype=bool)
    steps = 0
    while alive.any() and steps < max_steps:
        steps += 1
        g = rng.normal(mu, sigma, size=trials)
        z = np.where(alive, z + (g - a), z)
        exited = alive & (np.abs(z) > eps)
        exits[exited] = steps
        alive &= ~exited
    exits[alive] = max_steps
    return float(exits.mean()), float(exits.var())


def greedy_segment_count(gaps: np.ndarray, eps: float, slope: float = None) -> int:
    """Appendix F's renewal process: start a new segment as soon as the walk
    leaves the +-eps strip; returns the number of segments for the stream."""
    gaps = np.asarray(gaps, dtype=np.float64)
    a = float(gaps.mean()) if slope is None else slope
    z = 0.0
    segments = 1
    for g in gaps:
        z += g - a
        if abs(z) > eps:
            segments += 1
            z = 0.0
    return segments
