"""Shipped-frame codec for WAL replication (DESIGN.md §8.2).

A shipped frame wraps one unit of the primary's history in its own
CRC-guarded envelope, so a frame torn or corrupted IN TRANSIT is detected
at the replica independently of the on-disk WAL framing:

    frame := magic "CSF1" | u8 kind | u64 epoch | u64 seq
             | u32 payload_len | u32 crc32(payload) | payload

Kinds
-----
``F_WRITE``      — one WAL record.  ``(epoch, seq)`` are the record's WAL
    coordinates; the payload is ``u8 wal_kind | wal_payload`` — the EXACT
    bytes the journal holds, so the replica decodes with the appender's
    arithmetic (``storage.wal.decode_record``) and applies through the
    ordinary ``insert(rows, ids=...)`` / ``delete`` paths.
``F_ROTATE``     — the compaction control frame.  Keyed at
    ``(old_epoch, old_final_seq)`` — i.e. exactly where the next in-order
    frame slot of the old epoch would be — so the reorder buffer sequences
    it for free.  Payload carries ``new_epoch`` and whether the primary's
    compaction relearned FDs; a replica whose own §5 trigger already fired
    treats it as absorbed, one that rotated manually on the primary
    replays ``compact(relearn=...)`` verbatim (§8.2 epoch handoff).
``F_HEARTBEAT``  — primary liveness + shipped frontier
    ``(epoch, seq == records logged this epoch)`` plus a wall timestamp;
    replicas date their health from it and measure lag against it.

All integers little-endian, like the WAL format this protocol extends.
"""
from __future__ import annotations

import dataclasses
import struct
import zlib

__all__ = ["Frame", "FrameError", "encode_frame", "decode_frame",
           "frame_nbytes", "write_frame", "rotate_frame", "heartbeat_frame",
           "unpack_write", "unpack_rotate", "unpack_heartbeat",
           "F_WRITE", "F_ROTATE", "F_HEARTBEAT"]

_MAGIC = b"CSF1"
_HDR = struct.Struct("<4sBQQII")      # magic, kind, epoch, seq, plen, crc
_ROTATE_PAYLOAD = struct.Struct("<QB")    # new_epoch, relearned
_HEARTBEAT_PAYLOAD = struct.Struct("<d")  # send time (time.time())

F_WRITE = 1
F_ROTATE = 2
F_HEARTBEAT = 3


class FrameError(ValueError):
    """Torn, truncated or corrupt shipped frame — the transit-damage signal
    a replica counts and repairs via catch-up (never by guessing)."""


@dataclasses.dataclass(frozen=True)
class Frame:
    kind: int
    epoch: int
    seq: int
    payload: bytes

    @property
    def key(self):
        """Total order of the shipped stream: ``(epoch, seq)``."""
        return (self.epoch, self.seq)


def encode_frame(frame: Frame) -> bytes:
    return _HDR.pack(_MAGIC, frame.kind, frame.epoch, frame.seq,
                     len(frame.payload),
                     zlib.crc32(frame.payload) & 0xFFFFFFFF) + frame.payload


def frame_nbytes(frame: Frame) -> int:
    """Encoded wire size of ``frame`` — the unit of byte-lag accounting."""
    return _HDR.size + len(frame.payload)


def decode_frame(data: bytes) -> Frame:
    """Decode one shipped frame; raises ``FrameError`` on any damage —
    short header, bad magic, short payload, trailing garbage, CRC
    mismatch — exactly the failures in-flight truncation produces."""
    if len(data) < _HDR.size:
        raise FrameError(f"frame truncated to {len(data)} bytes")
    magic, kind, epoch, seq, plen, crc = _HDR.unpack_from(data, 0)
    if magic != _MAGIC:
        raise FrameError("bad frame magic")
    if len(data) != _HDR.size + plen:
        raise FrameError(f"frame payload {len(data) - _HDR.size} bytes, "
                         f"header says {plen}")
    payload = data[_HDR.size:]
    if zlib.crc32(payload) & 0xFFFFFFFF != crc:
        raise FrameError("frame payload CRC mismatch")
    return Frame(kind=kind, epoch=epoch, seq=seq, payload=payload)


# --------------------------------------------------------------------- #
# Constructors for the three frame kinds
# --------------------------------------------------------------------- #
def write_frame(epoch: int, seq: int, wal_kind: int, wal_payload: bytes) -> Frame:
    """Wrap one WAL record (exact journal bytes) for shipping."""
    return Frame(F_WRITE, epoch, seq, bytes([wal_kind]) + wal_payload)


def unpack_write(frame: Frame):
    """-> (wal_kind, wal_payload)."""
    return frame.payload[0], frame.payload[1:]


def rotate_frame(old_epoch: int, old_final_seq: int, new_epoch: int,
                 relearned: bool) -> Frame:
    return Frame(F_ROTATE, old_epoch, old_final_seq,
                 _ROTATE_PAYLOAD.pack(new_epoch, int(bool(relearned))))


def unpack_rotate(frame: Frame):
    """-> (new_epoch, relearned)."""
    new_epoch, relearned = _ROTATE_PAYLOAD.unpack(frame.payload)
    return int(new_epoch), bool(relearned)


def heartbeat_frame(epoch: int, seq: int, now: float) -> Frame:
    return Frame(F_HEARTBEAT, epoch, seq, _HEARTBEAT_PAYLOAD.pack(now))


def unpack_heartbeat(frame: Frame) -> float:
    """-> primary send time."""
    return float(_HEARTBEAT_PAYLOAD.unpack(frame.payload)[0])
