"""WAL shipping: the primary side of replication (DESIGN.md §8.2).

``ReplicationHub`` subscribes to a ``storage.Durability`` plane's
replication hooks and turns the primary's journal into a shipped stream:

* every journaled record ships as an ``F_WRITE`` frame carrying the EXACT
  WAL bytes, immediately after the append (``frame_observer``);
* every §7.5 compaction-rotation ships as an ``F_ROTATE`` control frame
  from INSIDE the rotation window (``rotate_observer``) — after the new
  epoch pair is on disk, before old WALs die — so a crash injected there
  models a primary dying mid-rotation with replicas mid-stream.  A §5.4
  background-compaction handoff fires the SAME observer
  (``Durability.handoff_rotate``); its re-journaled tail records are NOT
  pushed (``_suppress_ship``) — a sync replica already rotated implicitly
  at the trigger record, drops the old-epoch tail pushes as duplicates,
  and pulls the re-journaled tail via ``fetch(new_epoch, 0)``;
* ``heartbeat()`` ships the journal frontier + wall time, the liveness
  signal replicas date their health from.

Ship failures NEVER fail the primary's write path: each send runs under
``runtime.failure.retry`` (``TransportError`` is the retryable class), and
a frame that still cannot be delivered is counted and abandoned — the
replica repairs the gap through the pull path, ``fetch``, which reads the
primary's on-disk WAL through ``storage.WalFrameCursor`` (the journal
doubles as the retransmission buffer).  When the wanted epoch has rotated
away, ``fetch`` signals ``reseed`` and the replica re-bootstraps from
``seed_state`` — the same snapshot codec the durability plane uses, so the
seed is bit-identical by §7.3's round-trip contract.
"""
from __future__ import annotations

import io
import json
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs
from ..runtime.failure import FaultPlan, retry
from ..storage.durability import Durability
from ..storage.snapshot import pack_state, unpack_state
from ..storage.wal import WalFrameCursor, wal_path
from .frames import (Frame, encode_frame, heartbeat_frame, rotate_frame,
                     write_frame)
from .transport import Transport, TransportError

__all__ = ["ReplicationHub", "seed_state"]


def seed_state(index) -> dict:
    """Deep, bit-identical copy of an index's full state, as the dict
    ``COAXIndex._restore_state`` eats.

    Round-trips through the snapshot codec (``pack_state`` -> in-memory
    npz + JSON -> ``unpack_state``) rather than handing out
    ``_snapshot_state()`` directly: the raw state dict ALIASES the live
    index's arrays, and a replica restored from it would mutate its
    primary.  The codec path is the §7.3 bit-identity contract made into
    a copier — exactly what shipping a snapshot over a wire would do.

    Any in-flight §5.4 background build is JOINED first: a seed taken
    mid-build would hand the replica the old epoch plus a delta the
    primary is about to fold into a NEW epoch built from an earlier
    freeze — the replica's own (synchronous) trigger would then fire over
    a different row set and diverge.  Post-join, the seed is an ordinary
    whole-epoch state.
    """
    fh = getattr(index, "finish_handoff", None)
    if fh is not None:
        fh()
    manifest, arrays = pack_state(index._snapshot_state())
    buf = io.BytesIO()
    np.savez(buf, **arrays)
    buf.seek(0)
    with np.load(buf) as z:
        loaded = {k: z[k] for k in z.files}
    return unpack_state(json.loads(json.dumps(manifest)), loaded)


class ReplicationHub:
    """Fan-out point between one primary's durability plane and its
    replicas' transport destinations (DESIGN.md §8.2).

    Construction subscribes to ``durability.frame_observer`` /
    ``rotate_observer``; ``detach()`` unsubscribes (a killed primary stops
    shipping).  ``total_writes`` / ``total_bytes`` are the cumulative
    shipped-stream totals replicas measure their lag against.
    """

    def __init__(self, durability: Durability, transport: Transport,
                 plan: Optional[FaultPlan] = None, retries: int = 3,
                 backoff: float = 0.0):
        self.durability = durability
        self.transport = transport
        self.plan = plan
        self.retries = int(retries)
        self.backoff = float(backoff)
        self.destinations: List[str] = []
        self.total_writes = 0           # F_WRITE frames shipped (stream length)
        self.total_bytes = 0            # encoded bytes of those frames
        self.send_retries = 0           # transport retries that later succeeded
        self.ship_failures = 0          # (frame, dest) pairs abandoned to catch-up
        self.heartbeats = 0
        # old_epoch -> (old_final_seq, new_epoch, relearned): lets ``fetch``
        # re-issue the ROTATE control frame during the §7.5 crash window in
        # which the old epoch's WAL is still on disk
        self.rotations: Dict[int, Tuple[int, int, bool]] = {}
        durability.frame_observer = self._on_append
        durability.rotate_observer = self._on_rotate

    # ------------------------------------------------------------------ #
    @property
    def index(self):
        return self.durability.index

    @property
    def frontier(self) -> Tuple[int, int]:
        """The primary journal's ``(epoch, next_seq)`` — what a fully
        caught-up replica's applied frontier equals."""
        wal = self.durability.wal
        if wal is None:
            return (self.index.epoch, 0)
        return (wal.epoch, wal.next_seq)

    def detach(self) -> None:
        """Stop shipping (the primary-death switch): a real dead process
        stops sending; here the observers are torn down explicitly."""
        if self.durability.frame_observer is self._on_append:
            self.durability.frame_observer = None
        if self.durability.rotate_observer is self._on_rotate:
            self.durability.rotate_observer = None

    # ------------------------------------------------------------------ #
    def register(self, dest: str) -> None:
        if dest not in self.destinations:
            self.destinations.append(dest)

    def unregister(self, dest: str) -> None:
        if dest in self.destinations:
            self.destinations.remove(dest)

    def _ship(self, dest: str, data: bytes) -> None:
        def _count_retry(attempt, exc):
            self.send_retries += 1
            obs.get_registry().counter(
                "coax_ship_retries_total", "Push-side send retries.").inc()

        with obs.span("ship.send", dest=dest, nbytes=len(data)) as sp:
            try:
                retry(lambda: self.transport.send(dest, data),
                      retries=self.retries, backoff=self.backoff,
                      on_error=_count_retry, retryable=(TransportError,))
            except TransportError:
                # give up on the push; the replica pulls the gap from the
                # journal (``fetch``).  The primary's write path never fails
                # because a replica link is down.
                self.ship_failures += 1
                obs.get_registry().counter(
                    "coax_ship_failures_total",
                    "Frames abandoned after retry exhaustion.").inc()
                if sp is not None:
                    sp.args["failed"] = True
        reg = obs.get_registry()
        reg.counter("coax_ship_frames_total",
                    "Frames pushed to replica links.").inc()
        reg.counter("coax_ship_bytes_total",
                    "Encoded frame bytes pushed.").inc(len(data))

    def _broadcast(self, frame: Frame) -> bytes:
        data = encode_frame(frame)
        for dest in self.destinations:
            self._ship(dest, data)
        return data

    # ------------------------------------------------------------------ #
    # Durability-plane hooks
    # ------------------------------------------------------------------ #
    def _on_append(self, epoch: int, seq: int, kind: int,
                   payload: bytes) -> None:
        data = self._broadcast(write_frame(epoch, seq, kind, payload))
        self.total_writes += 1
        self.total_bytes += len(data)

    def _on_rotate(self, old_epoch: int, old_final_seq: int, new_epoch: int,
                   relearned: bool) -> None:
        if self.plan is not None:
            # primary dies mid-rotation: the new epoch pair is on disk, the
            # old WALs are not yet deleted, no ROTATE frame was shipped
            self.plan.crash_if("primary.rotate")
        self.rotations[old_epoch] = (old_final_seq, new_epoch,
                                     bool(relearned))
        self._broadcast(rotate_frame(old_epoch, old_final_seq, new_epoch,
                                     relearned))

    def heartbeat(self) -> None:
        epoch, seq = self.frontier
        self._broadcast(heartbeat_frame(epoch, seq, time.time()))
        self.heartbeats += 1

    # ------------------------------------------------------------------ #
    # Pull path: catch-up reads against the on-disk journal
    # ------------------------------------------------------------------ #
    def fetch(self, epoch: int, from_seq: int,
              max_records: Optional[int] = None) -> dict:
        """Re-derive the shipped stream from ``(epoch, from_seq)`` out of
        the primary's on-disk WAL.  Returns ``{"frames": [...], "reseed":
        bool}`` — ``reseed`` means the wanted epoch rotated away (its WAL
        was deleted, §7.5 step 3), so no frame-level repair exists and the
        replica must re-bootstrap from a fresh seed."""
        path = wal_path(self.durability.directory, epoch)
        cur_epoch, _ = self.frontier
        if not path.exists():
            return {"frames": [], "reseed": epoch < cur_epoch}
        cursor = WalFrameCursor(path, expect_epoch=epoch, start_seq=from_seq)
        frames = [write_frame(epoch, seq, kind, payload)
                  for seq, kind, payload in cursor.read(max_records)]
        if epoch < cur_epoch:
            rot = self.rotations.get(epoch)
            if rot is None:
                # rotation predates this hub (or history was lost with a
                # crashed predecessor): cannot hand over the epoch boundary
                return {"frames": [], "reseed": True}
            frames.append(rotate_frame(epoch, rot[0], rot[1], rot[2]))
        return {"frames": frames, "reseed": False}

    def seed(self) -> Tuple[dict, Tuple[int, int], int, int]:
        """Bootstrap payload for a (re)seeding replica: a deep state copy
        plus the journal frontier and stream totals it corresponds to."""
        return (seed_state(self.index), self.frontier, self.total_writes,
                self.total_bytes)

    # ------------------------------------------------------------------ #
    def describe(self) -> dict:
        epoch, seq = self.frontier
        return {
            "destinations": list(self.destinations),
            "frontier": {"epoch": epoch, "seq": seq},
            "shipped_frames": self.total_writes,
            "shipped_bytes": self.total_bytes,
            "send_retries": self.send_retries,
            "ship_failures": self.ship_failures,
            "heartbeats": self.heartbeats,
            "rotations": len(self.rotations),
        }
