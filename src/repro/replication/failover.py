"""Replicated serving: routed reads, health checks, promotion (§8.5–8.6).

``ReplicatedServer`` owns one primary ``COAXIndex`` (journaling under
``<directory>/primary``), a ``ReplicationHub`` shipping its WAL, and N
``Replica`` instances.  Writes go to the primary and are ACKNOWLEDGED at
the journal frontier the call returned at (``acked`` — the no-data-loss
yardstick for promotion: an op that raised never acked, so a promoted
frontier ≥ ``acked`` proves no client-visible write was lost).

Reads round-robin over HEALTHY replicas — alive, a recent-enough
heartbeat, and lag within the bounded-staleness budget — and degrade to
primary-serves-reads (counted) when none qualifies.  ``tick()`` is the
control loop body: ship a heartbeat, pump every live replica.

``promote()`` is the failover sequence: pick the most-caught-up live
replica, deliver whatever the wire still holds, finish the dead primary's
journal straight off disk (``Replica.drain_from_disk``), gate on
``frontier ≥ acked``, then turn the replica into the new primary — its
index attaches a FRESH durability directory (snapshot + rotated WAL under
its own name) and a new hub re-seeds the surviving replicas against it.
Every step is synchronous and deterministic, so a ``FaultPlan`` schedule
reproduces an entire failover scenario exactly.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Union

import numpy as np

from .. import obs
from ..core import COAXIndex
from ..runtime.failure import FaultPlan
from .replica import Replica, ReplicationError
from .ship import ReplicationHub
from .transport import FaultyTransport, InProcTransport, Transport

__all__ = ["ReplicatedServer"]


class ReplicatedServer:
    """Primary + N replicas behind one read/write façade."""

    def __init__(self, index: COAXIndex, directory: Union[str, Path],
                 n_replicas: int = 2, plan: Optional[FaultPlan] = None,
                 replica_backend: str = "numpy",
                 device_opts: Optional[dict] = None,
                 transport: Optional[Transport] = None,
                 heartbeat_timeout: float = 5.0, max_lag_frames: int = 256,
                 ship_retries: int = 3, ship_backoff: float = 0.0):
        self.directory = Path(directory)
        self.plan = plan
        self.heartbeat_timeout = float(heartbeat_timeout)
        self.max_lag_frames = int(max_lag_frames)
        self._ship_retries = int(ship_retries)
        self._ship_backoff = float(ship_backoff)
        self.primary = index
        if index.durable is None:
            index.attach_durability(self.directory / "primary")
        self.primary_dir = index.durable.directory
        base = transport if transport is not None else InProcTransport()
        self.transport: Transport = (FaultyTransport(base, plan)
                                     if plan is not None else base)
        self.hub = ReplicationHub(index.durable, self.transport, plan=plan,
                                  retries=ship_retries, backoff=ship_backoff)
        self.replicas: List[Replica] = [
            Replica(f"replica-{i}", self.hub, backend=replica_backend,
                    device_opts=device_opts, plan=plan)
            for i in range(int(n_replicas))
        ]
        self.primary_alive = True
        self.acked = self.hub.frontier  # journal frontier of the last ack'd op
        self.promotions = 0
        self.degraded_reads = 0
        self.replica_reads = 0
        self.primary_reads = 0
        self._rr = 0

    # ------------------------------------------------------------------ #
    # Write path (primary only; ack = the journal frontier on return)
    # ------------------------------------------------------------------ #
    def _require_primary(self) -> None:
        if not self.primary_alive:
            raise ReplicationError("primary is down; promote() a replica "
                                   "before writing")

    def insert(self, rows: np.ndarray,
               ids: Optional[np.ndarray] = None) -> np.ndarray:
        self._require_primary()
        out = self.primary.insert(rows, ids=ids)
        self.acked = self.hub.frontier
        return out

    def delete(self, row_ids) -> int:
        self._require_primary()
        out = self.primary.delete(row_ids)
        self.acked = self.hub.frontier
        return out

    def compact(self, relearn: Optional[bool] = None) -> dict:
        self._require_primary()
        out = self.primary.compact(relearn=relearn)
        self.acked = self.hub.frontier
        return out

    def sync(self) -> None:
        if self.primary_alive and self.primary.durable is not None:
            self.primary.durable.sync()

    # ------------------------------------------------------------------ #
    # Control loop
    # ------------------------------------------------------------------ #
    def tick(self) -> int:
        """One control-loop beat: heartbeat the stream, pump every live
        replica (apply + catch-up).  Returns frames applied."""
        if self.primary_alive:
            self.hub.heartbeat()
        applied = 0
        for rep in self.replicas:
            if rep.alive:
                applied += rep.pump()
        return applied

    def healthy(self, rep: Replica) -> bool:
        return (rep.alive
                and rep.heartbeat_age() <= self.heartbeat_timeout
                and rep.lag_frames() <= self.max_lag_frames)

    def healthy_replicas(self) -> List[Replica]:
        return [r for r in self.replicas if self.healthy(r)]

    # ------------------------------------------------------------------ #
    # Read path (bounded-staleness routing)
    # ------------------------------------------------------------------ #
    def read_index(self) -> COAXIndex:
        """The index the next read is served from: round-robin over healthy
        replicas, degrading to the primary (counted) when none qualifies."""
        healthy = self.healthy_replicas()
        if healthy:
            rep = healthy[self._rr % len(healthy)]
            self._rr += 1
            self.replica_reads += 1
            return rep.index
        if self.primary_alive:
            self.degraded_reads += 1
            self.primary_reads += 1
            return self.primary
        raise ReplicationError("no healthy replica and the primary is down")

    def query(self, rect) -> np.ndarray:
        return self.read_index().query(rect)

    def query_batch(self, rects):
        return self.read_index().query_batch(rects)

    def query_batch_split(self, rects):
        return self.read_index().query_batch_split(rects)

    # ------------------------------------------------------------------ #
    # Failure + promotion
    # ------------------------------------------------------------------ #
    def kill_primary(self) -> None:
        """Model the primary process dying: shipping stops, the façade
        refuses writes, and the durability directory is left exactly as the
        dead process left it (no orderly close — that is the point)."""
        self.primary_alive = False
        self.hub.detach()

    def promote(self, name: Optional[str] = None) -> Replica:
        """Fail over onto the most-caught-up live replica (or ``name``).

        Sequence: (1) the wire surrenders what it still holds (held frames
        flushed, queue pumped); (2) the replica finishes the dead primary's
        journal off disk; (3) the no-data-loss gate — promoted frontier ≥
        last acked frontier — or ``ReplicationError``; (4) the replica's
        index attaches a fresh durability directory (snapshot + rotated
        WAL under its own name) and becomes the primary of a new hub;
        (5) surviving replicas re-seed against it.
        """
        if self.primary_alive:
            self.kill_primary()             # controlled switchover
        candidates = [r for r in self.replicas if r.alive]
        if not candidates:
            raise ReplicationError("no live replica to promote")
        if name is not None:
            rep = next(r for r in candidates if r.name == name)
        else:
            rep = max(candidates, key=lambda r: r.frontier)

        with obs.span("failover.promote", replica=rep.name) as sp:
            flush = getattr(self.transport, "flush_held", None)
            if flush is not None:
                flush(rep.name)             # the OS delivers its buffers
            rep.pump()                      # shipped tail + journal catch-up
            rep.drain_from_disk(self.primary_dir)
            if rep.frontier < self.acked:
                raise ReplicationError(
                    f"promotion would lose acknowledged writes: {rep.name} "
                    f"reached {rep.frontier}, last ack at {self.acked}")

            self.promotions += 1
            promoted_dir = self.directory / f"{rep.name}-gen{self.promotions}"
            rep.index.attach_durability(promoted_dir)
            self.primary = rep.index
            self.primary_dir = promoted_dir
            self.primary_alive = True
            self.hub = ReplicationHub(rep.index.durable, self.transport,
                                      plan=self.plan,
                                      retries=self._ship_retries,
                                      backoff=self._ship_backoff)
            self.replicas = [r for r in self.replicas if r is not rep]
            for r in self.replicas:
                r.hub = self.hub
                self.hub.register(r.name)
                r.reseed()                  # fresh subscription to the new
                r.alive = True              # primary's stream
            self.acked = self.hub.frontier
            if sp is not None:
                sp.args["epoch"], sp.args["seq"] = rep.frontier
        obs.get_registry().counter(
            "coax_promotions_total", "Replica-to-primary promotions.").inc()
        return rep

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        counts = (self.transport.counts()
                  if isinstance(self.transport, FaultyTransport) else {})
        return {
            "primary_alive": self.primary_alive,
            "primary_dir": str(self.primary_dir),
            "frontier": {"epoch": self.hub.frontier[0],
                         "seq": self.hub.frontier[1]},
            "acked": {"epoch": self.acked[0], "seq": self.acked[1]},
            "promotions": self.promotions,
            "reads": {"replica": self.replica_reads,
                      "primary": self.primary_reads,
                      "degraded": self.degraded_reads},
            "ship": self.hub.describe(),
            "transport_faults": counts,
            "fault_plan": self.plan.counts() if self.plan is not None else {},
            "replicas": [r.describe() for r in self.replicas],
        }

    def describe(self) -> dict:
        return self.stats()

    def close(self) -> None:
        """Orderly teardown: sync + close the primary's durability plane
        (idempotent, like everything on the close path)."""
        if self.primary.durable is not None:
            self.primary.durable.close()
