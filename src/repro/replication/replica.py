"""Read replicas: ordered apply of the shipped WAL stream (DESIGN.md §8.4).

A ``Replica`` owns a full ``COAXIndex`` bootstrapped from a bit-identical
seed of the primary (``ship.seed_state``) and advances it by applying
shipped frames THROUGH THE ORDINARY ``insert(rows, ids=...)`` /
``delete`` / ``compact`` paths — the same §7.4 recovery ≡ replay argument
that makes crash restore exact makes every replica exact at its applied
frontier ``(epoch, next_seq)``.

The wire owes it nothing: frames may arrive torn (CRC-rejected, counted),
duplicated (at-or-below-frontier, absorbed), out of order (parked in a
reorder buffer until their frontier slot opens) or not at all (repaired by
pulling the gap from the primary's journal via ``hub.fetch``; a gap whose
epoch has rotated away forces a reseed).  Compaction arrives two ways and
both are handled: a replica whose own §5 trigger fires while applying the
trigger record rotates IMPLICITLY — deterministically identical to the
primary, because trigger state and config are identical — and absorbs the
late ``F_ROTATE`` as a duplicate; a manual primary ``compact()`` has no
replica-side trigger, so the control frame at the frontier replays
``compact(relearn=...)`` verbatim.

``drain_from_disk`` is the promotion path (§8.6): with the primary dead,
the most-caught-up replica finishes the primary's journal straight off
disk — the WAL is the retransmission buffer of last resort — falling back
to a read-only ``storage.restore`` of the primary's directory only when a
rotation boundary cannot be replayed from frames (the §7.5 crash window
of a manual compaction).
"""
from __future__ import annotations

import time
from pathlib import Path
from typing import Dict, Optional, Tuple

from .. import obs
from ..core import COAXIndex
from ..runtime.failure import FaultPlan
from ..storage.snapshot import latest_snapshot, read_manifest
from ..storage.wal import (OP_INSERT, WalFrameCursor, decode_record,
                           read_wal, wal_path)
from .frames import (F_HEARTBEAT, F_ROTATE, F_WRITE, Frame, FrameError,
                     decode_frame, frame_nbytes, rotate_frame,
                     unpack_heartbeat, unpack_rotate, unpack_write,
                     write_frame)
from .ship import ReplicationHub

__all__ = ["Replica", "ReplicationError"]


class ReplicationError(RuntimeError):
    """A replica diverged from the protocol's invariants (e.g. a replayed
    rotation landed on a different epoch than the primary announced) —
    never expected, always a bug, never silently absorbed."""


def _newest_epoch_on_disk(directory: Path) -> Optional[int]:
    best = None
    for p in Path(directory).glob("wal_*.log"):
        try:
            e = int(p.stem.split("_", 1)[1])
        except ValueError:
            continue
        best = e if best is None else max(best, e)
    snap = latest_snapshot(directory)
    if snap is not None:
        e = int(read_manifest(snap)["epoch"])
        best = e if best is None else max(best, e)
    return best


class Replica:
    """One read replica: seeded copy + ordered frame application.

    ``alive`` is the crash switch: a ``FaultPlan`` action ``"crash"`` on
    channel ``"<name>.apply"`` halts the replica BEFORE the frame mutates
    anything, so its state stays exactly at the applied frontier — the
    in-process model of a process killed between ops.  ``revive()``
    resumes from that frontier (a restarted process would reload its own
    checkpoint and land in the same place); the next ``pump`` repairs
    whatever the outage missed via catch-up.
    """

    def __init__(self, name: str, hub: ReplicationHub, backend: str = "numpy",
                 device_opts: Optional[dict] = None,
                 plan: Optional[FaultPlan] = None):
        self.name = name
        self.hub = hub
        self.backend = backend
        self.device_opts = device_opts
        self.plan = plan
        self.alive = True
        self.index: Optional[COAXIndex] = None
        self.epoch = 0                  # applied frontier: next frame slot is
        self.next_seq = 0               # (epoch, next_seq)
        self.position = 0               # cumulative write-frames absorbed
        self.position_bytes = 0         # ... and their encoded bytes
        self._future: Dict[Tuple[int, int], Frame] = {}   # reorder buffer
        self.frames_applied = 0
        self.frames_corrupt = 0
        self.frames_duplicate = 0
        self.rotations_applied = 0
        self.implicit_rotations = 0
        self.catchup_fetches = 0
        self.reseeds = 0
        self.crashes = 0
        # (local receive time, primary send time, shipped frontier)
        self.last_heartbeat: Optional[Tuple[float, float, Tuple[int, int]]] = None
        hub.register(name)
        self._bootstrap()

    def _bootstrap(self) -> None:
        # a (re)seed is a fresh subscription: frames queued for the OLD
        # stream are meaningless under the new journal's coordinates (a
        # promoted primary rotates its WAL, resetting seq), so purge them
        flush = getattr(self.hub.transport, "flush_held", None)
        if flush is not None:
            flush(self.name)
        self.hub.transport.recv(self.name)
        state, (epoch, seq), writes, nbytes = self.hub.seed()
        self.index = COAXIndex._restore_state(state, backend=self.backend,
                                              device_opts=self.device_opts)
        self._force_sync_compaction()
        self.epoch, self.next_seq = epoch, seq
        self.position, self.position_bytes = writes, nbytes
        self._future.clear()
        self.last_heartbeat = (time.time(), time.time(), (epoch, seq))

    def _force_sync_compaction(self) -> None:
        """Replicas always compact SYNCHRONOUSLY, whatever the seeded
        config says: the §8.2 implicit-rotation contract needs the epoch
        to advance AT the trigger record (so the frontier resets exactly
        where the primary's freeze happened), which a §5.4 background
        build — installing at some later poll — would break.  A background
        primary's handoff converges to the same state (same frozen row
        set, tail re-journaled into the new epoch's WAL and pulled here
        via catch-up), so sync apply stays bit-identical."""
        import dataclasses
        cfg = self.index.config
        if cfg.background_compact:
            self.index.config = dataclasses.replace(
                cfg, background_compact=False)

    # ------------------------------------------------------------------ #
    @property
    def frontier(self) -> Tuple[int, int]:
        return (self.epoch, self.next_seq)

    def lag_frames(self) -> int:
        return max(self.hub.total_writes - self.position, 0)

    def lag_bytes(self) -> int:
        return max(self.hub.total_bytes - self.position_bytes, 0)

    def heartbeat_age(self, now: Optional[float] = None) -> float:
        if self.last_heartbeat is None:
            return float("inf")
        return (time.time() if now is None else now) - self.last_heartbeat[0]

    def behind(self) -> bool:
        return self._future or self.hub.frontier > self.frontier

    # ------------------------------------------------------------------ #
    def pump(self, catch_up: bool = True) -> int:
        """Drain the transport queue and apply everything applicable;
        optionally repair gaps from the primary's journal.  Returns the
        number of frames applied."""
        if not self.alive:
            return 0
        applied = 0
        with obs.span("replica.apply", replica=self.name) as sp:
            for data in self.hub.transport.recv(self.name):
                try:
                    frame = decode_frame(data)
                except FrameError:
                    self.frames_corrupt += 1  # torn in transit; catch-up
                    continue                  # repairs the gap
                applied += self._ingest(frame)
                if not self.alive:
                    break
            if catch_up and self.alive and self.behind():
                applied += self.catch_up()
            if sp is not None:
                sp.args["applied"] = applied
        if applied:
            obs.get_registry().counter(
                "coax_replica_frames_applied_total",
                "Frames applied on replicas.", ("replica",)).inc(
                    applied, replica=self.name)
        return applied

    def _ingest(self, frame: Frame) -> int:
        if frame.kind == F_HEARTBEAT:
            self.last_heartbeat = (time.time(), unpack_heartbeat(frame),
                                   frame.key)
            return 0
        if frame.key < self.frontier:
            self.frames_duplicate += 1      # dup in transit, or already pulled
            return 0
        if frame.key > self.frontier:
            if frame.key in self._future:
                self.frames_duplicate += 1
            else:
                self._future[frame.key] = frame
            return 0
        applied = self._apply(frame)
        if self.alive:
            applied += self._drain_future()
        return applied

    def _drain_future(self) -> int:
        applied = 0
        while self.alive:
            frame = self._future.pop(self.frontier, None)
            if frame is None:
                break
            applied += self._apply(frame)
        # rotation may leap the frontier past parked old-epoch keys (the
        # absorbed late-ROTATE case); they are duplicates now
        for key in [k for k in self._future if k < self.frontier]:
            del self._future[key]
            self.frames_duplicate += 1
        return applied

    # ------------------------------------------------------------------ #
    def _apply(self, frame: Frame) -> int:
        if self.plan is not None and \
                self.plan.action(f"{self.name}.apply") == "crash":
            self.alive = False              # dies BEFORE mutating: state
            self.crashes += 1               # stays at the applied frontier
            return 0
        if frame.kind == F_ROTATE:
            new_epoch, relearned = unpack_rotate(frame)
            self.index.compact(relearn=relearned)
            if self.index.epoch != new_epoch:
                raise ReplicationError(
                    f"{self.name}: replayed rotation reached epoch "
                    f"{self.index.epoch}, primary announced {new_epoch}")
            self.epoch, self.next_seq = new_epoch, 0
            self.rotations_applied += 1
            self.frames_applied += 1
            return 1
        if frame.kind != F_WRITE:
            raise ReplicationError(f"{self.name}: unknown frame kind "
                                   f"{frame.kind} at {frame.key}")
        kind, payload = unpack_write(frame)
        rows, ids = decode_record(kind, payload)
        epoch_before = self.index.epoch
        if kind == OP_INSERT:
            self.index.insert(rows, ids=ids)
        else:
            self.index.delete(ids)
        self.position += 1
        self.position_bytes += frame_nbytes(frame)
        self.frames_applied += 1
        if self.index.epoch != epoch_before:
            # the §5 trigger fired on this record here exactly as it did on
            # the primary (identical state, identical config): implicit
            # rotation; the primary's ROTATE frame arrives late and is
            # absorbed above as a duplicate
            self.implicit_rotations += 1
            self.epoch, self.next_seq = self.index.epoch, 0
        else:
            self.epoch, self.next_seq = frame.epoch, frame.seq + 1
        return 1

    # ------------------------------------------------------------------ #
    # Gap repair
    # ------------------------------------------------------------------ #
    def catch_up(self) -> int:
        """Pull the gap ``frontier .. primary frontier`` from the primary's
        journal (``hub.fetch``); reseed when the epoch rotated away."""
        self.catchup_fetches += 1
        resp = self.hub.fetch(self.epoch, self.next_seq)
        if resp["reseed"]:
            self.reseed()
            return 0
        applied = 0
        for frame in resp["frames"]:
            applied += self._ingest(frame)
            if not self.alive:
                break
        return applied

    def reseed(self) -> None:
        """Re-bootstrap from a fresh bit-identical seed of the live primary
        (frame-level repair impossible: the needed epoch rotated away)."""
        self._bootstrap()
        self.reseeds += 1

    def revive(self) -> None:
        """Bring a crashed replica back at its applied frontier; the next
        ``pump`` catches up whatever the outage missed."""
        self.alive = True

    # ------------------------------------------------------------------ #
    # Promotion support (DESIGN.md §8.6)
    # ------------------------------------------------------------------ #
    def drain_from_disk(self, directory=None) -> int:
        """Finish a dead primary's journal straight off its durability
        directory: apply every record past our frontier (ordinary write
        paths, implicit rotations included), crossing epoch boundaries via
        the hub's rotation history when frames can replay them and a
        read-only ``storage.restore`` of the directory when they cannot
        (manual compaction interrupted mid-rotation).  Returns frames
        applied; the caller asserts the resulting frontier covers every
        client-acknowledged write."""
        directory = Path(directory if directory is not None
                         else self.hub.durability.directory)
        applied = 0
        while self.alive:
            start = self.frontier
            path = wal_path(directory, self.epoch)
            if path.exists():
                cursor = WalFrameCursor(path, expect_epoch=self.epoch,
                                        start_seq=self.next_seq)
                for seq, kind, payload in cursor.read():
                    applied += self._apply(write_frame(self.epoch, seq,
                                                       kind, payload))
                    if not self.alive or self.epoch != start[0]:
                        break               # crashed, or implicitly rotated
            if not self.alive:
                break
            if self.frontier != start and self.epoch != start[0]:
                continue                    # drain the new epoch's WAL too
            disk_epoch = _newest_epoch_on_disk(directory)
            if disk_epoch is None or disk_epoch <= self.epoch:
                break                       # journal fully absorbed
            rot = self.hub.rotations.get(self.epoch)
            if rot is not None and rot[0] == self.next_seq:
                applied += self._apply(rotate_frame(self.epoch, rot[0],
                                                    rot[1], rot[2]))
                continue
            # epoch boundary with no replayable control frame (primary died
            # mid-rotation of a manual compact): recover exactly like a
            # restarted primary would — snapshot + WAL replay, read-only
            self._reseed_from_disk(directory)
        return applied

    def _reseed_from_disk(self, directory: Path) -> None:
        from ..storage import restore
        self.index = restore(directory, backend=self.backend,
                             device_opts=self.device_opts, durable=False)
        self._force_sync_compaction()
        _, next_seq, _ = read_wal(wal_path(directory, self.index.epoch),
                                  expect_epoch=self.index.epoch)
        self.epoch, self.next_seq = self.index.epoch, next_seq
        self.position = self.hub.total_writes
        self.position_bytes = self.hub.total_bytes
        self._future.clear()
        self.reseeds += 1

    # ------------------------------------------------------------------ #
    def describe(self) -> dict:
        return {
            "name": self.name,
            "alive": self.alive,
            "epoch": self.epoch,
            "next_seq": self.next_seq,
            "lag_frames": self.lag_frames(),
            "lag_bytes": self.lag_bytes(),
            "heartbeat_age": self.heartbeat_age(),
            "frames_applied": self.frames_applied,
            "frames_corrupt": self.frames_corrupt,
            "frames_duplicate": self.frames_duplicate,
            "rotations_applied": self.rotations_applied,
            "implicit_rotations": self.implicit_rotations,
            "catchup_fetches": self.catchup_fetches,
            "reseeds": self.reseeds,
            "crashes": self.crashes,
            "buffered": len(self._future),
        }
