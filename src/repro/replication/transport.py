"""Pluggable frame transport + deterministic fault injection (DESIGN.md §8.3).

``Transport`` is deliberately socket-shaped — ``send(dest, bytes)`` /
``recv(dest) -> [bytes]`` with no shared-memory assumptions, at-most-once
delivery and no ordering promise — so the in-process implementation used
here swaps for a real socket later without touching the protocol: replicas
already tolerate loss, duplication, reordering and truncation (the replica
protocol repairs via catch-up, §8.4, never by trusting the wire).

``InProcTransport``  — per-destination FIFO of raw frame bytes.
``FaultyTransport``  — wraps any transport and executes a
    ``runtime.failure.FaultPlan``'s schedule on channel ``"ship.<dest>"``:
    drop / duplicate / reorder / tear (truncate mid-frame) / delay /
    error (raise ``TransportError`` — the sender's retry+backoff path).
    Every injection is tallied, so serving stats can report exactly what
    the wire did to the stream.
"""
from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from ..runtime.failure import FaultPlan

__all__ = ["TransportError", "Transport", "InProcTransport", "FaultyTransport"]


class TransportError(RuntimeError):
    """Transient send failure — retryable (``runtime.failure.retry``)."""


class Transport:
    """The socket-shaped contract replication is written against."""

    def send(self, dest: str, data: bytes) -> None:
        raise NotImplementedError

    def recv(self, dest: str, max_messages: Optional[int] = None) -> List[bytes]:
        raise NotImplementedError

    def pending(self, dest: str) -> int:
        raise NotImplementedError


class InProcTransport(Transport):
    """Per-destination FIFO queues; the single-process stand-in."""

    def __init__(self):
        self._queues: Dict[str, deque] = {}

    def send(self, dest: str, data: bytes) -> None:
        self._queues.setdefault(dest, deque()).append(bytes(data))

    def recv(self, dest: str, max_messages: Optional[int] = None) -> List[bytes]:
        q = self._queues.get(dest)
        out: List[bytes] = []
        while q and (max_messages is None or len(out) < max_messages):
            out.append(q.popleft())
        return out

    def pending(self, dest: str) -> int:
        return len(self._queues.get(dest, ()))


class FaultyTransport(Transport):
    """Deterministic wire damage between a sender and its destinations.

    Consults ``plan.action(f"ship.<dest>")`` once per ``send`` and applies:

    ``"drop"``            — the frame never arrives.
    ``"dup"``             — the frame arrives twice.
    ``"reorder"``         — the frame is held and released after the NEXT
                            send to the same destination (adjacent swap).
    ``"tear"`` / ``("tear", n)`` — the first ``n`` bytes arrive (default:
                            half the frame) — a mid-frame truncation the
                            replica's CRC catches.
    ``("delay", k)``      — held for ``k`` subsequent sends, then released
                            BEFORE that send's own frame (delayed, not
                            reordered relative to later traffic forever).
    ``"error"`` / ``("error", k)`` — ``TransportError`` raised ``k`` times
                            (default 1) before the frame goes through on
                            retry; the sender's ``retry`` path.

    Held frames survive in per-destination queues; ``flush_held`` releases
    everything (promotion drains call it — a dead wire keeps no secrets the
    catch-up path cannot re-derive, but flushing models the OS delivering
    its socket buffers).
    """

    def __init__(self, inner: Transport, plan: Optional[FaultPlan] = None):
        self.inner = inner
        self.plan = plan
        self.drops = 0
        self.dups = 0
        self.tears = 0
        self.reorders = 0
        self.delays = 0
        self.errors = 0
        # dest -> [[countdown, data, release_after_frame]]: reordered frames
        # release AFTER the next frame (the adjacent swap), delayed frames
        # BEFORE their k-th subsequent frame (delayed, never swapped)
        self._held: Dict[str, List[List]] = {}
        self._error_budget: Dict[str, int] = {}  # dest -> errors still to raise

    # ------------------------------------------------------------------ #
    def _release_due(self, dest: str, after: bool) -> None:
        held = self._held.get(dest, [])
        still = []
        for item in held:
            if item[2] != after:
                still.append(item)
                continue
            item[0] -= 1
            if item[0] <= 0:
                self.inner.send(dest, item[1])
            else:
                still.append(item)
        self._held[dest] = still

    def send(self, dest: str, data: bytes) -> None:
        budget = self._error_budget.get(dest, 0)
        if budget > 0:                      # mid-retry of an injected error
            self._error_budget[dest] = budget - 1
            self.errors += 1
            raise TransportError(f"injected send error to {dest}")
        act = self.plan.action(f"ship.{dest}") if self.plan is not None else None
        name = act[0] if isinstance(act, tuple) else act
        self._release_due(dest, after=False)
        if name == "error":
            times = act[1] if isinstance(act, tuple) else 1
            self._error_budget[dest] = times - 1
            self.errors += 1
            raise TransportError(f"injected send error to {dest}")
        hold = None
        if name == "drop":
            self.drops += 1
        elif name == "dup":
            self.dups += 1
            self.inner.send(dest, data)
            self.inner.send(dest, data)
        elif name == "tear":
            keep = act[1] if isinstance(act, tuple) else max(len(data) // 2, 1)
            self.tears += 1
            self.inner.send(dest, data[:keep])
        elif name == "reorder":
            self.reorders += 1
            hold = [1, bytes(data), True]
        elif name == "delay":
            self.delays += 1
            hold = [int(act[1]), bytes(data), False]
        else:
            self.inner.send(dest, data)
        # frames held by EARLIER sends that were due "after the next frame"
        # go out now — behind this send's own frame (the adjacent swap); the
        # frame held by THIS send joins the queue only afterwards
        self._release_due(dest, after=True)
        if hold is not None:
            self._held.setdefault(dest, []).append(hold)

    def recv(self, dest: str, max_messages: Optional[int] = None) -> List[bytes]:
        return self.inner.recv(dest, max_messages)

    def pending(self, dest: str) -> int:
        return self.inner.pending(dest) + len(self._held.get(dest, ()))

    def flush_held(self, dest: Optional[str] = None) -> None:
        """Deliver every held (reordered/delayed) frame immediately."""
        dests = [dest] if dest is not None else list(self._held)
        for d in dests:
            for item in self._held.pop(d, []):
                self.inner.send(d, item[1])

    def counts(self) -> dict:
        return {"drops": self.drops, "dups": self.dups, "tears": self.tears,
                "reorders": self.reorders, "delays": self.delays,
                "errors": self.errors}
