"""Replication plane: WAL-shipped read replicas + health-checked failover
(DESIGN.md §8).

The durability plane (§7) made one process restartable; this package makes
the index SURVIVE the process.  The primary's journal — already a complete,
bit-exact history — doubles as the replication log:

``frames``     — the shipped-frame codec: CRC-guarded envelopes around
                 exact WAL record bytes (``F_WRITE``), the
                 compaction-rotation control frame (``F_ROTATE``), and
                 liveness (``F_HEARTBEAT``)
``transport``  — the socket-shaped delivery contract, its in-process
                 implementation, and ``FaultyTransport``: scripted wire
                 damage (drop / dup / reorder / tear / delay / error)
                 driven by a ``runtime.failure.FaultPlan``
``ship``       — ``ReplicationHub``: the primary-side fan-out hooked into
                 ``storage.Durability``, plus the pull/catch-up and
                 seeding paths (the journal is the retransmission buffer)
``replica``    — ``Replica``: ordered apply through the ordinary write
                 paths; bit-identical at its applied ``(epoch, next_seq)``
                 frontier under ANY fault schedule (§8.7 invariant)
``failover``   — ``ReplicatedServer``: bounded-staleness read routing over
                 healthy replicas, degradation to primary-serves-reads,
                 and no-data-loss promotion of the most-caught-up replica

Everything is numpy + stdlib and synchronous — determinism is the point:
one ``FaultPlan`` schedule reproduces an entire partial-failure scenario,
which is what lets the tests assert bit-identity instead of "eventually
looks right".
"""
from .frames import (F_HEARTBEAT, F_ROTATE, F_WRITE, Frame, FrameError,
                     decode_frame, encode_frame, frame_nbytes,
                     heartbeat_frame, rotate_frame, unpack_heartbeat,
                     unpack_rotate, unpack_write, write_frame)
from .transport import (FaultyTransport, InProcTransport, Transport,
                        TransportError)
from .ship import ReplicationHub, seed_state
from .replica import Replica, ReplicationError
from .failover import ReplicatedServer

__all__ = [
    "Frame", "FrameError", "F_WRITE", "F_ROTATE", "F_HEARTBEAT",
    "encode_frame", "decode_frame", "frame_nbytes", "write_frame",
    "rotate_frame", "heartbeat_frame", "unpack_write", "unpack_rotate",
    "unpack_heartbeat",
    "Transport", "InProcTransport", "FaultyTransport", "TransportError",
    "ReplicationHub", "seed_state",
    "Replica", "ReplicationError",
    "ReplicatedServer",
]
