"""minicpm3-4b [dense]: multi-head latent attention (MLA).
[hf:openbmb/MiniCPM3-4B; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    n_kv_heads=40,
    d_ff=6400,
    vocab_size=73448,
    mla=True,
    q_lora=768,
    kv_lora=256,
    nope_dim=64,
    rope_dim=32,
    v_dim=64,
    rope_theta=10_000.0,
    notes="MLA latent cache (kv_lora 256 + rope 32 per token); decode uses absorbed matmuls",
)
