"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks.
[arXiv:2411.15242; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    n_layers=54,                # mamba2 layers
    d_model=2560,
    n_heads=32,
    n_kv_heads=32,
    d_ff=10240,                 # shared block MLP
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_p=64,
    attn_every=6,               # shared attn applied after every 6 mamba layers
    n_shared_attn=2,            # two shared blocks, cycled
    rope_theta=10_000.0,
    notes="Mamba2 + 2 shared attn/MLP blocks cycled every 6 layers (9 applications)",
)
