"""Config dataclasses: architecture (ModelConfig) and workload shape
(ShapeConfig) definitions shared by smoke tests, the dry-run and launchers.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "VOCAB_PAD"]

VOCAB_PAD = 128  # pad vocab to a multiple (Megatron-style) so TP always divides


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None

    # attention pattern: cycled over layers ("global" / "local")
    layer_pattern: Tuple[str, ...] = ("global",)
    window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    query_scale: Optional[float] = None     # e.g. gemma2 query_pre_attn_scalar
    sandwich_norm: bool = False             # gemma2 post-block RMSNorms
    rope_theta: float = 10_000.0

    # MLA (multi-head latent attention)
    mla: bool = False
    q_lora: int = 0
    kv_lora: int = 0
    nope_dim: int = 0
    rope_dim: int = 0
    v_dim: int = 0

    # MoE
    n_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2) / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_p: int = 64
    ssd_chunk: int = 128            # SSD intra-chunk length (memory lever)
    attn_every: int = 0                     # hybrid: shared attn cadence
    n_shared_attn: int = 0                  # hybrid: number of shared blocks

    # enc-dec
    enc_layers: int = 0

    # multimodal stubs
    mrope_sections: Optional[Tuple[int, int, int]] = None
    n_patches: int = 0                      # vlm: stub patch embeds prepended
    frontend: Optional[str] = None          # "audio" | "vision" stub frontends

    tie_embeddings: bool = True
    rms_eps: float = 1e-6
    remat: str = "full"                     # "none" | "full"
    attn_chunk: int = 1024                  # flash-attention KV chunk length
    split_local_cache: bool = False         # local/global alternating archs:
                                            # ring caches (window slots) for the
                                            # local layers, full-length caches
                                            # only for the global ones
    notes: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        return ((self.vocab_size + VOCAB_PAD - 1) // VOCAB_PAD) * VOCAB_PAD

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_p

    def layer_kind(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    @property
    def uses_swa_everywhere(self) -> bool:
        return all(k == "local" for k in self.layer_pattern)

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: bounded-memory attention everywhere."""
        return self.family in ("ssm", "hybrid") or self.uses_swa_everywhere

    @property
    def paired_local_global(self) -> bool:
        return (self.split_local_cache
                and self.layer_pattern == ("local", "global")
                and self.n_layers % 2 == 0)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
