"""minitron-4b [dense]: width-pruned nemotron. [arXiv:2407.14679; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    d_ff=9216,
    vocab_size=256000,
    head_dim=128,
    rope_theta=10_000.0,
    notes="pruned nemotron; 24 heads (not divisible by TP=16 -> attention "
          "weights replicated, MLP/vocab sharded; see DESIGN.md)",
)
