"""Architecture registry: one module per assigned architecture plus the
paper's own experiment config.  ``get_config(name)`` accepts the canonical
ids used throughout benchmarks/launchers."""
from __future__ import annotations

from typing import Dict, List

from .base import SHAPES, ModelConfig, ShapeConfig
from . import (
    gemma2_27b,
    h2o_danube3_4b,
    mamba2_130m,
    minicpm3_4b,
    minitron_4b,
    mixtral_8x7b,
    phi35_moe,
    qwen2_vl_2b,
    seamless_m4t_large_v2,
    zamba2_2p7b,
)

_MODULES = [
    h2o_danube3_4b,
    minicpm3_4b,
    gemma2_27b,
    minitron_4b,
    seamless_m4t_large_v2,
    qwen2_vl_2b,
    mixtral_8x7b,
    phi35_moe,
    zamba2_2p7b,
    mamba2_130m,
]

REGISTRY: Dict[str, ModelConfig] = {m.CONFIG.name: m.CONFIG for m in _MODULES}


def get_config(name: str) -> ModelConfig:
    if name not in REGISTRY:
        raise KeyError(f"unknown arch '{name}'; known: {sorted(REGISTRY)}")
    return REGISTRY[name]


def list_configs() -> List[str]:
    return list(REGISTRY)


__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "REGISTRY", "get_config", "list_configs"]
