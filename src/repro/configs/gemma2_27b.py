"""gemma2-27b [dense]: alternating local/global attention + logit softcaps.
[arXiv:2408.00118; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    d_ff=36864,
    vocab_size=256000,
    head_dim=128,               # decoupled from d_model/n_heads, per the hf config
    layer_pattern=("local", "global"),
    window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    query_scale=0.0625,         # 1/sqrt(query_pre_attn_scalar=256)
    sandwich_norm=True,
    rope_theta=10_000.0,
    notes="local+global alternating; attn softcap 50, final softcap 30; GeGLU",
)
