"""h2o-danube-3-4b [dense]: llama+mistral mix with sliding-window attention.
[arXiv:2401.16818; unverified]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10240,
    vocab_size=32000,
    layer_pattern=("local",),   # SWA everywhere -> ring KV cache, long_500k OK
    window=4096,
    rope_theta=10_000.0,
    notes="GQA kv=8; SWA window 4096; head_dim 120",
)
