"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.
[arXiv:2401.04088; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    head_dim=128,
    n_experts=8,
    top_k=2,
    layer_pattern=("local",),   # SWA everywhere -> long_500k OK
    window=4096,
    rope_theta=1_000_000.0,
    notes="8e top-2; E=8 does not divide TP=16 -> per-expert d_ff tensor parallel",
)
