"""COAX index-side configuration defaults (the paper's own experiment setup,
§8.1): datasets, workload shapes, and index tuning used by the benchmarks."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class CoaxExperimentConfig:
    airline_rows: int = 2_000_000       # paper: 80M (scaled for CPU CI; --rows overrides)
    osm_rows: int = 2_000_000           # paper: 105M
    airline_2008_rows: int = 700_000    # paper Fig. 7: 7M (year 2008 slice)
    n_queries: int = 200
    knn_k: int = 100                    # controls selectivity (paper §8.1.2)
    selectivities: tuple = (10, 100, 1_000, 10_000)  # K sweep for Fig. 7
    rtree_node_cap: int = 10            # paper: best between 8 and 12
    seed: int = 7


CONFIG = CoaxExperimentConfig()
