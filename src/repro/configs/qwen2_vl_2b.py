"""qwen2-vl-2b [vlm]: M-RoPE decoder; vision patch embeds are a stub frontend.
[arXiv:2409.12191; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    head_dim=128,
    mrope_sections=(16, 24, 24),
    n_patches=1024,             # stub image: 1024 patch embeddings prepended
    rope_theta=1_000_000.0,
    notes="M-RoPE (t/h/w sections 16/24/24); dynamic resolution stubbed to 1024 patches",
)
