"""seamless-m4t-large-v2 [audio]: encoder-decoder backbone; the speech
frontend is a stub per the brief (input_specs provides precomputed frame
embeddings). [arXiv:2308.11596; hf]"""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="encdec",
    n_layers=24,                # decoder layers
    enc_layers=24,              # encoder layers
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    frontend="audio",
    rope_theta=10_000.0,
    notes="enc-dec; frames arrive as stub embeddings (B, S, d_model)",
)
