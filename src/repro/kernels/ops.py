"""Public jit'd wrappers around the Pallas kernels.

These handle ragged sizes (padding to tile multiples), parameter packing,
and expose numpy-friendly entry points the COAX core and benchmarks call.
``use_pallas=False`` routes to the pure-jnp oracle (identical results) —
the default on CPU, where interpret-mode Pallas is a correctness tool, not
a fast path.  The device serving plane (``engine.device``, DESIGN.md §4)
bypasses these host-facing wrappers: it embeds ``fused_scan_call`` /
``ref.fused_scan_ref`` segments directly inside its own jitted wave program
with plan-resident pre-padded arrays; ``fused_range_scan`` below is the
standalone entry for tests and notebooks.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .fused_scan import fused_scan
from .grid_histogram import grid_histogram
from .margin_split import margin_split
from .range_scan import range_scan
from .range_scan_batch import range_scan_batch

__all__ = [
    "range_scan_query",
    "range_scan_batch_query",
    "fused_range_scan",
    "bucket_histogram",
    "split_by_margin",
]


def _pad_to(arr: jnp.ndarray, multiple: int, value) -> jnp.ndarray:
    n = arr.shape[-1]
    rem = (-n) % multiple
    if rem == 0:
        return arr
    pad = [(0, 0)] * (arr.ndim - 1) + [(0, rem)]
    return jnp.pad(arr, pad, constant_values=value)


def range_scan_query(
    rows_t,                # (D, N) column-major records
    rect_lo,               # (D,)
    rect_hi,               # (D,)
    window=None,           # (2,) [lo, hi) scan window; None -> whole array
    *,
    tile: int = 512,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Count + mask for one translated query (paper §6 scan).

    Returns ``(count, mask (N,))`` where mask covers the ORIGINAL n records.
    """
    rows_t = jnp.asarray(rows_t, jnp.float32)
    d, n = rows_t.shape
    if window is None:
        window = jnp.array([0, n], jnp.int32)
    window = jnp.asarray(window, jnp.int32)
    padded = _pad_to(rows_t, tile, jnp.inf)  # +inf rows never match (< hi fails)
    if use_pallas:
        mask, counts = range_scan(
            padded, jnp.asarray(rect_lo, jnp.float32), jnp.asarray(rect_hi, jnp.float32),
            window, tile=tile, interpret=interpret,
        )
    else:
        mask, counts = ref.range_scan_ref(
            padded, jnp.asarray(rect_lo, jnp.float32), jnp.asarray(rect_hi, jnp.float32),
            window, tile=tile,
        )
    return counts.sum(), mask[:n]


def range_scan_batch_query(
    rows_t,                # (D, N) column-major records
    rect_lo,               # (B, D) per-query lower bounds
    rect_hi,               # (B, D) per-query upper bounds
    windows=None,          # (B, 2) per-query [lo, hi) scan windows; None -> whole
    *,
    tile: int = 512,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Counts + masks for a BATCH of translated queries in one device launch.

    Returns ``(counts (B,), mask (B, N))`` where each mask row covers the
    ORIGINAL n records.  The kernel wants bounds as (D, B) columns so one
    query's rect is a lane-resident block; this wrapper transposes.
    """
    rows_t = jnp.asarray(rows_t, jnp.float32)
    rect_lo = jnp.asarray(rect_lo, jnp.float32)
    rect_hi = jnp.asarray(rect_hi, jnp.float32)
    d, n = rows_t.shape
    b = rect_lo.shape[0]
    if windows is None:
        windows = jnp.broadcast_to(jnp.array([0, n], jnp.int32), (b, 2))
    windows = jnp.asarray(windows, jnp.int32)
    padded = _pad_to(rows_t, tile, jnp.inf)  # +inf rows never match (< hi fails)
    if use_pallas:
        mask, counts = range_scan_batch(
            padded, rect_lo.T, rect_hi.T, windows, tile=tile, interpret=interpret,
        )
    else:
        mask, counts = ref.range_scan_batch_ref(
            padded, rect_lo.T, rect_hi.T, windows, tile=tile,
        )
    return counts.sum(axis=1), mask[:, :n]


def fused_range_scan(
    rows_t,                # (D, N) column-major records
    rect_lo,               # (B, D) per-query ceil-rounded lower bounds
    rect_hi,               # (B, D) per-query ceil-rounded upper bounds
    alive=None,            # (N,) liveness; None -> all alive
    coords=None,           # (kk, N) per-dim cell coords (probe stage)
    first=None,            # (B, kk) per-query first cell coord
    last=None,             # (B, kk) per-query last cell coord
    sv=None,               # (N,) in-cell sorted attribute (sort stage)
    tband=None,            # (B, 2) per-query [t_lo, t_hi) sort targets
    *,
    tile: int = 512,
    hit_cap: int = 1024,
    use_pallas: bool = True,
    interpret: bool = True,
):
    """Standalone megakernel entry: pads N to a tile multiple and routes to
    the Pallas kernel or the jnp oracle.

    Returns ``(counts (B,), hits (B, hit_cap + tile), scanned (B,))``; see
    ``fused_scan`` for the compacted-hits contract.  Positions ≥ the
    original N never appear (pads are dead: rows +inf, alive 0, coords -1).
    """
    rows_t = jnp.asarray(rows_t, jnp.float32)
    d, n = rows_t.shape
    padded = _pad_to(rows_t, tile, jnp.inf)
    if alive is None:
        alive = jnp.ones(n, jnp.int32)
    alive_p = _pad_to(jnp.asarray(alive, jnp.int32), tile, 0)[None, :]
    kwargs = {}
    if coords is not None:
        kwargs["coords"] = _pad_to(jnp.asarray(coords, jnp.int32), tile, -1)
        kwargs["first"] = jnp.asarray(first, jnp.int32)
        kwargs["last"] = jnp.asarray(last, jnp.int32)
    if sv is not None:
        kwargs["sv"] = _pad_to(jnp.asarray(sv, jnp.float32), tile, jnp.inf)[None, :]
        kwargs["tband"] = jnp.asarray(tband, jnp.float32)
    flo_t = jnp.asarray(rect_lo, jnp.float32).T
    fhi_t = jnp.asarray(rect_hi, jnp.float32).T
    fn = fused_scan if use_pallas else ref.fused_scan_ref
    extra = {"interpret": interpret} if use_pallas else {}
    counts, hits, scanned = fn(padded, flo_t, fhi_t, alive_p,
                               tile=tile, hit_cap=hit_cap, **kwargs, **extra)
    return counts[:, 0], hits, scanned[:, 0]


def bucket_histogram(
    x, d, *,
    buckets: int = 64,
    tile: int = 256,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jnp.ndarray:
    """Algorithm 1 bucket counts on the device; returns (B, B) float32."""
    x = jnp.asarray(x, jnp.float32)
    d = jnp.asarray(d, jnp.float32)
    n = x.shape[0]
    x_lo, x_hi = x.min(), x.max()
    d_lo, d_hi = d.min(), d.max()
    wx = jnp.maximum((x_hi - x_lo) / buckets, 1e-30)
    wd = jnp.maximum((d_hi - d_lo) / buckets, 1e-30)
    params = jnp.stack(
        [x_lo, 1.0 / wx, d_lo, 1.0 / wd,
         jnp.float32(n), jnp.float32(0), jnp.float32(0), jnp.float32(0)]
    )
    xp = _pad_to(x, tile, 0.0)
    dp = _pad_to(d, tile, 0.0)
    if use_pallas:
        return grid_histogram(xp, dp, params, buckets=buckets, tile=tile, interpret=interpret)
    return ref.grid_histogram_ref(xp, dp, params, buckets=buckets)


def split_by_margin(
    x, d, m, b, eps_lb, eps_ub, *,
    tile: int = 1024,
    use_pallas: bool = True,
    interpret: bool = True,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Fused Alg.-1 split: returns ``(disp (N,), inlier_mask (N,) bool)``."""
    x = jnp.asarray(x, jnp.float32)
    d = jnp.asarray(d, jnp.float32)
    n = x.shape[0]
    params = jnp.array([m, b, eps_lb, eps_ub, n, 0, 0, 0], jnp.float32)
    xp = _pad_to(x, tile, 0.0)
    dp = _pad_to(d, tile, 0.0)
    if use_pallas:
        disp, mask, _ = margin_split(xp, dp, params, tile=tile, interpret=interpret)
    else:
        disp, mask, _ = ref.margin_split_ref(xp, dp, params, tile=tile)
    return disp[:n], mask[:n].astype(bool)
