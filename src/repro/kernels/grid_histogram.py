"""Pallas TPU kernel for Algorithm 1's grid bucketing (paper §5, Fig. 3).

The detection step histograms a 2-attribute sample onto a B x B grid.  A GPU
would scatter-add with atomics; on TPU (DESIGN.md §3) each grid program
builds the bucket assignment of its record tile as two one-hot matrices and
multiplies them on the MXU:

    hist_tile = onehot_x^T @ onehot_d        # (B, T) @ (T, B) -> (B, B)

The output BlockSpec maps every program to the SAME (B, B) block, so the
kernel accumulates in place across the sequential grid — the standard Pallas
revisiting-output reduction, no atomics required.

VMEM: two (T, B) one-hots + the (B, B) accumulator; with T=256, B=128 that is
2*128KiB + 64KiB at f32 — comfortably resident.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 256


def _grid_histogram_kernel(x_ref, d_ref, params_ref, hist_ref):
    """Accumulate one record tile into the (B, B) bucket histogram.

    x_ref, d_ref : (1, T) f32 — the two attribute columns for this tile
    params_ref   : (1, 8) f32 — [x_lo, inv_wx, d_lo, inv_wd, n_valid, ...]
    hist_ref     : (B, B) f32 out — accumulated across all programs
    """
    t = x_ref.shape[1]
    b = hist_ref.shape[0]
    pid = pl.program_id(0)

    x_lo = params_ref[0, 0]
    inv_wx = params_ref[0, 1]
    d_lo = params_ref[0, 2]
    inv_wd = params_ref[0, 3]
    n_valid = params_ref[0, 4]

    # Padding rows (global id >= n_valid) contribute nothing.
    gid = pid * t + jax.lax.broadcasted_iota(jnp.float32, (1, t), 1)
    valid = gid < n_valid                                          # (1, T)

    ix = jnp.clip((x_ref[...] - x_lo) * inv_wx, 0, b - 1).astype(jnp.int32)
    jd = jnp.clip((d_ref[...] - d_lo) * inv_wd, 0, b - 1).astype(jnp.int32)

    lanes = jax.lax.broadcasted_iota(jnp.int32, (t, b), 1)
    onehot_x = jnp.where((lanes == ix[0, :, None]) & valid[0, :, None], 1.0, 0.0)
    onehot_d = jnp.where((lanes == jd[0, :, None]) & valid[0, :, None], 1.0, 0.0)

    # MXU contraction over the record axis: (B, T) @ (T, B).
    tile_hist = jax.lax.dot_general(
        onehot_x, onehot_d,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pid == 0)
    def _init():
        hist_ref[...] = tile_hist

    @pl.when(pid > 0)
    def _acc():
        hist_ref[...] += tile_hist


@functools.partial(jax.jit, static_argnames=("buckets", "tile", "interpret"))
def grid_histogram(
    x: jax.Array,          # (N,) f32, N multiple of tile (ops pads)
    d: jax.Array,          # (N,) f32
    params: jax.Array,     # (8,) f32 — [x_lo, inv_wx, d_lo, inv_wd, n_valid, 0, 0, 0]
    *,
    buckets: int = 64,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Bucket-count the (x, d) sample onto a ``buckets x buckets`` grid."""
    n = x.shape[0]
    if n % tile:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    num_tiles = n // tile

    hist = pl.pallas_call(
        _grid_histogram_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((buckets, buckets), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((buckets, buckets), jnp.float32),
        interpret=interpret,
    )(x[None, :], d[None, :], params[None, :])
    return hist
