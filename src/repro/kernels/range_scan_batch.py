"""Pallas TPU kernel for the batched COAX range scan (DESIGN.md §3; the
filter stage of the §4 device serving plane).

``range_scan.py`` evaluates ONE translated rectangle per launch; the batched
engine instead fuses B queries into a single ``pl.pallas_call`` so the record
block is streamed from HBM once per batch row rather than once per Python
round-trip, and the (D, TILE) tile in VMEM is reused across the whole rect
batch wavefront.

Layout: the grid is (num_tiles, B) — the LAST grid axis iterates fastest on
TPU, so b varies innermost.  Program (i, b) loads the shared record tile
``rows[:, i*TILE:(i+1)*TILE]`` plus query b's bounds column (rect lo/hi
stored (D, B) so each query's bounds are one (D, 1) lane-resident block) and
window row, and emits query b's per-record match mask and per-tile count.
The rows BlockSpec maps every b to the same tile, so the pipeline keeps the
tile resident across the whole rect batch — B predicate evaluations per HBM
fetch instead of B full passes over the record array.

VMEM per program: (D, TILE) f32 rows + two (D, 1) bound columns ≈ D*2 KiB at
TILE=512 — identical budget to the single-query kernel; batching lives
entirely in the grid, not the block shapes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _range_scan_batch_kernel(rows_ref, lo_ref, hi_ref, win_ref, mask_ref, count_ref):
    """One (tile i, query b) program: rect predicate + window mask + count.

    rows_ref : (D, TILE) f32 — record block shared by all b at this i
    lo_ref   : (D, 1)   f32 — query b's lower bounds
    hi_ref   : (D, 1)   f32 — query b's upper bounds
    win_ref  : (1, 2)   i32 — query b's [scan_lo, scan_hi) window
    mask_ref : (1, TILE) i32 out — 1 where the record matches query b
    count_ref: (1, 1)   i32 out — matches for (b, tile i)
    """
    tile = rows_ref.shape[1]
    i = pl.program_id(0)

    rows = rows_ref[...]                                   # (D, TILE)
    lo = lo_ref[...]                                       # (D, 1)
    hi = hi_ref[...]
    inside = jnp.all((rows >= lo) & (rows < hi), axis=0)   # (TILE,)

    gid = i * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    in_window = (gid >= win_ref[0, 0]) & (gid < win_ref[0, 1])

    hit = in_window & inside[None, :]
    mask_ref[...] = hit.astype(jnp.int32)
    count_ref[0, 0] = jnp.sum(hit.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def range_scan_batch(
    rows_t: jax.Array,      # (D, N) f32, column-major records
    rect_lo_t: jax.Array,   # (D, B) f32 — one bounds column per query
    rect_hi_t: jax.Array,   # (D, B) f32
    windows: jax.Array,     # (B, 2) i32 — per-query [scan_lo, scan_hi)
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Evaluate B translated queries over one record block in one launch.

    Returns ``(mask (B, N) int32, counts (B, num_tiles) int32)``.  N must be
    a multiple of ``tile`` (``ops.range_scan_batch_query`` pads).
    """
    d, n = rows_t.shape
    if n % tile:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    b = rect_lo_t.shape[1]
    num_tiles = n // tile

    mask, counts = pl.pallas_call(
        _range_scan_batch_kernel,
        grid=(num_tiles, b),                               # b innermost: tile stays resident
        in_specs=[
            pl.BlockSpec((d, tile), lambda i, b: (0, i)),  # rows: shared tile
            pl.BlockSpec((d, 1), lambda i, b: (0, b)),     # lo: query column
            pl.BlockSpec((d, 1), lambda i, b: (0, b)),     # hi: query column
            pl.BlockSpec((1, 2), lambda i, b: (b, 0)),     # window: query row
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i, b: (b, i)),
            pl.BlockSpec((1, 1), lambda i, b: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.int32),
            jax.ShapeDtypeStruct((b, num_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(rows_t, rect_lo_t, rect_hi_t, windows)
    return mask, counts
