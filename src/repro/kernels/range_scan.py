"""Pallas TPU kernel for the COAX scan-between-bounds hot loop (paper §6).

The paper's C implementation binary-searches the in-cell sorted attribute and
then linearly scans rows, testing the (translated) query rectangle per row.
On TPU the scan is re-blocked (DESIGN.md §3): rows are stored column-major
(D, N) so the record axis lies along the 128-wide vector lanes; each grid
program streams one (D, TILE) block from HBM into VMEM, evaluates the whole
rectangle predicate for TILE records with predicated vector compares, masks
records outside the [lo, hi) scan window, and emits

  * a per-record match mask   (the gather/driver consumes it), and
  * a per-tile match count    (for two-pass count/allocate query execution).

Divergence-free: out-of-window tiles still execute but contribute zeros — the
wrapper in ``ops.py`` restricts the grid to the touched tile range instead.

Block shapes: TILE defaults to 512 lanes (4 VREGs deep at f32) and the full
attribute dimension D sits along sublanes; (D, 512) f32 = D*2KiB of VMEM per
operand, far under the ~16 MiB/core budget even with D=8 and double buffering.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512


def _range_scan_kernel(rows_ref, lo_ref, hi_ref, win_ref, mask_ref, count_ref):
    """One (D, TILE) block: rectangle predicate + window mask + tile count.

    rows_ref : (D, TILE) f32 — column-major record block
    lo_ref   : (D, 1)   f32 — rectangle lower bounds (broadcast over lanes)
    hi_ref   : (D, 1)   f32 — rectangle upper bounds
    win_ref  : (1, 2)   i32 — [scan_lo, scan_hi) window in global row ids
    mask_ref : (1, TILE) i32 out — 1 where the record matches
    count_ref: (1, 1)   i32 out — number of matches in this tile
    """
    tile = rows_ref.shape[1]
    pid = pl.program_id(0)

    rows = rows_ref[...]                                   # (D, TILE)
    lo = lo_ref[...]                                       # (D, 1)
    hi = hi_ref[...]
    inside = jnp.all((rows >= lo) & (rows < hi), axis=0)   # (TILE,)

    # Global record ids of this tile -> window predicate.
    gid = pid * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
    win_lo = win_ref[0, 0]
    win_hi = win_ref[0, 1]
    in_window = (gid >= win_lo) & (gid < win_hi)           # (1, TILE)

    hit = in_window & inside[None, :]
    mask_ref[...] = hit.astype(jnp.int32)
    count_ref[0, 0] = jnp.sum(hit.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def range_scan(
    rows_t: jax.Array,      # (D, N) f32, column-major records
    rect_lo: jax.Array,     # (D,)  f32
    rect_hi: jax.Array,     # (D,)  f32
    window: jax.Array,      # (2,)  i32 — [scan_lo, scan_hi)
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Evaluate one translated query over a record block.

    Returns ``(mask (N,) int32, counts (num_tiles,) int32)``.  N must be a
    multiple of ``tile`` (``ops.range_scan_query`` pads).
    """
    d, n = rows_t.shape
    if n % tile:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    num_tiles = n // tile

    mask, counts = pl.pallas_call(
        _range_scan_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((d, tile), lambda i: (0, i)),   # rows: stream tiles
            pl.BlockSpec((d, 1), lambda i: (0, 0)),      # rect lo: resident
            pl.BlockSpec((d, 1), lambda i: (0, 0)),      # rect hi: resident
            pl.BlockSpec((1, 2), lambda i: (0, 0)),      # window: resident
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, num_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(rows_t, rect_lo[:, None], rect_hi[:, None], window[None, :])
    return mask[0], counts[0]
