"""Pallas TPU kernels for COAX's compute hot spots (paper §6 scans, §5 Alg. 1).

Each kernel file contains the ``pl.pallas_call`` + BlockSpec tiling; ``ops``
exposes padded jit'd wrappers; ``ref`` holds the pure-jnp oracles the tests
compare against.  All kernels are validated in interpret mode on CPU; the
BlockSpecs target TPU v5e VMEM/VPU/MXU geometry (DESIGN.md §3).  The
device-resident serving plane (``engine.device``, DESIGN.md §4) embeds
``fused_scan`` — probe + segment search + filter + compaction in ONE launch
with device-resident compacted hit buffers — as its per-wave program.
"""
from .fused_scan import fused_scan, fused_scan_call
from .ops import (bucket_histogram, fused_range_scan, range_scan_batch_query,
                  range_scan_query, split_by_margin)
from . import ref

__all__ = ["range_scan_query", "range_scan_batch_query", "fused_range_scan",
           "fused_scan", "fused_scan_call", "bucket_histogram",
           "split_by_margin", "ref"]
