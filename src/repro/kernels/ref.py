"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` mirrors its kernel's exact contract (same inputs incl. padding
and params vectors, same outputs) so the tests can ``assert_allclose`` across
shape/dtype sweeps, and doubles as the CPU fallback path — notably
``range_scan_batch_ref`` is the CPU filter stage of the device serving
plane's fused per-wave program (``engine.device``, DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["range_scan_ref", "range_scan_batch_ref", "fused_scan_ref",
           "grid_histogram_ref", "margin_split_ref"]


def range_scan_ref(rows_t, rect_lo, rect_hi, window, *, tile: int = 512):
    """Oracle for ``range_scan.range_scan``: (mask (N,), counts (num_tiles,))."""
    d, n = rows_t.shape
    inside = jnp.all(
        (rows_t >= rect_lo[:, None]) & (rows_t < rect_hi[:, None]), axis=0
    )
    gid = jnp.arange(n, dtype=jnp.int32)
    in_window = (gid >= window[0]) & (gid < window[1])
    mask = (inside & in_window).astype(jnp.int32)
    counts = mask.reshape(n // tile, tile).sum(axis=1)
    return mask, counts


def range_scan_batch_ref(rows_t, rect_lo_t, rect_hi_t, windows, *, tile: int = 512):
    """Oracle for ``range_scan_batch``: (mask (B, N), counts (B, num_tiles)).

    rect_lo_t/rect_hi_t are (D, B) bounds columns, windows is (B, 2) — the
    exact kernel contract including padding.
    """
    d, n = rows_t.shape
    lo = rect_lo_t.T[:, :, None]                               # (B, D, 1)
    hi = rect_hi_t.T[:, :, None]
    inside = jnp.all((rows_t[None] >= lo) & (rows_t[None] < hi), axis=1)  # (B, N)
    gid = jnp.arange(n, dtype=jnp.int32)[None, :]
    in_window = (gid >= windows[:, :1]) & (gid < windows[:, 1:])
    mask = (inside & in_window).astype(jnp.int32)
    counts = mask.reshape(mask.shape[0], n // tile, tile).sum(axis=2)
    return mask, counts


def fused_scan_ref(rows_t, flo_t, fhi_t, alive, coords=None, first=None,
                   last=None, sv=None, tband=None, gidx=None, *,
                   tile: int = 512, hit_cap: int = 1024):
    """Oracle for ``fused_scan.fused_scan`` — identical contract, and the
    CPU fast path of the §4 device plane.

    Returns ``(counts (Bp, 1) i32, hits (Bp, hit_cap + tile) i32,
    scanned (Bp, 1) i32)`` with ``hits[b, :min(counts[b], hit_cap)]`` the
    matching row positions ascending (unspecified slots are -1, which also
    matches the kernel for non-overflowing queries).

    Two things differ from the kernel's tile loop, neither observable:

    * **Candidate-gather scan** (``gidx (Bp, R)`` i32): each query's
      predicate evaluation runs over only ``rows_t[:, gidx[b]]`` — the
      device plane fills ``gidx`` with EXACTLY each query's probe-derived
      candidate-box row positions, ascending (each cell in the candidate
      coord box is a contiguous cell-major block), padded with the
      position of a dead ``+inf`` pad row.  Exact because every row a
      query can HIT is a member of its candidate box (rows outside fail
      the coord test in the full scan too), each candidate appears exactly
      once, and pad slots fail the ``alive`` test.  Because membership is
      exact, the ``coords``/``first``/``last`` test is implied and skipped
      on this path (same ``counts``/``hits``/``scanned``).  Hit positions
      come back global via a ``gidx`` gather.  This makes the CPU oracle
      scale with per-query candidate counts instead of table size, like
      the numpy path; ``gidx=None`` scans the full array
      (kernel-identical shape work).
    * **Bisect compaction**: instead of the kernel's per-tile
      cumsum-scatter (XLA CPU scatters serialise), the j-th defined hit
      slot is located by bisecting the running hit count — same prefix,
      built by pure gathers.
    """
    d, n = rows_t.shape
    bp = flo_t.shape[1]
    if gidx is not None:
        width = gidx.shape[1]
        inside = jnp.ones((bp, width), bool)
        for j in range(d):
            inside &= (rows_t[j][gidx] >= flo_t[j][:, None]) & (
                rows_t[j][gidx] < fhi_t[j][:, None])
        cand = alive[0][gidx] > 0
        # coord-box membership is implied: gidx holds exactly the box rows
        if sv is not None:
            cand = cand & (sv[0][gidx] >= tband[:, :1]) & (
                sv[0][gidx] < tband[:, 1:])
    else:
        inside = jnp.ones((bp, n), bool)
        for j in range(d):
            inside &= (rows_t[j][None, :] >= flo_t[j][:, None]) & (
                rows_t[j][None, :] < fhi_t[j][:, None])
        cand = jnp.broadcast_to(alive > 0, (bp, n))
        if coords is not None:
            for j in range(coords.shape[0]):
                cand = cand & (coords[j][None, :] >= first[:, j:j + 1]) & (
                    coords[j][None, :] <= last[:, j:j + 1])
        if sv is not None:
            cand = cand & (sv >= tband[:, :1]) & (sv < tband[:, 1:])
    hit = cand & inside

    running = jnp.cumsum(hit.astype(jnp.int32), axis=1)        # nondecreasing
    counts = running[:, -1:]
    scanned = cand.sum(axis=1, dtype=jnp.int32)[:, None]
    targets = jnp.arange(1, hit_cap + 1, dtype=jnp.int32)
    idx = jax.vmap(                        # j-th hit = first i with count j+1
        lambda r: jnp.searchsorted(r, targets, side="left"))(running)
    defined = targets[None, :] <= jnp.minimum(counts, hit_cap)
    if gidx is not None:                   # local slot -> global row position
        pos = jnp.take_along_axis(
            gidx, jnp.minimum(idx, gidx.shape[1] - 1), axis=1)
    else:
        pos = idx
    body = jnp.where(defined, pos.astype(jnp.int32), -1)
    hits = jnp.pad(body, ((0, 0), (0, tile)), constant_values=-1)
    return counts, hits, scanned


def grid_histogram_ref(x, d, params, *, buckets: int = 64):
    """Oracle for ``grid_histogram.grid_histogram``: (B, B) f32 counts."""
    x_lo, inv_wx, d_lo, inv_wd, n_valid = params[0], params[1], params[2], params[3], params[4]
    n = x.shape[0]
    ix = jnp.clip((x - x_lo) * inv_wx, 0, buckets - 1).astype(jnp.int32)
    jd = jnp.clip((d - d_lo) * inv_wd, 0, buckets - 1).astype(jnp.int32)
    valid = jnp.arange(n, dtype=jnp.float32) < n_valid
    flat = ix * buckets + jd
    hist = jnp.zeros(buckets * buckets, dtype=jnp.float32).at[flat].add(
        valid.astype(jnp.float32)
    )
    return hist.reshape(buckets, buckets)


def margin_split_ref(x, d, params, *, tile: int = 1024):
    """Oracle for ``margin_split.margin_split``: (disp, mask, tile_counts)."""
    m, b, eps_lb, eps_ub, n_valid = params[0], params[1], params[2], params[3], params[4]
    n = x.shape[0]
    disp = d - (m * x + b)
    valid = jnp.arange(n, dtype=jnp.float32) < n_valid
    mask = ((disp >= -eps_lb) & (disp <= eps_ub) & valid).astype(jnp.int32)
    counts = mask.reshape(n // tile, tile).sum(axis=1)
    return disp, mask, counts
