"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` mirrors its kernel's exact contract (same inputs incl. padding
and params vectors, same outputs) so the tests can ``assert_allclose`` across
shape/dtype sweeps, and doubles as the CPU fallback path — notably
``range_scan_batch_ref`` is the CPU filter stage of the device serving
plane's fused per-wave program (``engine.device``, DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["range_scan_ref", "range_scan_batch_ref", "grid_histogram_ref",
           "margin_split_ref"]


def range_scan_ref(rows_t, rect_lo, rect_hi, window, *, tile: int = 512):
    """Oracle for ``range_scan.range_scan``: (mask (N,), counts (num_tiles,))."""
    d, n = rows_t.shape
    inside = jnp.all(
        (rows_t >= rect_lo[:, None]) & (rows_t < rect_hi[:, None]), axis=0
    )
    gid = jnp.arange(n, dtype=jnp.int32)
    in_window = (gid >= window[0]) & (gid < window[1])
    mask = (inside & in_window).astype(jnp.int32)
    counts = mask.reshape(n // tile, tile).sum(axis=1)
    return mask, counts


def range_scan_batch_ref(rows_t, rect_lo_t, rect_hi_t, windows, *, tile: int = 512):
    """Oracle for ``range_scan_batch``: (mask (B, N), counts (B, num_tiles)).

    rect_lo_t/rect_hi_t are (D, B) bounds columns, windows is (B, 2) — the
    exact kernel contract including padding.
    """
    d, n = rows_t.shape
    lo = rect_lo_t.T[:, :, None]                               # (B, D, 1)
    hi = rect_hi_t.T[:, :, None]
    inside = jnp.all((rows_t[None] >= lo) & (rows_t[None] < hi), axis=1)  # (B, N)
    gid = jnp.arange(n, dtype=jnp.int32)[None, :]
    in_window = (gid >= windows[:, :1]) & (gid < windows[:, 1:])
    mask = (inside & in_window).astype(jnp.int32)
    counts = mask.reshape(mask.shape[0], n // tile, tile).sum(axis=2)
    return mask, counts


def grid_histogram_ref(x, d, params, *, buckets: int = 64):
    """Oracle for ``grid_histogram.grid_histogram``: (B, B) f32 counts."""
    x_lo, inv_wx, d_lo, inv_wd, n_valid = params[0], params[1], params[2], params[3], params[4]
    n = x.shape[0]
    ix = jnp.clip((x - x_lo) * inv_wx, 0, buckets - 1).astype(jnp.int32)
    jd = jnp.clip((d - d_lo) * inv_wd, 0, buckets - 1).astype(jnp.int32)
    valid = jnp.arange(n, dtype=jnp.float32) < n_valid
    flat = ix * buckets + jd
    hist = jnp.zeros(buckets * buckets, dtype=jnp.float32).at[flat].add(
        valid.astype(jnp.float32)
    )
    return hist.reshape(buckets, buckets)


def margin_split_ref(x, d, params, *, tile: int = 1024):
    """Oracle for ``margin_split.margin_split``: (disp, mask, tile_counts)."""
    m, b, eps_lb, eps_ub, n_valid = params[0], params[1], params[2], params[3], params[4]
    n = x.shape[0]
    disp = d - (m * x + b)
    valid = jnp.arange(n, dtype=jnp.float32) < n_valid
    mask = ((disp >= -eps_lb) & (disp <= eps_ub) & valid).astype(jnp.int32)
    counts = mask.reshape(n // tile, tile).sum(axis=1)
    return disp, mask, counts
