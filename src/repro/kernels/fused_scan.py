"""Fused probe+search+filter Pallas megakernel (DESIGN.md §4).

One ``pl.pallas_call`` evaluates, for every (query b, record tile i) of a
``(Bp, num_tiles)`` grid, the WHOLE per-row serving predicate that the old
device pipeline spread over three stages (directory probe, per-cell bisect,
windowed filter):

  ``hit[p] = alive[p] ∧ candidate[p] ∧ full-predicate[p]``

* ``candidate`` replaces both the probe and the bisect: the host passes the
  per-query per-grid-dim cell range ``[first, last]`` (ONE conservative-f32
  directory pass, shared with the overflow pre-check) and the kernel tests
  each row's precomputed cell coordinates against it, plus the in-cell
  sorted attribute against ``[t_lo, t_hi)``.  Because rows are stored
  cell-major and cell-sorted, this membership test selects exactly the rows
  of the numpy path's refined candidate blocks — no window union, no
  ragged cell expansion, no ``cell_cap`` padding inside the kernel.
* ``full-predicate`` is the ceil-rounded f32 rect compare (`f32_ceil`
  pairing makes it bit-equal to the f64 host compare).
* ``alive`` masks tombstoned snapshot rows and delta padding, so the §5
  delta/tombstone scan runs in the same launch (``probe=False`` segments
  scan an append-log block with candidacy ≡ alive).

Outputs are device-resident and COMPACTED per query: a true hit count, the
first ``min(count, hit_cap)`` hit positions in ascending order, and the
candidate-rows-scanned counter.  Only these small buffers ever transfer
back (at explicit drain points, ``engine.device``), replacing the old
``(B, N)`` hit-mask transfer.

Grid order: ``b`` is the OUTER axis, tiles innermost — each query's output
block stays resident while its tiles accumulate (counts/hits/scanned revisit
the same ``(b, 0)`` block every step, the §3 accumulation idiom).  That
trades the record-tile reuse of ``range_scan_batch`` for resident per-query
accumulators; the record block streams once per query.

Compaction inside a tile is branch-free: ``pos = cumsum(hit) - 1`` ranks the
tile's hits, a drop-mode scatter packs their global row positions ascending,
and the packed tile is stored at dynamic offset ``min(count_so_far,
hit_cap)``.  Entries past ``min(count, hit_cap)`` are unspecified (the
buffer is ``hit_cap + tile`` wide so the last store stays in bounds); a
query whose count exceeds ``hit_cap`` is re-answered exactly on the host
from captured state (the drain-time overflow contract, DESIGN.md §4).

``ref.fused_scan_ref`` is the pure-jnp oracle with the identical contract;
it doubles as the CPU fast path inside the device plane's jitted wave
program (interpret-mode Pallas is a correctness tool, not a fast path).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 512
DEFAULT_HIT_CAP = 1024

__all__ = ["fused_scan", "fused_scan_call", "DEFAULT_TILE", "DEFAULT_HIT_CAP"]


def _make_kernel(probe: bool, has_sort: bool, tile: int, hit_cap: int):
    """Kernel body specialised to which predicate stages this segment has.

    Ref order (present refs only):
      rows (D, T) f32 | [coords (kk, T) i32, first (1, kk) i32,
      last (1, kk) i32] | [sv (1, T) f32, tband (1, 2) f32] |
      alive (1, T) i32, flo (D, 1) f32, fhi (D, 1) f32
      -> count (1, 1) i32, hits (1, hit_cap + T) i32, scanned (1, 1) i32
    """

    def kernel(*refs):
        it = iter(refs)
        rows_ref = next(it)
        coords_ref = next(it) if probe else None
        first_ref = next(it) if probe else None
        last_ref = next(it) if probe else None
        sv_ref = next(it) if has_sort else None
        tband_ref = next(it) if has_sort else None
        alive_ref = next(it)
        flo_ref = next(it)
        fhi_ref = next(it)
        count_ref = next(it)
        hits_ref = next(it)
        scanned_ref = next(it)

        i = pl.program_id(1)

        @pl.when(i == 0)
        def _init():                     # fresh resident buffers per wave
            count_ref[...] = jnp.zeros_like(count_ref)
            scanned_ref[...] = jnp.zeros_like(scanned_ref)
            hits_ref[...] = jnp.full_like(hits_ref, -1)

        rows = rows_ref[...]                                   # (D, T)
        inside = jnp.all((rows >= flo_ref[...]) & (rows < fhi_ref[...]),
                         axis=0, keepdims=True)                # (1, T)
        cand = alive_ref[...] > 0                              # (1, T)
        if probe:
            coords = coords_ref[...]                           # (kk, T)
            in_range = (coords >= first_ref[...].T) & (coords <= last_ref[...].T)
            cand = cand & jnp.all(in_range, axis=0, keepdims=True)
        if has_sort:
            sv = sv_ref[...]                                   # (1, T)
            cand = cand & (sv >= tband_ref[0, 0]) & (sv < tband_ref[0, 1])
        hit = cand & inside

        # branch-free per-tile compaction: rank hits, pack ascending
        gid = i * tile + jax.lax.broadcasted_iota(jnp.int32, (1, tile), 1)
        hi32 = hit.astype(jnp.int32)
        nh = jnp.sum(hi32)
        pos = jnp.cumsum(hi32[0]) - 1                          # (T,)
        tgt = jnp.where(hit[0], pos, tile)                     # miss -> dropped
        packed = jnp.full((tile,), -1, jnp.int32).at[tgt].set(
            gid[0], mode="drop")

        base = count_ref[0, 0]
        start = jnp.minimum(base, hit_cap)   # clamp keeps the store in bounds
        hits_ref[0, pl.ds(start, tile)] = packed
        count_ref[0, 0] = base + nh
        scanned_ref[0, 0] = scanned_ref[0, 0] + jnp.sum(cand.astype(jnp.int32))

    return kernel


def fused_scan_call(
    rows_t,            # (D, N_pad) f32, N_pad % tile == 0, pads +inf
    flo_t,             # (D, Bp) f32 ceil-rounded lower bounds (columns)
    fhi_t,             # (D, Bp) f32 ceil-rounded upper bounds
    alive,             # (1, N_pad) i32, 0 for tombstoned/padding rows
    coords=None,       # (kk, N_pad) i32 per-dim cell coords (pads -1); probe
    first=None,        # (Bp, kk) i32 per-query first cell coord;     segments
    last=None,         # (Bp, kk) i32 per-query last cell coord;      only
    sv=None,           # (1, N_pad) f32 in-cell sorted attribute (pads +inf)
    tband=None,        # (Bp, 2) f32 ceil-rounded [t_lo, t_hi) sort targets
    *,
    tile: int = DEFAULT_TILE,
    hit_cap: int = DEFAULT_HIT_CAP,
    interpret: bool = True,
):
    """Launch the megakernel over one segment; see module docstring.

    Returns ``(counts (Bp, 1) i32, hits (Bp, hit_cap + tile) i32,
    scanned (Bp, 1) i32)``.  ``hits[b, :min(counts[b], hit_cap)]`` are the
    matching row positions ascending; later entries are unspecified.
    Probe/sort stages are enabled by passing their operands (all-or-none
    per stage).  Not jitted — the device plane embeds this inside its own
    jitted wave program; ``fused_scan`` is the standalone jitted entry.
    """
    probe = coords is not None
    has_sort = sv is not None
    d, n = rows_t.shape
    if n % tile:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    bp = flo_t.shape[1]
    num_tiles = n // tile

    operands = [rows_t]
    in_specs = [pl.BlockSpec((d, tile), lambda b, i: (0, i))]
    if probe:
        kk = coords.shape[0]
        operands += [coords, first, last]
        in_specs += [
            pl.BlockSpec((kk, tile), lambda b, i: (0, i)),
            pl.BlockSpec((1, kk), lambda b, i: (b, 0)),
            pl.BlockSpec((1, kk), lambda b, i: (b, 0)),
        ]
    if has_sort:
        operands += [sv, tband]
        in_specs += [
            pl.BlockSpec((1, tile), lambda b, i: (0, i)),
            pl.BlockSpec((1, 2), lambda b, i: (b, 0)),
        ]
    operands += [alive, flo_t, fhi_t]
    in_specs += [
        pl.BlockSpec((1, tile), lambda b, i: (0, i)),
        pl.BlockSpec((d, 1), lambda b, i: (0, b)),
        pl.BlockSpec((d, 1), lambda b, i: (0, b)),
    ]
    width = hit_cap + tile
    counts, hits, scanned = pl.pallas_call(
        _make_kernel(probe, has_sort, tile, hit_cap),
        grid=(bp, num_tiles),              # tiles innermost: resident outputs
        in_specs=in_specs,
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
            pl.BlockSpec((1, width), lambda b, i: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, i: (b, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
            jax.ShapeDtypeStruct((bp, width), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
    )(*operands)
    return counts, hits, scanned


@functools.partial(jax.jit,
                   static_argnames=("tile", "hit_cap", "interpret"))
def fused_scan(rows_t, flo_t, fhi_t, alive, coords=None, first=None,
               last=None, sv=None, tband=None, *,
               tile: int = DEFAULT_TILE, hit_cap: int = DEFAULT_HIT_CAP,
               interpret: bool = True):
    """Jitted standalone wrapper of ``fused_scan_call`` (tests, notebooks)."""
    return fused_scan_call(rows_t, flo_t, fhi_t, alive, coords, first, last,
                           sv, tband, tile=tile, hit_cap=hit_cap,
                           interpret=interpret)
