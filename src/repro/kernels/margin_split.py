"""Pallas TPU kernel fusing Algorithm 1's split loop (paper §5, Alg. 1 end).

For every record the split computes the displacement against the learned
soft-FD model and routes the record to the primary or outlier index:

    disp   = d - (m * x + b)
    inlier = (-eps_lb <= disp) & (disp <= eps_ub)

A scalar loop on the host; one fused multiply-compare pass on the TPU VPU.
The kernel also emits per-tile inlier counts, whose exclusive prefix sum
gives each tile its write offset for the stable partition performed by the
wrapper (``ops.margin_split``) — the TPU-idiomatic replacement for the
paper's row-at-a-time ``primary.insert/outlier.insert``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 1024


def _margin_split_kernel(x_ref, d_ref, params_ref, disp_ref, mask_ref, count_ref):
    """disp/inlier/count for one (1, T) record tile.

    params_ref: (1, 8) f32 — [m, b, eps_lb, eps_ub, n_valid, ...]
    """
    t = x_ref.shape[1]
    pid = pl.program_id(0)

    m = params_ref[0, 0]
    b = params_ref[0, 1]
    eps_lb = params_ref[0, 2]
    eps_ub = params_ref[0, 3]
    n_valid = params_ref[0, 4]

    disp = d_ref[...] - (m * x_ref[...] + b)              # (1, T) fused FMA
    gid = pid * t + jax.lax.broadcasted_iota(jnp.float32, (1, t), 1)
    valid = gid < n_valid
    inlier = (disp >= -eps_lb) & (disp <= eps_ub) & valid

    disp_ref[...] = disp
    mask_ref[...] = inlier.astype(jnp.int32)
    count_ref[0, 0] = jnp.sum(inlier.astype(jnp.int32))


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def margin_split(
    x: jax.Array,        # (N,) f32, N multiple of tile (ops pads)
    d: jax.Array,        # (N,) f32
    params: jax.Array,   # (8,) f32 — [m, b, eps_lb, eps_ub, n_valid, 0, 0, 0]
    *,
    tile: int = DEFAULT_TILE,
    interpret: bool = True,
):
    """Returns ``(disp (N,), inlier_mask (N,) int32, tile_counts)``."""
    n = x.shape[0]
    if n % tile:
        raise ValueError(f"N={n} must be a multiple of tile={tile}")
    num_tiles = n // tile

    disp, mask, counts = pl.pallas_call(
        _margin_split_kernel,
        grid=(num_tiles,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, 8), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, tile), lambda i: (0, i)),
            pl.BlockSpec((1, 1), lambda i: (0, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((1, n), jnp.float32),
            jax.ShapeDtypeStruct((1, n), jnp.int32),
            jax.ShapeDtypeStruct((1, num_tiles), jnp.int32),
        ],
        interpret=interpret,
    )(x[None, :], d[None, :], params[None, :])
    return disp[0], mask[0], counts[0]
