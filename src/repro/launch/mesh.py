"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the smoke tests, which must see
exactly one device.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: "pod" (outer data parallel, crosses DCN), "data" (data parallel /
    ZeRO shard axis), "model" (tensor/expert parallel, stays inside an ICI
    torus dimension).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) local devices exist."""
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(jax.sharding.AxisType.Auto, jax.sharding.AxisType.Auto))
