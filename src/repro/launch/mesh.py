"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required for the smoke tests, which must see
exactly one device.

``make_mesh_compat`` papers over the ``axis_types`` API gap: jax >= 0.5 wants
explicit ``jax.sharding.AxisType.Auto`` axis types, jax 0.4.x (the pinned
version) predates both the kwarg and the enum.  Every mesh in this repo (and
in the subprocess-driven distribution tests) is Auto-typed, which is exactly
the older versions' only behaviour, so falling back to a plain ``make_mesh``
is semantics-preserving.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh", "make_mesh_compat"]


def make_mesh_compat(shape, axes):
    """``jax.make_mesh`` with Auto axis types where supported, without
    where not (jax 0.4.x lacks the kwarg and ``jax.sharding.AxisType``)."""
    try:
        return jax.make_mesh(
            shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    except (AttributeError, TypeError):
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single-pod (256 chips) or 2x16x16 two-pod (512 chips) mesh.

    Axes: "pod" (outer data parallel, crosses DCN), "data" (data parallel /
    ZeRO shard axis), "model" (tensor/expert parallel, stays inside an ICI
    torus dimension).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh_compat(shape, axes)


def make_local_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many (fake or real) local devices exist."""
    return make_mesh_compat((data, model), ("data", "model"))
