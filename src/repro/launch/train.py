"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m \
        --steps 200 --batch 8 --seq 256 [--mesh-data D --mesh-model M]

On a multi-chip host this builds a (data, model) mesh, installs the
architecture's sharding rules, and runs the fault-tolerant train loop with
pjit'd steps; on this single-CPU container it degrades to one device (the
same code path the smoke tests exercise).  Checkpoints land in --ckpt-dir
and are elastic: restart with a different mesh and the restore re-shards.
"""
from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from ..configs import get_config, list_configs
from ..data.curation import CuratedSelector, MetaQuery
from ..data.pipeline import ShardedLoader, make_corpus
from ..distributed.partitioning import use_rules
from ..distributed.sharding import rules_for_arch
from ..models import build_model
from ..optim import AdamWConfig
from ..runtime.train_loop import TrainLoopConfig, train
from .mesh import make_local_mesh


def reduced(cfg, layers, d_model):
    return dataclasses.replace(
        cfg, n_layers=layers, d_model=d_model,
        d_ff=max(d_model * 3, 128),
        n_heads=min(cfg.n_heads, 8) if cfg.n_heads else 0,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        head_dim=(d_model // 8) if cfg.head_dim else None,
        vocab_size=min(cfg.vocab_size, 8192),
        enc_layers=min(cfg.enc_layers, layers) if cfg.enc_layers else 0,
        n_patches=min(cfg.n_patches, 16) if cfg.n_patches else 0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), default="mamba2-130m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--mesh-data", type=int, default=1)
    ap.add_argument("--mesh-model", type=int, default=1)
    ap.add_argument("--reduced-layers", type=int, default=None,
                    help="shrink the config for CPU runs (None = full)")
    ap.add_argument("--reduced-width", type=int, default=256)
    ap.add_argument("--curate", action="store_true",
                    help="select training docs through the COAX index")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced_layers:
        cfg = reduced(cfg, args.reduced_layers, args.reduced_width)
    model = build_model(cfg)
    print(f"[launch] {cfg.name}: {model.param_count()/1e6:.1f}M params")

    corpus = make_corpus(50_000, vocab_size=min(cfg.padded_vocab, 32_000))
    doc_ids = None
    if args.curate:
        sel = CuratedSelector(corpus)
        doc_ids = sel.select(MetaQuery(token_len=(args.seq // 2, 32768),
                                       quality=(0.5, 1.1)))
        print(f"[launch] COAX curation: {doc_ids.size:,} docs")
    loader = ShardedLoader(corpus, batch_size=args.batch, seq_len=args.seq,
                           doc_ids=doc_ids,
                           process_index=jax.process_index(),
                           process_count=jax.process_count())

    use_mesh = args.mesh_data * args.mesh_model > 1
    loop_cfg = TrainLoopConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                               ckpt_every=args.ckpt_every, log_every=10)
    if use_mesh:
        mesh = make_local_mesh(args.mesh_data, args.mesh_model)
        rules = rules_for_arch(cfg, mesh)
        with jax.set_mesh(mesh), use_rules(rules):
            out = train(model, iter(loader), AdamWConfig(lr=args.lr), loop_cfg)
    else:
        out = train(model, iter(loader), AdamWConfig(lr=args.lr), loop_cfg)
    loader.close()
    print(f"[launch] finished step {out['final_step']}, "
          f"loss {out['history'][-1]['loss']:.4f}, restarts {out['restarts']}")


if __name__ == "__main__":
    main()
