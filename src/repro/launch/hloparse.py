"""Parse collective-communication volume out of compiled HLO text.

``cost_analysis`` has no collective-bytes entry, so the roofline's collective
term is derived here: sum the RESULT sizes of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute in the (per-device SPMD)
module.  Async pairs (``*-start``/``*-done``) are counted once via the start
op; ``*-done`` and fusion-internal duplicates are skipped.
"""
from __future__ import annotations

import re
from typing import Dict, Tuple

__all__ = ["collective_bytes", "DTYPE_BYTES"]

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

# one shape: f32[128,256]{1,0}
_SHAPE = re.compile(r"(\w+)\[([\d,]*)\]")
# op line:  %name = <type-or-tuple> <collective>(...)
_LINE = re.compile(
    r"=\s*(\([^=]*?\)|[\w\[\]{},]+)\s+(" + "|".join(_COLLECTIVES) + r")(-start)?\(")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(type_str):
        if dt not in DTYPE_BYTES:
            continue  # token types etc.
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Tuple[int, Dict[str, int]]:
    """Returns (total_bytes, per_op_type_bytes) for one SPMD module."""
    per: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line or "-done." in line:
            continue
        m = _LINE.search(line)
        if not m:
            continue
        type_str, op, _ = m.groups()
        b = _shape_bytes(type_str)
        per[op] = per.get(op, 0) + b
    return sum(per.values()), per
