"""Roofline terms for the TPU v5e target (structural, from compiled HLO).

    compute    = HLO_FLOPs_per_device / peak_FLOPs
    memory     = HLO_bytes_per_device / HBM_bw
    collective = collective_bytes_per_device / ICI_bw

``cost_analysis``/HLO text describe the per-device SPMD program, so no /chips
normalisation is needed beyond what XLA already applied.

MODEL_FLOPS uses the standard 6·N·D (train) / 2·N·D (inference) accounting
with N = active non-embedding parameters and D = tokens processed per step;
MODEL_FLOPS / HLO_FLOPs exposes remat recompute and redundant work.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from ..configs.base import ModelConfig, ShapeConfig

__all__ = ["TPU_V5E", "roofline", "model_flops"]

TPU_V5E = {
    "peak_flops": 197e12,   # bf16 FLOP/s per chip
    "hbm_bw": 819e9,        # bytes/s per chip
    "ici_bw": 50e9,         # bytes/s per link
}


def model_flops(cfg: ModelConfig, shape: ShapeConfig, active_params: int,
                embed_params: int) -> float:
    """Useful model FLOPs per step (global, all chips)."""
    n = max(active_params - embed_params, 1)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    # decode: one token per sequence per step
    return 2.0 * n * shape.global_batch


def roofline(flops_per_device: float, bytes_per_device: float,
             coll_bytes_per_device: float, hw: Dict[str, float] = TPU_V5E
             ) -> Dict[str, float]:
    compute = flops_per_device / hw["peak_flops"]
    memory = bytes_per_device / hw["hbm_bw"]
    collective = coll_bytes_per_device / hw["ici_bw"]
    dominant = max(("compute", compute), ("memory", memory),
                   ("collective", collective), key=lambda kv: kv[1])
    step_time = max(compute, memory, collective)
    return {
        "compute_s": compute,
        "memory_s": memory,
        "collective_s": collective,
        "dominant": dominant[0],
        "step_time_bound_s": step_time,
    }
