"""Production serving launcher: COAX-routed wave-batched server.

    PYTHONPATH=src python -m repro.launch.serve --arch h2o-danube-3-4b \
        --requests 64 [--reduced-layers 4]

Loads (or initialises) weights, spins up the Server with a CoaxRouter and
drains a synthetic request stream, reporting wave composition and token
throughput.  ``--ckpt-dir`` restores trained weights from the train
launcher's checkpoints (elastic: any mesh).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs import get_config, list_configs
from ..models import build_model
from ..optim import adamw_init
from ..runtime.checkpoint import Checkpointer, latest_step
from ..runtime.serve_loop import ServeConfig, Server
from .train import reduced


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), default="h2o-danube-3-4b")
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced-layers", type=int, default=4)
    ap.add_argument("--reduced-width", type=int, default=256)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced_layers:
        cfg = reduced(cfg, args.reduced_layers, args.reduced_width)
    model = build_model(cfg)
    params, _ = model.init(jax.random.key(args.seed))
    if args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        ck = Checkpointer(args.ckpt_dir)
        state = ck.restore({"params": params, "opt": adamw_init(params)})
        params = state["params"]
        print(f"[serve] restored step {ck.manifest()['step']} from {args.ckpt_dir}")

    srv = Server(model, params, ServeConfig(
        batch_size=args.batch_size, max_new_tokens=args.max_new,
        cache_len=args.cache_len, eos_token=0))
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        plen = int(rng.choice([16, 32, 64, 128]))
        srv.submit(rng.integers(1, cfg.padded_vocab - 1, plen).astype(np.int32),
                   max_new_tokens=int(rng.integers(4, args.max_new)),
                   priority=float(rng.random()))
    print(f"[serve] {args.requests} requests queued; "
          f"router: {srv.router.stats()}")
    t0 = time.time()
    results = srv.run_until_drained(max_waves=200)
    dt = time.time() - t0
    toks = sum(r.tokens.size for r in results)
    print(f"[serve] {len(results)} responses, {srv.waves} waves, "
          f"{toks} tokens in {dt:.1f}s ({toks/max(dt,1e-9):.0f} tok/s)")


if __name__ == "__main__":
    main()
