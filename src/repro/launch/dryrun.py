import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell with ShapeDtypeStruct inputs — no allocation — and record the memory
analysis, cost analysis and collective-communication volume that feed the
roofline table (EXPERIMENTS.md §Dry-run / §Roofline).

The XLA_FLAGS line above MUST run before any jax import: jax locks the device
count at first backend initialisation.

Usage:
  python -m repro.launch.dryrun --arch gemma2-27b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all            # every cell, both meshes
  python -m repro.launch.dryrun --all --jobs 4   # parallel subprocesses
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..configs import SHAPES, get_config, list_configs
from ..configs.base import ModelConfig, ShapeConfig
from ..distributed.partitioning import use_rules
from ..distributed.sharding import (
    fsdp_param_specs,
    input_pspecs,
    rules_for_arch,
    zero1_state_specs,
)
from ..models.common import axes_to_pspecs
from ..models.model import build_model
from ..optim import AdamWConfig, adamw_init
from ..runtime.steps import make_prefill_step, make_serve_step, make_train_step
from .hloparse import collective_bytes
from .mesh import make_production_mesh
from .roofline import TPU_V5E, model_flops, roofline

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def skip_reason(cfg: ModelConfig, shape: ShapeConfig):
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return ("full quadratic attention at 524k context exceeds any serving "
                "envelope; run only for SSM/hybrid/SWA archs per the brief")
    return None


def _enc_len(cfg: ModelConfig, shape: ShapeConfig):
    """Encoder length for enc-dec decode cells (frames seen at prefill)."""
    return 4096 if cfg.family == "encdec" else None


def _lower_cell(cfg, shape, mesh, rules, *, fsdp: bool, microbatches: int = 1):
    """Lower + compile one cell; returns (compiled, params_sds)."""
    model = build_model(cfg)
    holder = {}

    def _init_params(k):
        params, ax = model.init(k)
        holder["axes"] = ax
        return params

    params_sds = jax.eval_shape(_init_params, jax.random.key(0))
    if shape.kind != "train":
        # serving deploys bf16 weights (f32 masters are a training artifact)
        params_sds = jax.tree.map(
            lambda a: jax.ShapeDtypeStruct(a.shape, jnp.bfloat16)
            if a.dtype == jnp.float32 else a, params_sds)
    param_specs = axes_to_pspecs(holder["axes"], rules)
    if fsdp and shape.kind == "train":
        param_specs = fsdp_param_specs(param_specs, params_sds, mesh)
    batch_sds = model.input_specs(shape)
    batch_specs = input_pspecs(model.input_logical_axes(shape), rules)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(adamw_init, params_sds)
        opt_specs = zero1_state_specs(param_specs, params_sds, mesh)
        step_fn = make_train_step(model, AdamWConfig(), microbatches=microbatches)
        jitted = jax.jit(
            step_fn,
            in_shardings=(param_specs, opt_specs, batch_specs),
            out_shardings=(param_specs, opt_specs, None),
            donate_argnums=(0, 1),
        )
        lowered = jitted.lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        step_fn = make_prefill_step(model, cache_len=shape.seq_len)
        jitted = jax.jit(step_fn, in_shardings=(param_specs, batch_specs))
        lowered = jitted.lower(params_sds, batch_sds)
    else:
        enc_len = _enc_len(cfg, shape)
        cache_sds = model.init_cache(shape.global_batch, shape.seq_len,
                                     enc_len=enc_len, abstract=True)
        cache_ax = model.cache_logical_axes(shape.global_batch, shape.seq_len,
                                            enc_len=enc_len)
        cache_specs = input_pspecs(cache_ax, rules)
        tok_sds = model.input_specs(shape)["tokens"]
        tok_spec = input_pspecs(model.input_logical_axes(shape), rules)["tokens"]
        step_fn = make_serve_step(model)
        jitted = jax.jit(
            step_fn,
            in_shardings=(param_specs, cache_specs, tok_spec, P()),
            out_shardings=(None, cache_specs),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_sds, cache_sds, tok_sds,
                               jax.ShapeDtypeStruct((), jnp.int32))
    return lowered.compile(), params_sds


def _probe_depths(cfg):
    """Reduced-depth config pair for linear cost extrapolation."""
    if cfg.family == "hybrid":
        period = cfg.attn_every * cfg.n_shared_attn
        l1, l2 = period, 2 * period
        return (dataclasses.replace(cfg, n_layers=l1),
                dataclasses.replace(cfg, n_layers=l2), l1, l2)
    if cfg.family == "encdec":
        return (dataclasses.replace(cfg, n_layers=1, enc_layers=1),
                dataclasses.replace(cfg, n_layers=2, enc_layers=2), 1, 2)
    period = max(len(cfg.layer_pattern), 1)
    return (dataclasses.replace(cfg, n_layers=period),
            dataclasses.replace(cfg, n_layers=2 * period), period, 2 * period)


def _extract_cost(compiled):
    ca = compiled.cost_analysis() or {}
    total, per = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "transcendentals": float(ca.get("transcendentals", 0.0)),
        "coll_total": float(total),
        "coll_per_op": per,
    }


def probe_costs(cfg, shape, mesh, rules, *, fsdp: bool,
                microbatches: int = 1) -> dict:
    """Trip-count-correct cost terms.

    XLA's cost analysis counts while-loop bodies ONCE, so the production
    (scan-over-layers) compile under-reports flops/bytes/collectives by ~L.
    This probe recompiles two reduced-depth configs with every scan fully
    unrolled (models/common.set_probe_unroll) and extrapolates linearly in
    depth — exact for homogeneous stacks, period-aware for alternating ones.
    """
    from ..models.common import set_probe_unroll
    cfg1, cfg2, l1, l2 = _probe_depths(cfg)
    # cost is linear in tokens, so the probe always uses microbatches=1:
    # identical totals, 1/M the unrolled-HLO compile time.
    set_probe_unroll(True)
    try:
        c1, _ = _lower_cell(cfg1, shape, mesh, rules, fsdp=fsdp, microbatches=1)
        m1 = _extract_cost(c1)
        c2, _ = _lower_cell(cfg2, shape, mesh, rules, fsdp=fsdp, microbatches=1)
        m2 = _extract_cost(c2)
    finally:
        set_probe_unroll(False)
    L = cfg.n_layers
    scale = (L - l1) / (l2 - l1)

    def ext(a, b):
        return a + (b - a) * scale

    ops = set(m1["coll_per_op"]) | set(m2["coll_per_op"])
    per_op = {op: max(ext(m1["coll_per_op"].get(op, 0), m2["coll_per_op"].get(op, 0)), 0.0)
              for op in ops}
    return {
        "method": f"unrolled depth-extrapolation (L1={l1}, L2={l2}, L={L})",
        "flops_per_device": max(ext(m1["flops"], m2["flops"]), 0.0),
        "bytes_per_device": max(ext(m1["bytes"], m2["bytes"]), 0.0),
        "transcendentals": max(ext(m1["transcendentals"], m2["transcendentals"]), 0.0),
        "collective_bytes_per_device": max(ext(m1["coll_total"], m2["coll_total"]), 0.0),
        "collective_per_op": per_op,
        "probe_points": {"l1": m1, "l2": m2},
    }


def run_cell(arch: str, shape_name: str, mesh_kind: str, *,
             fsdp: bool = True, sequence_parallel: bool = None,
             expert_parallel: bool = True, remat: str = None,
             attn_chunk: int = 1024, tag: str = "baseline",
             probe: bool = True, microbatches: int = None,
             split_cache: bool = False, ssd_chunk: int = None,
             capacity_factor: float = None,
             out_dir: Path = OUT_DIR) -> dict:
    cfg = get_config(arch)
    if remat is not None:
        cfg = dataclasses.replace(cfg, remat=remat)
    if split_cache:
        cfg = dataclasses.replace(cfg, split_local_cache=True)
    if ssd_chunk is not None:
        cfg = dataclasses.replace(cfg, ssd_chunk=ssd_chunk)
    if capacity_factor is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity_factor)
    if attn_chunk != 1024:
        cfg = dataclasses.replace(cfg, attn_chunk=attn_chunk)
    shape = SHAPES[shape_name]
    if sequence_parallel is None:
        # default: Megatron-style SP for train cells (remat-saved residual
        # carries shrink by the TP degree; v0 dry-run overflowed HBM without)
        sequence_parallel = shape.kind == "train"
    if microbatches is None:
        # default: 4-way gradient accumulation for train cells (live
        # activations scale with the microbatch, not the global batch)
        microbatches = 4 if shape.kind == "train" else 1
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag,
        "fsdp": fsdp, "sequence_parallel": sequence_parallel,
        "expert_parallel": expert_parallel, "remat": cfg.remat,
        "attn_chunk": attn_chunk, "microbatches": microbatches,
    }
    reason = skip_reason(cfg, shape)
    if reason:
        result.update(status="skipped", reason=reason)
        return result

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    rules = rules_for_arch(cfg, mesh, shape,
                           sequence_parallel=sequence_parallel,
                           expert_parallel=expert_parallel)
    model = build_model(cfg)
    t0 = time.time()

    with jax.set_mesh(mesh), use_rules(rules):
        compiled, params_sds = _lower_cell(cfg, shape, mesh, rules, fsdp=fsdp,
                                           microbatches=microbatches)
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        scanbody = _extract_cost(compiled)

        cost = None
        if probe:
            cost = probe_costs(cfg, shape, mesh, rules, fsdp=fsdp,
                               microbatches=microbatches)

    if cost is not None:
        flops_dev = cost["flops_per_device"]
        bytes_dev = cost["bytes_per_device"]
        coll_total = cost["collective_bytes_per_device"]
        coll_per_op = cost["collective_per_op"]
    else:  # fall back to the (trip-count-naive) scan-body numbers
        flops_dev = scanbody["flops"]
        bytes_dev = scanbody["bytes"]
        coll_total = scanbody["coll_total"]
        coll_per_op = scanbody["coll_per_op"]

    active = model.active_param_count(params_sds)
    total = model.param_count(params_sds)
    embed_p = cfg.padded_vocab * cfg.d_model * (1 if cfg.tie_embeddings else 2)
    mf = model_flops(cfg, shape, active, embed_p)
    terms = roofline(flops_dev, bytes_dev, coll_total)

    result.update(
        status="ok",
        n_chips=n_chips,
        compile_s=round(t_compile, 1),
        params_total=total,
        params_active=active,
        memory={
            "argument_bytes": ma.argument_size_in_bytes,
            "output_bytes": ma.output_size_in_bytes,
            "temp_bytes": ma.temp_size_in_bytes,
            "alias_bytes": ma.alias_size_in_bytes,
            "peak_bytes_per_device": ma.argument_size_in_bytes
                + ma.output_size_in_bytes + ma.temp_size_in_bytes
                - ma.alias_size_in_bytes,
        },
        cost={
            "flops_per_device": flops_dev,
            "bytes_per_device": bytes_dev,
            "transcendentals": (cost or scanbody).get("transcendentals", 0.0),
            "method": (cost or {}).get("method", "scan-body (trip-count naive)"),
        },
        cost_scanbody=scanbody,
        collectives={"total_bytes_per_device": coll_total, "per_op": coll_per_op},
        model_flops_global=mf,
        model_flops_per_device=mf / n_chips,
        useful_flops_ratio=(mf / n_chips) / flops_dev if flops_dev else None,
        roofline=terms,
        roofline_mfu_bound=((mf / n_chips) / TPU_V5E["peak_flops"])
            / terms["step_time_bound_s"] if terms["step_time_bound_s"] else None,
        rules={k: list(v) if isinstance(v, tuple) else v for k, v in rules.items()},
    )
    return result


def cell_filename(arch, shape, mesh, tag):
    return f"{arch}__{shape}__{mesh}__{tag}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", type=str, default="single", choices=["single", "multi"])
    ap.add_argument("--all", action="store_true", help="run every cell, both meshes")
    ap.add_argument("--jobs", type=int, default=2)
    ap.add_argument("--tag", type=str, default="baseline")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--sp", type=int, default=-1,
                    help="sequence parallelism: -1 auto (train on), 0 off, 1 on")
    ap.add_argument("--ep", type=int, default=1, help="expert parallelism")
    ap.add_argument("--remat", type=str, default=None, choices=[None, "none", "full"])
    ap.add_argument("--attn-chunk", type=int, default=1024)
    ap.add_argument("--probe", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--split-cache", type=int, default=0)
    ap.add_argument("--ssd-chunk", type=int, default=None)
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--out", type=str, default=str(OUT_DIR))
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    if args.all:
        cells = [(a, s, m) for a in list_configs() for s in SHAPES
                 for m in ("single", "multi")]
        procs, failures = [], []
        for a, s, m in cells:
            fn = out_dir / cell_filename(a, s, m, args.tag)
            if fn.exists():
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", a, "--shape", s, "--mesh", m, "--tag", args.tag,
                   "--fsdp", str(args.fsdp), "--sp", str(args.sp),
                   "--ep", str(args.ep), "--probe", str(args.probe),
                   "--out", str(out_dir)]
            if args.remat:
                cmd += ["--remat", args.remat]
            procs.append((a, s, m, subprocess.Popen(cmd)))
            while len([p for *_, p in procs if p.poll() is None]) >= args.jobs:
                time.sleep(2)
        for a, s, m, p in procs:
            if p.wait() != 0:
                failures.append((a, s, m))
        print(f"dry-run complete; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    try:
        res = run_cell(args.arch, args.shape, args.mesh, fsdp=bool(args.fsdp),
                       sequence_parallel=(bool(args.sp) if args.sp >= 0 else None),
                       expert_parallel=bool(args.ep), remat=args.remat,
                       attn_chunk=args.attn_chunk, tag=args.tag,
                       probe=bool(args.probe), microbatches=args.microbatches,
                       split_cache=bool(args.split_cache),
                       ssd_chunk=args.ssd_chunk,
                       capacity_factor=args.capacity_factor,
                       out_dir=out_dir)
    except Exception:
        res = {"arch": args.arch, "shape": args.shape, "mesh": args.mesh,
               "tag": args.tag, "status": "error",
               "error": traceback.format_exc()}
    fn = out_dir / cell_filename(args.arch, args.shape, args.mesh, args.tag)
    fn.write_text(json.dumps(res, indent=2, default=str))
    if res["status"] == "ok":
        r = res["roofline"]
        print(f"{args.arch} {args.shape} {args.mesh}: OK compile={res['compile_s']}s "
              f"mem={res['memory']['peak_bytes_per_device']/2**30:.2f}GiB "
              f"terms(c/m/coll)={r['compute_s']:.4f}/{r['memory_s']:.4f}/"
              f"{r['collective_s']:.4f}s dominant={r['dominant']}")
    else:
        print(f"{args.arch} {args.shape} {args.mesh}: {res['status'].upper()}")
        if res["status"] == "error":
            print(res["error"][-2000:])
            sys.exit(1)


if __name__ == "__main__":
    main()
