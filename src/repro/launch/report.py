"""Generate the EXPERIMENTS.md §Dry-run/§Roofline tables from the dry-run
JSON cells.

    PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import json
from glob import glob
from pathlib import Path

HBM = 16 * 2**30


def load(dir_: Path, tag: str = "baseline"):
    cells = {}
    for f in sorted(glob(str(dir_ / f"*__{tag}.json"))):
        d = json.loads(Path(f).read_text())
        cells[(d["arch"], d["shape"], d["mesh"])] = d
    return cells


def fmt_row(d):
    if d["status"] == "skipped":
        return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | — | — | — | — | — | "
                f"skipped |")
    r = d["roofline"]
    mem = d["memory"]["peak_bytes_per_device"] / 2**30
    mfu = d.get("roofline_mfu_bound") or 0
    fit = "yes" if d["memory"]["peak_bytes_per_device"] <= HBM else "**NO**"
    return (f"| {d['arch']} | {d['shape']} | {d['mesh']} | {mem:.1f} | "
            f"{r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | {r['dominant']} | "
            f"{mfu:.3f} | {fit} |")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=str(
        Path(__file__).resolve().parents[3] / "experiments" / "dryrun"))
    ap.add_argument("--tag", default="baseline")
    ap.add_argument("--mesh", default=None, choices=[None, "single", "multi"])
    args = ap.parse_args()
    cells = load(Path(args.dir), args.tag)

    print("| arch | shape | mesh | GiB/dev | compute ms | memory ms | "
          "collective ms | dominant | MFU-bound | fits HBM |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for key in sorted(cells):
        d = cells[key]
        if args.mesh and d["mesh"] != args.mesh:
            continue
        print(fmt_row(d))

    ok = [d for d in cells.values() if d["status"] == "ok"]
    sk = [d for d in cells.values() if d["status"] == "skipped"]
    fit = [d for d in ok if d["memory"]["peak_bytes_per_device"] <= HBM]
    print(f"\ncells={len(cells)} ok={len(ok)} skipped={len(sk)} "
          f"fit_hbm={len(fit)}/{len(ok)}")
    if ok:
        worst = min(ok, key=lambda d: d.get("roofline_mfu_bound") or 0)
        coll = max(ok, key=lambda d: d["roofline"]["collective_s"]
                   / max(d["roofline"]["step_time_bound_s"], 1e-12))
        print(f"worst MFU-bound: {worst['arch']}/{worst['shape']}/{worst['mesh']} "
              f"= {worst.get('roofline_mfu_bound') or 0:.4f}")
        print(f"most collective-bound: {coll['arch']}/{coll['shape']}/{coll['mesh']}")


if __name__ == "__main__":
    main()
