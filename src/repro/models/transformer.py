"""Decoder-only transformer stacks (dense / MoE / VLM / SSM / hybrid).

Layers are stacked and driven by ``lax.scan`` so HLO size is O(1) in depth
(critical for 62-layer configs at dry-run compile time); per-layer
heterogeneity (gemma2's local/global alternation) is threaded through the
scan as a traced flag with the window limit selected by ``jnp.where`` — the
parameter tree stays homogeneous.

Every init function returns ``(params, axes)`` parallel trees (see
models/common.py); caches follow the same convention via ``*_cache_spec``.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.partitioning import shard
from .attention import (
    cross_attn_forward,
    cross_kv,
    gqa_decode,
    gqa_forward,
    gqa_init,
    mla_decode,
    mla_forward,
    mla_init,
)
from .common import (
    DTYPE,
    embed,
    embedding_init,
    mlp_apply,
    mlp_init,
    rmsnorm,
    rmsnorm_init,
    scan_unroll,
    softmax_cross_entropy,
    stacked,
    unembed,
)
from .moe import moe_apply, moe_init
from .ssm import CONV_K, mamba2_decode, mamba2_forward, mamba2_init

BIG_WINDOW = 1 << 30


# --------------------------------------------------------------------------- #
# per-layer init
# --------------------------------------------------------------------------- #

def _attn_layer_init(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params: Dict[str, Any] = {}
    axes: Dict[str, Any] = {}
    params["ln1"], axes["ln1"] = rmsnorm_init(cfg.d_model)
    if cfg.mla:
        params["attn"], axes["attn"] = mla_init(
            k1, cfg.d_model, cfg.n_heads, q_lora=cfg.q_lora, kv_lora=cfg.kv_lora,
            nope_dim=cfg.nope_dim, rope_dim=cfg.rope_dim, v_dim=cfg.v_dim)
    else:
        params["attn"], axes["attn"] = gqa_init(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
    params["ln2"], axes["ln2"] = rmsnorm_init(cfg.d_model)
    if cfg.n_experts:
        params["moe"], axes["moe"] = moe_init(k2, cfg.d_model, cfg.d_ff, cfg.n_experts)
    else:
        params["mlp"], axes["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, gated=True)
    if cfg.sandwich_norm:
        params["ln1_post"], axes["ln1_post"] = rmsnorm_init(cfg.d_model)
        params["ln2_post"], axes["ln2_post"] = rmsnorm_init(cfg.d_model)
    return params, axes


def _mamba_layer_init(key, cfg: ModelConfig):
    params, axes = {}, {}
    params["ln"], axes["ln"] = rmsnorm_init(cfg.d_model)
    params["mamba"], axes["mamba"] = mamba2_init(
        key, cfg.d_model, expand=cfg.ssm_expand, head_p=cfg.ssm_head_p,
        state=cfg.ssm_state)
    return params, axes


# --------------------------------------------------------------------------- #
# per-layer forward (train / prefill)
# --------------------------------------------------------------------------- #

def _attn_layer_fwd(p, cfg: ModelConfig, x, window_limit, *, positions=None,
                    positions3=None, causal=True, chunk=1024, collect_kv=False):
    h = rmsnorm(x, p["ln1"], cfg.rms_eps)
    if cfg.mla:
        attn_out, kv = mla_forward(
            p["attn"], h, n_heads=cfg.n_heads, q_lora=cfg.q_lora,
            kv_lora=cfg.kv_lora, nope_dim=cfg.nope_dim, rope_dim=cfg.rope_dim,
            v_dim=cfg.v_dim, rope_theta=cfg.rope_theta, positions=positions,
            chunk=chunk)
    else:
        attn_out, kv = gqa_forward(
            p["attn"], h, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, positions=positions,
            mrope_sections=cfg.mrope_sections, positions3=positions3,
            causal=causal, window=window_limit, attn_softcap=cfg.attn_softcap,
            query_scale=cfg.query_scale, chunk=chunk)
    if cfg.sandwich_norm:
        attn_out = rmsnorm(attn_out, p["ln1_post"], cfg.rms_eps)
    x = x + attn_out

    h = rmsnorm(x, p["ln2"], cfg.rms_eps)
    aux = jnp.zeros((), jnp.float32)
    if cfg.n_experts:
        ff, aux = moe_apply(p["moe"], h, n_experts=cfg.n_experts, top_k=cfg.top_k,
                            capacity_factor=cfg.capacity_factor)
    else:
        ff = mlp_apply(p["mlp"], h, act=jax.nn.gelu if cfg.sandwich_norm else jax.nn.silu)
    if cfg.sandwich_norm:
        ff = rmsnorm(ff, p["ln2_post"], cfg.rms_eps)
    x = x + ff
    return (x, aux, kv) if collect_kv else (x, aux)


def _window_limits(cfg: ModelConfig, n_layers: int) -> jnp.ndarray:
    return jnp.array(
        [cfg.window if cfg.layer_kind(i) == "local" else BIG_WINDOW
         for i in range(n_layers)], jnp.int32)


# --------------------------------------------------------------------------- #
# decoder stacks
# --------------------------------------------------------------------------- #

def decoder_init(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers + 2)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(keys[0], cfg.padded_vocab, cfg.d_model)
    if cfg.family in ("ssm",):
        params["layers"], axes["layers"] = stacked(keys[1:-1], _mamba_layer_init, cfg)
    else:
        params["layers"], axes["layers"] = stacked(keys[1:-1], _attn_layer_init, cfg)
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    if not cfg.tie_embeddings:
        params["unembed"], axes["unembed"] = embedding_init(keys[-1], cfg.padded_vocab, cfg.d_model)
    return params, axes


def decoder_forward(params, cfg: ModelConfig, tokens=None, *, x_embed=None,
                    positions=None, positions3=None, chunk=1024,
                    logits_slice: Optional[str] = None):
    """Full-sequence forward.  Either ``tokens`` or pre-embedded ``x_embed``.

    logits_slice: None -> full logits; "last" -> last position only (prefill).
    Returns (logits, aux_loss).
    """
    if x_embed is None:
        x = embed(params["embed"], tokens, scale_by_dim=cfg.sandwich_norm)
    else:
        x = x_embed
    x = shard(x, "batch", "seq", "embed")

    if cfg.family == "ssm":
        def body(carry, xs):
            h, aux = carry
            (p_l,) = xs
            out = mamba2_forward(
                p_l["mamba"], rmsnorm(h, p_l["ln"], cfg.rms_eps),
                d_model=cfg.d_model, expand=cfg.ssm_expand,
                head_p=cfg.ssm_head_p, state=cfg.ssm_state, chunk=cfg.ssd_chunk)
            return (h + out, aux), None
        (x, aux), _ = jax.lax.scan(
            jax.checkpoint(body) if cfg.remat == "full" else body,
            (x, jnp.zeros((), jnp.float32)), (params["layers"],),
            unroll=scan_unroll())
    else:
        limits = _window_limits(cfg, cfg.n_layers)

        def body(carry, xs):
            h, aux = carry
            p_l, limit = xs
            h, aux_l = _attn_layer_fwd(
                p_l, cfg, h, limit, positions=positions, positions3=positions3,
                chunk=chunk)
            return (h, aux + aux_l), None
        (x, aux), _ = jax.lax.scan(
            jax.checkpoint(body) if cfg.remat == "full" else body,
            (x, jnp.zeros((), jnp.float32)), (params["layers"], limits),
            unroll=scan_unroll())

    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    if logits_slice == "hidden":
        return x, aux
    if logits_slice == "last":
        x = x[:, -1:, :]
    w_un = params["unembed"] if not cfg.tie_embeddings else params["embed"]
    logits = unembed(w_un, x, cap=cfg.final_softcap)
    return logits, aux


# --------------------------------------------------------------------------- #
# caches
# --------------------------------------------------------------------------- #

def cache_spec(cfg: ModelConfig, batch: int, cache_len: int):
    """(shapes, logical axes) for the decode cache of this architecture.

    SWA-everywhere architectures get a RING cache of ``min(window, cache_len)``
    slots — this is what keeps long_500k decode bounded (DESIGN.md §5).
    """
    shapes: Dict[str, Tuple[tuple, Any, Any]] = {}
    L = cfg.n_layers
    def conv_entries(nl):
        shapes["conv_x"] = ((nl, batch, CONV_K - 1, cfg.ssm_heads, cfg.ssm_head_p),
                            ("layers", "batch", None, None, "ssm_inner"), DTYPE)
        shapes["conv_b"] = ((nl, batch, CONV_K - 1, cfg.ssm_state),
                            ("layers", "batch", None, None), DTYPE)
        shapes["conv_c"] = ((nl, batch, CONV_K - 1, cfg.ssm_state),
                            ("layers", "batch", None, None), DTYPE)

    if cfg.family == "ssm":
        conv_entries(L)
        shapes["ssm"] = ((L, batch, cfg.ssm_heads, cfg.ssm_head_p, cfg.ssm_state),
                         ("layers", "batch", None, "ssm_inner", None), jnp.float32)
    elif cfg.family == "hybrid":
        n_app = L // cfg.attn_every
        conv_entries(L)
        shapes["ssm"] = ((L, batch, cfg.ssm_heads, cfg.ssm_head_p, cfg.ssm_state),
                         ("layers", "batch", None, "ssm_inner", None), jnp.float32)
        shapes["k"] = ((n_app, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                       ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
        shapes["v"] = ((n_app, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                       ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
    elif cfg.mla:
        shapes["ckv"] = ((L, batch, cache_len, cfg.kv_lora),
                         ("layers", "batch", "kv_len", None), DTYPE)
        shapes["kpe"] = ((L, batch, cache_len, cfg.rope_dim),
                         ("layers", "batch", "kv_len", None), DTYPE)
    elif cfg.paired_local_global:
        # local layers: ring caches of `window` slots; global layers: full.
        half = L // 2
        w = min(cfg.window, cache_len)
        shapes["k_loc"] = ((half, batch, w, cfg.n_kv_heads, cfg.hd),
                           ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
        shapes["v_loc"] = ((half, batch, w, cfg.n_kv_heads, cfg.hd),
                           ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
        shapes["k_glob"] = ((half, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                            ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
        shapes["v_glob"] = ((half, batch, cache_len, cfg.n_kv_heads, cfg.hd),
                            ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
    else:
        t = min(cfg.window, cache_len) if cfg.uses_swa_everywhere else cache_len
        shapes["k"] = ((L, batch, t, cfg.n_kv_heads, cfg.hd),
                       ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
        shapes["v"] = ((L, batch, t, cfg.n_kv_heads, cfg.hd),
                       ("layers", "batch", "kv_len", "kv_heads", None), DTYPE)
    return shapes


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, abstract: bool = False):
    spec = cache_spec(cfg, batch, cache_len)
    if abstract:
        return {k: jax.ShapeDtypeStruct(s, dt) for k, (s, _, dt) in spec.items()}
    return {k: jnp.zeros(s, dt) for k, (s, _, dt) in spec.items()}


def cache_axes(cfg: ModelConfig, batch: int, cache_len: int):
    spec = cache_spec(cfg, batch, cache_len)
    return {k: ax for k, (s, ax, dt) in spec.items()}


def _finish_block(p_l, cfg: ModelConfig, h, attn_out):
    """Residual + MLP half of a decoder block (decode path)."""
    if cfg.sandwich_norm:
        attn_out = rmsnorm(attn_out, p_l["ln1_post"], cfg.rms_eps)
    h = h + attn_out
    hn = rmsnorm(h, p_l["ln2"], cfg.rms_eps)
    if cfg.n_experts:
        ff, _ = moe_apply(p_l["moe"], hn, n_experts=cfg.n_experts,
                          top_k=cfg.top_k, capacity_factor=cfg.capacity_factor)
    else:
        ff = mlp_apply(p_l["mlp"], hn,
                       act=jax.nn.gelu if cfg.sandwich_norm else jax.nn.silu)
    if cfg.sandwich_norm:
        ff = rmsnorm(ff, p_l["ln2_post"], cfg.rms_eps)
    return h + ff


# --------------------------------------------------------------------------- #
# prefill (build decode caches from a prompt)
# --------------------------------------------------------------------------- #

def _fill_ring(k_stack, cache_len: int, window: int):
    """Place the last ``window`` positions of (L, B, S, ...) into ring slots."""
    s = k_stack.shape[2]
    w = min(window, cache_len)
    if s <= w:
        zeros = jnp.zeros(k_stack.shape[:2] + (w,) + k_stack.shape[3:], DTYPE)
        return jax.lax.dynamic_update_slice_in_dim(zeros, k_stack.astype(DTYPE), 0, axis=2)
    tail = k_stack[:, :, s - w:, ...]
    slots = (jnp.arange(s - w, s)) % w
    zeros = jnp.zeros(k_stack.shape[:2] + (w,) + k_stack.shape[3:], DTYPE)
    return zeros.at[:, :, slots, ...].set(tail.astype(DTYPE))


def _fill_flat(k_stack, cache_len: int):
    zeros = jnp.zeros(k_stack.shape[:2] + (cache_len,) + k_stack.shape[3:], DTYPE)
    return jax.lax.dynamic_update_slice_in_dim(zeros, k_stack.astype(DTYPE), 0, axis=2)


def decoder_prefill(params, cfg: ModelConfig, tokens=None, *, x_embed=None,
                    cache_len: int, positions=None, positions3=None, chunk=1024):
    """Prompt pass: returns (last-token logits, decode cache)."""
    if x_embed is None:
        x = embed(params["embed"], tokens, scale_by_dim=cfg.sandwich_norm)
    else:
        x = x_embed
    x = shard(x, "batch", "seq", "embed")

    if cfg.family == "ssm":
        def body(h, xs):
            (p_l,) = xs
            out, (conv_n, ssm_n) = mamba2_forward(
                p_l["mamba"], rmsnorm(h, p_l["ln"], cfg.rms_eps),
                d_model=cfg.d_model, expand=cfg.ssm_expand,
                head_p=cfg.ssm_head_p, state=cfg.ssm_state, chunk=cfg.ssd_chunk,
                return_state=True)
            return h + out, (conv_n["x"].astype(DTYPE), conv_n["b"].astype(DTYPE),
                             conv_n["c"].astype(DTYPE), ssm_n)
        x, (cx, cb, cc, ssm_s) = jax.lax.scan(body, x, (params["layers"],),
                                              unroll=scan_unroll())
        cache = {"conv_x": cx, "conv_b": cb, "conv_c": cc,
                 "ssm": ssm_s.astype(jnp.float32)}
    else:
        limits = _window_limits(cfg, cfg.n_layers)

        def body(carry, xs):
            h, aux = carry
            p_l, limit = xs
            h, aux_l, kv = _attn_layer_fwd(
                p_l, cfg, h, limit, positions=positions, positions3=positions3,
                chunk=chunk, collect_kv=True)
            return (h, aux + aux_l), kv
        (x, _), kvs = jax.lax.scan(
            body, (x, jnp.zeros((), jnp.float32)), (params["layers"], limits),
            unroll=scan_unroll())
        if cfg.mla:
            ckv, kpe = kvs
            cache = {"ckv": _fill_flat(ckv, cache_len), "kpe": _fill_flat(kpe, cache_len)}
        elif cfg.uses_swa_everywhere:
            k_s, v_s = kvs
            cache = {"k": _fill_ring(k_s, cache_len, cfg.window),
                     "v": _fill_ring(v_s, cache_len, cfg.window)}
        elif cfg.paired_local_global:
            k_s, v_s = kvs
            cache = {"k_loc": _fill_ring(k_s[0::2], cache_len, cfg.window),
                     "v_loc": _fill_ring(v_s[0::2], cache_len, cfg.window),
                     "k_glob": _fill_flat(k_s[1::2], cache_len),
                     "v_glob": _fill_flat(v_s[1::2], cache_len)}
        else:
            k_s, v_s = kvs
            cache = {"k": _fill_flat(k_s, cache_len), "v": _fill_flat(v_s, cache_len)}

    x = rmsnorm(x[:, -1:, :], params["final_norm"], cfg.rms_eps)
    w_un = params["unembed"] if not cfg.tie_embeddings else params["embed"]
    logits = unembed(w_un, x, cap=cfg.final_softcap)
    return logits, cache


def hybrid_prefill(params, cfg: ModelConfig, tokens, cache_len: int, *, chunk=1024):
    x = embed(params["embed"], tokens)
    n_seg = _hybrid_segments(cfg)

    def mamba_body(h, xs):
        (p_l,) = xs
        out, (conv_n, ssm_n) = mamba2_forward(
            p_l["mamba"], rmsnorm(h, p_l["ln"], cfg.rms_eps),
            d_model=cfg.d_model, expand=cfg.ssm_expand, head_p=cfg.ssm_head_p,
            state=cfg.ssm_state, chunk=cfg.ssd_chunk, return_state=True)
        return h + out, (conv_n["x"].astype(DTYPE), conv_n["b"].astype(DTYPE),
                         conv_n["c"].astype(DTYPE), ssm_n.astype(jnp.float32))

    cx_all, cb_all, cc_all, ssm_all, k_all, v_all = [], [], [], [], [], []
    for seg in range(n_seg):
        lo, hi = seg * cfg.attn_every, (seg + 1) * cfg.attn_every
        x, (cx_n, cb_n, cc_n, ssm_n) = jax.lax.scan(
            mamba_body, x, (_take_layers(params["layers"], lo, hi),),
            unroll=scan_unroll())
        cx_all.append(cx_n)
        cb_all.append(cb_n)
        cc_all.append(cc_n)
        ssm_all.append(ssm_n)
        sp = _take_one(params["shared"], seg % cfg.n_shared_attn)
        x, _, kv = _attn_layer_fwd(sp, cfg, x, BIG_WINDOW, chunk=chunk, collect_kv=True)
        k_all.append(kv[0][None])
        v_all.append(kv[1][None])

    cache = {
        "conv_x": jnp.concatenate(cx_all, axis=0),
        "conv_b": jnp.concatenate(cb_all, axis=0),
        "conv_c": jnp.concatenate(cc_all, axis=0),
        "ssm": jnp.concatenate(ssm_all, axis=0),
        "k": _fill_flat(jnp.concatenate(k_all, axis=0), cache_len),
        "v": _fill_flat(jnp.concatenate(v_all, axis=0), cache_len),
    }
    x = rmsnorm(x[:, -1:, :], params["final_norm"], cfg.rms_eps)
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    return logits, cache


# --------------------------------------------------------------------------- #
# decode step (one token)
# --------------------------------------------------------------------------- #

def decoder_decode_step(params, cfg: ModelConfig, cache, tokens, step,
                        rope_pos=None):
    """One-token decode: returns (logits (B, 1, V), new_cache).

    ``rope_pos`` overrides the RoPE angle position when it differs from the
    cache slot position (VLM text positions exclude the patch block)."""
    x = embed(params["embed"], tokens, scale_by_dim=cfg.sandwich_norm)

    if cfg.family == "ssm":
        def body(h, xs):
            p_l, cx_l, cb_l, cc_l, ssm_l = xs
            conv_l = {"x": cx_l, "b": cb_l, "c": cc_l}
            out, conv_n, ssm_n = mamba2_decode(
                p_l["mamba"], rmsnorm(h, p_l["ln"], cfg.rms_eps), conv_l, ssm_l,
                d_model=cfg.d_model, expand=cfg.ssm_expand,
                head_p=cfg.ssm_head_p, state=cfg.ssm_state)
            return h + out, (conv_n["x"].astype(DTYPE), conv_n["b"].astype(DTYPE),
                             conv_n["c"].astype(DTYPE), ssm_n)
        x, (cx, cb, cc, ssm_new) = jax.lax.scan(
            body, x, (params["layers"], cache["conv_x"], cache["conv_b"],
                      cache["conv_c"], cache["ssm"]), unroll=scan_unroll())
        new_cache = {"conv_x": cx, "conv_b": cb, "conv_c": cc, "ssm": ssm_new}
    else:
        limits = _window_limits(cfg, cfg.n_layers)
        ring = cfg.uses_swa_everywhere

        def body(h, xs):
            p_l, limit, *cache_l = xs
            hn = rmsnorm(h, p_l["ln1"], cfg.rms_eps)
            if cfg.mla:
                ckv_l, kpe_l = cache_l
                attn_out, ckv_n, kpe_n = mla_decode(
                    p_l["attn"], hn, ckv_l, kpe_l, step, n_heads=cfg.n_heads,
                    nope_dim=cfg.nope_dim, rope_dim=cfg.rope_dim, v_dim=cfg.v_dim,
                    rope_theta=cfg.rope_theta)
                new_c = (ckv_n, kpe_n)
            else:
                k_l, v_l = cache_l
                attn_out, k_n, v_n = gqa_decode(
                    p_l["attn"], hn, k_l, v_l, step, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, ring=ring,
                    window_limit=limit, rope_pos=rope_pos,
                    attn_softcap=cfg.attn_softcap, query_scale=cfg.query_scale)
                new_c = (k_n, v_n)
            if cfg.sandwich_norm:
                attn_out = rmsnorm(attn_out, p_l["ln1_post"], cfg.rms_eps)
            h = h + attn_out
            hn = rmsnorm(h, p_l["ln2"], cfg.rms_eps)
            if cfg.n_experts:
                ff, _ = moe_apply(p_l["moe"], hn, n_experts=cfg.n_experts,
                                  top_k=cfg.top_k,
                                  capacity_factor=cfg.capacity_factor)
            else:
                ff = mlp_apply(p_l["mlp"], hn,
                               act=jax.nn.gelu if cfg.sandwich_norm else jax.nn.silu)
            if cfg.sandwich_norm:
                ff = rmsnorm(ff, p_l["ln2_post"], cfg.rms_eps)
            return h + ff, new_c

        if cfg.mla:
            x, (ckv_new, kpe_new) = jax.lax.scan(
                body, x, (params["layers"], limits, cache["ckv"], cache["kpe"]),
                unroll=scan_unroll())
            new_cache = {"ckv": ckv_new, "kpe": kpe_new}
        elif cfg.paired_local_global:
            # scan over (local, global) layer PAIRS: the local layer's cache
            # is a ring of `window` slots, the global layer's is full length.
            half = cfg.n_layers // 2
            pair_params = jax.tree.map(
                lambda a: a.reshape(half, 2, *a.shape[1:]), params["layers"])

            def pair_body(h, xs):
                pp, kl, vl, kg, vg = xs
                p_loc = jax.tree.map(lambda a: a[0], pp)
                p_glob = jax.tree.map(lambda a: a[1], pp)
                hn = rmsnorm(h, p_loc["ln1"], cfg.rms_eps)
                a_out, kl_n, vl_n = gqa_decode(
                    p_loc["attn"], hn, kl, vl, step, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, ring=True, rope_pos=rope_pos,
                    attn_softcap=cfg.attn_softcap, query_scale=cfg.query_scale)
                h = _finish_block(p_loc, cfg, h, a_out)
                hn = rmsnorm(h, p_glob["ln1"], cfg.rms_eps)
                a_out, kg_n, vg_n = gqa_decode(
                    p_glob["attn"], hn, kg, vg, step, n_heads=cfg.n_heads,
                    n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
                    rope_theta=cfg.rope_theta, ring=False, rope_pos=rope_pos,
                    attn_softcap=cfg.attn_softcap, query_scale=cfg.query_scale)
                h = _finish_block(p_glob, cfg, h, a_out)
                return h, (kl_n, vl_n, kg_n, vg_n)

            x, (kl, vl, kg, vg) = jax.lax.scan(
                pair_body, x,
                (pair_params, cache["k_loc"], cache["v_loc"],
                 cache["k_glob"], cache["v_glob"]), unroll=scan_unroll())
            new_cache = {"k_loc": kl, "v_loc": vl, "k_glob": kg, "v_glob": vg}
        else:
            x, (k_new, v_new) = jax.lax.scan(
                body, x, (params["layers"], limits, cache["k"], cache["v"]),
                unroll=scan_unroll())
            new_cache = {"k": k_new, "v": v_new}

    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    w_un = params["unembed"] if not cfg.tie_embeddings else params["embed"]
    logits = unembed(w_un, x, cap=cfg.final_softcap)
    return logits, new_cache


# --------------------------------------------------------------------------- #
# hybrid (zamba2): mamba backbone + shared attention blocks
# --------------------------------------------------------------------------- #

def hybrid_init(key, cfg: ModelConfig):
    keys = jax.random.split(key, cfg.n_layers + cfg.n_shared_attn + 2)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(keys[0], cfg.padded_vocab, cfg.d_model)
    params["layers"], axes["layers"] = stacked(
        list(keys[1:1 + cfg.n_layers]), _mamba_layer_init, cfg)
    params["shared"], axes["shared"] = stacked(
        list(keys[1 + cfg.n_layers:1 + cfg.n_layers + cfg.n_shared_attn]),
        _attn_layer_init, cfg)
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, axes


def _hybrid_segments(cfg: ModelConfig):
    n_seg = cfg.n_layers // cfg.attn_every
    return n_seg


def _take_layers(tree, lo, hi):
    return jax.tree.map(lambda a: a[lo:hi], tree)


def _take_one(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def hybrid_forward(params, cfg: ModelConfig, tokens, *, chunk=1024,
                   logits_slice: Optional[str] = None):
    x = embed(params["embed"], tokens)
    n_seg = _hybrid_segments(cfg)

    def mamba_body(h, xs):
        (p_l,) = xs
        out = mamba2_forward(
            p_l["mamba"], rmsnorm(h, p_l["ln"], cfg.rms_eps),
            d_model=cfg.d_model, expand=cfg.ssm_expand, head_p=cfg.ssm_head_p,
            state=cfg.ssm_state)
        return h + out, None
    mb = jax.checkpoint(mamba_body) if cfg.remat == "full" else mamba_body

    for seg in range(n_seg):
        seg_params = _take_layers(params["layers"],
                                  seg * cfg.attn_every, (seg + 1) * cfg.attn_every)
        x, _ = jax.lax.scan(mb, x, (seg_params,), unroll=scan_unroll())
        sp = _take_one(params["shared"], seg % cfg.n_shared_attn)
        x, _ = _attn_layer_fwd(sp, cfg, x, BIG_WINDOW, chunk=chunk)
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    if logits_slice == "hidden":
        return x, jnp.zeros((), jnp.float32)
    if logits_slice == "last":
        x = x[:, -1:, :]
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    return logits, jnp.zeros((), jnp.float32)


def hybrid_decode_step(params, cfg: ModelConfig, cache, tokens, step):
    x = embed(params["embed"], tokens)
    n_seg = _hybrid_segments(cfg)

    def mamba_body(h, xs):
        p_l, cx_l, cb_l, cc_l, ssm_l = xs
        conv_l = {"x": cx_l, "b": cb_l, "c": cc_l}
        out, conv_n, ssm_n = mamba2_decode(
            p_l["mamba"], rmsnorm(h, p_l["ln"], cfg.rms_eps), conv_l, ssm_l,
            d_model=cfg.d_model, expand=cfg.ssm_expand, head_p=cfg.ssm_head_p,
            state=cfg.ssm_state)
        return h + out, (conv_n["x"].astype(DTYPE), conv_n["b"].astype(DTYPE),
                         conv_n["c"].astype(DTYPE), ssm_n)

    cx_out, cb_out, cc_out, ssm_out, k_out, v_out = [], [], [], [], [], []
    for seg in range(n_seg):
        lo, hi = seg * cfg.attn_every, (seg + 1) * cfg.attn_every
        x, (cx_n, cb_n, cc_n, ssm_n) = jax.lax.scan(
            mamba_body, x,
            (_take_layers(params["layers"], lo, hi),
             _take_layers(cache["conv_x"], lo, hi),
             _take_layers(cache["conv_b"], lo, hi),
             _take_layers(cache["conv_c"], lo, hi),
             _take_layers(cache["ssm"], lo, hi)), unroll=scan_unroll())
        cx_out.append(cx_n)
        cb_out.append(cb_n)
        cc_out.append(cc_n)
        ssm_out.append(ssm_n)

        sp = _take_one(params["shared"], seg % cfg.n_shared_attn)
        hn = rmsnorm(x, sp["ln1"], cfg.rms_eps)
        attn_out, k_n, v_n = gqa_decode(
            sp["attn"], hn, cache["k"][seg], cache["v"][seg], step,
            n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads, head_dim=cfg.hd,
            rope_theta=cfg.rope_theta, ring=False, window_limit=None)
        x = x + attn_out
        hn = rmsnorm(x, sp["ln2"], cfg.rms_eps)
        x = x + mlp_apply(sp["mlp"], hn)
        k_out.append(k_n)
        v_out.append(v_n)

    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    logits = unembed(params["embed"], x, cap=cfg.final_softcap)
    new_cache = {
        "conv_x": jnp.concatenate(cx_out, axis=0),
        "conv_b": jnp.concatenate(cb_out, axis=0),
        "conv_c": jnp.concatenate(cc_out, axis=0),
        "ssm": jnp.concatenate(ssm_out, axis=0),
        "k": jnp.stack(k_out),
        "v": jnp.stack(v_out),
    }
    return logits, new_cache
