"""Attention layers: GQA (+RoPE / M-RoPE, sliding-window, softcap), MLA
(DeepSeek/MiniCPM3-style multi-head latent attention), and cross-attention.

Training attention is CHUNKED (flash-style online softmax over KV blocks via
``lax.scan``) so the S x S score matrix is never materialised — O(S * chunk)
live memory instead of O(S^2).  This is the TPU-idiomatic formulation (splash
attention's structure) and keeps the dry-run memory analysis honest at 32k
sequence length.

Decode attention is a single fused pass over the KV cache.  Sliding-window
layers use a RING cache of exactly ``window`` slots, which is what makes the
``long_500k`` decode shape tractable for SWA architectures (DESIGN.md §5).
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.partitioning import shard
from .common import (DTYPE, apply_mrope, apply_rope, dense_init, scan_unroll,
                     softcap)

NEG = -1e30


# --------------------------------------------------------------------------- #
# chunked training attention
# --------------------------------------------------------------------------- #

def chunked_attention(
    q: jax.Array,                 # (B, Sq, H, hd)
    k: jax.Array,                 # (B, Skv, K, hd)
    v: jax.Array,                 # (B, Skv, K, vd)
    *,
    q_offset=0,
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    chunk: int = 1024,
    scale: Optional[float] = None,
) -> jax.Array:
    """Online-softmax attention over KV chunks; returns (B, Sq, H, vd)."""
    b, sq, h, hd = q.shape
    _, skv, kh, vd = v.shape
    rep = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    chunk = min(chunk, skv)
    assert skv % chunk == 0, (skv, chunk)
    n_chunks = skv // chunk

    qh = q.reshape(b, sq, kh, rep, hd)
    kc = k.reshape(b, n_chunks, chunk, kh, hd)
    vc = v.reshape(b, n_chunks, chunk, kh, vd)
    pos_q = q_offset + jnp.arange(sq)

    def step(carry, xs):
        m, l, acc = carry
        j, kj, vj = xs
        s = jnp.einsum("bqkrd,bckd->bqkrc", qh, kj.astype(qh.dtype),
                       preferred_element_type=jnp.float32) * scale
        if attn_softcap is not None:
            s = softcap(s, attn_softcap)
        pos_k = j * chunk + jnp.arange(chunk)
        mask = jnp.ones((sq, chunk), bool)
        if causal:
            mask &= pos_q[:, None] >= pos_k[None, :]
        if window is not None:
            mask &= (pos_q[:, None] - pos_k[None, :]) < window
        s = jnp.where(mask[None, :, None, None, :], s, NEG)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqkrc,bckd->bqkrd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kh, rep), NEG, jnp.float32)
    l0 = jnp.zeros((b, sq, kh, rep), jnp.float32)
    a0 = jnp.zeros((b, sq, kh, rep, vd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0),
        (jnp.arange(n_chunks), kc.swapaxes(0, 1), vc.swapaxes(0, 1)),
        unroll=scan_unroll(),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, vd).astype(q.dtype)


def decode_attention(
    q: jax.Array,                 # (B, 1, H, hd)
    k_cache: jax.Array,           # (B, T, K, hd)
    v_cache: jax.Array,           # (B, T, K, vd)
    valid_mask: jax.Array,        # (B, T) bool — which slots hold real keys
    *,
    attn_softcap: Optional[float] = None,
    scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention over a (possibly ring) KV cache."""
    b, _, h, hd = q.shape
    _, t, kh, vd = v_cache.shape
    rep = h // kh
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    # bf16 operands + f32 accumulation: never materialise an f32 copy of the
    # KV cache (it tripled decode memory in the v1 dry-run).
    qh = q.reshape(b, kh, rep, hd).astype(k_cache.dtype)
    s = jnp.einsum("bkrd,btkd->bkrt", qh, k_cache,
                   preferred_element_type=jnp.float32) * scale
    if attn_softcap is not None:
        s = softcap(s, attn_softcap)
    s = jnp.where(valid_mask[:, None, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkrt,btkd->bkrd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, vd).astype(q.dtype)


# --------------------------------------------------------------------------- #
# GQA attention block
# --------------------------------------------------------------------------- #

def gqa_init(key, d_model: int, n_heads: int, n_kv: int, head_dim: int):
    kq, kk, kv, ko = jax.random.split(key, 4)
    params, axes = {}, {}
    params["wq"], axes["wq"] = dense_init(kq, d_model, n_heads * head_dim, "embed", "heads")
    params["wk"], axes["wk"] = dense_init(kk, d_model, n_kv * head_dim, "embed", "kv_heads")
    params["wv"], axes["wv"] = dense_init(kv, d_model, n_kv * head_dim, "embed", "kv_heads")
    params["wo"], axes["wo"] = dense_init(ko, n_heads * head_dim, d_model, "heads", "embed")
    return params, axes


def _project_qkv(params, x, n_heads, n_kv, head_dim):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)).reshape(b, s, n_heads, head_dim)
    k = jnp.einsum("bsd,dh->bsh", x, params["wk"].astype(x.dtype)).reshape(b, s, n_kv, head_dim)
    v = jnp.einsum("bsd,dh->bsh", x, params["wv"].astype(x.dtype)).reshape(b, s, n_kv, head_dim)
    q = shard(q, "batch", "attn_seq", "heads", None)
    k = shard(k, "batch", None, "kv_heads", None)
    v = shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def gqa_forward(
    params, x, *,
    n_heads: int, n_kv: int, head_dim: int,
    rope_theta: float = 10_000.0,
    positions=None,               # (B, S) or None -> arange
    mrope_sections: Optional[Tuple[int, int, int]] = None,
    positions3=None,              # (B, S, 3) for M-RoPE
    causal: bool = True,
    window: Optional[int] = None,
    attn_softcap: Optional[float] = None,
    query_scale: Optional[float] = None,
    chunk: int = 1024,
):
    """Training/prefill attention; returns (out, (k, v)) so prefill can
    seed the decode cache."""
    b, s, _ = x.shape
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    if mrope_sections is not None:
        q = apply_mrope(q, positions3, mrope_sections, rope_theta)
        k = apply_mrope(k, positions3, mrope_sections, rope_theta)
    else:
        pos = positions if positions is not None else jnp.arange(s)[None, :]
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    out = chunked_attention(
        q, k, v, causal=causal, window=window,
        attn_softcap=attn_softcap, chunk=chunk, scale=query_scale,
    )
    out = shard(out, "batch", "attn_seq", "heads", None)
    proj = jnp.einsum("bsh,he->bse", out.reshape(b, s, n_heads * head_dim),
                      params["wo"].astype(x.dtype))
    return shard(proj, "batch", "seq", "embed"), (k, v)


def gqa_decode(
    params, x, cache_k, cache_v, step, *,
    n_heads: int, n_kv: int, head_dim: int,
    rope_theta: float = 10_000.0,
    ring: bool = False,
    window_limit=None,            # traced int or None: SWA mask in a flat cache
    attn_softcap: Optional[float] = None,
    query_scale: Optional[float] = None,
    rope_pos=None,                # RoPE position if it differs from ``step``
                                  # (e.g. VLM text positions exclude patches)
):
    """One-token decode.  ``step`` is the absolute position of the new token.

    Plain cache: slot = step, valid slots are [0, step] (optionally windowed
    by ``window_limit`` for local layers living in a full-length cache).
    Ring cache (SWA-everywhere): slot = step % T; every filled slot is valid
    because the ring length equals the attention window.
    Returns (out, new_k_cache, new_v_cache).
    """
    b, one, _ = x.shape
    t = cache_k.shape[1]
    q, k, v = _project_qkv(params, x, n_heads, n_kv, head_dim)
    pos = jnp.full((b, 1), step if rope_pos is None else rope_pos, jnp.int32)
    q = apply_rope(q, pos, rope_theta)
    k = apply_rope(k, pos, rope_theta)

    slot = (step % t) if ring else step  # ring: overwrite the oldest slot
    cache_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, axis=1)
    cache_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, axis=1)

    idx = jnp.arange(t)
    if ring:
        valid = idx[None, :] <= jnp.minimum(step, t - 1)
    else:
        valid = idx[None, :] <= step
        if window_limit is not None:
            valid &= idx[None, :] > (step - window_limit)
    valid = jnp.broadcast_to(valid, (b, t))

    out = decode_attention(q, cache_k, cache_v, valid,
                           attn_softcap=attn_softcap, scale=query_scale)
    proj = jnp.einsum("bsh,he->bse", out.reshape(b, 1, n_heads * head_dim),
                      params["wo"].astype(x.dtype))
    return proj, cache_k, cache_v


# --------------------------------------------------------------------------- #
# MLA — multi-head latent attention (MiniCPM3 / DeepSeek-V2 style)
# --------------------------------------------------------------------------- #

def mla_init(key, d_model: int, n_heads: int, *, q_lora: int, kv_lora: int,
             nope_dim: int, rope_dim: int, v_dim: int):
    ks = jax.random.split(key, 7)
    params, axes = {}, {}
    params["w_dq"], axes["w_dq"] = dense_init(ks[0], d_model, q_lora, "embed", "q_lora")
    params["w_uq"], axes["w_uq"] = dense_init(
        ks[1], q_lora, n_heads * (nope_dim + rope_dim), "q_lora", "heads")
    params["w_dkv"], axes["w_dkv"] = dense_init(ks[2], d_model, kv_lora, "embed", "kv_lora")
    params["w_kpe"], axes["w_kpe"] = dense_init(ks[3], d_model, rope_dim, "embed", None)
    params["w_uk"], axes["w_uk"] = dense_init(ks[4], kv_lora, n_heads * nope_dim, "kv_lora", "heads")
    params["w_uv"], axes["w_uv"] = dense_init(ks[5], kv_lora, n_heads * v_dim, "kv_lora", "heads")
    params["wo"], axes["wo"] = dense_init(ks[6], n_heads * v_dim, d_model, "heads", "embed")
    return params, axes


def _mla_qkv(params, x, n_heads, nope_dim, rope_dim, v_dim, rope_theta, positions):
    """Full (non-absorbed) q/k/v materialisation for train/prefill."""
    b, s, _ = x.shape
    cq = jnp.einsum("bsd,dq->bsq", x, params["w_dq"].astype(x.dtype))
    q = jnp.einsum("bsq,qh->bsh", cq, params["w_uq"].astype(x.dtype))
    q = q.reshape(b, s, n_heads, nope_dim + rope_dim)
    q_nope, q_pe = q[..., :nope_dim], q[..., nope_dim:]

    c_kv = jnp.einsum("bsd,dc->bsc", x, params["w_dkv"].astype(x.dtype))   # latent
    k_pe = jnp.einsum("bsd,dr->bsr", x, params["w_kpe"].astype(x.dtype))   # shared
    k_nope = jnp.einsum("bsc,ch->bsh", c_kv, params["w_uk"].astype(x.dtype))
    k_nope = k_nope.reshape(b, s, n_heads, nope_dim)
    v = jnp.einsum("bsc,ch->bsh", c_kv, params["w_uv"].astype(x.dtype))
    v = v.reshape(b, s, n_heads, v_dim)

    pos = positions if positions is not None else jnp.arange(s)[None, :]
    q_pe = apply_rope(q_pe, pos, rope_theta)
    k_pe_r = apply_rope(k_pe[:, :, None, :], pos, rope_theta)              # (b,s,1,r)
    q_full = jnp.concatenate([q_nope, q_pe], axis=-1)
    k_full = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe_r, (b, s, n_heads, rope_dim))], axis=-1)
    return q_full, k_full, v, c_kv, k_pe_r[:, :, 0, :]


def mla_forward(params, x, *, n_heads: int, q_lora: int, kv_lora: int,
                nope_dim: int, rope_dim: int, v_dim: int,
                rope_theta: float = 10_000.0, positions=None,
                chunk: int = 1024):
    b, s, _ = x.shape
    q, k, v, c_kv, k_pe = _mla_qkv(
        params, x, n_heads, nope_dim, rope_dim, v_dim, rope_theta, positions)
    q = shard(q, "batch", "attn_seq", "heads", None)
    scale = 1.0 / math.sqrt(nope_dim + rope_dim)
    out = chunked_attention(q, k, v, causal=True, chunk=chunk, scale=scale)
    out = shard(out, "batch", "attn_seq", "heads", None)
    proj = jnp.einsum("bsh,he->bse", out.reshape(b, s, n_heads * v_dim),
                      params["wo"].astype(x.dtype))
    return shard(proj, "batch", "seq", "embed"), (c_kv, k_pe)


def mla_decode(params, x, cache_ckv, cache_kpe, step, *, n_heads: int,
               nope_dim: int, rope_dim: int, v_dim: int,
               rope_theta: float = 10_000.0):
    """Absorbed-matmul MLA decode: attention runs directly in the latent
    space, so the cache stays (T, kv_lora + rope_dim) per token — the MLA
    memory win — and W_uk/W_uv are folded into the query/output paths."""
    b, one, d = x.shape
    t = cache_ckv.shape[1]
    kv_lora = cache_ckv.shape[-1]

    cq = jnp.einsum("bsd,dq->bsq", x, params["w_dq"].astype(x.dtype))
    q = jnp.einsum("bsq,qh->bsh", cq, params["w_uq"].astype(x.dtype))
    q = q.reshape(b, 1, n_heads, nope_dim + rope_dim)
    q_nope, q_pe = q[..., :nope_dim], q[..., nope_dim:]

    pos = jnp.full((b, 1), step, jnp.int32)
    q_pe = apply_rope(q_pe, pos, rope_theta)

    c_kv_new = jnp.einsum("bsd,dc->bsc", x, params["w_dkv"].astype(x.dtype))
    k_pe_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_kpe"].astype(x.dtype))[:, :, None, :],
        pos, rope_theta)[:, :, 0, :]

    cache_ckv = jax.lax.dynamic_update_slice_in_dim(
        cache_ckv, c_kv_new.astype(cache_ckv.dtype), step, axis=1)
    cache_kpe = jax.lax.dynamic_update_slice_in_dim(
        cache_kpe, k_pe_new.astype(cache_kpe.dtype), step, axis=1)

    # Absorb W_uk into the query: q_lat (b, h, c)
    w_uk = params["w_uk"].astype(x.dtype).reshape(kv_lora, n_heads, nope_dim)
    q_lat = jnp.einsum("bshn,chn->bshc", q_nope, w_uk)[:, 0]               # (b,h,c)

    scale = 1.0 / math.sqrt(nope_dim + rope_dim)
    s_lat = jnp.einsum("bhc,btc->bht", q_lat.astype(cache_ckv.dtype), cache_ckv,
                       preferred_element_type=jnp.float32)
    s_pe = jnp.einsum("bhr,btr->bht", q_pe[:, 0].astype(cache_kpe.dtype),
                      cache_kpe, preferred_element_type=jnp.float32)
    s = (s_lat + s_pe) * scale
    valid = (jnp.arange(t)[None, :] <= step)
    s = jnp.where(valid[:, None, :], s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bht,btc->bhc", p.astype(cache_ckv.dtype), cache_ckv,
                         preferred_element_type=jnp.float32)  # (b,h,c)

    # Absorb W_uv into the output projection.
    w_uv = params["w_uv"].astype(x.dtype).reshape(kv_lora, n_heads, v_dim)
    ctx = jnp.einsum("bhc,chv->bhv", ctx_lat.astype(x.dtype), w_uv)
    proj = jnp.einsum("bh,he->be",
                      ctx.reshape(b, n_heads * v_dim), params["wo"].astype(x.dtype))
    return proj[:, None, :], cache_ckv, cache_kpe


# --------------------------------------------------------------------------- #
# cross-attention (enc-dec)
# --------------------------------------------------------------------------- #

def cross_attn_forward(params, x, enc_kv, *, n_heads: int, n_kv: int, head_dim: int,
                       chunk: int = 1024):
    """Decoder->encoder attention; enc_kv = (k, v) precomputed from encoder."""
    b, s, _ = x.shape
    k, v = enc_kv
    q = jnp.einsum("bsd,dh->bsh", x, params["wq"].astype(x.dtype)).reshape(
        b, s, n_heads, head_dim)
    q = shard(q, "batch", "attn_seq", "heads", None)
    out = chunked_attention(q, k, v, causal=False, chunk=min(chunk, k.shape[1]))
    proj = jnp.einsum("bsh,he->bse", out.reshape(b, s, n_heads * head_dim),
                      params["wo"].astype(x.dtype))
    return shard(proj, "batch", "seq", "embed")


def cross_kv(params, enc_out, *, n_kv: int, head_dim: int):
    b, s, _ = enc_out.shape
    k = jnp.einsum("bsd,dh->bsh", enc_out, params["wk"].astype(enc_out.dtype)).reshape(
        b, s, n_kv, head_dim)
    v = jnp.einsum("bsd,dh->bsh", enc_out, params["wv"].astype(enc_out.dtype)).reshape(
        b, s, n_kv, head_dim)
    return k, v
