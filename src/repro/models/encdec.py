"""Encoder-decoder stack (seamless-m4t backbone).

Encoder: bidirectional self-attention over stub modality embeddings (the
speech frontend provides precomputed frame embeddings per the brief).
Decoder: causal self-attention + cross-attention to the encoder output.
Both stacks scan over stacked layer params.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.partitioning import shard
from .attention import (
    chunked_attention,
    cross_attn_forward,
    cross_kv,
    gqa_decode,
    gqa_forward,
    gqa_init,
)
from .common import (DTYPE, embed, embedding_init, mlp_apply, mlp_init,
                     rmsnorm, rmsnorm_init, scan_unroll, unembed)
from .transformer import BIG_WINDOW


def encdec_init(key, cfg: ModelConfig):
    from .common import stacked  # local import to avoid cycle surprises

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        p, a = {}, {}
        p["ln1"], a["ln1"] = rmsnorm_init(cfg.d_model)
        p["attn"], a["attn"] = gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
        p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"], a["mlp"] = mlp_init(k2, cfg.d_model, cfg.d_ff, gated=False)
        return p, a

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        p, a = {}, {}
        p["ln1"], a["ln1"] = rmsnorm_init(cfg.d_model)
        p["attn"], a["attn"] = gqa_init(k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
        p["lnx"], a["lnx"] = rmsnorm_init(cfg.d_model)
        p["xattn"], a["xattn"] = gqa_init(k2, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd)
        p["ln2"], a["ln2"] = rmsnorm_init(cfg.d_model)
        p["mlp"], a["mlp"] = mlp_init(k3, cfg.d_model, cfg.d_ff, gated=False)
        return p, a

    keys = jax.random.split(key, cfg.enc_layers + cfg.n_layers + 3)
    params, axes = {}, {}
    params["embed"], axes["embed"] = embedding_init(keys[0], cfg.padded_vocab, cfg.d_model)
    params["enc_layers"], axes["enc_layers"] = stacked(
        list(keys[1:1 + cfg.enc_layers]), enc_layer)
    params["dec_layers"], axes["dec_layers"] = stacked(
        list(keys[1 + cfg.enc_layers:1 + cfg.enc_layers + cfg.n_layers]), dec_layer)
    params["enc_norm"], axes["enc_norm"] = rmsnorm_init(cfg.d_model)
    params["final_norm"], axes["final_norm"] = rmsnorm_init(cfg.d_model)
    return params, axes


def encode(params, cfg: ModelConfig, frames: jax.Array, *, chunk=1024) -> jax.Array:
    """frames: (B, Se, D) stub embeddings -> encoder output (B, Se, D)."""
    x = shard(frames.astype(DTYPE), "batch", "seq", "embed")

    def body(h, xs):
        (p_l,) = xs
        hn = rmsnorm(h, p_l["ln1"], cfg.rms_eps)
        attn_out, _ = gqa_forward(
            p_l["attn"], hn, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
            head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=False,
            chunk=chunk)
        h = h + attn_out
        hn = rmsnorm(h, p_l["ln2"], cfg.rms_eps)
        return h + mlp_apply(p_l["mlp"], hn, act=jax.nn.gelu), None

    fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(fn, x, (params["enc_layers"],), unroll=scan_unroll())
    return rmsnorm(x, params["enc_norm"], cfg.rms_eps)


def _dec_layer_fwd(p_l, cfg: ModelConfig, h, enc_out, *, chunk, collect=False):
    hn = rmsnorm(h, p_l["ln1"], cfg.rms_eps)
    attn_out, kv = gqa_forward(
        p_l["attn"], hn, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.hd, rope_theta=cfg.rope_theta, causal=True, chunk=chunk)
    h = h + attn_out
    hn = rmsnorm(h, p_l["lnx"], cfg.rms_eps)
    ckv = cross_kv(p_l["xattn"], enc_out, n_kv=cfg.n_kv_heads, head_dim=cfg.hd)
    h = h + cross_attn_forward(
        p_l["xattn"], hn, ckv, n_heads=cfg.n_heads, n_kv=cfg.n_kv_heads,
        head_dim=cfg.hd, chunk=chunk)
    hn = rmsnorm(h, p_l["ln2"], cfg.rms_eps)
    h = h + mlp_apply(p_l["mlp"], hn, act=jax.nn.gelu)
    return (h, kv, ckv) if collect else h


def encdec_forward(params, cfg: ModelConfig, frames, tokens, *, chunk=1024,
                   logits_slice: Optional[str] = None):
    """Training forward: returns (decoder logits, aux=0)."""
    enc_out = encode(params, cfg, frames, chunk=chunk)
    x = embed(params["embed"], tokens)

    def body(h, xs):
        (p_l,) = xs
        return _dec_layer_fwd(p_l, cfg, h, enc_out, chunk=chunk), None

    fn = jax.checkpoint(body) if cfg.remat == "full" else body
    x, _ = jax.lax.scan(fn, x, (params["dec_layers"],), unroll=scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    if logits_slice == "hidden":
        return x, jnp.zeros((), jnp.float32)
    if logits_slice == "last":
        x = x[:, -1:, :]
    logits = unembed(params["embed"], x)
    return logits, jnp.zeros((), jnp.float32)


def encdec_prefill(params, cfg: ModelConfig, frames, tokens, cache_len: int,
                   *, chunk=1024):
    """Encode + run the decoder prompt; build self- and cross-attn caches."""
    enc_out = encode(params, cfg, frames, chunk=chunk)
    x = embed(params["embed"], tokens)
    s = tokens.shape[1]

    def body(h, xs):
        (p_l,) = xs
        h, kv, ckv = _dec_layer_fwd(p_l, cfg, h, enc_out, chunk=chunk, collect=True)
        return h, (kv[0], kv[1], ckv[0], ckv[1])

    x, (ks, vs, cks, cvs) = jax.lax.scan(body, x, (params["dec_layers"],),
                                         unroll=scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    logits = unembed(params["embed"], x[:, -1:, :])

    L, b = cfg.n_layers, tokens.shape[0]
    k_cache = jnp.zeros((L, b, cache_len, cfg.n_kv_heads, cfg.hd), DTYPE)
    v_cache = jnp.zeros_like(k_cache)
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, ks.astype(DTYPE), 0, axis=2)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, vs.astype(DTYPE), 0, axis=2)
    cache = {"k": k_cache, "v": v_cache,
             "ck": cks.astype(DTYPE), "cv": cvs.astype(DTYPE)}
    return logits, cache


def encdec_decode_step(params, cfg: ModelConfig, cache, tokens, step):
    x = embed(params["embed"], tokens)

    def body(h, xs):
        p_l, k_l, v_l, ck_l, cv_l = xs
        hn = rmsnorm(h, p_l["ln1"], cfg.rms_eps)
        attn_out, k_n, v_n = gqa_decode(
            p_l["attn"], hn, k_l, v_l, step, n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.hd, rope_theta=cfg.rope_theta)
        h = h + attn_out
        hn = rmsnorm(h, p_l["lnx"], cfg.rms_eps)
        h = h + cross_attn_forward(
            p_l["xattn"], hn, (ck_l, cv_l), n_heads=cfg.n_heads,
            n_kv=cfg.n_kv_heads, head_dim=cfg.hd, chunk=ck_l.shape[1])
        hn = rmsnorm(h, p_l["ln2"], cfg.rms_eps)
        h = h + mlp_apply(p_l["mlp"], hn, act=jax.nn.gelu)
        return h, (k_n, v_n)

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["k"], cache["v"],
                  cache["ck"], cache["cv"]), unroll=scan_unroll())
    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    logits = unembed(params["embed"], x)
    new_cache = {"k": k_new, "v": v_new, "ck": cache["ck"], "cv": cache["cv"]}
    return logits, new_cache


def encdec_cache_spec(cfg: ModelConfig, batch: int, cache_len: int, enc_len: int):
    L = cfg.n_layers
    return {
        "k": ((L, batch, cache_len, cfg.n_kv_heads, cfg.hd),
              ("layers", "batch", "kv_len", "kv_heads", None)),
        "v": ((L, batch, cache_len, cfg.n_kv_heads, cfg.hd),
              ("layers", "batch", "kv_len", "kv_heads", None)),
        "ck": ((L, batch, enc_len, cfg.n_kv_heads, cfg.hd),
               ("layers", "batch", "kv_len", "kv_heads", None)),
        "cv": ((L, batch, enc_len, cfg.n_kv_heads, cfg.hd),
               ("layers", "batch", "kv_len", "kv_heads", None)),
    }
