"""Shared building blocks for every architecture: parameter construction with
logical axes, norms, MLPs, rotary embeddings, softcap, embeddings.

Parameter convention
--------------------
``init`` functions return ``(params, axes)`` — two parallel pytrees, where
``axes`` holds a tuple of logical axis names (see distributed/partitioning)
per array leaf.  ``axes_to_pspecs`` converts the axes tree into the
PartitionSpec tree handed to pjit.  Stacked (scanned) layers prepend a
"layers" axis to both trees.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from ..distributed.partitioning import logical_to_spec, shard

Params = Dict[str, Any]
Axes = Dict[str, Any]

DTYPE = jnp.bfloat16      # activation/weight dtype on the wire
PARAM_DTYPE = jnp.float32  # master weights

# ---------------------------------------------------------------------------
# cost-probe mode: XLA's cost analysis counts while-loop bodies ONCE, so the
# dry-run's cost probe recompiles reduced-depth configs with every lax.scan
# fully unrolled (see launch/dryrun.py).  Model code asks scan_unroll() at
# each scan site.
# ---------------------------------------------------------------------------
import threading as _threading

_probe = _threading.local()


def set_probe_unroll(on: bool) -> None:
    _probe.on = bool(on)


def scan_unroll():
    return True if getattr(_probe, "on", False) else 1


# --------------------------------------------------------------------------- #
# parameter construction
# --------------------------------------------------------------------------- #

def dense_init(key, in_dim: int, out_dim: int, in_ax: Optional[str],
               out_ax: Optional[str], scale: Optional[float] = None):
    """Weight (in, out) with truncated-normal fan-in init + logical axes."""
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    w = jax.random.truncated_normal(key, -2.0, 2.0, (in_dim, out_dim), PARAM_DTYPE) * scale
    return w, (in_ax, out_ax)


def stacked(keys, fn, *args, **kwargs):
    """Initialise ``fn`` once per layer key and stack leaves on axis 0,
    prepending the 'layers' logical axis."""
    outs = [fn(k, *args, **kwargs) for k in keys]
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[p for p, _ in outs])
    axes = jax.tree.map(
        lambda ax: ("layers",) + tuple(ax),
        outs[0][1],
        is_leaf=lambda x: isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x),
    )
    return params, axes


def axes_to_pspecs(axes_tree, rules=None):
    """Convert a logical-axes tree into a PartitionSpec tree."""
    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(e, (str, type(None))) for e in x)
    return jax.tree.map(lambda ax: logical_to_spec(ax, rules), axes_tree, is_leaf=is_axes)


def cast_params(params, dtype=DTYPE):
    return jax.tree.map(lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x, params)


# --------------------------------------------------------------------------- #
# normalisation / activations
# --------------------------------------------------------------------------- #

def rmsnorm_init(dim: int):
    return jnp.ones((dim,), PARAM_DTYPE), (None,)


def rmsnorm(x, scale, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * scale.astype(jnp.float32)).astype(dt)


def softcap(x, cap: Optional[float]):
    """Gemma-2 style logit soft-capping: cap * tanh(x / cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------------- #
# MLPs
# --------------------------------------------------------------------------- #

def mlp_init(key, d_model: int, d_ff: int, gated: bool = True):
    k1, k2, k3 = jax.random.split(key, 3)
    params: Params = {}
    axes: Axes = {}
    params["w_in"], axes["w_in"] = dense_init(k1, d_model, d_ff, "embed", "ff")
    if gated:
        params["w_gate"], axes["w_gate"] = dense_init(k2, d_model, d_ff, "embed", "ff")
    params["w_out"], axes["w_out"] = dense_init(k3, d_ff, d_model, "ff", "embed")
    return params, axes


def mlp_apply(params, x, act=jax.nn.silu):
    """(Gated-)MLP with TP-friendly sharding constraints."""
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(x.dtype))
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(x.dtype))
        h = act(g) * h
    else:
        h = act(h)
    h = shard(h, "batch", "mlp_seq", "ff")
    out = jnp.einsum("...f,fd->...d", h, params["w_out"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed")


# --------------------------------------------------------------------------- #
# rotary position embeddings (standard + M-RoPE)
# --------------------------------------------------------------------------- #

def rope_freqs(head_dim: int, theta: float = 10_000.0):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                                  # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * inv      # (..., S, hd/2)
    sin = jnp.sin(ang)[..., :, None, :]                          # (..., S, 1, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections: Tuple[int, int, int], theta: float = 1_000_000.0):
    """Qwen2-VL multimodal RoPE.

    x: (..., S, H, hd); positions3: (..., S, 3) temporal/height/width ids.
    ``sections`` partitions the hd/2 frequency bands among the 3 position
    streams (e.g. (16, 24, 24) for hd=128).
    """
    hd = x.shape[-1]
    half = hd // 2
    assert sum(sections) == half, (sections, hd)
    inv = rope_freqs(hd, theta)                                  # (half,)
    # pick which position stream drives each frequency band
    sec_id = jnp.repeat(jnp.arange(3), jnp.array(sections), total_repeat_length=half)
    gather_ix = jnp.broadcast_to(sec_id, positions3.shape[:-1] + (half,)).astype(jnp.int32)
    pos = jnp.take_along_axis(positions3.astype(jnp.float32), gather_ix, axis=-1)
    # (..., S, half)
    ang = pos * inv
    sin = jnp.sin(ang)[..., :, None, :]
    cos = jnp.cos(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------- #
# embeddings / unembedding
# --------------------------------------------------------------------------- #

def embedding_init(key, vocab: int, d_model: int):
    w = jax.random.normal(key, (vocab, d_model), PARAM_DTYPE) * 0.02
    return w, ("vocab", "embed")


def embed(params_w, tokens, scale_by_dim: bool = False):
    out = jnp.take(params_w.astype(DTYPE), tokens, axis=0)
    if scale_by_dim:
        out = out * math.sqrt(params_w.shape[1])
    return shard(out, "batch", "seq", "embed")


def unembed(params_w, x, cap: Optional[float] = None):
    logits = jnp.einsum("...d,vd->...v", x, params_w.astype(x.dtype))
    logits = softcap(logits.astype(jnp.float32), cap)
    return shard(logits, "batch", "logit_seq", "vocab")


# --------------------------------------------------------------------------- #
# losses
# --------------------------------------------------------------------------- #

def chunked_softmax_cross_entropy(x, w_un, labels, *, cap: Optional[float] = None,
                                  z_loss: float = 1e-4, seq_chunk: int = 512):
    """Cross-entropy that never materialises the full (B, S, V) logits.

    The unembed + CE runs per sequence-chunk under ``jax.checkpoint``: peak
    logits memory drops from O(S·V) to O(seq_chunk·V), the dominant buffer
    for 256k-vocab models at 4k+ context (the backward pass recomputes each
    chunk's logits, costing one extra unembed matmul — a good trade).
    """
    b, s, d = x.shape
    if s % seq_chunk or s <= seq_chunk:
        logits = unembed(w_un, x, cap=cap)
        return softmax_cross_entropy(logits, labels, z_loss)
    nc = s // seq_chunk
    xc = x.reshape(b, nc, seq_chunk, d).swapaxes(0, 1)        # (nc, b, c, d)
    lc = labels.reshape(b, nc, seq_chunk).swapaxes(0, 1)

    @jax.checkpoint
    def body(carry, xs):
        xi, li = xs
        logits = unembed(w_un, xi, cap=cap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(logits, li[..., None], axis=-1)[..., 0]
        piece = (lse - ll) + (z_loss * jnp.square(lse) if z_loss else 0.0)
        return carry + piece.sum(), None

    total, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (xc, lc),
                            unroll=scan_unroll())
    return total / (b * s)


def softmax_cross_entropy(logits, labels, z_loss: float = 1e-4):
    """Token-mean CE with an optional z-loss regulariser (stabilises the
    softmax normaliser at scale; standard in production LM training)."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    loss = lse - ll
    if z_loss:
        loss = loss + z_loss * jnp.square(lse)
    return loss.mean()
