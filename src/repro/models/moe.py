"""Mixture-of-Experts layer: top-k router + GROUPED sort-based dispatch.

Dispatch strategy (production-critical). Two earlier designs failed the
dry-run at scale and are kept here for the record (EXPERIMENTS.md §Perf):

* v0 — GShard one-hot dispatch tensor (T, E, C): O(T^2 k / E) memory;
  1.9 TiB/device for mixtral train_4k.
* v1 — global flat route-sort over all T = B*S tokens: right asymptotics,
  but the global argsort/gather/scatter crosses the batch sharding, so GSPMD
  replicates — 186 GiB/device and a 147 s collective term.

v2 (this file) — GROUPED routing, groups = batch rows (exactly GShard's
group dimension): every row of the batch routes its own S*k (token, slot)
pairs with a per-row capacity C = ceil(S*k*cf/E).  All sorting, position
computation, scatter and combine are per-row -> fully local to the data
shard; the ONLY cross-device movement is the (B, E, C, D) expert buffer
resharding when experts are model-sharded — which IS the EP all-to-all.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from ..distributed.partitioning import shard
from .common import dense_init


def moe_init(key, d_model: int, d_ff: int, n_experts: int):
    kr, k1, k2, k3 = jax.random.split(key, 4)
    params, axes = {}, {}
    params["router"], axes["router"] = dense_init(kr, d_model, n_experts, "embed", None)
    scale = 1.0 / math.sqrt(d_model)
    params["w_in"] = jax.random.truncated_normal(
        k1, -2, 2, (n_experts, d_model, d_ff), jnp.float32) * scale
    axes["w_in"] = ("experts", "embed", "expert_ff")
    params["w_gate"] = jax.random.truncated_normal(
        k2, -2, 2, (n_experts, d_model, d_ff), jnp.float32) * scale
    axes["w_gate"] = ("experts", "embed", "expert_ff")
    params["w_out"] = jax.random.truncated_normal(
        k3, -2, 2, (n_experts, d_ff, d_model), jnp.float32) * (1.0 / math.sqrt(d_ff))
    axes["w_out"] = ("experts", "expert_ff", "embed")
    return params, axes


def moe_apply(
    params, x, *,
    n_experts: int,
    top_k: int = 2,
    capacity_factor: float = 1.25,
    act=jax.nn.silu,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (output, aux_loss).  x: (B, S, D); groups = batch rows."""
    b, s, d = x.shape
    e = n_experts

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(x.dtype))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)          # (B,S,E)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)                    # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)

    capacity = max(1, int(math.ceil(s * top_k * capacity_factor / e)))
    p = s * top_k                                                        # pairs/row

    # ---- per-row route sort (local to the batch shard) ------------------ #
    flat_e = gate_idx.reshape(b, p)                                      # (B,P)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(s, dtype=jnp.int32), top_k)[None], (b, p))
    flat_gate = gate_vals.reshape(b, p)

    order = jnp.argsort(flat_e, axis=-1, stable=True)                    # (B,P)
    e_sorted = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=-1)
    gate_sorted = jnp.take_along_axis(flat_gate, order, axis=-1)

    # position inside each expert's buffer: running index - expert start
    onehot_counts = jax.nn.one_hot(e_sorted, e, dtype=jnp.int32).sum(axis=1)  # (B,E)
    starts = jnp.concatenate(
        [jnp.zeros((b, 1), jnp.int32),
         jnp.cumsum(onehot_counts, axis=-1)[:, :-1]], axis=-1)           # (B,E)
    pos = jnp.arange(p, dtype=jnp.int32)[None, :] - jnp.take_along_axis(
        starts, e_sorted, axis=-1)
    keep = pos < capacity
    pos_c = jnp.where(keep, pos, 0)

    # ---- scatter into (B, E, C, D) --------------------------------------- #
    # vmap over the batch row so the scatter carries an explicit batching
    # dim: GSPMD keeps it local to the data shard (a flat 3-index scatter
    # defeats sharding propagation and replicates — v1 lesson).
    gathered = jnp.take_along_axis(x, tok_sorted[..., None], axis=1)     # (B,P,D)
    upd = jnp.where(keep[..., None], gathered, 0).astype(x.dtype)

    def _scatter_row(ei, pi, ui):
        return jnp.zeros((e, capacity, d), ui.dtype).at[ei, pi].add(ui)

    expert_in = jax.vmap(_scatter_row)(e_sorted, pos_c, upd)
    expert_in = shard(expert_in, "batch", "experts", None, "embed")

    # ---- expert FFNs (EP all-to-all emerges here when E is sharded) ----- #
    h = jnp.einsum("becd,edf->becf", expert_in, params["w_in"].astype(x.dtype))
    g = jnp.einsum("becd,edf->becf", expert_in, params["w_gate"].astype(x.dtype))
    h = act(g) * h
    h = shard(h, "batch", "experts", None, "expert_ff")
    expert_out = jnp.einsum("becf,efd->becd", h, params["w_out"].astype(x.dtype))
    expert_out = shard(expert_out, "batch", "experts", None, "embed")

    # ---- combine (local gather + per-row scatter-add) -------------------- #
    def _gather_row(eo, ei, pi):
        return eo[ei, pi]

    pair_out = jax.vmap(_gather_row)(expert_out, e_sorted, pos_c)        # (B,P,D)
    pair_out = jnp.where(keep[..., None], pair_out, 0)
    pair_out = pair_out * gate_sorted[..., None].astype(x.dtype)

    def _combine_row(ti, po):
        return jnp.zeros((s, d), po.dtype).at[ti].add(po)

    out = jax.vmap(_combine_row)(tok_sorted, pair_out)
    out = shard(out, "batch", "seq", "embed")

    # load-balancing auxiliary loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    onehot = jax.nn.one_hot(gate_idx, e, dtype=jnp.float32)
    ce = onehot.sum(axis=2).mean(axis=(0, 1)) / top_k
    aux = e * jnp.sum(me * ce)
    return out, aux
