"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) block.

Training uses the chunked SSD algorithm: within a chunk the recurrence is
evaluated in its quadratic 'attention' dual form (MXU-friendly), and chunk
states are threaded through a ``lax.scan`` — O(S * chunk) work with constant
memory per chunk.  Decode is the exact single-step SSM recurrence with a
constant-size (H, P, N) state plus a small causal-conv tail — which is why
SSM/hybrid architectures keep the ``long_500k`` shape feasible.

Sharding layout (DESIGN.md §6): the inner dimension is kept factored as
(H heads, P head-dim) everywhere and the model axis shards P (64 % 16 == 0
for every assigned config), so z/x/out projections, the causal conv and all
SSD einsums shard conflict-free; B/C/dt are small and replicated.  The input
projection is SPLIT per component (z, x, B, C, dt) rather than fused, so no
shard ever straddles a component boundary.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..distributed.partitioning import shard
from .common import dense_init, scan_unroll

CONV_K = 4


def mamba2_init(key, d_model: int, *, expand: int = 2, head_p: int = 64,
                state: int = 128):
    d_inner = expand * d_model
    n_heads = d_inner // head_p
    ks = jax.random.split(key, 8)
    params, axes = {}, {}
    scale = 1.0 / math.sqrt(d_model)

    def hp_proj(k):  # (d_model, H, P) projection sharded on P
        w = jax.random.truncated_normal(k, -2, 2, (d_model, n_heads, head_p),
                                        jnp.float32) * scale
        return w, ("embed", None, "ssm_inner")

    params["w_z"], axes["w_z"] = hp_proj(ks[0])
    params["w_x"], axes["w_x"] = hp_proj(ks[1])
    params["w_b"], axes["w_b"] = dense_init(ks[2], d_model, state, "embed", None)
    params["w_c"], axes["w_c"] = dense_init(ks[3], d_model, state, "embed", None)
    params["w_dt"], axes["w_dt"] = dense_init(ks[4], d_model, n_heads, "embed", None)
    params["conv_x"] = jax.random.normal(ks[5], (CONV_K, n_heads, head_p), jnp.float32) * 0.1
    axes["conv_x"] = (None, None, "ssm_inner")
    params["conv_b"] = jax.random.normal(ks[6], (CONV_K, state), jnp.float32) * 0.1
    axes["conv_b"] = (None, None)
    params["conv_c"] = jax.random.normal(ks[7], (CONV_K, state), jnp.float32) * 0.1
    axes["conv_c"] = (None, None)
    params["A_log"] = jnp.log(jnp.linspace(1.0, 16.0, n_heads).astype(jnp.float32))
    axes["A_log"] = (None,)
    params["dt_bias"] = jnp.zeros((n_heads,), jnp.float32)
    axes["dt_bias"] = (None,)
    params["D"] = jnp.ones((n_heads,), jnp.float32)
    axes["D"] = (None,)
    params["norm"] = jnp.ones((n_heads, head_p), jnp.float32)
    axes["norm"] = (None, "ssm_inner")
    w_out = jax.random.truncated_normal(
        ks[0], -2, 2, (n_heads, head_p, d_model), jnp.float32) / math.sqrt(d_inner)
    params["w_out"] = w_out
    axes["w_out"] = (None, "ssm_inner", "embed")
    return params, axes


def _causal_conv(seq, w, tail):
    """Depthwise causal conv along time.  seq: (b, s, ...ch), w: (K, ...ch),
    tail: (b, K-1, ...ch) history (zeros at sequence start)."""
    s = seq.shape[1]
    full = jnp.concatenate([tail.astype(seq.dtype), seq], axis=1)
    out = sum(full[:, i : i + s] * w[i][None, None] for i in range(CONV_K))
    return out, full[:, -( CONV_K - 1):]


def _gated_norm(y, z, scale, eps=1e-6):
    """RMSNorm over the (H, P) inner dims of y * silu(z)."""
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=(-2, -1), keepdims=True)
    return g * jax.lax.rsqrt(var + eps) * scale[None, None]


def _segsum(dA):
    """Stable 'segment sum' for the intra-chunk decay matrix L.

    dA: (..., L) -> L_mat (..., L, L) with L[i, j] = exp(sum_{j<k<=i} dA_k),
    lower-triangular (zero above diagonal).
    """
    l = dA.shape[-1]
    csum = jnp.cumsum(dA, axis=-1)
    diff = csum[..., :, None] - csum[..., None, :]
    ii = jnp.arange(l)
    mask = ii[:, None] >= ii[None, :]
    # Mask BEFORE exp: upper-tri diffs are large-positive and would overflow;
    # masking after exp leaves a 0*inf -> NaN in the backward pass.
    diff = jnp.where(mask, diff, -jnp.inf)
    return jnp.exp(diff)


def ssd_chunked(x, dt, A, B, C, D, *, chunk: int = 256,
                init_state: Optional[jax.Array] = None):
    """Chunked SSD scan.

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative decay;
    B, C: (b, s, n); D: (h,) skip.  Returns (y (b, s, h, p), final_state
    (b, h, p, n)).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk

    xb = x.reshape(b, nc, chunk, h, p)
    dtb = dt.reshape(b, nc, chunk, h)
    Bb = B.reshape(b, nc, chunk, n)
    Cb = C.reshape(b, nc, chunk, n)

    dA = dtb * A[None, None, None, :]                      # (b,nc,l,h) <= 0
    dA_cum = jnp.cumsum(dA, axis=2)                        # within chunk
    dA_tot = dA_cum[:, :, -1:, :]                          # (b,nc,1,h)

    # intra-chunk (dual quadratic form): y_intra = (L o (C B^T)) (dt*x)
    L = _segsum(dA.transpose(0, 1, 3, 2))                  # (b,nc,h,l,l)
    scores = jnp.einsum("bcln,bcmn->bclm", Cb, Bb)         # (b,nc,l,l)
    gated = scores[:, :, None, :, :] * L                   # (b,nc,h,l,l)
    xdt = xb * dtb[..., None]                              # (b,nc,l,h,p)
    y_intra = jnp.einsum("bchlm,bcmhp->bclhp", gated, xdt)

    # chunk-final states: sum_l exp(dA_tot - dA_cum_l) * B_l (dt*x)_l
    decay_to_end = jnp.exp(dA_tot - dA_cum)                # (b,nc,l,h)
    states = jnp.einsum("bcln,bclh,bclhp->bchpn", Bb, decay_to_end, xdt)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(dA_tot[:, :, 0, :])              # (b,nc,h)

    def step(carry, xs):
        h_prev = carry                                     # (b,h,p,n)
        st, dec = xs                                       # (b,h,p,n), (b,h)
        h_new = h_prev * dec[..., None, None] + st
        return h_new, h_prev

    h0 = init_state if init_state is not None else jnp.zeros((b, h, p, n), x.dtype)
    # the state-passing scan is cost-negligible (elementwise adds, no
    # collectives); cap probe unrolling so 32k-seq probes stay compilable
    unroll = scan_unroll()
    if unroll is True and nc > 32:
        unroll = 1
    final, h_prevs = jax.lax.scan(
        step, h0, (states.swapaxes(0, 1), chunk_decay.swapaxes(0, 1)),
        unroll=unroll)
    h_prevs = h_prevs.swapaxes(0, 1)                       # (b,nc,h,p,n) entering state

    # contribution of the entering state to each position in the chunk
    decay_from_start = jnp.exp(dA_cum)                     # (b,nc,l,h)
    y_inter = jnp.einsum("bcln,bchpn,bclh->bclhp", Cb, h_prevs, decay_from_start)

    y = (y_intra + y_inter).reshape(b, s, h, p) + x * D[None, None, :, None]
    return y, final


def mamba2_forward(params, hidden, *, d_model: int, expand: int = 2,
                   head_p: int = 64, state: int = 128, chunk: int = 256,
                   conv_state=None, ssm_state=None, return_state: bool = False):
    """Full-sequence Mamba2 block (train / prefill).

    conv_state: optional dict {"x": (b,K-1,h,p), "b": (b,K-1,n), "c": ...}.
    """
    b, s, _ = hidden.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_p

    z = jnp.einsum("bsd,dhp->bshp", hidden, params["w_z"].astype(hidden.dtype))
    x = jnp.einsum("bsd,dhp->bshp", hidden, params["w_x"].astype(hidden.dtype))
    # inner activations keep the full sequence locally (the SSD scan is
    # sequential in time); under SP the residual re-gathers at block entry.
    z = shard(z, "batch", None, None, "ssm_inner")
    x = shard(x, "batch", None, None, "ssm_inner")
    Bp = jnp.einsum("bsd,dn->bsn", hidden, params["w_b"].astype(hidden.dtype))
    Cp = jnp.einsum("bsd,dn->bsn", hidden, params["w_c"].astype(hidden.dtype))
    dt = jnp.einsum("bsd,dh->bsh", hidden, params["w_dt"].astype(hidden.dtype))

    zeros_x = jnp.zeros((b, CONV_K - 1, n_heads, head_p), hidden.dtype)
    zeros_n = jnp.zeros((b, CONV_K - 1, state), hidden.dtype)
    cs = conv_state or {"x": zeros_x, "b": zeros_n, "c": zeros_n}
    x_c, tail_x = _causal_conv(x, params["conv_x"].astype(x.dtype), cs["x"])
    B_c, tail_b = _causal_conv(Bp, params["conv_b"].astype(x.dtype), cs["b"])
    C_c, tail_c = _causal_conv(Cp, params["conv_c"].astype(x.dtype), cs["c"])
    x_c = jax.nn.silu(x_c)
    B_c = jax.nn.silu(B_c)
    C_c = jax.nn.silu(C_c)

    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])
    y, final = ssd_chunked(
        x_c.astype(jnp.float32), dt_s, A, B_c.astype(jnp.float32),
        C_c.astype(jnp.float32), params["D"], chunk=min(chunk, s),
        init_state=ssm_state)
    y = _gated_norm(y, z, params["norm"]).astype(hidden.dtype)
    out = jnp.einsum("bshp,hpd->bsd", y, params["w_out"].astype(hidden.dtype))
    out = shard(out, "batch", "seq", "embed")
    if return_state:
        new_conv = {"x": tail_x, "b": tail_b, "c": tail_c}
        return out, (new_conv, final)
    return out


def mamba2_decode(params, hidden, conv_state, ssm_state, *, d_model: int,
                  expand: int = 2, head_p: int = 64, state: int = 128):
    """Single-token recurrent step.

    conv_state: {"x": (b,K-1,h,p), "b": (b,K-1,n), "c": (b,K-1,n)};
    ssm_state : (b, h, p, n).  Returns (out, conv_state, ssm_state).
    """
    b, one, _ = hidden.shape
    d_inner = expand * d_model
    n_heads = d_inner // head_p

    z = jnp.einsum("bsd,dhp->bshp", hidden, params["w_z"].astype(hidden.dtype))[:, 0]
    x = jnp.einsum("bsd,dhp->bshp", hidden, params["w_x"].astype(hidden.dtype))[:, 0]
    Bp = jnp.einsum("bsd,dn->bsn", hidden, params["w_b"].astype(hidden.dtype))[:, 0]
    Cp = jnp.einsum("bsd,dn->bsn", hidden, params["w_c"].astype(hidden.dtype))[:, 0]
    dt = jnp.einsum("bsd,dh->bsh", hidden, params["w_dt"].astype(hidden.dtype))[:, 0]

    def conv_step(tail, new, w):
        hist = jnp.concatenate([tail.astype(new.dtype), new[:, None]], axis=1)  # (b,K,...)
        out = jnp.einsum("bk...,k...->b...", hist, w.astype(new.dtype))
        return jax.nn.silu(out), hist[:, 1:]

    x_c, tail_x = conv_step(conv_state["x"], x, params["conv_x"])
    B_c, tail_b = conv_step(conv_state["b"], Bp, params["conv_b"])
    C_c, tail_c = conv_step(conv_state["c"], Cp, params["conv_c"])

    dt_s = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])   # (b,h)
    A = -jnp.exp(params["A_log"])
    dA = jnp.exp(dt_s * A[None, :])                                      # (b,h)
    xdt = x_c.astype(jnp.float32) * dt_s[..., None]                      # (b,h,p)
    new_state = ssm_state * dA[..., None, None] + jnp.einsum(
        "bhp,bn->bhpn", xdt, B_c.astype(jnp.float32))
    y = jnp.einsum("bhpn,bn->bhp", new_state, C_c.astype(jnp.float32))
    y = y + x_c.astype(jnp.float32) * params["D"][None, :, None]
    y = _gated_norm(y[:, None], z[:, None], params["norm"])[:, 0]
    y = y.astype(hidden.dtype)
    out = jnp.einsum("bhp,hpd->bd", y, params["w_out"].astype(hidden.dtype))
    new_conv = {"x": tail_x, "b": tail_b, "c": tail_c}
    return out[:, None], new_conv, new_state
