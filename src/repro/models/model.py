"""Unified model facade: one object per architecture config exposing

    init / forward / loss / prefill / decode_step / init_cache /
    input_specs / input_axes / param_count

so the trainer, server, dry-run and smoke tests never dispatch on family
themselves.  All heavy lifting lives in transformer.py / encdec.py.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from .common import (DTYPE, axes_to_pspecs, chunked_softmax_cross_entropy,
                     softmax_cross_entropy)
from . import encdec as ed
from . import transformer as tf

__all__ = ["Model", "build_model"]


def _vlm_positions3(batch: int, n_patches: int, seq_total: int, grid: int):
    """M-RoPE position ids: image patches get (t=0, h, w) grid coords; text
    continues temporally after the image."""
    p_h = jnp.arange(n_patches) // grid
    p_w = jnp.arange(n_patches) % grid
    img = jnp.stack([jnp.zeros(n_patches, jnp.int32), p_h, p_w], axis=-1)
    s_text = seq_total - n_patches
    t0 = grid  # text starts after the image's spatial extent
    txt_pos = t0 + jnp.arange(s_text, dtype=jnp.int32)
    txt = jnp.stack([txt_pos] * 3, axis=-1)
    pos = jnp.concatenate([img, txt], axis=0).astype(jnp.int32)
    return jnp.broadcast_to(pos[None], (batch, seq_total, 3))


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ----------------------------- init -------------------------------- #
    def init(self, rng) -> Tuple[Any, Any]:
        cfg = self.cfg
        if cfg.family == "encdec":
            return ed.encdec_init(rng, cfg)
        if cfg.family == "hybrid":
            return tf.hybrid_init(rng, cfg)
        return tf.decoder_init(rng, cfg)

    def param_pspecs(self, rules=None):
        holder = {}
        def _init(k):
            params, ax = self.init(k)
            holder["axes"] = ax
            return params
        jax.eval_shape(_init, jax.random.key(0))
        return axes_to_pspecs(holder["axes"], rules)

    # --------------------------- forward -------------------------------- #
    def _vlm_embed(self, params, batch):
        cfg = self.cfg
        tok_emb = jnp.take(params["embed"].astype(DTYPE), batch["tokens"], axis=0)
        x = jnp.concatenate([batch["patches"].astype(DTYPE), tok_emb], axis=1)
        return x

    def forward(self, params, batch, *, chunk: Optional[int] = None,
                logits_slice: Optional[str] = None):
        """Training forward; returns (logits, aux_loss)."""
        cfg = self.cfg
        chunk = chunk or cfg.attn_chunk
        if cfg.family == "encdec":
            return ed.encdec_forward(params, cfg, batch["frames"], batch["tokens"],
                                     chunk=chunk, logits_slice=logits_slice)
        if cfg.family == "hybrid":
            return tf.hybrid_forward(params, cfg, batch["tokens"], chunk=chunk,
                                     logits_slice=logits_slice)
        if cfg.family == "vlm":
            x = self._vlm_embed(params, batch)
            s_total = x.shape[1]
            grid = int(math.sqrt(cfg.n_patches))
            pos3 = _vlm_positions3(x.shape[0], cfg.n_patches, s_total, grid)
            return tf.decoder_forward(params, cfg, x_embed=x, positions3=pos3,
                                      chunk=chunk, logits_slice=logits_slice)
        return tf.decoder_forward(params, cfg, batch["tokens"], chunk=chunk,
                                  logits_slice=logits_slice)

    def loss(self, params, batch, *, chunk: Optional[int] = None):
        """Token-mean CE via the chunked unembed (big-vocab memory path)."""
        cfg = self.cfg
        hidden, aux = self.forward(params, batch, chunk=chunk,
                                   logits_slice="hidden")
        if cfg.family == "vlm":
            hidden = hidden[:, cfg.n_patches:, :]
        w_un = params.get("unembed", params["embed"]) if not cfg.tie_embeddings             else params["embed"]
        ce = chunked_softmax_cross_entropy(
            hidden, w_un, batch["labels"], cap=cfg.final_softcap)
        return ce + 0.01 * aux

    # --------------------------- serving -------------------------------- #
    def prefill(self, params, batch, cache_len: int, *, chunk: Optional[int] = None):
        cfg = self.cfg
        chunk = chunk or cfg.attn_chunk
        if cfg.family == "encdec":
            return ed.encdec_prefill(params, cfg, batch["frames"], batch["tokens"],
                                     cache_len, chunk=chunk)
        if cfg.family == "hybrid":
            return tf.hybrid_prefill(params, cfg, batch["tokens"], cache_len,
                                     chunk=chunk)
        if cfg.family == "vlm":
            x = self._vlm_embed(params, batch)
            s_total = x.shape[1]
            grid = int(math.sqrt(cfg.n_patches))
            pos3 = _vlm_positions3(x.shape[0], cfg.n_patches, s_total, grid)
            return tf.decoder_prefill(params, cfg, x_embed=x, cache_len=cache_len,
                                      positions3=pos3, chunk=chunk)
        return tf.decoder_prefill(params, cfg, batch["tokens"],
                                  cache_len=cache_len, chunk=chunk)

    def decode_step(self, params, cache, tokens, step):
        cfg = self.cfg
        if cfg.family == "encdec":
            return ed.encdec_decode_step(params, cfg, cache, tokens, step)
        if cfg.family == "hybrid":
            return tf.hybrid_decode_step(params, cfg, cache, tokens, step)
        rope_pos = None
        if cfg.family == "vlm":
            # M-RoPE text stream: positions continue at grid offset after the
            # image block, not at the raw sequence index (see _vlm_positions3).
            grid = int(math.sqrt(cfg.n_patches))
            rope_pos = step - cfg.n_patches + grid
        return tf.decoder_decode_step(params, cfg, cache, tokens, step,
                                      rope_pos=rope_pos)

    # --------------------------- caches --------------------------------- #
    def init_cache(self, batch: int, cache_len: int, *, enc_len: Optional[int] = None,
                   abstract: bool = False):
        cfg = self.cfg
        if cfg.family == "encdec":
            spec = ed.encdec_cache_spec(cfg, batch, cache_len, enc_len or cache_len)
            if abstract:
                return {k: jax.ShapeDtypeStruct(s, DTYPE) for k, (s, _) in spec.items()}
            return {k: jnp.zeros(s, DTYPE) for k, (s, _) in spec.items()}
        return tf.init_cache(cfg, batch, cache_len, abstract=abstract)

    def cache_logical_axes(self, batch: int, cache_len: int, *, enc_len=None):
        cfg = self.cfg
        if cfg.family == "encdec":
            spec = ed.encdec_cache_spec(cfg, batch, cache_len, enc_len or cache_len)
            return {k: ax for k, (s, ax) in spec.items()}
        return tf.cache_axes(cfg, batch, cache_len)

    # --------------------------- input specs ----------------------------- #
    def input_specs(self, shape: ShapeConfig, *, enc_len: Optional[int] = None
                    ) -> Dict[str, Any]:
        """ShapeDtypeStruct stand-ins for the dry-run (no allocation)."""
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        sd = jax.ShapeDtypeStruct
        if shape.kind == "train":
            if cfg.family == "encdec":
                return {"frames": sd((b, s, cfg.d_model), DTYPE),
                        "tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
            if cfg.family == "vlm":
                s_text = s - cfg.n_patches
                return {"patches": sd((b, cfg.n_patches, cfg.d_model), DTYPE),
                        "tokens": sd((b, s_text), i32),
                        "labels": sd((b, s_text), i32)}
            return {"tokens": sd((b, s), i32), "labels": sd((b, s), i32)}
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": sd((b, s, cfg.d_model), DTYPE),
                        "tokens": sd((b, 1), i32)}
            if cfg.family == "vlm":
                s_text = s - cfg.n_patches
                return {"patches": sd((b, cfg.n_patches, cfg.d_model), DTYPE),
                        "tokens": sd((b, s_text), i32)}
            return {"tokens": sd((b, s), i32)}
        # decode: one new token against a cache of seq_len
        return {"tokens": sd((b, 1), i32)}

    def input_logical_axes(self, shape: ShapeConfig) -> Dict[str, Tuple]:
        cfg = self.cfg
        if shape.kind == "train":
            if cfg.family == "encdec":
                return {"frames": ("batch", "seq", "embed"),
                        "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
            if cfg.family == "vlm":
                return {"patches": ("batch", "seq", "embed"),
                        "tokens": ("batch", "seq"), "labels": ("batch", "seq")}
            return {"tokens": ("batch", "seq"), "labels": ("batch", "seq")}
        if shape.kind == "prefill":
            if cfg.family == "encdec":
                return {"frames": ("batch", "seq", "embed"), "tokens": ("batch", None)}
            if cfg.family == "vlm":
                return {"patches": ("batch", "seq", "embed"), "tokens": ("batch", "seq")}
            return {"tokens": ("batch", "seq")}
        return {"tokens": ("batch", None)}

    # --------------------------- accounting ------------------------------ #
    def param_count(self, params=None) -> int:
        if params is None:
            params = jax.eval_shape(lambda k: self.init(k)[0], jax.random.key(0))
        return int(sum(np.prod(x.shape) for x in jax.tree.leaves(params)))

    def active_param_count(self, params=None) -> int:
        """MoE: params touched per token (top_k of n_experts)."""
        cfg = self.cfg
        total = self.param_count(params)
        if not cfg.n_experts:
            return total
        expert_p = 3 * cfg.d_model * cfg.d_ff  # w_in, w_gate, w_out per expert
        moe_total = cfg.n_layers * cfg.n_experts * expert_p
        moe_active = cfg.n_layers * cfg.top_k * expert_p
        return total - moe_total + moe_active


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
