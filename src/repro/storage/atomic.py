"""The repo's ONE atomic-persistence idiom (DESIGN.md §7.1).

Every durable artifact in this codebase — training checkpoints
(``runtime.checkpoint.Checkpointer``), index snapshots
(``storage.snapshot``) and the sharded partitioner spec — is written the
same way:

1. **stage**: the payload is written into a sibling ``.tmp.<nonce>.<name>``
   directory (or file), never into the final path;
2. **rename**: one ``os.rename``/``os.replace`` publishes it — POSIX renames
   within a directory are atomic, so a crash at ANY byte of the write leaves
   either the old complete artifact or the new complete artifact, never a
   torn one;
3. **scan**: readers recognise an artifact as *complete* only when its
   manifest file exists (the manifest is the last thing staged before the
   rename), and restore from the NEWEST complete one — half-staged ``.tmp``
   litter from a crash is invisible to them and swept opportunistically;
4. **retain**: bounded retention deletes the oldest complete artifacts
   beyond ``keep``, never the newest.

Names carry their ordering: ``<prefix><int>[_<int>...]`` with zero-padded
fields, so "newest" is the lexicographic/tuple max of the parsed integer
key (checkpoints order by step; snapshots by (epoch, wal_seq)).

Extracted from ``runtime/checkpoint.py`` (which now calls back into this
module) so the durability plane and the training stack share one audited
implementation of the crash-safety contract.
"""
from __future__ import annotations

import os
import shutil
import uuid
from pathlib import Path
from typing import Callable, List, Optional, Tuple

__all__ = [
    "stage_and_rename",
    "replace_file",
    "fsync_dir",
    "parse_key",
    "complete_entries",
    "latest_complete",
    "retain",
    "sweep_stale_tmp",
]

_TMP_MARK = ".tmp."
_OLD_MARK = ".old."


def fsync_dir(path: Path) -> None:
    """fsync a DIRECTORY so a rename/unlink inside it is durable, not just
    ordered — the other half of the atomic-publish contract (a rename the
    parent never persisted can vanish at power loss even though the process
    saw it).  Best-effort on filesystems that refuse directory fds."""
    try:
        fd = os.open(path, os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def stage_and_rename(final: Path, write_fn: Callable[[Path], None]) -> Path:
    """Write an artifact directory atomically: stage via ``write_fn(tmp)``,
    then rename ``tmp`` -> ``final`` (replacing any previous ``final``).

    ``write_fn`` receives the empty staging directory and must write the
    manifest LAST — completeness is judged by the manifest's existence.
    On any exception the staging directory is removed and nothing at
    ``final`` changes.

    Durability ordering: every staged file is fsynced (then the staging
    dir, then — after the rename — the parent dir), so by the time a later
    operation can observe the artifact as published, its CONTENT is on
    stable storage too; a power cut never yields a "complete" manifest
    with torn payload, nor a durable follow-up (e.g. a WAL unlink) whose
    prerequisite snapshot evaporated.
    """
    final = Path(final)
    final.parent.mkdir(parents=True, exist_ok=True)
    tmp = final.parent / f"{_TMP_MARK}{uuid.uuid4().hex[:8]}.{final.name}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)
    try:
        write_fn(tmp)
        for p in sorted(tmp.rglob("*")):
            if p.is_file():
                with open(p, "rb") as f:
                    os.fsync(f.fileno())
        fsync_dir(tmp)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    backup = None
    if final.exists():
        # never rmtree-before-rename: a crash in between would leave
        # NEITHER artifact.  Rename the old one aside (atomic), publish,
        # then discard; ``sweep_stale_tmp`` repairs the tiny window where
        # only the ``.old.`` backup exists by renaming it back.
        backup = final.parent / f"{_OLD_MARK}{uuid.uuid4().hex[:8]}.{final.name}"
        os.rename(final, backup)
    os.rename(tmp, final)
    fsync_dir(final.parent)
    if backup is not None:
        shutil.rmtree(backup, ignore_errors=True)
    return final


def replace_file(path: Path, data: bytes) -> Path:
    """Atomically (re)write a single file: stage bytes in a sibling tmp
    file, fsync, ``os.replace`` into place."""
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    tmp = path.parent / f"{_TMP_MARK}{uuid.uuid4().hex[:8]}.{path.name}"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    fsync_dir(path.parent)
    return path


def parse_key(name: str, prefix: str) -> Optional[Tuple[int, ...]]:
    """``"epoch_00000002_000000000015"`` with prefix ``"epoch_"`` ->
    ``(2, 15)``; None when the name does not parse."""
    if not name.startswith(prefix):
        return None
    try:
        return tuple(int(part) for part in name[len(prefix):].split("_"))
    except ValueError:
        return None


def complete_entries(directory: Path, prefix: str,
                     manifest: str = "MANIFEST.json",
                     ) -> List[Tuple[Tuple[int, ...], Path]]:
    """All COMPLETE artifacts under ``directory`` matching ``prefix``,
    sorted oldest -> newest by parsed integer key.  Complete = the manifest
    file exists (the rename published it); ``.tmp.*`` staging litter never
    qualifies."""
    directory = Path(directory)
    if not directory.exists():
        return []
    out = []
    for p in directory.iterdir():
        if p.name.startswith(_TMP_MARK):
            continue
        key = parse_key(p.name, prefix)
        if key is not None and (p / manifest).exists():
            out.append((key, p))
    out.sort(key=lambda kp: kp[0])
    return out


def latest_complete(directory: Path, prefix: str,
                    manifest: str = "MANIFEST.json") -> Optional[Path]:
    """Path of the newest complete artifact, or None."""
    entries = complete_entries(directory, prefix, manifest)
    return entries[-1][1] if entries else None


def retain(directory: Path, prefix: str, keep: int,
           manifest: str = "MANIFEST.json") -> int:
    """Delete the oldest complete artifacts beyond ``keep``; returns how
    many were removed.  Incomplete artifacts are never counted or touched
    (``sweep_stale_tmp`` handles staging litter)."""
    entries = complete_entries(directory, prefix, manifest)
    doomed = entries[: max(len(entries) - keep, 0)]
    for _, p in doomed:
        shutil.rmtree(p, ignore_errors=True)
    return len(doomed)


def sweep_stale_tmp(directory: Path) -> int:
    """Repair and sweep crash litter; returns how many entries were
    handled.  ``.old.<nonce>.<name>`` backups (a publish died between its
    two renames) are renamed BACK to ``<name>`` when nothing was published
    there — restoring the displaced complete artifact — and deleted when
    the publish did land.  ``.tmp.*`` staging litter is removed.  Safe any
    time recovery owns the directory: a live stage uses a fresh nonce and
    renames away before anyone else can observe it."""
    directory = Path(directory)
    if not directory.exists():
        return 0
    n = 0
    for p in list(directory.iterdir()):
        if p.name.startswith(_OLD_MARK):
            original = directory / p.name[len(_OLD_MARK) + 9:]  # strip nonce.
            if original.exists():
                shutil.rmtree(p, ignore_errors=True)
            else:
                os.rename(p, original)
            n += 1
    for p in list(directory.iterdir()):
        if p.name.startswith(_TMP_MARK):
            if p.is_dir():
                shutil.rmtree(p, ignore_errors=True)
            else:
                p.unlink(missing_ok=True)
            n += 1
    return n
