"""Versioned on-disk snapshots of a ``COAXIndex`` epoch (DESIGN.md §7.3).

Layout — one directory per snapshot, published atomically
(``storage.atomic``):

    <dir>/epoch_<epoch:08d>_<wal_seq:012d>/
        manifest.json       # format version, structure, scalars, config
        arrays.npz          # every array payload, exact dtypes

``wal_seq`` is the number of WAL records already FOLDED INTO the snapshot:
an epoch snapshot written at build/compaction carries ``wal_seq=0`` (the
epoch's WAL is empty or freshly rotated); a mid-epoch checkpoint
(``Durability.checkpoint``) carries the journal position, so restore
replays only the records the snapshot has not absorbed.  "Newest" orders
by ``(epoch, wal_seq)`` — exactly the prefix-of-history ordering.

Scalar floats (config knobs, FD model slopes/margins) live in the JSON
manifest: ``json`` emits ``repr``-shortest floats, which round-trip IEEE
float64 exactly, so nothing about the restored index is approximate.
Array payloads keep their dtypes through ``np.savez``.

The snapshot captures the FULL index state — epoch arrays in their exact
order (the order feeds compaction's sampling rng, so it is part of the
bit-identity contract), both grid directories, soft-FD groups and margins,
outlier bboxes, the live delta planes and the Bayesian drift trackers'
sufficient statistics (``COAXIndex._snapshot_state``).  Restoring is pure
deserialisation: no re-sort, no re-quantile, no relearn (§7.3 warm-restart
argument).
"""
from __future__ import annotations

import dataclasses
import json
import time
from pathlib import Path
from typing import Optional, Tuple, Union

import numpy as np

from ..core import COAXIndex, CoaxConfig, SoftFDConfig
from ..core.types import FDGroup, LinearModel
from . import atomic

__all__ = ["SNAPSHOT_PREFIX", "MANIFEST_NAME", "FORMAT_VERSION",
           "snapshot_name", "write_snapshot", "load_snapshot",
           "latest_snapshot", "read_manifest", "snapshot_nbytes"]

SNAPSHOT_PREFIX = "epoch_"
MANIFEST_NAME = "manifest.json"
FORMAT_VERSION = 1


def snapshot_name(epoch: int, wal_seq: int) -> str:
    return f"{SNAPSHOT_PREFIX}{epoch:08d}_{wal_seq:012d}"


def _config_to_doc(cfg: CoaxConfig) -> dict:
    return dataclasses.asdict(cfg)


def _config_from_doc(doc: dict) -> CoaxConfig:
    soft = SoftFDConfig(**doc.pop("softfd"))
    return CoaxConfig(softfd=soft, **doc)


def _grid_meta(meta: dict) -> dict:
    return {k: (list(v) if isinstance(v, (list, tuple)) else v)
            for k, v in meta.items()}


def pack_state(state: dict) -> Tuple[dict, dict]:
    """``COAXIndex._snapshot_state`` -> (manifest doc, npz array dict)."""
    groups = state["groups"]
    keys = [(gi, dep) for gi, g in enumerate(groups) for dep in g.dependents]
    fd_models = (np.asarray(
        [[groups[gi].models[dep].m, groups[gi].models[dep].b,
          groups[gi].models[dep].eps_lb, groups[gi].models[dep].eps_ub]
         for gi, dep in keys], np.float64)
        if keys else np.empty((0, 4)))
    has_bbox = state["outlier_lo"] is not None
    arrays = {
        "data": state["data"],
        "row_ids": state["row_ids"],
        "p__rows": state["primary"]["rows"],
        "p__row_ids": state["primary"]["row_ids"],
        "p__offsets": state["primary"]["offsets"],
        "p__edges": state["primary"]["inner_edges"],
        "o__rows": state["outlier"]["rows"],
        "o__row_ids": state["outlier"]["row_ids"],
        "o__offsets": state["outlier"]["offsets"],
        "o__edges": state["outlier"]["inner_edges"],
        "dp__rows": state["delta_primary"]["rows"],
        "dp__ids": state["delta_primary"]["ids"],
        "dp__dead": state["delta_primary"]["dead"],
        "do__rows": state["delta_outlier"]["rows"],
        "do__ids": state["delta_outlier"]["ids"],
        "do__dead": state["delta_outlier"]["dead"],
        "fd_models": fd_models,
        "tracker_xtx": state["tracker_xtx"],
        "tracker_xty": state["tracker_xty"],
        "tracker_lam": state["tracker_lam"],
        "x_scale": state["x_scale"],
    }
    if has_bbox:
        arrays["outlier_lo"] = state["outlier_lo"]
        arrays["outlier_hi"] = state["outlier_hi"]
    manifest = {
        "format": "coax-snapshot",
        "version": FORMAT_VERSION,
        "kind": "coax",
        "time": time.time(),
        "epoch": int(state["epoch"]),
        "wal_seq": 0,                     # overwritten by write_snapshot
        "compactions": int(state["compactions"]),
        "next_id": int(state["next_id"]),
        "primary_ratio": float(state["primary_ratio"]),
        "n_dims": int(state["data"].shape[1]),
        "base_rows": int(state["data"].shape[0]),
        "has_outlier_bbox": has_bbox,
        "config": _config_to_doc(state["config"]),
        "groups": [{"predictor": int(g.predictor),
                    "dependents": [int(d) for d in g.dependents]}
                   for g in groups],
        "primary_meta": _grid_meta(state["primary"]["meta"]),
        "outlier_meta": _grid_meta(state["outlier"]["meta"]),
        "delta": {
            "primary": {"n_log_dead": int(state["delta_primary"]["n_log_dead"]),
                        "n_base_dead": int(state["delta_primary"]["n_base_dead"]),
                        "organized": int(state["delta_primary"].get("organized", 0))},
            "outlier": {"n_log_dead": int(state["delta_outlier"]["n_log_dead"]),
                        "n_base_dead": int(state["delta_outlier"]["n_base_dead"]),
                        "organized": int(state["delta_outlier"].get("organized", 0))},
        },
        # amortized-trigger counters (DESIGN.md §5.3): check timing is part
        # of the §7.3 bit-identity contract, so it must survive restore
        "write_units": int(state.get("write_units", 0)),
        "spill_pending": bool(state.get("spill_pending", False)),
        "trigger_checks": int(state.get("trigger_checks", 0)),
        # violation-mass counters: the contamination side of the drift gate
        "viol_total": [int(v) for v in state.get("viol_total", [])],
        "viol_bad": [int(v) for v in state.get("viol_bad", [])],
    }
    return manifest, arrays


def unpack_state(manifest: dict, arrays: dict) -> dict:
    """(manifest, npz arrays) -> the dict ``COAXIndex._restore_state`` eats."""
    if manifest.get("format") != "coax-snapshot":
        raise ValueError("not a coax snapshot manifest")
    if manifest.get("version") != FORMAT_VERSION:
        raise ValueError(f"snapshot format v{manifest.get('version')} "
                         f"unsupported (reader is v{FORMAT_VERSION})")
    fd_models = np.asarray(arrays["fd_models"], np.float64)
    groups = []
    i = 0
    for gdoc in manifest["groups"]:
        deps = tuple(int(d) for d in gdoc["dependents"])
        models = {}
        for dep in deps:
            m, b, lb, ub = fd_models[i]
            models[dep] = LinearModel(m=float(m), b=float(b),
                                      eps_lb=float(lb), eps_ub=float(ub))
            i += 1
        groups.append(FDGroup(predictor=int(gdoc["predictor"]),
                              dependents=deps, models=models))

    def grid(prefix: str, meta: dict) -> dict:
        return {"rows": arrays[f"{prefix}__rows"],
                "row_ids": arrays[f"{prefix}__row_ids"],
                "offsets": arrays[f"{prefix}__offsets"],
                "inner_edges": arrays[f"{prefix}__edges"],
                "meta": meta}

    def delta(prefix: str, counters: dict) -> dict:
        return {"rows": arrays[f"{prefix}__rows"],
                "ids": arrays[f"{prefix}__ids"],
                "dead": arrays[f"{prefix}__dead"],
                "n_log_dead": counters["n_log_dead"],
                "n_base_dead": counters["n_base_dead"],
                # pre-LSM snapshots: fully-unorganized log (L0 only)
                "organized": counters.get("organized", 0)}

    has_bbox = manifest["has_outlier_bbox"]
    return {
        "data": arrays["data"],
        "row_ids": arrays["row_ids"],
        "next_id": manifest["next_id"],
        "epoch": manifest["epoch"],
        "compactions": manifest["compactions"],
        "primary_ratio": manifest["primary_ratio"],
        "config": _config_from_doc(dict(manifest["config"])),
        "groups": groups,
        "primary": grid("p", manifest["primary_meta"]),
        "outlier": grid("o", manifest["outlier_meta"]),
        "outlier_lo": arrays["outlier_lo"] if has_bbox else None,
        "outlier_hi": arrays["outlier_hi"] if has_bbox else None,
        "delta_primary": delta("dp", manifest["delta"]["primary"]),
        "delta_outlier": delta("do", manifest["delta"]["outlier"]),
        "tracker_xtx": arrays["tracker_xtx"],
        "tracker_xty": arrays["tracker_xty"],
        "tracker_lam": arrays["tracker_lam"],
        "x_scale": arrays["x_scale"],
        "write_units": manifest.get("write_units", 0),
        "spill_pending": manifest.get("spill_pending", False),
        "trigger_checks": manifest.get("trigger_checks", 0),
        "viol_total": np.asarray(manifest.get("viol_total", []), np.int64),
        "viol_bad": np.asarray(manifest.get("viol_bad", []), np.int64),
    }


# --------------------------------------------------------------------- #
def write_snapshot(index: COAXIndex, directory: Union[str, Path],
                   wal_seq: int = 0, keep: Optional[int] = None) -> Path:
    """Atomically publish a full-state snapshot of ``index`` under
    ``directory``; ``wal_seq`` stamps how many WAL records the state
    already contains.  ``keep`` (None = unbounded) prunes the oldest
    complete snapshots beyond that count."""
    manifest, arrays = pack_state(index._snapshot_state())
    manifest["wal_seq"] = int(wal_seq)
    directory = Path(directory)

    def stage(tmp: Path) -> None:
        np.savez(tmp / "arrays.npz", **arrays)
        # manifest last: its presence is the completeness marker (§7.1)
        (tmp / MANIFEST_NAME).write_text(json.dumps(manifest, indent=2))

    final = atomic.stage_and_rename(
        directory / snapshot_name(index.epoch, int(wal_seq)), stage)
    if keep is not None:
        atomic.retain(directory, SNAPSHOT_PREFIX, keep, MANIFEST_NAME)
    return final


def latest_snapshot(directory: Union[str, Path]) -> Optional[Path]:
    """Newest COMPLETE snapshot directory by (epoch, wal_seq), or None.
    Half-staged ``.tmp.*`` litter and manifest-less directories never
    qualify (the §7.1 completeness scan)."""
    return atomic.latest_complete(Path(directory), SNAPSHOT_PREFIX,
                                  MANIFEST_NAME)


def read_manifest(snapshot_path: Union[str, Path]) -> dict:
    return json.loads((Path(snapshot_path) / MANIFEST_NAME).read_text())


def load_snapshot(snapshot_path: Union[str, Path], backend: str = "numpy",
                  device_opts: Optional[dict] = None,
                  ) -> Tuple[COAXIndex, dict]:
    """Deserialise one snapshot directory -> (index, manifest).  The WAL
    tail, if any, is the caller's job (``storage.restore``)."""
    snapshot_path = Path(snapshot_path)
    manifest = read_manifest(snapshot_path)
    with np.load(snapshot_path / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    state = unpack_state(manifest, arrays)
    return COAXIndex._restore_state(state, backend=backend,
                                    device_opts=device_opts), manifest


def snapshot_nbytes(snapshot_path: Union[str, Path]) -> int:
    """Total on-disk bytes of one snapshot directory."""
    return sum(p.stat().st_size for p in Path(snapshot_path).iterdir())
