"""Durability plane: versioned snapshots + write-ahead log + bit-identical
crash recovery (DESIGN.md §7).

The memory-only serving planes (batched engine §2, device backend §4,
delta/compaction lifecycle §5, sharded scatter-gather §6) all die with the
process; this package makes them restartable:

``atomic``      — the repo-wide staged-rename / newest-complete-manifest /
                  bounded-retention idiom (§7.1; shared with
                  ``runtime.checkpoint``)
``snapshot``    — versioned ``manifest.json`` + ``arrays.npz`` serialisation
                  of a full ``COAXIndex`` state (§7.3)
``wal``         — framed, epoch-stamped, torn-tail-tolerant write-ahead log
                  (§7.2)
``durability``  — the plane itself: attach/rotate/checkpoint/sync, sharded
                  layout, and ``restore`` = snapshot + WAL replay ≡ the
                  never-crashed index, bit for bit (§7.4)

Everything here is numpy + stdlib — no jax in the import path, so a
restored index serves from the numpy backend anywhere and lazily builds
device plans where jax exists (cold-start replicas warm-loading a snapshot
into a ``DevicePlan``).
"""
from . import atomic
from .snapshot import (latest_snapshot, load_snapshot, read_manifest,
                       snapshot_nbytes, write_snapshot)
from .wal import (WalFrameCursor, WalRecord, WriteAheadLog, decode_record,
                  read_wal, wal_path)
from .durability import Durability, ShardedDurability, restore

__all__ = [
    "atomic",
    "write_snapshot",
    "load_snapshot",
    "latest_snapshot",
    "read_manifest",
    "snapshot_nbytes",
    "WriteAheadLog",
    "WalFrameCursor",
    "WalRecord",
    "decode_record",
    "read_wal",
    "wal_path",
    "Durability",
    "ShardedDurability",
    "restore",
]
