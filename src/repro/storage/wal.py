"""Write-ahead log: framed, epoch-stamped, torn-tail tolerant (DESIGN.md §7.2).

One WAL file exists per snapshot epoch (``wal_<epoch>.log``) and records the
index's write stream SINCE that epoch, in arrival order:

    file   := header record*
    header := magic "CWH1" | u32 format version | u64 epoch
    record := magic "CWR1" | u64 seq | u8 kind | u32 payload_len
              | u32 crc32(payload) | payload
    insert payload := u32 n_rows | u32 n_dims | rows f32[n*d] | ids i64[n]
    delete payload := u32 n_ids  | ids i64[n]

All integers little-endian.  Rows are logged as the exact float32 bytes the
in-memory path stores, and insert records carry the ASSIGNED ids, so replay
through the ordinary ``COAXIndex.insert(rows, ids=...)`` / ``delete`` paths
reproduces the live index bit for bit — including the Bayesian drift
trackers, because one record per ``insert()`` call preserves the exact
batch boundaries and arrival order the tracker accumulations folded in
(DESIGN.md §7.4 recovery ≡ replay argument).

Failure contract: appends go straight to the OS (``write``+``flush``) but
are NOT fsynced per record; ``sync()`` fsyncs and is called by
``QueryServer`` at wave boundaries — so the durable frontier advances in
the same per-wave steps as the server's snapshot semantics (§7.2 fsync
contract), and ``pending_bytes`` is exactly the at-risk tail.  The reader
treats ANY malformed tail — truncated header, short payload, CRC or magic
or sequence mismatch — as a torn write: replay stops at the last intact
record and ``Durability`` truncates the torn bytes before appending again.

Replication hooks (DESIGN.md §8): the WAL is also the replication log.
``observer`` — when set — sees every appended record ``(epoch, seq, kind,
payload)`` at append time (the push-shipping hook), and ``WalFrameCursor``
reads a WAL file's records incrementally from a sequence position (the
pull/catch-up path): an incomplete tail merely pauses the cursor — the
bytes may still be in flight from a concurrent appender — so re-reading
later resumes where it stopped, while genuinely torn bytes pause it
forever at the last intact record, exactly like ``read_wal``.
"""
from __future__ import annotations

import dataclasses
import os
import struct
import time
import zlib
from pathlib import Path
from typing import Iterator, List, Optional, Tuple, Union

import numpy as np

from .. import obs

__all__ = ["WalRecord", "WriteAheadLog", "WalFrameCursor", "read_wal",
           "wal_path", "decode_record", "OP_INSERT", "OP_DELETE"]

_FILE_MAGIC = b"CWH1"
_REC_MAGIC = b"CWR1"
_FORMAT_VERSION = 1
_FILE_HDR = struct.Struct("<4sIQ")      # magic, version, epoch
_REC_HDR = struct.Struct("<4sQBII")     # magic, seq, kind, payload_len, crc

OP_INSERT = 1
OP_DELETE = 2


def wal_path(directory: Union[str, Path], epoch: int) -> Path:
    return Path(directory) / f"wal_{epoch:08d}.log"


@dataclasses.dataclass(frozen=True)
class WalRecord:
    """One decoded write op.  ``rows`` is None for deletes."""
    seq: int
    kind: int
    rows: Optional[np.ndarray]      # (n, d) float32, insert only
    ids: np.ndarray                 # (n,) int64: assigned (insert) or requested (delete)


def _encode_insert(rows: np.ndarray, ids: np.ndarray) -> bytes:
    rows = np.ascontiguousarray(rows, dtype=np.float32)
    ids = np.ascontiguousarray(ids, dtype=np.int64)
    return (struct.pack("<II", rows.shape[0], rows.shape[1])
            + rows.tobytes() + ids.tobytes())


def _encode_delete(ids: np.ndarray) -> bytes:
    ids = np.ascontiguousarray(ids, dtype=np.int64)
    return struct.pack("<I", ids.shape[0]) + ids.tobytes()


def _decode(kind: int, payload: bytes) -> Tuple[Optional[np.ndarray], np.ndarray]:
    if kind == OP_INSERT:
        n, d = struct.unpack_from("<II", payload, 0)
        off = 8
        rows = np.frombuffer(payload, np.float32, n * d, off).reshape(n, d)
        ids = np.frombuffer(payload, np.int64, n, off + n * d * 4)
        return rows.copy(), ids.copy()
    if kind == OP_DELETE:
        (n,) = struct.unpack_from("<I", payload, 0)
        return None, np.frombuffer(payload, np.int64, n, 4).copy()
    raise ValueError(f"unknown WAL op kind {kind}")


def decode_record(kind: int, payload: bytes) -> Tuple[Optional[np.ndarray],
                                                      np.ndarray]:
    """Decode one record payload -> ``(rows, ids)`` (rows None for deletes).
    The public face of the record codec — replicas shipping raw WAL frames
    (DESIGN.md §8) decode them with exactly the appender's arithmetic."""
    return _decode(kind, payload)


class WriteAheadLog:
    """Appender for one epoch's WAL file.

    Opens in append mode, creating the file (with its epoch-stamped header)
    when absent.  ``start_seq`` must be the sequence number of the next
    record — callers opening an existing file pass the count of intact
    records already in it (``read_wal``'s ``next_seq``), after truncating
    any torn tail to ``intact_bytes``.
    """

    def __init__(self, path: Union[str, Path], epoch: int, start_seq: int = 0):
        self.path = Path(path)
        self.epoch = int(epoch)
        self.next_seq = int(start_seq)
        self.pending_bytes = 0          # appended since the last fsync
        self.pending_records = 0
        self.observer = None            # callable(epoch, seq, kind, payload)
        fresh = not self.path.exists()
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._f = open(self.path, "ab")
        if fresh or self._f.tell() == 0:
            self._f.write(_FILE_HDR.pack(_FILE_MAGIC, _FORMAT_VERSION, self.epoch))
            self._f.flush()

    # ------------------------------------------------------------------ #
    def _append(self, kind: int, payload: bytes) -> int:
        hdr = _REC_HDR.pack(_REC_MAGIC, self.next_seq, kind, len(payload),
                            zlib.crc32(payload) & 0xFFFFFFFF)
        with obs.span("wal.append", seq=self.next_seq, kind=kind,
                      nbytes=len(hdr) + len(payload)):
            self._f.write(hdr)
            self._f.write(payload)
            self._f.flush()             # reaches the OS; fsync is sync()'s job
        self.next_seq += 1
        self.pending_bytes += len(hdr) + len(payload)
        self.pending_records += 1
        g = obs.get_registry()
        g.counter("coax_wal_appends_total", "WAL records appended").inc()
        g.counter("coax_wal_bytes_total", "WAL bytes appended").inc(
            len(hdr) + len(payload))
        if self.observer is not None:   # ship AFTER the journal has the record
            self.observer(self.epoch, self.next_seq - 1, kind, payload)
        return self.next_seq - 1

    def append_insert(self, rows: np.ndarray, ids: np.ndarray) -> int:
        """Log one ``insert()`` call (rows with their assigned ids); returns
        the record's sequence number."""
        return self._append(OP_INSERT, _encode_insert(rows, ids))

    def append_delete(self, ids: np.ndarray) -> int:
        """Log one ``delete()`` call (the requested ids, verbatim)."""
        return self._append(OP_DELETE, _encode_delete(ids))

    def sync(self) -> None:
        """fsync the appended tail — the per-wave durability point.  Safe on
        an already-closed handle: a closed file has either synced its tail
        (orderly ``close``) or lost the handle to a failed rotation — both
        cases where raising from a cleanup path helps nobody."""
        if self.pending_bytes and not self._f.closed:
            t0 = time.perf_counter()
            with obs.span("wal.fsync", nbytes=self.pending_bytes):
                self._f.flush()
                os.fsync(self._f.fileno())
            g = obs.get_registry()
            g.counter("coax_wal_fsync_total", "WAL fsyncs").inc()
            g.histogram("coax_wal_fsync_seconds",
                        "WAL tail fsync latency").observe(
                            time.perf_counter() - t0)
            obs.stage_hist().observe(time.perf_counter() - t0,
                                     stage="fsync", backend="numpy")
            self.pending_bytes = 0
            self.pending_records = 0

    def nbytes(self) -> int:
        """Total WAL bytes on disk (header + records appended so far)."""
        return self.path.stat().st_size if self._f.closed else self._f.tell()

    @property
    def closed(self) -> bool:
        return self._f.closed

    def close(self) -> None:
        """fsync the tail, then close.  Idempotent: double-close (and close
        after a failed rotation left the handle dead) is a no-op."""
        if not self._f.closed:
            self.sync()
            self._f.close()


def read_wal(path: Union[str, Path],
             expect_epoch: Optional[int] = None,
             ) -> Tuple[List[WalRecord], int, int]:
    """Decode every intact record of a WAL file.

    Returns ``(records, next_seq, intact_bytes)``: the complete-prefix
    records, the sequence number an appender should continue from, and the
    byte offset of the first torn/garbage byte (== file size when the file
    is clean).  A missing file reads as empty at epoch ``expect_epoch``.
    Raises only on a wrong FILE header (wrong epoch or magic) — that is a
    wiring bug, not a crash artifact; everything after a valid header
    degrades gracefully to "torn tail".
    """
    path = Path(path)
    if not path.exists():
        return [], 0, 0
    blob = path.read_bytes()
    if len(blob) < _FILE_HDR.size:
        return [], 0, 0                 # torn before the header completed
    magic, version, epoch = _FILE_HDR.unpack_from(blob, 0)
    if magic != _FILE_MAGIC or version != _FORMAT_VERSION:
        raise ValueError(f"{path} is not a v{_FORMAT_VERSION} WAL file")
    if expect_epoch is not None and epoch != expect_epoch:
        raise ValueError(f"{path} holds epoch {epoch}, expected {expect_epoch}")

    records: List[WalRecord] = []
    off = _FILE_HDR.size
    intact = off
    while off + _REC_HDR.size <= len(blob):
        rmagic, seq, kind, plen, crc = _REC_HDR.unpack_from(blob, off)
        end = off + _REC_HDR.size + plen
        if (rmagic != _REC_MAGIC or seq != len(records)
                or end > len(blob)):
            break                       # torn or foreign bytes: stop here
        payload = blob[off + _REC_HDR.size:end]
        if zlib.crc32(payload) & 0xFFFFFFFF != crc:
            break
        try:
            rows, ids = _decode(kind, payload)
        except (ValueError, struct.error):
            break
        records.append(WalRecord(seq=seq, kind=kind, rows=rows, ids=ids))
        off = end
        intact = off
    return records, len(records), intact


class WalFrameCursor:
    """Incremental reader of one WAL file's records from a seq position —
    the replica catch-up path (DESIGN.md §8.2): the primary's WAL doubles
    as the retransmission buffer, so a replica that lost shipped frames
    pulls the gap straight out of the journal.

    ``read()`` returns every intact ``(seq, kind, payload)`` appended since
    the last call.  The cursor keeps a byte offset and only ever advances
    past COMPLETE records, so a trailing partial record — an append still
    in flight from a live primary, or a genuinely torn crash tail — just
    pauses it: the next ``read()`` re-examines the same bytes and resumes
    if the record completed.  Foreign bytes / CRC mismatch / seq mismatch
    pause it the same way (and stay paused forever), matching ``read_wal``'s
    torn-tail contract.  A missing file reads as empty.
    """

    def __init__(self, path: Union[str, Path], expect_epoch: Optional[int] = None,
                 start_seq: int = 0):
        self.path = Path(path)
        self.expect_epoch = expect_epoch
        self.next_seq = int(start_seq)
        self._offset: Optional[int] = None    # None until the header is read
        self._skip_seq = int(start_seq)       # records to skip before start_seq

    def read(self, max_records: Optional[int] = None
             ) -> List[Tuple[int, int, bytes]]:
        """Intact ``(seq, kind, payload)`` frames available past the cursor."""
        if not self.path.exists():
            return []
        blob = self.path.read_bytes()
        if self._offset is None:
            if len(blob) < _FILE_HDR.size:
                return []                     # header still incomplete
            magic, version, epoch = _FILE_HDR.unpack_from(blob, 0)
            if magic != _FILE_MAGIC or version != _FORMAT_VERSION:
                raise ValueError(f"{self.path} is not a v{_FORMAT_VERSION} WAL file")
            if self.expect_epoch is not None and epoch != self.expect_epoch:
                raise ValueError(f"{self.path} holds epoch {epoch}, "
                                 f"expected {self.expect_epoch}")
            self._offset = _FILE_HDR.size
            self._seen = 0                    # records parsed from the top
        out: List[Tuple[int, int, bytes]] = []
        off = self._offset
        while off + _REC_HDR.size <= len(blob):
            if max_records is not None and len(out) >= max_records:
                break
            rmagic, seq, kind, plen, crc = _REC_HDR.unpack_from(blob, off)
            end = off + _REC_HDR.size + plen
            if rmagic != _REC_MAGIC or seq != self._seen or end > len(blob):
                break                         # torn / in-flight / foreign tail
            payload = blob[off + _REC_HDR.size:end]
            if zlib.crc32(payload) & 0xFFFFFFFF != crc:
                break
            try:
                _decode(kind, payload)        # validate before advancing
            except (ValueError, struct.error):
                break
            if seq >= self._skip_seq:
                out.append((seq, kind, payload))
                self.next_seq = seq + 1
            self._seen += 1
            off = end
            self._offset = off
        return out
