"""The durability plane: journaled writes + crash recovery (DESIGN.md §7).

``Durability`` hooks one ``COAXIndex``'s write path to disk:

* ``log_insert``/``log_delete`` append one WAL frame per write call BEFORE
  the in-memory apply (``storage.wal`` framing), so on-disk state is always
  ``newest complete snapshot + WAL tail`` — a prefix of the live history;
* ``on_compact`` is the §7.5 truncation point: compaction already bumped
  the epoch and emptied the delta planes, so the plane publishes a fresh
  epoch snapshot (atomic, §7.1), opens the new epoch's WAL and only then
  deletes older WAL files — every crash window leaves a recoverable pair;
* ``handoff_rotate`` is the same truncation point for a BACKGROUND
  compaction (epoch handoff, DESIGN.md §5.4): the writes admitted during
  the build are re-journaled into the new epoch's WAL and fsynced BEFORE
  the new snapshot is published, so a crash in any window recovers either
  from the old pair (whose WAL still holds the trigger record + tail —
  replay re-fires the compaction deterministically) or from the new pair;
* ``checkpoint`` publishes a mid-epoch full-state snapshot stamped with the
  journal position (``wal_seq``), bounding replay cost without touching the
  WAL file;
* ``sync`` fsyncs the WAL tail — called by ``QueryServer`` at wave
  boundaries (§7.2 fsync contract).

``restore`` rebuilds an index from a durability directory: load the newest
complete snapshot, replay the WAL records it has not absorbed through the
ORDINARY ``insert``/``delete`` paths (identical arithmetic, identical
tracker accumulation order — the §7.4 recovery ≡ replay argument), and
optionally re-attach the plane so journaling continues where the crashed
process stopped.  If a replayed record trips the compaction trigger —
possible only when the crash hit the rotation window — the attached plane
rotates exactly as the live index would have, converging disk and memory.

Sharded layout (``ShardedDurability``): a ``spec.json`` partitioner spec
(atomic single-file replace) plus one independent per-shard durability
directory — each shard journals and rotates on its own epochs (§6 shard
locality), and the global id high-water mark is recovered as the max of
the spec's checkpointed value and every shard's restored ``_next_id``.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path
from typing import List, Optional, Union

import numpy as np

from .. import obs
from ..core import COAXIndex
from . import atomic
from .snapshot import (MANIFEST_NAME, SNAPSHOT_PREFIX, latest_snapshot,
                       load_snapshot, read_manifest, snapshot_nbytes,
                       write_snapshot)
from .wal import WriteAheadLog, OP_INSERT, read_wal, wal_path

__all__ = ["Durability", "ShardedDurability", "restore", "SPEC_NAME"]

SPEC_NAME = "spec.json"


def _wal_files(directory: Path) -> List[Path]:
    return sorted(Path(directory).glob("wal_*.log"))


class Durability:
    """Journal + snapshot series for one ``COAXIndex``.

    Build via ``Durability.attach`` (fresh directory) or implicitly through
    ``storage.restore(..., durable=True)`` (crash recovery).  The plane
    holds a reference to its index (``checkpoint`` snapshots it) and the
    index holds ``self`` as ``.durable`` — attached means journaling.
    """

    def __init__(self, index: COAXIndex, directory: Union[str, Path],
                 keep: int = 3, sync_every_op: bool = False):
        self.index = index
        self.directory = Path(directory)
        self.keep = int(keep)
        self.sync_every_op = bool(sync_every_op)
        self.wal: Optional[WriteAheadLog] = None
        self._suppress_append = False    # True only while replaying (§7.4)
        self._replaying = False          # defers rotation disk work (§7.5)
        self._suppress_ship = False      # True while re-journaling the §5.4
                                         # handoff tail (replicas pull it
                                         # via catch-up fetch instead, §8.4)
        self.last_snapshot_path: Optional[Path] = None
        self.last_snapshot_wal_seq = 0
        self.last_snapshot_bytes = 0
        # Replication hooks (DESIGN.md §8): a ReplicationHub subscribes by
        # setting these.  frame_observer(epoch, seq, kind, payload) fires on
        # every journaled record; rotate_observer(old_epoch, old_final_seq,
        # new_epoch, relearned) fires inside the §7.5 rotation, after the
        # new epoch's snapshot+WAL are published and before old WALs die.
        self.frame_observer = None
        self.rotate_observer = None

    def _open_wal(self, path: Path, epoch: int,
                  start_seq: int = 0) -> WriteAheadLog:
        """Every WAL this plane appends to routes records through
        ``_frame_appended`` so a subscribed shipper sees rotations and
        replays transparently (replayed records are NOT re-shipped — the
        replica protocol reseeds instead, §8.4)."""
        wal = WriteAheadLog(path, epoch, start_seq=start_seq)
        wal.observer = self._frame_appended
        return wal

    def _frame_appended(self, epoch: int, seq: int, kind: int,
                        payload: bytes) -> None:
        if (self.frame_observer is not None and not self._replaying
                and not self._suppress_ship):
            self.frame_observer(epoch, seq, kind, payload)

    # ------------------------------------------------------------------ #
    @classmethod
    def attach(cls, index: COAXIndex, directory: Union[str, Path],
               keep: int = 3, sync_every_op: bool = False) -> "Durability":
        """Start journaling ``index`` under a fresh (or snapshot-only)
        directory: publish a full-state snapshot of the CURRENT state at
        journal position 0 and open the epoch's WAL.  A directory that
        already holds journal records belongs to ``storage.restore`` —
        attaching over it would fork history, so it is refused."""
        directory = Path(directory)
        wal_file = wal_path(directory, index.epoch)
        if wal_file.exists():
            records, _, intact = read_wal(wal_file, expect_epoch=index.epoch)
            if records:
                raise ValueError(
                    f"{wal_file} already holds {len(records)} journal "
                    f"records; use storage.restore(durable=True) instead "
                    f"of re-attaching over live history")
            if intact < wal_file.stat().st_size:
                # recordless torn tail (a first append died mid-write):
                # cut it, or everything appended after it is unreadable
                os.truncate(wal_file, intact)
        entries = atomic.complete_entries(directory, SNAPSHOT_PREFIX,
                                          MANIFEST_NAME)
        if entries and entries[-1][0] > (index.epoch, 0):
            # a newer-keyed snapshot would shadow everything we write
            raise ValueError(
                f"{directory} already holds snapshot "
                f"{entries[-1][1].name}, newer than this index's "
                f"(epoch={index.epoch}, wal_seq=0); restore from it or "
                f"attach to a fresh directory")
        dur = cls(index, directory, keep=keep, sync_every_op=sync_every_op)
        dur._record_snapshot(write_snapshot(index, directory, wal_seq=0,
                                            keep=keep), 0)
        dur.wal = dur._open_wal(wal_file, index.epoch, start_seq=0)
        index.durable = dur
        return dur

    def _record_snapshot(self, path: Path, wal_seq: int) -> None:
        self.last_snapshot_path = path
        self.last_snapshot_wal_seq = int(wal_seq)
        self.last_snapshot_bytes = snapshot_nbytes(path)

    # ------------------------------------------------------------------ #
    # Write-path hooks (called by COAXIndex.insert/delete/compact)
    # ------------------------------------------------------------------ #
    def log_insert(self, rows: np.ndarray, ids: np.ndarray) -> None:
        if self._suppress_append:
            return
        self.wal.append_insert(rows, ids)
        if self.sync_every_op:
            self.wal.sync()

    def log_delete(self, ids: np.ndarray) -> None:
        if self._suppress_append:
            return
        self.wal.append_delete(ids)
        if self.sync_every_op:
            self.wal.sync()

    def on_compact(self, index: COAXIndex) -> None:
        """Rotate at the compaction boundary (§7.5).  Ordering is the crash
        contract: (1) publish the new epoch snapshot — from here recovery
        prefers it and ignores older WALs; (2) open the new epoch's WAL;
        (3) only then delete older WAL files.  A crash before (1) replays
        the old pair and deterministically re-fires this compaction; a
        crash between any later pair leaves a complete (snapshot, WAL)
        prefix.

        Mid-REPLAY compactions do nothing here: the WAL being replayed is
        still the authoritative journal of every op, so rotating (and
        deleting it) before the tail is re-applied would strand fsynced
        ops in memory if recovery itself crashed.  ``finish_replay``
        republishes the rotated state in one crash-safe pass at the end."""
        if self._replaying:
            return
        with obs.span("wal.rotate", epoch=index.epoch, mode="sync"):
            self._record_snapshot(
                write_snapshot(index, self.directory, wal_seq=0,
                               keep=self.keep), 0)
            old = self.wal
            self.wal = self._open_wal(wal_path(self.directory, index.epoch),
                                      index.epoch, start_seq=0)
            if old is not None:
                old.close()
            if self.rotate_observer is not None:
                # mid-rotation ship point (§8.2): the new epoch pair is live
                # on disk, the old WALs are not yet deleted — a crash raised
                # from the observer models "primary died mid-rotation"
                self.rotate_observer(
                    old.epoch if old is not None else index.epoch - 1,
                    old.next_seq if old is not None else 0,
                    index.epoch,
                    bool(getattr(index, "_last_compact_relearned", False)))
            for p in _wal_files(self.directory):
                if p != self.wal.path:
                    p.unlink(missing_ok=True)
        obs.get_registry().counter(
            "coax_wal_rotations_total", "WAL epoch rotations.",
            ("mode",)).inc(mode="sync")

    def handoff_rotate(self, index: COAXIndex, replay_tail,
                       relearned: bool) -> None:
        """Rotate at a BACKGROUND-compaction handoff (DESIGN.md §5.4): the
        index has already installed the built epoch (empty deltas), but the
        writes admitted during the build still live only in the OLD WAL.
        Ordering is the crash contract:

        1. open the new epoch's WAL (unlinking torn leftovers of a crashed
           prior handoff);
        2. run ``replay_tail`` — the index re-applies the recorded tail
           through its ordinary write paths, which journals each op into
           the new WAL.  Frame shipping is suppressed for these records: a
           replica rotates at the trigger record it replayed itself and
           pulls the re-journaled tail via catch-up ``fetch`` (§8.4), so
           ``total_writes`` never double-counts the tail;
        3. fsync the new WAL, THEN publish the new epoch snapshot stamped
           past the tail — from here recovery prefers the new pair;
        4. only then delete older WAL files.

        A crash before (3)'s snapshot lands recovers from the old pair:
        its WAL still holds the trigger record and the full tail, replay
        re-fires this compaction deterministically (sync, §7.3) and
        ``finish_replay`` unlinks the partial new WAL.  Mid-replay
        handoffs cannot happen (replay forces synchronous compaction)."""
        if self._replaying:            # defensive: replay is sync-only
            return
        with obs.span("wal.rotate", epoch=index.epoch, mode="handoff"):
            old = self.wal
            fresh = wal_path(self.directory, index.epoch)
            fresh.unlink(missing_ok=True)  # torn leftovers of a crashed
            self.wal = self._open_wal(fresh, index.epoch, start_seq=0)
            self._suppress_ship = True
            try:
                replay_tail()
            finally:
                self._suppress_ship = False
            self.wal.sync()
            if old is not None:
                old.close()
            self._record_snapshot(
                write_snapshot(index, self.directory,
                               wal_seq=self.wal.next_seq, keep=self.keep),
                self.wal.next_seq)
            if self.rotate_observer is not None:
                # same mid-rotation ship point as ``on_compact`` (§8.2)
                self.rotate_observer(
                    old.epoch if old is not None else index.epoch - 1,
                    old.next_seq if old is not None else 0,
                    index.epoch, bool(relearned))
            for p in _wal_files(self.directory):
                if p != self.wal.path:
                    p.unlink(missing_ok=True)
        obs.get_registry().counter(
            "coax_wal_rotations_total", "WAL epoch rotations.",
            ("mode",)).inc(mode="handoff")

    def finish_replay(self, tail_records) -> None:
        """Deferred rotation after a replay that crossed >=1 compaction
        (§7.5): the replayed WAL stayed untouched throughout, so every
        crash inside replay was a pure retry.  Now converge disk to the
        replayed state: (1) write the current epoch's WAL fresh with the
        records applied AFTER the last compaction (fsynced); (2) publish a
        full-state snapshot stamped past them; (3) only then delete older
        WAL files.  A crash before (2) re-recovers from the old pair —
        deterministically reaching this same point — and a crash after (2)
        recovers from the new pair directly."""
        old = self.wal
        fresh = wal_path(self.directory, self.index.epoch)
        fresh.unlink(missing_ok=True)      # torn leftovers of a crashed pass
        self.wal = self._open_wal(fresh, self.index.epoch, start_seq=0)
        for rec in tail_records:
            if rec.kind == OP_INSERT:
                self.wal.append_insert(rec.rows, rec.ids)
            else:
                self.wal.append_delete(rec.ids)
        self.wal.sync()
        if old is not None:
            old.close()                    # superseded; deleted below
        self._record_snapshot(
            write_snapshot(self.index, self.directory,
                           wal_seq=self.wal.next_seq, keep=self.keep),
            self.wal.next_seq)
        for p in _wal_files(self.directory):
            if p != self.wal.path:
                p.unlink(missing_ok=True)

    # ------------------------------------------------------------------ #
    def sync(self) -> None:
        """fsync the WAL tail — the wave-boundary durability point."""
        if self.wal is not None:
            self.wal.sync()

    def checkpoint(self, keep: Optional[int] = None) -> Path:
        """Publish a mid-epoch full-state snapshot stamped with the current
        journal position; replay after a crash then starts at this op
        instead of the epoch's beginning.  The WAL file itself is never cut
        mid-epoch (truncation happens only at rotation, §7.5).  ``keep``
        overrides the attach-time retention for this one call (the
        ``save(directory, keep=...)`` path).

        An in-flight §5.4 background build is folded in first: a snapshot
        taken mid-build would otherwise become a restore base from which
        the build's deterministic re-fire diverges (the freeze set is
        already fixed, but the checkpoint would split the tail across the
        rotation boundary)."""
        fh = getattr(self.index, "finish_handoff", None)
        if fh is not None:
            fh()
        self.sync()
        seq = self.wal.next_seq
        if (keep is None and self.last_snapshot_path is not None
                and self.last_snapshot_wal_seq == seq
                and self.last_snapshot_path.exists()):
            return self.last_snapshot_path    # nothing new to absorb
        with obs.span("durability.checkpoint", wal_seq=seq):
            path = write_snapshot(self.index, self.directory, wal_seq=seq,
                                  keep=self.keep if keep is None else keep)
        obs.get_registry().counter(
            "coax_checkpoints_total", "Mid-epoch checkpoint snapshots.").inc()
        self._record_snapshot(path, seq)
        return path

    def close(self) -> None:
        """fsync the WAL tail and release the handle.  Idempotent: a second
        ``close()`` — or a close after a failed rotation left a dead handle
        behind — is a no-op instead of raising from cleanup
        (``WriteAheadLog.close`` carries the guard)."""
        if self.wal is not None:
            self.wal.close()

    @property
    def closed(self) -> bool:
        return self.wal is None or self.wal.closed

    # ------------------------------------------------------------------ #
    @property
    def wal_pending_bytes(self) -> int:
        return self.wal.pending_bytes if self.wal is not None else 0

    def describe(self) -> dict:
        return {
            "directory": str(self.directory),
            "epoch": self.wal.epoch if self.wal is not None else None,
            "wal_records": self.wal.next_seq if self.wal is not None else 0,
            "wal_bytes": self.wal.nbytes() if self.wal is not None else 0,
            "wal_pending_bytes": self.wal_pending_bytes,
            "wal_pending_records": (self.wal.pending_records
                                    if self.wal is not None else 0),
            "last_snapshot_epoch": (self.index.epoch
                                    if self.last_snapshot_path else None),
            "last_snapshot_wal_seq": self.last_snapshot_wal_seq,
            "last_snapshot_bytes": self.last_snapshot_bytes,
            "snapshots": len(atomic.complete_entries(
                self.directory, SNAPSHOT_PREFIX, MANIFEST_NAME)),
        }


# --------------------------------------------------------------------- #
# Recovery
# --------------------------------------------------------------------- #
def _replay(index: COAXIndex, directory: Path, durable: bool,
            keep: int, sync_every_op: bool, start_seq: int) -> int:
    """Replay the WAL tail of ``index.epoch`` through the ordinary write
    paths; returns the number of records applied.  The WAL file is never
    mutated while it is being replayed — it stays the authoritative
    journal, so a crash anywhere inside replay is a pure retry (§7.4).
    With ``durable`` the plane is attached first (append suppressed); if
    the replay crossed a compaction, ``finish_replay`` converges disk to
    the rotated state in one crash-safe pass afterwards (§7.5)."""
    wfile = wal_path(directory, index.epoch)
    records, next_seq, intact = read_wal(wfile, expect_epoch=index.epoch)
    dur = None
    if durable:
        if wfile.exists() and intact < wfile.stat().st_size:
            os.truncate(wfile, intact)    # drop the torn tail before appending
        dur = Durability(index, directory, keep=keep,
                         sync_every_op=sync_every_op)
        dur.wal = dur._open_wal(wfile, index.epoch, start_seq=next_seq)
        dur._suppress_append = True
        dur._replaying = True
        latest = latest_snapshot(directory)
        if latest is not None:
            dur._record_snapshot(latest, read_manifest(latest)["wal_seq"])
        index.durable = dur
    applied = []
    tail_start = 0
    epoch_before = cur_epoch = index.epoch
    # replay is sync-only (§7.3): a replayed op that trips the compaction
    # trigger must compact HERE, not kick off a §5.4 background build —
    # also covers durable=False (read-only) loads, where no plane's
    # ``_replaying`` flag exists to force it
    sync_flag = hasattr(index, "_in_handoff_replay")
    if sync_flag:
        index._in_handoff_replay = True
    try:
        for rec in records:
            if rec.seq < start_seq:
                continue                  # already folded into the snapshot
            if rec.kind == OP_INSERT:
                index.insert(rec.rows, ids=rec.ids)
            else:
                index.delete(rec.ids)
            applied.append(rec)
            if index.epoch != cur_epoch:  # a replayed op re-fired compaction
                cur_epoch = index.epoch
                tail_start = len(applied)  # later ops belong to the new WAL
    finally:
        if sync_flag:
            index._in_handoff_replay = False
    if dur is not None:
        dur._replaying = False
        dur._suppress_append = False
        if cur_epoch != epoch_before:
            dur.finish_replay(applied[tail_start:])
        else:
            dur.sync()
    return len(applied)


def _restore_single(directory: Path, backend: str,
                    device_opts: Optional[dict], durable: bool,
                    keep: int, sync_every_op: bool) -> COAXIndex:
    if durable:
        # half-staged checkpoint litter from the crash (this is also the
        # sweep for each shard_<k>/ of a sharded recovery)
        atomic.sweep_stale_tmp(directory)
    snap = latest_snapshot(directory)
    if snap is None:
        raise FileNotFoundError(f"no complete snapshot under {directory}")
    index, manifest = load_snapshot(snap, backend=backend,
                                    device_opts=device_opts)
    _replay(index, directory, durable, keep, sync_every_op,
            start_seq=int(manifest["wal_seq"]))
    if durable:
        # stale WALs of older epochs (rotation crash window) are dead weight
        live = wal_path(directory, index.epoch)
        for p in _wal_files(directory):
            if p != live:
                p.unlink(missing_ok=True)
    return index


class ShardedDurability:
    """Per-shard durability planes + the partitioner spec for a
    ``ShardedCOAX`` (DESIGN.md §7.6).  Each shard journals independently
    under ``shard_<k>/``; the spec pins what queries cannot recompute —
    partitioner kind/dim, frozen range boundaries and the checkpointed
    global id high-water mark."""

    def __init__(self, sharded, directory: Union[str, Path]):
        self.sharded = sharded
        self.directory = Path(directory)

    @staticmethod
    def shard_dir(directory: Union[str, Path], k: int) -> Path:
        return Path(directory) / f"shard_{k:02d}"

    # ------------------------------------------------------------------ #
    @classmethod
    def attach(cls, sharded, directory: Union[str, Path], keep: int = 3,
               sync_every_op: bool = False) -> "ShardedDurability":
        directory = Path(directory)
        dur = cls(sharded, directory)
        dur.write_spec()
        for k, shard in enumerate(sharded.shards):
            Durability.attach(shard, cls.shard_dir(directory, k), keep=keep,
                              sync_every_op=sync_every_op)
        sharded.durable = dur
        return dur

    def write_spec(self) -> None:
        s = self.sharded
        spec = {
            "format": "sharded-coax-spec",
            "version": 1,
            "kind": "sharded",
            "time": time.time(),
            "n_shards": s.n_shards,
            "partition": s.partition,
            "partition_dim": s.partition_dim,
            "boundaries": (None if s._boundaries is None
                           else [float(b) for b in s._boundaries]),
            "next_id": int(s._next_id),
            "n_dims": s.n_dims,
        }
        atomic.replace_file(self.directory / SPEC_NAME,
                            json.dumps(spec, indent=2).encode())

    # ------------------------------------------------------------------ #
    def sync(self) -> None:
        for shard in self.sharded.shards:
            if shard.durable is not None:
                shard.durable.sync()

    def checkpoint(self, keep: Optional[int] = None) -> List[Path]:
        """Checkpoint every shard and re-pin the global id high-water mark
        in the spec (restore takes the max of spec and shard values, so a
        stale spec only ever understates — never aliases an id)."""
        paths = [shard.durable.checkpoint(keep=keep)
                 for shard in self.sharded.shards
                 if shard.durable is not None]
        self.write_spec()
        return paths

    def close(self) -> None:
        """fsync + close every shard's WAL; idempotent like the per-shard
        ``Durability.close`` it fans out to."""
        for shard in self.sharded.shards:
            if shard.durable is not None:
                shard.durable.close()

    @property
    def closed(self) -> bool:
        return all(shard.durable is None or shard.durable.closed
                   for shard in self.sharded.shards)

    @property
    def wal_pending_bytes(self) -> int:
        return sum(shard.durable.wal_pending_bytes
                   for shard in self.sharded.shards
                   if shard.durable is not None)

    def describe(self) -> dict:
        per_shard = [shard.durable.describe() if shard.durable is not None
                     else None for shard in self.sharded.shards]
        return {
            "directory": str(self.directory),
            "wal_records": sum(d["wal_records"] for d in per_shard if d),
            "wal_bytes": sum(d["wal_bytes"] for d in per_shard if d),
            "wal_pending_bytes": self.wal_pending_bytes,
            "last_snapshot_bytes": sum(d["last_snapshot_bytes"]
                                       for d in per_shard if d),
            "per_shard": per_shard,
        }


def _restore_sharded(directory: Path, backend: str,
                     device_opts: Optional[dict], durable: bool,
                     keep: int, sync_every_op: bool):
    from ..engine.sharded import ShardedCOAX

    spec = json.loads((directory / SPEC_NAME).read_text())
    if spec.get("format") != "sharded-coax-spec":
        raise ValueError(f"{directory / SPEC_NAME} is not a partitioner spec")
    shards = [
        _restore_single(ShardedDurability.shard_dir(directory, k), backend,
                        device_opts, durable, keep, sync_every_op)
        for k in range(int(spec["n_shards"]))
    ]
    sharded = ShardedCOAX._restore_parts(spec, shards, backend=backend)
    if durable:
        sharded.durable = ShardedDurability(sharded, directory)
    return sharded


def restore(directory: Union[str, Path], backend: str = "numpy",
            device_opts: Optional[dict] = None, durable: bool = False,
            keep: int = 3, sync_every_op: bool = False):
    """Recover an index from a durability directory (DESIGN.md §7.4).

    Sniffs the layout: a ``spec.json`` means a ``ShardedCOAX`` (per-shard
    recovery + partitioner spec), otherwise a single ``COAXIndex``
    (newest complete snapshot + WAL-tail replay).  ``durable=False`` is a
    strictly read-only load — the cold-start-replica path: nothing in the
    directory is modified, and the returned index does not journal.
    ``durable=True`` re-attaches the plane (truncating any torn WAL tail
    first) so the index resumes journaling at the recovered position.
    """
    directory = Path(directory)
    if durable:
        atomic.sweep_stale_tmp(directory)
    if (directory / SPEC_NAME).exists():
        return _restore_sharded(directory, backend, device_opts, durable,
                                keep, sync_every_op)
    return _restore_single(directory, backend, device_opts, durable,
                           keep, sync_every_op)
