"""Span tracing (DESIGN.md §10.2).

A ``Tracer`` records *spans* — named `(t0, t1)` intervals on the
monotonic clock with an explicit parent id — into a bounded ring, so a
long-running ``QueryServer`` holds the trailing window only.  Parenting
is implicit through a thread-local stack (a span opened inside another
on the same thread becomes its child) with an explicit ``parent=``
override for the two places that legitimately cross that model:

* the executor's pipelined submit/collect, where wave *k*'s collect
  runs while wave *k+1*'s submit is already on the stack — collect-side
  spans pass wave *k*'s span explicitly so they never adopt *k+1*;
* background threads (compactor build, replication pump), which carry
  the spawning span across the thread boundary.

Export: ``events()`` (finished-span dicts), ``dump_jsonl``, and
``to_chrome()`` — Chrome ``trace_event`` JSON that ``chrome://tracing``
/ Perfetto opens as a wave timeline.  ``validate()`` is the CI gate:
every span closed, parents precede children, wave spans cover their
dispatch spans.
"""
from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Tuple, Union

__all__ = ["Span", "Tracer"]


class Span:
    """One open (then finished) interval.  Use via ``tracer.span(...)``
    as a context manager, or ``start``/``finish`` for intervals whose
    ends live in different call frames (submit vs collect)."""

    __slots__ = ("name", "id", "parent", "t0", "t1", "args", "tid")

    def __init__(self, name: str, id: int, parent: Optional[int],
                 t0: float, tid: int, args: Dict[str, object]):
        self.name = name
        self.id = id
        self.parent = parent
        self.t0 = t0
        self.t1: Optional[float] = None
        self.args = args
        self.tid = tid

    def to_dict(self) -> dict:
        return {"name": self.name, "id": self.id, "parent": self.parent,
                "t0": self.t0, "t1": self.t1, "tid": self.tid,
                "args": self.args}


class Tracer:
    """Thread-safe bounded-ring span recorder on ``time.perf_counter``."""

    def __init__(self, capacity: int = 8192):
        self.capacity = int(capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._ids = itertools.count(1)
        self._open: Dict[int, Span] = {}
        self._lock = threading.Lock()
        self._stack = threading.local()
        self.dropped = 0            # spans evicted from the ring

    # -- recording ------------------------------------------------------ #
    def _stack_list(self) -> List[Span]:
        st = getattr(self._stack, "spans", None)
        if st is None:
            st = self._stack.spans = []
        return st

    def current(self) -> Optional[Span]:
        st = self._stack_list()
        return st[-1] if st else None

    def start(self, name: str,
              parent: Union[Span, int, None] = None, **args) -> Span:
        """Open a span.  ``parent`` defaults to the innermost span open
        on THIS thread; pass a ``Span``/id explicitly to pin the parent
        across the pipelined submit/collect seam or a thread boundary
        (see module docstring).  The caller must ``finish`` it; started
        spans do NOT join the thread-local stack (context-manager spans
        do)."""
        if parent is None:
            cur = self.current()
            pid = cur.id if cur is not None else None
        else:
            pid = parent.id if isinstance(parent, Span) else int(parent)
        sp = Span(name, next(self._ids), pid, time.perf_counter(),
                  threading.get_ident(), args)
        with self._lock:
            self._open[sp.id] = sp
        return sp

    def finish(self, span: Span, **args) -> Span:
        span.t1 = time.perf_counter()
        if args:
            span.args.update(args)
        # a span finished on a different thread than it started (the
        # §10.2 thread-boundary handoff) takes the finishing thread's
        # lane: that is where the work ran, and validate() uses the tid
        # mismatch to exempt it from same-thread parent containment
        span.tid = threading.get_ident()
        with self._lock:
            self._open.pop(span.id, None)
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(span)
        return span

    class _Ctx:
        __slots__ = ("_tracer", "_span", "_push")

        def __init__(self, tracer: "Tracer", span: Span, push: bool):
            self._tracer = tracer
            self._span = span
            self._push = push

        def __enter__(self) -> Span:
            if self._push:
                self._tracer._stack_list().append(self._span)
            return self._span

        def __exit__(self, *exc) -> bool:
            if self._push:
                st = self._tracer._stack_list()
                if st and st[-1] is self._span:
                    st.pop()
                elif self._span in st:       # tolerate misnested exits
                    st.remove(self._span)
            self._tracer.finish(self._span)
            return False

    def span(self, name: str,
             parent: Union[Span, int, None] = None, **args) -> "_Ctx":
        """Context manager: records the span over the ``with`` body and
        makes it the implicit parent for nested spans on this thread."""
        return Tracer._Ctx(self, self.start(name, parent, **args), True)

    class _Attach:
        """Push an already-open span as the implicit parent for the
        ``with`` body WITHOUT finishing it on exit — the executor's
        pipelined collect re-attaches wave *k*'s span so drain-side
        children never adopt wave *k+1* (module docstring)."""
        __slots__ = ("_tracer", "_span")

        def __init__(self, tracer: "Tracer", span: Span):
            self._tracer = tracer
            self._span = span

        def __enter__(self) -> Span:
            self._tracer._stack_list().append(self._span)
            return self._span

        def __exit__(self, *exc) -> bool:
            st = self._tracer._stack_list()
            if st and st[-1] is self._span:
                st.pop()
            elif self._span in st:
                st.remove(self._span)
            return False

    def attach(self, span: Span) -> "_Attach":
        return Tracer._Attach(self, span)

    # -- reads / export ------------------------------------------------- #
    def events(self) -> List[dict]:
        with self._lock:
            return [s.to_dict() for s in self._ring]

    def open_spans(self) -> List[dict]:
        with self._lock:
            return [s.to_dict() for s in self._open.values()]

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self._open.clear()
            self.dropped = 0

    def dump_jsonl(self, path: str) -> int:
        evs = self.events()
        with open(path, "w") as f:
            for e in evs:
                f.write(json.dumps(e) + "\n")
        return len(evs)

    def to_chrome(self) -> dict:
        """Chrome ``trace_event`` format: one complete ("ph": "X") event
        per finished span, µs timescale, tid = recording thread."""
        evs = []
        for e in self.events():
            evs.append({
                "name": e["name"], "ph": "X", "pid": 1, "tid": e["tid"],
                "ts": e["t0"] * 1e6,
                "dur": max((e["t1"] - e["t0"]) * 1e6, 0.0),
                "args": dict(e["args"], span_id=e["id"],
                             parent=e["parent"]),
            })
        return {"traceEvents": evs, "displayTimeUnit": "ms"}

    def validate(self, wave_prefix: str = "wave",
                 covered_names: Tuple[str, ...] = ("device.dispatch",
                                                   "device.transfer"),
                 ) -> Tuple[bool, List[str]]:
        """CI gate (§10.2): (a) no span left open, (b) every in-ring
        parent precedes its children (t0 ordering) and contains them
        (t1 ordering) — containment is only asserted for same-thread
        children, because a span handed across a thread boundary (the
        compactor's ``compact.build``, spawned by a drain that returns
        long before the build lands) legitimately outlives its parent —
        (c) every ``covered_names`` span reaches a ``wave_prefix``-named
        ancestor whose interval covers it.  Returns ``(ok, problems)``;
        spans whose parents were evicted from the ring are skipped, not
        failed."""
        problems: List[str] = []
        evs = self.events()
        for o in self.open_spans():
            problems.append(f"span never finished: {o['name']} id={o['id']}")
        by_id = {e["id"]: e for e in evs}
        eps = 1e-6
        for e in evs:
            p = by_id.get(e["parent"]) if e["parent"] is not None else None
            if p is None:
                continue
            if p["t0"] > e["t0"] + eps:
                problems.append(
                    f"parent {p['name']} starts after child {e['name']}")
            if p["t1"] is not None and e["t1"] is not None \
                    and p["tid"] == e["tid"] and p["t1"] + eps < e["t1"]:
                problems.append(
                    f"parent {p['name']} ends before child {e['name']}")
        for e in evs:
            if e["name"] not in covered_names:
                continue
            node, seen = e, 0
            covered = orphaned = False
            while node["parent"] is not None and seen < 64:
                node = by_id.get(node["parent"])
                seen += 1
                if node is None:
                    orphaned = True          # ancestor evicted: skip
                    break
                if node["name"].startswith(wave_prefix) \
                        and node["t0"] <= e["t0"] + eps \
                        and node["t1"] is not None \
                        and node["t1"] + eps >= e["t1"]:
                    covered = True
                    break
            if not covered and not orphaned:
                problems.append(
                    f"{e['name']} id={e['id']} not covered by a "
                    f"{wave_prefix}* ancestor")
        return (not problems), problems
