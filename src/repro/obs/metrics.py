"""Process-global metrics registry (DESIGN.md §10.1).

One source of truth for every ``stats()``/``describe()`` surface in the
tree: counters (monotonic), gauges (last-write-wins) and log-bucketed
histograms (p50/p90/p99/max without storing samples).  Families are
keyed by metric name; children by a tuple of label values (``backend``,
``shard``, ``epoch``, ``plane``, ...).  Everything is guarded by one
coarse lock — updates happen at wave/record granularity, never per row,
so contention is negligible (§10.4 overhead budget).

Zero dependencies beyond the standard library.  Exposition:

* ``registry.render_text()``   — Prometheus-style text format
* ``registry.snapshot()``      — nested JSON-serialisable dict
* ``parse_text_exposition()``  — inverse of ``render_text`` (CI gate)
"""
from __future__ import annotations

import math
import threading
from typing import Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry",
    "get_registry", "set_registry", "parse_text_exposition",
]

# Log-spaced bucket boundaries shared by every histogram: 1µs .. ~4.6h in
# ×2 steps (44 finite buckets + overflow).  Observations are clamped into
# [0, +inf); quantiles interpolate linearly inside a bucket.
_HIST_BASE = 1e-6
_HIST_GROWTH = 2.0
_HIST_BUCKETS = 44
_BOUNDS = tuple(_HIST_BASE * _HIST_GROWTH ** i for i in range(_HIST_BUCKETS))


def _label_key(labelnames: Tuple[str, ...], labels: Dict[str, object]) -> Tuple[str, ...]:
    if set(labels) != set(labelnames):
        raise ValueError(
            f"labels {sorted(labels)} != declared {sorted(labelnames)}")
    return tuple(str(labels[k]) for k in labelnames)


def _escape(v: str) -> str:
    return v.replace("\\", "\\\\").replace('"', '\\"').replace("\n", "\\n")


class _Family:
    """Base: a named metric family with fixed label names and one child
    per observed label-value combination."""

    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Tuple[str, ...],
                 lock: threading.Lock):
        self.name = name
        self.help = help
        self.labelnames = labelnames
        self._lock = lock
        self._children: Dict[Tuple[str, ...], object] = {}

    def _child(self, labels: Dict[str, object]):
        key = _label_key(self.labelnames, labels)
        child = self._children.get(key)
        if child is None:
            child = self._make_child()
            self._children[key] = child
        return child

    def labelsets(self) -> List[Tuple[str, ...]]:
        with self._lock:
            return sorted(self._children)


class Counter(_Family):
    """Monotonically increasing count (resets only via ``registry.reset``)."""

    kind = "counter"

    def _make_child(self):
        return [0.0]

    def inc(self, amount: float = 1.0, **labels) -> None:
        with self._lock:
            self._child(labels)[0] += amount

    def value(self, **labels) -> float:
        with self._lock:
            key = _label_key(self.labelnames, labels)
            child = self._children.get(key)
            return child[0] if child is not None else 0.0

    def total(self) -> float:
        """Sum over every labelset (the unlabeled rollup)."""
        with self._lock:
            return sum(c[0] for c in self._children.values())


class Gauge(_Family):
    """Last-write-wins instantaneous value (``set``) with ``add`` for
    up/down counts (inflight queries, pinned epochs)."""

    kind = "gauge"

    def _make_child(self):
        return [0.0]

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._child(labels)[0] = float(value)

    def add(self, amount: float = 1.0, **labels) -> None:
        with self._lock:
            self._child(labels)[0] += amount

    def value(self, **labels) -> float:
        with self._lock:
            key = _label_key(self.labelnames, labels)
            child = self._children.get(key)
            return child[0] if child is not None else 0.0


class _HistChild:
    __slots__ = ("counts", "overflow", "count", "sum", "max")

    def __init__(self):
        self.counts = [0] * _HIST_BUCKETS
        self.overflow = 0
        self.count = 0
        self.sum = 0.0
        self.max = 0.0


class Histogram(_Family):
    """Log-bucketed (×2 from 1µs) distribution.  ``observe`` is O(1);
    ``quantile`` interpolates linearly inside the winning bucket, so
    p50/p90/p99 are exact to within one bucket's width (§10.1)."""

    kind = "histogram"

    def _make_child(self):
        return _HistChild()

    @staticmethod
    def bucket_index(value: float) -> int:
        """Index of the first bucket whose upper bound >= value (or
        ``_HIST_BUCKETS`` for overflow)."""
        if value <= _HIST_BASE:
            return 0
        i = int(math.ceil(math.log(value / _HIST_BASE, _HIST_GROWTH) - 1e-12))
        return min(i, _HIST_BUCKETS)

    def observe(self, value: float, **labels) -> None:
        v = max(float(value), 0.0)
        with self._lock:
            c = self._child(labels)
            i = self.bucket_index(v)
            if i >= _HIST_BUCKETS:
                c.overflow += 1
            else:
                c.counts[i] += 1
            c.count += 1
            c.sum += v
            if v > c.max:
                c.max = v

    # -- reads ---------------------------------------------------------- #
    def _merged(self, labels: Optional[Dict[str, object]]) -> _HistChild:
        """One child, or the sum over all labelsets when ``labels=None``."""
        if labels is not None:
            key = _label_key(self.labelnames, labels)
            return self._children.get(key) or _HistChild()
        out = _HistChild()
        for c in self._children.values():
            out.counts = [a + b for a, b in zip(out.counts, c.counts)]
            out.overflow += c.overflow
            out.count += c.count
            out.sum += c.sum
            out.max = max(out.max, c.max)
        return out

    def quantile(self, q: float, **labels) -> float:
        with self._lock:
            c = self._merged(labels or None)
            if c.count == 0:
                return 0.0
            rank = q * c.count
            seen = 0.0
            for i, n in enumerate(c.counts):
                if n == 0:
                    continue
                if seen + n >= rank:
                    lo = 0.0 if i == 0 else _BOUNDS[i - 1]
                    hi = min(_BOUNDS[i], c.max)
                    frac = (rank - seen) / n
                    return lo + (hi - lo) * min(max(frac, 0.0), 1.0)
                seen += n
            return c.max

    def summary(self, **labels) -> Dict[str, float]:
        """{count, sum, mean, p50, p90, p99, max} for one labelset (or the
        all-labelset rollup with no labels)."""
        with self._lock:
            c = self._merged(labels or None)
        if c.count == 0:
            return {"count": 0, "sum": 0.0, "mean": 0.0,
                    "p50": 0.0, "p90": 0.0, "p99": 0.0, "max": 0.0}
        out = {"count": c.count, "sum": c.sum,
               "mean": c.sum / c.count, "max": c.max}
        for q, k in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
            out[k] = self.quantile(q, **labels)
        return out


class MetricsRegistry:
    """Container of metric families.  ``counter``/``gauge``/``histogram``
    get-or-create by name (re-declaration with different labelnames or a
    different kind is an error — ONE schema per name across the tree)."""

    def __init__(self):
        self._lock = threading.Lock()
        self._families: Dict[str, _Family] = {}

    # -- declaration ---------------------------------------------------- #
    def _get(self, cls, name: str, help: str, labelnames: Iterable[str]):
        labelnames = tuple(labelnames)
        with self._lock:
            fam = self._families.get(name)
            if fam is None:
                fam = cls(name, help, labelnames, self._lock)
                self._families[name] = fam
                return fam
        if not isinstance(fam, cls):
            raise ValueError(f"{name} already declared as {fam.kind}")
        if fam.labelnames != labelnames:
            raise ValueError(
                f"{name} labelnames {fam.labelnames} != {labelnames}")
        return fam

    def counter(self, name: str, help: str = "",
                labelnames: Iterable[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Iterable[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Iterable[str] = ()) -> Histogram:
        return self._get(Histogram, name, help, labelnames)

    def get(self, name: str) -> Optional[_Family]:
        with self._lock:
            return self._families.get(name)

    def reset(self) -> None:
        with self._lock:
            self._families.clear()

    # -- exposition ----------------------------------------------------- #
    def snapshot(self) -> dict:
        """Nested JSON-serialisable dump: {name: {kind, help, series:
        [{labels, value | summary}]}}."""
        out: dict = {}
        with self._lock:
            fams = list(self._families.values())
        for fam in fams:
            series = []
            for key in fam.labelsets():
                labels = dict(zip(fam.labelnames, key))
                if isinstance(fam, Histogram):
                    series.append({"labels": labels,
                                   "summary": fam.summary(**labels)})
                else:
                    series.append({"labels": labels,
                                   "value": fam.value(**labels)})
            out[fam.name] = {"kind": fam.kind, "help": fam.help,
                             "series": series}
        return out

    def render_text(self) -> str:
        """Prometheus-style text exposition.  Histograms render as a
        summary: ``<name>{...,quantile="0.5"}``, ``<name>_sum``,
        ``<name>_count``, ``<name>_max``."""
        lines: List[str] = []
        with self._lock:
            fams = sorted(self._families.values(), key=lambda f: f.name)
        for fam in fams:
            lines.append(f"# HELP {fam.name} {fam.help}")
            kind = "summary" if isinstance(fam, Histogram) else fam.kind
            lines.append(f"# TYPE {fam.name} {kind}")
            for key in fam.labelsets():
                labels = dict(zip(fam.labelnames, key))
                base = ",".join(f'{k}="{_escape(v)}"'
                                for k, v in zip(fam.labelnames, key))
                if isinstance(fam, Histogram):
                    s = fam.summary(**labels)
                    for q, k in (("0.5", "p50"), ("0.9", "p90"),
                                 ("0.99", "p99")):
                        ql = (base + "," if base else "") + f'quantile="{q}"'
                        lines.append(f"{fam.name}{{{ql}}} {s[k]:.9g}")
                    suff = f"{{{base}}}" if base else ""
                    lines.append(f"{fam.name}_sum{suff} {s['sum']:.9g}")
                    lines.append(f"{fam.name}_count{suff} {s['count']}")
                    lines.append(f"{fam.name}_max{suff} {s['max']:.9g}")
                else:
                    suff = f"{{{base}}}" if base else ""
                    v = fam.value(**labels)
                    lines.append(f"{fam.name}{suff} {v:.9g}")
        return "\n".join(lines) + "\n"


def parse_text_exposition(text: str) -> Dict[str, dict]:
    """Parse ``render_text`` output back into ``{name: {type, help,
    samples: [(labels_dict, value)]}}``.  Used by the CI smoke gate to
    prove the exposition round-trips; raises ValueError on malformed
    lines."""
    out: Dict[str, dict] = {}
    for line in text.splitlines():
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            _, _, rest = line.partition("# HELP ")
            name, _, help_ = rest.partition(" ")
            out.setdefault(name, {"help": "", "type": "untyped",
                                  "samples": []})["help"] = help_
            continue
        if line.startswith("# TYPE "):
            _, _, rest = line.partition("# TYPE ")
            name, _, kind = rest.partition(" ")
            out.setdefault(name, {"help": "", "type": "untyped",
                                  "samples": []})["type"] = kind.strip()
            continue
        if line.startswith("#"):
            continue
        # sample: name{l="v",...} value   |   name value
        brace = line.find("{")
        if brace >= 0:
            name = line[:brace]
            close = line.rfind("}")
            if close < brace:
                raise ValueError(f"malformed sample line: {line!r}")
            body, valstr = line[brace + 1:close], line[close + 1:].strip()
            labels: Dict[str, str] = {}
            i = 0
            while i < len(body):
                eq = body.index("=", i)
                k = body[i:eq]
                if body[eq + 1] != '"':
                    raise ValueError(f"malformed labels: {line!r}")
                j = eq + 2
                val = []
                while body[j] != '"':
                    if body[j] == "\\":
                        j += 1
                        val.append({"\\": "\\", '"': '"', "n": "\n"}[body[j]])
                    else:
                        val.append(body[j])
                    j += 1
                labels[k] = "".join(val)
                i = j + 1
                if i < len(body) and body[i] == ",":
                    i += 1
        else:
            name, _, valstr = line.partition(" ")
            labels = {}
        try:
            value = float(valstr)
        except ValueError:
            raise ValueError(f"malformed value in: {line!r}")
        root = name
        for suffix in ("_sum", "_count", "_max"):
            if name.endswith(suffix) and name[:-len(suffix)] in out:
                root = name[:-len(suffix)]
        out.setdefault(root, {"help": "", "type": "untyped", "samples": []})
        out[root]["samples"].append((name, labels, value))
    return out


# ---------------------------------------------------------------------- #
# Process-global default registry.  Import-time singleton: every plane
# records here unless a test swaps it out with ``set_registry``.
# ---------------------------------------------------------------------- #
_global = MetricsRegistry()
_global_lock = threading.Lock()


def get_registry() -> MetricsRegistry:
    return _global


def set_registry(registry: MetricsRegistry) -> MetricsRegistry:
    """Swap the process-global registry (tests); returns the previous one."""
    global _global
    with _global_lock:
        prev, _global = _global, registry
    return prev
