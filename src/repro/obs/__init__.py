"""Unified telemetry plane (DESIGN.md §10).

Three layers, zero dependencies:

1. **Metrics registry** (`metrics.py`) — process-global counters,
   gauges and log-bucketed histograms with labeled families; every
   ``stats()``/``describe()`` surface in the tree reads from it.
   Always on: registry updates happen at wave/record granularity and
   fit the §10.4 overhead budget (≤5% QPS, CI-gated).
2. **Span tracing** (`trace.py`) — opt-in (``obs.enable_tracing()``);
   when no tracer is installed every ``obs.span(...)`` site is a
   cheap no-op, which is how the telemetry-off path stays at zero
   overhead beyond the registry.
3. **Profiling hooks** (`profile.py`) — ``obs.profile(logdir)`` gates
   ``jax.profiler`` capture around device waves.

`watchdog.py` builds the serving-pause monitor on layers 1+2.
"""
from __future__ import annotations

import time
from typing import Optional, Union

from .metrics import (Counter, Gauge, Histogram, MetricsRegistry,
                      get_registry, parse_text_exposition, set_registry)
from .profile import profile
from .trace import Span, Tracer
from .watchdog import PauseWatchdog

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "PauseWatchdog",
    "Span", "Tracer", "disable_tracing", "enable_tracing", "get_registry",
    "metrics", "parse_text_exposition", "profile", "set_registry",
    "set_tracer", "span", "stage_timer", "tracer",
]

# -------------------------------------------------------------------- #
# Global tracer: None (the default) means every span site no-ops.
# -------------------------------------------------------------------- #
_tracer: Optional[Tracer] = None


def tracer() -> Optional[Tracer]:
    """The installed global tracer, or None when tracing is off."""
    return _tracer


def set_tracer(t: Optional[Tracer]) -> Optional[Tracer]:
    """Install (or remove, with None) the global tracer; returns the
    previous one."""
    global _tracer
    prev, _tracer = _tracer, t
    return prev


def enable_tracing(capacity: int = 8192) -> Tracer:
    """Install a fresh global ring-buffered tracer and return it."""
    t = Tracer(capacity=capacity)
    set_tracer(t)
    return t


def disable_tracing() -> Optional[Tracer]:
    """Remove the global tracer (span sites become no-ops again)."""
    return set_tracer(None)


def metrics() -> MetricsRegistry:
    """The process-global metrics registry (alias of ``get_registry``)."""
    return get_registry()


class _NullCtx:
    """No-tracer fallback for ``obs.span``: zero-allocation enter/exit,
    yields None so call sites can pass the result as a parent safely
    (``parent=None`` means implicit parenting downstream)."""
    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_CTX = _NullCtx()


def span(name: str, parent: Union[Span, int, None] = None, **args):
    """Context manager recording a span on the global tracer — or a
    no-op when tracing is off.  Yields the ``Span`` (or None)."""
    t = _tracer
    if t is None:
        return _NULL_CTX
    return t.span(name, parent, **args)


# -------------------------------------------------------------------- #
# Per-stage timing (§10.1): ONE histogram family shared by every plane
# so the bench's per-stage breakdown reads from a single place.
# stages: probe | search | filter | merge | delta_scan | cache_route |
#         cache_admit | dispatch | transfer | flush | fsync
# -------------------------------------------------------------------- #
def stage_hist() -> Histogram:
    return get_registry().histogram(
        "coax_stage_seconds",
        "per-pipeline-stage wall time (DESIGN.md §10.1)",
        ("stage", "backend"))


class _StageTimer:
    """Always-on stage timer: one ``perf_counter`` pair + one histogram
    observe per stage per wave (the §10.4 overhead budget)."""
    __slots__ = ("stage", "backend", "_t0")

    def __init__(self, stage: str, backend: str):
        self.stage = stage
        self.backend = backend

    def __enter__(self):
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        stage_hist().observe(time.perf_counter() - self._t0,
                             stage=self.stage, backend=self.backend)
        return False


def stage_timer(stage: str, backend: str = "numpy") -> _StageTimer:
    return _StageTimer(stage, backend)
