"""Device profiling hooks (DESIGN.md §10.2, profiling layer).

``profile(logdir)`` gates an optional ``jax.profiler`` trace capture
around a block of device waves — XLA compile/execute timelines land in
``logdir`` for TensorBoard / Perfetto.  The context is a strict no-op
(and never raises) when jax is absent, the profiler is unavailable, or
a capture is already active, so call sites can wrap hot paths
unconditionally.  The cheap per-wave counters (``transfer_bytes``,
``dispatches``, ``compile_count``) do NOT live here — they fold into
the metrics registry from the device plan itself (§10.1).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Iterator, Optional

__all__ = ["profile"]

_active = threading.Lock()    # one capture at a time, process-wide


@contextlib.contextmanager
def profile(logdir: Optional[str], enabled: bool = True) -> Iterator[bool]:
    """Capture a ``jax.profiler`` trace into ``logdir`` over the block.

    Yields True when a capture actually started (jax importable, no
    other capture running, ``enabled`` and ``logdir`` truthy), False
    otherwise — callers may branch on it but never need to."""
    if not enabled or not logdir:
        yield False
        return
    if not _active.acquire(blocking=False):
        yield False                       # nested/concurrent: outer wins
        return
    started = False
    try:
        try:
            import jax
            jax.profiler.start_trace(logdir)
            started = True
        except Exception:                 # pragma: no cover - no jax
            pass
        yield started
    finally:
        if started:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:             # pragma: no cover
                pass
        _active.release()
