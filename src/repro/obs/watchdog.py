"""Serving-pause watchdog (DESIGN.md §10.3).

PR 8's mixed-write bench gates ``pause_max <= 5x median wave gap``
offline; this makes that signal live and always-on.  ``wave_done()`` is
called once per completed wave (``QueryServer._finish_wave``); the
watchdog keeps a trailing window of completion timestamps and, when the
gap since the previous completion exceeds ``factor`` × the trailing
median gap, increments ``serving_pause_total{culprit=...}`` in the
metrics registry and fires the optional callback.

The *culprit* is attributed from the tracer ring: the background span
(``compact.*``, ``wal.*``, ``ship.*``, ``replica.*``, ``failover.*``)
with the largest time overlap with the gap window — i.e. "this pause
was a compaction install / a WAL fsync / a ship retry", attached to the
counter label and the callback.
"""
from __future__ import annotations

import time
from collections import deque
from typing import Callable, List, Optional

from .metrics import MetricsRegistry, get_registry
from .trace import Tracer

__all__ = ["PauseWatchdog"]

_BACKGROUND_PREFIXES = ("compact.", "wal.", "ship.", "replica.",
                        "failover.", "durability.")


class PauseWatchdog:
    """Trailing-median gap monitor over wave completions.

    Parameters
    ----------
    factor : pause threshold as a multiple of the trailing median gap
        (the PR 8 bench gate used 5x at r=0.5).
    window : completions kept for the median estimate.
    min_samples : completions required before pauses are judged (the
        first waves of a cold server always straggle).
    min_gap_s : gaps below this are never pauses regardless of the
        median (guards the microsecond-median regime where scheduler
        jitter alone exceeds ``factor``×).
    callback : ``f(gap_s, median_s, culprit)`` with ``culprit`` a
        finished-span dict or None.
    """

    def __init__(self, factor: float = 5.0, window: int = 64,
                 min_samples: int = 8, min_gap_s: float = 1e-4,
                 tracer: Optional[Tracer] = None,
                 registry: Optional[MetricsRegistry] = None,
                 callback: Optional[Callable] = None):
        self.factor = float(factor)
        self.window = int(window)
        self.min_samples = int(min_samples)
        self.min_gap_s = float(min_gap_s)
        self.tracer = tracer
        self.registry = registry
        self.callback = callback
        self._gaps: deque = deque(maxlen=self.window)
        self._last: Optional[float] = None
        self.pauses: List[dict] = []          # bounded: last 64 judgments
        self.pause_count = 0

    def _median(self) -> float:
        if not self._gaps:
            return 0.0
        s = sorted(self._gaps)
        n = len(s)
        return s[n // 2] if n % 2 else 0.5 * (s[n // 2 - 1] + s[n // 2])

    def _tracer_now(self) -> Optional[Tracer]:
        """Pinned tracer, else whatever global tracer is installed at
        judgment time (tracing may be enabled after the server starts)."""
        if self.tracer is not None:
            return self.tracer
        from . import tracer as _global
        return _global()

    def _culprit(self, gap_start: float, gap_end: float) -> Optional[dict]:
        """Background span in the tracer ring with max overlap with the
        gap window."""
        tr = self._tracer_now()
        if tr is None:
            return None
        best, best_ov = None, 0.0
        for e in tr.events():
            if not e["name"].startswith(_BACKGROUND_PREFIXES):
                continue
            t1 = e["t1"] if e["t1"] is not None else gap_end
            ov = min(t1, gap_end) - max(e["t0"], gap_start)
            if ov > best_ov:
                best, best_ov = e, ov
        # an open background span (mid-install) also counts
        for e in tr.open_spans():
            if not e["name"].startswith(_BACKGROUND_PREFIXES):
                continue
            ov = gap_end - max(e["t0"], gap_start)
            if ov > best_ov:
                best, best_ov = e, ov
        return best

    def wave_done(self, now: Optional[float] = None) -> Optional[dict]:
        """Record one wave completion; returns the pause record when the
        gap since the previous completion breached the threshold, else
        None."""
        now = time.perf_counter() if now is None else now
        last, self._last = self._last, now
        if last is None:
            return None
        gap = now - last
        med = self._median()
        self._gaps.append(gap)
        if (len(self._gaps) <= self.min_samples or med <= 0.0
                or gap < self.min_gap_s or gap <= self.factor * med):
            return None
        culprit = self._culprit(last, now)
        label = culprit["name"] if culprit else "unknown"
        reg = self.registry if self.registry is not None else get_registry()
        reg.counter("serving_pause_total",
                    "wave-completion gaps exceeding factor x trailing median",
                    ("culprit",)).inc(culprit=label)
        rec = {"gap_s": gap, "median_s": med, "factor": gap / med,
               "culprit": culprit}
        self.pause_count += 1
        self.pauses.append(rec)
        if len(self.pauses) > 64:
            del self.pauses[0]
        if self.callback is not None:
            try:
                self.callback(gap, med, culprit)
            except Exception:
                pass
        return rec

    def describe(self) -> dict:
        return {"pauses": self.pause_count, "median_gap_s": self._median(),
                "window": len(self._gaps), "factor": self.factor,
                "last_culprit": (self.pauses[-1]["culprit"]["name"]
                                 if self.pauses and self.pauses[-1]["culprit"]
                                 else None)}
