"""Batched serving loop: COAX-routed admission -> prefill -> decode waves.

Wave-batched continuous serving: the router admits a length-homogeneous
batch (range query on prompt_len — COAX's job), the wave prefills once and
decodes until every sequence finishes or hits its budget, then the next
wave is admitted.  Per-slot positions within a wave share the step counter;
fully per-slot continuous batching (scatter cache writes) is an orthogonal
extension noted in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..models.model import Model
from .router import CoaxRouter, Request

__all__ = ["ServeConfig", "Server", "ServeResult"]


@dataclasses.dataclass
class ServeConfig:
    batch_size: int = 8
    max_prompt_len: int = 512
    max_new_tokens: int = 64
    cache_len: int = 1024
    eos_token: int = 1
    greedy: bool = True


@dataclasses.dataclass
class ServeResult:
    rid: int
    tokens: np.ndarray
    prompt_len: int
    wave: int
    latency_s: float


class Server:
    def __init__(self, model: Model, params, cfg: ServeConfig,
                 router: Optional[CoaxRouter] = None):
        self.model = model
        self.params = params
        self.cfg = cfg
        self.router = router or CoaxRouter()
        self._prefill = jax.jit(
            lambda p, b: model.prefill(p, b, cfg.cache_len))
        self._decode = jax.jit(model.decode_step)
        self.waves = 0

    def submit(self, prompt: np.ndarray, max_new_tokens: Optional[int] = None,
               priority: float = 0.0) -> int:
        return self.router.submit(prompt, max_new_tokens or self.cfg.max_new_tokens,
                                  priority)

    # ------------------------------------------------------------------ #
    def _pad_prompts(self, reqs: List[Request]) -> np.ndarray:
        """Left-pad to a common length so position 'S-1' is the last prompt
        token for every row (wave batches are length-homogeneous by routing,
        so padding waste is small — that is the router's point)."""
        s = max(r.prompt_len for r in reqs)
        out = np.zeros((len(reqs), s), np.int32)
        for i, r in enumerate(reqs):
            out[i, s - r.prompt_len:] = r.prompt
        return out

    def run_wave(self) -> List[ServeResult]:
        cfg = self.cfg
        # admission: length-homogeneous band around the oldest pending request
        reqs = self.router.admit(
            cfg.batch_size, prompt_len_range=(0, cfg.max_prompt_len))
        if not reqs:
            return []
        t0 = time.time()
        prompts = self._pad_prompts(reqs)
        b, s = prompts.shape

        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)})
        max_new = max(r.max_new_tokens for r in reqs)
        out_tokens = np.zeros((b, max_new), np.int32)
        done = np.zeros(b, bool)

        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        for t in range(max_new):
            out_tokens[:, t] = np.where(done, cfg.eos_token, np.asarray(tok[:, 0]))
            done |= np.asarray(tok[:, 0]) == cfg.eos_token
            done |= np.array([t + 1 >= r.max_new_tokens for r in reqs])
            if done.all():
                break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.int32(s + t))
            tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]

        dt = time.time() - t0
        self.waves += 1
        results = []
        for i, r in enumerate(reqs):
            n = min(r.max_new_tokens, max_new)
            results.append(ServeResult(
                rid=r.rid, tokens=out_tokens[i, :n], prompt_len=r.prompt_len,
                wave=self.waves, latency_s=dt))
        return results

    def run_until_drained(self, max_waves: int = 100) -> List[ServeResult]:
        out: List[ServeResult] = []
        for _ in range(max_waves):
            res = self.run_wave()
            if not res and len(self.router) == 0:
                break
            out.extend(res)
        return out
