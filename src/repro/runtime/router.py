"""COAX-indexed request router for continuous-batching admission
(DESIGN.md §2 — the paper's index in the serving plane).

The pending-request pool is a multidimensional table
(arrival_time, prompt_len, predicted_decode_len, priority); admission
queries are range queries ("prompt_len in [lo, hi) and priority >= p and
oldest first") used to form length-homogeneous decode batches (minimises
padding waste).  prompt_len -> predicted_decode_len is a soft FD (decode
budgets are set proportionally to prompt length in practice), so COAX
indexes the pool with a reduced-dimensionality primary index.

The router is rebuild-on-dirty: COAX's bucketed Bayesian fit makes rebuilds
cheap (paper §5), and between rebuilds new arrivals sit in a small overflow
list that is scanned linearly (bounded by ``rebuild_threshold``).
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from .. import obs
from ..core import COAXIndex, CoaxConfig, full_rect, rect_contains
from ..obs import MetricsRegistry

__all__ = ["Request", "CoaxRouter"]

COLS = ("arrival", "prompt_len", "predicted_decode", "priority")


@dataclasses.dataclass
class Request:
    rid: int
    arrival: float
    prompt: np.ndarray                 # token ids
    max_new_tokens: int
    priority: float = 0.0

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


def _predict_decode_len(prompt_len: int, max_new: int) -> float:
    # serving-time heuristic: decode budget tracks prompt length (soft FD)
    return float(min(max_new, 16 + 0.25 * prompt_len))


class CoaxRouter:
    def __init__(self, rebuild_threshold: int = 256,
                 config: Optional[CoaxConfig] = None):
        self.config = config or CoaxConfig()
        self.rebuild_threshold = rebuild_threshold
        self._pool: Dict[int, Request] = {}
        self._index: Optional[COAXIndex] = None
        self._index_rids: np.ndarray = np.empty(0, np.int64)
        self._overflow: List[int] = []
        self._tombstones = 0          # admitted rows still in the index
        self._ids = itertools.count()
        # private registry (DESIGN.md §10.4): stats() delegates here so the
        # router shares the exposition schema with the serving planes
        self.metrics = MetricsRegistry()
        self._c_submits = self.metrics.counter(
            "coax_router_submits_total", "Requests submitted to the pool.")
        self._c_admitted = self.metrics.counter(
            "coax_router_admitted_total", "Requests admitted into batches.")
        self._c_rebuilds = self.metrics.counter(
            "coax_router_rebuilds_total", "Lazy index rebuilds.")
        self._h_admit = self.metrics.histogram(
            "coax_router_admit_seconds", "Latency of admit() calls.")

    # ------------------------------------------------------------------ #
    def submit(self, prompt: np.ndarray, max_new_tokens: int,
               priority: float = 0.0, arrival: Optional[float] = None) -> int:
        rid = next(self._ids)
        req = Request(rid, arrival if arrival is not None else time.time(),
                      np.asarray(prompt), max_new_tokens, priority)
        self._pool[rid] = req
        self._overflow.append(rid)
        self._c_submits.inc()
        if len(self._overflow) >= self.rebuild_threshold:
            self._rebuild()
        return rid

    def _row(self, req: Request) -> np.ndarray:
        return np.array([req.arrival, req.prompt_len,
                         _predict_decode_len(req.prompt_len, req.max_new_tokens),
                         req.priority], np.float32)

    def _rebuild(self) -> None:
        self._c_rebuilds.inc()
        if not self._pool:
            self._index, self._index_rids = None, np.empty(0, np.int64)
            self._overflow = []
            return
        rids = np.array(sorted(self._pool), np.int64)
        rows = np.stack([self._row(self._pool[r]) for r in rids])
        self._index = COAXIndex(rows, self.config) if len(rows) >= 64 else None
        self._index_rids = rids
        self._rows = rows
        self._overflow = []
        self._tombstones = 0

    # ------------------------------------------------------------------ #
    def admit(self, batch_size: int, *,
              prompt_len_range: Tuple[float, float] = (0, np.inf),
              min_priority: float = -np.inf,
              max_predicted_decode: float = np.inf) -> List[Request]:
        """Form a batch: range query over the pool, oldest-first."""
        t0 = time.perf_counter()
        rect = full_rect(len(COLS))
        rect[1] = prompt_len_range
        rect[2, 1] = max_predicted_decode
        rect[3, 0] = min_priority

        hit_rids: List[int] = []
        if self._index is not None:
            rows_idx = self._index.query(rect)
            hit_rids.extend(int(self._index_rids[i]) for i in rows_idx)
        # overflow (not yet indexed) checked in one vectorised pass
        ov = [r for r in self._overflow if r in self._pool]
        if ov:
            ov_rows = np.stack([self._row(self._pool[r]) for r in ov])
            hit_rids.extend(r for r, ok in zip(ov, rect_contains(rect, ov_rows)) if ok)

        cands = [self._pool[r] for r in dict.fromkeys(hit_rids) if r in self._pool]
        cands.sort(key=lambda r: (-r.priority, r.arrival))
        batch = cands[:batch_size]
        for r in batch:
            self._pool.pop(r.rid, None)
        # admitted rows become tombstones (filtered by pool membership above);
        # the index is rebuilt lazily once tombstones+overflow cross the
        # threshold — COAX's cheap bucketed refit makes that a ~ms operation,
        # per-admission rebuilds would dominate latency.
        self._tombstones += len(batch)
        if self._tombstones + len(self._overflow) >= self.rebuild_threshold:
            self._rebuild()
        self._c_admitted.inc(len(batch))
        self._h_admit.observe(time.perf_counter() - t0)
        return batch

    def __len__(self) -> int:
        return len(self._pool)

    def stats(self) -> Dict:
        """Pool shape plus the registry-backed counters (DESIGN.md §10.4).
        Pool/index gauges are derived live (they are state, not events);
        event counts delegate to ``self.metrics`` — the one source of
        truth shared with ``render_text()`` exposition."""
        lat = self._h_admit.summary()
        return {
            "pending": len(self._pool),
            "indexed": int(self._index_rids.size),
            "overflow": len(self._overflow),
            "index_memory": self._index.memory_footprint() if self._index else 0,
            "index_groups": [
                (g.predictor, list(g.dependents)) for g in self._index.groups
            ] if self._index else [],
            "submits": self._c_submits.value(),
            "admitted": self._c_admitted.value(),
            "rebuilds": self._c_rebuilds.value(),
            "admit_p50_ms": lat["p50"] * 1e3,
            "admit_p99_ms": lat["p99"] * 1e3,
        }
