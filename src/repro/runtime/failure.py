"""Fault-tolerance utilities: graceful shutdown, bounded retry, straggler
detection, and failure injection for tests.

On a real multi-pod deployment these hook the cluster manager (preemption
notices arrive as SIGTERM; stragglers feed back into the scheduler).  The
mechanisms themselves — checkpoint-on-signal, retry-from-latest-good,
per-step timing surveillance — are fully exercised here on CPU.
"""
from __future__ import annotations

import dataclasses
import math
import signal
import threading
import time
from typing import Callable, List, Optional

__all__ = ["GracefulShutdown", "retry", "StragglerDetector", "FailureInjector"]


class GracefulShutdown:
    """Installs SIGTERM/SIGINT handlers that flip a flag instead of dying.

    The train loop polls ``requested`` each step and checkpoints + exits
    cleanly — the standard preemption dance on managed clusters.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def requested(self) -> bool:
        return self._flag.is_set()

    def request(self) -> None:  # for tests
        self._flag.set()


def retry(fn: Callable, retries: int = 3, backoff: float = 0.5,
          on_error: Optional[Callable] = None,
          retryable=(RuntimeError, OSError)):
    """Bounded retry with exponential backoff; ``on_error(attempt, exc)``
    runs before each retry (e.g. restore from the latest good checkpoint)."""
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:
            attempt += 1
            if attempt > retries:
                raise
            if on_error is not None:
                on_error(attempt, e)
            time.sleep(backoff * (2 ** (attempt - 1)))


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration: float
    mean: float
    std: float
    z: float


class StragglerDetector:
    """EWMA-based per-step timing surveillance.

    Flags steps slower than ``mean + z_thresh * std``.  At fleet scale the
    same statistic runs per-host on the synchronisation barrier wait time;
    flagged hosts get drained/replaced.  ``hot`` exposes whether mitigation
    (e.g. re-dispatch of that host's shard) should trigger.
    """

    def __init__(self, alpha: float = 0.1, z_thresh: float = 3.0,
                 warmup: int = 5, trip_count: int = 3):
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.warmup = warmup
        self.trip_count = trip_count
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flags: List[StragglerReport] = []
        self._consecutive = 0

    def record(self, step: int, duration: float) -> Optional[StragglerReport]:
        self.n += 1
        if self.n <= self.warmup:
            # seed the statistics
            delta = duration - self.mean
            self.mean += delta / self.n
            self.var += delta * (duration - self.mean)
            return None
        std = math.sqrt(max(self.var / max(self.n - 1, 1), 1e-12))
        z = (duration - self.mean) / std if std > 0 else 0.0
        report = None
        if z > self.z_thresh:
            report = StragglerReport(step, duration, self.mean, std, z)
            self.flags.append(report)
            self._consecutive += 1
        else:
            self._consecutive = 0
        # EWMA update (skip extreme outliers so one straggler doesn't poison
        # the baseline)
        if z <= self.z_thresh * 2:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * duration
            self.var = (1 - self.alpha) * self.var + self.alpha * (duration - self.mean) ** 2
        return report

    @property
    def hot(self) -> bool:
        return self._consecutive >= self.trip_count


class FailureInjector:
    """Deterministic failure injection for fault-tolerance tests."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected failure at step {step}")
