"""Fault-tolerance utilities: graceful shutdown, bounded retry, straggler
detection, and failure injection for tests.

On a real multi-pod deployment these hook the cluster manager (preemption
notices arrive as SIGTERM; stragglers feed back into the scheduler).  The
mechanisms themselves — checkpoint-on-signal, retry-from-latest-good,
per-step timing surveillance — are fully exercised here on CPU.
"""
from __future__ import annotations

import dataclasses
import math
import signal
import threading
import time
from typing import Callable, List, Optional

__all__ = ["GracefulShutdown", "retry", "StragglerDetector",
           "FailureInjector", "FaultPlan"]


class GracefulShutdown:
    """Installs SIGTERM/SIGINT handlers that flip a flag instead of dying.

    The train loop polls ``requested`` each step and checkpoints + exits
    cleanly — the standard preemption dance on managed clusters.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self._flag = threading.Event()
        self._prev = {}
        self._signals = signals

    def __enter__(self):
        for s in self._signals:
            self._prev[s] = signal.signal(s, self._handler)
        return self

    def __exit__(self, *exc):
        for s, h in self._prev.items():
            signal.signal(s, h)
        return False

    def _handler(self, signum, frame):
        self._flag.set()

    @property
    def requested(self) -> bool:
        return self._flag.is_set()

    def request(self) -> None:  # for tests
        self._flag.set()


def retry(fn: Callable, retries: int = 3, backoff: float = 0.5,
          on_error: Optional[Callable] = None,
          retryable=(RuntimeError, OSError)):
    """Bounded retry with exponential backoff; ``on_error(attempt, exc)``
    runs before each retry (e.g. restore from the latest good checkpoint)."""
    attempt = 0
    while True:
        try:
            return fn()
        except retryable as e:
            attempt += 1
            if attempt > retries:
                raise
            if on_error is not None:
                on_error(attempt, e)
            time.sleep(backoff * (2 ** (attempt - 1)))


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration: float
    mean: float
    std: float
    z: float


class StragglerDetector:
    """EWMA-based per-step timing surveillance.

    Flags steps slower than ``mean + z_thresh * std``.  At fleet scale the
    same statistic runs per-host on the synchronisation barrier wait time;
    flagged hosts get drained/replaced.  ``hot`` exposes whether mitigation
    (e.g. re-dispatch of that host's shard) should trigger.
    """

    def __init__(self, alpha: float = 0.1, z_thresh: float = 3.0,
                 warmup: int = 5, trip_count: int = 3):
        self.alpha = alpha
        self.z_thresh = z_thresh
        self.warmup = warmup
        self.trip_count = trip_count
        self.mean = 0.0
        self.var = 0.0
        self.n = 0
        self.flags: List[StragglerReport] = []
        self._consecutive = 0

    def record(self, step: int, duration: float) -> Optional[StragglerReport]:
        self.n += 1
        if self.n <= self.warmup:
            # seed the statistics
            delta = duration - self.mean
            self.mean += delta / self.n
            self.var += delta * (duration - self.mean)
            return None
        std = math.sqrt(max(self.var / max(self.n - 1, 1), 1e-12))
        z = (duration - self.mean) / std if std > 0 else 0.0
        report = None
        if z > self.z_thresh:
            report = StragglerReport(step, duration, self.mean, std, z)
            self.flags.append(report)
            self._consecutive += 1
        else:
            self._consecutive = 0
        # EWMA update (skip extreme outliers so one straggler doesn't poison
        # the baseline)
        if z <= self.z_thresh * 2:
            self.mean = (1 - self.alpha) * self.mean + self.alpha * duration
            self.var = (1 - self.alpha) * self.var + self.alpha * (duration - self.mean) ** 2
        return report

    @property
    def hot(self) -> bool:
        return self._consecutive >= self.trip_count


class FailureInjector:
    """Deterministic failure injection for fault-tolerance tests."""

    def __init__(self, fail_at_steps=(), exc=RuntimeError):
        self.fail_at = set(fail_at_steps)
        self.exc = exc
        self.fired = set()

    def maybe_fail(self, step: int) -> None:
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise self.exc(f"injected failure at step {step}")


class FaultPlan(FailureInjector):
    """Deterministic multi-channel fault schedule (DESIGN.md §8.5).

    Generalises ``FailureInjector``'s step-indexed crashes to NAMED
    channels, each with its own auto-incrementing event counter, so one
    plan scripts an entire partial-failure scenario: shipped-frame drops
    and tears on the replication transport, replica crashes mid-apply,
    a primary kill mid-rotation — every injection keyed by (channel,
    event index) and therefore exactly reproducible.

    ``schedule`` maps ``channel -> {event_index: action}``.  Actions are
    strings or tuples, interpreted by the instrumented component:

    * transport send channels (``"ship.<replica>"``): ``"drop"``, ``"dup"``,
      ``"reorder"`` (hold one frame, release after the next), ``"tear"`` /
      ``("tear", keep_bytes)`` (deliver a truncated frame),
      ``("delay", n)`` (hold for n sends), ``"error"`` /
      ``("error", n)`` (raise ``TransportError`` n times — exercises
      retry+backoff);
    * apply channels (``"<replica>.apply"``): ``"crash"`` — the component
      calls ``crash_if`` and dies mid-apply;
    * rotation channel (``"primary.rotate"``): ``"crash"`` — primary dies
      mid-compaction-rotation, after the new epoch pair is published and
      before old WALs are deleted.

    Every consumed action lands in ``self.log`` and the per-action tallies
    in ``counts()`` — the observability surface the serving stats report.
    """

    def __init__(self, schedule=None, exc=RuntimeError):
        super().__init__((), exc)
        self.schedule = {str(c): dict(m) for c, m in (schedule or {}).items()}
        self.counters = {}
        self.log = []                    # [(channel, event_index, action)]

    def action(self, channel: str):
        """Consume one event on ``channel``; returns the scheduled action
        for this event index (logged), or None."""
        step = self.counters.get(channel, 0)
        self.counters[channel] = step + 1
        act = self.schedule.get(channel, {}).get(step)
        if act is not None:
            self.log.append((channel, step, act))
            self.fired.add((channel, step))
        return act

    def crash_if(self, channel: str) -> None:
        """Consume one event; raise ``exc`` when it is scheduled as a
        ``"crash"`` (the named-channel ``maybe_fail``)."""
        if self.action(channel) == "crash":
            raise self.exc(f"injected crash on {channel} "
                           f"(event {self.counters[channel] - 1})")

    def counts(self) -> dict:
        """{action_name: times_fired} over everything consumed so far."""
        out = {}
        for _, _, act in self.log:
            name = act[0] if isinstance(act, tuple) else act
            out[name] = out.get(name, 0) + 1
        return out
