"""Production training loop: pjit'd steps, atomic/async checkpointing,
preemption handling, bounded retry with restore-from-latest-good, straggler
surveillance, metric logging.

Works identically on a single CPU device (smoke tests / examples) and under
a mesh+rules context (dry-run configs); the loop never touches device state
directly, only through the jitted step.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from ..models.model import Model
from ..optim import AdamWConfig, adamw_init, linear_warmup_cosine
from .checkpoint import Checkpointer, latest_step
from .failure import FailureInjector, GracefulShutdown, StragglerDetector, retry
from .steps import make_train_step

__all__ = ["TrainLoopConfig", "train"]


@dataclasses.dataclass
class TrainLoopConfig:
    steps: int = 100
    ckpt_dir: Optional[str] = None
    ckpt_every: int = 50
    keep: int = 3
    log_every: int = 10
    resume: bool = True
    seed: int = 0
    warmup: int = 10
    max_retries: int = 2
    async_ckpt: bool = True


def train(
    model: Model,
    data_iter: Iterator[Dict[str, Any]],
    opt_cfg: AdamWConfig = AdamWConfig(),
    loop: TrainLoopConfig = TrainLoopConfig(),
    *,
    failure_injector: Optional[FailureInjector] = None,
    log_fn: Callable[[str], None] = print,
) -> Dict[str, Any]:
    """Returns {"params", "opt_state", "history", "stragglers", "restarts"}."""
    lr_sched = linear_warmup_cosine(opt_cfg.lr, loop.warmup, loop.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg, lr_sched))

    ckpt = Checkpointer(loop.ckpt_dir, keep=loop.keep) if loop.ckpt_dir else None
    params, _ = model.init(jax.random.key(loop.seed))
    opt_state = adamw_init(params)
    start_step = 0

    if ckpt and loop.resume and latest_step(loop.ckpt_dir) is not None:
        state = ckpt.restore({"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        start_step = ckpt.manifest()["step"]
        log_fn(f"[train] resumed from step {start_step}")

    detector = StragglerDetector()
    history: List[Dict[str, float]] = []
    restarts = 0

    def save(step, blocking=False):
        if not ckpt:
            return
        tree = {"params": params, "opt": opt_state}
        if loop.async_ckpt and not blocking:
            ckpt.save_async(step, tree, extra={"loss": history[-1]["loss"] if history else None})
        else:
            ckpt.save(step, tree, extra={})

    with GracefulShutdown() as shutdown:
        step = start_step
        while step < loop.steps:
            batch = next(data_iter)
            t0 = time.time()

            def run_step():
                if failure_injector is not None:
                    failure_injector.maybe_fail(step)
                return step_fn(params, opt_state, batch)

            def on_error(attempt, exc):
                nonlocal params, opt_state, restarts
                restarts += 1
                log_fn(f"[train] step {step} failed ({exc}); retry {attempt} "
                       f"from latest checkpoint")
                if ckpt and latest_step(loop.ckpt_dir) is not None:
                    state = ckpt.restore({"params": params, "opt": opt_state})
                    params, opt_state = state["params"], state["opt"]

            params, opt_state, metrics = retry(
                run_step, retries=loop.max_retries, on_error=on_error)
            dt = time.time() - t0
            report = detector.record(step, dt)
            if report is not None:
                log_fn(f"[train] straggler: step {report.step} took "
                       f"{report.duration:.3f}s (z={report.z:.1f})")

            loss = float(metrics["loss"])
            history.append({"step": step, "loss": loss, "dt": dt,
                            "grad_norm": float(metrics["grad_norm"])})
            if step % loop.log_every == 0:
                log_fn(f"[train] step {step} loss {loss:.4f} "
                       f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms")

            step += 1
            if ckpt and step % loop.ckpt_every == 0:
                save(step)
            if shutdown.requested:
                log_fn(f"[train] shutdown requested; checkpointing at step {step}")
                save(step, blocking=True)
                break

    if ckpt:
        save(step, blocking=True)
        ckpt.wait()
    return {"params": params, "opt_state": opt_state, "history": history,
            "stragglers": detector.flags, "restarts": restarts,
            "final_step": step}
