"""Pure step functions handed to pjit: train / prefill / serve.

These close over the Model and optimizer config only — params, optimizer
state, batch and cache all flow through arguments so pjit shardings apply.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models.model import Model
from ..optim import AdamWConfig, adamw_update

__all__ = ["make_train_step", "make_prefill_step", "make_serve_step", "make_eval_step"]


def make_train_step(model: Model, opt_cfg: AdamWConfig,
                    lr_schedule: Optional[Callable] = None,
                    grad_transform: Optional[Callable] = None,
                    microbatches: int = 1):
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    ``grad_transform`` optionally rewrites gradients before the optimizer —
    the hook used by gradient compression (distributed/compression.py).

    ``microbatches > 1`` enables gradient accumulation: the global batch is
    split along dim 0 and scanned, so live activation memory scales with the
    microbatch, not the global batch — the standard production lever for
    fitting large global batches (and the prerequisite for pipeline
    parallelism's microbatch streams).
    """
    from ..models.common import scan_unroll

    def _loss_and_grads(params, batch):
        return jax.value_and_grad(model.loss)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = _loss_and_grads(params, batch)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]), batch)

            def body(carry, mb):
                acc_loss, acc_g = carry
                l, g = _loss_and_grads(params, mb)
                return (acc_loss + l,
                        jax.tree.map(lambda a, b_: a + b_.astype(jnp.float32),
                                     acc_g, g)), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros((), jnp.float32), zeros), micro,
                unroll=scan_unroll())
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        if grad_transform is not None:
            grads = grad_transform(grads)
        params, opt_state, metrics = adamw_update(params, grads, opt_state, opt_cfg,
                                                  lr_schedule)
        metrics["loss"] = loss
        return params, opt_state, metrics
    return train_step


def make_eval_step(model: Model):
    def eval_step(params, batch):
        return model.loss(params, batch)
    return eval_step


def make_prefill_step(model: Model, cache_len: int):
    def prefill_step(params, batch):
        return model.prefill(params, batch, cache_len)
    return prefill_step


def make_serve_step(model: Model):
    def serve_step(params, cache, tokens, step):
        return model.decode_step(params, cache, tokens, step)
    return serve_step
