"""Checkpointing: atomic, async, elastic (mesh-independent restore).

No orbax offline, so this is a complete self-contained implementation:

* **Atomic**: each checkpoint is staged and ``os.rename``d into place via
  the repo-wide idiom in ``storage.atomic`` (shared with the index
  durability plane, DESIGN.md §7.1) — a crash mid-write never corrupts the
  latest good checkpoint; restore scans for the newest *complete* manifest.
* **Async**: ``save_async`` snapshots device arrays to host (blocking only
  for the device->host copy) and writes on a worker thread, overlapping the
  next training steps.
* **Elastic**: arrays are stored as full (unsharded) host arrays + the
  original PartitionSpec metadata; ``restore`` re-deviceputs onto whatever
  mesh/sharding the new job uses, so restarting on a different chip count
  (elastic scaling, failed-node replacement) is a first-class path.
* Bounded retention (``keep``) + content manifest with step/time/tree-spec.
"""
from __future__ import annotations

import json
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np

from ..storage import atomic

__all__ = ["Checkpointer", "latest_step"]

_SEP = "//"


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def _unflatten_into(template, flat: Dict[str, Any]):
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in paths:
        key = _SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf '{key}'")
        leaves.append(flat[key])
    return jax.tree_util.tree_unflatten(treedef, leaves)


def latest_step(directory) -> Optional[int]:
    entries = atomic.complete_entries(Path(directory), "step_")
    return entries[-1][0][0] if entries else None


class Checkpointer:
    def __init__(self, directory, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._last_error: Optional[BaseException] = None

    # ------------------------------------------------------------------ #
    def save(self, step: int, tree, *, extra: Optional[dict] = None) -> Path:
        """Blocking atomic save (flushes any in-flight async save first)."""
        self.wait()
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
        return self._write(step, host, extra or {})

    def save_async(self, step: int, tree, *, extra: Optional[dict] = None) -> None:
        """Device->host copy now; disk write on a background thread."""
        self.wait()  # one in-flight save at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                self._write(step, host, extra or {})
            except BaseException as e:  # surfaced on next wait()
                self._last_error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._last_error is not None:
            err, self._last_error = self._last_error, None
            raise err

    # ------------------------------------------------------------------ #
    def _write(self, step: int, host_tree, extra: dict) -> Path:
        flat = _flatten(host_tree)

        def stage(tmp: Path) -> None:
            np.savez(tmp / "arrays.npz", **flat)
            manifest = {
                "step": step,
                "time": time.time(),
                "leaves": {k: {"shape": list(np.shape(v)),
                               "dtype": str(np.asarray(v).dtype)}
                           for k, v in flat.items()},
                "extra": extra,
            }
            (tmp / "MANIFEST.json").write_text(json.dumps(manifest, indent=2))

        final = atomic.stage_and_rename(self.dir / f"step_{step:08d}", stage)
        atomic.retain(self.dir, "step_", self.keep)
        return final

    # ------------------------------------------------------------------ #
    def restore(self, template, step: Optional[int] = None,
                shardings=None) -> Any:
        """Restore into the structure of ``template``.

        ``shardings``: optional pytree of jax.sharding.Sharding matching the
        template — arrays are device_put onto it (elastic restore onto a new
        mesh).  Without it, arrays come back as host numpy cast to the
        template leaf dtypes.
        """
        step = step if step is not None else latest_step(self.dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {self.dir}")
        path = self.dir / f"step_{step:08d}"
        with np.load(path / "arrays.npz") as z:
            flat = {k: z[k] for k in z.files}
        tree = _unflatten_into(template, flat)

        def _cast(t, v):
            if not hasattr(t, "dtype"):
                return np.asarray(v)
            want = np.dtype(t.dtype)
            v = np.asarray(v)
            if v.dtype.kind == "V" and v.dtype.itemsize == want.itemsize:
                return v.view(want)  # npz round-trips bf16 etc. as void bytes
            return v.astype(want)

        tree = jax.tree.map(_cast, template, tree)
        if shardings is not None:
            tree = jax.tree.map(lambda v, s: jax.device_put(v, s), tree, shardings)
        return tree

    def manifest(self, step: Optional[int] = None) -> dict:
        step = step if step is not None else latest_step(self.dir)
        path = self.dir / f"step_{step:08d}" / "MANIFEST.json"
        return json.loads(path.read_text())
