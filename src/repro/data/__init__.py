from .synth import Dataset, knn_rect_queries, make_airline, make_generic_fd, make_osm

__all__ = ["Dataset", "make_airline", "make_osm", "make_generic_fd", "knn_rect_queries"]
