"""Synthetic datasets matching the paper's evaluation data (Table 1).

The real Airline (80M x 8, US flights 2000-2009) and OSM US-Northeast
(105M x 4) files are not redistributable offline, so these generators
reproduce their published *statistics*: dimensionality, which attribute
groups are correlated, approximate outlier mass (primary-index ratios of
92% / 73%), and the multi-cluster geography of OSM.  Row counts are scaled
by the caller (benchmarks default to a few million on CPU; pass the paper's
counts to regenerate full-scale).

Attribute layouts
-----------------
airline (8 cols):  0 Distance, 1 TimeElapsed, 2 AirTime, 3 DepTime,
                   4 ArrTime, 5 SchedArrTime, 6 DayOfWeek, 7 Carrier
  groups: (0 -> 1, 2)   distance ~ elapsed/air time   [paper §8.1.2]
          (3 -> 4, 5)   departure ~ arrival/scheduled times
osm (4 cols):      0 Id, 1 Timestamp, 2 Lat, 3 Lon
  group:  (0 -> 1)      id ~ timestamp; lat/lon form dense clusters
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

__all__ = ["make_airline", "make_osm", "make_generic_fd", "knn_rect_queries", "Dataset"]


@dataclasses.dataclass
class Dataset:
    name: str
    data: np.ndarray              # (N, D) float32
    correlated_groups: tuple      # ground-truth group layout, for tests


def make_airline(n_rows: int = 1_000_000, seed: int = 0, outlier_frac: float = 0.08) -> Dataset:
    """8-attribute airline-like data; ~92% of rows follow the two soft FDs."""
    rng = np.random.default_rng(seed)
    n = n_rows

    distance = rng.gamma(shape=2.2, scale=420.0, size=n) + 80.0       # miles
    # Block time ~ taxi overhead + distance/speed, with per-row jitter.
    elapsed = 28.0 + distance / 7.2 + rng.normal(0.0, 7.0, n)          # minutes
    airtime = elapsed - (18.0 + rng.normal(0.0, 3.0, n))               # minus taxi

    dep = rng.uniform(300.0, 1380.0, n)                                # minutes-of-day
    arr = dep + elapsed * 0.97 + rng.normal(0.0, 9.0, n)
    sched = arr - rng.normal(4.0, 6.0, n)                              # schedule padding

    day = rng.integers(0, 7, n).astype(np.float64) + rng.uniform(0, 0.01, n)
    carrier = rng.integers(0, 14, n).astype(np.float64) + rng.uniform(0, 0.01, n)

    # Outliers: weather/diversion rows breaking the FD pattern (paper: a
    # 'considerable number of outliers' must be supported).
    n_out = int(outlier_frac * n)
    out = rng.choice(n, size=n_out, replace=False)
    half = n_out // 2
    elapsed[out[:half]] += rng.gamma(2.0, 90.0, half)                  # big delays
    arr[out[half:]] = rng.uniform(0.0, 1440.0, n_out - half)           # red-eye wraps

    data = np.stack([distance, elapsed, airtime, dep, arr, sched, day, carrier], axis=1)
    return Dataset("airline", data.astype(np.float32), ((0, 1, 2), (3, 4, 5)))


def make_osm(n_rows: int = 1_000_000, seed: int = 0, outlier_frac: float = 0.27) -> Dataset:
    """4-attribute OSM-like data; id~timestamp soft FD, clustered lat/lon.

    The paper reports a 73% primary-index ratio for OSM — bulk-imported
    regions have ids far off the id~timestamp trend, modelled here as a
    27% outlier mass with its own offset trends.
    """
    rng = np.random.default_rng(seed)
    n = n_rows

    ids = np.sort(rng.uniform(0.0, 7e9, n))
    t0 = 1.1e9
    # timestamp grows with id (sequential editing), sigma ~ weeks
    ts = t0 + ids * 0.065 + rng.normal(0.0, 3e6, n)

    n_out = int(outlier_frac * n)
    out = rng.choice(n, size=n_out, replace=False)
    # bulk imports: clusters of ids re-stamped at a handful of import dates
    import_dates = t0 + rng.uniform(0.0, 4.5e8, 12)
    ts[out] = rng.choice(import_dates, n_out) + rng.normal(0.0, 1e5, n_out)

    # dense population centres (paper: 'Latitude and Longitude coordinates
    # contain multiple dense areas')
    n_clusters = 9
    centres = np.stack(
        [rng.uniform(40.0, 47.0, n_clusters), rng.uniform(-80.0, -67.0, n_clusters)], axis=1
    )
    which = rng.integers(0, n_clusters, n)
    lat = centres[which, 0] + rng.normal(0.0, 0.35, n)
    lon = centres[which, 1] + rng.normal(0.0, 0.45, n)

    data = np.stack([ids, ts, lat, lon], axis=1)
    return Dataset("osm", data.astype(np.float32), ((0, 1),))


def make_generic_fd(
    n_rows: int,
    n_dims: int,
    fd_pairs: Tuple[Tuple[int, int], ...],
    noise: float = 0.02,
    outlier_frac: float = 0.05,
    seed: int = 0,
) -> Dataset:
    """Parametric generator for property tests: arbitrary (pred, dep) pairs."""
    rng = np.random.default_rng(seed)
    data = rng.uniform(0.0, 1000.0, size=(n_rows, n_dims))
    for pred, dep in fd_pairs:
        m = rng.uniform(0.5, 3.0) * (1 if rng.random() < 0.5 else -1)
        b = rng.uniform(-100.0, 100.0)
        data[:, dep] = m * data[:, pred] + b + rng.normal(0.0, noise * 1000.0, n_rows)
        n_out = int(outlier_frac * n_rows)
        if n_out:
            out = rng.choice(n_rows, size=n_out, replace=False)
            data[out, dep] = rng.uniform(data[:, dep].min(), data[:, dep].max(), n_out)
    return Dataset("generic", data.astype(np.float32), tuple((p, d) for p, d in fd_pairs))


def knn_rect_queries(
    data: np.ndarray,
    n_queries: int,
    k: int,
    seed: int = 0,
    sample_cap: int = 200_000,
) -> np.ndarray:
    """Paper §8.1.2 query workload: pick a random record, take its K nearest
    records, and use the per-dimension min/max of that neighbourhood as the
    query rectangle.  Selectivity is controlled by K.

    KNN runs on a normalised subsample (exact KNN over 100M rows is not the
    point of the workload; the paper's queries target realistic local boxes).
    Returns (Q, D, 2) rects.
    """
    rng = np.random.default_rng(seed)
    n, d = data.shape
    sub = data[rng.choice(n, size=min(sample_cap, n), replace=False)].astype(np.float64)
    scale = sub.std(axis=0)
    scale[scale == 0.0] = 1.0
    sub_n = sub / scale

    centres = data[rng.choice(n, size=n_queries, replace=True)].astype(np.float64)
    rects = np.empty((n_queries, d, 2), dtype=np.float64)
    k_eff = min(k, sub.shape[0])
    for i, c in enumerate(centres):
        dist = np.einsum("nd,nd->n", sub_n - c / scale, sub_n - c / scale)
        nn = np.argpartition(dist, k_eff - 1)[:k_eff]
        pts = sub[nn]
        rects[i, :, 0] = pts.min(axis=0)
        rects[i, :, 1] = np.nextafter(pts.max(axis=0), np.inf)
    return rects
