"""Sharded, resumable, prefetching LM data pipeline.

Documents carry multidimensional metadata with natural soft-FD structure
(byte_len ~ token_len; compute_cost ~ token_len; timestamp ~ doc id), which
is what `curation.py` indexes with COAX.  The token stream itself is
synthetic (deterministic from seed) — the pipeline machinery (sharding,
resumability, prefetch) is the production part.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

__all__ = ["DocCorpus", "ShardedLoader", "make_corpus"]


@dataclasses.dataclass
class DocCorpus:
    """A corpus of documents with correlated metadata columns.

    meta columns: 0 doc_id, 1 timestamp, 2 token_len, 3 byte_len,
                  4 compute_cost, 5 domain_id, 6 quality
    """
    meta: np.ndarray           # (N, 7) float32
    seed: int
    vocab_size: int

    META_COLS = ("doc_id", "timestamp", "token_len", "byte_len",
                 "compute_cost", "domain_id", "quality")

    def tokens_for(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng(self.seed * 1_000_003 + int(doc_id))
        n = int(self.meta[int(doc_id), 2])
        return rng.integers(0, self.vocab_size, size=n).astype(np.int32)


def make_corpus(n_docs: int = 50_000, vocab_size: int = 32_000,
                seed: int = 0) -> DocCorpus:
    rng = np.random.default_rng(seed)
    doc_id = np.arange(n_docs, dtype=np.float64)
    # crawl time grows with id (soft FD), with re-crawl outliers
    ts = 1.6e9 + doc_id * 30.0 + rng.normal(0, 3600.0, n_docs)
    recrawl = rng.random(n_docs) < 0.05
    ts[recrawl] += rng.uniform(3e6, 3e7, recrawl.sum())
    token_len = np.clip(rng.lognormal(6.2, 0.8, n_docs), 64, 32768)
    byte_len = token_len * rng.normal(4.2, 0.25, n_docs)          # soft FD
    compute_cost = token_len * rng.normal(1.0, 0.05, n_docs)      # tight FD
    domain = rng.integers(0, 24, n_docs).astype(np.float64)
    quality = np.clip(rng.beta(4, 2, n_docs) + 0.05 * (domain % 3 == 0), 0, 1)
    meta = np.stack([doc_id, ts, token_len, byte_len, compute_cost,
                     domain, quality], axis=1).astype(np.float32)
    return DocCorpus(meta=meta, seed=seed, vocab_size=vocab_size)


class ShardedLoader:
    """Deterministic, resumable, host-sharded batch iterator with prefetch.

    Every host computes the same global permutation per epoch and takes its
    strided shard — no coordination traffic.  ``state_dict``/``load_state``
    capture (epoch, cursor) so a restore resumes mid-epoch on the exact next
    batch (checkpoint/restart correctness is tested).
    """

    def __init__(self, corpus: DocCorpus, *, batch_size: int, seq_len: int,
                 process_index: int = 0, process_count: int = 1,
                 doc_ids: Optional[np.ndarray] = None, seed: int = 0,
                 prefetch: int = 2):
        self.corpus = corpus
        self.batch_size = batch_size
        self.seq_len = seq_len
        self.process_index = process_index
        self.process_count = process_count
        self.seed = seed
        self.doc_ids = (np.arange(corpus.meta.shape[0], dtype=np.int64)
                        if doc_ids is None else np.asarray(doc_ids, np.int64))
        self.epoch = 0
        self.cursor = 0  # batches CONSUMED within this epoch (this host)
        self._prefetch = prefetch
        self._queue: "queue.Queue" = queue.Queue(maxsize=prefetch)
        self._worker: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # ----------------------------- state ------------------------------- #
    def state_dict(self) -> Dict[str, int]:
        return {"epoch": self.epoch, "cursor": self.cursor}

    def load_state(self, state: Dict[str, int]) -> None:
        self.epoch = int(state["epoch"])
        self.cursor = int(state["cursor"])

    # --------------------------- iteration ----------------------------- #
    def _epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed, epoch))
        order = rng.permutation(self.doc_ids)
        return order[self.process_index::self.process_count]

    def _build_batch(self, docs: np.ndarray) -> Dict[str, np.ndarray]:
        toks = np.zeros((self.batch_size, self.seq_len + 1), np.int32)
        for i, d in enumerate(docs):
            stream = self.corpus.tokens_for(int(d))
            reps = int(np.ceil((self.seq_len + 1) / len(stream)))
            toks[i] = np.tile(stream, reps)[: self.seq_len + 1]
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def _next_indices(self, epoch: int, cursor: int):
        """Docs for position (epoch, cursor) plus the position AFTER it.

        Pure in the loader's public state: the prefetch worker runs ahead of
        the consumer with its own local position, and ``self.epoch``/
        ``self.cursor`` only advance when a batch is actually consumed — so
        ``state_dict`` is exact however far prefetch has run.
        """
        order = self._epoch_order(epoch)
        per_epoch = len(order) // self.batch_size
        if cursor >= per_epoch:
            epoch += 1
            cursor = 0
            order = self._epoch_order(epoch)
        lo = cursor * self.batch_size
        docs = order[lo: lo + self.batch_size]
        return docs, epoch, cursor + 1

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        def work(epoch: int, cursor: int):
            while not self._stop.is_set():
                docs, epoch, cursor = self._next_indices(epoch, cursor)
                item = (epoch, cursor, self._build_batch(docs))
                while not self._stop.is_set():
                    try:
                        self._queue.put(item, timeout=0.2)
                        break
                    except queue.Full:
                        continue

        if self._worker is not None and self._worker.is_alive():
            self._stop.set()                  # retire any previous worker
            self._worker.join()               # before it can feed the new queue
        self._stop.clear()
        self._queue = queue.Queue(maxsize=self._prefetch)  # drop stale prefetch
        self._worker = threading.Thread(
            target=work, args=(self.epoch, self.cursor), daemon=True)
        self._worker.start()
        try:
            while True:
                epoch, cursor, batch = self._queue.get()
                # commit the consumed position (epoch rollover sets cursor=1)
                self.epoch, self.cursor = epoch, cursor
                yield batch
        finally:
            self._stop.set()

    def close(self):
        self._stop.set()
