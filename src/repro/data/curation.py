"""COAX-indexed data curation: the paper's index as a first-class feature of
the training data plane (DESIGN.md §2).

Sample-selection queries in production pipelines are multidimensional range
queries over document metadata — "length in [1k, 8k), quality > 0.8,
crawled after T, domain in {...}" — exactly the workload COAX accelerates.
The metadata columns carry natural soft FDs (byte_len ~ token_len,
compute_cost ~ token_len, timestamp ~ doc_id), so COAX indexes fewer
dimensions than a conventional grid and answers curriculum/filter queries
with the paper's memory/latency profile.

``CuratedSelector`` returns doc-id sets consumable by data.pipeline's
ShardedLoader — the full path data -> COAX -> loader -> train loop.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from ..core import COAXIndex, CoaxConfig, FullScan, full_rect
from .pipeline import DocCorpus

__all__ = ["CuratedSelector", "MetaQuery"]


@dataclasses.dataclass
class MetaQuery:
    """Half-open constraints on named metadata columns."""
    token_len: Optional[Tuple[float, float]] = None
    byte_len: Optional[Tuple[float, float]] = None
    compute_cost: Optional[Tuple[float, float]] = None
    timestamp: Optional[Tuple[float, float]] = None
    doc_id: Optional[Tuple[float, float]] = None
    domain_id: Optional[Tuple[float, float]] = None
    quality: Optional[Tuple[float, float]] = None

    def rect(self, corpus: DocCorpus) -> np.ndarray:
        r = full_rect(len(corpus.META_COLS))
        for i, name in enumerate(corpus.META_COLS):
            bounds = getattr(self, name, None)
            if bounds is not None:
                r[i, 0], r[i, 1] = bounds
        return r


class CuratedSelector:
    """COAX index over corpus metadata with a full-scan reference engine."""

    def __init__(self, corpus: DocCorpus, config: CoaxConfig = CoaxConfig()):
        self.corpus = corpus
        t0 = time.time()
        self.index = COAXIndex(corpus.meta, config)
        self.build_time = time.time() - t0
        self.reference = FullScan(corpus.meta)

    def select(self, query: MetaQuery) -> np.ndarray:
        """Doc ids matching the query (sorted)."""
        return self.index.query(query.rect(self.corpus))

    def select_reference(self, query: MetaQuery) -> np.ndarray:
        return self.reference.query(query.rect(self.corpus))

    def describe(self) -> Dict:
        d = self.index.describe()
        d["build_time_s"] = self.build_time
        d["meta_cols"] = list(self.corpus.META_COLS)
        return d

    def curriculum(self, stages: Sequence[MetaQuery]) -> Dict[int, np.ndarray]:
        """Resolve a staged curriculum (e.g. short->long documents) into
        per-stage doc-id sets via the index."""
        return {i: self.select(q) for i, q in enumerate(stages)}
