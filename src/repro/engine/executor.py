"""Wave-sliced batch executor over any index exposing ``query_batch``.

The executor is the throughput layer between "a pile of rects" and the
vectorised index path: it slices the pile into waves of at most
``max_batch`` queries (bounding the flat candidate/hit buffers the batched
grid probe materialises), runs each wave through one ``query_batch`` call,
and keeps per-wave stats so the serving loop can report QPS and hit rates.
Per-wave ``rows_scanned``/``cells_probed`` come from the index's planning
stage (``last_batch_stats``), so backend comparisons report work done, not
just wall-clock throughput.

``backend="device"`` routes waves through the index's device-resident plan
(DESIGN.md §4); numpy stays the default and the correctness oracle.  When
the index exposes the split ``query_batch_submit``/``query_batch_collect``
wave API, device waves are DOUBLE-BUFFERED: the executor keeps up to two
waves in flight, uploading + launching wave ``i+1`` before draining wave
``i``'s device-resident hit buffers, so host-side wave prep overlaps the
previous wave's fused kernel.  ``WaveStats.latency_s`` is then the full
submit→drain latency of that wave (the p50/p99 the benchmark reports)
while ``stats()['total_s']`` counts non-overlapping wall-clock, so QPS
reflects the pipelining win instead of double-counting overlap.

Telemetry (DESIGN.md §10): per-wave rollups land in a per-executor
``MetricsRegistry`` — the ONE source of truth ``stats()`` reads from in
O(1), replacing the old re-reduce over the whole wave list — and are
mirrored into the process-global registry (`coax_waves_total`,
`coax_queries_total`, `coax_wave_seconds{backend}`) for exposition.  The
retained per-wave rows live in a bounded ring (``wave_history``, default
1024): a long-running server keeps the trailing window for debugging
while the aggregates stay exact over the full run.  With tracing enabled
each wave is one ``wave`` span covering submit→drain; drain-side work
re-attaches wave *k*'s span explicitly so the pipelined wave *k+1* on
the stack never adopts its children (§10.2).

Under the mutable lifecycle (DESIGN.md §5) the index may compact between
waves — the executor re-validates ``index.backend`` per wave and stamps
each ``WaveStats`` with the epoch/delta/tombstone state it was SUBMITTED
from (the snapshot the device plan answers from, even if writes land
before the drain).  Indexes without a ``query_batch`` (e.g. the §8.1.3
baselines) degrade to a per-rect loop inside the same interface, which is
also what the benchmark's ``--batch`` mode compares against.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..core.types import split_hits
from ..obs.metrics import MetricsRegistry

__all__ = ["BatchQueryExecutor", "WaveStats", "split_hits"]

PIPELINE_DEPTH = 2     # waves in flight: upload i+1 while i's kernel runs

WAVE_HISTORY = 1024    # per-wave rows retained (ring); aggregates are exact


@dataclasses.dataclass
class WaveStats:
    wave: int
    n_queries: int
    n_hits: int
    latency_s: float
    rows_scanned: int = 0        # scan-window rows the planning stage visited
    cells_probed: int = 0        # candidate (query, cell) pairs enumerated
    backend: str = "numpy"       # backend that answered this wave
    fallbacks: int = 0           # device waves re-answered by numpy (§4)
    hit_overflows: int = 0       # queries whose hits overflowed the §4
                                 # device hit buffer (re-answered at drain)
    epoch: int = 0               # snapshot epoch the wave was served from (§5)
    delta_rows: int = 0          # live delta-log rows unioned into the wave
    tombstones: int = 0          # tombstoned ids masked out of the wave
    shards_hit: int = 0          # shards the wave scattered to (§6; 0 = unsharded)
    shard_stats: tuple = ()      # per-shard (queries, rows_scanned,
                                 # cells_probed, fallbacks) this wave (§6)
    cache_hits: int = 0          # queries answered exactly from the §9 cache
    cache_partial: int = 0       # queries answered by containment filtering
    cache_bytes: int = 0         # cache residency when the wave was routed

    @property
    def qps(self) -> float:
        return self.n_queries / self.latency_s if self.latency_s > 0 else float("inf")


class BatchQueryExecutor:
    """Runs rect batches through an index in bounded waves.

    Parameters
    ----------
    index : any engine with ``query(rect)``; ``query_batch(rects)`` (flat
        (query_ids, row_ids) contract) is used when present.
    max_batch : wave width — queries per fused ``query_batch`` call.
    backend : ``None`` leaves the index's backend untouched; ``"numpy"`` /
        ``"device"`` set it on indexes that expose one (GridFile/COAXIndex)
        before the first wave.  Requesting ``"device"`` on an index without
        backend support raises.
    shards : ``None`` serves the index as-is.  ``K`` turns on sharded mode
        (DESIGN.md §6): an index that is already a K-shard plane is accepted
        unchanged; a mutable single index (``live_rows`` + ``config``) is
        re-partitioned into a ``ShardedCOAX`` over its live rows.  Waves then
        carry per-shard rollups in ``WaveStats.shard_stats``.
    cache_bytes : byte budget for a §9 semantic result cache attached to
        the index (``attach_cache``); ``None`` leaves caching off.  Hit
        rollups land in ``WaveStats``/``stats()``.
    wave_history : per-wave ``WaveStats`` rows retained in the bounded
        ring behind the ``wave_stats`` property (§10.4 satellite — the
        old unbounded list grew O(waves) on a long-running server).
        Aggregates in ``stats()`` stay exact regardless of eviction.
    """

    def __init__(self, index, max_batch: int = 64,
                 backend: Optional[str] = None,
                 shards: Optional[int] = None,
                 cache_bytes: Optional[int] = None,
                 wave_history: int = WAVE_HISTORY):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if wave_history < 1:
            raise ValueError("wave_history must be >= 1")
        if shards is not None:
            n = getattr(index, "n_shards", None)
            if n is not None:
                if n != shards:
                    raise ValueError(
                        f"index has {n} shards, executor asked for {shards}")
            elif hasattr(index, "live_rows") and hasattr(index, "config"):
                from .sharded import ShardedCOAX
                index = ShardedCOAX.from_index(index, shards)
            else:
                raise ValueError(
                    f"{type(index).__name__} cannot be sharded")
        self.index = index
        self.max_batch = max_batch
        self.wave_history = int(wave_history)
        self._batched = hasattr(index, "query_batch")
        self._requested_backend = backend
        if backend is not None:
            if hasattr(index, "backend"):
                index.backend = backend
            elif backend != "numpy":
                raise ValueError(
                    f"{type(index).__name__} has no device backend")
        if cache_bytes is not None:
            attach = getattr(self.index, "attach_cache", None)
            if attach is None:
                raise ValueError(
                    f"{type(self.index).__name__} has no attach_cache")
            attach(byte_budget=int(cache_bytes))
        self.reset_stats()

    def reset_stats(self) -> None:
        """Fresh ring + fresh per-executor registry (the global-registry
        mirror is monotonic and NOT reset — process counters never go
        backwards)."""
        self._ring: deque = deque(maxlen=self.wave_history)
        self._wave_seq = 0       # waves ever run (ring may hold fewer)
        self._wall_s = 0.0       # non-overlapping busy time (pipelined QPS)
        self._last_done = 0.0    # perf_counter stamp of the last drain
        self._epochs: set = set()
        m = self.metrics = MetricsRegistry()
        self._c_queries = m.counter("queries", "queries answered")
        self._c_hits = m.counter("hits", "hit rows returned")
        self._c_rows = m.counter("rows_scanned", "planning-stage rows")
        self._c_cells = m.counter("cells_probed", "candidate (q,cell) pairs")
        self._c_fallbacks = m.counter("device_fallbacks",
                                      "device waves re-answered on host")
        self._c_fb_waves = m.counter("fallback_waves",
                                     "waves with >=1 fallback")
        self._c_overflows = m.counter("hit_overflows",
                                      "per-query device hit-buffer overflows")
        self._c_cache_hits = m.counter("cache_hits", "exact cache answers")
        self._c_cache_partial = m.counter("cache_partial",
                                          "containment cache answers")
        self._h_wave = m.histogram("wave_seconds", "submit->drain latency",
                                   ("backend",))
        self._g_cache_bytes = m.gauge("cache_bytes", "cache residency")
        self._g_delta = m.gauge("delta_rows", "live delta rows at last wave")
        self._g_tomb = m.gauge("tombstones", "tombstones at last wave")
        self._c_shard = m.counter("shard_queries", "queries per shard",
                                  ("shard",))
        self._c_shard_rows = m.counter("shard_rows_scanned",
                                       "rows per shard", ("shard",))
        self._c_shard_cells = m.counter("shard_cells_probed",
                                        "cells per shard", ("shard",))
        self._c_shard_fb = m.counter("shard_fallbacks",
                                     "fallbacks per shard", ("shard",))

    @property
    def wave_stats(self) -> List[WaveStats]:
        """Trailing window of per-wave rows (bounded ring, §10.4).  Sums
        over it equal ``stats()`` totals only while nothing has been
        evicted (``stats()['waves'] <= wave_history``)."""
        return list(self._ring)

    @property
    def backend(self) -> str:
        """The backend the next wave will be served from — re-read from the
        index every time rather than cached at construction, so an index
        compaction (epoch swap, DESIGN.md §5) or an external backend flip
        mid-stream can never be reported (or served) stale."""
        return self._requested_backend or getattr(self.index, "backend", "numpy")

    def _revalidate_backend(self) -> None:
        """Re-assert the requested backend on the index before a wave: if
        anything reset it (compaction path, another executor sharing the
        index), the wave would otherwise silently serve from the wrong
        plane.  Also the wave-boundary handoff point (DESIGN.md §5.4): a
        finished background compaction installs here, BEFORE the wave
        captures its snapshot, so every wave serves one whole epoch."""
        poll = getattr(self.index, "poll_handoff", None)
        if poll is not None:
            poll()
        if self._requested_backend is None:
            return
        cur = getattr(self.index, "backend", None)
        if cur is not None and cur != self._requested_backend:
            self.index.backend = self._requested_backend

    # ------------------------------------------------------------------ #
    def _run_wave(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        if self._batched:
            return self.index.query_batch(rects)
        hits = [np.asarray(self.index.query(r), dtype=np.int64) for r in rects]
        qids = np.repeat(np.arange(len(hits), dtype=np.int64),
                         [h.size for h in hits])
        rids = np.concatenate(hits) if hits else np.empty(0, np.int64)
        return qids, rids

    def _wave_meta(self) -> Tuple[int, int, int, Tuple[int, int, int]]:
        """Epoch/delta/tombstone + §9 cache state captured at SUBMIT time —
        the frozen snapshot + write-plane state the wave is answered from
        (§4/§5).  Cache stats MUST be read here, not at drain: a pipelined
        wave ``i+1`` routes through the cache (overwriting the index's
        ``last_cache_stats``) before wave ``i`` drains."""
        cs = getattr(self.index, "last_cache_stats", None)
        cache = (cs.hits, cs.partial, cs.bytes) if cs is not None else (0, 0, 0)
        return (int(getattr(self.index, "epoch", 0)),
                int(getattr(self.index, "delta_rows", 0)),
                int(getattr(self.index, "tombstone_count", 0)),
                cache)

    def _record_wave(self, wave: np.ndarray, qids: np.ndarray,
                     rids: np.ndarray, t0: float,
                     meta: Tuple[int, int, int, Tuple[int, int, int]],
                     ) -> List[np.ndarray]:
        """Shared drain-side bookkeeping: wall-clock accounting, per-wave
        stats row (ring), registry aggregates, hit splitting.
        ``latency_s`` is submit→drain; the busy accumulator only charges
        time not already charged to an overlapping wave, so pipelined QPS
        is wall-clock-true."""
        done = time.perf_counter()
        self._wall_s += done - max(t0, self._last_done)
        self._last_done = done
        bs = getattr(self.index, "last_batch_stats", None) \
            if self._batched else None
        ss = getattr(self.index, "last_shard_stats", None) \
            if self._batched else None
        shard_stats = tuple(
            (s.queries, s.rows_scanned, s.cells_probed, s.fallbacks)
            for s in ss) if ss is not None else ()
        ws = WaveStats(
            self._wave_seq, int(wave.shape[0]), int(rids.size),
            done - t0,
            rows_scanned=bs.rows_scanned if bs else 0,
            cells_probed=bs.cells_probed if bs else 0,
            backend=bs.backend if bs else self.backend,
            fallbacks=bs.fallbacks if bs else 0,
            hit_overflows=getattr(bs, "hit_overflows", 0) if bs else 0,
            epoch=meta[0], delta_rows=meta[1], tombstones=meta[2],
            shards_hit=sum(1 for s in shard_stats if s[0] > 0),
            shard_stats=shard_stats,
            cache_hits=meta[3][0], cache_partial=meta[3][1],
            cache_bytes=meta[3][2])
        self._wave_seq += 1
        self._ring.append(ws)
        # -- registry aggregates (stats() reads these in O(1), §10.1) -- #
        self._c_queries.inc(ws.n_queries)
        self._c_hits.inc(ws.n_hits)
        self._c_rows.inc(ws.rows_scanned)
        self._c_cells.inc(ws.cells_probed)
        if ws.fallbacks:
            self._c_fallbacks.inc(ws.fallbacks)
            self._c_fb_waves.inc()
        if ws.hit_overflows:
            self._c_overflows.inc(ws.hit_overflows)
        if ws.cache_hits:
            self._c_cache_hits.inc(ws.cache_hits)
        if ws.cache_partial:
            self._c_cache_partial.inc(ws.cache_partial)
        self._h_wave.observe(ws.latency_s, backend=ws.backend)
        self._g_cache_bytes.set(ws.cache_bytes)
        self._g_delta.set(ws.delta_rows)
        self._g_tomb.set(ws.tombstones)
        self._epochs.add(ws.epoch)
        for k, s in enumerate(shard_stats):
            if s[0]:
                self._c_shard.inc(s[0], shard=k)
            if s[1]:
                self._c_shard_rows.inc(s[1], shard=k)
            if s[2]:
                self._c_shard_cells.inc(s[2], shard=k)
            if s[3]:
                self._c_shard_fb.inc(s[3], shard=k)
        # process-global mirror (exposition; DESIGN.md §10.1)
        g = obs.get_registry()
        g.counter("coax_waves_total", "waves served",
                  ("backend",)).inc(backend=ws.backend)
        g.counter("coax_queries_total", "queries served",
                  ("backend",)).inc(ws.n_queries, backend=ws.backend)
        g.histogram("coax_wave_seconds", "wave submit->drain latency",
                    ("backend",)).observe(ws.latency_s, backend=ws.backend)
        return split_hits(qids, rids, wave.shape[0])

    # -- split wave API (device pipelining; DESIGN.md §4) -------------- #
    def execute_submit(self, rects: Sequence[np.ndarray]):
        """Submit ONE wave (≤ ``max_batch`` rects) without draining it.

        Returns an opaque pending handle for ``execute_collect``, or
        ``None`` when the index has no split wave API / the backend is not
        the device plane — callers then fall back to ``execute``.  The
        device plan snapshots epoch + delta + tombstones here, so writes
        applied before the drain don't leak into the wave."""
        if not (self._batched and self.backend == "device"
                and hasattr(self.index, "query_batch_submit")):
            return None
        wave = np.asarray(rects, dtype=np.float64)
        self._revalidate_backend()
        tr = obs.tracer()
        wsp = tr.start("wave", queries=int(wave.shape[0]),
                       backend="device") if tr else None
        t0 = time.perf_counter()
        if wsp is not None:
            with tr.attach(wsp):       # dispatch/cache spans nest under it
                handle = self.index.query_batch_submit(wave)
        else:
            handle = self.index.query_batch_submit(wave)
        return (wave, handle, t0, self._wave_meta(), wsp)

    def execute_collect(self, pending) -> List[np.ndarray]:
        """Drain one ``execute_submit`` wave; returns one sorted row-id
        array per rect (same contract as ``execute``).  Drain-side spans
        re-attach THIS wave's span (explicit parent), not whatever wave
        is currently on the submit stack (§10.2)."""
        wave, handle, t0, meta, wsp = pending
        tr = obs.tracer()
        if wsp is not None and tr is not None:
            with tr.attach(wsp):
                qids, rids = self.index.query_batch_collect(handle)
            out = self._record_wave(wave, qids, rids, t0, meta)
            tr.finish(wsp, hits=int(rids.size))
            return out
        qids, rids = self.index.query_batch_collect(handle)
        return self._record_wave(wave, qids, rids, t0, meta)

    def execute(self, rects: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Answer every rect; returns one sorted row-id array per rect.

        Device waves with a split submit/collect index API are pipelined
        ``PIPELINE_DEPTH`` deep: wave ``i+1``'s host prep + upload + launch
        happens while wave ``i``'s fused kernel output is still device-
        resident, and only then is ``i`` drained."""
        rects = np.asarray(rects, dtype=np.float64)
        n = rects.shape[0]
        out: List[np.ndarray] = []
        inflight: deque = deque()
        for start in range(0, n, self.max_batch):
            wave = rects[start:start + self.max_batch]
            pending = self.execute_submit(wave)
            if pending is not None:            # pipelined device path
                inflight.append(pending)
                if len(inflight) >= PIPELINE_DEPTH:
                    out.extend(self.execute_collect(inflight.popleft()))
                continue
            while inflight:                    # backend flipped mid-stream
                out.extend(self.execute_collect(inflight.popleft()))
            self._revalidate_backend()
            tr = obs.tracer()
            wsp = tr.start("wave", queries=int(wave.shape[0]),
                           backend=self.backend) if tr else None
            t0 = time.perf_counter()
            if wsp is not None:
                with tr.attach(wsp):
                    qids, rids = self._run_wave(wave)
            else:
                qids, rids = self._run_wave(wave)
            out.extend(self._record_wave(wave, qids, rids, t0,
                                         self._wave_meta()))
            if wsp is not None:
                tr.finish(wsp, hits=int(rids.size))
        while inflight:
            out.extend(self.execute_collect(inflight.popleft()))
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        """O(1) rollup read from the per-executor registry (§10.1) — the
        old implementation re-reduced the whole ``wave_stats`` list on
        every call, O(waves) on the serving path."""
        total_q = int(self._c_queries.total())
        total_s = self._wall_s      # non-overlapping busy time; < sum of
        lat = self._h_wave          # latencies when the pipeline overlapped
        n_shards = int(getattr(self.index, "n_shards", 0))
        per_shard = [
            {"queries": int(self._c_shard.value(shard=k)),
             "rows_scanned": int(self._c_shard_rows.value(shard=k)),
             "cells_probed": int(self._c_shard_cells.value(shard=k)),
             "fallbacks": int(self._c_shard_fb.value(shard=k))}
            for k in range(n_shards)]
        cache_hits = int(self._c_cache_hits.total())
        cache_partial = int(self._c_cache_partial.total())
        return {
            "shards": n_shards,
            "per_shard": per_shard,
            "waves": self._wave_seq,
            "queries": total_q,
            "cache_hits": cache_hits,
            "cache_partial": cache_partial,
            "cache_hit_rate": ((cache_hits + cache_partial) / total_q
                               if total_q else 0.0),
            "cache_bytes": int(self._g_cache_bytes.value()),
            "hits": int(self._c_hits.total()),
            "rows_scanned": int(self._c_rows.total()),
            "cells_probed": int(self._c_cells.total()),
            "device_fallbacks": int(self._c_fallbacks.total()),
            "fallback_waves": int(self._c_fb_waves.total()),
            "hit_overflows": int(self._c_overflows.total()),
            "total_s": total_s,
            "qps": total_q / total_s if total_s > 0 else 0.0,
            "wave_p50_ms": lat.quantile(0.5) * 1e3,
            "wave_p99_ms": lat.quantile(0.99) * 1e3,
            "batched": self._batched,
            "backend": self.backend,
            "epochs": sorted(self._epochs),
            "delta_rows": int(self._g_delta.value()) if self._wave_seq
                          else int(getattr(self.index, "delta_rows", 0)),
            "tombstones": int(self._g_tomb.value()) if self._wave_seq
                          else int(getattr(self.index, "tombstone_count", 0)),
        }
