"""Wave-sliced batch executor over any index exposing ``query_batch``.

The executor is the throughput layer between "a pile of rects" and the
vectorised index path: it slices the pile into waves of at most
``max_batch`` queries (bounding the flat candidate/hit buffers the batched
grid probe materialises), runs each wave through one ``query_batch`` call,
and keeps per-wave stats so the serving loop can report QPS and hit rates.
Per-wave ``rows_scanned``/``cells_probed`` come from the index's planning
stage (``last_batch_stats``), so backend comparisons report work done, not
just wall-clock throughput.

``backend="device"`` routes waves through the index's device-resident plan
(DESIGN.md §4); numpy stays the default and the correctness oracle.  When
the index exposes the split ``query_batch_submit``/``query_batch_collect``
wave API, device waves are DOUBLE-BUFFERED: the executor keeps up to two
waves in flight, uploading + launching wave ``i+1`` before draining wave
``i``'s device-resident hit buffers, so host-side wave prep overlaps the
previous wave's fused kernel.  ``WaveStats.latency_s`` is then the full
submit→drain latency of that wave (the p50/p99 the benchmark reports)
while ``stats()['total_s']`` counts non-overlapping wall-clock, so QPS
reflects the pipelining win instead of double-counting overlap.

Under the mutable lifecycle (DESIGN.md §5) the index may compact between
waves — the executor re-validates ``index.backend`` per wave and stamps
each ``WaveStats`` with the epoch/delta/tombstone state it was SUBMITTED
from (the snapshot the device plan answers from, even if writes land
before the drain).  Indexes without a ``query_batch`` (e.g. the §8.1.3
baselines) degrade to a per-rect loop inside the same interface, which is
also what the benchmark's ``--batch`` mode compares against.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import List, Optional, Sequence, Tuple

import numpy as np

from ..core.types import split_hits

__all__ = ["BatchQueryExecutor", "WaveStats", "split_hits"]

PIPELINE_DEPTH = 2     # waves in flight: upload i+1 while i's kernel runs


@dataclasses.dataclass
class WaveStats:
    wave: int
    n_queries: int
    n_hits: int
    latency_s: float
    rows_scanned: int = 0        # scan-window rows the planning stage visited
    cells_probed: int = 0        # candidate (query, cell) pairs enumerated
    backend: str = "numpy"       # backend that answered this wave
    fallbacks: int = 0           # device waves re-answered by numpy (§4)
    hit_overflows: int = 0       # queries whose hits overflowed the §4
                                 # device hit buffer (re-answered at drain)
    epoch: int = 0               # snapshot epoch the wave was served from (§5)
    delta_rows: int = 0          # live delta-log rows unioned into the wave
    tombstones: int = 0          # tombstoned ids masked out of the wave
    shards_hit: int = 0          # shards the wave scattered to (§6; 0 = unsharded)
    shard_stats: tuple = ()      # per-shard (queries, rows_scanned,
                                 # cells_probed, fallbacks) this wave (§6)
    cache_hits: int = 0          # queries answered exactly from the §9 cache
    cache_partial: int = 0       # queries answered by containment filtering
    cache_bytes: int = 0         # cache residency when the wave was routed

    @property
    def qps(self) -> float:
        return self.n_queries / self.latency_s if self.latency_s > 0 else float("inf")


class BatchQueryExecutor:
    """Runs rect batches through an index in bounded waves.

    Parameters
    ----------
    index : any engine with ``query(rect)``; ``query_batch(rects)`` (flat
        (query_ids, row_ids) contract) is used when present.
    max_batch : wave width — queries per fused ``query_batch`` call.
    backend : ``None`` leaves the index's backend untouched; ``"numpy"`` /
        ``"device"`` set it on indexes that expose one (GridFile/COAXIndex)
        before the first wave.  Requesting ``"device"`` on an index without
        backend support raises.
    shards : ``None`` serves the index as-is.  ``K`` turns on sharded mode
        (DESIGN.md §6): an index that is already a K-shard plane is accepted
        unchanged; a mutable single index (``live_rows`` + ``config``) is
        re-partitioned into a ``ShardedCOAX`` over its live rows.  Waves then
        carry per-shard rollups in ``WaveStats.shard_stats``.
    cache_bytes : byte budget for a §9 semantic result cache attached to
        the index (``attach_cache``); ``None`` leaves caching off.  Hit
        rollups land in ``WaveStats``/``stats()``.
    """

    def __init__(self, index, max_batch: int = 64,
                 backend: Optional[str] = None,
                 shards: Optional[int] = None,
                 cache_bytes: Optional[int] = None):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if shards is not None:
            n = getattr(index, "n_shards", None)
            if n is not None:
                if n != shards:
                    raise ValueError(
                        f"index has {n} shards, executor asked for {shards}")
            elif hasattr(index, "live_rows") and hasattr(index, "config"):
                from .sharded import ShardedCOAX
                index = ShardedCOAX.from_index(index, shards)
            else:
                raise ValueError(
                    f"{type(index).__name__} cannot be sharded")
        self.index = index
        self.max_batch = max_batch
        self.wave_stats: List[WaveStats] = []
        self._batched = hasattr(index, "query_batch")
        self._wall_s = 0.0       # non-overlapping busy time (pipelined QPS)
        self._last_done = 0.0    # perf_counter stamp of the last drain
        self._requested_backend = backend
        if backend is not None:
            if hasattr(index, "backend"):
                index.backend = backend
            elif backend != "numpy":
                raise ValueError(
                    f"{type(index).__name__} has no device backend")
        if cache_bytes is not None:
            attach = getattr(self.index, "attach_cache", None)
            if attach is None:
                raise ValueError(
                    f"{type(self.index).__name__} has no attach_cache")
            attach(byte_budget=int(cache_bytes))

    @property
    def backend(self) -> str:
        """The backend the next wave will be served from — re-read from the
        index every time rather than cached at construction, so an index
        compaction (epoch swap, DESIGN.md §5) or an external backend flip
        mid-stream can never be reported (or served) stale."""
        return self._requested_backend or getattr(self.index, "backend", "numpy")

    def _revalidate_backend(self) -> None:
        """Re-assert the requested backend on the index before a wave: if
        anything reset it (compaction path, another executor sharing the
        index), the wave would otherwise silently serve from the wrong
        plane.  Also the wave-boundary handoff point (DESIGN.md §5.4): a
        finished background compaction installs here, BEFORE the wave
        captures its snapshot, so every wave serves one whole epoch."""
        poll = getattr(self.index, "poll_handoff", None)
        if poll is not None:
            poll()
        if self._requested_backend is None:
            return
        cur = getattr(self.index, "backend", None)
        if cur is not None and cur != self._requested_backend:
            self.index.backend = self._requested_backend

    # ------------------------------------------------------------------ #
    def _run_wave(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        if self._batched:
            return self.index.query_batch(rects)
        hits = [np.asarray(self.index.query(r), dtype=np.int64) for r in rects]
        qids = np.repeat(np.arange(len(hits), dtype=np.int64),
                         [h.size for h in hits])
        rids = np.concatenate(hits) if hits else np.empty(0, np.int64)
        return qids, rids

    def _wave_meta(self) -> Tuple[int, int, int, Tuple[int, int, int]]:
        """Epoch/delta/tombstone + §9 cache state captured at SUBMIT time —
        the frozen snapshot + write-plane state the wave is answered from
        (§4/§5).  Cache stats MUST be read here, not at drain: a pipelined
        wave ``i+1`` routes through the cache (overwriting the index's
        ``last_cache_stats``) before wave ``i`` drains."""
        cs = getattr(self.index, "last_cache_stats", None)
        cache = (cs.hits, cs.partial, cs.bytes) if cs is not None else (0, 0, 0)
        return (int(getattr(self.index, "epoch", 0)),
                int(getattr(self.index, "delta_rows", 0)),
                int(getattr(self.index, "tombstone_count", 0)),
                cache)

    def _record_wave(self, wave: np.ndarray, qids: np.ndarray,
                     rids: np.ndarray, t0: float,
                     meta: Tuple[int, int, int, Tuple[int, int, int]]
                     ) -> List[np.ndarray]:
        """Shared drain-side bookkeeping: wall-clock accounting, per-wave
        stats row, hit splitting.  ``latency_s`` is submit→drain; the busy
        accumulator only charges time not already charged to an overlapping
        wave, so pipelined QPS is wall-clock-true."""
        done = time.perf_counter()
        self._wall_s += done - max(t0, self._last_done)
        self._last_done = done
        bs = getattr(self.index, "last_batch_stats", None) \
            if self._batched else None
        ss = getattr(self.index, "last_shard_stats", None) \
            if self._batched else None
        shard_stats = tuple(
            (s.queries, s.rows_scanned, s.cells_probed, s.fallbacks)
            for s in ss) if ss is not None else ()
        self.wave_stats.append(WaveStats(
            len(self.wave_stats), int(wave.shape[0]), int(rids.size),
            done - t0,
            rows_scanned=bs.rows_scanned if bs else 0,
            cells_probed=bs.cells_probed if bs else 0,
            backend=bs.backend if bs else self.backend,
            fallbacks=bs.fallbacks if bs else 0,
            hit_overflows=getattr(bs, "hit_overflows", 0) if bs else 0,
            epoch=meta[0], delta_rows=meta[1], tombstones=meta[2],
            shards_hit=sum(1 for s in shard_stats if s[0] > 0),
            shard_stats=shard_stats,
            cache_hits=meta[3][0], cache_partial=meta[3][1],
            cache_bytes=meta[3][2]))
        return split_hits(qids, rids, wave.shape[0])

    # -- split wave API (device pipelining; DESIGN.md §4) -------------- #
    def execute_submit(self, rects: Sequence[np.ndarray]):
        """Submit ONE wave (≤ ``max_batch`` rects) without draining it.

        Returns an opaque pending handle for ``execute_collect``, or
        ``None`` when the index has no split wave API / the backend is not
        the device plane — callers then fall back to ``execute``.  The
        device plan snapshots epoch + delta + tombstones here, so writes
        applied before the drain don't leak into the wave."""
        if not (self._batched and self.backend == "device"
                and hasattr(self.index, "query_batch_submit")):
            return None
        wave = np.asarray(rects, dtype=np.float64)
        self._revalidate_backend()
        t0 = time.perf_counter()
        handle = self.index.query_batch_submit(wave)
        return (wave, handle, t0, self._wave_meta())

    def execute_collect(self, pending) -> List[np.ndarray]:
        """Drain one ``execute_submit`` wave; returns one sorted row-id
        array per rect (same contract as ``execute``)."""
        wave, handle, t0, meta = pending
        qids, rids = self.index.query_batch_collect(handle)
        return self._record_wave(wave, qids, rids, t0, meta)

    def execute(self, rects: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Answer every rect; returns one sorted row-id array per rect.

        Device waves with a split submit/collect index API are pipelined
        ``PIPELINE_DEPTH`` deep: wave ``i+1``'s host prep + upload + launch
        happens while wave ``i``'s fused kernel output is still device-
        resident, and only then is ``i`` drained."""
        rects = np.asarray(rects, dtype=np.float64)
        n = rects.shape[0]
        out: List[np.ndarray] = []
        inflight: deque = deque()
        for start in range(0, n, self.max_batch):
            wave = rects[start:start + self.max_batch]
            pending = self.execute_submit(wave)
            if pending is not None:            # pipelined device path
                inflight.append(pending)
                if len(inflight) >= PIPELINE_DEPTH:
                    out.extend(self.execute_collect(inflight.popleft()))
                continue
            while inflight:                    # backend flipped mid-stream
                out.extend(self.execute_collect(inflight.popleft()))
            self._revalidate_backend()
            t0 = time.perf_counter()
            qids, rids = self._run_wave(wave)
            out.extend(self._record_wave(wave, qids, rids, t0,
                                         self._wave_meta()))
        while inflight:
            out.extend(self.execute_collect(inflight.popleft()))
        return out

    # ------------------------------------------------------------------ #
    def stats(self) -> dict:
        total_q = sum(w.n_queries for w in self.wave_stats)
        # non-overlapping busy time; equals sum of latencies when waves are
        # serial, strictly less when the device pipeline overlapped them
        total_s = self._wall_s
        lat_ms = np.array([w.latency_s * 1e3 for w in self.wave_stats])
        n_shards = int(getattr(self.index, "n_shards", 0))
        per_shard = []
        if n_shards:
            acc = np.zeros((n_shards, 4), dtype=np.int64)
            for w in self.wave_stats:
                for k, s in enumerate(w.shard_stats):
                    acc[k] += s
            per_shard = [
                {"queries": int(a[0]), "rows_scanned": int(a[1]),
                 "cells_probed": int(a[2]), "fallbacks": int(a[3])}
                for a in acc]
        cache_hits = sum(w.cache_hits for w in self.wave_stats)
        cache_partial = sum(w.cache_partial for w in self.wave_stats)
        return {
            "shards": n_shards,
            "per_shard": per_shard,
            "waves": len(self.wave_stats),
            "queries": total_q,
            "cache_hits": cache_hits,
            "cache_partial": cache_partial,
            "cache_hit_rate": ((cache_hits + cache_partial) / total_q
                               if total_q else 0.0),
            "cache_bytes": (self.wave_stats[-1].cache_bytes
                            if self.wave_stats else 0),
            "hits": sum(w.n_hits for w in self.wave_stats),
            "rows_scanned": sum(w.rows_scanned for w in self.wave_stats),
            "cells_probed": sum(w.cells_probed for w in self.wave_stats),
            "device_fallbacks": sum(w.fallbacks for w in self.wave_stats),
            "fallback_waves": sum(1 for w in self.wave_stats if w.fallbacks),
            "hit_overflows": sum(w.hit_overflows for w in self.wave_stats),
            "total_s": total_s,
            "qps": total_q / total_s if total_s > 0 else 0.0,
            "wave_p50_ms": float(np.percentile(lat_ms, 50)) if lat_ms.size else 0.0,
            "wave_p99_ms": float(np.percentile(lat_ms, 99)) if lat_ms.size else 0.0,
            "batched": self._batched,
            "backend": self.backend,
            "epochs": sorted({w.epoch for w in self.wave_stats}),
            "delta_rows": self.wave_stats[-1].delta_rows if self.wave_stats
                          else int(getattr(self.index, "delta_rows", 0)),
            "tombstones": self.wave_stats[-1].tombstones if self.wave_stats
                          else int(getattr(self.index, "tombstone_count", 0)),
        }

    def reset_stats(self) -> None:
        self.wave_stats = []
        self._wall_s = 0.0
        self._last_done = 0.0
