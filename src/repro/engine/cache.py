"""Semantic result cache + pinned-epoch MVCC read handles (DESIGN.md §9).

Two read-side constructions that exploit the epoch versioning the mutable
lifecycle already maintains (§5):

``SemanticCache`` — a rect-containment result cache.  Entries store a
    *superset* rect, its flat hit ids and the hit rows (upcast to f64
    once).  A later query whose rect is CONTAINED in a cached rect is
    answered by filtering the cached rows with the exact half-open f32
    predicate (``lo <= v < hi`` after upcast) — the identical membership
    test every backend's pipeline evaluates, so the filtered answer is
    bit-identical to a full probe.  Exactness argument (§9.1): for rects
    Q ⊆ S, every row matching Q matches S (per-dim ``S.lo <= Q.lo`` and
    ``Q.hi <= S.hi``), so S's hit set is a superset of Q's, and filtering
    it with Q's own predicate yields exactly Q's hit set.  This is the
    cache-shaped face of the nav⊇filter invariant: a superset candidate
    set plus the exact filter is always a correct answer.

    Entries are keyed on ``(version, rect-bytes)`` where ``version`` is the
    owning index's write-state version — epoch PLUS the per-plane log and
    tombstone counters, so any write (not just a compaction) moves the key
    and stale entries simply never match (§9.2).  On a sharded plane each
    shard owns its own cache keyed ``(shard_id, shard's OWN version)`` —
    the plane-level aggregate epoch (a sum) is ambiguous as a key and is
    never used (§9.2).  Eviction is LRU under both a byte budget and an
    entry count; a version bump purges the dead generation wholesale.

``EpochPin`` / ``ShardedEpochPin`` — MVCC snapshot-read handles (§9.3).
    ``pin_epoch()`` captures strong references to the pinned epoch's
    ``GridFile`` pair, device plan (jit-cache retention) and a
    ``FrozenDelta`` image of each write plane, refcounted in the index's
    ``_pins`` table.  A background compaction handoff (§5.4) swaps the
    serving index to a new epoch, but the pin keeps the old epoch's objects
    alive and keeps answering from them — release (or ``with`` exit) drops
    the references and the old epoch is freed.  Pinned reads run the exact
    host composition, so they are bit-identical to what the live index
    answered at pin time, no matter how many handoffs install meanwhile.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import List, Optional, Tuple

import numpy as np

from .. import obs
from ..core.translate import translate_rects
from ..core.types import rect_contains, sorted_contains, split_hits

__all__ = ["CacheLookup", "SemanticCache", "EpochPin", "ShardedEpochPin"]

# OrderedDict slot + entry object + key tuple bookkeeping, amortized
_ENTRY_OVERHEAD = 128


@dataclasses.dataclass(frozen=True)
class CacheLookup:
    """Outcome of one wave's cache consult — threaded into ``WaveStats``
    as ``cache_hits``/``cache_partial``/``cache_bytes`` (§9.2)."""

    queries: int = 0
    hits: int = 0        # exact rect matches (same bytes, same version)
    partial: int = 0     # answered by filtering a containing superset entry
    misses: int = 0      # fell through to the full pipeline
    bytes: int = 0       # cache-resident bytes after the consult

    def merge(self, other: "CacheLookup") -> "CacheLookup":
        return CacheLookup(self.queries + other.queries,
                           self.hits + other.hits,
                           self.partial + other.partial,
                           self.misses + other.misses,
                           self.bytes + other.bytes)


class _Entry:
    __slots__ = ("rect", "ids", "rows64", "nbytes")

    def __init__(self, rect, ids, rows64, nbytes):
        self.rect = rect          # (D, 2) f64 superset rect
        self.ids = ids            # sorted i64 hit ids
        self.rows64 = rows64      # (M, D) f64 hit rows, aligned with ids
        self.nbytes = nbytes


class SemanticCache:
    """Rect-containment semantic cache for one index (or one shard).

    Parameters
    ----------
    byte_budget : resident-bytes ceiling; LRU entries evict past it.
    max_entries : entry-count ceiling (bounds the containment scan).
    shard_id : set by ``ShardedCOAX.attach_cache`` — prefixes every version
        key so entries are keyed ``(shard_id, shard's own version)``, never
        the plane's ambiguous aggregate epoch (§9.2).
    """

    def __init__(self, byte_budget: int = 64 << 20, max_entries: int = 512,
                 shard_id: Optional[int] = None):
        if byte_budget < 1 or max_entries < 1:
            raise ValueError("byte_budget and max_entries must be >= 1")
        self.byte_budget = int(byte_budget)
        self.max_entries = int(max_entries)
        self.shard_id = shard_id
        self._prefix = () if shard_id is None else (int(shard_id),)
        self._entries: "OrderedDict[tuple, _Entry]" = OrderedDict()
        self._nbytes = 0
        self._vseen: Optional[tuple] = None
        self._stack = None        # lazily stacked (keys, lo, hi, sizes)
        # lifetime counters (per-wave outcomes live in CacheLookup)
        self.hits = 0
        self.partial = 0
        self.misses = 0
        self.admissions = 0
        self.evictions = 0
        self.invalidations = 0    # entries purged by a version bump
        self.rejections = 0       # admissions refused (entry > whole budget)

    # ------------------------------------------------------------------ #
    @property
    def nbytes(self) -> int:
        return self._nbytes

    def __len__(self) -> int:
        return len(self._entries)

    def _vkey(self, version) -> tuple:
        return self._prefix + tuple(int(v) for v in version)

    def _purge_stale(self, vkey: tuple) -> None:
        """Version moved: every resident entry belongs to a dead generation
        and can never match again — drop them all (the 'invalidation for
        free on epoch bump' contract, §9.2)."""
        if self._vseen == vkey:
            return
        if self._entries:
            self.invalidations += len(self._entries)
            self._entries.clear()
            self._nbytes = 0
            self._stack = None
        self._vseen = vkey

    def _stacked(self):
        """Entry rects stacked for one vectorised containment test per
        wave: (keys, lo (E, D), hi (E, D), sizes (E,))."""
        if self._stack is None:
            keys = list(self._entries.keys())
            rects = np.stack([self._entries[k].rect for k in keys])
            self._stack = (keys,
                           np.ascontiguousarray(rects[:, :, 0]),
                           np.ascontiguousarray(rects[:, :, 1]),
                           np.array([self._entries[k].ids.size for k in keys],
                                    dtype=np.int64))
        return self._stack

    def _evict_lru(self) -> None:
        _, e = self._entries.popitem(last=False)
        self._nbytes -= e.nbytes
        self.evictions += 1
        obs.get_registry().counter(
            "coax_cache_evictions_total", "LRU evictions.").inc()
        self._stack = None

    # ------------------------------------------------------------------ #
    def lookup_wave(self, version, rects: np.ndarray,
                    ) -> Tuple[List[Optional[np.ndarray]], CacheLookup]:
        """Consult the cache for a whole wave.

        Returns ``(answers, stats)``: ``answers[i]`` is the sorted hit-id
        array for ``rects[i]`` — from an exact entry or filtered out of a
        containing superset entry — or ``None`` for a miss the caller must
        run through the full pipeline (and may ``admit`` back)."""
        vkey = self._vkey(version)
        self._purge_stale(vkey)
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        answers: List[Optional[np.ndarray]] = [None] * b
        hits = partial = 0
        open_idx: List[int] = []
        for i in range(b):
            key = (vkey, rects[i].tobytes())
            e = self._entries.get(key)
            if e is not None:
                self._entries.move_to_end(key)
                answers[i] = e.ids
                hits += 1
            else:
                open_idx.append(i)
        if open_idx and self._entries:
            keys, lo, hi, sizes = self._stacked()
            sub = rects[open_idx]                       # (m, D, 2)
            # contained[m, e]: per-dim S.lo <= Q.lo and Q.hi <= S.hi (§9.1)
            contained = (np.all(lo[None, :, :] <= sub[:, None, :, 0], axis=2)
                         & np.all(sub[:, None, :, 1] <= hi[None, :, :], axis=2))
            for j, i in enumerate(open_idx):
                cand = np.nonzero(contained[j])[0]
                if cand.size == 0:
                    continue
                # smallest containing hit set => cheapest exact filter
                key = keys[cand[np.argmin(sizes[cand])]]
                e = self._entries[key]
                self._entries.move_to_end(key)
                answers[i] = e.ids[rect_contains(rects[i], e.rows64)]
                partial += 1
        misses = b - hits - partial
        self.hits += hits
        self.partial += partial
        self.misses += misses
        # one registry touch per wave (not per rect): §10 overhead budget
        c = obs.get_registry().counter(
            "coax_cache_lookups_total", "Cache lookup outcomes per rect.",
            ("outcome",))
        if hits:
            c.inc(hits, outcome="hit")
        if partial:
            c.inc(partial, outcome="partial")
        if misses:
            c.inc(misses, outcome="miss")
        return answers, CacheLookup(queries=b, hits=hits, partial=partial,
                                    misses=misses, bytes=self._nbytes)

    def admit(self, version, rect: np.ndarray, ids: np.ndarray,
              rows: np.ndarray) -> bool:
        """Store one answered rect with its hit ids + rows.  The caller
        guarantees ``version`` is still the index's CURRENT version (the
        §9.2 stale-admission gate — a pipelined device wave may drain
        after a handoff installed a new epoch)."""
        vkey = self._vkey(version)
        self._purge_stale(vkey)
        rect = np.ascontiguousarray(rect, dtype=np.float64)
        key = (vkey, rect.tobytes())
        if key in self._entries:
            self._entries.move_to_end(key)
            return False
        ids = np.ascontiguousarray(ids, dtype=np.int64)
        rows64 = np.ascontiguousarray(rows, dtype=np.float64)
        nbytes = rect.nbytes + ids.nbytes + rows64.nbytes + _ENTRY_OVERHEAD
        if nbytes > self.byte_budget:
            self.rejections += 1          # would evict everything and still
            return False                  # not fit — never admit it
        self._entries[key] = _Entry(rect, ids, rows64, nbytes)
        self._nbytes += nbytes
        self.admissions += 1
        obs.get_registry().counter(
            "coax_cache_admissions_total", "Entries admitted.").inc()
        self._stack = None
        while (self._nbytes > self.byte_budget
               or len(self._entries) > self.max_entries):
            self._evict_lru()
        return True

    def clear(self) -> None:
        self._entries.clear()
        self._nbytes = 0
        self._stack = None

    def describe(self) -> dict:
        return {
            "entries": len(self._entries),
            "bytes": self._nbytes,
            "byte_budget": self.byte_budget,
            "max_entries": self.max_entries,
            "shard_id": self.shard_id,
            "hits": self.hits,
            "partial": self.partial,
            "misses": self.misses,
            "admissions": self.admissions,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
            "rejections": self.rejections,
        }


class EpochPin:
    """MVCC read handle over one ``COAXIndex`` epoch (DESIGN.md §9.3).

    Construction captures strong references to everything a read needs —
    both epoch ``GridFile``s, the learned groups/keep-dims, the §8.2.3
    outlier bbox, a frozen dead-id array, one ``FrozenDelta`` per write
    plane, and the device plan (so its jit cache survives for ``adopt()``)
    — and registers in the index's ``_pins`` refcount table.  Queries run
    the exact HOST composition against that frozen state: answers are
    bit-identical to the live index at pin time, across any number of
    background-compaction handoffs.  ``release()`` (idempotent; also the
    ``with`` exit) drops every reference and decrements the refcount —
    once the last pin of an old epoch releases, its grids and delta image
    become garbage and the epoch's memory is actually freed.
    """

    def __init__(self, index):
        self.epoch = int(index.epoch)
        self.n_dims = int(index.n_dims)
        self.released = False
        self._index = index
        self._groups = list(index.groups)
        self._keep_dims = list(index.keep_dims)
        self._primary = index.primary
        self._outlier = index.outlier
        lo, hi = index._outlier_lo, index._outlier_hi
        self._outlier_lo = None if lo is None else np.array(lo)
        self._outlier_hi = None if hi is None else np.array(hi)
        self._dead = index._dead_ids()              # fresh sorted array
        self._delta_primary = index.delta_primary.freeze()
        self._delta_outlier = index.delta_outlier.freeze()
        self._plan = index._coax_plan               # jit-cache retention

    # ------------------------------------------------------------------ #
    def _check(self) -> None:
        if self.released:
            raise RuntimeError("pin released: this epoch handle no longer "
                               "holds its snapshot")

    def query_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Flat ``(query_ids, row_ids)`` against the pinned epoch — the
        exact host composition of ``COAXIndex._query_batch_host`` over
        frozen state (grids − frozen tombstones ∪ frozen delta)."""
        self._check()
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        if b == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        nav = translate_rects(rects, self._groups, self._keep_dims)
        q, r = self._primary._query_batch_numpy(nav, rects)
        if self._outlier_lo is not None:
            touch = np.all((rects[:, :, 0] <= self._outlier_hi)
                           & (rects[:, :, 1] > self._outlier_lo), axis=1)
            if touch.any():
                sub = rects[touch]
                q_o, r_o = self._outlier._query_batch_numpy(sub, sub)
                if r_o.size:
                    q = np.concatenate([q, np.nonzero(touch)[0][q_o]])
                    r = np.concatenate([r, r_o])
        if self._dead.size and r.size:
            keep = ~sorted_contains(self._dead, r)
            q, r = q[keep], r[keep]
        q1, r1 = self._delta_primary.scan_batch(rects)
        q2, r2 = self._delta_outlier.scan_batch(rects)
        if r1.size or r2.size:
            q = np.concatenate([q, q1, q2])
            r = np.concatenate([r, r1, r2])
        order = np.lexsort((r, q))
        return q[order], r[order]

    def query_batch_split(self, rects: np.ndarray) -> List[np.ndarray]:
        rects = np.asarray(rects, dtype=np.float64)
        qids, rids = self.query_batch(rects)
        return split_hits(qids, rids, rects.shape[0])

    def query(self, rect) -> np.ndarray:
        _, rids = self.query_batch(np.asarray(rect, np.float64)[None])
        return rids

    # ------------------------------------------------------------------ #
    def release(self) -> None:
        if self.released:
            return
        self.released = True
        index, self._index = self._index, None
        self._groups = self._keep_dims = None
        self._primary = self._outlier = self._plan = None
        self._outlier_lo = self._outlier_hi = self._dead = None
        self._delta_primary = self._delta_outlier = None
        if index is not None:
            index._release_pin(self.epoch)

    def __enter__(self) -> "EpochPin":
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class ShardedEpochPin:
    """MVCC read handle over a ``ShardedCOAX`` plane: one ``EpochPin`` per
    shard plus a frozen copy of the shard bboxes (widen-only on the live
    plane, so the frozen copy stays a conservative over-approximation of
    the pinned rows).  Scatter-gathers exactly like the live plane, so a
    pinned sharded read is bit-identical to the plane at pin time (§9.3)."""

    def __init__(self, plane):
        self.n_dims = int(plane.n_dims)
        self.n_shards = int(plane.n_shards)
        self.epoch = int(plane.epoch)
        self.released = False
        self._pins = [s.pin_epoch() for s in plane.shards]
        self._lo = [None if lo is None else np.array(lo)
                    for lo in plane._shard_lo]
        self._hi = [None if hi is None else np.array(hi)
                    for hi in plane._shard_hi]

    @property
    def shard_epochs(self) -> List[int]:
        return [p.epoch for p in self._pins]

    def query_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        if self.released:
            raise RuntimeError("pin released: this epoch handle no longer "
                               "holds its snapshot")
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        if b == 0:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        q_parts: List[np.ndarray] = []
        r_parts: List[np.ndarray] = []
        for k, pin in enumerate(self._pins):
            if self._lo[k] is None:
                continue
            touch = np.all((rects[:, :, 0] <= self._hi[k])
                           & (rects[:, :, 1] > self._lo[k]), axis=1)
            if not touch.any():
                continue
            q_k, r_k = pin.query_batch(rects[touch])
            if r_k.size:
                q_parts.append(np.nonzero(touch)[0][q_k])
                r_parts.append(r_k)
        if not q_parts:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        qids = np.concatenate(q_parts)
        rids = np.concatenate(r_parts)
        order = np.lexsort((rids, qids))
        return qids[order], rids[order]

    def query_batch_split(self, rects: np.ndarray) -> List[np.ndarray]:
        rects = np.asarray(rects, dtype=np.float64)
        qids, rids = self.query_batch(rects)
        return split_hits(qids, rids, rects.shape[0])

    def query(self, rect) -> np.ndarray:
        _, rids = self.query_batch(np.asarray(rect, np.float64)[None])
        return rids

    def release(self) -> None:
        if self.released:
            return
        self.released = True
        pins, self._pins = self._pins, []
        self._lo = self._hi = None
        for p in pins:
            p.release()

    def __enter__(self) -> "ShardedEpochPin":
        return self

    def __exit__(self, *exc) -> None:
        self.release()
