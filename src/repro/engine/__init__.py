"""Batched query execution engine (DESIGN.md §2, §4, §5, §6).

The per-call path (``COAXIndex.query``) answers one rect per Python
round-trip; this package turns B queries into one translation pass, one
directory probe and one fused scan, and wraps that in an admission/drain
server modelled on ``runtime.router``'s continuous-batching loop — the same
pattern, applied to range-query traffic instead of decode requests.
Under the mutable lifecycle (§5) the server also admits inserts/deletes,
flushed at wave boundaries so every wave sees one snapshot+delta state.
``ShardedCOAX`` (§6) scales the same contracts *out*: K per-region shards
behind one scatter-gather plane, each with its own FDs, delta and epochs.

``BatchQueryExecutor`` — wave-sliced ``query_batch`` driver with per-wave stats
``QueryServer``        — submit rects/writes, drain in priority/FIFO waves;
                         wave-boundary WAL fsync + checkpoint cadence and the
                         ``recover()`` restart constructor (§7)
``ShardedCOAX``        — sharded scatter-gather serving plane (§6); journals
                         per shard via ``repro.storage`` (§7.6)
``SemanticCache``      — rect-containment result cache, exact by nav⊇filter
                         and version-keyed for free invalidation (§9.1–§9.2)
``EpochPin``           — pinned-epoch MVCC read handle (``ShardedEpochPin``
                         for a plane): bit-identical snapshot reads across
                         background-compaction handoffs (§9.3)
``DevicePlan``         — device-resident serving plane for one grid (§4)
``CoaxDevicePlan``     — the COAX megakernel plan: primary + outlier +
                         delta/tombstone segments fused into ONE kernel
                         launch per wave, hits compacted into device-
                         resident buffers and drained one wave behind the
                         submit (double-buffered by executor/server);
                         imported lazily so the numpy engine works
                         without jax
"""
from .cache import CacheLookup, EpochPin, SemanticCache, ShardedEpochPin
from .executor import BatchQueryExecutor, WaveStats, split_hits
from .server import PendingQuery, QueryServer
from .sharded import ShardedCOAX, partition_rows

__all__ = [
    "BatchQueryExecutor",
    "WaveStats",
    "split_hits",
    "QueryServer",
    "PendingQuery",
    "ShardedCOAX",
    "partition_rows",
    "SemanticCache",
    "CacheLookup",
    "EpochPin",
    "ShardedEpochPin",
    "DevicePlan",
    "CoaxDevicePlan",
    "device_available",
]


def __getattr__(name):  # PEP 562: keep jax out of the default import path
    if name in ("DevicePlan", "CoaxDevicePlan", "device_available"):
        from . import device
        return getattr(device, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
