"""Sharded scatter-gather serving plane (DESIGN.md §6).

``ShardedCOAX`` partitions rows across K independent ``COAXIndex`` shards —
hash or range partitioning on a chosen attribute — and each shard learns its
*own* soft FDs from only its rows, so per-region correlations sharpen (the
Tsunami insight: correlation-aware structure wins hardest when every data
region gets its own model).  Queries scatter-gather: a per-shard bounding
box prunes shards a rect cannot touch, surviving shards answer their
sub-batch through their own ``query_batch`` (numpy or device backend), and
the hits merge back into the same flat ``(query_id, row_id)`` contract —
bit-identical to a single ``COAXIndex`` over the union of rows, because
every shard is exact over its disjoint row set and the merge re-sorts by
(query, row) exactly as the single-index path does.

Writes route per shard: ``insert`` hashes/ranges each row to its shard and
assigns ids from ONE global sequence (``COAXIndex.insert(rows, ids=...)``),
``delete`` broadcasts ids (globally unique, so per-shard removal counts sum
exactly).  Every shard keeps its own delta planes, drift trackers and
compaction epochs — DESIGN.md §5's invariants hold shard-locally, and one
shard compacting never invalidates another shard's device plan.

The differential-test harness for every (workload × backend × shard-count ×
mutation-schedule) cell lives in ``tests/test_sharded.py``, driven by the
shared registry in ``tests/workloads.py``.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence, Tuple

import numpy as np

from .. import obs
from ..core import COAXIndex, CoaxConfig
from ..core.gridfile import BatchStats
from ..core.types import Rect, split_hits
from .cache import CacheLookup

__all__ = ["ShardedCOAX", "partition_rows"]

_KNUTH = np.uint32(2654435761)


def _hash_route(values: np.ndarray, n_shards: int) -> np.ndarray:
    """Deterministic shard of each float32 value via its bit pattern.

    Fibonacci-hash the raw 32 bits so nearby values spread across shards;
    any fixed value always routes to the same shard, which is all insert
    routing needs (deletes are broadcast, ids are globally unique).
    """
    bits = np.ascontiguousarray(values, dtype=np.float32).view(np.uint32)
    return ((bits * _KNUTH) >> np.uint32(16)).astype(np.int64) % n_shards


def partition_rows(data: np.ndarray, n_shards: int, partition: str,
                   partition_dim: int,
                   boundaries: Optional[np.ndarray] = None,
                   ) -> Tuple[np.ndarray, Optional[np.ndarray]]:
    """Shard index of every row; returns ``(shard_of_row, boundaries)``.

    ``partition="hash"`` bit-hashes the partition attribute; ``"range"``
    splits at K-1 quantile boundaries of the attribute (computed from
    ``data`` when ``boundaries`` is None — the build; passed back in for
    insert routing, so routing stays frozen between compactions).
    """
    col = np.ascontiguousarray(data[:, partition_dim], dtype=np.float32)
    if n_shards == 1:
        return np.zeros(data.shape[0], dtype=np.int64), boundaries
    if partition == "hash":
        return _hash_route(col, n_shards), None
    if partition != "range":
        raise ValueError(f"partition must be 'hash' or 'range', got {partition!r}")
    if boundaries is None:
        qs = np.arange(1, n_shards) / n_shards
        boundaries = (np.quantile(col.astype(np.float64), qs)
                      if col.size else np.zeros(n_shards - 1))
    return np.searchsorted(boundaries, col.astype(np.float64),
                           side="right").astype(np.int64), boundaries


class ShardedCOAX:
    """K independent ``COAXIndex`` shards behind one index interface.

    Exposes the full ``COAXIndex`` serving surface (``query``,
    ``query_batch``, ``query_batch_split``, ``insert``, ``delete``,
    ``live_rows``, stats properties) so ``BatchQueryExecutor`` and
    ``QueryServer`` drive it unchanged; ``last_shard_stats`` additionally
    carries one ``BatchStats`` per shard for per-shard wave rollups.

    Parameters
    ----------
    data : (N, D) rows, partitioned across shards at build.
    config : per-shard ``CoaxConfig`` (compaction triggers fire per shard).
    n_shards : K.
    partition : ``"hash"`` (uniform load) or ``"range"`` (quantile split —
        shard bboxes become disjoint along ``partition_dim``, so pruning
        actually bites).
    partition_dim : the attribute rows are partitioned on.
    groups : optional pre-learned FD groups forced onto EVERY shard;
        default None lets each shard learn its own FDs (the point).
    row_ids : original identities of ``data`` rows (default arange(N)).
    """

    name = "sharded_coax"

    def __init__(self, data: np.ndarray, config: CoaxConfig = CoaxConfig(),
                 n_shards: int = 4, partition: str = "range",
                 partition_dim: int = 0, groups=None,
                 backend: str = "numpy", device_opts: Optional[dict] = None,
                 row_ids: Optional[np.ndarray] = None):
        if n_shards < 1:
            raise ValueError("n_shards must be >= 1")
        data = np.ascontiguousarray(data, dtype=np.float32)
        self.n_dims = data.shape[1]
        self.n_shards = int(n_shards)
        self.partition = partition
        self.partition_dim = int(partition_dim)
        self.config = config
        ids = (np.arange(data.shape[0], dtype=np.int64) if row_ids is None
               else np.asarray(row_ids, dtype=np.int64))
        if ids.shape[0] != data.shape[0]:
            raise ValueError("row_ids length must match data rows")
        self._next_id = int(ids.max()) + 1 if ids.size else 0

        shard_of, self._boundaries = partition_rows(
            data, self.n_shards, partition, self.partition_dim)
        self.shards: List[COAXIndex] = []
        self._shard_lo: List[Optional[np.ndarray]] = []
        self._shard_hi: List[Optional[np.ndarray]] = []
        for k in range(self.n_shards):
            mask = shard_of == k
            rows_k = data[mask]
            self.shards.append(COAXIndex(
                rows_k, config, groups=groups, device_opts=device_opts,
                row_ids=ids[mask]))
            if rows_k.shape[0]:
                self._shard_lo.append(rows_k.min(axis=0).astype(np.float64))
                self._shard_hi.append(rows_k.max(axis=0).astype(np.float64))
            else:
                self._shard_lo.append(None)
                self._shard_hi.append(None)
        self.last_batch_stats = BatchStats()
        self.last_shard_stats: List[BatchStats] = [BatchStats()
                                                   for _ in self.shards]
        self.durable = None     # storage.ShardedDurability, via attach_durability
        self.last_cache_stats = None   # merged CacheLookup of the last wave (§9)
        self._cache_attached = False
        self.backend = backend

    # ------------------------------------------------------------------ #
    @classmethod
    def from_index(cls, index: COAXIndex, n_shards: int,
                   partition: str = "range", partition_dim: int = 0,
                   ) -> "ShardedCOAX":
        """Re-shard an existing (possibly mutated) index: partition its
        live row set, keeping original ids, config and backend.

        A journaled donor is refused: the new plane would start with
        ``durable=None`` while the donor's single-index snapshot+WAL sat
        stale on disk, so every acknowledged write after the re-partition
        would silently vanish at the next recovery.  Save the donor to a
        fresh directory and re-attach the sharded plane explicitly."""
        if getattr(index, "durable", None) is not None:
            raise ValueError(
                "cannot re-partition a journaled index: its durability "
                "history would be silently forked; detach/save first and "
                "attach_durability on the sharded plane")
        rows, ids = index.live_rows()
        out = cls(rows, index.config, n_shards=n_shards,
                  partition=partition, partition_dim=partition_dim,
                  backend=index.backend, device_opts=index._device_opts,
                  row_ids=ids)
        # carry the donor's id high-water mark: the max live id understates
        # it when the highest-id rows were deleted, and a reused id would
        # alias a client's handle to a dead row
        out._next_id = max(out._next_id, int(getattr(index, "_next_id", 0)))
        return out

    # ------------------------------------------------------------------ #
    # Durability (DESIGN.md §7.6)
    # ------------------------------------------------------------------ #
    @classmethod
    def _restore_parts(cls, spec: dict, shards: List[COAXIndex],
                       backend: str = "numpy") -> "ShardedCOAX":
        """Assemble a plane from a recovered partitioner spec + per-shard
        recovered indexes (``storage.durability._restore_sharded``).

        Shard bboxes are recomputed from each shard's live rows — tighter
        than the crashed plane's widen-only boxes is fine, because a bbox
        only gates PRUNING and every live row stays covered (conservative
        over-approximation, §6).  The global id sequence resumes at the max
        of the spec's checkpointed high-water mark and every shard's
        recovered ``_next_id`` (each insert journaled its assigned ids into
        its shard, so the max never understates the crashed sequence)."""
        out = cls.__new__(cls)
        out.n_dims = int(spec["n_dims"])
        out.n_shards = int(spec["n_shards"])
        out.partition = spec["partition"]
        out.partition_dim = int(spec["partition_dim"])
        out.config = shards[0].config if shards else None
        out._boundaries = (None if spec["boundaries"] is None
                           else np.asarray(spec["boundaries"], np.float64))
        out.shards = list(shards)
        out._shard_lo, out._shard_hi = [], []
        for s in out.shards:
            rows, _ = s.live_rows()
            if rows.shape[0]:
                out._shard_lo.append(rows.min(axis=0).astype(np.float64))
                out._shard_hi.append(rows.max(axis=0).astype(np.float64))
            else:
                out._shard_lo.append(None)
                out._shard_hi.append(None)
        out._next_id = max([int(spec["next_id"])]
                           + [s._next_id for s in out.shards])
        out.last_batch_stats = BatchStats()
        out.last_shard_stats = [BatchStats() for _ in out.shards]
        out.durable = None
        out.last_cache_stats = None
        out._cache_attached = False
        out.backend = backend
        return out

    def save(self, directory, keep: Optional[int] = None):
        """Full-state save: partitioner spec + one self-contained snapshot
        per shard.  Saving into the attached durability directory routes
        through ``ShardedDurability.checkpoint`` (journal-consistent
        ``wal_seq`` stamps); any other target gets a standalone copy —
        the shard-migration / replica-seeding artifact."""
        from pathlib import Path
        from ..storage import ShardedDurability, write_snapshot
        directory = Path(directory)
        if (self.durable is not None
                and directory.resolve() == self.durable.directory.resolve()):
            return self.durable.checkpoint(keep=keep)
        ShardedDurability(self, directory).write_spec()
        return [write_snapshot(s, ShardedDurability.shard_dir(directory, k),
                               keep=keep)
                for k, s in enumerate(self.shards)]

    @classmethod
    def restore(cls, directory, backend: str = "numpy",
                device_opts: Optional[dict] = None,
                durable: bool = False) -> "ShardedCOAX":
        """Recover a sharded plane (per-shard snapshot + WAL replay); see
        ``repro.storage.restore``."""
        from ..storage import restore as _restore
        out = _restore(directory, backend=backend, device_opts=device_opts,
                       durable=durable)
        if not isinstance(out, cls):
            raise TypeError(f"{directory} holds a {type(out).__name__} "
                            f"snapshot, not {cls.__name__}")
        return out

    def attach_durability(self, directory, keep: int = 3,
                          sync_every_op: bool = False) -> "ShardedCOAX":
        """Journal every shard's writes under ``directory`` (per-shard
        WALs + snapshots, one partitioner spec).  Returns self."""
        from ..storage import ShardedDurability
        ShardedDurability.attach(self, directory, keep=keep,
                                 sync_every_op=sync_every_op)
        return self

    # ------------------------------------------------------------------ #
    @property
    def backend(self) -> str:
        return self.shards[0].backend

    @backend.setter
    def backend(self, value: str) -> None:
        for s in self.shards:
            s.backend = value

    @property
    def n_rows(self) -> int:
        return sum(s.n_rows for s in self.shards)

    @property
    def delta_rows(self) -> int:
        return sum(s.delta_rows for s in self.shards)

    @property
    def tombstone_count(self) -> int:
        return sum(s.tombstone_count for s in self.shards)

    @property
    def epoch(self) -> int:
        """Monotone plane version: total compactions across shards (each
        shard's epoch advances independently; the sum stamps wave stats).

        The sum is AMBIGUOUS as a cache/snapshot key — shard A at epoch 2 +
        shard B at 0 sums the same as A at 1 + B at 1 — so the §9 semantic
        cache never keys on it: ``attach_cache`` gives each shard its own
        cache keyed on ``(shard_id, the shard's OWN version)``."""
        return sum(s.epoch for s in self.shards)

    @property
    def compactions(self) -> int:
        return sum(s.compactions for s in self.shards)

    @property
    def trigger_checks(self) -> int:
        return sum(s.trigger_checks for s in self.shards)

    @property
    def background_compactions(self) -> int:
        return sum(s.background_compactions for s in self.shards)

    def poll_handoff(self, wait: bool = False) -> bool:
        """Fan the §5.4 epoch-handoff poll across shards (each shard's
        background compactor runs independently); True iff any shard
        installed a finished build."""
        installed = False
        for s in self.shards:
            installed |= s.poll_handoff(wait=wait)
        return installed

    def finish_handoff(self) -> bool:
        """Join every shard's in-flight background compaction — the
        graceful-shutdown barrier, fanned out."""
        return self.poll_handoff(wait=True)

    # ------------------------------------------------------------------ #
    # Semantic cache + MVCC pins (DESIGN.md §9), fanned out per shard
    # ------------------------------------------------------------------ #
    def attach_cache(self, byte_budget: int = 64 << 20,
                     max_entries: int = 512) -> "ShardedCOAX":
        """Attach one §9.2 ``SemanticCache`` PER SHARD (budget split K
        ways), each keyed on ``(shard_id, the shard's own version)`` —
        never the aggregate ``epoch`` sum, which is ambiguous (a compaction
        in shard A and an insert in shard B can collide).  Returns self."""
        per = max(int(byte_budget) // self.n_shards, 1)
        for k, s in enumerate(self.shards):
            s.attach_cache(byte_budget=per, max_entries=max_entries,
                           shard_id=k)
        self._cache_attached = True
        self.last_cache_stats = None
        return self

    def detach_cache(self) -> None:
        for s in self.shards:
            s.detach_cache()
        self._cache_attached = False
        self.last_cache_stats = None

    def pin_epoch(self):
        """One §9.3 MVCC handle over the whole plane: pins every shard's
        current epoch at once (plus frozen copies of the pruning bboxes),
        so scatter-gather reads through the handle stay bit-identical to
        this instant while any shard compacts underneath."""
        from .cache import ShardedEpochPin
        return ShardedEpochPin(self)

    @property
    def pinned_epochs(self) -> List[List[int]]:
        return [s.pinned_epochs for s in self.shards]

    # ------------------------------------------------------------------ #
    # Write path: route per shard, ids from one global sequence
    # ------------------------------------------------------------------ #
    def _route(self, rows: np.ndarray) -> np.ndarray:
        shard_of, _ = partition_rows(rows, self.n_shards, self.partition,
                                     self.partition_dim,
                                     boundaries=self._boundaries)
        return shard_of

    def insert(self, rows: np.ndarray) -> np.ndarray:
        """Insert rows, routed to their shard; returns globally unique ids
        in input order (identical to the ids a single ``COAXIndex`` would
        assign for the same insert sequence)."""
        rows = np.ascontiguousarray(np.atleast_2d(
            np.asarray(rows, dtype=np.float32)))
        if rows.ndim != 2 or rows.shape[1] != self.n_dims:
            raise ValueError(f"rows must be (m, {self.n_dims}), got {rows.shape}")
        m = rows.shape[0]
        ids = np.arange(self._next_id, self._next_id + m, dtype=np.int64)
        self._next_id += m
        if m == 0:
            return ids
        shard_of = self._route(rows)
        for k in np.unique(shard_of):
            mask = shard_of == k
            sub = rows[mask]
            self.shards[k].insert(sub, ids=ids[mask])
            lo, hi = sub.min(axis=0).astype(np.float64), sub.max(axis=0).astype(np.float64)
            if self._shard_lo[k] is None:
                self._shard_lo[k], self._shard_hi[k] = lo, hi
            else:   # bbox only ever widens: over-approximation keeps pruning safe
                self._shard_lo[k] = np.minimum(self._shard_lo[k], lo)
                self._shard_hi[k] = np.maximum(self._shard_hi[k], hi)
        return ids

    def delete(self, row_ids) -> int:
        """Delete by original id, broadcast to every shard — ids are
        globally unique, so at most one shard absorbs each and the per-shard
        removal counts sum exactly."""
        ids = np.asarray(row_ids, dtype=np.int64).reshape(-1)
        return sum(s.delete(ids) for s in self.shards)

    def compact(self, relearn: Optional[bool] = None) -> List[dict]:
        """Force-compact every shard (auto-compaction fires per shard on
        its own triggers; this is the explicit all-shards form)."""
        return [s.compact(relearn=relearn) for s in self.shards]

    def live_rows(self) -> Tuple[np.ndarray, np.ndarray]:
        """(rows, ids) of every live row across shards — the scratch-
        rebuild oracle's input, ordered shard-major."""
        parts = [s.live_rows() for s in self.shards]
        return (np.concatenate([p[0] for p in parts]),
                np.concatenate([p[1] for p in parts]))

    # ------------------------------------------------------------------ #
    # Read path: prune by shard bbox, scatter, gather, merge
    # ------------------------------------------------------------------ #
    def _touch_mask(self, rects: np.ndarray) -> np.ndarray:
        """(K, B) bool: can rect b intersect shard k's bounding box?
        Half-open rect [lo, hi) vs closed bbox [blo, bhi]: lo <= bhi and
        hi > blo on every dim — the §8.2.3 test, per shard."""
        b = rects.shape[0]
        out = np.zeros((self.n_shards, b), dtype=bool)
        for k in range(self.n_shards):
            if self._shard_lo[k] is None:
                continue
            out[k] = np.all((rects[:, :, 0] <= self._shard_hi[k])
                            & (rects[:, :, 1] > self._shard_lo[k]), axis=1)
        return out

    def query(self, rect: Rect) -> np.ndarray:
        rect = np.asarray(rect, dtype=np.float64)
        touch = self._touch_mask(rect[None])[:, 0]
        hits = [self.shards[k].query(rect)
                for k in range(self.n_shards) if touch[k]]
        if not hits:
            return np.empty(0, np.int64)
        return np.sort(np.concatenate(hits))

    def query_batch(self, rects: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Scatter-gather B queries across shards.

        Each shard answers only the sub-batch of rects that can touch its
        bbox; sub-batch query ids are remapped to batch ids and the K hit
        lists merge under one (query, row) lexsort — bit-identical to a
        single index over the union of rows, because shard row sets are
        disjoint and each shard's answer is exact.
        """
        rects = np.asarray(rects, dtype=np.float64)
        b = rects.shape[0]
        self.last_shard_stats = [BatchStats(backend=self.backend)
                                 for _ in self.shards]
        if b == 0:
            self.last_batch_stats = BatchStats(backend=self.backend)
            return np.empty(0, np.int64), np.empty(0, np.int64)
        touch = self._touch_mask(rects)
        q_parts: List[np.ndarray] = []
        r_parts: List[np.ndarray] = []
        merged = BatchStats(queries=b, backend=self.backend)
        cache_stats = None
        hit_shards = 0
        with obs.span("shard.scatter", queries=b,
                      shards=self.n_shards) as sp:
            for k in range(self.n_shards):
                if not touch[k].any():
                    continue
                hit_shards += 1
                sub = rects[touch[k]]
                with obs.span("shard.query", shard=k, queries=len(sub)):
                    q_k, r_k = self.shards[k].query_batch(sub)
                stats_k = dataclasses.replace(
                    self.shards[k].last_batch_stats,
                    queries=int(touch[k].sum()))
                self.last_shard_stats[k] = stats_k
                merged = merged.merge(stats_k)
                cs_k = self.shards[k].last_cache_stats
                if cs_k is not None:
                    cache_stats = cs_k if cache_stats is None \
                        else cache_stats.merge(cs_k)
                if r_k.size:
                    q_parts.append(np.nonzero(touch[k])[0][q_k])
                    r_parts.append(r_k)
            if sp is not None:
                sp.args["shards_hit"] = hit_shards
        reg = obs.get_registry()
        reg.counter("coax_shard_subqueries_total",
                    "(rect, shard) pairs dispatched after bbox pruning."
                    ).inc(int(touch.sum()))
        reg.counter("coax_shard_subqueries_pruned_total",
                    "(rect, shard) pairs skipped by bbox pruning."
                    ).inc(int(touch.size - touch.sum()))
        merged.queries = b
        self.last_batch_stats = merged
        if self._cache_attached:
            self.last_cache_stats = cache_stats or CacheLookup()
        if not q_parts:
            return np.empty(0, np.int64), np.empty(0, np.int64)
        qids = np.concatenate(q_parts)
        rids = np.concatenate(r_parts)
        order = np.lexsort((rids, qids))
        return qids[order], rids[order]

    def query_batch_split(self, rects: np.ndarray) -> List[np.ndarray]:
        rects = np.asarray(rects, dtype=np.float64)
        qids, rids = self.query_batch(rects)
        return split_hits(qids, rids, rects.shape[0])

    # ------------------------------------------------------------------ #
    def shard_sizes(self) -> List[int]:
        return [s.n_rows for s in self.shards]

    def memory_footprint(self) -> int:
        bbox = sum(lo.nbytes + hi.nbytes
                   for lo, hi in zip(self._shard_lo, self._shard_hi)
                   if lo is not None)
        bounds = self._boundaries.nbytes if self._boundaries is not None else 0
        return sum(s.memory_footprint() for s in self.shards) + bbox + bounds

    def describe(self) -> dict:
        return {
            "n_shards": self.n_shards,
            "partition": self.partition,
            "partition_dim": self.partition_dim,
            "n_rows": self.n_rows,
            "shard_sizes": self.shard_sizes(),
            "epoch": self.epoch,
            "compactions": self.compactions,
            "delta_rows": self.delta_rows,
            "tombstones": self.tombstone_count,
            "trigger_checks": self.trigger_checks,
            "background": {
                "enabled": bool(self.config.background_compact)
                if self.config is not None else False,
                "in_flight": sum(s._handoff_thread is not None
                                 for s in self.shards),
                "completed": self.background_compactions,
            },
            "delta_runs": [s.delta_primary.n_runs + s.delta_outlier.n_runs
                           for s in self.shards],
            "shard_epochs": [s.epoch for s in self.shards],
            "cache": ([s.cache.describe() for s in self.shards]
                      if self._cache_attached else None),
            "pinned_epochs": self.pinned_epochs,
            "shard_groups": [[(g.predictor, list(g.dependents))
                              for g in s.groups] for s in self.shards],
            "memory_footprint_bytes": self.memory_footprint(),
            "durability": (self.durable.describe()
                           if self.durable is not None else None),
        }
