"""Device-resident batch query plane (DESIGN.md §4): the megakernel wave.

The old pipeline ran three device stages per wave (probe → candidate-cell
expansion + bisect → windowed filter) and shipped a (B, N) hit mask back to
the host; the §5 delta/tombstone scan then ran on the host.  This module
replaces all of it with ONE launch per wave of the ``kernels.fused_scan``
megakernel, driven by the per-row candidacy identity (DESIGN.md §4):

    a row is in the numpy path's refined candidate blocks
      ⟺  its cell coordinate lies in the host-probed [first, last] on every
          grid dim  ∧  its sorted attribute lies in [t_lo, t_hi)

so probe + segment search collapse into a branch-free membership test the
kernel evaluates alongside the exact full-predicate filter and the liveness
mask — ``hit = alive ∧ candidate ∧ inside`` — and the nav⊇filter invariant
makes the result bit-identical to numpy.

Frozen per-grid image (``_GridImage``, uploaded once per epoch):
  * ``rows_t``  (D, N_pad) f32 records, ``+inf``-padded to a tile multiple;
  * ``coords``  (k, N_pad) i32 per-dim cell coordinate of every row (the
    device twin of the directory: mixed-radix decode of each row's cell);
  * ``sv``      (1, N_pad) f32 in-cell sorted attribute;
  * ``alive``   (1, N_pad) i32 liveness (tombstones re-uploaded only when
    the tombstone counters move);
  * host f32 edge images (``f32_ceil``/``f32_floor`` paired rounding) for
    the ONE conservative host directory pass per wave that yields
    [first, last], the ``cell_cap`` overflow pre-check AND the
    ``cells_probed`` stat (previously two passes).

Per wave, every segment — primary grid, outlier grid, and the fixed-shape
delta/tombstone image of the live append log — goes into ONE jitted
``_wave_program`` dispatch (``dispatch_count`` asserts one launch per
wave).  On the CPU-oracle route the grid segments additionally ship
per-query candidate gather-index images (and skew-split into thin/fat
sub-segments, still one dispatch) so per-wave work scales with candidate
counts, not table size — DESIGN.md §4 "CPU oracle fast path".  Outputs stay device-resident and compacted (per-query hit count +
first ``hit_cap`` hit positions); nothing transfers until ``collect`` — the
explicit drain point (``jax.block_until_ready``) — so a submitted wave can
overlap the previous wave's drain (the executor/server double-buffering
schedule, depth 2).

Overflow contracts (both exact):
  * ``cell_cap`` — detected at SUBMIT from the host probe; the whole wave
    is answered by the numpy path (``fallbacks`` stat).
  * ``hit_cap``  — detected at DRAIN from the exact device counts; only the
    overflowing queries are re-answered on the host FROM CAPTURED STATE
    (frozen grids + the tombstone set and delta log captured at submit), so
    interleaved writes between submit and drain cannot shift the wave's
    snapshot (``hit_overflows`` stat).

Shape bucketing: wave width pads to a pow2 bucket (min ``min_bucket``),
grid images to a pow2 row count (min ``tile``), and the delta image to
``max(128, pow2)`` rows, so steady-state serving — and epoch swaps under
background compaction (§5.4), via ``_PlanBase.adopt`` — re-enter compiled
executables; ``compile_count`` exposes the jit cache size for the
regression test.

Epoch versioning (DESIGN.md §5): images freeze ONE snapshot epoch;
compaction swaps the grids, which invalidates the plan by identity
(``COAXIndex`` checks ``plan.primary is self.primary``) — in-flight tickets
keep draining against the frozen images they captured.
"""
from __future__ import annotations

import functools
import time
from typing import Optional, Tuple

import numpy as np

from .. import obs
from ..core.gridfile import BatchStats, f32_ceil
from ..core.types import sorted_contains

__all__ = ["DevicePlan", "CoaxDevicePlan", "device_available", "f32_floor"]

try:  # the container bakes jax in; gate anyway so numpy-only installs work
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only without jax
    jax = None
    jnp = None
    _HAVE_JAX = False

DELTA_TILE = 128          # delta images bucket to max(128, pow2(m)) rows


def device_available() -> bool:
    """True when the jax runtime needed by the device plans is importable."""
    return _HAVE_JAX


def f32_floor(x: np.ndarray) -> np.ndarray:
    """Largest float32 <= x, elementwise (the mirror of ``gridfile.f32_ceil``)."""
    x = np.asarray(x, dtype=np.float64)
    with np.errstate(over="ignore"):
        y = x.astype(np.float32)
        rounded_up = y.astype(np.float64) > x
        # nextafter past f32 min overflows to -inf — the correct floor there
        return np.where(rounded_up, np.nextafter(y, np.float32(-np.inf)), y)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1


def _multi_arange(starts: np.ndarray, lens: np.ndarray) -> np.ndarray:
    """Concatenate ``[arange(s, s + l) for s, l in zip(starts, lens)]``
    without a Python loop (the candidate-block flattening primitive)."""
    keep = lens > 0
    starts, lens = starts[keep], lens[keep]
    tot = int(lens.sum())
    if not tot:
        return np.empty(0, np.int64)
    step = np.ones(tot, np.int64)
    step[0] = starts[0]
    ends = np.cumsum(lens)[:-1]
    step[ends] = starts[1:] - (starts[:-1] + lens[:-1] - 1)
    return np.cumsum(step)


def _wave_program(segs, config):
    """ONE wave = one dispatch of this jitted program over every segment.

    ``segs`` is a tuple of array dicts (a pytree), ``config`` the matching
    tuple of static per-segment tuples ``(tile, hit_cap, probe, has_sort,
    use_pallas, interpret, gw)``.  Each segment runs the fused megakernel
    (the Pallas kernel on accelerators, its jnp oracle — same contract — on
    CPU; ``gw > 0`` additionally restricts the oracle to each query's
    probe-derived candidate rows via a gather-index image, an
    exactness-preserving CPU fast path) and returns its compacted
    ``(counts, hits, scanned)``.
    """
    from ..kernels import ref
    from ..kernels.fused_scan import fused_scan_call

    out = []
    for seg, (tile, hit_cap, probe, has_sort, use_pallas, interpret,
              gw) in zip(segs, config):
        kwargs = {}
        if probe:
            kwargs.update(coords=seg["coords"], first=seg["first"],
                          last=seg["last"])
        if has_sort:
            kwargs.update(sv=seg["sv"], tband=seg["tband"])
        if use_pallas:
            out.append(fused_scan_call(
                seg["rows"], seg["flo"], seg["fhi"], seg["alive"],
                tile=tile, hit_cap=hit_cap, interpret=interpret, **kwargs))
        else:
            if gw:
                kwargs["gidx"] = seg["gidx"]
            out.append(ref.fused_scan_ref(
                seg["rows"], seg["flo"], seg["fhi"], seg["alive"],
                tile=tile, hit_cap=hit_cap, **kwargs))
    return tuple(out)


class _GridImage:
    """Frozen device image of one ``GridFile`` epoch (uploaded once) plus
    the host-side conservative f32 directory for the per-wave probe."""

    def __init__(self, grid, tile: int):
        n, k = grid.n_rows, len(grid.grid_dims)
        c = grid.cells_per_dim
        self.grid = grid
        self.tile = int(tile)
        self.n = n
        self.grid_pos = [grid.index_dims.index(d) for d in grid.grid_dims]
        self.sort_pos = (grid.index_dims.index(grid.sort_dim)
                         if grid.sort_dim is not None else None)
        self.has_sort = grid.sort_vals is not None

        edges = (np.stack(grid.inner_edges) if k
                 else np.zeros((0, 0), np.float64))
        self.edges_up_h = f32_ceil(edges).astype(np.float32)
        self.edges_down_h = f32_floor(edges).astype(np.float32)
        # single-cell grids (k == 0 or c == 1) have no probe stage: every
        # live row is a candidate (modulo the sort band)
        self.probe = bool(k and self.edges_up_h.shape[1])
        self.k, self.c = k, c
        self.offsets_h = np.asarray(grid.offsets, np.int64)
        # mixed-radix weights of the row-major cell id, for window bounds
        self._radix = c ** (k - 1 - np.arange(k, dtype=np.int64))

        # pow2 bucket (min tile, kept a tile multiple for the kernel grid)
        # with always >= 1 pad row: the gather-list fast path points pad
        # slots at the last (dead, +inf) padded row, which must exist.
        # Bucketing means epoch-over-epoch growth re-enters compiled wave
        # shapes instead of minting one executable per compaction (§5.4).
        n_pad = max(self.tile, _next_pow2(n + 1))
        n_pad += (-n_pad) % self.tile
        self.n_pad = n_pad
        pad = n_pad - n
        rows_t = np.pad(grid.rows.T, ((0, 0), (0, pad)),
                        constant_values=np.inf)
        self.rows_t = jnp.asarray(rows_t, jnp.float32)
        self.bytes_resident = rows_t.size * 4
        if self.probe:
            cell_of_row = np.repeat(
                np.arange(grid.n_cells, dtype=np.int64), np.diff(grid.offsets))
            coords = np.full((k, self.n_pad), -1, np.int32)
            for j in range(k):                 # row-major decode, dim j digit
                coords[j, :n] = (cell_of_row // c ** (k - 1 - j)) % c
            self.coords = jnp.asarray(coords)
            self.bytes_resident += coords.size * 4
        if self.has_sort:
            sv = np.pad(grid.sort_vals, (0, pad), constant_values=np.inf)
            self.sv = jnp.asarray(sv, jnp.float32)[None, :]
            self.bytes_resident += sv.size * 4
        self.bytes_resident += self.set_alive(None)

    # ------------------------------------------------------------------ #
    def set_alive(self, dead_ids: Optional[np.ndarray]) -> int:
        """(Re)upload the liveness mask — all-live, or ``row_ids`` minus the
        tombstone set.  Returns bytes uploaded."""
        alive = np.zeros((1, self.n_pad), np.int32)
        if dead_ids is None or not dead_ids.size:
            alive[0, :self.n] = 1
        else:
            # dead_ids is sorted (``COAXIndex._dead_ids``): binary-search
            # membership, no per-upload re-sort of the 50k-id base
            alive[0, :self.n] = ~sorted_contains(dead_ids, self.grid.row_ids)
        self.alive = jnp.asarray(alive)
        return alive.size * 4

    def probe_batch(self, nav_rects: np.ndarray):
        """ONE host directory pass per wave: per-query per-dim [first, last]
        cell coordinates under the conservative f32 rounding, plus the
        candidate-cell counts reused for the ``cell_cap`` pre-check and the
        ``cells_probed`` stat (previously a second pass)."""
        b = nav_rects.shape[0]
        k = len(self.grid_pos)
        if not self.probe:
            return (np.zeros((b, max(k, 1)), np.int64),
                    np.zeros((b, max(k, 1)), np.int64),
                    np.ones(b, np.int64))
        glo = f32_floor(nav_rects[:, self.grid_pos, 0]).astype(np.float32)
        ghi = f32_ceil(nav_rects[:, self.grid_pos, 1]).astype(np.float32)
        first = np.stack(
            [np.searchsorted(self.edges_up_h[i], glo[:, i], side="right")
             for i in range(k)], axis=1)
        last = np.stack(
            [np.searchsorted(self.edges_down_h[i], ghi[:, i], side="left")
             for i in range(k)], axis=1)
        counts = last - first + 1
        n_cells_q = np.where((counts > 0).all(axis=1),
                             np.maximum(counts, 1).prod(axis=1), 0)
        return first, last, n_cells_q

    def candidate_lists(self, first, last, n_cells_q,
                        qmask: Optional[np.ndarray] = None):
        """Per-query ascending candidate row-position lists, derived from
        the SAME probe pass: every cell in the candidate coord box is one
        contiguous cell-major block ``[offsets[cell], offsets[cell + 1])``,
        enumerated in ascending linear cell id — the exact row set the
        numpy path refines, feeding the oracle's gather fast path
        (``fused_scan_ref``'s ``gidx``)."""
        lists = []
        for q in range(first.shape[0]):
            if n_cells_q[q] <= 0 or (qmask is not None and not qmask[q]):
                lists.append(np.empty(0, np.int64))
                continue
            cells = np.zeros(1, np.int64)
            for j in range(self.k):        # C-order box walk == ascending id
                span = np.arange(first[q, j], last[q, j] + 1) * self._radix[j]
                cells = (cells[:, None] + span[None, :]).ravel()
            starts = self.offsets_h[cells]
            lens = self.offsets_h[cells + 1] - starts
            lists.append(_multi_arange(starts, lens))
        return lists

    def gather_bucket(self, lists) -> int:
        """Static gather width for this wave: the max per-query candidate
        row count, pow2-bucketed (min 512) so steady-state waves share
        compiled shapes; 0 (= full scan) when gathering wouldn't help."""
        if not self.probe:
            return 0
        w = _next_pow2(max(max(l.size for l in lists), 512))
        return 0 if w * 2 >= self.n_pad else w

    def seg_inputs(self, nav_rects, filter_rects, first, last, bp: int,
                   qmask: Optional[np.ndarray] = None,
                   glists=None, gw: int = 0):
        """Build this wave's padded per-query device inputs for one segment.

        Padding queries (and ``qmask``-suppressed ones, e.g. the §8.2.3
        outlier bbox skip) are inert: empty probe range and an empty filter
        rect, so they contribute no hits.  When ``gw > 0`` the per-query
        candidate lists ``glists`` ship as a ``(bp, gw)`` gather-index
        image for the oracle's candidate-gather scan (pad slots point at
        the dead ``+inf`` pad row).  Returns ``(seg dict, uploaded
        bytes)``; the static config tuple comes from ``config_for``.
        """
        b = nav_rects.shape[0]
        flo = np.full((bp, filter_rects.shape[1]), np.inf, np.float32)
        fhi = np.full((bp, filter_rects.shape[1]), -np.inf, np.float32)
        flo[:b] = f32_ceil(filter_rects[:, :, 0])
        fhi[:b] = f32_ceil(filter_rects[:, :, 1])
        if qmask is not None:
            flo[:b][~qmask] = np.inf
            fhi[:b][~qmask] = -np.inf
        seg = {"rows": self.rows_t, "alive": self.alive,
               "flo": jnp.asarray(flo.T), "fhi": jnp.asarray(fhi.T)}
        nbytes = flo.size * 8
        if self.probe:
            k = first.shape[1]
            fa = np.ones((bp, k), np.int32)     # pad: empty range [1, 0]
            la = np.zeros((bp, k), np.int32)
            fa[:b], la[:b] = first, last
            if qmask is not None:
                fa[:b][~qmask], la[:b][~qmask] = 1, 0
            seg["coords"] = self.coords
            seg["first"] = jnp.asarray(fa)
            seg["last"] = jnp.asarray(la)
            nbytes += fa.size * 8
            if gw:
                gi = np.full((bp, gw), self.n_pad - 1, np.int32)
                for q, lst in enumerate(glists):
                    gi[q, :lst.size] = lst[:gw]
                seg["gidx"] = jnp.asarray(gi)
                nbytes += gi.size * 4
        if self.has_sort:
            tb = np.full((bp, 2), np.inf, np.float32)
            tb[:, 1] = -np.inf                   # pad: empty band [inf, -inf)
            if self.sort_pos is not None:
                tb[:b, 0] = f32_ceil(nav_rects[:, self.sort_pos, 0])
                tb[:b, 1] = f32_ceil(nav_rects[:, self.sort_pos, 1])
            seg["sv"] = self.sv
            seg["tband"] = jnp.asarray(tb)
            nbytes += tb.size * 4
        return seg, nbytes

    def config_for(self, hit_cap: int, use_pallas: bool, interpret: bool,
                   gw: int = 0) -> tuple:
        # the Pallas kernel path always scans full-N (the accelerator
        # design); the gather is the CPU oracle's candidate-scaling lever
        return (self.tile, hit_cap, self.probe, self.has_sort,
                use_pallas, interpret, 0 if use_pallas else int(gw))


def _extract_hits(counts: np.ndarray, hits: np.ndarray, cap: int,
                  over: np.ndarray):
    """Unpack one segment's compacted device hits: per-query row positions
    for every non-overflowing query (overflowers are host re-answered)."""
    take = np.where(over, 0, np.minimum(counts, cap))
    if not take.sum():
        return np.empty(0, np.int64), np.empty(0, np.int64)
    valid = np.arange(cap)[None, :] < take[:, None]
    q, c = np.nonzero(valid)
    return q.astype(np.int64), hits[q, c].astype(np.int64)


class _PlanBase:
    """Knobs + counters shared by the grid-level and COAX-level plans."""

    def _init_opts(self, cell_cap, tile, min_bucket, hit_cap,
                   use_pallas, interpret):
        if not _HAVE_JAX:
            raise ImportError("jax is required for the device backend")
        self.cell_cap = int(cell_cap)
        self.tile = int(tile)
        self.min_bucket = int(min_bucket)
        self.hit_cap = int(hit_cap)
        on_cpu = jax.default_backend() == "cpu"
        self.use_pallas = (not on_cpu) if use_pallas is None else bool(use_pallas)
        self.interpret = on_cpu if interpret is None else bool(interpret)
        # a fresh partial per plan keeps the jit cache (and compile_count)
        # private to this plan instead of shared process-wide
        self._fn = jax.jit(functools.partial(_wave_program),
                           static_argnums=(1,))
        self.dispatch_count = 0      # jitted wave-program launches (1/wave)
        self.bytes_h2d = 0           # resident images + per-wave inputs
        self.bytes_d2h = 0           # drained compacted result buffers

    def adopt(self, other: "_PlanBase") -> None:
        """Carry the previous epoch's jit cache and cumulative counters into
        this fresh plan.  Epoch handoff (§5.4) swaps the grids and rebuilds
        the plan; with pow2-bucketed image shapes the new epoch's waves hit
        the SAME compiled executables, so adopting ``_fn`` keeps
        ``compile_count`` flat across compactions and the launch/transfer
        accounting monotonic."""
        self._fn = other._fn
        self.dispatch_count = other.dispatch_count
        self.bytes_h2d += other.bytes_h2d
        self.bytes_d2h = other.bytes_d2h

    @property
    def compile_count(self) -> int:
        """Distinct compiled wave shapes so far — the §4 cache-policy metric."""
        if hasattr(self._fn, "_cache_size"):
            return int(self._fn._cache_size())
        return 0  # pragma: no cover - older jax without cache introspection

    def bucket(self, b: int) -> int:
        return max(self.min_bucket, _next_pow2(b))

    def _count_h2d(self, nbytes: int) -> None:
        """Fold an upload into the plan counter AND the global registry
        (``coax_device_bytes{direction="h2d"}``, DESIGN.md §10.1).
        ``adopt`` bypasses this: carried bytes were already counted."""
        self.bytes_h2d += nbytes
        obs.get_registry().counter(
            "coax_device_bytes", "bytes moved across the PCIe/ICI boundary",
            ("direction",)).inc(nbytes, direction="h2d")

    def _dispatch(self, segs, config):
        """One jitted wave-program launch.  Telemetry (DESIGN.md §10): the
        ``device.dispatch`` span splits compile from execute — a jit-cache
        miss on this call stamps ``compiled=True`` (and the span's whole
        duration is dominated by XLA compilation; steady-state waves re-
        enter compiled executables and the span is launch cost only).
        Launch count and any compile fold into the global registry."""
        before = self.compile_count
        t0 = time.perf_counter()
        with obs.span("device.dispatch", segs=len(segs)) as sp:
            res = self._fn(tuple(segs), tuple(config))
        compiled = self.compile_count - before
        if sp is not None and compiled:
            sp.args["compiled"] = True
        self.dispatch_count += 1
        g = obs.get_registry()
        g.counter("coax_device_dispatch_total",
                  "jitted wave-program launches").inc()
        if compiled:
            g.counter("coax_device_compile_total",
                      "jit cache misses (new wave shapes)").inc(compiled)
        obs.stage_hist().observe(time.perf_counter() - t0,
                                 stage="dispatch", backend="device")
        return res

    def _drain(self, res, bs):
        """Drain point: block, transfer the compacted buffers, count bytes.
        ``bs`` is the real (un-padded) query count per segment.  Returns
        per-segment ``(counts (b,), hits (bp, W), scanned (b,))``.  The
        ``device.transfer`` span covers the ``block_until_ready`` fence
        plus the d2h copies — execute+transfer time, distinct from the
        dispatch span's compile+launch (DESIGN.md §10.2)."""
        t0 = time.perf_counter()
        d2h = 0
        with obs.span("device.transfer") as sp:
            res = jax.block_until_ready(res)
            out = []
            for (counts, hits, scanned), b in zip(res, bs):
                counts = np.asarray(counts)[:b, 0]
                hits = np.asarray(hits)
                scanned = np.asarray(scanned)[:b, 0]
                d2h += counts.nbytes + hits.nbytes + scanned.nbytes
                out.append((counts, hits, scanned))
            if sp is not None:
                sp.args["bytes_d2h"] = d2h
        self.bytes_d2h += d2h
        obs.get_registry().counter(
            "coax_device_bytes", "bytes moved across the PCIe/ICI boundary",
            ("direction",)).inc(d2h, direction="d2h")
        obs.stage_hist().observe(time.perf_counter() - t0,
                                 stage="transfer", backend="device")
        return out


class DevicePlan(_PlanBase):
    """Frozen device-resident image of one ``GridFile`` plus its compiled
    megakernel wave program (DESIGN.md §4).

    Parameters
    ----------
    grid : the host ``GridFile`` to freeze (arrays are uploaded once here).
    cell_cap : per-query candidate-cell budget; waves where any query's
        directory probe exceeds it return ``None`` from ``submit_wave`` so
        the caller falls back to the numpy path (submit-time contract, §4).
    hit_cap : per-query device hit-buffer budget; queries whose exact count
        exceeds it are re-answered on the host at drain time (§4).
    tile : record tile width for the megakernel (N pads to a multiple).
    min_bucket : smallest wave bucket; B pads up to ``max(min_bucket,
        next_pow2(B))`` so steady-state widths share compiled shapes.
    use_pallas : route segments through the Pallas kernel; ``None`` picks
        the kernel on real accelerators and the jnp oracle (same contract,
        XLA-compiled) on CPU, where interpret-mode Pallas is a correctness
        tool rather than a fast path.
    """

    def __init__(self, grid, *, cell_cap: int = 256, tile: int = 512,
                 min_bucket: int = 4, hit_cap: int = 1024,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self._init_opts(cell_cap, tile, min_bucket, hit_cap,
                        use_pallas, interpret)
        self.grid = grid
        self.epoch = int(getattr(grid, "epoch", 0))   # snapshot version (§5)
        self.n_rows = grid.n_rows
        self._img = _GridImage(grid, self.tile) if grid.n_rows else None
        if self._img is not None:
            self._count_h2d(self._img.bytes_resident)

    # ------------------------------------------------------------------ #
    def plan_counts(self, nav_rects: np.ndarray,
                    bounds: Optional[tuple] = None) -> np.ndarray:
        """Per-query candidate-cell counts under the device probe (the same
        conservative f32 rounding) — ``probe_batch``'s counts, exposed for
        callers that only need the overflow pre-check / work stat."""
        if self._img is None:
            return np.ones(nav_rects.shape[0], np.int64)
        del bounds                    # probe_batch recomputes; ONE pass total
        return self._img.probe_batch(nav_rects)[2]

    # ------------------------------------------------------------------ #
    def submit_wave(self, nav_rects: np.ndarray, filter_rects: np.ndarray):
        """Launch one wave (ONE dispatch); returns an opaque ticket for
        ``collect``, or ``None`` on ``cell_cap`` overflow (caller falls back
        to numpy).  No results transfer until ``collect``."""
        b = nav_rects.shape[0]
        if b == 0 or self.n_rows == 0:
            return {"b": b, "res": None}
        first, last, n_cells_q = self._img.probe_batch(nav_rects)
        if int(n_cells_q.max(initial=0)) > self.cell_cap:
            return None                                # overflow fallback
        bp = self.bucket(b)
        glists, gw = None, 0
        if not self.use_pallas:
            glists = self._img.candidate_lists(first, last, n_cells_q)
            gw = self._img.gather_bucket(glists)
        seg, nbytes = self._img.seg_inputs(nav_rects, filter_rects,
                                           first, last, bp,
                                           glists=glists, gw=gw)
        cfg = self._img.config_for(self.hit_cap, self.use_pallas,
                                   self.interpret, gw)
        res = self._dispatch([seg], [cfg])
        self._count_h2d(nbytes)
        return {"b": b, "res": res, "cells": int(n_cells_q.sum()),
                "nav": nav_rects, "filt": filter_rects}

    def collect(self, ticket) -> Tuple[np.ndarray, np.ndarray, dict]:
        """Drain one wave: block, transfer the compacted buffers, unpack,
        and host re-answer any ``hit_cap`` overflowers from the frozen grid."""
        b = ticket["b"]
        if ticket["res"] is None:
            return (np.empty(0, np.int64), np.empty(0, np.int64),
                    {"cells_probed": 0, "rows_scanned": 0, "hit_overflows": 0})
        ((counts, hits, scanned),) = self._drain(ticket["res"], [b])
        over = counts > self.hit_cap
        q, pos = _extract_hits(counts, hits, self.hit_cap, over)
        out_q, out_r = q, self.grid.row_ids[pos]
        rows_scanned = int(scanned.sum())
        if over.any():                # exact per-query host re-answer (§4)
            qsel = np.nonzero(over)[0]
            qo, ro = self.grid._query_batch_numpy(
                ticket["nav"][qsel], ticket["filt"][qsel])
            rows_scanned += self.grid.last_batch_stats.rows_scanned
            out_q = np.concatenate([out_q, qsel[qo]])
            out_r = np.concatenate([out_r, ro])
        order = np.lexsort((out_r, out_q))
        stats = {"cells_probed": ticket["cells"],
                 "rows_scanned": rows_scanned,
                 "hit_overflows": int(over.sum())}
        return out_q[order], out_r[order], stats

    def run_wave(self, nav_rects: np.ndarray, filter_rects: np.ndarray
                 ) -> Optional[Tuple[np.ndarray, np.ndarray, dict]]:
        """Submit + drain one wave synchronously; ``None`` on ``cell_cap``
        overflow (the ``GridFile.query_batch`` fallback contract)."""
        ticket = self.submit_wave(nav_rects, filter_rects)
        if ticket is None:
            return None
        return self.collect(ticket)


class CoaxDevicePlan(_PlanBase):
    """Device wave plan for a whole ``COAXIndex``: primary grid + outlier
    grid + the live delta/tombstone image, fused into ONE dispatch per wave
    (DESIGN.md §4).

    The plan freezes the index's CURRENT epoch grids; write-state (liveness
    masks, delta image) refreshes lazily at submit when the delta-plane
    counters move.  Tickets capture every host array a drain-time re-answer
    needs, so collecting after further writes still answers from the wave's
    submit-time snapshot.

    §9.3 pin retention: an ``EpochPin`` holds a strong reference to the
    plan that was live at pin time, so a compaction's ``adopt()`` of a new
    epoch never drops the jit cache out from under a pinned reader — but
    pinned QUERIES never dispatch through the plan; they run the exact
    host composition over the pin's frozen arrays (``engine.cache``).
    """

    def __init__(self, index, *, cell_cap: int = 256, tile: int = 512,
                 min_bucket: int = 4, hit_cap: int = 1024,
                 use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        self._init_opts(cell_cap, tile, min_bucket, hit_cap,
                        use_pallas, interpret)
        self.index = index
        self.primary = index.primary
        self.outlier = index.outlier
        self.epoch = int(index.epoch)
        self.p_img = (_GridImage(self.primary, self.tile)
                      if self.primary.n_rows else None)
        self.o_img = (_GridImage(self.outlier, self.tile)
                      if self.outlier.n_rows else None)
        for img in (self.p_img, self.o_img):
            if img is not None:
                self._count_h2d(img.bytes_resident)
        self._dead_key = None
        self._dead_host = np.empty(0, np.int64)
        self._delta_key = None
        self._delta = None

    # ------------------------------------------------------------------ #
    def _refresh_writes(self) -> None:
        """Re-upload liveness masks / the delta image iff the delta-plane
        counters moved since the last wave (cheap no-op in steady state)."""
        dp, do = self.index.delta_primary, self.index.delta_outlier
        dead_key = (dp.n_tombstones, do.n_tombstones)
        if dead_key != self._dead_key:
            self._dead_host = self.index._dead_ids()
            for img in (self.p_img, self.o_img):
                if img is not None:
                    self._count_h2d(img.set_alive(self._dead_host))
            self._dead_key = dead_key
        delta_key = (dp.n_log, dp.n_log_dead, do.n_log, do.n_log_dead)
        if delta_key != self._delta_key:
            r1, i1 = dp.live_log()
            r2, i2 = do.live_log()
            rows = np.concatenate([r1, r2])
            ids = np.concatenate([i1, i2])
            m = rows.shape[0]
            if m:
                m_pad = max(DELTA_TILE, _next_pow2(m))   # bounded recompiles
                rows_t = np.full((rows.shape[1], m_pad), np.inf, np.float32)
                rows_t[:, :m] = rows.T
                alive = np.zeros((1, m_pad), np.int32)
                alive[0, :m] = 1
                self._delta = {"rows_t": jnp.asarray(rows_t),
                               "alive": jnp.asarray(alive),
                               "rows": rows, "ids": ids, "m_pad": m_pad}
                self._count_h2d(rows_t.size * 4 + alive.size * 4)
            else:
                self._delta = None
            self._delta_key = delta_key

    # ------------------------------------------------------------------ #
    def _add_grid_segs(self, img, ids, nav, filt, first, last, ncq,
                       bp: int, out: dict, qmask=None) -> int:
        """Append one grid's wave segment(s) to ``out`` (the in-progress
        dispatch lists).  On the CPU-oracle path the per-query candidate
        lists feed the gather fast path, and a wave whose width budget
        would be set by a few fat queries is SPLIT: a thin segment at the
        median-sized gather width (fat queries inert) plus a fat segment
        over just those queries at a small batch bucket — still one
        dispatch, each query live in exactly one segment (``qmap`` routes
        fat hits back to wave query ids at collect)."""
        b = nav.shape[0]
        glists, gw = None, 0
        if not self.use_pallas:
            glists = img.candidate_lists(first, last, ncq, qmask=qmask)
            gw = img.gather_bucket(glists)
        fat = np.empty(0, np.int64)
        gw_thin = gw
        if gw:
            sizes = np.array([l.size for l in glists])
            gw_thin = _next_pow2(max(512, int(np.median(sizes)) * 2))
            if gw_thin < gw:
                fat = np.nonzero(sizes > gw_thin)[0]
            else:
                gw_thin = gw
        nbytes = 0
        thin_mask = qmask
        thin_lists = glists
        if fat.size:
            thin_mask = np.ones(b, bool) if qmask is None else qmask.copy()
            thin_mask[fat] = False
            thin_lists = [l if m else np.empty(0, np.int64)
                          for l, m in zip(glists, thin_mask)]
        seg, nb = img.seg_inputs(nav, filt, first, last, bp,
                                 qmask=thin_mask, glists=thin_lists,
                                 gw=gw_thin)
        out["segs"].append(seg)
        out["cfgs"].append(img.config_for(self.hit_cap, self.use_pallas,
                                          self.interpret, gw_thin))
        out["ids"].append(ids)
        out["qmaps"].append(None)
        out["bs"].append(b)
        nbytes += nb
        if fat.size:
            bp_f = max(self.min_bucket, _next_pow2(fat.size))
            flists = [glists[q] for q in fat]
            gw_f = img.gather_bucket(flists)
            seg, nb = img.seg_inputs(nav[fat], filt[fat], first[fat],
                                     last[fat], bp_f,
                                     glists=flists, gw=gw_f)
            out["segs"].append(seg)
            out["cfgs"].append(img.config_for(
                self.hit_cap, self.use_pallas, self.interpret, gw_f))
            out["ids"].append(ids)
            out["qmaps"].append(fat)
            out["bs"].append(fat.size)
            nbytes += nb
        return nbytes

    def submit_wave(self, nav_rects: np.ndarray, rects: np.ndarray):
        """Launch one COAX wave (ONE dispatch over up to three segments —
        plus thin/fat splits of the grid segments on the CPU-oracle path);
        returns a ticket for ``collect`` or ``None`` on ``cell_cap``
        overflow.  All snapshot/write state the drain needs is captured
        here, synchronously — per-wave snapshot semantics (§5)."""
        b = rects.shape[0]
        if b == 0:
            return {"b": 0, "res": None}
        self._refresh_writes()
        bp = self.bucket(b)
        out = {"segs": [], "cfgs": [], "ids": [], "qmaps": [], "bs": []}
        cells_probed = 0
        nbytes = 0

        if self.p_img is not None:
            first, last, ncq = self.p_img.probe_batch(nav_rects)
            if int(ncq.max(initial=0)) > self.cell_cap:
                return None
            cells_probed += int(ncq.sum())
            nbytes += self._add_grid_segs(self.p_img, self.primary.row_ids,
                                          nav_rects, rects, first, last,
                                          ncq, bp, out)

        # §8.2.3 bbox skip: non-touch queries go in inert, not sub-batched —
        # same result (no outlier row can pass their predicate), fixed shape
        touch = np.zeros(b, bool)
        if self.index._outlier_lo is not None:
            touch = np.all(
                (rects[:, :, 0] <= self.index._outlier_hi)
                & (rects[:, :, 1] > self.index._outlier_lo), axis=1)
        if self.o_img is not None and touch.any():
            # nav == full rect for the full-dim outlier grid
            of, ol, oncq = self.o_img.probe_batch(rects)
            oncq = np.where(touch, oncq, 0)
            if int(oncq.max(initial=0)) > self.cell_cap:
                return None
            cells_probed += int(oncq.sum())
            nbytes += self._add_grid_segs(self.o_img, self.outlier.row_ids,
                                          rects, rects, of, ol, oncq, bp,
                                          out, qmask=touch)
        segs, cfgs, ids_list = out["segs"], out["cfgs"], out["ids"]

        delta = self._delta
        if delta is not None:
            flo = np.full((bp, rects.shape[1]), np.inf, np.float32)
            fhi = np.full((bp, rects.shape[1]), -np.inf, np.float32)
            flo[:b] = f32_ceil(rects[:, :, 0])
            fhi[:b] = f32_ceil(rects[:, :, 1])
            segs.append({"rows": delta["rows_t"], "alive": delta["alive"],
                         "flo": jnp.asarray(flo.T), "fhi": jnp.asarray(fhi.T)})
            cfgs.append((min(DELTA_TILE, delta["m_pad"]), self.hit_cap,
                         False, False, self.use_pallas, self.interpret, 0))
            ids_list.append(delta["ids"])
            out["qmaps"].append(None)
            out["bs"].append(b)
            nbytes += flo.size * 8

        res = self._dispatch(segs, cfgs) if segs else ()
        self._count_h2d(nbytes)
        return {"b": b, "res": res, "ids": ids_list, "cells": cells_probed,
                "qmaps": out["qmaps"], "bs": out["bs"],
                "nav": nav_rects, "rects": rects, "touch": touch,
                "dead": self._dead_host,
                "delta": None if delta is None
                else (delta["rows"], delta["ids"])}

    # ------------------------------------------------------------------ #
    def collect(self, ticket) -> Tuple[np.ndarray, np.ndarray, BatchStats]:
        """Drain one COAX wave at its explicit drain point and assemble the
        exact ``query_batch`` answer (plus ``BatchStats``)."""
        b = ticket["b"]
        if b == 0 or not ticket["res"]:
            return (np.empty(0, np.int64), np.empty(0, np.int64),
                    BatchStats(queries=b, backend="device"))
        seg_np = self._drain(ticket["res"], ticket["bs"])
        over = np.zeros(b, bool)
        rows_scanned = 0
        for (counts, _, scanned), qmap in zip(seg_np, ticket["qmaps"]):
            o = counts > self.hit_cap
            if qmap is None:
                over |= o
            else:
                over[qmap[o]] = True
            rows_scanned += int(scanned.sum())
        parts_q, parts_r = [], []
        for (counts, hits, _), ids, qmap in zip(seg_np, ticket["ids"],
                                                ticket["qmaps"]):
            q, pos = _extract_hits(counts, hits, self.hit_cap,
                                   over if qmap is None else over[qmap])
            parts_q.append(q if qmap is None else qmap[q])
            parts_r.append(ids[pos])
        n_over = int(over.sum())
        if n_over:
            qsel = np.nonzero(over)[0]
            qo, ro, extra = self._reanswer(ticket, qsel)
            parts_q.append(qsel[qo])
            parts_r.append(ro)
            rows_scanned += extra
        out_q = np.concatenate(parts_q)
        out_r = np.concatenate(parts_r)
        order = np.lexsort((out_r, out_q))
        stats = BatchStats(queries=b, cells_probed=ticket["cells"],
                           rows_scanned=rows_scanned, backend="device",
                           hit_overflows=n_over)
        return out_q[order], out_r[order], stats

    def _reanswer(self, ticket, qsel: np.ndarray):
        """Exact host answer for ``hit_cap``-overflowing queries, replayed
        from the ticket's CAPTURED state (frozen epoch grids + the tombstone
        set and delta log as of submit) — writes applied between submit and
        drain are invisible, preserving per-wave snapshot semantics."""
        nav = ticket["nav"][qsel]
        rects = ticket["rects"][qsel]
        q_p, r_p = self.primary._query_batch_numpy(nav, rects)
        extra = self.primary.last_batch_stats.rows_scanned
        touch = ticket["touch"][qsel]
        if touch.any() and self.outlier.n_rows:
            sub = rects[touch]
            q_o, r_o = self.outlier._query_batch_numpy(sub, sub)
            extra += self.outlier.last_batch_stats.rows_scanned
            if r_o.size:
                q_p = np.concatenate([q_p, np.nonzero(touch)[0][q_o]])
                r_p = np.concatenate([r_p, r_o])
        dead = ticket["dead"]
        if dead.size and r_p.size:
            keep = ~sorted_contains(dead, r_p)
            q_p, r_p = q_p[keep], r_p[keep]
        if ticket["delta"] is not None:
            drows, dids = ticket["delta"]
            rows64 = drows.astype(np.float64)      # exact f64 upcast compare
            hit = np.ones((qsel.size, dids.size), bool)
            for j in range(drows.shape[1]):
                v = rows64[:, j]
                np.logical_and(hit, v[None, :] >= rects[:, j, 0][:, None],
                               out=hit)
                np.logical_and(hit, v[None, :] < rects[:, j, 1][:, None],
                               out=hit)
            qd, pos = np.nonzero(hit)
            q_p = np.concatenate([q_p, qd.astype(np.int64)])
            r_p = np.concatenate([r_p, dids[pos]])
            extra += int(qsel.size) * int(dids.size)
        return q_p, r_p, int(extra)
