"""Device-resident batch query plane (DESIGN.md §4).

The numpy batch path (``GridFile.query_batch``) is a chain of host gathers
and temporaries; this module fuses the whole per-wave pipeline — directory
probe, per-segment binary search over the in-cell sorted attribute, and the
final full-predicate filter — into ONE jitted fixed-shape device program so
a wave costs one launch plus one hit-mask transfer back.

Frozen plan (uploaded once at build):
  * ``rows_t``    (D, N_pad) f32 column-major records, padded with ``+inf``
    to a tile multiple (padding never matches: ``v < hi`` fails);
  * ``sort_vals`` (N_pad,)  f32 in-cell sorted attribute;
  * ``offsets``   (n_cells+1,) i32 cell block boundaries;
  * ``edges_up`` / ``edges_down`` (k, c-1) f32 grid lines rounded toward
    ``+inf`` / ``-inf`` — paired with query bounds rounded the OPPOSITE way
    the f32 directory probe can only widen the candidate range vs the exact
    float64 host probe, never narrow it (DESIGN.md §4, exactness argument).

Per-wave pipeline (``_device_pipeline``, one ``jax.jit`` program):
  1. probe: ``jnp.searchsorted`` over the stacked edges -> per-dim
     [first, last] cell coordinates;
  2. expand: mixed-radix decode of up to ``cell_cap`` candidate cells per
     query (raggedness is padded; a host-side pre-check falls the wave back
     to numpy when any query exceeds the cap);
  3. bisect: a fixed-trip ``lax.fori_loop`` port of
     ``core.gridfile.batched_searchsorted`` refines every (query, cell)
     block against the sorted attribute;
  4. window: min/max-reduce the refined blocks into one [lo, hi) scan
     window per query (non-candidate rows inside the window are removed by
     the exact full-predicate filter, so the union is safe — §4);
  5. filter: the ``range_scan_batch`` Pallas kernel (or its jnp oracle on
     CPU, same contract) evaluates every query's ceil-rounded f32 bounds
     against the shared record block with per-query windows.

Shape bucketing: the wave width B is padded up to a power-of-two bucket and
candidate counts to ``cell_cap``, so steady-state serving re-enters an
already-compiled executable — at most one compile per
``(bucket_B, padded_N, D)`` (``DevicePlan.compile_count`` exposes the jit
cache size for the regression test).

Exactness contract: device results equal the numpy path whenever the
nav-rect over-approximates the filter-rect on the indexed dims — which is
exactly the COAX invariant (§7.1 translation for the primary index,
nav == filter for the outlier/raw grid).  ``GridFile.query_batch`` only
routes here under that contract.

Epoch versioning (DESIGN.md §5): a plan is the frozen image of ONE grid
file epoch (``DevicePlan.epoch``).  Under the mutable lifecycle the plan
keeps serving that frozen epoch while ``COAXIndex`` unions an exact numpy
delta scan and masks tombstones on the host — identical arithmetic for
every backend, so results stay bit-identical to numpy while writes accrue.
Compaction replaces the grid file with a new-epoch instance, which is the
only event that invalidates a plan: the stale plan is dropped with its
grid and a fresh one is built lazily on the next device wave.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import numpy as np

from ..core.gridfile import f32_ceil

__all__ = ["DevicePlan", "device_available", "f32_floor"]

try:  # the container bakes jax in; gate anyway so numpy-only installs work
    import jax
    import jax.numpy as jnp
    _HAVE_JAX = True
except Exception:  # pragma: no cover - exercised only without jax
    jax = None
    jnp = None
    _HAVE_JAX = False


def device_available() -> bool:
    """True when the jax runtime needed by ``DevicePlan`` is importable."""
    return _HAVE_JAX


def f32_floor(x: np.ndarray) -> np.ndarray:
    """Largest float32 <= x, elementwise (the mirror of ``gridfile.f32_ceil``)."""
    x = np.asarray(x, dtype=np.float64)
    with np.errstate(over="ignore"):
        y = x.astype(np.float32)
        rounded_up = y.astype(np.float64) > x
        # nextafter past f32 min overflows to -inf — the correct floor there
        return np.where(rounded_up, np.nextafter(y, np.float32(-np.inf)), y)


def _next_pow2(n: int) -> int:
    return 1 << max(int(n - 1).bit_length(), 0) if n > 1 else 1


def _bisect_device(vals, lo, hi, target, n_iter: int):
    """Fixed-trip ``lax.fori_loop`` port of ``gridfile.batched_searchsorted``
    (side="left"): per-segment insertion points of ``target`` in ``vals``.

    ``lo``/``hi`` are (B, C) segment bounds; ``target`` broadcasts.  The trip
    count is static (log2 of the longest possible segment), so converged
    lanes just idle — the device analogue of the numpy loop's early exit.
    """
    def body(_, state):
        lo, hi = state
        active = lo < hi
        mid = (lo + hi) // 2
        mv = vals[jnp.where(active, mid, 0)]       # masked gather, like numpy
        go_right = active & (mv < target)
        return (jnp.where(go_right, mid + 1, lo),
                jnp.where(active & ~go_right, mid, hi))

    lo, hi = jax.lax.fori_loop(0, n_iter, body, (lo, hi))
    return lo


def _device_pipeline(
    rows_t,        # (D, N_pad) f32
    sort_vals,     # (N_pad,) f32 (dummy (1,) when has_sort=False)
    offsets,       # (n_cells+1,) i32
    edges_up,      # (k, c-1) f32, rounded up
    edges_down,    # (k, c-1) f32, rounded down
    glo, ghi,      # (Bp, k) f32 grid-dim bounds (lo rounded down, hi up)
    t_lo, t_hi,    # (Bp,) f32 sorted-dim targets (ceil-rounded, exact)
    flo, fhi,      # (Bp, D) f32 full-predicate bounds (ceil-rounded, exact)
    *,
    n_valid: int,
    cells_per_dim: int,
    cell_cap: int,
    n_iter: int,
    tile: int,
    has_sort: bool,
    use_pallas: bool,
    interpret: bool,
):
    """The whole per-wave hot path as one fixed-shape jitted program.

    Returns ``(mask (Bp, n_valid) bool, windows (Bp, 2) i32, scanned (Bp,))``.
    """
    from ..kernels import ref
    from ..kernels.range_scan_batch import range_scan_batch

    bp, k = glo.shape
    c = cells_per_dim
    n_pad = rows_t.shape[1]

    # 1. directory probe (conservative f32 rounding can only widen) --------
    if k and edges_up.shape[1]:
        first = jnp.stack(
            [jnp.searchsorted(edges_up[i], glo[:, i], side="right") for i in range(k)],
            axis=1).astype(jnp.int32)                               # (Bp, k)
        last = jnp.stack(
            [jnp.searchsorted(edges_down[i], ghi[:, i], side="left") for i in range(k)],
            axis=1).astype(jnp.int32)
    else:  # 0 grid dims, or 1 cell per dim: every query sees cell range [0, 0]
        first = jnp.zeros((bp, max(k, 1)), jnp.int32)
        last = jnp.zeros((bp, max(k, 1)), jnp.int32)
    counts = last - first + 1
    ok = jnp.all(counts > 0, axis=1)
    safe = jnp.maximum(counts, 1)
    n_cells_q = jnp.where(ok, jnp.prod(safe, axis=1), 0)            # (Bp,)

    # 2. candidate-cell expansion: mixed-radix decode into cell_cap slots --
    j = jnp.arange(cell_cap, dtype=jnp.int32)[None, :]              # (1, cap)
    valid = j < n_cells_q[:, None]                                  # (Bp, cap)
    rev = jnp.cumprod(safe[:, ::-1], axis=1)[:, ::-1]               # suffix prods
    strides = jnp.concatenate(
        [rev[:, 1:], jnp.ones((bp, 1), rev.dtype)], axis=1)         # (Bp, kk)
    flat = jnp.zeros((bp, cell_cap), jnp.int32)
    for i in range(first.shape[1]):
        digit = (j // strides[:, i:i + 1]) % safe[:, i:i + 1]
        flat = flat * c + (first[:, i:i + 1] + digit.astype(jnp.int32))
    cell = jnp.where(valid, flat, 0)

    blk_lo = jnp.where(valid, offsets[cell], 0)
    blk_hi = jnp.where(valid, offsets[cell + 1], 0)

    # 3. per-segment binary search over the in-cell sorted attribute ------
    if has_sort:
        blk_lo = _bisect_device(sort_vals, blk_lo, blk_hi, t_lo[:, None], n_iter)
        blk_hi = _bisect_device(sort_vals, blk_lo, blk_hi, t_hi[:, None], n_iter)

    # 4. union scan window per query --------------------------------------
    win_lo = jnp.min(jnp.where(valid, blk_lo, n_pad), axis=1)
    win_hi = jnp.max(jnp.where(valid, blk_hi, 0), axis=1)
    win_lo = jnp.minimum(win_lo, win_hi)           # empty -> [x, x)
    windows = jnp.stack([win_lo, win_hi], axis=1).astype(jnp.int32)

    # 5. windowed full-predicate filter (Pallas kernel / jnp oracle) ------
    if use_pallas:
        mask, _ = range_scan_batch(rows_t, flo.T, fhi.T, windows,
                                   tile=tile, interpret=interpret)
    else:
        mask, _ = ref.range_scan_batch_ref(rows_t, flo.T, fhi.T, windows, tile=tile)
    return mask[:, :n_valid].astype(bool), windows, win_hi - win_lo


class DevicePlan:
    """Frozen device-resident image of one ``GridFile`` plus its compiled
    per-wave pipeline (DESIGN.md §4).

    Parameters
    ----------
    grid : the host ``GridFile`` to freeze (arrays are uploaded once here).
    cell_cap : per-query candidate-cell budget; waves where any query's
        directory probe exceeds it return ``None`` from ``run_wave`` so the
        caller falls back to the numpy path (the overflow contract, §4).
    tile : record tile width for the scan kernel (N is padded to a multiple).
    min_bucket : smallest wave bucket; B pads up to ``max(min_bucket,
        next_pow2(B))`` so steady-state widths share compiled shapes.
    use_pallas : route step 5 through the Pallas kernel; ``None`` picks the
        kernel on real accelerators and the jnp oracle (same contract,
        XLA-compiled) on CPU, where interpret-mode Pallas is a correctness
        tool rather than a fast path.
    """

    def __init__(self, grid, *, cell_cap: int = 256, tile: int = 512,
                 min_bucket: int = 4, use_pallas: Optional[bool] = None,
                 interpret: Optional[bool] = None):
        if not _HAVE_JAX:
            raise ImportError("jax is required for the device backend")
        self.grid = grid
        self.epoch = int(getattr(grid, "epoch", 0))   # snapshot version (§5)
        self.cell_cap = int(cell_cap)
        self.tile = int(tile)
        self.min_bucket = int(min_bucket)
        on_cpu = jax.default_backend() == "cpu"
        self.use_pallas = (not on_cpu) if use_pallas is None else bool(use_pallas)
        self.interpret = on_cpu if interpret is None else bool(interpret)

        n, k = grid.n_rows, len(grid.grid_dims)
        self.n_rows = n
        self._grid_pos = [grid.index_dims.index(d) for d in grid.grid_dims]
        self._sort_pos = (grid.index_dims.index(grid.sort_dim)
                          if grid.sort_dim is not None else None)

        # conservative f32 images of the float64 grid lines (host + device)
        edges = (np.stack(grid.inner_edges) if k
                 else np.zeros((0, 0), np.float64))
        self._edges_up_h = f32_ceil(edges).astype(np.float32)
        self._edges_down_h = f32_floor(edges).astype(np.float32)

        if n:
            pad = (-n) % self.tile
            rows_t = np.pad(grid.rows.T, ((0, 0), (0, pad)),
                            constant_values=np.inf)
            sv = (np.pad(grid.sort_vals, (0, pad), constant_values=np.inf)
                  if grid.sort_vals is not None else np.zeros(1, np.float32))
            self.rows_t = jnp.asarray(rows_t, jnp.float32)
            self.sort_vals = jnp.asarray(sv, jnp.float32)
            self.offsets = jnp.asarray(grid.offsets, jnp.int32)
            self.edges_up = jnp.asarray(self._edges_up_h)
            self.edges_down = jnp.asarray(self._edges_down_h)
            n_iter = int(np.ceil(np.log2(max(n, 2)))) + 1
            self._fn = jax.jit(functools.partial(
                _device_pipeline,
                n_valid=n, cells_per_dim=grid.cells_per_dim,
                cell_cap=self.cell_cap, n_iter=n_iter, tile=self.tile,
                has_sort=grid.sort_vals is not None,
                use_pallas=self.use_pallas, interpret=self.interpret,
            ))
        else:
            self._fn = None
        self._shapes_seen: set = set()

    # ------------------------------------------------------------------ #
    @property
    def compile_count(self) -> int:
        """Distinct compiled shapes so far — the §4 cache-policy metric."""
        if self._fn is not None and hasattr(self._fn, "_cache_size"):
            return int(self._fn._cache_size())
        return len(self._shapes_seen)

    def bucket(self, b: int) -> int:
        return max(self.min_bucket, _next_pow2(b))

    # ------------------------------------------------------------------ #
    def plan_counts(self, nav_rects: np.ndarray,
                    bounds: Optional[tuple] = None) -> np.ndarray:
        """Per-query candidate-cell counts under the DEVICE probe (the same
        conservative f32 rounding), used for the overflow pre-check and the
        ``cells_probed`` stat.  Pure host numpy — O(B * k * log c).
        ``bounds`` may carry precomputed ``_grid_bounds`` output."""
        b = nav_rects.shape[0]
        k = len(self.grid.grid_dims)
        if k == 0 or self._edges_up_h.shape[1] == 0:
            return np.ones(b, dtype=np.int64)
        glo, ghi = bounds if bounds is not None else self._grid_bounds(nav_rects)
        first = np.stack(
            [np.searchsorted(self._edges_up_h[i], glo[:, i], side="right")
             for i in range(k)], axis=1)
        last = np.stack(
            [np.searchsorted(self._edges_down_h[i], ghi[:, i], side="left")
             for i in range(k)], axis=1)
        counts = last - first + 1
        return np.where((counts > 0).all(axis=1),
                        np.maximum(counts, 1).prod(axis=1), 0)

    def _grid_bounds(self, nav_rects: np.ndarray):
        glo = f32_floor(nav_rects[:, self._grid_pos, 0]).astype(np.float32)
        ghi = f32_ceil(nav_rects[:, self._grid_pos, 1]).astype(np.float32)
        return glo, ghi

    # ------------------------------------------------------------------ #
    def run_wave(
        self, nav_rects: np.ndarray, filter_rects: np.ndarray
    ) -> Optional[Tuple[np.ndarray, np.ndarray, dict]]:
        """Answer one wave on the device.

        Returns ``(query_ids, row_ids, stats)`` with the exact
        ``query_batch`` contract, or ``None`` when any query's candidate
        cells overflow ``cell_cap`` (caller falls back to numpy).
        """
        b = nav_rects.shape[0]
        empty = (np.empty(0, np.int64), np.empty(0, np.int64),
                 {"cells_probed": 0, "rows_scanned": 0})
        if b == 0 or self.n_rows == 0:
            return empty
        glo, ghi = self._grid_bounds(nav_rects)
        n_cells_q = self.plan_counts(nav_rects, bounds=(glo, ghi))
        if int(n_cells_q.max(initial=0)) > self.cell_cap:
            return None                                   # overflow fallback

        bp = self.bucket(b)
        k = len(self.grid.grid_dims)
        glo = self._pad_rows(glo, bp, np.inf)             # inert queries:
        ghi = self._pad_rows(ghi, bp, -np.inf)            # empty cell range
        if self._sort_pos is not None:
            t_lo = f32_ceil(nav_rects[:, self._sort_pos, 0]).astype(np.float32)
            t_hi = f32_ceil(nav_rects[:, self._sort_pos, 1]).astype(np.float32)
        else:
            t_lo = np.full(b, -np.inf, np.float32)
            t_hi = np.full(b, np.inf, np.float32)
        t_lo = self._pad_rows(t_lo[:, None], bp, np.inf)[:, 0]
        t_hi = self._pad_rows(t_hi[:, None], bp, -np.inf)[:, 0]
        flo = self._pad_rows(f32_ceil(filter_rects[:, :, 0]).astype(np.float32),
                             bp, np.inf)
        fhi = self._pad_rows(f32_ceil(filter_rects[:, :, 1]).astype(np.float32),
                             bp, -np.inf)

        mask, windows, scanned = self._fn(
            self.rows_t, self.sort_vals, self.offsets,
            self.edges_up, self.edges_down,
            jnp.asarray(glo.reshape(bp, k)), jnp.asarray(ghi.reshape(bp, k)),
            jnp.asarray(t_lo), jnp.asarray(t_hi),
            jnp.asarray(flo), jnp.asarray(fhi))
        self._shapes_seen.add((bp, k))

        mask = np.asarray(mask)[:b]                       # one transfer back
        qids, ridx = np.nonzero(mask)
        out_q = qids.astype(np.int64)
        out_r = self.grid.row_ids[ridx]
        order = np.lexsort((out_r, out_q))
        stats = {
            "cells_probed": int(n_cells_q.sum()),
            "rows_scanned": int(np.asarray(scanned)[:b].sum()),
        }
        return out_q[order], out_r[order], stats

    @staticmethod
    def _pad_rows(a: np.ndarray, bp: int, value) -> np.ndarray:
        b = a.shape[0]
        if b == bp:
            return a
        return np.pad(a, ((0, bp - b), (0, 0)), constant_values=value)
