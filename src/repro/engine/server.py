"""Range-query admission server (DESIGN.md §2).

Adapts ``runtime.router.CoaxRouter``'s continuous-batching admission pattern
to range-query traffic: clients ``submit`` rects into a pending pool, the
server ``drain``s the pool in priority-then-FIFO waves of ``max_batch``
queries, and each wave is one fused ``BatchQueryExecutor`` call.  Per-wave
stats mirror the router's so the serving plane exposes one vocabulary
(waves, pending, qps) whether it batches decode requests or index probes.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from typing import Dict, List, Optional

import numpy as np

from .executor import BatchQueryExecutor

__all__ = ["PendingQuery", "QueryServer"]


@dataclasses.dataclass
class PendingQuery:
    qid: int
    rect: np.ndarray              # (D, 2)
    priority: float
    arrival: float


class QueryServer:
    """Submit range queries, drain them in batched waves.

    Parameters
    ----------
    index : engine handed to ``BatchQueryExecutor`` (COAXIndex or baseline).
    max_batch : queries fused per wave.
    backend : forwarded to ``BatchQueryExecutor`` — ``"device"`` serves
        waves from the index's device-resident plan (DESIGN.md §4).
    """

    def __init__(self, index, max_batch: int = 64,
                 executor: Optional[BatchQueryExecutor] = None,
                 backend: Optional[str] = None):
        self.executor = executor or BatchQueryExecutor(
            index, max_batch=max_batch, backend=backend)
        self._pending: Dict[int, PendingQuery] = {}
        self._ids = itertools.count()
        self.waves_drained = 0

    # ------------------------------------------------------------------ #
    def submit(self, rect: np.ndarray, priority: float = 0.0,
               arrival: Optional[float] = None) -> int:
        """Queue one rect; returns its query id."""
        rect = np.asarray(rect, dtype=np.float64)
        if rect.ndim != 2 or rect.shape[1] != 2:
            raise ValueError(f"rect must be (D, 2), got {rect.shape}")
        n_dims = getattr(self.executor.index, "n_dims", None)
        if n_dims is not None and rect.shape[0] != n_dims:
            raise ValueError(f"rect has {rect.shape[0]} dims, index has {n_dims}")
        qid = next(self._ids)
        self._pending[qid] = PendingQuery(
            qid, rect, priority,
            arrival if arrival is not None else time.time())
        return qid

    def submit_many(self, rects: np.ndarray, priority: float = 0.0) -> List[int]:
        return [self.submit(r, priority=priority) for r in rects]

    # ------------------------------------------------------------------ #
    def drain(self, max_waves: Optional[int] = None) -> Dict[int, np.ndarray]:
        """Run pending queries to completion (or for ``max_waves`` waves).

        Returns {query_id: sorted row ids} for every query answered.  Wave
        formation is priority-then-FIFO, like the router's admission sort.
        """
        results: Dict[int, np.ndarray] = {}
        width = self.executor.max_batch
        waves_this_call = 0
        while self._pending:
            if max_waves is not None and waves_this_call >= max_waves:
                break
            cands = sorted(self._pending.values(),
                           key=lambda q: (-q.priority, q.arrival, q.qid))
            wave = cands[:width]
            rects = np.stack([q.rect for q in wave])
            answers = self.executor.execute(rects)
            for q, ans in zip(wave, answers):
                results[q.qid] = ans
                del self._pending[q.qid]
            self.waves_drained += 1
            waves_this_call += 1
        return results

    # ------------------------------------------------------------------ #
    def __len__(self) -> int:
        return len(self._pending)

    def stats(self) -> dict:
        s = self.executor.stats()
        s.update(pending=len(self._pending), waves_drained=self.waves_drained)
        return s
